//! Engineering workload: a 2-D Poisson equation (5-point stencil) solved
//! with the distributed solvers — the "physics and engineering" systems the
//! paper's introduction motivates.
//!
//! ```sh
//! cargo run --release --example poisson2d
//! ```
//!
//! The stencil matrix is SPD, so CG and Cholesky both apply; we also run
//! GMRES to show a general method on the same operator, and compare
//! iteration counts and virtual-time makespans.

use cuplss::accel::EngineKind;
use cuplss::cluster::{Cluster, ClusterConfig, Method};
use cuplss::solvers::{IterConfig, IterMethod};
use cuplss::util::fmt;
use cuplss::workloads::Workload;

fn main() -> cuplss::Result<()> {
    let grid = 24; // 24 x 24 interior points -> n = 576
    let n = grid * grid;
    println!("2-D Poisson, {grid}x{grid} grid (n = {n}), 4 ranks\n");

    let cluster = Cluster::new(ClusterConfig {
        ranks: 4,
        tile: 48,
        engine: EngineKind::CpuSerial,
        iter: IterConfig { tol: 1e-9, max_iter: 2_000, restart: 40 },
        ..Default::default()
    })?;

    for method in [
        Method::Iterative(IterMethod::Cg),
        Method::Iterative(IterMethod::Bicgstab),
        Method::Iterative(IterMethod::Gmres),
        Method::Cholesky,
    ] {
        let report = cluster.solve::<f64>(Workload::Poisson2d, n, method)?;
        let iters = report
            .iter_stats
            .map(|(it, _, _)| format!("{it:>4} iters"))
            .unwrap_or_else(|| "  direct".to_string());
        println!(
            "  {:<9} {}  makespan {:>12}  max err {:.2e}",
            report.method,
            iters,
            fmt::secs(report.makespan()),
            report.max_err
        );
        assert!(report.max_err < 1e-5, "{}: {}", report.method, report.max_err);
    }

    println!("\nNote: CG converges far faster than GMRES/BiCGSTAB on this SPD");
    println!("operator, and the direct factorisation costs the most virtual");
    println!("time at this size — the crossover the paper's §2 discusses.");
    Ok(())
}
