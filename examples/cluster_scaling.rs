//! End-to-end validation driver (experiment E7): the full three-layer system
//! on a real workload, sweeping the paper's rank counts with both engine
//! arms, and reporting the headline metric — speedup over the one-CPU serial
//! baseline — from *live* distributed runs (real messages, real tile ops,
//! PJRT-executed Pallas kernels on the accelerated arm).
//!
//! ```sh
//! make artifacts && cargo run --release --example cluster_scaling
//! ```
//!
//! Results are recorded in EXPERIMENTS.md §E7.

use cuplss::accel::EngineKind;
use cuplss::cluster::{Cluster, ClusterConfig, Method};
use cuplss::comm::NetworkModel;
use cuplss::solvers::{IterConfig, IterMethod};
use cuplss::util::fmt;
use cuplss::workloads::Workload;

fn main() -> cuplss::Result<()> {
    // n is CLI-overridable: `cargo run --release --example cluster_scaling -- 2048`
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(1536);
    let tile = 128;
    let ranks_sweep = [1usize, 2, 4, 8, 16];
    let have_artifacts = std::path::Path::new("artifacts/manifest.txt").exists();
    let engines: &[EngineKind] = if have_artifacts {
        &[EngineKind::Accelerated, EngineKind::CpuSerial]
    } else {
        eprintln!("note: artifacts missing; running the ATLAS arm only");
        &[EngineKind::CpuSerial]
    };

    println!("== E7: live cluster scaling, n = {n}, tile = {tile} ==\n");

    for (workload, method, label) in [
        (Workload::DiagDominant, Method::Lu, "LU (Figure 4 live analogue)"),
        (
            Workload::DiagDominant,
            Method::Iterative(IterMethod::Bicgstab),
            "BiCGSTAB (Figure 3 live analogue)",
        ),
    ] {
        println!("-- {label} --");
        // Serial baseline: P = 1, CPU engine (the paper's definition).
        let base = Cluster::new(ClusterConfig {
            ranks: 1,
            tile,
            engine: EngineKind::CpuSerial,
            net: NetworkModel::gigabit_ethernet(),
            iter: IterConfig { tol: 1e-8, max_iter: 400, restart: 30 },
            ..Default::default()
        })?
        .solve::<f32>(workload, n, method)?;
        let t1 = base.makespan();
        println!("   serial baseline: {} (wall {})", fmt::secs(t1), fmt::secs(base.wall_max()));

        for &engine in engines {
            println!("   {}:", engine.label());
            for &ranks in &ranks_sweep {
                let report = Cluster::new(ClusterConfig {
                    ranks,
                    tile,
                    engine,
                    net: NetworkModel::gigabit_ethernet(),
                    iter: IterConfig { tol: 1e-8, max_iter: 400, restart: 30 },
                    ..Default::default()
                })?
                .solve::<f32>(workload, n, method)?;
                println!(
                    "     P={ranks:>2}: makespan {:>12}  speedup {:>6.2}  comm {:>4.1}%  err {:.1e}",
                    fmt::secs(report.makespan()),
                    t1 / report.makespan(),
                    report.comm_fraction() * 100.0,
                    report.max_err,
                );
                assert!(report.max_err < 1e-2, "solution must stay correct at P={ranks}");
            }
        }
        println!();
    }

    println!("(virtual time = calibrated 2008-era cluster model; see DESIGN.md §3)");
    Ok(())
}
