//! Macroeconometric workload: simultaneous-equation country-block systems —
//! the application the paper's authors build CUPLSS for ("from physics and
//! engineering to macroeconometric modeling", and their own [Oancea et al.
//! 2011] reference on parallel algorithms for large econometric models).
//!
//! ```sh
//! cargo run --release --example econometric
//! ```
//!
//! The system couples dense 32-equation country blocks through weak trade
//! links.  We solve it with LU (the robust default for nonsymmetric
//! econometric systems), then compare the nonstationary iterative methods,
//! and sweep rank counts to show the capacity argument: the distributed
//! library handles models that outgrow a single node's memory.

use cuplss::accel::EngineKind;
use cuplss::cluster::{Cluster, ClusterConfig, Method};
use cuplss::solvers::{IterConfig, IterMethod};
use cuplss::util::fmt;
use cuplss::workloads::Workload;

fn main() -> cuplss::Result<()> {
    let n = 768; // 24 country blocks x 32 equations
    println!("Econometric block system, n = {n} (24 countries x 32 equations)\n");

    // Method comparison on 4 ranks.
    let cluster = Cluster::new(ClusterConfig {
        ranks: 4,
        tile: 64,
        engine: EngineKind::CpuSerial,
        iter: IterConfig { tol: 1e-9, max_iter: 1_000, restart: 30 },
        ..Default::default()
    })?;
    for method in [
        Method::Lu,
        Method::Iterative(IterMethod::Bicgstab),
        Method::Iterative(IterMethod::Bicg),
        Method::Iterative(IterMethod::Gmres),
    ] {
        let report = cluster.solve::<f64>(Workload::Econometric, n, method)?;
        println!("  {}", report.summary());
        assert!(report.max_err < 1e-5);
    }

    // Rank sweep with LU: per-rank memory shrinks ~1/P — the paper's point
    // that distribution lets you solve systems no single GPU could hold.
    println!("\nLU rank sweep (per-rank tile memory):");
    for ranks in [1usize, 2, 4, 8] {
        let cluster = Cluster::new(ClusterConfig {
            ranks,
            tile: 64,
            engine: EngineKind::CpuSerial,
            ..Default::default()
        })?;
        let report = cluster.solve::<f64>(Workload::Econometric, n, Method::Lu)?;
        let per_rank_bytes = (n * n * 8) as f64 / ranks as f64;
        println!(
            "  P={ranks:>2}: makespan {:>12}  ~{} per rank",
            fmt::secs(report.makespan()),
            fmt::bytes(per_rank_bytes),
        );
    }
    Ok(())
}
