//! Sparse workload: the 2-D Poisson operator as *distributed CSR*, solved
//! with the operator-generic Krylov solvers — the "very large systems"
//! regime the paper motivates iterative methods with, where dense storage
//! (n² elements for 5n nonzeros) stops making sense.
//!
//! ```sh
//! cargo run --release --example sparse_poisson
//! ```
//!
//! Contrasts the same solve through the dense and the sparse operand
//! (identical iterations — the math doesn't change, only storage and the
//! matvec), then uses model mode to project a paper-scale grid no dense
//! operand could hold.

use std::sync::Arc;

use cuplss::accel::{ComputeProfile, CpuEngine};
use cuplss::bench_harness::model::{sparse_iter_makespan, ModelParams};
use cuplss::comm::{NetworkModel, World};
use cuplss::dist::{gather_vector, Descriptor, DistMatrix, DistVector};
use cuplss::mesh::{Mesh, MeshShape};
use cuplss::pblas::Ctx;
use cuplss::solvers::{cg, gmres, IterConfig, IterMethod};
use cuplss::util::fmt;
use cuplss::workloads::stencil::{poisson2d_nnz, poisson2d_row, stencil_rhs};
use cuplss::workloads::{poisson2d_csr, Workload};

fn main() -> cuplss::Result<()> {
    let g = 24usize; // 24 x 24 interior grid -> n = 576
    let n = g * g;
    let (pr, pc) = (2usize, 2usize);
    let tile = 48usize;
    println!("2-D Poisson, {g}x{g} grid (n = {n}), {} ranks", pr * pc);
    println!(
        "dense operand: {} elements; sparse CSR: {} stored entries\n",
        n * n,
        poisson2d_nnz(g)
    );

    let x_true = |i: usize| ((i as f64) * 0.21).sin() + 1.0;
    let results = World::run::<f64, _, _>(pr * pc, NetworkModel::gigabit_ethernet(), move |comm| {
        let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
        let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(tile)));
        let desc = Descriptor::new(n, n, tile, mesh.shape());
        let b = DistVector::from_fn(desc, mesh.row(), mesh.col(), |i| {
            stencil_rhs(&poisson2d_row::<f64>(g, i), x_true)
        });
        let cfg = IterConfig { tol: 1e-10, max_iter: 2_000, restart: 40 };

        // The same operator, twice: dense block-cyclic and sparse CSR.
        let dense =
            DistMatrix::from_fn(desc, mesh.row(), mesh.col(), Workload::Poisson2d.elem::<f64>(n));
        let sparse = poisson2d_csr::<f64>(desc, mesh.row(), mesh.col());

        let mut report = Vec::new();
        comm.clock().reset();
        let (xd, st) = cg(&ctx, &dense, &b, &cfg)?;
        report.push(("CG", "dense ", st.iterations, comm.clock().now(), xd));
        comm.clock().reset();
        let (xs, st) = cg(&ctx, &sparse, &b, &cfg)?;
        report.push(("CG", "sparse", st.iterations, comm.clock().now(), xs));
        comm.clock().reset();
        let (xg, st) = gmres(&ctx, &sparse, &b, &cfg)?;
        report.push(("GMRES", "sparse", st.iterations, comm.clock().now(), xg));

        let gathered: Vec<_> = report
            .into_iter()
            .map(|(m, fmt_, it, t, x)| (m, fmt_, it, t, gather_vector(&mesh, &x)))
            .collect();
        Ok::<_, cuplss::Error>(gathered)
    });

    for row in results.into_iter().next().unwrap()? {
        let (method, format, iters, vtime, x) = row;
        if let Some(x) = x {
            let err = x
                .iter()
                .enumerate()
                .map(|(i, &xi)| (xi - x_true(i)).abs())
                .fold(0.0f64, f64::max);
            println!(
                "  {method:<6} {format}  {iters:>4} iters  vtime {:>12}  max err {err:.2e}",
                fmt::secs(vtime)
            );
            assert!(err < 1e-6, "{method}/{format}: {err}");
        }
    }

    // Model mode: a 1000x1000 grid (n = 1e6) — the dense operand would
    // need 8 TB; the CSR needs ~5e6 entries.
    let gm = 1_000usize;
    let nm = gm * gm;
    println!("\nModel-mode projection, {gm}x{gm} grid (n = {nm}), 100 CG iterations:");
    for ranks in [1usize, 4, 16] {
        let p = ModelParams {
            tile: 256,
            shape: MeshShape::near_square(ranks),
            net: NetworkModel::gigabit_ethernet(),
            engine: ComputeProfile::q6600_atlas(),
            panel_cpu: ComputeProfile::q6600_atlas(),
            swap_fraction: 0.0,
        };
        let t = sparse_iter_makespan::<f64>(IterMethod::Cg, nm, poisson2d_nnz(gm), 100, 30, &p);
        println!("  P = {ranks:>2}: {}", fmt::secs(t));
    }
    println!("\nNote: on Gigabit Ethernet the halo-free full-vector allgather moves");
    println!("~n elements per matvec regardless of P, so the sparse makespan stops");
    println!("improving with ranks — the honest cost of the simple exchange, and");
    println!("orders of magnitude below the dense operand either way (DESIGN.md §10).");
    println!("\nsparse_poisson OK");
    Ok(())
}
