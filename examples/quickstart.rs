//! Quickstart: solve one dense system with a direct and an iterative method.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Mirrors the paper's usage story: the API hides all distribution — you
//! pick a workload, a method, a rank count and an engine; the library builds
//! the 2-D mesh, distributes the tiles, runs the MPI-style algorithm with
//! engine-accelerated local compute, and hands back the verified solution.

use cuplss::accel::EngineKind;
use cuplss::cluster::{Cluster, ClusterConfig, Method};
use cuplss::solvers::{IterConfig, IterMethod};
use cuplss::workloads::Workload;

fn main() -> cuplss::Result<()> {
    let n = 512;

    // A 4-rank simulated cluster with serial-CPU local compute
    // (the paper's "MPI+ATLAS" arm; switch to EngineKind::Accelerated for
    // the PJRT/Pallas "MPI+CUDA" arm after `make artifacts`).
    let cluster = Cluster::new(ClusterConfig {
        ranks: 4,
        tile: 64,
        engine: EngineKind::CpuSerial,
        iter: IterConfig { tol: 1e-10, max_iter: 500, restart: 30 },
        ..Default::default()
    })?;

    // Direct: blocked LU with partial pivoting.
    let report = cluster.solve::<f64>(Workload::DiagDominant, n, Method::Lu)?;
    println!("{}", report.summary());
    assert!(report.max_err < 1e-8);

    // Iterative: BiCGSTAB on the same workload.
    let report =
        cluster.solve::<f64>(Workload::DiagDominant, n, Method::Iterative(IterMethod::Bicgstab))?;
    println!("{}", report.summary());
    assert!(report.max_err < 1e-6);

    // SPD pairing: Cholesky vs CG.
    let report = cluster.solve::<f64>(Workload::Spd, n, Method::Cholesky)?;
    println!("{}", report.summary());
    let report = cluster.solve::<f64>(Workload::Spd, n, Method::Iterative(IterMethod::Cg))?;
    println!("{}", report.summary());

    println!("quickstart OK");
    Ok(())
}
