//! Integration: PJRT runtime loads the AOT artifacts and the XLA engine
//! agrees numerically with the pure-rust CPU engine on every tile op.
//!
//! Requires `make artifacts` to have run (skips with a message otherwise).

use std::sync::Arc;

use cuplss::accel::{CpuEngine, Engine, XlaEngine};
use cuplss::runtime::Runtime;
use cuplss::util::Prng;

fn runtime() -> Option<Arc<Runtime>> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.txt").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::new(dir).expect("runtime"))
}

const T: usize = 128;

fn rand_tile(rng: &mut Prng) -> Vec<f64> {
    let mut v = vec![0.0f64; T * T];
    rng.fill_normal(&mut v);
    v
}

fn rand_vec(rng: &mut Prng) -> Vec<f64> {
    let mut v = vec![0.0f64; T];
    rng.fill_normal(&mut v);
    v
}

fn lower_unit(rng: &mut Prng) -> Vec<f64> {
    let mut l = vec![0.0f64; T * T];
    for i in 0..T {
        for j in 0..i {
            l[i * T + j] = rng.normal() * 0.1;
        }
        l[i * T + i] = 1.0;
    }
    l
}

fn lower_nonunit(rng: &mut Prng) -> Vec<f64> {
    let mut l = lower_unit(rng);
    for i in 0..T {
        l[i * T + i] = rng.normal().abs() + 1.0;
    }
    l
}

fn upper_nonunit(rng: &mut Prng) -> Vec<f64> {
    let mut u = vec![0.0f64; T * T];
    for i in 0..T {
        for j in i + 1..T {
            u[i * T + j] = rng.normal() * 0.1;
        }
        u[i * T + i] = rng.normal().abs() + 1.0;
    }
    u
}

fn spd_tile(rng: &mut Prng) -> Vec<f64> {
    let g = rand_tile(rng);
    let mut a = vec![0.0f64; T * T];
    for i in 0..T {
        for j in 0..=i {
            let mut s = 0.0;
            for k in 0..T {
                s += g[i * T + k] * g[j * T + k];
            }
            a[i * T + j] = s;
            a[j * T + i] = s;
        }
        a[i * T + i] += T as f64;
    }
    a
}

fn assert_close(a: &[f64], b: &[f64], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len());
    let mut worst = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        worst = worst.max((x - y).abs());
    }
    assert!(worst < tol, "{what}: max abs diff {worst}");
}

#[test]
fn xla_engine_matches_cpu_engine_on_all_ops() {
    let Some(rt) = runtime() else { return };
    let xla = XlaEngine::<f64>::new(&rt, T).expect("xla engine");
    let cpu = CpuEngine::new(T);
    let mut rng = Prng::new(2024);

    // gemm
    let (a, b) = (rand_tile(&mut rng), rand_tile(&mut rng));
    let mut c1 = vec![0.0; T * T];
    let mut c2 = vec![0.0; T * T];
    xla.gemm(&a, &b, &mut c1).unwrap();
    Engine::<f64>::gemm(&cpu, &a, &b, &mut c2).unwrap();
    assert_close(&c1, &c2, 1e-9, "gemm");

    // gemm_update
    let c0 = rand_tile(&mut rng);
    let mut c1 = c0.clone();
    let mut c2 = c0.clone();
    xla.gemm_update(&mut c1, &a, &b).unwrap();
    Engine::<f64>::gemm_update(&cpu, &mut c2, &a, &b).unwrap();
    assert_close(&c1, &c2, 1e-9, "gemm_update");

    // gemm_nt_update
    let mut c1 = c0.clone();
    let mut c2 = c0.clone();
    xla.gemm_nt_update(&mut c1, &a, &b).unwrap();
    Engine::<f64>::gemm_nt_update(&cpu, &mut c2, &a, &b).unwrap();
    assert_close(&c1, &c2, 1e-9, "gemm_nt_update");

    // gemv family
    let x = rand_vec(&mut rng);
    let mut y1 = vec![0.0; T];
    let mut y2 = vec![0.0; T];
    xla.gemv(&a, &x, &mut y1).unwrap();
    Engine::<f64>::gemv(&cpu, &a, &x, &mut y2).unwrap();
    assert_close(&y1, &y2, 1e-9, "gemv");

    xla.gemv_t(&a, &x, &mut y1).unwrap();
    Engine::<f64>::gemv_t(&cpu, &a, &x, &mut y2).unwrap();
    assert_close(&y1, &y2, 1e-9, "gemv_t");

    let y0 = rand_vec(&mut rng);
    let mut y1 = y0.clone();
    let mut y2 = y0.clone();
    xla.gemv_update(&mut y1, &a, &x).unwrap();
    Engine::<f64>::gemv_update(&cpu, &mut y2, &a, &x).unwrap();
    assert_close(&y1, &y2, 1e-9, "gemv_update");

    // triangular block solves
    let l = lower_unit(&mut rng);
    let b0 = rand_tile(&mut rng);
    let mut b1 = b0.clone();
    let mut b2 = b0.clone();
    xla.trsm_llu(&l, &mut b1).unwrap();
    Engine::<f64>::trsm_llu(&cpu, &l, &mut b2).unwrap();
    assert_close(&b1, &b2, 1e-8, "trsm_llu");

    let u = upper_nonunit(&mut rng);
    let mut b1 = b0.clone();
    let mut b2 = b0.clone();
    xla.trsm_ru(&mut b1, &u).unwrap();
    Engine::<f64>::trsm_ru(&cpu, &mut b2, &u).unwrap();
    assert_close(&b1, &b2, 1e-8, "trsm_ru");

    let ln = lower_nonunit(&mut rng);
    let mut b1 = b0.clone();
    let mut b2 = b0.clone();
    xla.trsm_rlt(&mut b1, &ln).unwrap();
    Engine::<f64>::trsm_rlt(&cpu, &mut b2, &ln).unwrap();
    assert_close(&b1, &b2, 1e-8, "trsm_rlt");

    // triangular vector solves
    let v0 = rand_vec(&mut rng);

    let mut v1 = v0.clone();
    let mut v2 = v0.clone();
    xla.trsv_lu(&l, &mut v1).unwrap();
    Engine::<f64>::trsv_lu(&cpu, &l, &mut v2).unwrap();
    assert_close(&v1, &v2, 1e-8, "trsv_lu");

    let mut v1 = v0.clone();
    let mut v2 = v0.clone();
    xla.trsv_l(&ln, &mut v1).unwrap();
    Engine::<f64>::trsv_l(&cpu, &ln, &mut v2).unwrap();
    assert_close(&v1, &v2, 1e-8, "trsv_l");

    let mut v1 = v0.clone();
    let mut v2 = v0.clone();
    xla.trsv_u(&u, &mut v1).unwrap();
    Engine::<f64>::trsv_u(&cpu, &u, &mut v2).unwrap();
    assert_close(&v1, &v2, 1e-8, "trsv_u");

    let mut v1 = v0.clone();
    let mut v2 = v0.clone();
    xla.trsv_lt(&ln, &mut v1).unwrap();
    Engine::<f64>::trsv_lt(&cpu, &ln, &mut v2).unwrap();
    assert_close(&v1, &v2, 1e-8, "trsv_lt");

    // potrf
    let spd = spd_tile(&mut rng);
    let mut a1 = spd.clone();
    let mut a2 = spd.clone();
    xla.potrf(&mut a1).unwrap();
    Engine::<f64>::potrf(&cpu, &mut a2).unwrap();
    assert_close(&a1, &a2, 1e-8, "potrf");
}

#[test]
fn xla_engine_f32_variant_works() {
    let Some(rt) = runtime() else { return };
    let xla = XlaEngine::<f32>::new(&rt, T).expect("f32 engine");
    let mut rng = Prng::new(7);
    let mut a = vec![0.0f32; T * T];
    let mut b = vec![0.0f32; T * T];
    rng.fill_normal(&mut a);
    rng.fill_normal(&mut b);
    let mut c = vec![0.0f32; T * T];
    xla.gemm(&a, &b, &mut c).unwrap();
    let cpu = CpuEngine::new(T);
    let mut want = vec![0.0f32; T * T];
    Engine::<f32>::gemm(&cpu, &a, &b, &mut want).unwrap();
    let mut worst = 0.0f32;
    for (x, y) in c.iter().zip(&want) {
        worst = worst.max((x - y).abs());
    }
    assert!(worst < 1e-2, "f32 gemm diff {worst}");
}

#[test]
fn concurrent_execution_from_many_threads() {
    // The engine is shared across rank threads; PJRT must tolerate
    // concurrent execute calls (validates the Send/Sync wrapper).
    let Some(rt) = runtime() else { return };
    let xla = std::sync::Arc::new(XlaEngine::<f64>::new(&rt, T).expect("engine"));
    let mut handles = Vec::new();
    for seed in 0..8u64 {
        let e = xla.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Prng::new(seed);
            let a = {
                let mut v = vec![0.0f64; T * T];
                rng.fill_normal(&mut v);
                v
            };
            let x = {
                let mut v = vec![0.0f64; T];
                rng.fill_normal(&mut v);
                v
            };
            for _ in 0..5 {
                let mut y = vec![0.0f64; T];
                e.gemv(&a, &x, &mut y).unwrap();
                // spot-check one element
                let want: f64 = (0..T).map(|j| a[j] * x[j]).sum();
                assert!((y[0] - want).abs() < 1e-9);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn runtime_caches_executables() {
    let Some(rt) = runtime() else { return };
    let _e1 = rt.op::<f64>("gemm", T).unwrap();
    let after_first = rt.compiled_count();
    let _e2 = rt.op::<f64>("gemm", T).unwrap();
    assert_eq!(rt.compiled_count(), after_first, "second fetch must hit cache");
}

#[test]
fn manifest_covers_engine_ops() {
    let Some(rt) = runtime() else { return };
    for &op in cuplss::accel::TILE_OPS {
        for dtype in ["f32", "f64"] {
            for tile in [128usize, 256] {
                assert!(
                    rt.manifest().find(op, dtype, tile).is_some(),
                    "missing artifact {op}_{dtype}_{tile}"
                );
            }
        }
    }
}

#[test]
fn executable_rejects_bad_inputs() {
    let Some(rt) = runtime() else { return };
    let exe = rt.op::<f64>("gemm", T).unwrap();
    // wrong arity
    let a = vec![0.0f64; T * T];
    assert!(exe.run::<f64>(&[&a]).is_err());
    // wrong length
    let short = vec![0.0f64; 3];
    assert!(exe.run::<f64>(&[&a, &short]).is_err());
}
