//! Device-residency integration tests (`DESIGN.md` §12).
//!
//! The tile cache only ever re-prices the PCIe share of an op's virtual
//! cost — the math executes identically either way — so every solver must
//! produce **bit-identical** results with the cache enabled vs the paper's
//! copy-per-call streaming flow, on every mesh.  On an accelerated profile
//! the cached run must charge strictly less transfer time (and report the
//! saved bytes); on host profiles (`pcie_bw == 0`) the residency layer is
//! inert and `pcie_saved_bytes` stays exactly 0.

use std::sync::Arc;

use cuplss::accel::{ComputeProfile, CpuEngine, Engine};
use cuplss::comm::{NetworkModel, World};
use cuplss::dist::{gather_matrix, gather_vector, Descriptor, DistMatrix, DistVector};
use cuplss::mesh::{Mesh, MeshShape};
use cuplss::pblas::{pgemm_acc, Ctx};
use cuplss::solvers::{cg, pchol_factor, plu_solve, IterConfig};

const TILE: usize = 8;
const N: usize = 24;

fn engine(gpu: bool) -> Arc<CpuEngine> {
    Arc::new(if gpu {
        CpuEngine::with_profile(TILE, ComputeProfile::gtx280_cublas())
    } else {
        CpuEngine::new(TILE)
    })
}

/// Per-rank virtual-clock observations of one run.
#[derive(Clone, Debug)]
struct Obs {
    bits: Vec<u64>,
    compute: f64,
    transfer: f64,
    pcie_saved: u64,
    launches_fused: u64,
}

/// Run `kernel` on a pr x pc mesh with/without the cache; returns (cached,
/// streaming) observations per rank.  `kernel` returns the result vector to
/// compare bitwise.
fn run_both<F>(pr: usize, pc: usize, gpu: bool, kernel: F) -> (Vec<Obs>, Vec<Obs>)
where
    F: Fn(&Ctx<'_, f64>) -> Vec<f64> + Send + Sync + Copy + 'static,
{
    let run = |cached: bool| -> Vec<Obs> {
        let eng = engine(gpu);
        World::run::<f64, _, _>(pr * pc, NetworkModel::gigabit_ethernet(), move |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
            let ctx = if cached {
                Ctx::new(&mesh, eng.clone() as Arc<dyn Engine<f64>>)
            } else {
                Ctx::streaming(&mesh, eng.clone() as Arc<dyn Engine<f64>>)
            };
            let out = kernel(&ctx);
            Obs {
                bits: out.iter().map(|v| v.to_bits()).collect(),
                compute: comm.clock().compute_secs(),
                transfer: comm.clock().transfer_secs(),
                pcie_saved: comm.stats().pcie_saved_bytes(),
                launches_fused: comm.stats().launches_fused(),
            }
        })
    };
    (run(true), run(false))
}

fn meshes() -> Vec<(usize, usize)> {
    vec![(1, 1), (2, 1), (2, 2)]
}

fn lu_kernel(ctx: &Ctx<'_, f64>) -> Vec<f64> {
    let mesh = ctx.mesh;
    let desc = Descriptor::new(N, N, TILE, mesh.shape());
    let mut a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), |i, j| {
        ((i * 7 + j * 13) as f64 * 0.37).sin() + if i == j { 4.0 } else { 0.0 }
    });
    let b = DistVector::from_fn(desc, mesh.row(), mesh.col(), |i| (i as f64 * 0.21).cos());
    let x = plu_solve(ctx, &mut a, &b).expect("lu solve");
    gather_vector(mesh, &x).unwrap_or_default()
}

fn chol_kernel(ctx: &Ctx<'_, f64>) -> Vec<f64> {
    let mesh = ctx.mesh;
    let desc = Descriptor::new(N, N, TILE, mesh.shape());
    // SPD: diagonally dominant symmetric.
    let mut a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), |i, j| {
        let v = ((i.min(j) * 5 + i.max(j) * 3) as f64 * 0.11).sin() * 0.3;
        if i == j { 6.0 + v } else { v }
    });
    pchol_factor(ctx, &mut a).expect("cholesky");
    gather_matrix(mesh, &a).unwrap_or_default()
}

fn summa_kernel(ctx: &Ctx<'_, f64>) -> Vec<f64> {
    let mesh = ctx.mesh;
    let desc = Descriptor::new(N, N, TILE, mesh.shape());
    let a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), |i, j| {
        ((i + 2 * j) as f64 * 0.1).sin()
    });
    let b = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), |i, j| {
        ((3 * i + j) as f64 * 0.07).cos()
    });
    let mut c = DistMatrix::zeros(desc, mesh.row(), mesh.col());
    pgemm_acc(ctx, &a, &b, &mut c);
    gather_matrix(mesh, &c).unwrap_or_default()
}

fn cg_kernel(ctx: &Ctx<'_, f64>) -> Vec<f64> {
    let mesh = ctx.mesh;
    let desc = Descriptor::new(N, N, TILE, mesh.shape());
    let a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), |i, j| {
        let v = ((i.min(j) * 5 + i.max(j) * 3) as f64 * 0.11).sin() * 0.3;
        if i == j { 6.0 + v } else { v }
    });
    let b = DistVector::from_fn(desc, mesh.row(), mesh.col(), |i| (i as f64 * 0.5).sin());
    let cfg = IterConfig { tol: 1e-12, max_iter: 200, restart: 30 };
    let (x, stats) = cg(ctx, &a, &b, &cfg).expect("cg");
    assert!(stats.converged);
    gather_vector(mesh, &x).unwrap_or_default()
}

fn assert_bit_identical_and_accounted(
    name: &str,
    pr: usize,
    pc: usize,
    gpu: bool,
    cached: &[Obs],
    streaming: &[Obs],
) {
    for (rank, (c, s)) in cached.iter().zip(streaming).enumerate() {
        assert_eq!(
            c.bits, s.bits,
            "{name} {pr}x{pc} gpu={gpu} rank {rank}: cache changed the results"
        );
        assert!(
            (c.compute - s.compute).abs() < 1e-12 * s.compute.max(1.0),
            "{name} {pr}x{pc} rank {rank}: residency must not touch compute time"
        );
        assert_eq!(s.pcie_saved, 0, "streaming run never saves PCIe");
        if gpu {
            assert!(
                c.transfer <= s.transfer + 1e-15,
                "{name} {pr}x{pc} rank {rank}: cached transfer {} > streaming {}",
                c.transfer,
                s.transfer
            );
        } else {
            assert_eq!(c.transfer, 0.0, "host profile streams nothing");
            assert_eq!(c.pcie_saved, 0, "pcie_saved must be 0 when pcie_bw == 0");
        }
    }
    if gpu {
        let saved: u64 = cached.iter().map(|o| o.pcie_saved).sum();
        assert!(saved > 0, "{name} {pr}x{pc}: residency must save PCIe bytes");
        let (ct, st) = (
            cached.iter().map(|o| o.transfer).sum::<f64>(),
            streaming.iter().map(|o| o.transfer).sum::<f64>(),
        );
        assert!(ct < st, "{name} {pr}x{pc}: total transfer must drop ({ct} vs {st})");
    }
}

#[test]
fn lu_bit_identical_with_cache_on_and_off() {
    for (pr, pc) in meshes() {
        for gpu in [false, true] {
            let (c, s) = run_both(pr, pc, gpu, lu_kernel);
            assert_bit_identical_and_accounted("LU", pr, pc, gpu, &c, &s);
        }
    }
}

#[test]
fn cholesky_bit_identical_with_cache_on_and_off() {
    for (pr, pc) in meshes() {
        for gpu in [false, true] {
            let (c, s) = run_both(pr, pc, gpu, chol_kernel);
            assert_bit_identical_and_accounted("Cholesky", pr, pc, gpu, &c, &s);
        }
    }
}

#[test]
fn summa_bit_identical_with_cache_on_and_off() {
    for (pr, pc) in meshes() {
        for gpu in [false, true] {
            let (c, s) = run_both(pr, pc, gpu, summa_kernel);
            assert_bit_identical_and_accounted("SUMMA", pr, pc, gpu, &c, &s);
        }
    }
}

#[test]
fn cg_bit_identical_with_cache_on_and_off() {
    for (pr, pc) in meshes() {
        for gpu in [false, true] {
            let (c, s) = run_both(pr, pc, gpu, cg_kernel);
            assert_bit_identical_and_accounted("CG", pr, pc, gpu, &c, &s);
            // The fused BLAS-1 chain fires in both modes.
            assert!(c.iter().all(|o| o.launches_fused > 0));
            assert_eq!(
                c.iter().map(|o| o.launches_fused).collect::<Vec<_>>(),
                s.iter().map(|o| o.launches_fused).collect::<Vec<_>>(),
            );
        }
    }
}

#[test]
fn tiny_budget_still_correct_just_slower() {
    // A cache two tiles big must thrash, never corrupt: results stay
    // bit-identical and the charged transfer lands between the resident
    // and streaming extremes.
    let eng = engine(true);
    let budget = 2 * TILE * TILE * std::mem::size_of::<f64>();
    let out = World::run::<f64, _, _>(4, NetworkModel::gigabit_ethernet(), move |comm| {
        let mesh = Mesh::new(&comm, MeshShape::new(2, 2));
        let ctx = Ctx::with_device_mem(&mesh, eng.clone() as _, budget);
        let bits = summa_kernel(&ctx);
        (bits, comm.clock().transfer_secs())
    });
    let (full_c, _) = run_both(2, 2, true, summa_kernel);
    for (rank, ((bits, transfer), c)) in out.iter().zip(&full_c).enumerate() {
        assert_eq!(
            bits.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            c.bits,
            "rank {rank}: tiny budget changed results"
        );
        assert!(*transfer >= c.transfer - 1e-15, "thrash can't beat a big cache");
    }
}
