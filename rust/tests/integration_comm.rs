//! Integration + property tests of the message-passing substrate:
//! collectives composed, interleaved on sub-groups, and stressed with
//! seeded random payloads (in-tree property harness, no proptest offline).

use cuplss::comm::{NetworkModel, Payload, ReduceOp, Tag, World};
use cuplss::mesh::{Mesh, MeshShape};
use cuplss::util::prop;

#[test]
fn allreduce_equals_serial_sum_property() {
    // For random world sizes and payload lengths, allreduce == serial sum.
    prop::forall(15, 0xC0FFEE, |rng| {
        let p = 1 + rng.below(8);
        let len = 1 + rng.below(50);
        let seed = rng.next_u64();
        let out = World::run::<f64, _, _>(p, NetworkModel::ideal(), move |comm| {
            let mut local = cuplss::util::Prng::new(seed ^ comm.rank() as u64);
            let mine: Vec<f64> = (0..len).map(|_| local.normal()).collect();
            let got = comm.world().allreduce_vec(1, mine.clone(), ReduceOp::Sum);
            (mine, got)
        });
        let mut want = vec![0.0; len];
        for (mine, _) in &out {
            for (w, m) in want.iter_mut().zip(mine) {
                *w += m;
            }
        }
        for (_, got) in &out {
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-9);
            }
        }
    });
}

#[test]
fn bcast_arbitrary_roots_property() {
    prop::forall(15, 0xBEEF, |rng| {
        let p = 1 + rng.below(9);
        let root = rng.below(p);
        let len = 1 + rng.below(64);
        let out = World::run::<f32, _, _>(p, NetworkModel::ideal(), move |comm| {
            let data = if comm.rank() == root {
                Some(Payload::Data(
                    (0..len).map(|i| (i + root) as f32).collect(),
                ))
            } else {
                None
            };
            comm.world().bcast(root, 9, data).into_data()
        });
        for v in out {
            assert_eq!(v.len(), len);
            assert_eq!(v[0], root as f32);
        }
    });
}

#[test]
fn gather_scatter_inverse_property() {
    prop::forall(10, 0xFACE, |rng| {
        let p = 1 + rng.below(6);
        let root = rng.below(p);
        let out = World::run::<f64, _, _>(p, NetworkModel::ideal(), move |comm| {
            let g = comm.world();
            let mine = vec![comm.rank() as f64; comm.rank() + 1];
            let blocks = g.gather(root, 3, mine.clone());
            let back = g.scatter(root, 4, blocks);
            (mine, back)
        });
        for (mine, back) in out {
            assert_eq!(mine, back, "scatter(gather(x)) == x");
        }
    });
}

#[test]
fn interleaved_collectives_on_row_and_col_groups() {
    // Row and column collectives interleave without cross-matching:
    // every rank does row-allreduce then col-allreduce then world barrier,
    // several times, with tags reused across iterations.
    let (pr, pc) = (3usize, 3usize);
    let out = World::run::<f64, _, _>(pr * pc, NetworkModel::gigabit_ethernet(), move |comm| {
        let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
        let mut acc = 0.0;
        for it in 0..10 {
            let row_sum = mesh
                .row_comm()
                .allreduce_scalar(40, (comm.rank() + it) as f64, ReduceOp::Sum);
            let col_sum = mesh
                .col_comm()
                .allreduce_scalar(41, (comm.rank() * 2 + it) as f64, ReduceOp::Sum);
            mesh.world().barrier(42);
            acc += row_sum + col_sum;
        }
        acc
    });
    // Deterministic expected value per rank.
    for (rank, got) in out.iter().enumerate() {
        let (r, c) = MeshShape::new(pr, pc).coords(rank);
        let mut want = 0.0;
        for it in 0..10 {
            let row_sum: f64 =
                (0..pc).map(|cc| (MeshShape::new(pr, pc).rank_at(r, cc) + it) as f64).sum();
            let col_sum: f64 = (0..pr)
                .map(|rr| (MeshShape::new(pr, pc).rank_at(rr, c) * 2 + it) as f64)
                .sum();
            want += row_sum + col_sum;
        }
        assert!((got - want).abs() < 1e-9, "rank {rank}: {got} vs {want}");
    }
}

#[test]
fn p2p_heavy_crossing_traffic() {
    // All-pairs exchange with per-pair tags: no message may be lost,
    // duplicated or cross-delivered.
    let p = 6usize;
    let out = World::run::<f64, _, _>(p, NetworkModel::ideal(), move |comm| {
        let me = comm.rank();
        for dst in 0..p {
            if dst != me {
                comm.send(
                    dst,
                    Tag::P2p((me * p + dst) as u32),
                    Payload::Data(vec![me as f64, dst as f64]),
                );
            }
        }
        let mut sum = 0.0;
        for src in 0..p {
            if src != me {
                let v = comm.recv(src, Tag::P2p((src * p + me) as u32)).into_data();
                assert_eq!(v, vec![src as f64, me as f64]);
                sum += v[0];
            }
        }
        sum
    });
    let total: f64 = (0..p).map(|r| r as f64).sum();
    for (me, got) in out.iter().enumerate() {
        assert_eq!(*got, total - me as f64);
    }
}

#[test]
fn makespan_reflects_critical_path_chain() {
    // A chain 0 -> 1 -> 2 -> 3 of 1 MiB messages: the last rank's clock must
    // be ~3x the single-hop cost.
    let net = NetworkModel::gigabit_ethernet();
    let elems = (1usize << 20) / 8;
    let out = World::run::<f64, _, _>(4, net, move |comm| {
        let me = comm.rank();
        if me > 0 {
            comm.recv(me - 1, Tag::P2p(me as u32)).into_data();
        }
        if me < 3 {
            comm.send(me + 1, Tag::P2p((me + 1) as u32), Payload::Data(vec![0.0; elems]));
        }
        comm.clock().now()
    });
    let hop = net.p2p_secs(1 << 20);
    assert!((out[3] - 3.0 * hop).abs() < hop * 0.01, "{} vs {}", out[3], 3.0 * hop);
    // rank 0 pays only its own NIC occupancy
    let occupy = (1u64 << 20) as f64 * net.beta;
    assert!((out[0] - occupy).abs() < 1e-12);
}

#[test]
fn split_phase_collectives_bit_identical_and_never_slower() {
    // The overlap acceptance property, end to end: on identical traffic
    // with compute interleaved, the split-phase collectives return values
    // *bit-identical* to the blocking ones (same tree, same combine order)
    // and no rank's overlapped makespan exceeds its blocking one.
    fn run_mode(
        p: usize,
        len: usize,
        seed: u64,
        split: bool,
    ) -> Vec<(Vec<f64>, Vec<Vec<f64>>, f64)> {
        World::run::<f64, _, _>(p, NetworkModel::gigabit_ethernet(), move |comm| {
            let mut local = cuplss::util::Prng::new(seed ^ comm.rank() as u64);
            let mine: Vec<f64> = (0..len).map(|_| local.normal()).collect();
            let g = comm.world();
            let compute = 1e-3; // enough to cover the whole tree latency
            let (sum, all) = if split {
                let red = g.iallreduce_vec(5, mine.clone(), ReduceOp::Sum);
                comm.clock().advance_compute(compute);
                let sum = red.wait();
                let gat = g.iallgather(6, mine.clone());
                comm.clock().advance_compute(compute);
                (sum, gat.wait())
            } else {
                let sum = g.allreduce_vec(5, mine.clone(), ReduceOp::Sum);
                comm.clock().advance_compute(compute);
                let all = g.allgather(6, mine);
                comm.clock().advance_compute(compute);
                (sum, all)
            };
            (sum, all, comm.clock().busy_until())
        })
    }
    prop::forall(12, 0x5EED, |rng| {
        let p = 1 + rng.below(6);
        let len = 1 + rng.below(32);
        let seed = rng.next_u64();
        let blocking = run_mode(p, len, seed, false);
        let split = run_mode(p, len, seed, true);
        for (rank, ((sb, ab, tb), (ss, as_, ts))) in
            blocking.iter().zip(&split).enumerate()
        {
            assert_eq!(sb, ss, "allreduce must be bit-identical (rank {rank})");
            assert_eq!(ab, as_, "allgather must be bit-identical (rank {rank})");
            assert!(
                *ts <= tb + 1e-12,
                "rank {rank}: overlapped {ts} slower than blocking {tb}"
            );
        }
    });
}

#[test]
fn maxabsloc_ties_break_deterministically() {
    // Two ranks contribute the same |value|: everyone must agree on the
    // smaller index.
    let out = World::run::<f64, _, _>(4, NetworkModel::ideal(), |comm| {
        let v = if comm.rank() == 1 || comm.rank() == 3 { -5.0 } else { 1.0 };
        comm.world().allreduce_maxabsloc(7, v, comm.rank() as i64)
    });
    for (v, i) in out {
        assert_eq!(v, -5.0);
        assert_eq!(i, 1, "tie must break to the smaller index");
    }
}
