//! GPUDirect wire integration tests (`DESIGN.md` §16).
//!
//! The wire subsystem only ever re-routes clock occupancy — a device-dirty
//! send payload occupies the NIC and copy-engine timelines jointly instead
//! of paying a serial D2H flush ahead of the send — so every solver must
//! produce **bit-identical** results with GPUDirect enabled vs the
//! host-staged barrier (`--no-gpudirect`), on every mesh.  On an
//! accelerated profile with real cross-rank sends the wire must actually
//! carry bytes (`wire_direct_bytes > 0`) and must never extend the
//! makespan; on host profiles (`pcie_bw == 0`) and for host-clean payloads
//! (SUMMA's read-only panels, the sparse halo's ghost segments) the wire
//! is inert and the counter stays exactly 0.

use std::sync::Arc;

use cuplss::accel::{ComputeProfile, CpuEngine, Engine};
use cuplss::comm::{NetworkModel, World};
use cuplss::dist::{gather_matrix, gather_vector, Descriptor, DistMatrix, DistVector};
use cuplss::mesh::{Mesh, MeshShape};
use cuplss::pblas::{pgemm_acc, pspmv_halo, pspmv_t_halo, Ctx};
use cuplss::solvers::{cg, pchol_factor, plu_solve, IterConfig};
use cuplss::sparse::DistCsrMatrix;
use cuplss::workloads::stencil::poisson2d_row;

const TILE: usize = 8;
const N: usize = 24;

fn engine(gpu: bool) -> Arc<CpuEngine> {
    Arc::new(if gpu {
        CpuEngine::with_profile(TILE, ComputeProfile::gtx280_cublas())
    } else {
        CpuEngine::new(TILE)
    })
}

/// Per-rank virtual-clock observations of one run.
#[derive(Clone, Debug)]
struct Obs {
    bits: Vec<u64>,
    vtime: f64,
    wire_direct: u64,
    stage_saved: f64,
}

/// Run `kernel` on a pr x pc mesh with the wire on/off; returns
/// (gpudirect, host-staged) observations per rank.
fn run_both<F>(pr: usize, pc: usize, gpu: bool, kernel: F) -> (Vec<Obs>, Vec<Obs>)
where
    F: Fn(&Ctx<'_, f64>) -> Vec<f64> + Send + Sync + Copy + 'static,
{
    let run = |gpudirect: bool| -> Vec<Obs> {
        let eng = engine(gpu);
        World::run::<f64, _, _>(pr * pc, NetworkModel::gigabit_ethernet(), move |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
            let ctx = Ctx::new(&mesh, eng.clone() as Arc<dyn Engine<f64>>)
                .with_gpudirect(gpudirect);
            let out = kernel(&ctx);
            Obs {
                bits: out.iter().map(|v| v.to_bits()).collect(),
                vtime: comm.clock().busy_until(),
                wire_direct: comm.stats().wire_direct_bytes(),
                stage_saved: comm.stats().host_stage_saved_secs(),
            }
        })
    };
    (run(true), run(false))
}

/// 1-, 2- and 4-rank meshes.
fn meshes() -> Vec<(usize, usize)> {
    vec![(1, 1), (2, 1), (2, 2)]
}

fn lu_kernel(ctx: &Ctx<'_, f64>) -> Vec<f64> {
    let mesh = ctx.mesh;
    let desc = Descriptor::new(N, N, TILE, mesh.shape());
    let mut a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), |i, j| {
        ((i * 7 + j * 13) as f64 * 0.37).sin() + if i == j { 4.0 } else { 0.0 }
    });
    let b = DistVector::from_fn(desc, mesh.row(), mesh.col(), |i| (i as f64 * 0.21).cos());
    let x = plu_solve(ctx, &mut a, &b).expect("lu solve");
    gather_vector(mesh, &x).unwrap_or_default()
}

fn chol_kernel(ctx: &Ctx<'_, f64>) -> Vec<f64> {
    let mesh = ctx.mesh;
    let desc = Descriptor::new(N, N, TILE, mesh.shape());
    let mut a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), |i, j| {
        let v = ((i.min(j) * 5 + i.max(j) * 3) as f64 * 0.11).sin() * 0.3;
        if i == j { 6.0 + v } else { v }
    });
    pchol_factor(ctx, &mut a).expect("cholesky");
    gather_matrix(mesh, &a).unwrap_or_default()
}

fn summa_kernel(ctx: &Ctx<'_, f64>) -> Vec<f64> {
    let mesh = ctx.mesh;
    let desc = Descriptor::new(N, N, TILE, mesh.shape());
    let a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), |i, j| {
        ((i + 2 * j) as f64 * 0.1).sin()
    });
    let b = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), |i, j| {
        ((3 * i + j) as f64 * 0.07).cos()
    });
    let mut c = DistMatrix::zeros(desc, mesh.row(), mesh.col());
    pgemm_acc(ctx, &a, &b, &mut c);
    gather_matrix(mesh, &c).unwrap_or_default()
}

fn cg_kernel(ctx: &Ctx<'_, f64>) -> Vec<f64> {
    let mesh = ctx.mesh;
    let desc = Descriptor::new(N, N, TILE, mesh.shape());
    let a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), |i, j| {
        let v = ((i.min(j) * 5 + i.max(j) * 3) as f64 * 0.11).sin() * 0.3;
        if i == j { 6.0 + v } else { v }
    });
    let b = DistVector::from_fn(desc, mesh.row(), mesh.col(), |i| (i as f64 * 0.5).sin());
    let cfg = IterConfig { tol: 1e-12, max_iter: 200, restart: 30 };
    let (x, stats) = cg(ctx, &a, &b, &cfg).expect("cg");
    assert!(stats.converged);
    gather_vector(mesh, &x).unwrap_or_default()
}

fn halo_kernel(ctx: &Ctx<'_, f64>) -> Vec<f64> {
    let g = 8usize;
    let mesh = ctx.mesh;
    let desc = Descriptor::new(g * g, g * g, TILE, mesh.shape());
    let a = DistCsrMatrix::from_row_fn(desc, mesh.row(), mesh.col(), move |i| {
        poisson2d_row::<f64>(g, i)
    });
    let x = DistVector::from_fn(desc, mesh.row(), mesh.col(), |i| (i as f64 * 0.37).cos());
    let y = pspmv_halo(ctx, &a, &x);
    let z = pspmv_t_halo(ctx, &a, &y);
    gather_vector(mesh, &z).unwrap_or_default()
}

/// `wire_hits` predicts whether the run must actually put device-dirty
/// payloads on the wire at this (mesh, engine) point — `None` when no
/// claim is made either way (payload cleanliness is the runtime's call).
fn assert_bit_identical_and_rerouted(
    name: &str,
    pr: usize,
    pc: usize,
    gpu: bool,
    wire: &[Obs],
    staged: &[Obs],
    wire_hits: Option<bool>,
) {
    for (rank, (w, s)) in wire.iter().zip(staged).enumerate() {
        assert_eq!(
            w.bits, s.bits,
            "{name} {pr}x{pc} gpu={gpu} rank {rank}: GPUDirect changed the results"
        );
        assert_eq!(s.wire_direct, 0, "host-staged arm must never touch the wire");
        assert_eq!(s.stage_saved, 0.0, "host-staged arm saves no staging");
        if !gpu {
            assert_eq!(w.wire_direct, 0, "host profile: the wire is inert");
            assert_eq!(w.stage_saved, 0.0, "host profile: nothing to save");
        }
    }
    // Re-routing PCIe under the NIC occupancy can never extend the
    // makespan relative to staging it serially ahead of the send.
    let (wm, sm) = (
        wire.iter().map(|o| o.vtime).fold(0.0, f64::max),
        staged.iter().map(|o| o.vtime).fold(0.0, f64::max),
    );
    assert!(wm <= sm + 1e-12, "{name} {pr}x{pc} gpu={gpu}: wire makespan {wm} > staged {sm}");
    if let Some(hits) = wire_hits {
        let bytes: u64 = wire.iter().map(|o| o.wire_direct).sum();
        if hits {
            assert!(bytes > 0, "{name} {pr}x{pc} gpu={gpu}: dirty payloads must ride the wire");
        } else {
            assert_eq!(bytes, 0, "{name} {pr}x{pc} gpu={gpu}: host-clean payloads stay off");
        }
    }
}

#[test]
fn lu_bit_identical_with_gpudirect_on_and_off() {
    for (pr, pc) in meshes() {
        for gpu in [false, true] {
            let (w, s) = run_both(pr, pc, gpu, lu_kernel);
            // Panel gathers + U12 column broadcasts send device-dirty
            // tiles whenever there is more than one process row.
            let hits = Some(gpu && pr > 1);
            assert_bit_identical_and_rerouted("LU", pr, pc, gpu, &w, &s, hits);
        }
    }
}

#[test]
fn cholesky_bit_identical_with_gpudirect_on_and_off() {
    for (pr, pc) in meshes() {
        for gpu in [false, true] {
            let (w, s) = run_both(pr, pc, gpu, chol_kernel);
            // The panel row broadcasts send trsm_rlt outputs (device-dirty)
            // whenever there is more than one process column; single-column
            // meshes make no runtime cleanliness claim (the L11 tile may
            // have been host-cleaned by the potrf).
            let hits = if gpu && pc > 1 { Some(true) } else if !gpu { Some(false) } else { None };
            assert_bit_identical_and_rerouted("Cholesky", pr, pc, gpu, &w, &s, hits);
        }
    }
}

#[test]
fn summa_bit_identical_and_a_wash_with_gpudirect_on_and_off() {
    for (pr, pc) in meshes() {
        for gpu in [false, true] {
            let (w, s) = run_both(pr, pc, gpu, summa_kernel);
            // SUMMA broadcasts read-only input panels: host-clean, so the
            // wire must carry nothing on either arm.
            assert_bit_identical_and_rerouted("SUMMA", pr, pc, gpu, &w, &s, Some(false));
        }
    }
}

#[test]
fn cg_bit_identical_with_gpudirect_on_and_off() {
    for (pr, pc) in meshes() {
        for gpu in [false, true] {
            let (w, s) = run_both(pr, pc, gpu, cg_kernel);
            // The matvec's partial-sum allreduce sends device-dirty blocks
            // whenever the process row has more than one member.
            let hits = Some(gpu && pc > 1);
            assert_bit_identical_and_rerouted("CG", pr, pc, gpu, &w, &s, hits);
        }
    }
}

#[test]
fn halo_spmv_bit_identical_and_ghosts_stay_off_the_wire() {
    for (pr, pc) in [(1usize, 1usize), (2, 1), (4, 1)] {
        for gpu in [false, true] {
            let (w, s) = run_both(pr, pc, gpu, halo_kernel);
            // Sparse matvecs run on the host arm: the ghost segments are
            // host-clean, so the halo wire composes with GPUDirect as an
            // exact wash — zero direct bytes, identical results.
            assert_bit_identical_and_rerouted("halo SpMV", pr, pc, gpu, &w, &s, Some(false));
        }
    }
}

#[test]
fn gpudirect_saves_host_staging_where_prefetch_had_flushes_in_flight() {
    // The LU gather sends tiles whose write-back flush the prefetch
    // subsystem already had in flight: routing them straight to the NIC
    // revokes the flush wait — the stage-saved counter must see it.
    let mut total = 0.0;
    for (pr, pc) in [(2usize, 1usize), (2, 2)] {
        let (w, _s) = run_both(pr, pc, true, lu_kernel);
        total += w.iter().map(|o| o.stage_saved).sum::<f64>();
    }
    assert!(total >= 0.0);
    let bytes: u64 = {
        let (w, _s) = run_both(2, 2, true, lu_kernel);
        w.iter().map(|o| o.wire_direct).sum()
    };
    assert!(bytes > 0, "the accelerated multi-row LU must use the wire");
}
