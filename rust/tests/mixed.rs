//! Mixed-precision integration tests (`DESIGN.md` §17).
//!
//! Three contracts, exercised through the public API:
//!
//! * **Accuracy** — an f32 factorization plus wide iterative refinement
//!   reaches the *f64* backward-error bound (`refine_bound`) on every
//!   mesh shape, and the f64-accumulate Krylov solvers recover the known
//!   solution from f32 storage; an unrefinable system (Hilbert) must
//!   report `converged = false` rather than spin or lie.
//! * **Honesty** — at `S = f64` the `_mixed` Krylov routines are the
//!   uniform solvers bit for bit, and the cluster's `mixed_precision`
//!   knob is exactly inert where the gate is closed (the host arm):
//!   `--no-mixed` vs default is a bit-identical wash.
//! * **Reporting** — gate probes (`mixed_capable`, `mixed_advantage`,
//!   `model_mixed_engaged`) agree across layers, and uniform runs carry
//!   zeroed mixed fields in [`SolveReport`].
//!
//! The accelerated-arm end-to-end (narrow tiles + wide correction through
//! the XLA engine) is gated on `make artifacts`, like the other XLA tests.

use std::sync::Arc;

use cuplss::accel::{ComputeProfile, CpuEngine, EngineKind};
use cuplss::bench_harness::model::model_mixed_engaged;
use cuplss::bench_harness::ModelParams;
use cuplss::cluster::{Cluster, ClusterConfig, Method};
use cuplss::comm::{NetworkModel, World};
use cuplss::dist::{Descriptor, DistMatrix, DistVector};
use cuplss::mesh::{Mesh, MeshShape};
use cuplss::pblas::Ctx;
use cuplss::solvers::{
    bicgstab, bicgstab_mixed, cg, cg_mixed, pchol_solve_refined, plu_solve_refined, refine_bound,
    IterConfig, IterMethod, REFINE_MAX_SWEEPS,
};
use cuplss::workloads::Workload;
use cuplss::{mixed_capable, DEFAULT_TILE};

const TILE: usize = 8;

fn artifacts_dir() -> String {
    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
}

fn have_artifacts() -> bool {
    std::path::Path::new(&artifacts_dir()).join("manifest.txt").exists()
}

/// Per-rank worst forward error of the owned blocks of `x` against the
/// workload's known solution.
fn worst_err(
    x: &DistVector<f64>,
    desc: &Descriptor,
    mesh_row: usize,
    n: usize,
    xt: &impl Fn(usize) -> f64,
) -> f64 {
    let mut worst = 0.0f64;
    for l in 0..x.local_blocks() {
        let ti = desc.global_ti(mesh_row, l);
        for (i, &v) in x.block(l).iter().enumerate() {
            let g = ti * desc.tile + i;
            if g < n {
                worst = worst.max((v - xt(g)).abs());
            }
        }
    }
    worst
}

/// Refined f32-factor LU / Cholesky on the *workload* generators reach the
/// wide backward-error bound on square, ragged and single-rank meshes —
/// the accuracy the cluster's mixed direct path promises.
#[test]
fn refined_direct_solves_meet_the_wide_bound_on_workload_operators() {
    for &(pr, pc, n) in &[(1usize, 1usize, 32usize), (2, 1, 40), (2, 2, 45)] {
        for &(workload, method) in
            &[(Workload::DiagDominant, "lu"), (Workload::Spd, "chol")]
        {
            let out =
                World::run::<f32, _, _>(pr * pc, NetworkModel::gigabit_ethernet(), move |comm| {
                    let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
                    let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(TILE)));
                    let desc = Descriptor::new(n, n, TILE, mesh.shape());
                    let elem = workload.elem::<f64>(n);
                    let a_hi =
                        DistMatrix::<f64>::from_fn(desc, mesh.row(), mesh.col(), elem.clone());
                    let b_hi = DistVector::<f64>::from_fn(
                        desc,
                        mesh.row(),
                        mesh.col(),
                        workload.rhs::<f64>(n),
                    );
                    let mut a_lo =
                        DistMatrix::<f32>::from_fn(desc, mesh.row(), mesh.col(), move |i, j| {
                            elem(i, j) as f32
                        });
                    let (x, st) = if method == "lu" {
                        plu_solve_refined(&ctx, &mut a_lo, &a_hi, &b_hi).unwrap()
                    } else {
                        pchol_solve_refined(&ctx, &mut a_lo, &a_hi, &b_hi).unwrap()
                    };
                    let xt = workload.x_true::<f64>(n);
                    (st.sweeps, st.converged, st.backward_err, worst_err(&x, &desc, mesh.row(), n, &xt))
                });
            for (sweeps, converged, berr, worst) in out {
                assert!(converged, "{method} {pr}x{pc} n={n}: berr {berr}");
                assert!(
                    (1..=REFINE_MAX_SWEEPS).contains(&sweeps),
                    "{method} {pr}x{pc}: f32 factors must need 1..={REFINE_MAX_SWEEPS} sweeps, got {sweeps}"
                );
                assert!(berr <= refine_bound::<f32>(n), "{method}: berr {berr}");
                // Far beyond what an unrefined f32 solve could reach.
                assert!(worst < 1e-9, "{method} {pr}x{pc} n={n}: worst {worst}");
            }
        }
    }
}

/// A system whose condition number swamps f32 factors must come back
/// `converged = false` (or a factorization breakdown) — that flag is what
/// routes the cluster layer to its uniform-precision fallback.
#[test]
fn unrefinable_system_reports_failure_instead_of_lying() {
    let n = 24;
    let out = World::run::<f32, _, _>(2, NetworkModel::gigabit_ethernet(), move |comm| {
        let mesh = Mesh::new(&comm, MeshShape::new(2, 1));
        let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(TILE)));
        let desc = Descriptor::new(n, n, TILE, mesh.shape());
        let elem = |i: usize, j: usize| 1.0 / ((i + j + 1) as f64);
        let a_hi = DistMatrix::<f64>::from_fn(desc, mesh.row(), mesh.col(), elem);
        let b_hi = DistVector::<f64>::from_fn(desc, mesh.row(), mesh.col(), |i| {
            (0..n).map(|j| elem(i, j)).sum()
        });
        let mut a_lo = DistMatrix::<f32>::from_fn(desc, mesh.row(), mesh.col(), move |i, j| {
            elem(i, j) as f32
        });
        match plu_solve_refined(&ctx, &mut a_lo, &a_hi, &b_hi) {
            Ok((_, st)) => (!st.converged, st.sweeps),
            Err(_) => (true, 0),
        }
    });
    for (fell_back, sweeps) in out {
        assert!(fell_back, "refinement claimed convergence on a Hilbert system");
        assert!(sweeps <= REFINE_MAX_SWEEPS, "stagnation guard must cap the sweep count");
    }
}

/// At `S = f64` (`Hi = Self`) the mixed Krylov solvers ARE the uniform
/// solvers, bit for bit — every scalar of the recurrence and every entry
/// of the answer.  This is the `--no-mixed` honesty contract the cluster
/// relies on.
#[test]
fn mixed_krylov_at_f64_is_bit_identical_to_uniform() {
    let n = 48;
    for spd in [true, false] {
        let out = World::run::<f64, _, _>(4, NetworkModel::gigabit_ethernet(), move |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(2, 2));
            let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(TILE)));
            let desc = Descriptor::new(n, n, TILE, mesh.shape());
            let workload = if spd { Workload::Spd } else { Workload::DiagDominant };
            let a = DistMatrix::<f64>::from_fn(
                desc,
                mesh.row(),
                mesh.col(),
                workload.elem::<f64>(n),
            );
            let b =
                DistVector::<f64>::from_fn(desc, mesh.row(), mesh.col(), workload.rhs::<f64>(n));
            let cfg = IterConfig { tol: 1e-11, max_iter: 400, restart: 30 };
            let (xp, sp) = if spd {
                cg(&ctx, &a, &b, &cfg).unwrap()
            } else {
                bicgstab(&ctx, &a, &b, &cfg).unwrap()
            };
            let (xm, sm) = if spd {
                cg_mixed(&ctx, &a, &b, &cfg).unwrap()
            } else {
                bicgstab_mixed(&ctx, &a, &b, &cfg).unwrap()
            };
            let plain_bits: Vec<Vec<u64>> = (0..xp.local_blocks())
                .map(|l| xp.block(l).iter().map(|v| v.to_bits()).collect())
                .collect();
            let mixed_bits: Vec<Vec<u64>> = (0..xm.local_blocks())
                .map(|l| xm.block(l).iter().map(|v| v.to_bits()).collect())
                .collect();
            (
                plain_bits,
                mixed_bits,
                sp.iterations,
                sm.iterations,
                sp.converged && sm.converged,
                sp.rel_residual.to_bits(),
                sm.rel_residual.to_bits(),
            )
        });
        for (pb, mb, pit, mit, conv, pres, mres) in out {
            assert!(conv, "spd={spd}: both arms must converge");
            assert_eq!(pit, mit, "spd={spd}: same iteration count");
            assert_eq!(pres, mres, "spd={spd}: same final residual, bit for bit");
            assert_eq!(pb, mb, "spd={spd}: same answer, bit for bit");
        }
    }
}

/// In an f32 world the wide accumulators must still recover the known
/// solution: f32 storage, f32 wire payloads, f64 dot products.
#[test]
fn mixed_krylov_at_f32_recovers_the_known_solution() {
    let n = 40;
    for spd in [true, false] {
        let out = World::run::<f32, _, _>(4, NetworkModel::gigabit_ethernet(), move |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(2, 2));
            let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(TILE)));
            let desc = Descriptor::new(n, n, TILE, mesh.shape());
            let workload = if spd { Workload::Spd } else { Workload::DiagDominant };
            let a = DistMatrix::<f32>::from_fn(
                desc,
                mesh.row(),
                mesh.col(),
                workload.elem::<f32>(n),
            );
            let b =
                DistVector::<f32>::from_fn(desc, mesh.row(), mesh.col(), workload.rhs::<f32>(n));
            let cfg = IterConfig { tol: 1e-5, max_iter: 400, restart: 30 };
            let (x, st) = if spd {
                cg_mixed(&ctx, &a, &b, &cfg).unwrap()
            } else {
                bicgstab_mixed(&ctx, &a, &b, &cfg).unwrap()
            };
            let xt = workload.x_true::<f64>(n);
            let mut worst = 0.0f64;
            for l in 0..x.local_blocks() {
                let ti = desc.global_ti(mesh.row(), l);
                for (i, &v) in x.block(l).iter().enumerate() {
                    let g = ti * desc.tile + i;
                    if g < n {
                        worst = worst.max((v as f64 - xt(g)).abs());
                    }
                }
            }
            (st.converged, st.iterations, worst)
        });
        for (converged, iterations, worst) in out {
            assert!(converged, "spd={spd}: mixed Krylov must converge at 1e-5");
            assert!(iterations > 0 && iterations < 400);
            assert!(worst < 1e-3, "spd={spd}: worst forward error {worst}");
        }
    }
}

/// The gate probes agree across layers: dtype capability, engine profile
/// advantage, and the cost-model twin gate composed from them.
#[test]
fn gate_probes_agree_across_layers() {
    assert!(mixed_capable::<f64>(), "f64 has a narrower storage dtype (f32)");
    assert!(!mixed_capable::<f32>(), "f32 has nothing narrower to drop to");
    assert!(ComputeProfile::gtx280_cublas().mixed_advantage());
    assert!(!ComputeProfile::q6600_atlas().mixed_advantage());
    for gpu in [false, true] {
        let p = ModelParams {
            tile: DEFAULT_TILE,
            shape: MeshShape::near_square(4),
            net: NetworkModel::gigabit_ethernet(),
            engine: if gpu {
                ComputeProfile::gtx280_cublas()
            } else {
                ComputeProfile::q6600_atlas()
            },
            panel_cpu: ComputeProfile::q6600_atlas(),
            swap_fraction: 0.5,
            device_mem: cuplss::accel::DEFAULT_DEVICE_MEM,
        };
        assert_eq!(model_mixed_engaged::<f64>(&p), gpu);
        assert!(!model_mixed_engaged::<f32>(&p));
    }
}

/// On the host arm the gate is closed (`mixed_advantage` is false for the
/// Q6600 profile), so `mixed_precision: false` must change *nothing*:
/// same answer bits, same virtual time, zeroed mixed report fields.
#[test]
fn no_mixed_knob_is_exactly_inert_on_the_host_arm() {
    let solve = |mixed: bool, workload: Workload, n: usize, method: Method| {
        Cluster::new(ClusterConfig {
            mixed_precision: mixed,
            ..ClusterConfig::small(4, TILE)
        })
        .unwrap()
        .solve::<f64>(workload, n, method)
        .unwrap()
    };
    let cases: &[(Workload, usize, Method)] = &[
        (Workload::DiagDominant, 48, Method::Lu),
        (Workload::Spd, 48, Method::Cholesky),
        (Workload::Spd, 48, Method::Iterative(IterMethod::Cg)),
        (Workload::DiagDominant, 48, Method::Iterative(IterMethod::Bicgstab)),
    ];
    for &(w, n, m) in cases {
        let on = solve(true, w, n, m);
        let off = solve(false, w, n, m);
        for r in [&on, &off] {
            assert_eq!(r.refine_iters, 0, "{}: host arm never refines", m.name());
            assert_eq!(r.bytes_saved_mixed, 0, "{}: host arm saves no bytes", m.name());
            assert!(!r.mixed_fallback, "{}: nothing to fall back from", m.name());
        }
        assert_eq!(
            on.max_err.to_bits(),
            off.max_err.to_bits(),
            "{}: --no-mixed must be a bit-identical wash on the host arm",
            m.name()
        );
        assert_eq!(
            on.makespan().to_bits(),
            off.makespan().to_bits(),
            "{}: same virtual time too",
            m.name()
        );
        assert_eq!(on.total_bytes(), off.total_bytes(), "{}: same wire traffic", m.name());
    }
}

/// End to end on the accelerated arm (gate open): the mixed path must hold
/// f64 accuracy while reporting its narrow-precision work — refinement
/// sweeps for the direct solvers, saved wire bytes for both families —
/// and the `--no-mixed` arm must report none of it.
#[test]
fn mixed_cluster_end_to_end_on_the_accelerated_arm() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let solve = |mixed: bool, workload: Workload, n: usize, method: Method| {
        Cluster::new(ClusterConfig {
            ranks: 4,
            tile: 128,
            engine: EngineKind::Accelerated,
            artifact_dir: artifacts_dir(),
            mixed_precision: mixed,
            iter: IterConfig { tol: 1e-9, max_iter: 400, restart: 30 },
            ..Default::default()
        })
        .expect("accelerated cluster")
        .solve::<f64>(workload, n, method)
        .unwrap()
    };
    // Direct: f32 tiles through the XLA factor path + wide refinement.
    for (w, m) in [(Workload::DiagDominant, Method::Lu), (Workload::Spd, Method::Cholesky)] {
        let on = solve(true, w, 200, m);
        assert!(on.max_err < 1e-6, "{}: mixed path holds f64 accuracy, got {}", m.name(), on.max_err);
        if !on.mixed_fallback {
            assert!(on.refine_iters >= 1, "{}: narrow factors need sweeps", m.name());
            assert!(on.bytes_saved_mixed > 0, "{}: narrow wire must save bytes", m.name());
        }
        let off = solve(false, w, 200, m);
        assert!(off.max_err < 1e-6);
        assert_eq!(off.refine_iters, 0);
        assert_eq!(off.bytes_saved_mixed, 0);
        assert!(!off.mixed_fallback);
    }
    // Krylov: f32 storage world with f64 accumulators.  The tolerance must
    // clear the f32 storage floor (~n*eps32) or the honest fallback fires
    // and the narrow arm never gets to report its savings.
    let on = Cluster::new(ClusterConfig {
        ranks: 4,
        tile: 128,
        engine: EngineKind::Accelerated,
        artifact_dir: artifacts_dir(),
        mixed_precision: true,
        iter: IterConfig { tol: 1e-4, max_iter: 400, restart: 30 },
        ..Default::default()
    })
    .unwrap()
    .solve::<f64>(Workload::Spd, 200, Method::Iterative(IterMethod::Cg))
    .unwrap();
    assert!(!on.mixed_fallback, "1e-4 is reachable from f32 storage");
    assert!(on.max_err < 1e-2, "mixed CG forward error {}", on.max_err);
    assert_eq!(on.refine_iters, 0, "mixed Krylov refines nothing");
    assert!(on.bytes_saved_mixed > 0, "f32 payloads must save wire bytes");
    let (_, _, conv) = on.iter_stats.unwrap();
    assert!(conv);
}
