//! Integration: batched multi-RHS solves vs looped single-RHS solves.
//!
//! The contract (`DESIGN.md` §14): batching changes *cost accounting and
//! communication shape only* — per-column arithmetic is untouched.  So a
//! k-column panel solve must reproduce k looped single solves **bit for
//! bit** (LU, Cholesky, blocked CG), on every mesh, including edge tiles
//! (n not a multiple of the tile) and the k = 1 degenerate panel.
//! Block BiCGSTAB is pinned bitwise at k = 1 and to solver accuracy for
//! k > 1 (its breakdown handling is per-column "lite" masking).

use std::sync::Arc;

use cuplss::accel::CpuEngine;
use cuplss::comm::{NetworkModel, World};
use cuplss::dist::{gather_vector, Descriptor, DistMatrix, DistMultiVector, DistVector};
use cuplss::mesh::{Mesh, MeshShape};
use cuplss::pblas::Ctx;
use cuplss::solvers::{
    bicgstab, block_bicgstab, block_cg, cg, pchol_solve, pchol_solve_panel, plu_solve,
    plu_solve_panel, IterConfig,
};

/// Deterministic dense SPD test matrix (same on all ranks).
fn spd_elem(n: usize) -> impl Fn(usize, usize) -> f64 + Clone + Send + Sync {
    move |i, j| {
        let base = (((i * 37 + j * 61) % 97) as f64) / 97.0 - 0.5;
        let sym = base + ((((j * 37 + i * 61) % 97) as f64) / 97.0 - 0.5);
        if i == j {
            2.0 * n as f64 + sym
        } else {
            sym * 0.5
        }
    }
}

/// Deterministic diagonally-dominant nonsymmetric matrix.
fn nonsym_elem(n: usize) -> impl Fn(usize, usize) -> f64 + Clone + Send + Sync {
    move |i, j| {
        let v = (((i * 13 + j * 29 + 7) % 101) as f64) / 101.0 - 0.5;
        if i == j {
            n as f64 + 1.0 + v
        } else {
            v
        }
    }
}

fn rhs_elem(n: usize, elem: &impl Fn(usize, usize) -> f64, i: usize) -> f64 {
    let xt = |j: usize| ((j as f64) * 0.21).sin() + 1.0;
    (0..n).map(|j| elem(i, j) * xt(j)).sum()
}

/// Per-column RHS coefficients: exact in floating point (`serve`'s
/// `rhs_coeff` scheme), so `coeff * b` scales without rounding surprises.
const COEFFS: &[f64] = &[1.0, 1.625, 1.25];

/// 1 / 2 / 4 ranks — the panel paths must not care about the mesh shape.
const MESHES: &[(usize, usize)] = &[(1, 1), (1, 2), (2, 2)];

/// Gather every column of a batched solve and of k looped single solves;
/// assert bitwise equality per element.
fn assert_bitwise(batch: &[Vec<f64>], looped: &[Vec<f64>], what: &str, pr: usize, pc: usize) {
    assert_eq!(batch.len(), looped.len());
    for (j, (xb, xs)) in batch.iter().zip(looped).enumerate() {
        assert_eq!(xb.len(), xs.len());
        for i in 0..xb.len() {
            assert!(
                xb[i].to_bits() == xs[i].to_bits(),
                "{what} mesh {pr}x{pc} col {j} row {i}: batched {} != single {}",
                xb[i],
                xs[i]
            );
        }
    }
}

/// Run `which` ("lu" | "chol") batched-vs-looped on one mesh; k columns.
fn direct_panel_vs_looped(n: usize, tile: usize, pr: usize, pc: usize, which: &'static str) {
    let k = COEFFS.len();
    let out = World::run::<f64, _, _>(pr * pc, NetworkModel::gigabit_ethernet(), move |comm| {
        let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
        let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(tile)));
        let desc = Descriptor::new(n, n, tile, mesh.shape());
        let spd = which == "chol";
        let a0 = if spd {
            DistMatrix::from_fn(desc, mesh.row(), mesh.col(), spd_elem(n))
        } else {
            DistMatrix::from_fn(desc, mesh.row(), mesh.col(), nonsym_elem(n))
        };
        let rhs = move |i: usize| {
            if spd {
                rhs_elem(n, &spd_elem(n), i)
            } else {
                rhs_elem(n, &nonsym_elem(n), i)
            }
        };
        let bp = DistMultiVector::from_fn(desc, mesh.row(), mesh.col(), k, |i, j| {
            COEFFS[j] * rhs(i)
        });

        // Batched: one factorization, RHS-panel substitutions.
        let mut a = a0.clone();
        let xp = if spd {
            pchol_solve_panel(&ctx, &mut a, &bp).expect("panel chol")
        } else {
            plu_solve_panel(&ctx, &mut a, &bp).expect("panel lu")
        };
        let batch: Vec<Vec<f64>> =
            (0..k).map(|j| gather_vector(&mesh, xp.col(j))).collect();

        // Looped: k full single-RHS solves (fresh factorization each).
        let looped: Vec<Vec<f64>> = (0..k)
            .map(|j| {
                let b = DistVector::from_fn(desc, mesh.row(), mesh.col(), move |i| {
                    COEFFS[j] * rhs(i)
                });
                let mut a = a0.clone();
                let x = if spd {
                    pchol_solve(&ctx, &mut a, &b).expect("single chol")
                } else {
                    plu_solve(&ctx, &mut a, &b).expect("single lu")
                };
                gather_vector(&mesh, &x)
            })
            .collect();
        (batch, looped)
    });
    // Gathers land on rank 0 only.
    let (batch, looped) = out.into_iter().next().unwrap();
    let (batch, looped): (Vec<Vec<f64>>, Vec<Vec<f64>>) = (
        batch.into_iter().map(|c| c.unwrap()).collect(),
        looped.into_iter().map(|c| c.unwrap()).collect(),
    );
    assert_bitwise(&batch, &looped, which, pr, pc);
}

#[test]
fn plu_panel_matches_looped_singles_bitwise() {
    // n = 45, tile = 8: edge tiles + identity padding on the last panel —
    // the non-divisible case the RHS panel must survive.
    for &(pr, pc) in MESHES {
        direct_panel_vs_looped(45, 8, pr, pc, "lu");
    }
}

#[test]
fn pchol_panel_matches_looped_singles_bitwise() {
    for &(pr, pc) in MESHES {
        direct_panel_vs_looped(42, 8, pr, pc, "chol");
    }
}

#[test]
fn block_cg_matches_looped_cg_bitwise_with_mixed_tolerances() {
    let (n, tile) = (48usize, 8usize);
    let k = COEFFS.len();
    // Mixed per-column targets: columns converge at different iterations,
    // so the masking path is exercised, not just the all-active sweep.
    let tols = [1e-8, 1e-10, 1e-6];
    for &(pr, pc) in MESHES {
        let out =
            World::run::<f64, _, _>(pr * pc, NetworkModel::gigabit_ethernet(), move |comm| {
                let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
                let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(tile)));
                let desc = Descriptor::new(n, n, tile, mesh.shape());
                let a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), spd_elem(n));
                let rhs = move |i: usize| rhs_elem(n, &spd_elem(n), i);
                let bp = DistMultiVector::from_fn(desc, mesh.row(), mesh.col(), k, |i, j| {
                    COEFFS[j] * rhs(i)
                });
                let cfg = IterConfig { tol: 1e-8, max_iter: 400, restart: 30 };
                let (xp, stats) = block_cg(&ctx, &a, &bp, &cfg, &tols).expect("block cg");
                let batch: Vec<Vec<f64>> =
                    (0..k).map(|j| gather_vector(&mesh, xp.col(j))).collect();
                let mut looped = Vec::new();
                let mut looped_stats = Vec::new();
                for j in 0..k {
                    let b = DistVector::from_fn(desc, mesh.row(), mesh.col(), move |i| {
                        COEFFS[j] * rhs(i)
                    });
                    let cfg_j = IterConfig { tol: tols[j], ..cfg };
                    let (x, st) = cg(&ctx, &a, &b, &cfg_j).expect("single cg");
                    looped.push(gather_vector(&mesh, &x));
                    looped_stats.push((st.iterations, st.converged));
                }
                let batch_stats: Vec<(usize, bool)> =
                    stats.iter().map(|s| (s.iterations, s.converged)).collect();
                (batch, looped, batch_stats, looped_stats)
            });
        let (batch, looped, bs, ls) = out.into_iter().next().unwrap();
        let (batch, looped): (Vec<Vec<f64>>, Vec<Vec<f64>>) = (
            batch.into_iter().map(|c| c.unwrap()).collect(),
            looped.into_iter().map(|c| c.unwrap()).collect(),
        );
        assert_bitwise(&batch, &looped, "block_cg", pr, pc);
        // Same convergence story, column by column.
        assert_eq!(bs, ls, "mesh {pr}x{pc}: per-column iteration counts differ");
    }
}

#[test]
fn k_1_panels_are_the_single_rhs_path_bitwise() {
    // The degenerate batch: a one-column panel is *defined* as the single
    // path (plu_solve/pchol_solve route through it), and the block Krylov
    // solvers must collapse to their scalar recurrences.
    let (n, tile, pr, pc) = (40usize, 8usize, 2usize, 2usize);
    let out = World::run::<f64, _, _>(pr * pc, NetworkModel::gigabit_ethernet(), move |comm| {
        let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
        let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(tile)));
        let desc = Descriptor::new(n, n, tile, mesh.shape());
        let cfg = IterConfig { tol: 1e-9, max_iter: 400, restart: 30 };

        let a_spd = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), spd_elem(n));
        let b_spd = DistVector::from_fn(desc, mesh.row(), mesh.col(), move |i| {
            rhs_elem(n, &spd_elem(n), i)
        });
        let bp_spd = DistMultiVector::from_cols(vec![b_spd.clone_vec()]);
        let (x1, s1) = block_cg(&ctx, &a_spd, &bp_spd, &cfg, &[cfg.tol]).expect("block cg");
        let (x0, s0) = cg(&ctx, &a_spd, &b_spd, &cfg).expect("cg");

        let a_ns = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), nonsym_elem(n));
        let b_ns = DistVector::from_fn(desc, mesh.row(), mesh.col(), move |i| {
            rhs_elem(n, &nonsym_elem(n), i)
        });
        let bp_ns = DistMultiVector::from_cols(vec![b_ns.clone_vec()]);
        let (y1, t1) =
            block_bicgstab(&ctx, &a_ns, &bp_ns, &cfg, &[cfg.tol]).expect("block bicgstab");
        let (y0, t0) = bicgstab(&ctx, &a_ns, &b_ns, &cfg).expect("bicgstab");

        (
            gather_vector(&mesh, x1.col(0)),
            gather_vector(&mesh, &x0),
            (s1[0].iterations, s1[0].converged, s0.iterations, s0.converged),
            gather_vector(&mesh, y1.col(0)),
            gather_vector(&mesh, &y0),
            (t1[0].iterations, t1[0].converged, t0.iterations, t0.converged),
        )
    });
    let (x1, x0, s, y1, y0, t) = out.into_iter().next().unwrap();
    let (x1, x0, y1, y0) = (x1.unwrap(), x0.unwrap(), y1.unwrap(), y0.unwrap());
    assert_bitwise(&[x1], &[x0], "block_cg k=1", pr, pc);
    assert_bitwise(&[y1], &[y0], "block_bicgstab k=1", pr, pc);
    assert_eq!(s.0, s.2, "cg iteration count");
    assert_eq!(s.1, s.3, "cg convergence flag");
    assert_eq!(t.0, t.2, "bicgstab iteration count");
    assert_eq!(t.1, t.3, "bicgstab convergence flag");
}

#[test]
fn block_bicgstab_solves_k_rhs_to_solver_accuracy() {
    // k > 1 BiCGSTAB: pinned to accuracy (not bits — its per-column
    // breakdown masking is "lite", DESIGN.md §14) against known answers.
    let (n, tile) = (40usize, 8usize);
    let k = COEFFS.len();
    for &(pr, pc) in MESHES {
        let out =
            World::run::<f64, _, _>(pr * pc, NetworkModel::gigabit_ethernet(), move |comm| {
                let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
                let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(tile)));
                let desc = Descriptor::new(n, n, tile, mesh.shape());
                let a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), nonsym_elem(n));
                let rhs = move |i: usize| rhs_elem(n, &nonsym_elem(n), i);
                let bp = DistMultiVector::from_fn(desc, mesh.row(), mesh.col(), k, |i, j| {
                    COEFFS[j] * rhs(i)
                });
                let cfg = IterConfig { tol: 1e-10, max_iter: 400, restart: 30 };
                let (xp, stats) =
                    block_bicgstab(&ctx, &a, &bp, &cfg, &[1e-10; 3]).expect("block bicgstab");
                let cols: Vec<Vec<f64>> =
                    (0..k).map(|j| gather_vector(&mesh, xp.col(j))).collect();
                let conv: Vec<bool> = stats.iter().map(|s| s.converged).collect();
                (cols, conv)
            });
        let (cols, conv) = out.into_iter().next().unwrap();
        assert!(conv.iter().all(|&c| c), "mesh {pr}x{pc}: all columns converge");
        for (j, col) in cols.into_iter().enumerate() {
            let col = col.unwrap();
            for i in 0..n {
                let want = COEFFS[j] * (((i as f64) * 0.21).sin() + 1.0);
                assert!(
                    (col[i] - want).abs() < 1e-7,
                    "mesh {pr}x{pc} col {j} row {i}: {} vs {want}",
                    col[i]
                );
            }
        }
    }
}
