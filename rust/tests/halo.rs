//! Integration: the neighbor-exchange (halo) distribution — bit-identity
//! against the allgather path, wire volume pinned to the coupling surface,
//! the Krylov solvers routed through the halo `LinOp`, the Schur and
//! block-Jacobi consumers, and the plan invariants under random sparsity
//! (`DESIGN.md` §15).

use std::collections::BTreeSet;
use std::sync::Arc;

use cuplss::accel::CpuEngine;
use cuplss::comm::{NetworkModel, World};
use cuplss::dist::{gather_vector, Descriptor, DistVector};
use cuplss::mesh::{Mesh, MeshShape};
use cuplss::pblas::{pspmv, pspmv_halo, pspmv_t, pspmv_t_halo, Ctx};
use cuplss::solvers::{bicgstab, cg, pcg, schur_cg, BlockJacobiPrecond, IterConfig};
use cuplss::sparse::{DistCsrMatrix, HaloCsr};
use cuplss::util::prop;
use cuplss::workloads::stencil::{poisson2d_csr, poisson2d_row, stencil_rhs};

fn x_true(i: usize) -> f64 {
    ((i as f64) * 0.21).sin() + 1.0
}

fn x_probe(i: usize) -> f64 {
    ((i as f64) * 0.37).cos() + 0.25
}

/// Nonsymmetric banded test pattern: diagonal plus ±1 and ±5 bands with
/// different weights, so the transpose path is genuinely distinct.
fn band_rows(m: usize) -> impl Fn(usize) -> Vec<(usize, f64)> + Clone + Send + Sync {
    move |i| {
        let mut r = vec![(i, 6.0 + ((i * 3) % 5) as f64)];
        if i >= 1 {
            r.push((i - 1, -1.0));
        }
        if i + 1 < m {
            r.push((i + 1, -1.5));
        }
        if i >= 5 {
            r.push((i - 5, 0.25));
        }
        if i + 5 < m {
            r.push((i + 5, 0.75));
        }
        r
    }
}

const MESHES: &[(usize, usize)] = &[(1, 1), (2, 1), (2, 2), (4, 1)];

/// Forward and transpose halo matvecs must reproduce the allgather results
/// bit for bit on every rank's every block — padding included.
fn check_bit_identity(m: usize, tile: usize) {
    for &(pr, pc) in MESHES {
        World::run::<f64, _, _>(pr * pc, NetworkModel::gigabit_ethernet(), move |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
            let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(tile)));
            let desc = Descriptor::new(m, m, tile, mesh.shape());
            let a = DistCsrMatrix::from_row_fn(desc, mesh.row(), mesh.col(), band_rows(m));
            let x = DistVector::from_fn(desc, mesh.row(), mesh.col(), x_probe);
            let y_ag = pspmv(&ctx, &a, &x);
            let y_ha = pspmv_halo(&ctx, &a, &x);
            let z_ag = pspmv_t(&ctx, &a, &x);
            let z_ha = pspmv_t_halo(&ctx, &a, &x);
            for l in 0..y_ag.local_blocks() {
                for (k, (u, v)) in y_ag.block(l).iter().zip(y_ha.block(l)).enumerate() {
                    assert_eq!(
                        u.to_bits(),
                        v.to_bits(),
                        "forward drift m={m} mesh {pr}x{pc} block {l} elem {k}: {u} vs {v}"
                    );
                }
                for (k, (u, v)) in z_ag.block(l).iter().zip(z_ha.block(l)).enumerate() {
                    assert_eq!(
                        u.to_bits(),
                        v.to_bits(),
                        "transpose drift m={m} mesh {pr}x{pc} block {l} elem {k}: {u} vs {v}"
                    );
                }
            }
        });
    }
}

#[test]
fn halo_matvecs_bit_identical_even_meshes() {
    check_bit_identity(12, 4); // every rank's blocks full
}

#[test]
fn halo_matvecs_bit_identical_ragged() {
    check_bit_identity(13, 4); // non-divisible n: padded edge block
    check_bit_identity(11, 3); // odd tile too
}

/// A block-diagonal tail: ranks owning only uncoupled rows have zero
/// neighbors, send nothing, and still agree with the serial oracle.
#[test]
fn empty_neighbor_ranks_are_exact_and_silent() {
    let (n, tile, pr) = (16usize, 4usize, 4usize);
    // Rows 0..8 couple across tiles 0 and 1 (ranks 0, 1); rows 8.. are
    // diagonal-only, so ranks 2 and 3 exchange nothing.
    let rows = move |i: usize| {
        let mut r = vec![(i, 5.0 + i as f64)];
        if i < 8 {
            if i >= 4 {
                r.push((i - 4, -1.0));
            }
            if i + 4 < 8 {
                r.push((i + 4, -2.0));
            }
        }
        r
    };
    let out = World::run::<f64, _, _>(pr, NetworkModel::gigabit_ethernet(), move |comm| {
        let mesh = Mesh::new(&comm, MeshShape::new(pr, 1));
        let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(tile)));
        let desc = Descriptor::new(n, n, tile, mesh.shape());
        let a = DistCsrMatrix::from_row_fn(desc, mesh.row(), mesh.col(), rows);
        let x = DistVector::from_fn(desc, mesh.row(), mesh.col(), x_probe);
        let neighbors = a.halo_plan(&mesh.col_comm(), 91).neighbors();
        let before = comm.stats().bytes_sent();
        let y = pspmv_halo(&ctx, &a, &x);
        let sent = comm.stats().bytes_sent() - before;
        let y_ag = pspmv(&ctx, &a, &x);
        for l in 0..y.local_blocks() {
            for (u, v) in y.block(l).iter().zip(y_ag.block(l)) {
                assert_eq!(u.to_bits(), v.to_bits(), "halo vs allgather drift");
            }
        }
        (neighbors, sent, gather_vector(&mesh, &y))
    });
    // Ranks 0 and 1 talk to exactly each other; ranks 2 and 3 are silent.
    assert_eq!(out[0].0, 1);
    assert_eq!(out[1].0, 1);
    assert_eq!(out[2].0, 0, "rank 2 owns uncoupled rows: no neighbors");
    assert_eq!(out[3].0, 0);
    assert_eq!(out[2].1, 0, "no neighbors must mean zero bytes on the wire");
    assert_eq!(out[3].1, 0);
    // Serial oracle.
    let y = out.into_iter().next().unwrap().2.unwrap();
    for i in 0..n {
        let want: f64 = rows(i).into_iter().map(|(j, v)| v * x_probe(j)).sum();
        assert!((y[i] - want).abs() < 1e-12, "row {i}: {} vs {want}", y[i]);
    }
}

/// The per-matvec wire volume is exactly the coupling surface (send-list
/// elements x 8 bytes for f64), not the allgather's O(n) ring.
#[test]
fn wire_volume_is_the_coupling_surface() {
    let g = 8usize;
    let (pr, tile) = (4usize, 4usize);
    let n = g * g;
    let out = World::run::<f64, _, _>(pr, NetworkModel::gigabit_ethernet(), move |comm| {
        let mesh = Mesh::new(&comm, MeshShape::new(pr, 1));
        let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(tile)));
        let desc = Descriptor::new(n, n, tile, mesh.shape());
        let a = DistCsrMatrix::from_row_fn(desc, mesh.row(), mesh.col(), move |i| {
            poisson2d_row::<f64>(g, i)
        });
        let x = DistVector::from_fn(desc, mesh.row(), mesh.col(), x_probe);
        // Warm both plans so only the steady-state wire remains.
        let _ = pspmv(&ctx, &a, &x);
        let _ = pspmv_halo(&ctx, &a, &x);
        let (send_elems, ghost_elems) = {
            let plan = a.halo_plan(&mesh.col_comm(), 91);
            (plan.send_elems(), plan.ghost_elems())
        };
        let before = comm.stats().bytes_sent();
        let _ = pspmv_halo(&ctx, &a, &x);
        let halo_bytes = comm.stats().bytes_sent() - before;
        let before = comm.stats().bytes_sent();
        let _ = pspmv(&ctx, &a, &x);
        let ag_bytes = comm.stats().bytes_sent() - before;
        (halo_bytes, ag_bytes, send_elems, ghost_elems)
    });
    let mut total_send = 0usize;
    let mut total_ghost = 0usize;
    for (r, &(halo, ag, send_elems, ghost_elems)) in out.iter().enumerate() {
        assert_eq!(
            halo,
            send_elems as u64 * 8,
            "rank {r}: halo wire must be exactly the send lists ({send_elems} elems)"
        );
        assert!(
            halo < ag,
            "rank {r}: halo {halo} B must undercut the allgather's {ag} B"
        );
        total_send += send_elems;
        total_ghost += ghost_elems;
    }
    // What everyone sends is what everyone receives.
    assert_eq!(total_send, total_ghost, "global send/ghost element conservation");
}

/// CG and BiCGSTAB through the halo `LinOp`: bit-identical trajectory to
/// the allgather operator (same iterations, same solution bits) and
/// correct against the known solution.
#[test]
fn krylov_through_the_halo_operator() {
    for &(g, tile) in &[(6usize, 4usize), (5, 4)] {
        let n = g * g;
        for &(pr, pc) in MESHES {
            let out =
                World::run::<f64, _, _>(pr * pc, NetworkModel::gigabit_ethernet(), move |comm| {
                    let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
                    let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(tile)));
                    let desc = Descriptor::new(n, n, tile, mesh.shape());
                    let a = poisson2d_csr::<f64>(desc, mesh.row(), mesh.col());
                    let b = DistVector::from_fn(desc, mesh.row(), mesh.col(), |i| {
                        stencil_rhs(&poisson2d_row::<f64>(g, i), x_true)
                    });
                    let cfg = IterConfig { tol: 1e-12, max_iter: 2_000, restart: 30 };
                    let halo = HaloCsr::new(a.clone());
                    let (x_ag, st_ag) = cg(&ctx, &a, &b, &cfg).expect("cg allgather");
                    let (x_ha, st_ha) = cg(&ctx, &halo, &b, &cfg).expect("cg halo");
                    assert!(st_ag.converged && st_ha.converged);
                    assert_eq!(
                        st_ag.iterations, st_ha.iterations,
                        "bit-identical matvecs must give the identical trajectory"
                    );
                    for l in 0..x_ag.local_blocks() {
                        for (u, v) in x_ag.block(l).iter().zip(x_ha.block(l)) {
                            assert_eq!(u.to_bits(), v.to_bits(), "cg solution drift");
                        }
                    }
                    let (x_bs, st_bs) = bicgstab(&ctx, &halo, &b, &cfg).expect("bicgstab halo");
                    assert!(st_bs.converged);
                    (gather_vector(&mesh, &x_ha), gather_vector(&mesh, &x_bs))
                });
            let (x_cg, x_bs) = out.into_iter().next().unwrap();
            let (x_cg, x_bs) = (x_cg.unwrap(), x_bs.unwrap());
            for i in 0..n {
                assert!(
                    (x_cg[i] - x_true(i)).abs() < 1e-8,
                    "halo cg g={g} mesh {pr}x{pc} x[{i}]"
                );
                assert!(
                    (x_bs[i] - x_true(i)).abs() < 1e-7,
                    "halo bicgstab g={g} mesh {pr}x{pc} x[{i}]"
                );
            }
        }
    }
}

/// The two halo consumers — Schur sub-structuring and block-Jacobi PCG —
/// land on the plain-CG solution across mesh shapes.
#[test]
fn schur_and_block_jacobi_agree_with_cg() {
    let (g, tile) = (5usize, 4usize);
    let n = g * g;
    for &pr in &[1usize, 2, 4] {
        let out = World::run::<f64, _, _>(pr, NetworkModel::gigabit_ethernet(), move |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(pr, 1));
            let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(tile)));
            let desc = Descriptor::new(n, n, tile, mesh.shape());
            let a = poisson2d_csr::<f64>(desc, mesh.row(), mesh.col());
            let b = DistVector::from_fn(desc, mesh.row(), mesh.col(), |i| {
                stencil_rhs(&poisson2d_row::<f64>(g, i), x_true)
            });
            let cfg = IterConfig { tol: 1e-10, max_iter: 2_000, restart: 30 };
            let inner = IterConfig { tol: 1e-13, max_iter: 2_000, restart: 30 };
            let (x_cg, st_cg) = cg(&ctx, &a, &b, &cfg).expect("cg");
            let (x_sc, st_sc) = schur_cg(&ctx, &a, &b, &cfg, &inner).expect("schur");
            let m = BlockJacobiPrecond::build(&ctx, &a, inner);
            let (x_pc, st_pc) = pcg(&ctx, &a, &m, &b, &cfg).expect("pcg");
            assert!(st_cg.converged && st_pc.converged);
            assert!(
                st_pc.iterations <= st_cg.iterations + 2,
                "block-Jacobi must not slow CG down ({} vs {})",
                st_pc.iterations,
                st_cg.iterations
            );
            if pr == 1 {
                // One rank: the block is the whole operator, so the
                // preconditioner is (numerically) A^{-1}.
                assert!(st_pc.iterations <= 3, "exact block solve: {}", st_pc.iterations);
                assert_eq!(st_sc.outer.iterations, 0, "serial Schur is one local solve");
                assert_eq!(st_sc.interface_unknowns, 0);
            } else {
                assert!(st_sc.interface_unknowns > 0 && st_sc.interface_unknowns < n);
            }
            (
                gather_vector(&mesh, &x_cg),
                gather_vector(&mesh, &x_sc),
                gather_vector(&mesh, &x_pc),
            )
        });
        let (x_cg, x_sc, x_pc) = out.into_iter().next().unwrap();
        let (x_cg, x_sc, x_pc) = (x_cg.unwrap(), x_sc.unwrap(), x_pc.unwrap());
        for i in 0..n {
            assert!((x_cg[i] - x_true(i)).abs() < 1e-8, "cg pr={pr} x[{i}]");
            assert!((x_sc[i] - x_cg[i]).abs() < 1e-7, "schur pr={pr} x[{i}]");
            assert!((x_pc[i] - x_cg[i]).abs() < 1e-7, "pcg pr={pr} x[{i}]");
        }
    }
}

/// Property: over random sparsity patterns the plan's send/recv lists are
/// symmetric across ranks, the ghosts cover exactly the off-block columns,
/// and `local_mut` invalidates the cache (the rebuild is identical).
#[test]
fn plan_invariants_on_random_sparsity() {
    prop::forall(8, 0xa1_0_5eed, |rng| {
        let n = 8 + rng.below(33); // 8..=40
        let tile = 2 + rng.below(4); // 2..=5
        let pr = 2 + rng.below(3); // 2..=4
        let pattern: Arc<Vec<Vec<(usize, f64)>>> = Arc::new(
            (0..n)
                .map(|i| {
                    let mut r = vec![(i, 4.0 + rng.uniform())];
                    for _ in 0..(1 + rng.below(3)) {
                        let j = rng.below(n);
                        if j != i {
                            r.push((j, rng.range(-1.0, 1.0)));
                        }
                    }
                    r
                })
                .collect(),
        );
        let out = World::run::<f64, _, _>(pr, NetworkModel::ideal(), {
            let pattern = pattern.clone();
            move |comm| {
                let mesh = Mesh::new(&comm, MeshShape::new(pr, 1));
                let desc = Descriptor::new(n, n, tile, mesh.shape());
                let rows = {
                    let pattern = pattern.clone();
                    move |i: usize| pattern[i].clone()
                };
                let mut a = DistCsrMatrix::from_row_fn(desc, mesh.row(), mesh.col(), rows);
                let col = mesh.col_comm();
                let (ghost, recv, send, diag_nnz, off_nnz) = {
                    let plan = a.halo_plan(&col, 91);
                    (
                        plan.ghost_cols.clone(),
                        plan.recv.clone(),
                        plan.send.clone(),
                        plan.diag_local.nnz(),
                        plan.off_ghost.nnz(),
                    )
                };
                // Coverage: ghosts are exactly the distinct off-block columns.
                let me = mesh.row();
                let mut want = BTreeSet::new();
                for li in 0..a.local().nrows() {
                    for &c in a.local().row(li).0 {
                        if (c / tile) % pr != me {
                            want.insert(c);
                        }
                    }
                }
                assert_eq!(ghost, want.into_iter().collect::<Vec<_>>());
                assert_eq!(diag_nnz + off_nnz, a.local_nnz(), "split halves partition");
                // Invalidation: a value edit drops the cache; the rebuild
                // over the unchanged pattern is identical.
                assert!(a.halo_is_cached());
                a.local_mut();
                assert!(!a.halo_is_cached(), "local_mut must invalidate the plan");
                {
                    let plan = a.halo_plan(&col, 91);
                    assert_eq!(plan.ghost_cols, ghost);
                    assert_eq!(plan.send, send);
                }
                (recv, send)
            }
        });
        // Symmetry: what i receives from j is what j sends to i.
        for i in 0..pr {
            for j in 0..pr {
                assert_eq!(
                    out[i].0[j], out[j].1[i],
                    "recv[{i}<-{j}] must mirror send[{j}->{i}] (n={n} tile={tile} pr={pr})"
                );
            }
        }
    });
}
