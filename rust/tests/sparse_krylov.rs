//! Integration: the Krylov solvers on sparse (distributed CSR) operands —
//! Poisson stencils across the mesh shapes of the paper's rank sweep —
//! checked against the dense operand path and the serial oracle.

use std::sync::Arc;

use cuplss::accel::CpuEngine;
use cuplss::comm::{NetworkModel, World};
use cuplss::dist::{gather_vector, Descriptor, DistVector};
use cuplss::mesh::{Mesh, MeshShape};
use cuplss::pblas::Ctx;
use cuplss::solvers::{bicg, bicgstab, cg, gmres, pipecg, IterConfig, JacobiPrecond};
use cuplss::sparse::{CsrMatrix, DistCsrMatrix};
use cuplss::workloads::stencil::{
    poisson2d_csr, poisson2d_row, poisson3d_csr, poisson3d_row, stencil_rhs,
};
use cuplss::workloads::Workload;

fn x_true(i: usize) -> f64 {
    ((i as f64) * 0.21).sin() + 1.0
}

const MESHES: &[(usize, usize)] = &[(1, 1), (2, 1), (1, 2), (2, 2), (4, 1)];

/// Solve the n = g² 2-D Poisson system with `which` on a sparse operand,
/// returning the gathered solution.
fn solve_sparse_2d(
    g: usize,
    tile: usize,
    pr: usize,
    pc: usize,
    which: &'static str,
) -> Vec<f64> {
    let n = g * g;
    let out = World::run::<f64, _, _>(pr * pc, NetworkModel::gigabit_ethernet(), move |comm| {
        let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
        let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(tile)));
        let desc = Descriptor::new(n, n, tile, mesh.shape());
        let a = poisson2d_csr::<f64>(desc, mesh.row(), mesh.col());
        let b = DistVector::from_fn(desc, mesh.row(), mesh.col(), |i| {
            stencil_rhs(&poisson2d_row::<f64>(g, i), x_true)
        });
        let cfg = IterConfig { tol: 1e-12, max_iter: 2_000, restart: 30 };
        let (x, st) = match which {
            "cg" => cg(&ctx, &a, &b, &cfg).expect("cg"),
            "pipecg" => pipecg(&ctx, &a, &b, &cfg).expect("pipecg"),
            "bicg" => bicg(&ctx, &a, &b, &cfg).expect("bicg"),
            "bicgstab" => bicgstab(&ctx, &a, &b, &cfg).expect("bicgstab"),
            "gmres" => gmres(&ctx, &a, &b, &cfg).expect("gmres"),
            _ => unreachable!(),
        };
        assert!(st.converged, "{which} on {pr}x{pc}: residual {}", st.rel_residual);
        gather_vector(&mesh, &x)
    });
    out.into_iter().next().unwrap().unwrap()
}

fn check_2d(which: &'static str, g: usize, tile: usize, tol: f64) {
    let n = g * g;
    for &(pr, pc) in MESHES {
        let x = solve_sparse_2d(g, tile, pr, pc, which);
        for i in 0..n {
            assert!(
                (x[i] - x_true(i)).abs() < tol,
                "{which} g={g} mesh {pr}x{pc} x[{i}] = {} vs {}",
                x[i],
                x_true(i)
            );
        }
    }
}

#[test]
fn sparse_cg_all_meshes() {
    check_2d("cg", 6, 4, 1e-8); // n = 36: 9 tile rows, uneven split across process rows
    check_2d("cg", 5, 4, 1e-8); // n = 25: non-divisible, padded edge block
}

#[test]
fn sparse_pipecg_all_meshes() {
    // The pipelined recurrences must land on the same solution through the
    // split-phase pspmv + fused overlapped reduction, on every mesh shape.
    check_2d("pipecg", 6, 4, 1e-8);
    check_2d("pipecg", 5, 4, 1e-8);
}

#[test]
fn sparse_pipecg_converges_like_cg_and_hides_latency() {
    let g = 6usize;
    let n = g * g;
    let out = World::run::<f64, _, _>(4, NetworkModel::gigabit_ethernet(), move |comm| {
        let mesh = Mesh::new(&comm, MeshShape::new(2, 2));
        let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(4)));
        let desc = Descriptor::new(n, n, 4, mesh.shape());
        let a = poisson2d_csr::<f64>(desc, mesh.row(), mesh.col());
        let b = DistVector::from_fn(desc, mesh.row(), mesh.col(), |i| {
            stencil_rhs(&poisson2d_row::<f64>(g, i), x_true)
        });
        let cfg = IterConfig { tol: 1e-10, max_iter: 2_000, restart: 30 };
        let (_, st_cg) = cg(&ctx, &a, &b, &cfg).expect("cg");
        let (_, st_pipe) = pipecg(&ctx, &a, &b, &cfg).expect("pipecg");
        (st_cg.iterations, st_pipe.iterations, comm.stats().wait_saved_secs())
    });
    for &(it_cg, it_pipe, saved) in &out {
        // Same Krylov space: iteration counts agree up to round-off drift.
        assert!(
            (it_cg as i64 - it_pipe as i64).unsigned_abs() <= 5,
            "CG {it_cg} vs PipeCG {it_pipe} iterations"
        );
        assert!(saved > 0.0, "overlap must hide some latency");
    }
}

#[test]
fn sparse_gmres_all_meshes() {
    check_2d("gmres", 5, 4, 1e-7);
}

#[test]
fn sparse_bicg_and_bicgstab_exercise_the_transpose_path() {
    check_2d("bicg", 5, 4, 1e-7);
    check_2d("bicgstab", 5, 4, 1e-7);
}

/// CG on the sparse operand and on the dense operand (same operator, same
/// rhs) must agree with each other and with the serial dense oracle.
#[test]
fn sparse_matches_dense_operand_and_serial_oracle() {
    let g = 5usize;
    let n = g * g;
    // Serial oracle: dense CG... via direct dense solve from linalg.
    let elem = Workload::Poisson2d.elem::<f64>(n);
    let mut dense: Vec<f64> = (0..n * n).map(|k| elem(k / n, k % n)).collect();
    let mut oracle: Vec<f64> =
        (0..n).map(|i| stencil_rhs(&poisson2d_row::<f64>(g, i), x_true)).collect();
    cuplss::linalg::lu_solve(n, &mut dense, &mut oracle).expect("serial oracle");

    for &(pr, pc) in &[(2usize, 2usize), (1, 2)] {
        let out = World::run::<f64, _, _>(pr * pc, NetworkModel::gigabit_ethernet(), move |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
            let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(4)));
            let desc = Descriptor::new(n, n, 4, mesh.shape());
            let elem = Workload::Poisson2d.elem::<f64>(n);
            let ad = cuplss::dist::DistMatrix::from_fn(desc, mesh.row(), mesh.col(), elem);
            let asp = poisson2d_csr::<f64>(desc, mesh.row(), mesh.col());
            let b = DistVector::from_fn(desc, mesh.row(), mesh.col(), |i| {
                stencil_rhs(&poisson2d_row::<f64>(g, i), x_true)
            });
            let cfg = IterConfig { tol: 1e-12, max_iter: 1_000, restart: 30 };
            let (xd, std_) = cg(&ctx, &ad, &b, &cfg).expect("dense cg");
            let (xs, sts) = cg(&ctx, &asp, &b, &cfg).expect("sparse cg");
            assert!(std_.converged && sts.converged);
            (gather_vector(&mesh, &xd), gather_vector(&mesh, &xs))
        });
        let (xd, xs) = out[0].clone();
        let (xd, xs) = (xd.unwrap(), xs.unwrap());
        for i in 0..n {
            assert!((xd[i] - oracle[i]).abs() < 1e-7, "dense vs oracle at {i} ({pr}x{pc})");
            assert!((xs[i] - oracle[i]).abs() < 1e-7, "sparse vs oracle at {i} ({pr}x{pc})");
            assert!((xd[i] - xs[i]).abs() < 1e-8, "dense vs sparse at {i} ({pr}x{pc})");
        }
    }
}

#[test]
fn sparse_cg_3d_poisson() {
    let g = 3usize;
    let n = g * g * g; // 27
    let out = World::run::<f64, _, _>(4, NetworkModel::gigabit_ethernet(), move |comm| {
        let mesh = Mesh::new(&comm, MeshShape::new(2, 2));
        let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(4)));
        let desc = Descriptor::new(n, n, 4, mesh.shape());
        let a = poisson3d_csr::<f64>(desc, mesh.row(), mesh.col());
        let b = DistVector::from_fn(desc, mesh.row(), mesh.col(), |i| {
            stencil_rhs(&poisson3d_row::<f64>(g, i), x_true)
        });
        let cfg = IterConfig { tol: 1e-12, max_iter: 500, restart: 30 };
        let (x, st) = cg(&ctx, &a, &b, &cfg).expect("3d cg");
        assert!(st.converged);
        gather_vector(&mesh, &x)
    });
    let x = out[0].as_ref().unwrap();
    for i in 0..n {
        assert!((x[i] - x_true(i)).abs() < 1e-8, "x[{i}]");
    }
}

/// The sparse matvec path must charge the virtual clock: nonzero compute
/// everywhere, nonzero communication time on multi-rank meshes.
#[test]
fn sparse_path_charges_the_virtual_clock() {
    let g = 6usize;
    let n = g * g;
    let out = World::run::<f64, _, _>(4, NetworkModel::gigabit_ethernet(), move |comm| {
        let mesh = Mesh::new(&comm, MeshShape::new(2, 2));
        let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(4)));
        let desc = Descriptor::new(n, n, 4, mesh.shape());
        let a = poisson2d_csr::<f64>(desc, mesh.row(), mesh.col());
        let b = DistVector::from_fn(desc, mesh.row(), mesh.col(), |i| {
            stencil_rhs(&poisson2d_row::<f64>(g, i), x_true)
        });
        comm.clock().reset();
        let cfg = IterConfig { tol: 1e-10, max_iter: 500, restart: 30 };
        let _ = cg(&ctx, &a, &b, &cfg).expect("cg");
        let c = comm.clock();
        (c.compute_secs(), c.comm_wait_secs(), c.now())
    });
    for &(comp, _, now) in &out {
        assert!(comp > 0.0 && now > 0.0, "compute must be charged: {out:?}");
    }
    assert!(
        out.iter().any(|&(_, cw, _)| cw > 0.0),
        "multi-rank sparse CG must spend communication time: {out:?}"
    );
}

/// Jacobi preconditioning composes with the sparse operand: build from the
/// CSR diagonal, scale operator + rhs, solve, unscale.
#[test]
fn jacobi_precond_on_sparse_operand() {
    let g = 5usize;
    let n = g * g;
    let out = World::run::<f64, _, _>(4, NetworkModel::gigabit_ethernet(), move |comm| {
        let mesh = Mesh::new(&comm, MeshShape::new(2, 2));
        let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(4)));
        let desc = Descriptor::new(n, n, 4, mesh.shape());
        let mut a = poisson2d_csr::<f64>(desc, mesh.row(), mesh.col());
        let mut b = DistVector::from_fn(desc, mesh.row(), mesh.col(), |i| {
            stencil_rhs(&poisson2d_row::<f64>(g, i), x_true)
        });
        let pre = JacobiPrecond::build(&ctx, &a);
        pre.scale_matrix(&ctx, &mut a);
        pre.scale_rhs(&ctx, &mut b);
        let cfg = IterConfig { tol: 1e-12, max_iter: 1_000, restart: 30 };
        let (mut x, st) = cg(&ctx, &a, &b, &cfg).expect("preconditioned cg");
        assert!(st.converged);
        pre.unscale_solution(&ctx, &mut x);
        gather_vector(&mesh, &x)
    });
    let x = out[0].as_ref().unwrap();
    for i in 0..n {
        assert!((x[i] - x_true(i)).abs() < 1e-8, "x[{i}] = {}", x[i]);
    }
}

/// The CSR builder round-trips triplets, summing duplicate entries, at
/// both the local and the distributed level.
#[test]
fn csr_builder_roundtrips_triplets_with_duplicate_summing() {
    // Local: a 4x4 with two duplicated positions.
    let trip = [
        (0usize, 1usize, 2.0f64),
        (3, 3, 1.0),
        (0, 1, 3.0), // duplicate of (0,1): sums to 5
        (2, 0, -1.0),
        (1, 1, 4.0),
        (3, 3, -0.5), // duplicate of (3,3): sums to 0.5
    ];
    let a = CsrMatrix::from_triplets(4, 4, &trip);
    assert_eq!(a.nnz(), 4);
    assert_eq!(a.get(0, 1), Some(5.0));
    assert_eq!(a.get(3, 3), Some(0.5));
    assert_eq!(a.get(0, 0), None);

    // Distributed: the same global triplets dealt to 2 process rows agree
    // with the local build, row by row.
    let desc = Descriptor::new(4, 4, 2, MeshShape::new(2, 1));
    for prow in 0..2 {
        let d = DistCsrMatrix::from_triplets(desc, prow, 0, &trip);
        for li in 0..d.local().nrows() {
            let gi = d.global_row(li);
            let (cols, vals) = d.local().row(li);
            let (wcols, wvals) = a.row(gi);
            assert_eq!(cols, wcols, "prow {prow} row {gi}");
            assert_eq!(vals, wvals, "prow {prow} row {gi}");
        }
    }
}
