//! Fault-injection integration tests (`DESIGN.md` §18).
//!
//! Four contracts, exercised through the public API:
//!
//! * **Zero cost when off** — the empty [`FaultPlan`] is bit-identical to
//!   running with no fault layer at all (same answer bits, same virtual
//!   clock bits, same wire counters), and so is a plan whose events can
//!   never fire (hooks engaged, every multiplier an exact `× 1.0`).
//! * **Recovery is exact** — a mid-factorization (mid-Krylov) crash under
//!   a checkpoint policy reproduces the fault-free solution *bit for bit*;
//!   only the virtual makespan grows (reboot + replay).  A crash with no
//!   checkpoint policy is an [`Error::Runtime`] on every rank, not a hang
//!   or a wrong answer.
//! * **Stragglers price, never perturb** — a slow rank changes makespans
//!   only; answers, message counts and byte counts are untouched.
//! * **Retries are ledgered exactly** — scripted message drops inside a
//!   live solve surface in `CommStats::{retries, timeout_secs}` with the
//!   exponential-backoff total, and the payload still arrives intact: the
//!   answer is the fault-free answer, bit for bit.

use std::sync::Arc;

use cuplss::accel::CpuEngine;
use cuplss::comm::{CheckpointPolicy, FaultPlan, NetworkModel, World};
use cuplss::dist::{Descriptor, DistMatrix, DistMultiVector, DistVector};
use cuplss::mesh::{Mesh, MeshShape};
use cuplss::pblas::{pgemm_acc, Ctx};
use cuplss::solvers::{
    cg_ft, gmres_ft, pchol_solve_panel_ckpt, plu_solve_panel_ckpt, IterConfig,
};
use cuplss::workloads::Workload;

const TILE: usize = 8;
const N: usize = 40;

#[derive(Clone, Copy, PartialEq, Debug)]
enum Kernel {
    Lu,
    Chol,
    Summa,
    Cg,
    Gmres,
}

const ALL_KERNELS: [Kernel; 5] =
    [Kernel::Lu, Kernel::Chol, Kernel::Summa, Kernel::Cg, Kernel::Gmres];

/// Per-rank observation: answer bits, clock bits, wire/retry counters.
#[derive(Clone, PartialEq, Debug)]
struct Obs {
    bits: Vec<u64>,
    now: u64,
    bytes: u64,
    msgs: u64,
    retries: u64,
    timeout: u64,
}

fn vec_bits(x: &DistVector<f64>) -> Vec<u64> {
    (0..x.local_blocks())
        .flat_map(|l| x.block(l).iter().map(|v| v.to_bits()).collect::<Vec<_>>())
        .collect()
}

/// Run one kernel on `ranks` ranks under `plan`, checkpointing every
/// `every` panels/iterations when given, and observe every rank.
fn run_kernel(kernel: Kernel, ranks: usize, plan: FaultPlan, every: Option<usize>) -> Vec<Obs> {
    World::run_with_faults::<f64, _, _>(ranks, NetworkModel::gigabit_ethernet(), plan, move |comm| {
        let mesh = Mesh::new(&comm, MeshShape::near_square(ranks));
        let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(TILE)));
        let desc = Descriptor::new(N, N, TILE, mesh.shape());
        let ckpt = every.map(CheckpointPolicy::every);
        let bits = match kernel {
            Kernel::Lu | Kernel::Chol => {
                let w = if kernel == Kernel::Lu { Workload::DiagDominant } else { Workload::Spd };
                let mut a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), w.elem::<f64>(N));
                let b = DistMultiVector::from_cols(vec![DistVector::from_fn(
                    desc,
                    mesh.row(),
                    mesh.col(),
                    w.rhs::<f64>(N),
                )]);
                let x = if kernel == Kernel::Lu {
                    plu_solve_panel_ckpt(&ctx, &mut a, &b, ckpt).unwrap()
                } else {
                    pchol_solve_panel_ckpt(&ctx, &mut a, &b, ckpt).unwrap()
                };
                vec_bits(&x.into_cols().remove(0))
            }
            Kernel::Summa => {
                let a = DistMatrix::from_fn(
                    desc,
                    mesh.row(),
                    mesh.col(),
                    Workload::DiagDominant.elem::<f64>(N),
                );
                let b =
                    DistMatrix::from_fn(desc, mesh.row(), mesh.col(), Workload::Spd.elem::<f64>(N));
                let mut c = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), |_, _| 0.0);
                pgemm_acc(&ctx, &a, &b, &mut c);
                (0..c.local_mt())
                    .flat_map(|lti| {
                        (0..c.local_nt())
                            .flat_map(|ltj| c.tile(lti, ltj).iter().map(|v| v.to_bits()))
                            .collect::<Vec<_>>()
                    })
                    .collect()
            }
            Kernel::Cg | Kernel::Gmres => {
                let w = if kernel == Kernel::Cg { Workload::Spd } else { Workload::DiagDominant };
                let a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), w.elem::<f64>(N));
                let b = DistVector::from_fn(desc, mesh.row(), mesh.col(), w.rhs::<f64>(N));
                let cfg = IterConfig { tol: 1e-10, max_iter: 200, restart: 10 };
                let (x, st) = if kernel == Kernel::Cg {
                    cg_ft(&ctx, &a, &b, &cfg, ckpt).unwrap()
                } else {
                    gmres_ft(&ctx, &a, &b, &cfg, ckpt).unwrap()
                };
                assert!(st.converged, "{kernel:?} must converge at 1e-10");
                vec_bits(&x)
            }
        };
        Obs {
            bits,
            now: comm.clock().now().to_bits(),
            bytes: comm.stats().bytes_sent(),
            msgs: comm.stats().msgs_sent(),
            retries: comm.stats().retries(),
            timeout: comm.stats().timeout_secs().to_bits(),
        }
    })
}

fn makespan(obs: &[Obs]) -> f64 {
    obs.iter().map(|o| f64::from_bits(o.now)).fold(0.0, f64::max)
}

/// The empty plan is running with no fault layer: `World::run` and
/// `World::run_with_faults(default)` agree bit for bit on answers, clocks
/// and counters — for every kernel on 1, 2 and 4 ranks.
#[test]
fn zero_event_plan_is_bit_identical_to_no_fault_layer() {
    for &ranks in &[1usize, 2, 4] {
        for &kernel in &ALL_KERNELS {
            let bare = run_kernel(kernel, ranks, FaultPlan::default(), None);
            let zero = run_kernel(kernel, ranks, FaultPlan::new(), None);
            assert_eq!(bare, zero, "{kernel:?} P={ranks}: empty plan must cost nothing");
            assert!(bare.iter().all(|o| o.retries == 0 && o.timeout == 0));
        }
    }
}

/// A plan whose events can never fire (straggler/degrade/ecc on a rank
/// outside the world, a drop ordinal never reached) keeps every hook
/// engaged yet changes nothing: exact `× 1.0` multipliers, no drift.
#[test]
fn inert_events_are_an_exact_multiplicative_identity() {
    let inert = FaultPlan::parse(
        "slow:99x2.0; degrade:99x4.0@0.0-1e9; ecc:99@1024; drop:0-1#999999999",
    )
    .unwrap();
    for &ranks in &[2usize, 4] {
        for &kernel in &ALL_KERNELS {
            let base = run_kernel(kernel, ranks, FaultPlan::default(), None);
            let hooked = run_kernel(kernel, ranks, inert.clone(), None);
            assert_eq!(base, hooked, "{kernel:?} P={ranks}: inert events must be invisible");
        }
    }
}

/// Crash mid-run under a checkpoint policy: the recovered answer is the
/// fault-free answer bit for bit, and only the clock grows (reboot +
/// replay from the last checkpoint).  Checkpointing itself never changes
/// answer bits either (with or against the un-checkpointed run).
#[test]
fn crash_recovery_reproduces_the_fault_free_bits() {
    for &(kernel, every) in
        &[(Kernel::Lu, 2usize), (Kernel::Chol, 2), (Kernel::Cg, 5), (Kernel::Gmres, 1)]
    {
        let plain = run_kernel(kernel, 4, FaultPlan::default(), None);
        let ckpt = run_kernel(kernel, 4, FaultPlan::default(), Some(every));
        assert_eq!(
            plain.iter().map(|o| &o.bits).collect::<Vec<_>>(),
            ckpt.iter().map(|o| &o.bits).collect::<Vec<_>>(),
            "{kernel:?}: checkpointing must not perturb the answer"
        );
        // Crash rank 2 at ~40% of the fault-free makespan: comfortably
        // inside the factorization / iteration sweep.
        let at = 0.4 * makespan(&ckpt);
        assert!(at > 0.0);
        let plan = FaultPlan::parse(&format!("crash:2@{at}")).unwrap();
        let crashed = run_kernel(kernel, 4, plan, Some(every));
        assert_eq!(
            plain.iter().map(|o| &o.bits).collect::<Vec<_>>(),
            crashed.iter().map(|o| &o.bits).collect::<Vec<_>>(),
            "{kernel:?}: recovery must reproduce the fault-free bits"
        );
        assert!(
            makespan(&crashed) > makespan(&ckpt) + FaultPlan::default().reboot_secs,
            "{kernel:?}: the crash must cost at least the reboot ({} vs {})",
            makespan(&crashed),
            makespan(&ckpt)
        );
    }
}

/// A scripted crash with no checkpoint policy must surface as a runtime
/// error on every rank (the probe is collective — nobody hangs, nobody
/// returns a half-factored answer).
#[test]
fn crash_without_checkpoints_errors_on_every_rank() {
    let base = run_kernel(Kernel::Lu, 4, FaultPlan::default(), None);
    let at = 0.3 * makespan(&base);
    let plan = FaultPlan::parse(&format!("crash:1@{at}")).unwrap();
    let outcomes =
        World::run_with_faults::<f64, _, _>(4, NetworkModel::gigabit_ethernet(), plan, |comm| {
            let mesh = Mesh::new(&comm, MeshShape::near_square(4));
            let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(TILE)));
            let desc = Descriptor::new(N, N, TILE, mesh.shape());
            let w = Workload::DiagDominant;
            let mut a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), w.elem::<f64>(N));
            let b = DistMultiVector::from_cols(vec![DistVector::from_fn(
                desc,
                mesh.row(),
                mesh.col(),
                w.rhs::<f64>(N),
            )]);
            match plu_solve_panel_ckpt(&ctx, &mut a, &b, None) {
                Ok(_) => None,
                Err(e) => Some(e.to_string()),
            }
        });
    for (rank, outcome) in outcomes.iter().enumerate() {
        let msg = outcome.as_ref().unwrap_or_else(|| {
            panic!("rank {rank}: crash without checkpoints must error, not succeed")
        });
        assert!(msg.contains("crash"), "rank {rank}: diagnostic should name the crash: {msg}");
    }
}

/// A straggler re-prices compute, nothing else: answers, message counts
/// and byte counts are bit-for-bit the fault-free run; only makespans
/// move (and they must move — rank 0 computes 2× slower).
#[test]
fn stragglers_change_only_the_makespan() {
    let plan = FaultPlan::parse("slow:0x2.0").unwrap();
    for &kernel in &[Kernel::Lu, Kernel::Summa, Kernel::Cg] {
        let base = run_kernel(kernel, 4, FaultPlan::default(), None);
        let slow = run_kernel(kernel, 4, plan.clone(), None);
        for (rank, (b, s)) in base.iter().zip(&slow).enumerate() {
            assert_eq!(b.bits, s.bits, "{kernel:?} rank {rank}: answers must not move");
            assert_eq!(b.bytes, s.bytes, "{kernel:?} rank {rank}: same wire traffic");
            assert_eq!(b.msgs, s.msgs, "{kernel:?} rank {rank}: same message count");
            assert_eq!(s.retries, 0);
        }
        assert!(
            makespan(&slow) > makespan(&base),
            "{kernel:?}: a 2x straggler must stretch the makespan"
        );
    }
}

/// Scripted drops inside a live CG solve: the transport re-flies the lost
/// sends, the ledger prices exactly the exponential backoff (1 ms + 2 ms),
/// and the answer is untouched.
#[test]
fn scripted_drops_inside_a_solve_are_priced_and_harmless() {
    let base = run_kernel(Kernel::Cg, 2, FaultPlan::default(), None);
    let plan = FaultPlan::parse("drop:0-1#2x2; timeout:1e-3").unwrap();
    let dropped = run_kernel(Kernel::Cg, 2, plan, None);
    for (rank, (b, d)) in base.iter().zip(&dropped).enumerate() {
        assert_eq!(b.bits, d.bits, "rank {rank}: the re-flown payload must arrive intact");
    }
    assert_eq!(dropped[0].retries, 2, "two scripted drops = two retries");
    assert_eq!(dropped[1].retries, 0, "the receiver retries nothing");
    let waited = f64::from_bits(dropped[0].timeout);
    assert!((waited - 3e-3).abs() < 1e-12, "1ms + 2ms backoff: {waited}");
    assert!(
        makespan(&dropped) >= makespan(&base) + 3e-3 - 1e-12,
        "the backoff must land on the critical path"
    );
}
