//! Property tests of the block-cyclic distribution layer: ownership
//! invariants, round-trips, transposes, and PBLAS consistency under random
//! shapes (seeded in-tree property harness).

use std::sync::Arc;

use cuplss::accel::CpuEngine;
use cuplss::comm::{NetworkModel, World};
use cuplss::dist::{
    gather_matrix, gather_vector, ptranspose, Descriptor, DistMatrix, DistVector,
};
use cuplss::mesh::{Mesh, MeshShape};
use cuplss::pblas::{pdot, pgemv, pgemv_t, Ctx};
use cuplss::util::prop;

#[test]
fn every_tile_has_exactly_one_owner_property() {
    prop::forall(30, 0xD157, |rng| {
        let m = 1 + rng.below(200);
        let n = 1 + rng.below(200);
        let tile = 1 + rng.below(16);
        let pr = 1 + rng.below(4);
        let pc = 1 + rng.below(4);
        let desc = Descriptor::new(m, n, tile, MeshShape::new(pr, pc));
        for ti in 0..desc.mt() {
            for tj in 0..desc.nt() {
                let (orow, ocol) = desc.owner(ti, tj);
                assert!(orow < pr && ocol < pc);
                // local index round-trips
                assert_eq!(desc.global_ti(orow, desc.local_ti(ti)), ti);
                assert_eq!(desc.global_tj(ocol, desc.local_tj(tj)), tj);
            }
        }
        // local counts partition the tile grid
        let total: usize =
            (0..pr).map(|r| desc.local_mt(r)).sum::<usize>() * 0
                + (0..pr)
                    .flat_map(|r| (0..pc).map(move |c| (r, c)))
                    .map(|(r, c)| desc.local_mt(r) * desc.local_nt(c))
                    .sum::<usize>();
        assert_eq!(total, desc.mt() * desc.nt());
    });
}

#[test]
fn matrix_gather_roundtrip_property() {
    prop::forall(8, 0xD158, |rng| {
        let m = 5 + rng.below(40);
        let n = 5 + rng.below(40);
        let tile = 2 + rng.below(7);
        let pr = 1 + rng.below(3);
        let pc = 1 + rng.below(3);
        let out = World::run::<f64, _, _>(pr * pc, NetworkModel::ideal(), move |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
            let desc = Descriptor::new(m, n, tile, mesh.shape());
            let dm = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), |i, j| {
                (i * 1000 + j) as f64
            });
            gather_matrix(&mesh, &dm)
        });
        let g = out[0].as_ref().unwrap();
        for i in 0..m {
            for j in 0..n {
                assert_eq!(g[i * n + j], (i * 1000 + j) as f64);
            }
        }
    });
}

#[test]
fn double_transpose_is_identity_property() {
    prop::forall(8, 0xD159, |rng| {
        let n = 5 + rng.below(30);
        let tile = 2 + rng.below(6);
        let pr = 1 + rng.below(3);
        let pc = 1 + rng.below(3);
        let out = World::run::<f64, _, _>(pr * pc, NetworkModel::ideal(), move |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
            let desc = Descriptor::new(n, n, tile, mesh.shape());
            let a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), |i, j| {
                ((i * 31 + j * 17) % 13) as f64
            });
            let att = ptranspose(&mesh, &ptranspose(&mesh, &a));
            let ga = gather_matrix(&mesh, &a);
            let gt = gather_matrix(&mesh, &att);
            (ga, gt)
        });
        let (ga, gt) = &out[0];
        assert_eq!(ga.as_ref().unwrap(), gt.as_ref().unwrap());
    });
}

#[test]
fn pgemv_transpose_consistency_property() {
    // <A x, y> == <x, A^T y> for random sizes/meshes — ties pgemv and
    // pgemv_t together without a serial reference.
    prop::forall(6, 0xD15A, |rng| {
        let n = 8 + rng.below(40);
        let tile = 4 + rng.below(5);
        let pr = 1 + rng.below(3);
        let pc = 1 + rng.below(3);
        let seed = rng.next_u64();
        let out = World::run::<f64, _, _>(pr * pc, NetworkModel::ideal(), move |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
            let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(tile)));
            let desc = Descriptor::new(n, n, tile, mesh.shape());
            let a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), |i, j| {
                let mut h = seed ^ ((i * 131 + j) as u64);
                h ^= h >> 33;
                h = h.wrapping_mul(0xff51afd7ed558ccd);
                ((h >> 11) as f64 / (1u64 << 53) as f64) - 0.5
            });
            let x = DistVector::from_fn(desc, mesh.row(), mesh.col(), |i| {
                (i as f64 * 0.37).sin()
            });
            let y = DistVector::from_fn(desc, mesh.row(), mesh.col(), |i| {
                (i as f64 * 0.11).cos()
            });
            let ax = pgemv(&ctx, &a, &x);
            let aty = pgemv_t(&ctx, &a, &y);
            let lhs = pdot(&ctx, &ax, &y);
            let rhs = pdot(&ctx, &x, &aty);
            (lhs, rhs)
        });
        for (lhs, rhs) in out {
            assert!(
                (lhs - rhs).abs() < 1e-9 * (1.0 + lhs.abs()),
                "<Ax,y>={lhs} vs <x,Aty>={rhs}"
            );
        }
    });
}

#[test]
fn vector_scatter_gather_property() {
    prop::forall(8, 0xD15B, |rng| {
        let m = 3 + rng.below(60);
        let tile = 2 + rng.below(8);
        let pr = 1 + rng.below(3);
        let pc = 1 + rng.below(3);
        let out = World::run::<f32, _, _>(pr * pc, NetworkModel::ideal(), move |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
            let desc = Descriptor::new(m, m, tile, mesh.shape());
            let v = DistVector::from_fn(desc, mesh.row(), mesh.col(), |i| (i * i) as f32);
            gather_vector(&mesh, &v)
        });
        let g = out[0].as_ref().unwrap();
        for i in 0..m {
            assert_eq!(g[i], (i * i) as f32);
        }
    });
}

#[test]
fn replicas_stay_identical_after_ops() {
    // Column-replicated vectors must remain bit-identical across process
    // columns after pgemv (the invariant the whole layout rests on).
    let (pr, pc) = (2usize, 3usize);
    let n = 24usize;
    let out = World::run::<f64, _, _>(pr * pc, NetworkModel::ideal(), move |comm| {
        let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
        let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(4)));
        let desc = Descriptor::new(n, n, 4, mesh.shape());
        let a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), |i, j| {
            ((i + 2 * j) % 7) as f64
        });
        let x = DistVector::from_fn(desc, mesh.row(), mesh.col(), |i| i as f64);
        let y = pgemv(&ctx, &a, &x);
        // serialize local blocks with row id for cross-replica comparison
        let mut blocks = Vec::new();
        for l in 0..y.local_blocks() {
            blocks.extend_from_slice(y.block(l));
        }
        (mesh.row(), mesh.col(), blocks)
    });
    for r in 0..pr {
        let replicas: Vec<&Vec<f64>> = out
            .iter()
            .filter(|(row, _, _)| *row == r)
            .map(|(_, _, b)| b)
            .collect();
        assert_eq!(replicas.len(), pc);
        for w in replicas.windows(2) {
            assert_eq!(w[0], w[1], "row {r} replicas diverged");
        }
    }
}
