//! Integration: every distributed solver, on every mesh shape the paper
//! evaluates (1, 2, 4, 8, 16 ranks), against the serial oracles.
//!
//! These run with the CPU engine (pure rust local compute) so they need no
//! artifacts.

use std::sync::Arc;

use cuplss::accel::{CpuEngine, EngineKind};
use cuplss::comm::{NetworkModel, World};
use cuplss::dist::{gather_vector, Descriptor, DistMatrix, DistVector};
use cuplss::linalg;
use cuplss::mesh::{Mesh, MeshShape};
use cuplss::pblas::Ctx;
use cuplss::solvers::{
    self, bicg, bicgstab, cg, gmres, pchol_solve, pipecg, plu_solve, IterConfig,
};

/// Deterministic dense SPD test matrix (same on all ranks).
fn spd_elem(n: usize) -> impl Fn(usize, usize) -> f64 + Clone + Send + Sync {
    move |i, j| {
        let base = (((i * 37 + j * 61) % 97) as f64) / 97.0 - 0.5;
        let sym = base + ((((j * 37 + i * 61) % 97) as f64) / 97.0 - 0.5);
        if i == j {
            2.0 * n as f64 + sym
        } else {
            sym * 0.5
        }
    }
}

/// Deterministic diagonally-dominant nonsymmetric matrix.
fn nonsym_elem(n: usize) -> impl Fn(usize, usize) -> f64 + Clone + Send + Sync {
    move |i, j| {
        let v = (((i * 13 + j * 29 + 7) % 101) as f64) / 101.0 - 0.5;
        if i == j {
            n as f64 + 1.0 + v
        } else {
            v
        }
    }
}

fn x_true(n: usize) -> Vec<f64> {
    (0..n).map(|i| ((i as f64) * 0.21).sin() + 1.0).collect()
}

fn rhs_elem(n: usize, elem: &impl Fn(usize, usize) -> f64, i: usize) -> f64 {
    let xt = |j: usize| ((j as f64) * 0.21).sin() + 1.0;
    (0..n).map(|j| elem(i, j) * xt(j)).sum()
}

const MESHES: &[(usize, usize)] = &[(1, 1), (1, 2), (2, 2), (2, 4), (4, 4)];

fn solve_distributed(
    n: usize,
    tile: usize,
    pr: usize,
    pc: usize,
    which: &'static str,
) -> Vec<f64> {
    let out = World::run::<f64, _, _>(pr * pc, NetworkModel::gigabit_ethernet(), move |comm| {
        let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
        let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(tile)));
        let desc = Descriptor::new(n, n, tile, mesh.shape());
        let cfg = IterConfig { tol: 1e-11, max_iter: 600, restart: 25 };
        let spd = matches!(which, "cg" | "pipecg" | "chol");
        let a0 = if spd {
            DistMatrix::from_fn(desc, mesh.row(), mesh.col(), spd_elem(n))
        } else {
            DistMatrix::from_fn(desc, mesh.row(), mesh.col(), nonsym_elem(n))
        };
        let b = if spd {
            DistVector::from_fn(desc, mesh.row(), mesh.col(), |i| {
                rhs_elem(n, &spd_elem(n), i)
            })
        } else {
            DistVector::from_fn(desc, mesh.row(), mesh.col(), |i| {
                rhs_elem(n, &nonsym_elem(n), i)
            })
        };
        let x = match which {
            "lu" => {
                let mut a = a0;
                plu_solve(&ctx, &mut a, &b).expect("plu")
            }
            "chol" => {
                let mut a = a0;
                pchol_solve(&ctx, &mut a, &b).expect("pchol")
            }
            "cg" => cg(&ctx, &a0, &b, &cfg).expect("cg").0,
            "pipecg" => pipecg(&ctx, &a0, &b, &cfg).expect("pipecg").0,
            "bicg" => bicg(&ctx, &a0, &b, &cfg).expect("bicg").0,
            "bicgstab" => bicgstab(&ctx, &a0, &b, &cfg).expect("bicgstab").0,
            "gmres" => gmres(&ctx, &a0, &b, &cfg).expect("gmres").0,
            _ => unreachable!(),
        };
        gather_vector(&mesh, &x)
    });
    out.into_iter().next().unwrap().unwrap()
}

fn check_solver(which: &'static str, n: usize, tile: usize, tol: f64) {
    let want = x_true(n);
    for &(pr, pc) in MESHES {
        let x = solve_distributed(n, tile, pr, pc, which);
        let mut worst = 0.0f64;
        for i in 0..n {
            worst = worst.max((x[i] - want[i]).abs());
        }
        assert!(worst < tol, "{which} n={n} tile={tile} mesh {pr}x{pc}: max err {worst}");
    }
}

#[test]
fn plu_all_meshes_aligned() {
    check_solver("lu", 48, 8, 1e-9);
}

#[test]
fn plu_all_meshes_padded() {
    check_solver("lu", 45, 8, 1e-9); // edge tiles + identity padding
}

#[test]
fn pchol_all_meshes() {
    check_solver("chol", 48, 8, 1e-9);
    check_solver("chol", 42, 8, 1e-9);
}

#[test]
fn cg_all_meshes() {
    check_solver("cg", 48, 8, 1e-7);
}

#[test]
fn pipecg_all_meshes() {
    check_solver("pipecg", 48, 8, 1e-7);
}

#[test]
fn bicg_all_meshes() {
    check_solver("bicg", 40, 8, 1e-7);
}

#[test]
fn bicgstab_all_meshes() {
    check_solver("bicgstab", 40, 8, 1e-7);
}

#[test]
fn gmres_all_meshes() {
    check_solver("gmres", 40, 8, 1e-7);
}

#[test]
fn distributed_lu_matches_serial_factorisation_solution() {
    // Cross-check full pipeline vs linalg::lu_solve on the host.
    let n = 37;
    let elem = nonsym_elem(n);
    let mut a: Vec<f64> = (0..n * n).map(|k| elem(k / n, k % n)).collect();
    let mut b: Vec<f64> = (0..n).map(|i| rhs_elem(n, &elem, i)).collect();
    linalg::lu_solve(n, &mut a, &mut b).unwrap();
    let want = x_true(n);
    for i in 0..n {
        assert!((b[i] - want[i]).abs() < 1e-9, "serial oracle");
    }
    let x = solve_distributed(n, 8, 2, 2, "lu");
    for i in 0..n {
        assert!((x[i] - b[i]).abs() < 1e-8, "dist vs serial at {i}");
    }
}

#[test]
fn iterative_methods_report_convergence() {
    let n = 32;
    let out = World::run::<f64, _, _>(4, NetworkModel::gigabit_ethernet(), move |comm| {
        let mesh = Mesh::new(&comm, MeshShape::new(2, 2));
        let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(8)));
        let desc = Descriptor::new(n, n, 8, mesh.shape());
        let a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), spd_elem(n));
        let b = DistVector::from_fn(desc, mesh.row(), mesh.col(), |i| (i + 1) as f64);
        let cfg = IterConfig { tol: 1e-10, max_iter: 300, restart: 20 };
        let (_, st) = cg(&ctx, &a, &b, &cfg).unwrap();
        (st.converged, st.iterations, st.rel_residual)
    });
    for (conv, iters, res) in out {
        assert!(conv, "residual {res}");
        assert!(iters > 0 && iters <= 300);
        assert!(res <= 1e-10);
    }
}

#[test]
fn iteration_counts_identical_across_mesh_shapes() {
    // The distributed recurrences must be numerically consistent across
    // shapes (same math; only local summation order differs).
    let n = 32;
    let mut iters_per_mesh = Vec::new();
    for &(pr, pc) in &[(1usize, 1usize), (2, 2), (2, 4)] {
        let out =
            World::run::<f64, _, _>(pr * pc, NetworkModel::gigabit_ethernet(), move |comm| {
                let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
                let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(8)));
                let desc = Descriptor::new(n, n, 8, mesh.shape());
                let a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), nonsym_elem(n));
                let b = DistVector::from_fn(desc, mesh.row(), mesh.col(), |i| 1.0 + i as f64);
                let cfg = IterConfig { tol: 1e-9, max_iter: 400, restart: 30 };
                bicgstab(&ctx, &a, &b, &cfg).unwrap().1.iterations
            });
        iters_per_mesh.push(out[0]);
    }
    let min = *iters_per_mesh.iter().min().unwrap();
    let max = *iters_per_mesh.iter().max().unwrap();
    assert!(max - min <= 1, "iteration counts vary too much: {iters_per_mesh:?}");
}

#[test]
fn virtual_time_decreases_with_more_ranks_for_lu() {
    // The headline property behind Figure 4: more ranks => smaller makespan.
    // Ideal network isolates the compute-partitioning term (a toy n=64 with
    // tile 8 is latency-bound on any real profile; the bench harness covers
    // the realistic regime at scale).
    let n = 64;
    let mut makespans = Vec::new();
    for &(pr, pc) in &[(1usize, 1usize), (2, 2), (4, 4)] {
        let out =
            World::run::<f64, _, _>(pr * pc, NetworkModel::ideal(), move |comm| {
                let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
                let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(8)));
                let desc = Descriptor::new(n, n, 8, mesh.shape());
                let mut a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), nonsym_elem(n));
                let b = DistVector::from_fn(desc, mesh.row(), mesh.col(), |i| 1.0 + i as f64);
                let _ = plu_solve(&ctx, &mut a, &b).unwrap();
                comm.clock().now()
            });
        makespans.push(out.iter().cloned().fold(0.0, f64::max));
    }
    assert!(
        makespans[1] < makespans[0],
        "4 ranks should beat 1: {makespans:?}"
    );
    // (16 tiny ranks may be latency-bound at this size; only require P=4 win.)
}

#[test]
fn engine_kind_labels_used_by_bench() {
    assert_eq!(EngineKind::Accelerated.label(), "MPI+CUDA");
    let _ = solvers::IterMethod::parse("cg").unwrap();
}
