//! Copy-engine timeline integration tests (`DESIGN.md` §13).
//!
//! The prefetch subsystem only ever re-times PCIe traffic — moving it from
//! the compute timeline to the copy-engine timeline — so every solver must
//! produce **bit-identical** results with prefetch enabled vs the
//! synchronous residency accounting (`--no-prefetch`), on every mesh.  On
//! an accelerated profile the prefetch run must charge no more
//! compute-timeline transfer and must report hidden PCIe seconds and
//! prefetch hits; on host profiles (`pcie_bw == 0`) the copy engine is
//! inert and both counters stay exactly 0.

use std::sync::Arc;

use cuplss::accel::{ComputeProfile, CpuEngine, Engine};
use cuplss::comm::{NetworkModel, World};
use cuplss::dist::{gather_matrix, gather_vector, Descriptor, DistMatrix, DistVector};
use cuplss::mesh::{Mesh, MeshShape};
use cuplss::pblas::{pgemm_acc, pgemv, Ctx};
use cuplss::solvers::{cg, pchol_factor, plu_solve, IterConfig, TriKind};

const TILE: usize = 8;
const N: usize = 24;

fn engine(gpu: bool) -> Arc<CpuEngine> {
    Arc::new(if gpu {
        CpuEngine::with_profile(TILE, ComputeProfile::gtx280_cublas())
    } else {
        CpuEngine::new(TILE)
    })
}

/// Per-rank virtual-clock observations of one run.
#[derive(Clone, Debug)]
struct Obs {
    bits: Vec<u64>,
    compute: f64,
    transfer: f64,
    vtime: f64,
    pcie_hidden: f64,
    prefetch_hits: u64,
}

/// Run `kernel` on a pr x pc mesh with the copy engine on/off; returns
/// (prefetch, synchronous) observations per rank.
fn run_both<F>(pr: usize, pc: usize, gpu: bool, kernel: F) -> (Vec<Obs>, Vec<Obs>)
where
    F: Fn(&Ctx<'_, f64>) -> Vec<f64> + Send + Sync + Copy + 'static,
{
    let run = |prefetch: bool| -> Vec<Obs> {
        let eng = engine(gpu);
        World::run::<f64, _, _>(pr * pc, NetworkModel::gigabit_ethernet(), move |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
            let ctx = Ctx::new(&mesh, eng.clone() as Arc<dyn Engine<f64>>)
                .with_prefetch(prefetch);
            let out = kernel(&ctx);
            Obs {
                bits: out.iter().map(|v| v.to_bits()).collect(),
                compute: comm.clock().compute_secs(),
                transfer: comm.clock().transfer_secs(),
                vtime: comm.clock().busy_until(),
                pcie_hidden: comm.stats().pcie_hidden_secs(),
                prefetch_hits: comm.stats().prefetch_hits(),
            }
        })
    };
    (run(true), run(false))
}

fn meshes() -> Vec<(usize, usize)> {
    vec![(1, 1), (2, 1), (2, 2)]
}

fn lu_kernel(ctx: &Ctx<'_, f64>) -> Vec<f64> {
    let mesh = ctx.mesh;
    let desc = Descriptor::new(N, N, TILE, mesh.shape());
    let mut a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), |i, j| {
        ((i * 7 + j * 13) as f64 * 0.37).sin() + if i == j { 4.0 } else { 0.0 }
    });
    let b = DistVector::from_fn(desc, mesh.row(), mesh.col(), |i| (i as f64 * 0.21).cos());
    let x = plu_solve(ctx, &mut a, &b).expect("lu solve");
    gather_vector(mesh, &x).unwrap_or_default()
}

fn chol_kernel(ctx: &Ctx<'_, f64>) -> Vec<f64> {
    let mesh = ctx.mesh;
    let desc = Descriptor::new(N, N, TILE, mesh.shape());
    let mut a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), |i, j| {
        let v = ((i.min(j) * 5 + i.max(j) * 3) as f64 * 0.11).sin() * 0.3;
        if i == j { 6.0 + v } else { v }
    });
    pchol_factor(ctx, &mut a).expect("cholesky");
    gather_matrix(mesh, &a).unwrap_or_default()
}

fn summa_kernel(ctx: &Ctx<'_, f64>) -> Vec<f64> {
    let mesh = ctx.mesh;
    let desc = Descriptor::new(N, N, TILE, mesh.shape());
    let a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), |i, j| {
        ((i + 2 * j) as f64 * 0.1).sin()
    });
    let b = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), |i, j| {
        ((3 * i + j) as f64 * 0.07).cos()
    });
    let mut c = DistMatrix::zeros(desc, mesh.row(), mesh.col());
    pgemm_acc(ctx, &a, &b, &mut c);
    gather_matrix(mesh, &c).unwrap_or_default()
}

fn cg_kernel(ctx: &Ctx<'_, f64>) -> Vec<f64> {
    let mesh = ctx.mesh;
    let desc = Descriptor::new(N, N, TILE, mesh.shape());
    let a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), |i, j| {
        let v = ((i.min(j) * 5 + i.max(j) * 3) as f64 * 0.11).sin() * 0.3;
        if i == j { 6.0 + v } else { v }
    });
    let b = DistVector::from_fn(desc, mesh.row(), mesh.col(), |i| (i as f64 * 0.5).sin());
    let cfg = IterConfig { tol: 1e-12, max_iter: 200, restart: 30 };
    let (x, stats) = cg(ctx, &a, &b, &cfg).expect("cg");
    assert!(stats.converged);
    gather_vector(mesh, &x).unwrap_or_default()
}

fn assert_bit_identical_and_retimed(
    name: &str,
    pr: usize,
    pc: usize,
    gpu: bool,
    prefetch: &[Obs],
    sync: &[Obs],
) {
    for (rank, (p, s)) in prefetch.iter().zip(sync).enumerate() {
        assert_eq!(
            p.bits, s.bits,
            "{name} {pr}x{pc} gpu={gpu} rank {rank}: prefetch changed the results"
        );
        assert!(
            (p.compute - s.compute).abs() < 1e-12 * s.compute.max(1.0),
            "{name} {pr}x{pc} rank {rank}: prefetch must not touch compute time"
        );
        // Waiting only the remaining latency can never charge more
        // compute-timeline transfer than the synchronous accounting.
        assert!(
            p.transfer <= s.transfer + 1e-15,
            "{name} {pr}x{pc} rank {rank}: prefetch transfer {} > sync {}",
            p.transfer,
            s.transfer
        );
        assert_eq!(s.pcie_hidden, 0.0, "sync accounting hides nothing");
        assert_eq!(s.prefetch_hits, 0, "sync accounting issues no prefetches");
        if !gpu {
            assert_eq!(p.pcie_hidden, 0.0, "host profile: copy engine inert");
            assert_eq!(p.prefetch_hits, 0, "host profile: no prefetch issued");
            assert_eq!(p.transfer, 0.0, "host profile streams nothing");
        }
    }
    if gpu {
        let hidden: f64 = prefetch.iter().map(|o| o.pcie_hidden).sum();
        let hits: u64 = prefetch.iter().map(|o| o.prefetch_hits).sum();
        assert!(hidden > 0.0, "{name} {pr}x{pc}: some PCIe must hide behind compute");
        assert!(hits > 0, "{name} {pr}x{pc}: some operands must be served by prefetch");
        let (pt, st) = (
            prefetch.iter().map(|o| o.transfer).sum::<f64>(),
            sync.iter().map(|o| o.transfer).sum::<f64>(),
        );
        assert!(pt < st, "{name} {pr}x{pc}: blocked transfer must drop ({pt} vs {st})");
    }
}

#[test]
fn lu_bit_identical_with_prefetch_on_and_off() {
    for (pr, pc) in meshes() {
        for gpu in [false, true] {
            let (p, s) = run_both(pr, pc, gpu, lu_kernel);
            assert_bit_identical_and_retimed("LU", pr, pc, gpu, &p, &s);
        }
    }
}

#[test]
fn cholesky_bit_identical_with_prefetch_on_and_off() {
    for (pr, pc) in meshes() {
        for gpu in [false, true] {
            let (p, s) = run_both(pr, pc, gpu, chol_kernel);
            assert_bit_identical_and_retimed("Cholesky", pr, pc, gpu, &p, &s);
        }
    }
}

#[test]
fn summa_bit_identical_with_prefetch_on_and_off() {
    for (pr, pc) in meshes() {
        for gpu in [false, true] {
            let (p, s) = run_both(pr, pc, gpu, summa_kernel);
            assert_bit_identical_and_retimed("SUMMA", pr, pc, gpu, &p, &s);
        }
    }
}

#[test]
fn cg_bit_identical_with_prefetch_on_and_off() {
    for (pr, pc) in meshes() {
        for gpu in [false, true] {
            let (p, s) = run_both(pr, pc, gpu, cg_kernel);
            assert_bit_identical_and_retimed("CG", pr, pc, gpu, &p, &s);
        }
    }
}

#[test]
fn prefetch_never_extends_the_makespan() {
    // busy_until covers the copy-engine tail: even with occupancy queued
    // at capture, the async replay must not exceed the synchronous one.
    for (pr, pc) in meshes() {
        let (p, s) = run_both(pr, pc, true, summa_kernel);
        let (pm, sm) = (
            p.iter().map(|o| o.vtime).fold(0.0, f64::max),
            s.iter().map(|o| o.vtime).fold(0.0, f64::max),
        );
        assert!(pm <= sm + 1e-12, "{pr}x{pc}: prefetch makespan {pm} > sync {sm}");
    }
}

#[test]
fn trsv_routes_through_residency_and_stays_exact() {
    // The ROADMAP's remaining copy-per-call path: ptrsv now charges
    // through the tile cache.  Solve L y = b against a dense lower
    // triangle and pin both the numerics (vs the no-cache flow) and that
    // the gpu arm saves transfer relative to streaming.
    use cuplss::solvers::ptrsv;
    let kernel = |ctx: &Ctx<'_, f64>| -> Vec<f64> {
        let mesh = ctx.mesh;
        let desc = Descriptor::new(N, N, TILE, mesh.shape());
        let a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), |i, j| {
            if i == j {
                3.0
            } else if j < i {
                ((i * 3 + j) as f64 * 0.2).sin() * 0.4
            } else {
                0.0
            }
        });
        let mut b =
            DistVector::from_fn(desc, mesh.row(), mesh.col(), |i| (i as f64 * 0.3).cos());
        ptrsv(ctx, &a, &mut b, TriKind::Lower).expect("trsv");
        gather_vector(mesh, &b).unwrap_or_default()
    };
    for (pr, pc) in meshes() {
        let eng = engine(true);
        let out = World::run::<f64, _, _>(
            pr * pc,
            NetworkModel::gigabit_ethernet(),
            move |comm| {
                let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
                let cached = Ctx::new(&mesh, eng.clone() as Arc<dyn Engine<f64>>);
                let bits: Vec<u64> = kernel(&cached).iter().map(|v| v.to_bits()).collect();
                let cached_xfer = comm.clock().transfer_secs();
                comm.clock().reset();
                let streaming = Ctx::streaming(&mesh, eng.clone() as Arc<dyn Engine<f64>>);
                let bits_s: Vec<u64> =
                    kernel(&streaming).iter().map(|v| v.to_bits()).collect();
                (bits, bits_s, cached_xfer, comm.clock().transfer_secs())
            },
        );
        for (rank, (bits, bits_s, cx, sx)) in out.iter().enumerate() {
            assert_eq!(bits, bits_s, "{pr}x{pc} rank {rank}: cache changed trsv");
            assert!(cx <= sx, "{pr}x{pc} rank {rank}: trsv must not charge more");
        }
        let (ct, st): (f64, f64) =
            out.iter().fold((0.0, 0.0), |(a, b), o| (a + o.2, b + o.3));
        assert!(ct < st, "{pr}x{pc}: trsv residency must save transfer ({ct} vs {st})");
    }
}

#[test]
fn pgemv_output_stays_device_resident() {
    // Repeated matvecs: with residency the per-call D2H collapses to one
    // write-back per partial block per matvec (vs per tile in streaming) —
    // total transfer must drop strictly, and the results stay bit-equal.
    let eng = engine(true);
    let out = World::run::<f64, _, _>(4, NetworkModel::gigabit_ethernet(), move |comm| {
        let mesh = Mesh::new(&comm, MeshShape::new(2, 2));
        let desc = Descriptor::new(N, N, TILE, mesh.shape());
        let a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), |i, j| {
            ((i * 31 + j * 7) as f64).sin()
        });
        let x0 = DistVector::from_fn(desc, mesh.row(), mesh.col(), |i| (i as f64 * 0.37).cos());
        let run = |ctx: &Ctx<'_, f64>| -> Vec<u64> {
            let mut x = x0.clone_vec();
            for _ in 0..3 {
                x = pgemv(ctx, &a, &x);
            }
            gather_vector(&mesh, &x)
                .unwrap_or_default()
                .iter()
                .map(|v| v.to_bits())
                .collect()
        };
        let cached = Ctx::new(&mesh, eng.clone() as Arc<dyn Engine<f64>>);
        let bits_c = run(&cached);
        let cx = comm.clock().transfer_secs();
        comm.clock().reset();
        let streaming = Ctx::streaming(&mesh, eng.clone() as Arc<dyn Engine<f64>>);
        let bits_s = run(&streaming);
        (bits_c, bits_s, cx, comm.clock().transfer_secs())
    });
    for (rank, (bc, bs, _cx, _sx)) in out.iter().enumerate() {
        assert_eq!(bc, bs, "rank {rank}: residency changed the matvec chain");
    }
    let (ct, st): (f64, f64) = out.iter().fold((0.0, 0.0), |(a, b), o| (a + o.2, b + o.3));
    assert!(ct < st, "resident matvec output must cut transfer ({ct} vs {st})");
}
