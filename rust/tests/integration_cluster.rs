//! End-to-end cluster tests: the public `Cluster` facade over every solver
//! and workload, on both engine arms (the XLA arm needs `make artifacts`).

use cuplss::accel::EngineKind;
use cuplss::cluster::{Cluster, ClusterConfig, Method};
use cuplss::comm::NetworkModel;
use cuplss::solvers::{IterConfig, IterMethod};
use cuplss::workloads::Workload;

fn artifacts_dir() -> String {
    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
}

fn have_artifacts() -> bool {
    std::path::Path::new(&artifacts_dir()).join("manifest.txt").exists()
}

fn cpu_cluster(ranks: usize, tile: usize) -> Cluster {
    Cluster::new(ClusterConfig {
        ranks,
        tile,
        engine: EngineKind::CpuSerial,
        net: NetworkModel::gigabit_ethernet(),
        artifact_dir: artifacts_dir(),
        iter: IterConfig { tol: 1e-10, max_iter: 600, restart: 30 },
        ..Default::default()
    })
    .expect("cluster")
}

#[test]
fn all_methods_all_workload_pairings_cpu() {
    let cluster = cpu_cluster(4, 8);
    let cases: &[(Workload, Method, usize)] = &[
        (Workload::DiagDominant, Method::Lu, 40),
        (Workload::Spd, Method::Lu, 40),
        (Workload::Spd, Method::Cholesky, 40),
        (Workload::Spd, Method::Iterative(IterMethod::Cg), 40),
        (Workload::DiagDominant, Method::Iterative(IterMethod::Bicg), 40),
        (Workload::DiagDominant, Method::Iterative(IterMethod::Bicgstab), 40),
        (Workload::DiagDominant, Method::Iterative(IterMethod::Gmres), 40),
        (Workload::Econometric, Method::Lu, 64),
        (Workload::Econometric, Method::Iterative(IterMethod::Bicgstab), 64),
        (Workload::Poisson2d, Method::Iterative(IterMethod::Cg), 36),
        (Workload::Poisson2d, Method::Cholesky, 49),
    ];
    for &(w, m, n) in cases {
        let report = cluster.solve::<f64>(w, n, m).unwrap_or_else(|e| {
            panic!("{} on {w:?} n={n}: {e}", m.name());
        });
        assert!(
            report.max_err < 1e-5,
            "{} on {w:?} n={n}: max_err {}",
            m.name(),
            report.max_err
        );
        assert!(report.makespan() > 0.0);
        if let Some((_, _, converged)) = report.iter_stats {
            assert!(converged, "{} on {w:?} did not converge", m.name());
        }
    }
}

#[test]
fn f32_solves_work() {
    let cluster = cpu_cluster(4, 8);
    let report = cluster.solve::<f32>(Workload::DiagDominant, 32, Method::Lu).unwrap();
    assert!(report.max_err < 1e-2, "f32 LU max_err {}", report.max_err);
    let report = cluster
        .solve::<f32>(Workload::Spd, 32, Method::Iterative(IterMethod::Cg))
        .unwrap();
    assert!(report.max_err < 1e-2, "f32 CG max_err {}", report.max_err);
}

#[test]
fn report_metrics_are_consistent() {
    let cluster = cpu_cluster(4, 8);
    let report = cluster.solve::<f64>(Workload::DiagDominant, 48, Method::Lu).unwrap();
    assert_eq!(report.per_rank.len(), 4);
    for m in &report.per_rank {
        // clock decomposition can't exceed the total
        assert!(m.compute + m.comm_wait + m.transfer <= m.vtime + 1e-9);
        assert!(m.msgs > 0, "every rank communicates in a 2x2 LU");
    }
    assert!(report.makespan() >= report.per_rank.iter().map(|m| m.vtime).fold(0.0, f64::max));
    assert!(report.comm_fraction() >= 0.0 && report.comm_fraction() <= 1.0);
    assert!(report.total_bytes() > 0);
    assert!(report.summary().contains("LU"));
}

#[test]
fn makespan_shrinks_with_ranks_under_ideal_network() {
    let mk = |ranks| {
        Cluster::new(ClusterConfig {
            ranks,
            tile: 8,
            engine: EngineKind::CpuSerial,
            net: NetworkModel::ideal(),
            artifact_dir: artifacts_dir(),
            iter: IterConfig::default(),
            ..Default::default()
        })
        .unwrap()
        .solve::<f64>(Workload::DiagDominant, 64, Method::Lu)
        .unwrap()
        .makespan()
    };
    let t1 = mk(1);
    let t4 = mk(4);
    assert!(t4 < t1, "P=4 {t4} must beat P=1 {t1}");
}

#[test]
fn xla_engine_cluster_end_to_end() {
    // The full three-layer path: rust coordinator -> PJRT executables
    // (Pallas GEMM + portable-HLO factor tiles) on every rank.
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let cluster = Cluster::new(ClusterConfig {
        ranks: 4,
        tile: 128,
        engine: EngineKind::Accelerated,
        net: NetworkModel::gigabit_ethernet(),
        artifact_dir: artifacts_dir(),
        iter: IterConfig { tol: 1e-9, max_iter: 400, restart: 30 },
        ..Default::default()
    })
    .expect("accelerated cluster");
    // LU on a padded size (exercises identity padding through XLA tiles).
    let report = cluster.solve::<f64>(Workload::DiagDominant, 200, Method::Lu).unwrap();
    assert!(report.max_err < 1e-6, "XLA LU max_err {}", report.max_err);
    assert!(report.total_transfer() > 0.0, "accelerated arm must charge PCIe time");
    // An iterative method through the Pallas GEMV path.
    let report = cluster
        .solve::<f64>(Workload::Spd, 200, Method::Iterative(IterMethod::Bicgstab))
        .unwrap();
    assert!(report.max_err < 1e-5, "XLA BiCGSTAB max_err {}", report.max_err);
    let (_, _, conv) = report.iter_stats.unwrap();
    assert!(conv);
}

#[test]
fn accelerated_vs_cpu_same_answer() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let n = 150;
    let cpu = Cluster::new(ClusterConfig {
        ranks: 2,
        tile: 128,
        engine: EngineKind::CpuSerial,
        artifact_dir: artifacts_dir(),
        ..Default::default()
    })
    .unwrap()
    .solve::<f64>(Workload::Spd, n, Method::Cholesky)
    .unwrap();
    let xla = Cluster::new(ClusterConfig {
        ranks: 2,
        tile: 128,
        engine: EngineKind::Accelerated,
        artifact_dir: artifacts_dir(),
        ..Default::default()
    })
    .unwrap()
    .solve::<f64>(Workload::Spd, n, Method::Cholesky)
    .unwrap();
    // Both close to the true solution; engines agree to solver tolerance.
    assert!(cpu.max_err < 1e-7 && xla.max_err < 1e-7, "{} {}", cpu.max_err, xla.max_err);
}
