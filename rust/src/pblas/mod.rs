//! Parallel BLAS over the 2-D block-cyclic layout — the workhorse layer the
//! CUPLSS API exposes ("routines that implement parallel BLAS operations").
//!
//! Every routine is SPMD: each rank calls it with its own shard and its
//! [`Ctx`]; real messages flow through the mesh communicators and every
//! local tile op goes through the active [`crate::accel::Engine`]
//! (accelerated or serial), charging the rank's virtual clock.
//!
//! Two operand formats share the layer: dense 2-D block-cyclic matrices
//! ([`pgemv()`], [`pgemm_acc`]) and sparse row-block CSR matrices
//! ([`pspmv()`]); the [`LinOp`] trait presents both to the Krylov solvers
//! through one `apply`/`apply_t` interface (see `DESIGN.md` §10).
//!
//! Tag discipline: each routine owns a tag block (see `tags`), so no two
//! overlapping collectives can cross-match.

pub mod linop;
pub mod pgemm;
pub mod pgemv;
pub mod pspmv;
pub mod pvec;

pub use linop::LinOp;
pub use pgemm::pgemm_acc;
pub use pgemv::{pgemv, pgemv_t};
pub use pspmv::{pspmv, pspmv_t};
pub use pvec::{
    paxpy, pcopy, pdot, pdot_partial, pfused_axpy_norm2, pfused_axpy_norm2_dot,
    pfused_norm2_dot, pfused_norm2_dot_partial, pnorm2, pscal, pxpay,
};

use std::cell::RefCell;
use std::sync::Arc;

use crate::accel::{BufKey, Engine, OpCost, TileCache, DEFAULT_DEVICE_MEM};
use crate::mesh::Mesh;
use crate::Scalar;

/// Tag blocks per routine family (collectives add small offsets).
pub(crate) mod tags {
    pub const PGEMV: u32 = 100;
    pub const PGEMV_T: u32 = 200;
    pub const PDOT: u32 = 300;
    pub const PGEMM: u32 = 400;
    pub const PSPMV: u32 = 500;
    pub const PSPMV_T: u32 = 600;
    /// Pipelined CG's fused (gamma, delta) allreduce.
    pub const PIPECG: u32 = 700;
    /// Two-lane allreduces of the fused BLAS-1 kernels.
    pub const FUSED: u32 = 800;
    pub const LU: u32 = 1_000;
    pub const CHOL: u32 = 2_000;
    pub const TRSV: u32 = 3_000;
    /// Diagonal-extraction broadcasts (offset by the tile row index).
    pub const DIAG: u32 = 5_000;
    /// Symmetric-scaling allgathers.
    pub const SCALE: u32 = 5_100;
}

/// Per-rank execution context: mesh view + local compute engine + the
/// rank's device-residency tracker ([`TileCache`], `DESIGN.md` §12).
pub struct Ctx<'a, S: Scalar> {
    /// This rank's mesh view.
    pub mesh: &'a Mesh<'a, S>,
    /// Local tile-compute engine (shared across ranks).
    pub engine: Arc<dyn Engine<S>>,
    /// Device residency tracker; `None` reproduces the paper's §3
    /// copy-per-call flow exactly.  Single-threaded per rank, hence the
    /// `RefCell` (same pattern as the comm endpoint's counters).
    cache: Option<RefCell<TileCache>>,
}

impl<'a, S: Scalar> Ctx<'a, S> {
    /// Bundle a mesh view and an engine, with device residency enabled at
    /// the default (GTX 280) budget.  Residency only re-prices PCIe
    /// traffic, never changes results, so this is always safe.
    pub fn new(mesh: &'a Mesh<'a, S>, engine: Arc<dyn Engine<S>>) -> Self {
        Self::with_device_mem(mesh, engine, DEFAULT_DEVICE_MEM)
    }

    /// Residency with an explicit device-memory budget (bytes).
    pub fn with_device_mem(
        mesh: &'a Mesh<'a, S>,
        engine: Arc<dyn Engine<S>>,
        budget: usize,
    ) -> Self {
        Ctx { mesh, engine, cache: Some(RefCell::new(TileCache::new(budget))) }
    }

    /// The paper's §3 flow: every operand streams host<->device per call.
    pub fn streaming(mesh: &'a Mesh<'a, S>, engine: Arc<dyn Engine<S>>) -> Self {
        Ctx { mesh, engine, cache: None }
    }

    /// Is the residency subsystem active?
    pub fn residency_enabled(&self) -> bool {
        self.cache.is_some()
    }

    /// Charge an op cost to this rank's virtual clock, as-is (no
    /// residency adjustment — for ops whose operands can't stay resident).
    pub fn charge(&self, cost: OpCost) {
        cost.charge(self.mesh.comm().clock());
    }

    /// The residency tracker, if the engine's profile actually streams
    /// (host profiles never pay PCIe, so there is nothing to track — and
    /// `pcie_saved_bytes` must stay 0 on them).
    fn active_cache(&self) -> Option<&RefCell<TileCache>> {
        if self.engine.profile().pcie_bw > 0.0 { self.cache.as_ref() } else { None }
    }

    /// Charge a tile-op cost with its transfer share re-priced by
    /// residency: `ins` are the operands the op read, `out` the operand it
    /// wrote (`cost` as returned by the engine, i.e. full paper-flow
    /// streaming).  A resident read operand stops streaming H2D; a written
    /// operand pays its D2H write-back once per dirty period instead of
    /// per call.  The bytes kept off the link are recorded in
    /// [`crate::comm::CommStats::pcie_saved_bytes`].
    pub fn charge_op(&self, cost: OpCost, ins: &[&[S]], out: Option<&[S]>) {
        let Some(cache) = self.active_cache() else {
            self.charge(cost);
            return;
        };
        let keys: Vec<BufKey> = ins.iter().map(|b| BufKey::of(b)).collect();
        let traffic = cache.borrow_mut().access(&keys, out.map(BufKey::of));
        let pcie = self.engine.profile().pcie_bw;
        let adjusted = OpCost {
            compute_secs: cost.compute_secs,
            transfer_secs: traffic.streamed() as f64 / pcie,
        };
        adjusted.charge(self.mesh.comm().clock());
        self.mesh.comm().stats().add_pcie_saved(traffic.saved() as u64);
    }

    /// Charge one fused BLAS-1 kernel over vector blocks (`ins` read,
    /// `outs` written), crediting the `replaced - 1` launches the unfused
    /// op-per-block sequence would have made.  A zero transfer share means
    /// the fused dispatch stayed host-side (tiny vectors — see
    /// [`crate::accel::Engine::blas1_fused_cost`]): no new PCIe traffic,
    /// but the *invalidation rules* still apply exactly as for the unfused
    /// host ops — the host observed every read operand (ending its dirty
    /// period) and mutated every written one (dropping its device copy).
    pub fn charge_fused(&self, cost: OpCost, ins: &[&[S]], outs: &[&[S]], replaced: u64) {
        if cost.transfer_secs == 0.0 {
            for buf in ins {
                self.host_read(buf);
            }
            for buf in outs {
                self.host_mut(buf);
            }
            self.charge(cost);
            self.mesh.comm().stats().add_launches_fused(replaced.saturating_sub(1));
            return;
        }
        if let Some(cache) = self.active_cache() {
            let mut traffic = crate::accel::Traffic::default();
            {
                let mut c = cache.borrow_mut();
                let in_keys: Vec<BufKey> = ins.iter().map(|b| BufKey::of(b)).collect();
                let t = c.access(&in_keys, None);
                traffic.h2d_bytes += t.h2d_bytes;
                traffic.full_bytes += t.full_bytes;
                for o in outs {
                    let t = c.access(&[], Some(BufKey::of(o)));
                    traffic.d2h_bytes += t.d2h_bytes;
                    traffic.full_bytes += t.full_bytes;
                }
            }
            let pcie = self.engine.profile().pcie_bw;
            let adjusted = OpCost {
                compute_secs: cost.compute_secs,
                transfer_secs: traffic.streamed() as f64 / pcie,
            };
            adjusted.charge(self.mesh.comm().clock());
            self.mesh.comm().stats().add_pcie_saved(traffic.saved() as u64);
        } else {
            self.charge(cost);
        }
        self.mesh.comm().stats().add_launches_fused(replaced.saturating_sub(1));
    }

    /// The host observes `buf`'s current value (message payload, gather,
    /// pivot search): ends the buffer's device dirty period.
    pub fn host_read(&self, buf: &[S]) {
        if let Some(cache) = self.active_cache() {
            cache.borrow_mut().host_read(BufKey::of(buf));
        }
    }

    /// The host mutated `buf` (row swap, panel scatter) — or is about to
    /// free it (transient broadcast buffers are *retired* so a reused
    /// allocation can never alias a stale device copy).
    pub fn host_mut(&self, buf: &[S]) {
        if let Some(cache) = self.active_cache() {
            cache.borrow_mut().host_mut(BufKey::of(buf));
        }
    }

    /// Tile edge of the active engine.
    pub fn tile(&self) -> usize {
        self.engine.tile()
    }
}
