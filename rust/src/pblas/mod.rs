//! Parallel BLAS over the 2-D block-cyclic layout — the workhorse layer the
//! CUPLSS API exposes ("routines that implement parallel BLAS operations").
//!
//! Every routine is SPMD: each rank calls it with its own shard and its
//! [`Ctx`]; real messages flow through the mesh communicators and every
//! local tile op goes through the active [`crate::accel::Engine`]
//! (accelerated or serial), charging the rank's virtual clock.
//!
//! Two operand formats share the layer: dense 2-D block-cyclic matrices
//! ([`pgemv()`], [`pgemm_acc`]) and sparse row-block CSR matrices
//! ([`pspmv()`]); the [`LinOp`] trait presents both to the Krylov solvers
//! through one `apply`/`apply_t` interface (see `DESIGN.md` §10).
//!
//! Tag discipline: each routine owns a tag block (see `tags`), so no two
//! overlapping collectives can cross-match.

pub mod linop;
pub mod pgemm;
pub mod pgemv;
pub mod pspmv;
pub mod pvec;

pub use linop::LinOp;
pub use pgemm::pgemm_acc;
pub use pgemv::{pgemv, pgemv_cols, pgemv_t};
pub use pspmv::{pspmv, pspmv_halo, pspmv_t, pspmv_t_halo};
pub use pvec::{
    paxpy, paxpy_cols, pcopy, pdot, pdot_cols, pdot_hi, pdot_partial, pdot_partial_hi,
    pfused_axpy_norm2, pfused_axpy_norm2_cols, pfused_axpy_norm2_dot,
    pfused_axpy_norm2_dot_cols, pfused_axpy_norm2_dot_hi, pfused_axpy_norm2_hi,
    pfused_norm2_dot, pfused_norm2_dot_cols, pfused_norm2_dot_hi, pfused_norm2_dot_partial,
    pnorm2, pnorm2_cols, pnorm2_hi, pscal, pxpay, pxpay_cols,
};

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::Arc;

use crate::accel::{BufKey, Engine, OpCost, TileCache, DEFAULT_DEVICE_MEM};
use crate::comm::ReduceOp;
use crate::mesh::Mesh;
use crate::Scalar;

/// Crash probe at a checkpoint/snapshot boundary (`DESIGN.md` §18): every
/// rank reports whether its scripted crash has fired
/// ([`crate::comm::Comm::take_crash`]), a crashed rank first pays the
/// plan's reboot cost on its own timeline (the allreduce then propagates
/// the stall to everyone, exactly like a real recovery barrier), and the
/// max-reduction tells all ranks — collectively and deterministically —
/// whether to roll back.  Callers gate on
/// [`crate::comm::FaultPlan::has_crashes`], so crash-free plans (and the
/// empty plan) add zero probe traffic.
pub fn fault_probe<S: Scalar>(ctx: &Ctx<'_, S>) -> bool {
    let comm = ctx.mesh.comm();
    let mine = if comm.take_crash() {
        let clock = comm.clock();
        clock.observe_arrival(clock.now() + comm.fault_plan().reboot_secs);
        S::one()
    } else {
        S::zero()
    };
    let hit = comm.world().allreduce_scalar(tags::FAULT, mine, ReduceOp::Max);
    hit > S::zero()
}

/// Tag blocks per routine family (collectives add small offsets).
pub(crate) mod tags {
    pub const PGEMV: u32 = 100;
    pub const PGEMV_T: u32 = 200;
    pub const PDOT: u32 = 300;
    pub const PGEMM: u32 = 400;
    pub const PSPMV: u32 = 500;
    pub const PSPMV_T: u32 = 600;
    /// Pipelined CG's fused (gamma, delta) allreduce.
    pub const PIPECG: u32 = 700;
    /// Two-lane allreduces of the fused BLAS-1 kernels.
    pub const FUSED: u32 = 800;
    /// k-lane allreduces of the column-batched (multi-RHS) pvec kernels.
    pub const PBLOCK: u32 = 900;
    pub const LU: u32 = 1_000;
    pub const CHOL: u32 = 2_000;
    pub const TRSV: u32 = 3_000;
    /// RHS-panel triangular solve (`ptrsm`) broadcasts.
    pub const TRSM: u32 = 3_500;
    /// Diagonal-extraction broadcasts (offset by the tile row index).
    pub const DIAG: u32 = 5_000;
    /// Symmetric-scaling allgathers.
    pub const SCALE: u32 = 5_100;
    /// Halo-exchange ghost segments (`+0` forward, `+1` transpose).
    pub const HALO: u32 = 6_000;
    /// The halo plan's one-time index handshake.
    pub const HALO_PLAN: u32 = 6_100;
    /// Schur-complement interface-system scalar allreduces.
    pub const SCHUR: u32 = 6_200;
    /// Mixed-precision refinement: the wide solution-vector ring
    /// allgather and the backward-error reductions.
    pub const MIXED: u32 = 6_300;
    /// Fault-probe allreduces at checkpoint/snapshot boundaries.
    pub const FAULT: u32 = 6_400;
}

/// How a send payload reaches the NIC ([`Ctx::wire_read`], `DESIGN.md`
/// §16): staged through the host (the paper's flow — a blocking
/// `host_read` already happened), or straight off the device with a D2H
/// leg to be carried jointly with the NIC leg by a `*_wire` primitive.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WireRoute {
    /// Host-staged: the payload was flushed and read on the host; pass a
    /// zero PCIe leg so every `*_wire` primitive collapses to its host
    /// twin.
    Host,
    /// GPUDirect: hand the device-dirty buffer to the NIC; the D2H leg
    /// occupies the copy engine jointly with the NIC occupancy.
    Direct {
        /// The payload's D2H leg at PCIe bandwidth.
        pcie_secs: f64,
    },
}

impl WireRoute {
    /// The PCIe leg to hand to a `*_wire` send (`0.0` = host-staged, which
    /// makes every wire primitive delegate to its host twin).
    pub fn pcie_secs(&self) -> f64 {
        match *self {
            WireRoute::Host => 0.0,
            WireRoute::Direct { pcie_secs } => pcie_secs,
        }
    }
}

/// Per-rank execution context: mesh view + local compute engine + the
/// rank's device-residency tracker ([`TileCache`], `DESIGN.md` §12) + the
/// copy-engine state for async prefetch / write-back (`DESIGN.md` §13).
pub struct Ctx<'a, S: Scalar> {
    /// This rank's mesh view.
    pub mesh: &'a Mesh<'a, S>,
    /// Local tile-compute engine (shared across ranks).
    pub engine: Arc<dyn Engine<S>>,
    /// Device residency tracker; `None` reproduces the paper's §3
    /// copy-per-call flow exactly.  Single-threaded per rank, hence the
    /// `RefCell` (same pattern as the comm endpoint's counters).
    cache: Option<RefCell<TileCache>>,
    /// Route transfers through the copy-engine timeline (async H2D
    /// prefetch + async D2H write-back)?  `false` keeps residency's
    /// synchronous accounting: every surviving transfer charges the
    /// compute timeline — the `--no-prefetch` A/B arm.
    prefetch: bool,
    /// Hand device-dirty send payloads straight to the NIC
    /// ([`Ctx::wire_read`], `DESIGN.md` §16)?  `false` keeps the paper's
    /// host-staged flow: a blocking `host_read` barrier before every send —
    /// the `--no-gpudirect` A/B arm.  Inert without residency + prefetch.
    gpudirect: bool,
    /// In-flight H2D prefetches by buffer identity: `(completion time,
    /// occupancy)` — the occupancy is what gets revoked from the hidden
    /// credit if the prefetch is abandoned before use.
    inflight: RefCell<HashMap<BufKey, (f64, f64)>>,
    /// Completion times of in-flight async D2H write-backs.
    flushes: RefCell<HashMap<BufKey, f64>>,
    /// Per-request attribution buckets (the `serve` layer's multi-tenant
    /// accounting, `DESIGN.md` §14): when enabled (`len == k + 1`), every
    /// charge adds its engine-priced total to the current tenant's bucket,
    /// or to the last (shared) bucket when no tenant is set.  Empty =
    /// attribution off (the default — single-request solves pay nothing).
    attribution: RefCell<Vec<f64>>,
    /// The request index charges are currently attributed to.
    tenant: std::cell::Cell<Option<usize>>,
}

impl<'a, S: Scalar> Ctx<'a, S> {
    /// Bundle a mesh view and an engine, with device residency enabled at
    /// the default (GTX 280) budget and copy-engine prefetch on.
    /// Residency and prefetch only re-price PCIe traffic (and *when* it
    /// crosses the link), never change results, so this is always safe.
    pub fn new(mesh: &'a Mesh<'a, S>, engine: Arc<dyn Engine<S>>) -> Self {
        Self::with_device_mem(mesh, engine, DEFAULT_DEVICE_MEM)
    }

    /// Residency with an explicit device-memory budget (bytes).
    pub fn with_device_mem(
        mesh: &'a Mesh<'a, S>,
        engine: Arc<dyn Engine<S>>,
        budget: usize,
    ) -> Self {
        Ctx {
            mesh,
            engine,
            cache: Some(RefCell::new(TileCache::new(budget))),
            prefetch: true,
            gpudirect: true,
            inflight: RefCell::new(HashMap::new()),
            flushes: RefCell::new(HashMap::new()),
            attribution: RefCell::new(Vec::new()),
            tenant: std::cell::Cell::new(None),
        }
    }

    /// The paper's §3 flow: every operand streams host<->device per call.
    pub fn streaming(mesh: &'a Mesh<'a, S>, engine: Arc<dyn Engine<S>>) -> Self {
        Ctx {
            mesh,
            engine,
            cache: None,
            prefetch: false,
            gpudirect: false,
            inflight: RefCell::new(HashMap::new()),
            flushes: RefCell::new(HashMap::new()),
            attribution: RefCell::new(Vec::new()),
            tenant: std::cell::Cell::new(None),
        }
    }

    /// Toggle the copy-engine timeline (builder style): with `false`, every
    /// surviving transfer charges the compute timeline synchronously — the
    /// `--no-prefetch` A/B arm.  Inert without residency.
    pub fn with_prefetch(mut self, enabled: bool) -> Self {
        self.prefetch = enabled;
        self
    }

    /// Toggle GPUDirect-style wire sends (builder style): with `false`,
    /// every send site stages its payload through the blocking
    /// [`Ctx::host_read`] barrier first — the `--no-gpudirect` A/B arm.
    /// Inert without residency + prefetch (there is no device-dirty state
    /// to put on the wire).
    pub fn with_gpudirect(mut self, enabled: bool) -> Self {
        self.gpudirect = enabled;
        self
    }

    /// Is the residency subsystem active?
    pub fn residency_enabled(&self) -> bool {
        self.cache.is_some()
    }

    /// Is the copy-engine (async prefetch / write-back) timeline active?
    pub fn prefetch_enabled(&self) -> bool {
        self.prefetch && self.cache.is_some()
    }

    /// Is the GPUDirect wire active?  Requires the copy-engine timeline:
    /// the wire's D2H leg rides the copy engine jointly with the NIC leg,
    /// so without prefetch there is no async timeline to ride.
    pub fn gpudirect_enabled(&self) -> bool {
        self.gpudirect && self.prefetch_enabled()
    }

    /// Charge an op cost to this rank's virtual clock, as-is (no
    /// residency adjustment — for ops whose operands can't stay resident).
    pub fn charge(&self, cost: OpCost) {
        self.attribute(&cost);
        cost.charge(self.mesh.comm().clock());
    }

    /// Turn on per-request attribution with `k` tenants (the `serve`
    /// layer's multi-tenant accounting): every subsequent charge adds its
    /// engine-priced total to the current tenant's bucket, or to the
    /// shared bucket when none is set.  Buckets reset on each call.
    pub fn enable_attribution(&self, k: usize) {
        *self.attribution.borrow_mut() = vec![0.0; k + 1];
        self.tenant.set(None);
    }

    /// Route subsequent charges to request `j`'s bucket (`None` = shared).
    pub fn set_tenant(&self, j: Option<usize>) {
        self.tenant.set(j);
    }

    /// Snapshot of the attribution buckets: `k` per-request totals followed
    /// by the shared bucket.  Empty when attribution is off.
    pub fn attribution(&self) -> Vec<f64> {
        self.attribution.borrow().clone()
    }

    /// Book `cost` against the current attribution bucket.  Attribution
    /// records the **engine-priced** (paper-flow) total — a residency- and
    /// prefetch-independent measure of each request's work, so tenant
    /// shares don't wobble with cache state (`DESIGN.md` §14).
    fn attribute(&self, cost: &OpCost) {
        let mut a = self.attribution.borrow_mut();
        if a.is_empty() {
            return;
        }
        let shared = a.len() - 1;
        let idx = match self.tenant.get() {
            Some(j) if j < shared => j,
            _ => shared,
        };
        a[idx] += cost.total();
    }

    /// The residency tracker, if the engine's profile actually streams
    /// (host profiles never pay PCIe, so there is nothing to track — and
    /// `pcie_saved_bytes` must stay 0 on them).
    fn active_cache(&self) -> Option<&RefCell<TileCache>> {
        if self.engine.profile().pcie_bw > 0.0 { self.cache.as_ref() } else { None }
    }

    /// Issue an **async H2D prefetch** of `buf` on the copy-engine timeline
    /// (`DESIGN.md` §13): if the buffer has no device copy, it is admitted
    /// to the cache exactly as a demand read would admit it, but the
    /// transfer occupies [`crate::comm::VClock::pcie_free`] instead of
    /// blocking compute — a later [`Ctx::charge_op`] on the same operand
    /// waits only the *remaining* latency, so a transfer fully covered by
    /// interleaved compute costs zero makespan.  The admitted entry is
    /// **pinned** until consumed — a later insertion declines rather than
    /// evict a buffer mid-DMA, so a pathologically tight budget degrades
    /// to the synchronous flow instead of wasting copy-engine traffic.
    /// No-op without residency, on host profiles (nothing streams) and
    /// with prefetch disabled; a no-op on cache hits and declined
    /// admissions too, so callers prefetch unconditionally.
    pub fn prefetch(&self, buf: &[S]) {
        if !self.prefetch {
            return;
        }
        let Some(cache) = self.active_cache() else {
            return;
        };
        let key = BufKey::of(buf);
        {
            let mut c = cache.borrow_mut();
            if c.is_resident(key) {
                // Hit (possibly still in flight from an earlier prefetch):
                // nothing to queue — and no recency retouch either, so the
                // eviction order stays exactly the demand accesses', like
                // the `--no-prefetch` arm (a real prefetch of present data
                // is a no-op, not an access).
                return;
            }
            let bytes = c.touch_read(key);
            if bytes == 0 || !c.is_resident(key) {
                // Oversized, or declined by pin pressure: nothing to queue.
                return;
            }
            c.pin(key);
        }
        let dt = key.bytes() as f64 / self.engine.profile().pcie_bw;
        let ready = self.mesh.comm().clock().pcie_occupy(dt);
        self.mesh.comm().stats().add_pcie_hidden(dt);
        self.inflight.borrow_mut().insert(key, (ready, dt));
    }

    /// Charge a tile-op cost with its transfer share re-priced by
    /// residency: `ins` are the operands the op read, `out` the operand it
    /// wrote (`cost` as returned by the engine, i.e. full paper-flow
    /// streaming).  A resident read operand stops streaming H2D; a written
    /// operand pays its D2H write-back once per dirty period instead of
    /// per call.  The bytes kept off the link are recorded in
    /// [`crate::comm::CommStats::pcie_saved_bytes`].
    ///
    /// With the copy-engine timeline active ([`Ctx::prefetch_enabled`]),
    /// the surviving transfers move off the compute timeline: a prefetched
    /// read operand waits only its remaining latency, and the write-back
    /// becomes an async D2H flushed at the next [`Ctx::host_read`] /
    /// retire barrier.  Per operand the compute-timeline charge is `<=`
    /// the synchronous residency charge, which is itself `<=` streaming —
    /// and the math executes identically in all three flows, so results
    /// are bit-identical (`tests/prefetch.rs`).
    pub fn charge_op(&self, cost: OpCost, ins: &[&[S]], out: Option<&[S]>) {
        self.attribute(&cost);
        let Some(cache) = self.active_cache() else {
            cost.charge(self.mesh.comm().clock());
            return;
        };
        if !self.prefetch {
            let keys: Vec<BufKey> = ins.iter().map(|b| BufKey::of(b)).collect();
            let traffic = cache.borrow_mut().access(&keys, out.map(BufKey::of));
            let pcie = self.engine.profile().pcie_bw;
            let adjusted = OpCost {
                compute_secs: cost.compute_secs,
                transfer_secs: traffic.streamed() as f64 / pcie,
            };
            adjusted.charge(self.mesh.comm().clock());
            self.mesh.comm().stats().add_pcie_saved(traffic.saved() as u64);
            return;
        }
        // Copy-engine accounting.  Per read operand: an in-flight prefetch
        // is waited (remaining latency only), a cold miss streams
        // synchronously, a resident hit is free.  The op's compute runs
        // after its operands land; the write-back is queued async.
        let pcie = self.engine.profile().pcie_bw;
        let clock = self.mesh.comm().clock();
        let stats = self.mesh.comm().stats();
        let (mut full, mut streamed) = (0usize, 0usize);
        {
            let mut c = cache.borrow_mut();
            let mut inflight = self.inflight.borrow_mut();
            for buf in ins {
                let key = BufKey::of(buf);
                full += key.bytes();
                let h2d = c.touch_read(key);
                if h2d == 0 {
                    if let Some((ready, _dt)) = inflight.remove(&key) {
                        // Served by an async prefetch: those bytes did
                        // cross the link (just on the copy engine), so
                        // they are not "saved"; block only for whatever
                        // compute failed to cover.
                        c.unpin(key);
                        streamed += key.bytes();
                        stats.add_prefetch_hit();
                        let remaining = (ready - clock.now()).max(0.0);
                        clock.pcie_wait(ready);
                        stats.revoke_pcie_hidden(remaining);
                    }
                } else {
                    // Cold miss: synchronous stream, as without prefetch.
                    // (A stale in-flight entry would mean the prefetched
                    // copy vanished before use — pinning prevents that,
                    // but stay defensive: the DMA then hid nothing, so
                    // take its whole credit back.)
                    if let Some((_ready, dt)) = inflight.remove(&key) {
                        c.unpin(key);
                        stats.revoke_pcie_hidden(dt);
                    }
                    streamed += h2d;
                    clock.advance_transfer(h2d as f64 / pcie);
                }
            }
            clock.advance_compute(cost.compute_secs);
            if let Some(buf) = out {
                let key = BufKey::of(buf);
                full += key.bytes();
                let d2h = c.touch_write(key);
                if d2h > 0 {
                    // Async flush: occupies the copy engine now, blocks
                    // nobody until the host needs the value.  The flush
                    // ledger lives on the Ctx, not the cache, so this
                    // covers oversized / admission-declined buffers too —
                    // their repeated write-backs queue on the copy engine
                    // instead of serialising with compute.
                    streamed += d2h;
                    let dt = d2h as f64 / pcie;
                    let ready = clock.pcie_occupy(dt);
                    stats.add_pcie_hidden(dt);
                    self.flushes.borrow_mut().insert(key, ready);
                }
            }
        }
        stats.add_pcie_saved((full - streamed) as u64);
    }

    /// Charge one fused BLAS-1 kernel over vector blocks (`ins` read,
    /// `outs` written), crediting the `replaced - 1` launches the unfused
    /// op-per-block sequence would have made.  A zero transfer share means
    /// the fused dispatch stayed host-side (tiny vectors — see
    /// [`crate::accel::Engine::blas1_fused_cost`]): no new PCIe traffic,
    /// but the *invalidation rules* still apply exactly as for the unfused
    /// host ops — the host observed every read operand (ending its dirty
    /// period) and mutated every written one (dropping its device copy).
    pub fn charge_fused(&self, cost: OpCost, ins: &[&[S]], outs: &[&[S]], replaced: u64) {
        self.attribute(&cost);
        if cost.transfer_secs == 0.0 {
            for buf in ins {
                self.host_read(buf);
            }
            for buf in outs {
                self.host_mut(buf);
            }
            cost.charge(self.mesh.comm().clock());
            self.mesh.comm().stats().add_launches_fused(replaced.saturating_sub(1));
            return;
        }
        if let Some(cache) = self.active_cache() {
            let mut traffic = crate::accel::Traffic::default();
            {
                let mut c = cache.borrow_mut();
                let in_keys: Vec<BufKey> = ins.iter().map(|b| BufKey::of(b)).collect();
                let t = c.access(&in_keys, None);
                traffic.h2d_bytes += t.h2d_bytes;
                traffic.full_bytes += t.full_bytes;
                for o in outs {
                    let t = c.access(&[], Some(BufKey::of(o)));
                    traffic.d2h_bytes += t.d2h_bytes;
                    traffic.full_bytes += t.full_bytes;
                }
            }
            let pcie = self.engine.profile().pcie_bw;
            let adjusted = OpCost {
                compute_secs: cost.compute_secs,
                transfer_secs: traffic.streamed() as f64 / pcie,
            };
            adjusted.charge(self.mesh.comm().clock());
            self.mesh.comm().stats().add_pcie_saved(traffic.saved() as u64);
        } else {
            cost.charge(self.mesh.comm().clock());
        }
        self.mesh.comm().stats().add_launches_fused(replaced.saturating_sub(1));
    }

    /// Charge an RHS-panel tile op (`trsm_panel`/`gemm_panel`): like
    /// [`Ctx::charge_op`] but with **several** written operands — one per
    /// panel column.  Residency prices each operand individually (the tile
    /// streams once for the whole panel, each column block pays its own
    /// dirty-period write-back); with the copy-engine timeline the
    /// write-backs queue async exactly as the single-column op's would.
    pub fn charge_panel_op(&self, cost: OpCost, ins: &[&[S]], outs: &[&[S]]) {
        if outs.len() <= 1 {
            self.charge_op(cost, ins, outs.first().copied());
            return;
        }
        self.attribute(&cost);
        let Some(cache) = self.active_cache() else {
            cost.charge(self.mesh.comm().clock());
            return;
        };
        let pcie = self.engine.profile().pcie_bw;
        if !self.prefetch {
            let in_keys: Vec<BufKey> = ins.iter().map(|b| BufKey::of(b)).collect();
            let mut traffic = crate::accel::Traffic::default();
            {
                let mut c = cache.borrow_mut();
                let t = c.access(&in_keys, None);
                traffic.h2d_bytes += t.h2d_bytes;
                traffic.full_bytes += t.full_bytes;
                for o in outs {
                    let t = c.access(&[], Some(BufKey::of(o)));
                    traffic.d2h_bytes += t.d2h_bytes;
                    traffic.full_bytes += t.full_bytes;
                }
            }
            let adjusted = OpCost {
                compute_secs: cost.compute_secs,
                transfer_secs: traffic.streamed() as f64 / pcie,
            };
            adjusted.charge(self.mesh.comm().clock());
            self.mesh.comm().stats().add_pcie_saved(traffic.saved() as u64);
            return;
        }
        // Copy-engine accounting: reads as in `charge_op`, then one async
        // write-back per panel column.
        let clock = self.mesh.comm().clock();
        let stats = self.mesh.comm().stats();
        let (mut full, mut streamed) = (0usize, 0usize);
        {
            let mut c = cache.borrow_mut();
            let mut inflight = self.inflight.borrow_mut();
            for buf in ins {
                let key = BufKey::of(buf);
                full += key.bytes();
                let h2d = c.touch_read(key);
                if h2d == 0 {
                    if let Some((ready, _dt)) = inflight.remove(&key) {
                        c.unpin(key);
                        streamed += key.bytes();
                        stats.add_prefetch_hit();
                        let remaining = (ready - clock.now()).max(0.0);
                        clock.pcie_wait(ready);
                        stats.revoke_pcie_hidden(remaining);
                    }
                } else {
                    if let Some((_ready, dt)) = inflight.remove(&key) {
                        c.unpin(key);
                        stats.revoke_pcie_hidden(dt);
                    }
                    streamed += h2d;
                    clock.advance_transfer(h2d as f64 / pcie);
                }
            }
            clock.advance_compute(cost.compute_secs);
            for buf in outs {
                let key = BufKey::of(buf);
                full += key.bytes();
                let d2h = c.touch_write(key);
                if d2h > 0 {
                    streamed += d2h;
                    let dt = d2h as f64 / pcie;
                    let ready = clock.pcie_occupy(dt);
                    stats.add_pcie_hidden(dt);
                    self.flushes.borrow_mut().insert(key, ready);
                }
            }
        }
        stats.add_pcie_saved((full - streamed) as u64);
    }

    /// Route a send payload onto the wire (`DESIGN.md` §16).  Under
    /// GPUDirect, a **device-dirty** buffer skips the [`Ctx::host_read`]
    /// staging barrier entirely: the caller gets
    /// [`WireRoute::Direct`] with the payload's D2H leg priced at PCIe
    /// bandwidth, to be handed to a `*_wire` send/collective — the NIC and
    /// copy engine are then occupied *jointly* and compute is never
    /// blocked.  The dirty period stays open and any in-flight async flush
    /// keeps flushing (the wire reads the device copy, not the host one);
    /// the flush wait the staged flow would have paid is booked to
    /// [`crate::comm::CommStats::host_stage_saved_secs`].
    ///
    /// In every other case — GPUDirect off, no residency, host profile, or
    /// a host-clean buffer (nothing dirty on the device) — this **is**
    /// `host_read`, returning [`WireRoute::Host`]: the `*_wire` primitives
    /// delegate to their host twins on a zero leg, so the flow is
    /// bit-identical to the staged one by construction.
    pub fn wire_read(&self, buf: &[S]) -> WireRoute {
        if !self.gpudirect_enabled() {
            self.host_read(buf);
            return WireRoute::Host;
        }
        let Some(cache) = self.active_cache() else {
            self.host_read(buf);
            return WireRoute::Host;
        };
        let key = BufKey::of(buf);
        if !cache.borrow().is_dirty(key) {
            self.host_read(buf);
            return WireRoute::Host;
        }
        if let Some(&ready) = self.flushes.borrow().get(&key) {
            let now = self.mesh.comm().clock().now();
            self.mesh.comm().stats().add_host_stage_saved((ready - now).max(0.0));
        }
        let pcie_secs = key.bytes() as f64 / self.engine.profile().pcie_bw;
        WireRoute::Direct { pcie_secs }
    }

    /// The host observes `buf`'s current value (message payload, gather,
    /// pivot search): ends the buffer's device dirty period.  This is also
    /// the copy-engine **flush barrier**: an async D2H write-back still in
    /// flight must land before the host can read the value, so the caller
    /// blocks for its remaining latency (`DESIGN.md` §13).
    pub fn host_read(&self, buf: &[S]) {
        if let Some(cache) = self.active_cache() {
            let key = BufKey::of(buf);
            if let Some(ready) = self.flushes.borrow_mut().remove(&key) {
                let clock = self.mesh.comm().clock();
                let remaining = (ready - clock.now()).max(0.0);
                clock.pcie_wait(ready);
                self.mesh.comm().stats().revoke_pcie_hidden(remaining);
            }
            cache.borrow_mut().host_read(key);
        }
    }

    /// Price the D2H leg of checkpointing `buf` (`DESIGN.md` §18): a
    /// device-dirty buffer's authoritative copy lives on the device, so a
    /// host-side checkpoint must copy it down — a blocking transfer on
    /// the copy-engine timeline (queued behind any in-flight async
    /// traffic, then waited).  Unlike [`Ctx::host_read`] this does **not**
    /// end the dirty period or touch the flush bookkeeping: the snapshot
    /// is a side read, and all later PCIe accounting must be exactly what
    /// it would have been without it.  No-op for host-clean buffers, host
    /// profiles, and with residency off.
    pub fn snapshot_read(&self, buf: &[S]) {
        let Some(cache) = self.active_cache() else { return };
        let key = BufKey::of(buf);
        if !cache.borrow().is_dirty(key) {
            return;
        }
        let dt = key.bytes() as f64 / self.engine.profile().pcie_bw;
        let clock = self.mesh.comm().clock();
        let ready = clock.pcie_occupy(dt);
        clock.pcie_wait(ready);
    }

    /// The host mutated `buf` (row swap, panel scatter) — or is about to
    /// free it (transient broadcast buffers are *retired* so a reused
    /// allocation can never alias a stale device copy).  Any in-flight
    /// async transfer for the buffer is abandoned without blocking: the
    /// host overwrites (or frees) the value, so it never needs the device
    /// copy — the occupancy already queued on the copy engine stays queued
    /// (the DMA was issued), but an abandoned *prefetch*'s hidden credit
    /// is revoked, since it never served an op.
    pub fn host_mut(&self, buf: &[S]) {
        if let Some(cache) = self.active_cache() {
            let key = BufKey::of(buf);
            if let Some((_ready, dt)) = self.inflight.borrow_mut().remove(&key) {
                // Abandoned before use: the DMA ran but hid nothing — take
                // the optimistic credit back so `pcie_hidden_secs` only
                // counts transfers that actually served an op.
                self.mesh.comm().stats().revoke_pcie_hidden(dt);
            }
            self.flushes.borrow_mut().remove(&key);
            cache.borrow_mut().host_mut(key);
        }
    }

    /// Tile edge of the active engine.
    pub fn tile(&self) -> usize {
        self.engine.tile()
    }
}
