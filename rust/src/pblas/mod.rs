//! Parallel BLAS over the 2-D block-cyclic layout — the workhorse layer the
//! CUPLSS API exposes ("routines that implement parallel BLAS operations").
//!
//! Every routine is SPMD: each rank calls it with its own shard and its
//! [`Ctx`]; real messages flow through the mesh communicators and every
//! local tile op goes through the active [`crate::accel::Engine`]
//! (accelerated or serial), charging the rank's virtual clock.
//!
//! Two operand formats share the layer: dense 2-D block-cyclic matrices
//! ([`pgemv()`], [`pgemm_acc`]) and sparse row-block CSR matrices
//! ([`pspmv()`]); the [`LinOp`] trait presents both to the Krylov solvers
//! through one `apply`/`apply_t` interface (see `DESIGN.md` §10).
//!
//! Tag discipline: each routine owns a tag block (see `tags`), so no two
//! overlapping collectives can cross-match.

pub mod linop;
pub mod pgemm;
pub mod pgemv;
pub mod pspmv;
pub mod pvec;

pub use linop::LinOp;
pub use pgemm::pgemm_acc;
pub use pgemv::{pgemv, pgemv_t};
pub use pspmv::{pspmv, pspmv_t};
pub use pvec::{paxpy, pcopy, pdot, pdot_partial, pnorm2, pscal};

use std::sync::Arc;

use crate::accel::{Engine, OpCost};
use crate::mesh::Mesh;
use crate::Scalar;

/// Tag blocks per routine family (collectives add small offsets).
pub(crate) mod tags {
    pub const PGEMV: u32 = 100;
    pub const PGEMV_T: u32 = 200;
    pub const PDOT: u32 = 300;
    pub const PGEMM: u32 = 400;
    pub const PSPMV: u32 = 500;
    pub const PSPMV_T: u32 = 600;
    /// Pipelined CG's fused (gamma, delta) allreduce.
    pub const PIPECG: u32 = 700;
    pub const LU: u32 = 1_000;
    pub const CHOL: u32 = 2_000;
    pub const TRSV: u32 = 3_000;
    /// Diagonal-extraction broadcasts (offset by the tile row index).
    pub const DIAG: u32 = 5_000;
    /// Symmetric-scaling allgathers.
    pub const SCALE: u32 = 5_100;
}

/// Per-rank execution context: mesh view + local compute engine.
pub struct Ctx<'a, S: Scalar> {
    /// This rank's mesh view.
    pub mesh: &'a Mesh<'a, S>,
    /// Local tile-compute engine (shared across ranks).
    pub engine: Arc<dyn Engine<S>>,
}

impl<'a, S: Scalar> Ctx<'a, S> {
    /// Bundle a mesh view and an engine.
    pub fn new(mesh: &'a Mesh<'a, S>, engine: Arc<dyn Engine<S>>) -> Self {
        Ctx { mesh, engine }
    }

    /// Charge an op cost to this rank's virtual clock.
    pub fn charge(&self, cost: OpCost) {
        cost.charge(self.mesh.comm().clock());
    }

    /// Tile edge of the active engine.
    pub fn tile(&self) -> usize {
        self.engine.tile()
    }
}
