//! Distributed matrix-vector products — the kernel of every Krylov solver.
//!
//! Layouts: `A` is 2-D block-cyclic; `x`, `y` are row-distributed /
//! column-replicated ([`DistVector`]).  Only square matrices are supported
//! (the solvers' domain).
//!
//! `y = A x` ([`pgemv`]):
//!   1. **column allgather** — every rank collects the x-blocks of its
//!      process column's tile columns (they live spread over process rows);
//!   2. **local** — per owned tile, `y_part(I) += A(I,J) x(J)` via the
//!      engine's fused `gemv_acc`, so the partial-sum block stays
//!      device-resident across the tile sweep (one write-back per matvec,
//!      not one per tile — DESIGN.md §13);
//!   3. **row allreduce** — partial sums meet across the process row, leaving
//!      y replicated exactly like x.
//!
//! `y = A^T x` ([`pgemv_t`], BiCG's second sequence):
//!   1. **local** — `w_part(J) += A(I,J)^T x(I)` via `gemv_t_acc` (x blocks
//!      are already home);
//!   2. **column reduce** per tile column to the process row that owns tile
//!      row J in the *vector* layout;
//!   3. **row allgather** — replicate the finished blocks across rows.
//!
//! Both local sweeps prefetch the next tile's operands onto the
//! copy-engine timeline, so first-touch / post-eviction H2D streams hide
//! under the current tile's compute.

use super::{tags, Ctx};
use crate::comm::ReduceOp;
use crate::dist::{ceil_div, DistMatrix, DistMultiVector, DistVector};
use crate::Scalar;

/// `y = A x`; returns y in the same layout as x.
pub fn pgemv<S: Scalar>(
    ctx: &Ctx<'_, S>,
    a: &DistMatrix<S>,
    x: &DistVector<S>,
) -> DistVector<S> {
    let desc = *a.desc();
    assert!(desc.is_square(), "pgemv requires a square matrix");
    assert_eq!(&desc, x.desc(), "pgemv operand descriptors differ");
    let t = desc.tile;
    let mesh = ctx.mesh;

    // 1. Column allgather of x blocks (contributions indexed by process row).
    let mut mine = Vec::with_capacity(x.local_blocks() * t);
    for l in 0..x.local_blocks() {
        ctx.host_read(x.block(l)); // payload read ends any device dirty period
        mine.extend_from_slice(x.block(l));
    }
    let col = mesh.col_comm();
    let by_row = col.allgather(tags::PGEMV, mine);
    let x_block = |tj: usize| -> &[S] {
        let owner = tj % desc.shape.pr;
        let off = desc.local_ti(tj) * t;
        &by_row[owner][off..off + t]
    };

    // 2. Local partial products via the fused `gemv_acc` (y += A·x): the
    // partial-sum block stays device-resident across the whole tile sweep
    // — one D2H per block per matvec (at the allreduce's host read) where
    // the former gemv-into-scratch + host-axpy pair paid a D2H *per tile*
    // (DESIGN.md §13).  The A tiles are read-only stream operands: with
    // residency they pay their H2D on the first iteration of a Krylov
    // solve and then stay device-side — the Ioannidis et al.
    // keep-the-matrix-on-the-GPU optimisation.  Each step prefetches the
    // *next* tile's operands onto the copy-engine timeline, so first-touch
    // (and post-eviction re-)streams hide under the current tile's gemv.
    let mut y_part = vec![S::zero(); x.local_blocks() * t];
    let tiles: Vec<(usize, usize, usize, usize)> = a.owned_tiles().collect();
    for (idx, &(lti, ltj, _ti, tj)) in tiles.iter().enumerate() {
        if let Some(&(nlti, nltj, _nti, ntj)) = tiles.get(idx + 1) {
            ctx.prefetch(a.tile(nlti, nltj));
            ctx.prefetch(x_block(ntj));
            ctx.prefetch(&y_part[nlti * t..(nlti + 1) * t]);
        }
        let cost = ctx
            .engine
            .gemv_acc(&mut y_part[lti * t..(lti + 1) * t], a.tile(lti, ltj), x_block(tj))
            .expect("gemv_acc");
        let y_block = &y_part[lti * t..(lti + 1) * t];
        ctx.charge_op(cost, &[y_block, a.tile(lti, ltj), x_block(tj)], Some(y_block));
    }
    // Retire the transient allgather slices before they drop (the cache is
    // keyed per x-block slice, so retire at the same granularity).
    for buf in &by_row {
        for chunk in buf.chunks(t) {
            ctx.host_mut(chunk);
        }
    }
    // The allreduce payload is read off every partial block: under
    // GPUDirect a device-dirty block rides the wire (its D2H leg charged
    // jointly with the NIC occupancy below); otherwise host_read is the
    // flush barrier exactly as before.  Retire the blocks afterwards —
    // the buffer moves into the collective and is freed there.
    let mut leg = 0.0;
    for chunk in y_part.chunks(t) {
        leg += ctx.wire_read(chunk).pcie_secs();
    }
    for chunk in y_part.chunks(t) {
        ctx.host_mut(chunk);
    }

    // 3. Row allreduce of partials.
    let row = mesh.row_comm();
    let summed = row.allreduce_vec_wire(tags::PGEMV + 1, y_part, ReduceOp::Sum, leg);

    let mut y = DistVector::zeros(desc, mesh.row(), mesh.col());
    for l in 0..y.local_blocks() {
        y.block_mut(l).copy_from_slice(&summed[l * t..(l + 1) * t]);
        // Fresh host-written blocks: drop any device entry a reused
        // allocation might alias (a prior iteration's matvec output).
        ctx.host_mut(y.block(l));
    }
    y
}

/// `Y = A X` over an RHS panel — the shared matvec sweep of the block
/// Krylov solvers: **one** column allgather carries every active column's
/// blocks, each owned `A` tile is fetched **once** and applied to all
/// active columns through one `gemm`-shaped panel kernel
/// ([`crate::accel::Engine::gemm_panel`]), and **one** row allreduce
/// combines every column's partials (one tree latency for the batch).
///
/// Per column the arithmetic is exactly [`pgemv`]'s — same tile order,
/// same `gemv_acc` accumulation, element-wise identical reduction trees —
/// so each active output column is bit-identical to a single-column
/// matvec.  Masked columns are skipped entirely and return zero vectors.
pub fn pgemv_cols<S: Scalar>(
    ctx: &Ctx<'_, S>,
    a: &DistMatrix<S>,
    x: &DistMultiVector<S>,
    active: &[bool],
) -> DistMultiVector<S> {
    let desc = *a.desc();
    assert!(desc.is_square(), "pgemv_cols requires a square matrix");
    assert_eq!(&desc, x.desc(), "pgemv_cols operand descriptors differ");
    assert_eq!(x.ncols(), active.len(), "pgemv_cols mask width mismatch");
    let t = desc.tile;
    let mesh = ctx.mesh;
    let pr = desc.shape.pr;
    let actives: Vec<usize> = (0..x.ncols()).filter(|&j| active[j]).collect();
    let na = actives.len();
    if na == 0 {
        return DistMultiVector::zeros(desc, mesh.row(), mesh.col(), x.ncols());
    }

    // 1. One column allgather carrying every active column's local blocks
    //    (per-owner layout: column-major over the active set).
    let local = x.col(0).local_blocks();
    let mut mine = Vec::with_capacity(na * local * t);
    for &j in &actives {
        for l in 0..local {
            ctx.host_read(x.col(j).block(l));
            mine.extend_from_slice(x.col(j).block(l));
        }
    }
    let col = mesh.col_comm();
    let by_row = col.allgather(tags::PGEMV + 2, mine);
    let owner_blocks = |owner: usize| -> usize {
        if owner >= desc.mt() { 0 } else { ceil_div(desc.mt() - owner, pr) }
    };
    let x_block = |ja: usize, tj: usize| -> &[S] {
        let owner = tj % pr;
        let off = (ja * owner_blocks(owner) + desc.local_ti(tj)) * t;
        &by_row[owner][off..off + t]
    };

    // 2. Shared tile sweep: every owned A tile streams once for the whole
    //    panel; the per-column partial blocks stay device-resident across
    //    the sweep and the next tile's operands prefetch depth-1.
    let mut y_parts: Vec<Vec<S>> = (0..na).map(|_| vec![S::zero(); local * t]).collect();
    let tiles: Vec<(usize, usize, usize, usize)> = a.owned_tiles().collect();
    for (idx, &(lti, ltj, _ti, tj)) in tiles.iter().enumerate() {
        if let Some(&(nlti, nltj, _nti, ntj)) = tiles.get(idx + 1) {
            ctx.prefetch(a.tile(nlti, nltj));
            for ja in 0..na {
                ctx.prefetch(x_block(ja, ntj));
                ctx.prefetch(&y_parts[ja][nlti * t..(nlti + 1) * t]);
            }
        }
        let xs: Vec<&[S]> = (0..na).map(|ja| x_block(ja, tj)).collect();
        let cost = {
            let mut cols: Vec<&mut [S]> =
                y_parts.iter_mut().map(|p| &mut p[lti * t..(lti + 1) * t]).collect();
            ctx.engine
                .gemm_panel("gemv_acc", &mut cols, a.tile(lti, ltj), &xs)
                .expect("gemm_panel gemv_acc")
        };
        let outs: Vec<&[S]> = y_parts.iter().map(|p| &p[lti * t..(lti + 1) * t]).collect();
        let mut operands: Vec<&[S]> = outs.clone();
        operands.push(a.tile(lti, ltj));
        operands.extend(xs.iter().copied());
        ctx.charge_panel_op(cost, &operands, &outs);
    }
    // Retire the transient allgather slices before they drop.
    for buf in &by_row {
        for chunk in buf.chunks(t) {
            ctx.host_mut(chunk);
        }
    }
    // Wire route + retirement for every column's partials: under
    // GPUDirect each device-dirty block contributes its D2H leg to the
    // allreduce's joint occupancy; otherwise host_read is the flush
    // barrier as before.
    let mut leg = 0.0;
    for part in &y_parts {
        for chunk in part.chunks(t) {
            leg += ctx.wire_read(chunk).pcie_secs();
        }
    }
    for part in &y_parts {
        for chunk in part.chunks(t) {
            ctx.host_mut(chunk);
        }
    }

    // 3. One row allreduce over the concatenated panel partials — the
    //    element-wise tree combine is the single-column allreduce's, so
    //    every lane matches the looped matvec bit for bit.
    let mut lanes = Vec::with_capacity(na * local * t);
    for part in y_parts {
        lanes.extend(part);
    }
    let row = mesh.row_comm();
    let summed = row.allreduce_vec_wire(tags::PGEMV + 3, lanes, ReduceOp::Sum, leg);

    let mut y = DistMultiVector::zeros(desc, mesh.row(), mesh.col(), x.ncols());
    for (ja, &j) in actives.iter().enumerate() {
        let base = ja * local * t;
        let yj = y.col_mut(j);
        for l in 0..local {
            yj.block_mut(l).copy_from_slice(&summed[base + l * t..base + (l + 1) * t]);
            ctx.host_mut(yj.block(l));
        }
    }
    y
}

/// `y = A^T x`; returns y in the same layout as x.
pub fn pgemv_t<S: Scalar>(
    ctx: &Ctx<'_, S>,
    a: &DistMatrix<S>,
    x: &DistVector<S>,
) -> DistVector<S> {
    let desc = *a.desc();
    assert!(desc.is_square(), "pgemv_t requires a square matrix");
    assert_eq!(&desc, x.desc(), "pgemv_t operand descriptors differ");
    let t = desc.tile;
    let mesh = ctx.mesh;
    let (pr, pc) = (desc.shape.pr, desc.shape.pc);

    // 1. Local partials per owned tile column, via the fused `gemv_t_acc`
    //    (w += A^T·x): like `pgemv`, the partial block stays
    //    device-resident across the tile sweep — one write-back per block
    //    per matvec instead of a per-tile host axpy + D2H (the ROADMAP's
    //    "pgemv_t partial accumulation" open item) — and each step
    //    prefetches the next tile's operands under the current gemv_t.
    let lnt = a.local_nt();
    let mut w_part = vec![S::zero(); lnt * t];
    let tiles: Vec<(usize, usize, usize, usize)> = a.owned_tiles().collect();
    for (idx, &(lti, ltj, ti, _tj)) in tiles.iter().enumerate() {
        if let Some(&(nlti, nltj, nti, _ntj)) = tiles.get(idx + 1) {
            ctx.prefetch(a.tile(nlti, nltj));
            ctx.prefetch(x.global_block(nti));
            ctx.prefetch(&w_part[nltj * t..(nltj + 1) * t]);
        }
        let cost = ctx
            .engine
            .gemv_t_acc(&mut w_part[ltj * t..(ltj + 1) * t], a.tile(lti, ltj), x.global_block(ti))
            .expect("gemv_t_acc");
        let w_block = &w_part[ltj * t..(ltj + 1) * t];
        ctx.charge_op(cost, &[w_block, a.tile(lti, ltj), x.global_block(ti)], Some(w_block));
    }

    // 2. Column reduce per tile column, rooted at the process row that owns
    //    tile row `tj` in the vector layout.  The reduction payload is a
    //    host read of each partial block (flush barrier); the blocks are
    //    retired afterwards — `w_part` is transient.
    let col = mesh.col_comm();
    let mut finished: Vec<(usize, Vec<S>)> = Vec::new(); // (tj, block)
    for ltj in 0..lnt {
        let tj = desc.global_tj(mesh.col(), ltj);
        let root = tj % pr;
        // Device-dirty partials ride the wire under GPUDirect; otherwise
        // this is the staged host_read flush barrier as before.
        let leg = ctx.wire_read(&w_part[ltj * t..(ltj + 1) * t]).pcie_secs();
        let block = w_part[ltj * t..(ltj + 1) * t].to_vec();
        if let Some(sum) = col.reduce_vec_wire(root, tags::PGEMV_T, block, ReduceOp::Sum, leg) {
            finished.push((tj, sum));
        }
    }
    for chunk in w_part.chunks(t) {
        ctx.host_mut(chunk);
    }

    // 3. Row allgather of finished blocks (each rank contributes the blocks
    //    it rooted, in ascending tj order).
    let mut mine = Vec::with_capacity(finished.len() * t);
    for (_, b) in &finished {
        mine.extend_from_slice(b);
    }
    let row = mesh.row_comm();
    let by_col = row.allgather(tags::PGEMV_T + 1, mine);

    // Source (r=my prow, c) holds blocks { tj : tj%pr==prow && tj%pc==c }.
    let mut y = DistVector::zeros(desc, mesh.row(), mesh.col());
    let nt = desc.nt();
    for c in 0..pc {
        let mut pos = 0usize;
        for tj in 0..nt {
            if tj % pr == mesh.row() && tj % pc == c {
                let src = &by_col[c][pos * t..(pos + 1) * t];
                y.global_block_mut(tj).copy_from_slice(src);
                ctx.host_mut(y.global_block(tj)); // fresh host data
                pos += 1;
            }
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::CpuEngine;
    use crate::comm::{NetworkModel, World};
    use crate::dist::{gather_vector, Descriptor};
    use crate::mesh::{Mesh, MeshShape};
    use std::sync::Arc;

    fn elem(i: usize, j: usize) -> f64 {
        ((i * 31 + j * 7) as f64).sin()
    }

    fn xval(i: usize) -> f64 {
        (i as f64 * 0.37).cos()
    }

    fn serial_matvec(n: usize, transpose: bool) -> Vec<f64> {
        let mut y = vec![0.0; n];
        for i in 0..n {
            for j in 0..n {
                if transpose {
                    y[j] += elem(i, j) * xval(i);
                } else {
                    y[i] += elem(i, j) * xval(j);
                }
            }
        }
        y
    }

    fn run_case(n: usize, tile: usize, pr: usize, pc: usize, transpose: bool) {
        let out = World::run::<f64, _, _>(pr * pc, NetworkModel::ideal(), move |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
            let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(tile)));
            let desc = Descriptor::new(n, n, tile, mesh.shape());
            // identity-padded A would perturb the transpose result only in
            // pad rows, which are sliced away by gather_vector.
            let a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), elem);
            let x = DistVector::from_fn(desc, mesh.row(), mesh.col(), xval);
            let y = if transpose { pgemv_t(&ctx, &a, &x) } else { pgemv(&ctx, &a, &x) };
            gather_vector(&mesh, &y)
        });
        let got = out[0].as_ref().unwrap();
        let want = serial_matvec(n, transpose);
        for i in 0..n {
            assert!(
                (got[i] - want[i]).abs() < 1e-10,
                "n={n} tile={tile} {pr}x{pc} T={transpose} i={i}: {} vs {}",
                got[i],
                want[i]
            );
        }
    }

    #[test]
    fn pgemv_matches_serial() {
        for (pr, pc) in [(1, 1), (2, 1), (1, 2), (2, 2), (2, 3), (3, 2)] {
            run_case(12, 4, pr, pc, false); // aligned
            run_case(13, 4, pr, pc, false); // padded edge tile
        }
    }

    #[test]
    fn pgemv_t_matches_serial() {
        for (pr, pc) in [(1, 1), (2, 1), (1, 2), (2, 2), (2, 3), (3, 2)] {
            run_case(12, 4, pr, pc, true);
            run_case(13, 4, pr, pc, true);
        }
    }

    #[test]
    fn pgemv_larger_mesh() {
        run_case(32, 4, 4, 4, false);
        run_case(32, 4, 4, 4, true);
    }

    /// The panel matvec is bit-identical, column for column, to the looped
    /// single-column `pgemv` — including on a padded size and with a masked
    /// column, which must come back as an untouched zero vector.
    #[test]
    fn pgemv_cols_matches_looped_pgemv_bitwise() {
        let n = 13usize;
        let k = 3usize;
        for (pr, pc) in [(1usize, 1usize), (2, 2), (2, 3)] {
            let out = World::run::<f64, _, _>(pr * pc, NetworkModel::ideal(), move |comm| {
                let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
                let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(4)));
                let desc = Descriptor::new(n, n, 4, mesh.shape());
                let a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), elem);
                let x = DistMultiVector::from_fn(desc, mesh.row(), mesh.col(), k, |i, j| {
                    ((i + 11 * j) as f64 * 0.37).cos()
                });
                let active = [true, false, true];
                let y = pgemv_cols(&ctx, &a, &x, &active);
                let mut cols = Vec::new();
                for j in 0..k {
                    let want = if active[j] {
                        pgemv(&ctx, &a, x.col(j))
                    } else {
                        DistVector::zeros(desc, mesh.row(), mesh.col())
                    };
                    cols.push((gather_vector(&mesh, y.col(j)), gather_vector(&mesh, &want)));
                }
                cols
            });
            for (j, (got, want)) in out[0].iter().enumerate() {
                let (got, want) = (got.as_ref().unwrap(), want.as_ref().unwrap());
                for i in 0..n {
                    assert!(
                        got[i].to_bits() == want[i].to_bits(),
                        "{pr}x{pc} col {j} row {i}: {} vs {}",
                        got[i],
                        want[i]
                    );
                }
            }
        }
    }
}
