//! Distributed GEMM (SUMMA) — `C += A·B` over block-cyclic operands.
//!
//! The classic algorithm: for each tile step `kk`, the owners of tile column
//! `A(:,kk)` broadcast their tiles along process rows, the owners of tile row
//! `B(kk,:)` broadcast along process columns, and every rank accumulates
//! `C(i,j) += A(i,kk)·B(kk,j)` locally.
//!
//! This is the **pipelined** (double-buffered) variant: panel `kk+1`'s
//! broadcasts are *started* (split-phase, [`crate::comm::BcastRequest`])
//! before the rank multiplies panel `kk`, so the next panel streams through
//! the network while the current one streams through the FPUs — the virtual
//! clock sees `max(bcast, gemm)` per step instead of their sum (DESIGN.md
//! §11).  The same discipline applies to PCIe: the accumulation loop
//! prefetches the next tile's operands onto the copy-engine timeline, so
//! the panel H2D streams hide under the gemm stream too (DESIGN.md §13).
//! Message order and numerics are identical to the one-panel-in-
//! flight algorithm: panels are waited in `kk` order and the accumulation
//! order is unchanged.
//!
//! Operands may be **rectangular**: `A` is `m x k`, `B` is `k x n`, `C` is
//! `m x n`, all square-tiled on the same mesh with the same tile size.
//! Edge-tile padding (identity for dense operands) is masked to zero in the
//! broadcast copies so padded positions of `A`'s columns / `B`'s rows never
//! pollute real entries of `C` — with a rectangular inner dimension the pad
//! diagonal of `A`'s last tile column would otherwise multiply the pad
//! diagonal of `B`'s last tile row straight into `C`'s real diagonal.

use super::{tags, Ctx};
use crate::comm::{BcastRequest, Payload};
use crate::dist::DistMatrix;
use crate::Scalar;

/// One SUMMA panel in flight: the split-phase broadcasts of `A(:,kk)` along
/// process rows and `B(kk,:)` along process columns.
struct PanelInFlight<'a, S: Scalar> {
    a: Vec<BcastRequest<'a, S>>,
    b: Vec<BcastRequest<'a, S>>,
}

impl<'a, S: Scalar> PanelInFlight<'a, S> {
    fn wait(self) -> (Vec<Vec<S>>, Vec<Vec<S>>) {
        let a = self.a.into_iter().map(|r| r.wait().into_data()).collect();
        let b = self.b.into_iter().map(|r| r.wait().into_data()).collect();
        (a, b)
    }
}

/// Copy tile `(ti, tj)` of `m`'s descriptor with any padded rows/columns
/// zeroed (the identity pad is a factorisation invariant, not a GEMM one).
fn masked_tile<S: Scalar>(
    m: &DistMatrix<S>,
    lti: usize,
    ltj: usize,
    ti: usize,
    tj: usize,
) -> Vec<S> {
    let d = m.desc();
    let t = d.tile;
    let mut out = m.tile(lti, ltj).to_vec();
    let real_rows = d.m.saturating_sub(ti * t).min(t);
    let real_cols = d.n.saturating_sub(tj * t).min(t);
    if real_rows < t || real_cols < t {
        for r in 0..t {
            for c in 0..t {
                if r >= real_rows || c >= real_cols {
                    out[r * t + c] = S::zero();
                }
            }
        }
    }
    out
}

/// Start the split-phase broadcasts of panel `kk`.
fn start_panel<'a, S: Scalar>(
    ctx: &Ctx<'a, S>,
    a: &DistMatrix<S>,
    b: &DistMatrix<S>,
    kk: usize,
) -> PanelInFlight<'a, S> {
    let mesh = ctx.mesh;
    let shape = mesh.shape();
    let a_owner_col = kk % shape.pc;
    let b_owner_row = kk % shape.pr;
    let row = mesh.row_comm();
    let col = mesh.col_comm();

    // The panel tiles go through the wire route like every other sender;
    // SUMMA's operands are read-only (never device-dirty), so the route is
    // always `Host` and the wire broadcasts collapse to their host twins —
    // an exact wash by construction (`DESIGN.md` §16).
    let mut a_req = Vec::with_capacity(a.local_mt());
    for lti in 0..a.local_mt() {
        let mut leg = 0.0;
        let data = if mesh.col() == a_owner_col {
            let ti = a.desc().global_ti(mesh.row(), lti);
            leg = ctx.wire_read(a.tile(lti, a.desc().local_tj(kk))).pcie_secs();
            Some(Payload::Data(masked_tile(a, lti, a.desc().local_tj(kk), ti, kk)))
        } else {
            None
        };
        a_req.push(row.ibcast_wire(a_owner_col, tags::PGEMM, data, leg));
    }
    let mut b_req = Vec::with_capacity(b.local_nt());
    for ltj in 0..b.local_nt() {
        let mut leg = 0.0;
        let data = if mesh.row() == b_owner_row {
            let tj = b.desc().global_tj(mesh.col(), ltj);
            leg = ctx.wire_read(b.tile(b.desc().local_ti(kk), ltj)).pcie_secs();
            Some(Payload::Data(masked_tile(b, b.desc().local_ti(kk), ltj, kk, tj)))
        } else {
            None
        };
        b_req.push(col.ibcast_wire(b_owner_row, tags::PGEMM + 1, data, leg));
    }
    PanelInFlight { a: a_req, b: b_req }
}

/// `C += A·B` for conformable square-tiled operands: `A` is `m x k`, `B` is
/// `k x n`, `C` is `m x n`, all with the same tile size on the same mesh.
pub fn pgemm_acc<S: Scalar>(
    ctx: &Ctx<'_, S>,
    a: &DistMatrix<S>,
    b: &DistMatrix<S>,
    c: &mut DistMatrix<S>,
) {
    let (ad, bd, cd) = (*a.desc(), *b.desc(), *c.desc());
    assert_eq!(ad.tile, bd.tile, "pgemm operand tile sizes differ");
    assert_eq!(ad.tile, cd.tile, "pgemm output tile size differs");
    assert_eq!(ad.shape, bd.shape, "pgemm operand meshes differ");
    assert_eq!(ad.shape, cd.shape, "pgemm output mesh differs");
    assert_eq!(ad.m, cd.m, "pgemm: A rows ({}) != C rows ({})", ad.m, cd.m);
    assert_eq!(bd.n, cd.n, "pgemm: B cols ({}) != C cols ({})", bd.n, cd.n);
    assert_eq!(ad.n, bd.m, "pgemm: inner dimensions differ ({} vs {})", ad.n, bd.m);
    let t = ad.tile;
    let kt = ad.nt(); // == bd.mt(): tile steps along the inner dimension

    // Double-buffer: panel kk+1 is on the wire while panel kk multiplies.
    let mut inflight = Some(start_panel(ctx, a, b, 0));
    for kk in 0..kt {
        let (a_panel, b_panel) = inflight.take().expect("panel in flight").wait();
        if kk + 1 < kt {
            inflight = Some(start_panel(ctx, a, b, kk + 1));
        }

        // Local accumulation (order identical to the blocking variant):
        // one fused `C += A·B` kernel per tile, so each C tile stays
        // device-resident across the kk steps — the panel buffers stream
        // up once per step (their first touch), C never leaves the device
        // until somebody reads it host-side (DESIGN.md §12).  The former
        // gemm-into-scratch + host-axpy pair paid a per-call D2H for the
        // scratch *and* a full extra memory pass.  Each step prefetches
        // the *next* tile's operands onto the copy-engine timeline, so
        // this step's panel H2D (first touch of `a_panel`/`b_panel`, and
        // the C fill on step 0) rides under the gemm stream instead of
        // serialising with it (DESIGN.md §13).
        let tiles: Vec<(usize, usize)> = (0..c.local_mt())
            .flat_map(|lti| (0..c.local_nt()).map(move |ltj| (lti, ltj)))
            .collect();
        for (idx, &(lti, ltj)) in tiles.iter().enumerate() {
            if let Some(&(nlti, nltj)) = tiles.get(idx + 1) {
                ctx.prefetch(c.tile(nlti, nltj));
                ctx.prefetch(&a_panel[nlti]);
                ctx.prefetch(&b_panel[nltj]);
            }
            let cost = ctx
                .engine
                .gemm_acc(c.tile_mut(lti, ltj), &a_panel[lti], &b_panel[ltj])
                .expect("gemm_acc");
            let c_tile = c.tile(lti, ltj);
            ctx.charge_op(
                cost,
                &[c_tile, &a_panel[lti], &b_panel[ltj]],
                Some(c_tile),
            );
        }

        // Retire the panel buffers before they drop: a reused allocation
        // must never alias a stale device copy.
        for buf in a_panel.iter().chain(&b_panel) {
            ctx.host_mut(buf);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::CpuEngine;
    use crate::comm::{NetworkModel, World};
    use crate::dist::{gather_matrix, Descriptor};
    use crate::mesh::{Mesh, MeshShape};
    use std::sync::Arc;

    fn aval(i: usize, j: usize) -> f64 {
        ((i + 2 * j) as f64 * 0.1).sin()
    }

    fn bval(i: usize, j: usize) -> f64 {
        ((3 * i + j) as f64 * 0.07).cos()
    }

    #[test]
    fn summa_matches_serial() {
        let n = 12usize;
        let tile = 4usize;
        for (pr, pc) in [(1, 1), (2, 2), (2, 3)] {
            let out = World::run::<f64, _, _>(pr * pc, NetworkModel::ideal(), move |comm| {
                let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
                let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(tile)));
                let desc = Descriptor::new(n, n, tile, mesh.shape());
                let a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), aval);
                let b = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), bval);
                let mut c = DistMatrix::zeros(desc, mesh.row(), mesh.col());
                pgemm_acc(&ctx, &a, &b, &mut c);
                gather_matrix(&mesh, &c)
            });
            let got = out[0].as_ref().unwrap();
            for i in 0..n {
                for j in 0..n {
                    let want: f64 = (0..n).map(|k| aval(i, k) * bval(k, j)).sum();
                    assert!(
                        (got[i * n + j] - want).abs() < 1e-10,
                        "{pr}x{pc} ({i},{j}): {} vs {want}",
                        got[i * n + j]
                    );
                }
            }
        }
    }

    #[test]
    fn summa_rectangular_with_padding_matches_serial() {
        // m x k * k x n with every dimension padding differently; the inner
        // dimension's pad identity must NOT leak into C's real diagonal.
        let (m, k, n) = (10usize, 6usize, 14usize);
        let tile = 4usize;
        for (pr, pc) in [(1, 1), (2, 2), (2, 3), (3, 2)] {
            let out = World::run::<f64, _, _>(pr * pc, NetworkModel::ideal(), move |comm| {
                let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
                let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(tile)));
                let da = Descriptor::new(m, k, tile, mesh.shape());
                let db = Descriptor::new(k, n, tile, mesh.shape());
                let dc = Descriptor::new(m, n, tile, mesh.shape());
                let a = DistMatrix::from_fn(da, mesh.row(), mesh.col(), aval);
                let b = DistMatrix::from_fn(db, mesh.row(), mesh.col(), bval);
                let mut c = DistMatrix::zeros(dc, mesh.row(), mesh.col());
                pgemm_acc(&ctx, &a, &b, &mut c);
                gather_matrix(&mesh, &c)
            });
            let got = out[0].as_ref().unwrap();
            for i in 0..m {
                for j in 0..n {
                    let want: f64 = (0..k).map(|kk| aval(i, kk) * bval(kk, j)).sum();
                    assert!(
                        (got[i * n + j] - want).abs() < 1e-10,
                        "{pr}x{pc} ({i},{j}): {} vs {want}",
                        got[i * n + j]
                    );
                }
            }
        }
    }

    #[test]
    #[should_panic] // "inner dimensions differ", surfaced through the rank thread join
    fn summa_rejects_nonconformable() {
        let out = World::run::<f64, _, _>(1, NetworkModel::ideal(), |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(1, 1));
            let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(4)));
            let a = DistMatrix::from_fn(
                Descriptor::new(8, 4, 4, mesh.shape()),
                0,
                0,
                aval,
            );
            let b = DistMatrix::from_fn(
                Descriptor::new(8, 8, 4, mesh.shape()),
                0,
                0,
                bval,
            );
            let mut c = DistMatrix::zeros(Descriptor::new(8, 8, 4, mesh.shape()), 0, 0);
            pgemm_acc(&ctx, &a, &b, &mut c);
        });
        drop(out);
    }

    #[test]
    fn summa_accumulates_into_c() {
        let n = 8usize;
        let out = World::run::<f64, _, _>(4, NetworkModel::ideal(), move |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(2, 2));
            let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(4)));
            let desc = Descriptor::new(n, n, 4, mesh.shape());
            let a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), |i, j| {
                if i == j { 1.0 } else { 0.0 }
            });
            let b = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), aval);
            let mut c = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), |_, _| 10.0);
            pgemm_acc(&ctx, &a, &b, &mut c); // C = 10 + I*B
            gather_matrix(&mesh, &c)
        });
        let got = out[0].as_ref().unwrap();
        for i in 0..n {
            for j in 0..n {
                assert!((got[i * n + j] - (10.0 + aval(i, j))).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn pipelining_overlaps_panel_broadcasts() {
        // On a gigabit network the double-buffered SUMMA must spend less
        // virtual time blocked than a serialised panel stream would: with
        // prefetch, some latency is recorded as hidden.
        let out = World::run::<f64, _, _>(4, NetworkModel::gigabit_ethernet(), |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(2, 2));
            let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(8)));
            let desc = Descriptor::new(64, 64, 8, mesh.shape());
            let a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), aval);
            let b = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), bval);
            let mut c = DistMatrix::zeros(desc, mesh.row(), mesh.col());
            pgemm_acc(&ctx, &a, &b, &mut c);
            comm.stats().wait_saved_secs()
        });
        assert!(
            out.iter().any(|&s| s > 0.0),
            "prefetch must hide some panel latency: {out:?}"
        );
    }
}
