//! Distributed GEMM (SUMMA) — `C += A·B` over block-cyclic operands.
//!
//! The classic algorithm: for each tile step `kk`, the owners of tile column
//! `A(:,kk)` broadcast their tiles along process rows, the owners of tile row
//! `B(kk,:)` broadcast along process columns, and every rank accumulates
//! `C(i,j) += A(i,kk)·B(kk,j)` locally.  One panel in flight at a time —
//! the bandwidth-friendly variant; the virtual clock sees `nt` rounds of
//! `log P`-deep broadcasts, matching SUMMA's known cost shape.

use super::{tags, Ctx};
use crate::comm::Payload;
use crate::dist::DistMatrix;
use crate::{linalg, Scalar};

/// `C += A·B`.  All three matrices must share descriptor geometry (square,
/// same tile, same mesh).
pub fn pgemm_acc<S: Scalar>(
    ctx: &Ctx<'_, S>,
    a: &DistMatrix<S>,
    b: &DistMatrix<S>,
    c: &mut DistMatrix<S>,
) {
    let desc = *a.desc();
    assert_eq!(&desc, b.desc(), "pgemm operand descriptors differ");
    assert_eq!(&desc, c.desc(), "pgemm output descriptor differs");
    assert!(desc.is_square(), "pgemm_acc requires square operands");
    let t = desc.tile;
    let mesh = ctx.mesh;
    let row = mesh.row_comm();
    let col = mesh.col_comm();
    let nt = desc.nt();

    let mut tmp = vec![S::zero(); t * t];
    for kk in 0..nt {
        let a_owner_col = kk % desc.shape.pc;
        let b_owner_row = kk % desc.shape.pr;

        // A(:, kk) tiles broadcast along rows (one per owned tile row).
        let mut a_panel: Vec<Vec<S>> = Vec::with_capacity(a.local_mt());
        for lti in 0..a.local_mt() {
            let data = if mesh.col() == a_owner_col {
                Some(Payload::Data(a.tile(lti, desc.local_tj(kk)).to_vec()))
            } else {
                None
            };
            let tile = row.bcast(a_owner_col, tags::PGEMM, data).into_data();
            a_panel.push(tile);
        }

        // B(kk, :) tiles broadcast along columns (one per owned tile col).
        let mut b_panel: Vec<Vec<S>> = Vec::with_capacity(b.local_nt());
        for ltj in 0..b.local_nt() {
            let data = if mesh.row() == b_owner_row {
                Some(Payload::Data(b.tile(desc.local_ti(kk), ltj).to_vec()))
            } else {
                None
            };
            let tile = col.bcast(b_owner_row, tags::PGEMM + 1, data).into_data();
            b_panel.push(tile);
        }

        // Local accumulation.
        for lti in 0..c.local_mt() {
            for ltj in 0..c.local_nt() {
                let cost =
                    ctx.engine.gemm(&a_panel[lti], &b_panel[ltj], &mut tmp).expect("gemm");
                ctx.charge(cost);
                linalg::axpy(S::one(), &tmp, c.tile_mut(lti, ltj));
                ctx.charge(ctx.engine.blas1_cost(t * t));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::CpuEngine;
    use crate::comm::{NetworkModel, World};
    use crate::dist::{gather_matrix, Descriptor};
    use crate::mesh::{Mesh, MeshShape};
    use std::sync::Arc;

    fn aval(i: usize, j: usize) -> f64 {
        ((i + 2 * j) as f64 * 0.1).sin()
    }

    fn bval(i: usize, j: usize) -> f64 {
        ((3 * i + j) as f64 * 0.07).cos()
    }

    #[test]
    fn summa_matches_serial() {
        let n = 12usize;
        let tile = 4usize;
        for (pr, pc) in [(1, 1), (2, 2), (2, 3)] {
            let out = World::run::<f64, _, _>(pr * pc, NetworkModel::ideal(), move |comm| {
                let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
                let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(tile)));
                let desc = Descriptor::new(n, n, tile, mesh.shape());
                let a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), aval);
                let b = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), bval);
                let mut c = DistMatrix::zeros(desc, mesh.row(), mesh.col());
                pgemm_acc(&ctx, &a, &b, &mut c);
                gather_matrix(&mesh, &c)
            });
            let got = out[0].as_ref().unwrap();
            for i in 0..n {
                for j in 0..n {
                    let want: f64 = (0..n).map(|k| aval(i, k) * bval(k, j)).sum();
                    assert!(
                        (got[i * n + j] - want).abs() < 1e-10,
                        "{pr}x{pc} ({i},{j}): {} vs {want}",
                        got[i * n + j]
                    );
                }
            }
        }
    }

    #[test]
    fn summa_accumulates_into_c() {
        let n = 8usize;
        let out = World::run::<f64, _, _>(4, NetworkModel::ideal(), move |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(2, 2));
            let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(4)));
            let desc = Descriptor::new(n, n, 4, mesh.shape());
            let a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), |i, j| {
                if i == j { 1.0 } else { 0.0 }
            });
            let b = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), aval);
            let mut c = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), |_, _| 10.0);
            pgemm_acc(&ctx, &a, &b, &mut c); // C = 10 + I*B
            gather_matrix(&mesh, &c)
        });
        let got = out[0].as_ref().unwrap();
        for i in 0..n {
            for j in 0..n {
                assert!((got[i * n + j] - (10.0 + aval(i, j))).abs() < 1e-12);
            }
        }
    }
}
