//! The operator abstraction the Krylov solvers run on.
//!
//! Every non-stationary iterative method in this crate touches its system
//! matrix only through matvecs (`A x`, and `A^T x` for the BiCG family)
//! plus, for Jacobi preconditioning, diagonal extraction and symmetric
//! scaling.  [`LinOp`] captures exactly that contract, so the *same*
//! solver code runs on a dense 2-D block-cyclic [`DistMatrix`] (delegating
//! to [`pgemv`]/[`pgemv_t`]) or a sparse row-block [`DistCsrMatrix`]
//! (delegating to [`super::pspmv()`]/[`super::pspmv_t`]) with no per-solver
//! forks — the dense/sparse analogue of the engine swap at level 2.
//!
//! Contract (see `DESIGN.md` §10):
//!
//! * `desc()` names the layout; operands are conformable with a vector iff
//!   the descriptors are equal (the validation every PBLAS routine makes);
//! * `apply`/`apply_t` consume and produce the standard row-distributed,
//!   column-replicated [`DistVector`] layout, charging the virtual clock
//!   for local compute and every message;
//! * `extract_diag` returns the operator's diagonal in that same vector
//!   layout (positions at or beyond `m` are unspecified — callers guard);
//! * `scale_sym` applies the two-sided scaling `A := diag(d) A diag(d)`
//!   used by [`crate::solvers::JacobiPrecond`].

use super::pgemv::{pgemv, pgemv_cols, pgemv_t};
use super::pspmv::{pspmv, pspmv_halo, pspmv_t, pspmv_t_halo};
use super::{tags, Ctx};
use crate::comm::Payload;
use crate::dist::{Descriptor, DistMatrix, DistMultiVector, DistVector};
use crate::sparse::{DistCsrMatrix, HaloCsr};
use crate::Scalar;

/// A distributed linear operator the Krylov solvers can consume.
pub trait LinOp<S: Scalar> {
    /// The layout descriptor vectors must match (descriptor equality is
    /// conformability).
    fn desc(&self) -> &Descriptor;

    /// `y = A x` in the standard vector layout.
    fn apply(&self, ctx: &Ctx<'_, S>, x: &DistVector<S>) -> DistVector<S>;

    /// `y = A^T x` (the BiCG/QMR-style second sequence).
    fn apply_t(&self, ctx: &Ctx<'_, S>, x: &DistVector<S>) -> DistVector<S>;

    /// `Y = A X` over an RHS panel with a per-column activity mask — the
    /// shared matvec sweep of the block Krylov solvers.  Masked columns
    /// return zero vectors.  The default loops [`LinOp::apply`] per active
    /// column (tagging each for per-request attribution); dense operators
    /// override with the tile-amortized [`pgemv_cols`].  Either path is
    /// bit-identical, column for column, to the looped single-column apply.
    fn apply_cols(
        &self,
        ctx: &Ctx<'_, S>,
        x: &DistMultiVector<S>,
        active: &[bool],
    ) -> DistMultiVector<S> {
        assert_eq!(x.ncols(), active.len(), "apply_cols mask width mismatch");
        let cols = (0..x.ncols())
            .map(|j| {
                if active[j] {
                    ctx.set_tenant(Some(j));
                    let y = self.apply(ctx, x.col(j));
                    ctx.set_tenant(None);
                    y
                } else {
                    DistVector::zeros(*x.desc(), ctx.mesh.row(), ctx.mesh.col())
                }
            })
            .collect();
        DistMultiVector::from_cols(cols)
    }

    /// The operator's diagonal as a standard distributed vector.  Entries
    /// at padded positions (global index ≥ `m`) are format-specific
    /// (identity padding for dense, zero for sparse) — callers must guard.
    fn extract_diag(&self, ctx: &Ctx<'_, S>) -> DistVector<S>;

    /// Two-sided symmetric scaling `A := diag(d) A diag(d)`.
    fn scale_sym(&mut self, ctx: &Ctx<'_, S>, d: &DistVector<S>);
}

impl<S: Scalar> LinOp<S> for DistMatrix<S> {
    fn desc(&self) -> &Descriptor {
        DistMatrix::desc(self)
    }

    fn apply(&self, ctx: &Ctx<'_, S>, x: &DistVector<S>) -> DistVector<S> {
        pgemv(ctx, self, x)
    }

    fn apply_t(&self, ctx: &Ctx<'_, S>, x: &DistVector<S>) -> DistVector<S> {
        pgemv_t(ctx, self, x)
    }

    /// Dense override: one allgather / one tile sweep / one allreduce for
    /// the whole panel ([`pgemv_cols`]) — each `A` tile streams once for
    /// all k columns instead of once per column.
    fn apply_cols(
        &self,
        ctx: &Ctx<'_, S>,
        x: &DistMultiVector<S>,
        active: &[bool],
    ) -> DistMultiVector<S> {
        pgemv_cols(ctx, self, x, active)
    }

    /// The diagonal tiles live at mesh coordinates `(ti mod pr, ti mod pc)`;
    /// each owner broadcasts its tile's diagonal along its process row, and
    /// the standard vector layout is assembled locally.
    fn extract_diag(&self, ctx: &Ctx<'_, S>) -> DistVector<S> {
        let desc = *DistMatrix::desc(self);
        assert!(desc.is_square(), "extract_diag requires a square matrix");
        let t = desc.tile;
        let mesh = ctx.mesh;
        let row = mesh.row_comm();
        let mut diag = DistVector::zeros(desc, mesh.row(), mesh.col());
        for l in 0..diag.local_blocks() {
            let ti = desc.global_ti(mesh.row(), l);
            let owner_col = ti % desc.shape.pc;
            let data = if mesh.col() == owner_col {
                let tile = self.global_tile(ti, ti);
                let mut d = vec![S::zero(); t];
                for i in 0..t {
                    d[i] = tile[i * t + i];
                }
                Some(Payload::Data(d))
            } else {
                None
            };
            let d = row.bcast(owner_col, tags::DIAG + ti as u32, data).into_data();
            diag.block_mut(l).copy_from_slice(&d);
        }
        diag
    }

    /// Row scaling needs `d` for owned tile rows (local); column scaling
    /// needs `d` over every tile row — the same full-vector assembly
    /// `pspmv` uses ([`super::pspmv::allgather_full`]).
    fn scale_sym(&mut self, ctx: &Ctx<'_, S>, d: &DistVector<S>) {
        let desc = *DistMatrix::desc(self);
        assert!(desc.is_square(), "scale_sym requires a square matrix");
        assert_eq!(&desc, d.desc(), "scale_sym layout mismatch");
        let t = desc.tile;
        let dfull = super::pspmv::allgather_full(ctx, d, tags::SCALE);
        let tiles: Vec<_> = self.owned_tiles().collect();
        for (lti, ltj, ti, tj) in tiles {
            let drow = d.global_block(ti);
            let dcol = &dfull[tj * t..(tj + 1) * t];
            let tile = self.tile_mut(lti, ltj);
            for i in 0..t {
                for j in 0..t {
                    tile[i * t + j] *= drow[i] * dcol[j];
                }
            }
            ctx.charge(ctx.engine.blas1_cost(t * t));
        }
    }
}

impl<S: Scalar> LinOp<S> for DistCsrMatrix<S> {
    fn desc(&self) -> &Descriptor {
        DistCsrMatrix::desc(self)
    }

    fn apply(&self, ctx: &Ctx<'_, S>, x: &DistVector<S>) -> DistVector<S> {
        pspmv(ctx, self, x)
    }

    fn apply_t(&self, ctx: &Ctx<'_, S>, x: &DistVector<S>) -> DistVector<S> {
        pspmv_t(ctx, self, x)
    }

    /// Row-block layout: each rank's diagonal entries sit inside its own
    /// rows (replicated across process columns like the vector itself), so
    /// extraction is purely local — no communication.
    fn extract_diag(&self, _ctx: &Ctx<'_, S>) -> DistVector<S> {
        let desc = *DistCsrMatrix::desc(self);
        let t = desc.tile;
        let mut diag = DistVector::zeros(desc, self.prow(), self.pcol());
        for l in 0..diag.local_blocks() {
            let ti = desc.global_ti(self.prow(), l);
            let blk = diag.block_mut(l);
            for k in 0..t {
                let gi = ti * t + k;
                if let Some(v) = self.local().get(l * t + k, gi) {
                    blk[k] = v;
                }
            }
        }
        diag
    }

    /// Row scales are local (owned rows pair with owned `d` blocks); column
    /// scales come from the same full-vector assembly `pspmv` uses.
    fn scale_sym(&mut self, ctx: &Ctx<'_, S>, d: &DistVector<S>) {
        let desc = *DistCsrMatrix::desc(self);
        assert_eq!(&desc, d.desc(), "scale_sym layout mismatch");
        let t = desc.tile;
        let dfull = super::pspmv::allgather_full(ctx, d, tags::SCALE + 1);
        let nnz = self.local().nnz();
        let nrows = self.local().nrows();
        for li in 0..nrows {
            let drow = d.block(li / t)[li % t];
            let (cols, vals) = self.local_mut().row_mut(li);
            for (v, &c) in vals.iter_mut().zip(cols) {
                *v *= drow * dfull[c];
            }
        }
        ctx.charge(ctx.engine.blas1_cost(2 * nnz));
    }
}

/// The halo-exchange routing of the sparse operator: the same row-block
/// layout and the same results (bit for bit — see
/// [`crate::sparse::HaloPlan`]'s renumbering contract), but matvecs run the
/// point-to-point ghost exchange ([`pspmv_halo`]/[`pspmv_t_halo`]) instead
/// of the O(n) allgather/allreduce.  Diagonal extraction and symmetric
/// scaling delegate to the wrapped operator (`scale_sym` edits values via
/// `local_mut`, which also invalidates the cached halo plan).
impl<S: Scalar> LinOp<S> for HaloCsr<S> {
    fn desc(&self) -> &Descriptor {
        DistCsrMatrix::desc(self.inner())
    }

    fn apply(&self, ctx: &Ctx<'_, S>, x: &DistVector<S>) -> DistVector<S> {
        pspmv_halo(ctx, self.inner(), x)
    }

    fn apply_t(&self, ctx: &Ctx<'_, S>, x: &DistVector<S>) -> DistVector<S> {
        pspmv_t_halo(ctx, self.inner(), x)
    }

    fn extract_diag(&self, ctx: &Ctx<'_, S>) -> DistVector<S> {
        self.inner().extract_diag(ctx)
    }

    fn scale_sym(&mut self, ctx: &Ctx<'_, S>, d: &DistVector<S>) {
        self.inner_mut().scale_sym(ctx, d);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::CpuEngine;
    use crate::comm::{NetworkModel, World};
    use crate::dist::gather_vector;
    use crate::mesh::{Mesh, MeshShape};
    use std::sync::Arc;

    fn dense_elem(i: usize, j: usize) -> f64 {
        if i == j {
            10.0 + i as f64
        } else {
            ((i * 3 + j * 5) % 7) as f64 * 0.1
        }
    }

    fn sparse_rows(n: usize) -> impl Fn(usize) -> Vec<(usize, f64)> + Clone + Send + Sync {
        move |i| {
            let mut r = vec![(i, 10.0 + i as f64)];
            if i + 1 < n {
                r.push((i + 1, -0.3));
            }
            if i >= 1 {
                r.push((i - 1, -0.2));
            }
            r
        }
    }

    /// `extract_diag` agrees between the dense broadcast path and the
    /// sparse local path, on a padded (non-divisible) size.
    #[test]
    fn diag_extraction_dense_vs_sparse() {
        let n = 11usize;
        for (pr, pc) in [(1usize, 1usize), (2, 2), (2, 3)] {
            let out = World::run::<f64, _, _>(pr * pc, NetworkModel::ideal(), move |comm| {
                let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
                let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(4)));
                let desc = Descriptor::new(n, n, 4, mesh.shape());
                let a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), dense_elem);
                let s = DistCsrMatrix::from_row_fn(desc, mesh.row(), mesh.col(), |i| {
                    vec![(i, dense_elem(i, i))]
                });
                let da = a.extract_diag(&ctx);
                let ds = s.extract_diag(&ctx);
                (gather_vector(&mesh, &da), gather_vector(&mesh, &ds))
            });
            let (da, ds) = out[0].clone();
            let (da, ds) = (da.unwrap(), ds.unwrap());
            for i in 0..n {
                assert_eq!(da[i], dense_elem(i, i), "{pr}x{pc} dense diag {i}");
                assert_eq!(ds[i], da[i], "{pr}x{pc} sparse diag {i}");
            }
        }
    }

    /// Symmetric scaling agrees with the serial formula on both formats,
    /// through the generic `apply`.
    #[test]
    fn scale_sym_matches_serial_on_both_formats() {
        let n = 10usize;
        let dval = |i: usize| 1.0 + 0.1 * i as f64;
        let xv = |i: usize| (i as f64 * 0.3).sin() + 0.2;
        let out = World::run::<f64, _, _>(4, NetworkModel::ideal(), move |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(2, 2));
            let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(4)));
            let desc = Descriptor::new(n, n, 4, mesh.shape());
            let mut a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), dense_elem);
            let mut s = DistCsrMatrix::from_row_fn(desc, mesh.row(), mesh.col(), sparse_rows(n));
            let d = DistVector::from_fn(desc, mesh.row(), mesh.col(), dval);
            a.scale_sym(&ctx, &d);
            s.scale_sym(&ctx, &d);
            let x = DistVector::from_fn(desc, mesh.row(), mesh.col(), xv);
            let ya = a.apply(&ctx, &x);
            let ys = s.apply(&ctx, &x);
            (gather_vector(&mesh, &ya), gather_vector(&mesh, &ys))
        });
        let (ya, ys) = out[0].clone();
        let (ya, ys) = (ya.unwrap(), ys.unwrap());
        let rows = sparse_rows(n);
        for i in 0..n {
            let want_dense: f64 =
                (0..n).map(|j| dval(i) * dense_elem(i, j) * dval(j) * xv(j)).sum();
            let want_sparse: f64 =
                rows(i).into_iter().map(|(j, v)| dval(i) * v * dval(j) * xv(j)).sum();
            assert!((ya[i] - want_dense).abs() < 1e-11, "dense row {i}");
            assert!((ys[i] - want_sparse).abs() < 1e-12, "sparse row {i}");
        }
    }
}
