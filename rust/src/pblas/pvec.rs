//! Distributed vector BLAS-1: dot, norm, axpy, scal, copy.
//!
//! Vectors are row-distributed / column-replicated ([`DistVector`]), so axpy,
//! scal and copy are purely local (each replica updates identically); dot
//! and norm need one allreduce over the *column* communicator (one member
//! per process row = the full distributed sum, computed redundantly in every
//! process column — no second collective needed).

use super::{tags, Ctx};
use crate::comm::ReduceOp;
use crate::dist::DistVector;
use crate::Scalar;

/// Distributed inner product `x . y` (result replicated on every rank).
pub fn pdot<S: Scalar>(ctx: &Ctx<'_, S>, x: &DistVector<S>, y: &DistVector<S>) -> S {
    let partial = pdot_partial(ctx, x, y);
    let col = ctx.mesh.col_comm();
    col.allreduce_scalar(tags::PDOT, partial, ReduceOp::Sum)
}

/// This rank's local contribution to `x . y` (engine-charged, no
/// communication).  The split-phase solvers fuse several partials into one
/// overlapped allreduce instead of paying one blocking reduction per dot —
/// the pipelined-CG pattern (`DESIGN.md` §11).
pub fn pdot_partial<S: Scalar>(ctx: &Ctx<'_, S>, x: &DistVector<S>, y: &DistVector<S>) -> S {
    assert_eq!(x.desc(), y.desc(), "pdot descriptor mismatch");
    let mut partial = S::zero();
    for l in 0..x.local_blocks() {
        let (d, cost) = ctx.engine.dot(x.block(l), y.block(l));
        partial += d;
        ctx.charge(cost);
    }
    partial
}

/// Distributed 2-norm.
pub fn pnorm2<S: Scalar>(ctx: &Ctx<'_, S>, x: &DistVector<S>) -> S {
    pdot(ctx, x, x).sqrt()
}

/// `y += alpha x` (local on every replica).
pub fn paxpy<S: Scalar>(ctx: &Ctx<'_, S>, alpha: S, x: &DistVector<S>, y: &mut DistVector<S>) {
    assert_eq!(x.desc(), y.desc(), "paxpy descriptor mismatch");
    for l in 0..x.local_blocks() {
        let cost = ctx.engine.axpy(alpha, x.block(l), y.block_mut(l));
        ctx.charge(cost);
    }
}

/// `x *= alpha` (local).
pub fn pscal<S: Scalar>(ctx: &Ctx<'_, S>, alpha: S, x: &mut DistVector<S>) {
    for l in 0..x.local_blocks() {
        let cost = ctx.engine.scal(alpha, x.block_mut(l));
        ctx.charge(cost);
    }
}

/// `y = x` (local; no cost model charge — a memcpy is free next to BLAS).
pub fn pcopy<S: Scalar>(_ctx: &Ctx<'_, S>, x: &DistVector<S>, y: &mut DistVector<S>) {
    y.copy_from(x);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::CpuEngine;
    use crate::comm::{NetworkModel, World};
    use crate::dist::Descriptor;
    use crate::mesh::{Mesh, MeshShape};
    use std::sync::Arc;

    fn with_ctx<R: Send>(
        pr: usize,
        pc: usize,
        tile: usize,
        f: impl Fn(&Ctx<'_, f64>) -> R + Send + Sync,
    ) -> Vec<R> {
        World::run::<f64, _, _>(pr * pc, NetworkModel::ideal(), move |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
            let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(tile)));
            f(&ctx)
        })
    }

    #[test]
    fn pdot_matches_serial_all_mesh_shapes() {
        let n = 23usize;
        for (pr, pc) in [(1, 1), (2, 1), (1, 3), (2, 2), (2, 3)] {
            let out = with_ctx(pr, pc, 4, move |ctx| {
                let desc = Descriptor::new(n, n, 4, ctx.mesh.shape());
                let x = DistVector::from_fn(desc, ctx.mesh.row(), ctx.mesh.col(), |i| {
                    (i as f64 + 1.0).sin()
                });
                let y = DistVector::from_fn(desc, ctx.mesh.row(), ctx.mesh.col(), |i| {
                    (i as f64).cos()
                });
                pdot(ctx, &x, &y)
            });
            let want: f64 = (0..n).map(|i| ((i as f64) + 1.0).sin() * (i as f64).cos()).sum();
            for v in out {
                assert!((v - want).abs() < 1e-12, "pr={pr} pc={pc}: {v} vs {want}");
            }
        }
    }

    #[test]
    fn pnorm_and_axpy() {
        let n = 10usize;
        let out = with_ctx(2, 2, 4, move |ctx| {
            let desc = Descriptor::new(n, n, 4, ctx.mesh.shape());
            let x = DistVector::from_fn(desc, ctx.mesh.row(), ctx.mesh.col(), |_| 2.0);
            let mut y = DistVector::from_fn(desc, ctx.mesh.row(), ctx.mesh.col(), |_| 1.0);
            paxpy(ctx, 3.0, &x, &mut y); // y = 7 everywhere
            pscal(ctx, 0.5, &mut y); // 3.5
            (pnorm2(ctx, &x), pdot(ctx, &y, &y))
        });
        for (nx, dy) in out {
            assert!((nx - (4.0 * n as f64).sqrt()).abs() < 1e-12);
            assert!((dy - 3.5 * 3.5 * n as f64).abs() < 1e-12);
        }
    }

    #[test]
    fn virtual_cost_charged() {
        let out = with_ctx(2, 1, 4, |ctx| {
            let desc = Descriptor::new(8, 8, 4, ctx.mesh.shape());
            let x = DistVector::from_fn(desc, ctx.mesh.row(), ctx.mesh.col(), |_| 1.0);
            let _ = pdot(ctx, &x, &x);
            ctx.mesh.comm().clock().now()
        });
        for t in out {
            assert!(t > 0.0, "pdot must advance the virtual clock");
        }
    }
}
