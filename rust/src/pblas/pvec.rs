//! Distributed vector BLAS-1: dot, norm, axpy, scal, copy — plus the
//! **fused** kernels the Krylov solvers iterate on.
//!
//! Vectors are row-distributed / column-replicated ([`DistVector`]), so axpy,
//! scal and copy are purely local (each replica updates identically); dot
//! and norm need one allreduce over the *column* communicator (one member
//! per process row = the full distributed sum, computed redundantly in every
//! process column — no second collective needed).
//!
//! The fused routines ([`pfused_axpy_norm2`], [`pxpay`],
//! [`pfused_norm2_dot_partial`], ...) collapse an unfused chain of
//! one-kernel-per-block BLAS-1 calls into **one launch and one memory pass
//! over the whole local replica** (Rupp et al.-style kernel fusion), charged
//! through [`crate::accel::Engine::blas1_fused_cost`]; the launches they
//! eliminate are counted in [`crate::comm::CommStats::launches_fused`].
//! Arithmetic is the unfused sequence's bit for bit (same per-block loops,
//! same partial-sum order, same reduction trees), so fusing never perturbs a
//! solver's iterates.

use super::{tags, Ctx};
use crate::comm::ReduceOp;
use crate::dist::{DistMultiVector, DistVector};
use crate::{linalg, Scalar};

/// Distributed inner product `x . y` (result replicated on every rank).
pub fn pdot<S: Scalar>(ctx: &Ctx<'_, S>, x: &DistVector<S>, y: &DistVector<S>) -> S {
    let partial = pdot_partial(ctx, x, y);
    let col = ctx.mesh.col_comm();
    col.allreduce_scalar(tags::PDOT, partial, ReduceOp::Sum)
}

/// This rank's local contribution to `x . y` (engine-charged, no
/// communication).  The split-phase solvers fuse several partials into one
/// overlapped allreduce instead of paying one blocking reduction per dot —
/// the pipelined-CG pattern (`DESIGN.md` §11).
pub fn pdot_partial<S: Scalar>(ctx: &Ctx<'_, S>, x: &DistVector<S>, y: &DistVector<S>) -> S {
    assert_eq!(x.desc(), y.desc(), "pdot descriptor mismatch");
    let mut partial = S::zero();
    for l in 0..x.local_blocks() {
        // Host-side op: observing a device-dirty block ends its dirty
        // period (the residency invalidation rules, DESIGN.md §12).
        ctx.host_read(x.block(l));
        ctx.host_read(y.block(l));
        let (d, cost) = ctx.engine.dot(x.block(l), y.block(l));
        partial += d;
        ctx.charge(cost);
    }
    partial
}

/// Distributed 2-norm.
pub fn pnorm2<S: Scalar>(ctx: &Ctx<'_, S>, x: &DistVector<S>) -> S {
    pdot(ctx, x, x).sqrt()
}

/// `y += alpha x` (local on every replica; host-side — mutating `y` on the
/// host invalidates any device copy of its blocks).
pub fn paxpy<S: Scalar>(ctx: &Ctx<'_, S>, alpha: S, x: &DistVector<S>, y: &mut DistVector<S>) {
    assert_eq!(x.desc(), y.desc(), "paxpy descriptor mismatch");
    for l in 0..x.local_blocks() {
        ctx.host_read(x.block(l));
        ctx.host_mut(y.block(l));
        let cost = ctx.engine.axpy(alpha, x.block(l), y.block_mut(l));
        ctx.charge(cost);
    }
}

/// `x *= alpha` (local, host-side).
pub fn pscal<S: Scalar>(ctx: &Ctx<'_, S>, alpha: S, x: &mut DistVector<S>) {
    for l in 0..x.local_blocks() {
        ctx.host_mut(x.block(l));
        let cost = ctx.engine.scal(alpha, x.block_mut(l));
        ctx.charge(cost);
    }
}

/// `y = x` (local; no cost model charge — a memcpy is free next to BLAS).
pub fn pcopy<S: Scalar>(ctx: &Ctx<'_, S>, x: &DistVector<S>, y: &mut DistVector<S>) {
    for l in 0..x.local_blocks() {
        ctx.host_read(x.block(l));
        ctx.host_mut(y.block(l));
    }
    y.copy_from(x);
}

/// Total local elements of a replica (for the fused-kernel cost).
fn local_len<S: Scalar>(x: &DistVector<S>) -> usize {
    x.local_blocks() * x.desc().tile
}

/// Charge one fused kernel spanning every block of the listed vectors:
/// `reads`/`writes` count vector-length operand streams, `flops_per_elem`
/// the fused arithmetic, `replaced` the launches the unfused sequence
/// would have made.
fn charge_fused_vec<S: Scalar>(
    ctx: &Ctx<'_, S>,
    reads: &[&DistVector<S>],
    writes: &[&DistVector<S>],
    flops_per_elem: u64,
    replaced: u64,
) {
    let len = local_len(*reads.first().or(writes.first()).expect("an operand"));
    let streams = reads.len() + writes.len();
    let cost = ctx.engine.blas1_fused_cost(len, streams, flops_per_elem * len as u64);
    let in_blocks: Vec<&[S]> =
        reads.iter().flat_map(|v| (0..v.local_blocks()).map(|l| v.block(l))).collect();
    let out_blocks: Vec<&[S]> =
        writes.iter().flat_map(|v| (0..v.local_blocks()).map(|l| v.block(l))).collect();
    ctx.charge_fused(cost, &in_blocks, &out_blocks, replaced);
}

/// Fused `y += alpha x; return ⟨y,y⟩` — one kernel + the usual column-comm
/// allreduce, replacing an axpy launch and a dot launch per block.  Same
/// arithmetic and same reduction as `paxpy` + `pdot(y, y)`.
pub fn pfused_axpy_norm2<S: Scalar>(
    ctx: &Ctx<'_, S>,
    alpha: S,
    x: &DistVector<S>,
    y: &mut DistVector<S>,
) -> S {
    assert_eq!(x.desc(), y.desc(), "pfused_axpy_norm2 descriptor mismatch");
    let mut partial = S::zero();
    for l in 0..x.local_blocks() {
        partial += linalg::axpy_norm2(alpha, x.block(l), y.block_mut(l));
    }
    charge_fused_vec(ctx, &[x, &*y], &[&*y], 4, 2 * x.local_blocks() as u64);
    let col = ctx.mesh.col_comm();
    col.allreduce_scalar(tags::PDOT, partial, ReduceOp::Sum)
}

/// Fused `y += alpha x; return (⟨y,y⟩, ⟨w,y⟩)` with **one** two-lane
/// allreduce — BiCGSTAB's residual update, norm check and `rho` recurrence
/// in a single kernel + a single reduction (the unfused chain pays two
/// reduction latencies).  Lane values are bit-identical to the separate
/// dots; the two-lane tree combines each lane exactly like the scalar one.
pub fn pfused_axpy_norm2_dot<S: Scalar>(
    ctx: &Ctx<'_, S>,
    alpha: S,
    x: &DistVector<S>,
    y: &mut DistVector<S>,
    w: &DistVector<S>,
) -> (S, S) {
    assert_eq!(x.desc(), y.desc(), "pfused_axpy_norm2_dot descriptor mismatch");
    assert_eq!(w.desc(), y.desc(), "pfused_axpy_norm2_dot descriptor mismatch");
    let (mut n2, mut d) = (S::zero(), S::zero());
    for l in 0..x.local_blocks() {
        linalg::axpy(alpha, x.block(l), y.block_mut(l));
        n2 += linalg::dot(y.block(l), y.block(l));
        d += linalg::dot(w.block(l), y.block(l));
    }
    charge_fused_vec(ctx, &[x, w, &*y], &[&*y], 6, 3 * x.local_blocks() as u64);
    let col = ctx.mesh.col_comm();
    let reduced = col.allreduce_vec(tags::FUSED, vec![n2, d], ReduceOp::Sum);
    (reduced[0], reduced[1])
}

/// Fused `(⟨x,x⟩, ⟨x,y⟩)` with one two-lane allreduce (BiCGSTAB's
/// `(⟨t,t⟩, ⟨t,s⟩)` pair).
pub fn pfused_norm2_dot<S: Scalar>(
    ctx: &Ctx<'_, S>,
    x: &DistVector<S>,
    y: &DistVector<S>,
) -> (S, S) {
    let (n2, d) = pfused_norm2_dot_partial(ctx, x, y);
    let col = ctx.mesh.col_comm();
    let reduced = col.allreduce_vec(tags::FUSED, vec![n2, d], ReduceOp::Sum);
    (reduced[0], reduced[1])
}

/// Local partials of `(⟨x,x⟩, ⟨x,y⟩)` in one fused pass — pipelined CG's
/// `(γ, δ)` pair, whose reduction the caller overlaps with the matvec.
pub fn pfused_norm2_dot_partial<S: Scalar>(
    ctx: &Ctx<'_, S>,
    x: &DistVector<S>,
    y: &DistVector<S>,
) -> (S, S) {
    assert_eq!(x.desc(), y.desc(), "pfused_norm2_dot descriptor mismatch");
    let (mut n2, mut d) = (S::zero(), S::zero());
    for l in 0..x.local_blocks() {
        let (bn2, bd) = linalg::norm2_dot(x.block(l), y.block(l));
        n2 += bn2;
        d += bd;
    }
    charge_fused_vec(ctx, &[x, y], &[], 4, 2 * x.local_blocks() as u64);
    (n2, d)
}

// ---------------------------------------------------------------------------
// Wide-accumulate (mixed-precision) variants: the same storage dtype, the
// same fused launches and the same S-width reduction payloads as the kernels
// above — only the *local accumulation* runs in `S::Hi` (f64), and the
// caller's recurrence scalars stay wide.  In an f32 world this is exactly
// the "f32 storage / f32 wire / f64 accumulate" Krylov contract; for
// `S = f64` (`Hi = Self`, `from_hi` the identity) each variant reproduces
// its plain twin bit for bit, which is what pins `--no-mixed` honesty.
// The engine charge is the plain kernel's: wide accumulators live in
// registers, touching no extra memory streams.
// ---------------------------------------------------------------------------

/// This rank's local contribution to `x . y`, accumulated in `S::Hi`.
pub fn pdot_partial_hi<S: Scalar>(
    ctx: &Ctx<'_, S>,
    x: &DistVector<S>,
    y: &DistVector<S>,
) -> S::Hi {
    assert_eq!(x.desc(), y.desc(), "pdot_partial_hi descriptor mismatch");
    let mut partial = <S::Hi as num_traits::Zero>::zero();
    for l in 0..x.local_blocks() {
        ctx.host_read(x.block(l));
        ctx.host_read(y.block(l));
        // Same op, same charge as the plain kernel; the value lane rides
        // the wide accumulator.
        let (_, cost) = ctx.engine.dot(x.block(l), y.block(l));
        partial += linalg::dot_hi(x.block(l), y.block(l));
        ctx.charge(cost);
    }
    partial
}

/// Distributed inner product with `S::Hi` local accumulation and an
/// `S`-width reduction payload (the wire ships the storage dtype).
pub fn pdot_hi<S: Scalar>(ctx: &Ctx<'_, S>, x: &DistVector<S>, y: &DistVector<S>) -> S::Hi {
    let partial = pdot_partial_hi(ctx, x, y);
    let col = ctx.mesh.col_comm();
    col.allreduce_scalar(tags::PDOT, S::from_hi(partial), ReduceOp::Sum).to_hi()
}

/// Distributed 2-norm with wide accumulation.
pub fn pnorm2_hi<S: Scalar>(ctx: &Ctx<'_, S>, x: &DistVector<S>) -> S::Hi {
    pdot_hi(ctx, x, x).sqrt()
}

/// Wide-accumulate twin of [`pfused_axpy_norm2`]: the update stays in `S`,
/// the norm accumulates in `S::Hi`, the reduction payload is one `S`.
pub fn pfused_axpy_norm2_hi<S: Scalar>(
    ctx: &Ctx<'_, S>,
    alpha: S,
    x: &DistVector<S>,
    y: &mut DistVector<S>,
) -> S::Hi {
    assert_eq!(x.desc(), y.desc(), "pfused_axpy_norm2_hi descriptor mismatch");
    let mut partial = <S::Hi as num_traits::Zero>::zero();
    for l in 0..x.local_blocks() {
        partial += linalg::axpy_norm2_hi(alpha, x.block(l), y.block_mut(l));
    }
    charge_fused_vec(ctx, &[x, &*y], &[&*y], 4, 2 * x.local_blocks() as u64);
    let col = ctx.mesh.col_comm();
    col.allreduce_scalar(tags::PDOT, S::from_hi(partial), ReduceOp::Sum).to_hi()
}

/// Wide-accumulate twin of [`pfused_axpy_norm2_dot`]: one two-lane
/// `S`-width allreduce, both lanes accumulated locally in `S::Hi`.
pub fn pfused_axpy_norm2_dot_hi<S: Scalar>(
    ctx: &Ctx<'_, S>,
    alpha: S,
    x: &DistVector<S>,
    y: &mut DistVector<S>,
    w: &DistVector<S>,
) -> (S::Hi, S::Hi) {
    assert_eq!(x.desc(), y.desc(), "pfused_axpy_norm2_dot_hi descriptor mismatch");
    assert_eq!(w.desc(), y.desc(), "pfused_axpy_norm2_dot_hi descriptor mismatch");
    let zero = <S::Hi as num_traits::Zero>::zero();
    let (mut n2, mut d) = (zero, zero);
    for l in 0..x.local_blocks() {
        linalg::axpy(alpha, x.block(l), y.block_mut(l));
        n2 += linalg::dot_hi(y.block(l), y.block(l));
        d += linalg::dot_hi(w.block(l), y.block(l));
    }
    charge_fused_vec(ctx, &[x, w, &*y], &[&*y], 6, 3 * x.local_blocks() as u64);
    let col = ctx.mesh.col_comm();
    let reduced =
        col.allreduce_vec(tags::FUSED, vec![S::from_hi(n2), S::from_hi(d)], ReduceOp::Sum);
    (reduced[0].to_hi(), reduced[1].to_hi())
}

/// Wide-accumulate twin of [`pfused_norm2_dot`].
pub fn pfused_norm2_dot_hi<S: Scalar>(
    ctx: &Ctx<'_, S>,
    x: &DistVector<S>,
    y: &DistVector<S>,
) -> (S::Hi, S::Hi) {
    assert_eq!(x.desc(), y.desc(), "pfused_norm2_dot_hi descriptor mismatch");
    let zero = <S::Hi as num_traits::Zero>::zero();
    let (mut n2, mut d) = (zero, zero);
    for l in 0..x.local_blocks() {
        let (bn2, bd) = linalg::norm2_dot_hi(x.block(l), y.block(l));
        n2 += bn2;
        d += bd;
    }
    charge_fused_vec(ctx, &[x, y], &[], 4, 2 * x.local_blocks() as u64);
    let col = ctx.mesh.col_comm();
    let reduced =
        col.allreduce_vec(tags::FUSED, vec![S::from_hi(n2), S::from_hi(d)], ReduceOp::Sum);
    (reduced[0].to_hi(), reduced[1].to_hi())
}

/// Fused `y = x + beta y` — one pass instead of a scal launch plus an axpy
/// launch per block (the `p = r + beta p` recurrence of CG and friends).
pub fn pxpay<S: Scalar>(ctx: &Ctx<'_, S>, beta: S, x: &DistVector<S>, y: &mut DistVector<S>) {
    assert_eq!(x.desc(), y.desc(), "pxpay descriptor mismatch");
    for l in 0..x.local_blocks() {
        linalg::xpay(beta, x.block(l), y.block_mut(l));
    }
    charge_fused_vec(ctx, &[x, &*y], &[&*y], 2, 2 * x.local_blocks() as u64);
}

// ---------------------------------------------------------------------------
// Column-batched (multi-RHS) variants: the same per-column arithmetic as the
// single-vector kernels above — bit for bit, same block loops, same partial
// order — but the launches batch into **one** fused kernel over the active
// panel and the reductions share **one** k-lane allreduce (one tree latency
// for the whole batch instead of one per column; the lane-wise combine is
// the scalar tree's, so lane values match the looped solvers' exactly).
// Inactive columns (converged / masked) are skipped entirely: their lanes
// reduce as zero and their blocks are neither read nor written.
// ---------------------------------------------------------------------------

/// Charge one fused kernel spanning the listed panel columns' blocks:
/// `streams` operand streams *per column element*, `ncols` active columns.
#[allow(clippy::too_many_arguments)]
fn charge_fused_panel<S: Scalar>(
    ctx: &Ctx<'_, S>,
    reads: &[&DistVector<S>],
    writes: &[&DistVector<S>],
    streams: usize,
    ncols: usize,
    flops_per_elem: u64,
    replaced: u64,
) {
    if ncols == 0 {
        return;
    }
    let len = local_len(*reads.first().or(writes.first()).expect("an operand")) * ncols;
    let cost = ctx.engine.blas1_fused_cost(len, streams, flops_per_elem * len as u64);
    let in_blocks: Vec<&[S]> =
        reads.iter().flat_map(|v| (0..v.local_blocks()).map(|l| v.block(l))).collect();
    let out_blocks: Vec<&[S]> =
        writes.iter().flat_map(|v| (0..v.local_blocks()).map(|l| v.block(l))).collect();
    ctx.charge_fused(cost, &in_blocks, &out_blocks, replaced);
}

/// Per-column inner products `x_j . y_j` over an RHS panel, reduced in
/// **one** k-lane allreduce.  Masked columns return zero.  The per-column
/// compute is charged to that column's attribution tenant.
pub fn pdot_cols<S: Scalar>(
    ctx: &Ctx<'_, S>,
    x: &DistMultiVector<S>,
    y: &DistMultiVector<S>,
    active: &[bool],
) -> Vec<S> {
    assert_eq!(x.ncols(), y.ncols(), "pdot_cols panel width mismatch");
    assert_eq!(x.ncols(), active.len(), "pdot_cols mask width mismatch");
    let mut partials = vec![S::zero(); x.ncols()];
    for j in 0..x.ncols() {
        if !active[j] {
            continue;
        }
        ctx.set_tenant(Some(j));
        partials[j] = pdot_partial(ctx, x.col(j), y.col(j));
        ctx.set_tenant(None);
    }
    let col = ctx.mesh.col_comm();
    col.allreduce_vec(tags::PBLOCK, partials, ReduceOp::Sum)
}

/// Per-column 2-norms of an RHS panel (all columns), one k-lane allreduce.
pub fn pnorm2_cols<S: Scalar>(ctx: &Ctx<'_, S>, x: &DistMultiVector<S>) -> Vec<S> {
    let all = vec![true; x.ncols()];
    pdot_cols(ctx, x, x, &all).into_iter().map(|v| v.sqrt()).collect()
}

/// `y_j += alpha_j x_j` per active column (the per-column axpy of the
/// looped solver, charged to that column's tenant).
pub fn paxpy_cols<S: Scalar>(
    ctx: &Ctx<'_, S>,
    alpha: &[S],
    x: &DistMultiVector<S>,
    y: &mut DistMultiVector<S>,
    active: &[bool],
) {
    assert_eq!(x.ncols(), y.ncols(), "paxpy_cols panel width mismatch");
    assert_eq!(x.ncols(), alpha.len(), "paxpy_cols coefficient width mismatch");
    for j in 0..x.ncols() {
        if !active[j] {
            continue;
        }
        ctx.set_tenant(Some(j));
        paxpy(ctx, alpha[j], x.col(j), y.col_mut(j));
        ctx.set_tenant(None);
    }
}

/// Fused `y_j += alpha_j x_j; return ⟨y_j,y_j⟩` over an RHS panel: **one**
/// launch for every active column and **one** k-lane allreduce — the
/// batched twin of [`pfused_axpy_norm2`], lane values bit-identical to the
/// looped single-column calls'.
pub fn pfused_axpy_norm2_cols<S: Scalar>(
    ctx: &Ctx<'_, S>,
    alpha: &[S],
    x: &DistMultiVector<S>,
    y: &mut DistMultiVector<S>,
    active: &[bool],
) -> Vec<S> {
    let k = x.ncols();
    assert_eq!(k, y.ncols(), "pfused_axpy_norm2_cols panel width mismatch");
    assert_eq!(k, alpha.len(), "pfused_axpy_norm2_cols coefficient width mismatch");
    assert_eq!(k, active.len(), "pfused_axpy_norm2_cols mask width mismatch");
    let mut partials = vec![S::zero(); k];
    for j in 0..k {
        if !active[j] {
            continue;
        }
        let xj = x.col(j);
        let yj = y.col_mut(j);
        let mut p = S::zero();
        for l in 0..xj.local_blocks() {
            p += linalg::axpy_norm2(alpha[j], xj.block(l), yj.block_mut(l));
        }
        partials[j] = p;
    }
    let actives: Vec<usize> = (0..k).filter(|&j| active[j]).collect();
    let blocks = x.col(0).local_blocks() as u64;
    let mut reads: Vec<&DistVector<S>> = Vec::new();
    let mut writes: Vec<&DistVector<S>> = Vec::new();
    for &j in &actives {
        reads.push(x.col(j));
        reads.push(y.col(j));
        writes.push(y.col(j));
    }
    charge_fused_panel(ctx, &reads, &writes, 3, actives.len(), 4, 2 * blocks * actives.len() as u64);
    let col = ctx.mesh.col_comm();
    col.allreduce_vec(tags::PBLOCK + 1, partials, ReduceOp::Sum)
}

/// Fused `y_j += alpha_j x_j; return (⟨y_j,y_j⟩, ⟨w_j,y_j⟩)` over an RHS
/// panel with **one** 2k-lane allreduce — the batched twin of
/// [`pfused_axpy_norm2_dot`] (block-BiCGSTAB's residual update, norm check
/// and `rho` recurrence for the whole batch in one reduction).
pub fn pfused_axpy_norm2_dot_cols<S: Scalar>(
    ctx: &Ctx<'_, S>,
    alpha: &[S],
    x: &DistMultiVector<S>,
    y: &mut DistMultiVector<S>,
    w: &DistMultiVector<S>,
    active: &[bool],
) -> (Vec<S>, Vec<S>) {
    let k = x.ncols();
    assert_eq!(k, y.ncols(), "pfused_axpy_norm2_dot_cols panel width mismatch");
    assert_eq!(k, w.ncols(), "pfused_axpy_norm2_dot_cols panel width mismatch");
    let (mut n2, mut d) = (vec![S::zero(); k], vec![S::zero(); k]);
    for j in 0..k {
        if !active[j] {
            continue;
        }
        let (xj, wj) = (x.col(j), w.col(j));
        let yj = y.col_mut(j);
        for l in 0..xj.local_blocks() {
            linalg::axpy(alpha[j], xj.block(l), yj.block_mut(l));
            n2[j] += linalg::dot(yj.block(l), yj.block(l));
            d[j] += linalg::dot(wj.block(l), yj.block(l));
        }
    }
    let actives: Vec<usize> = (0..k).filter(|&j| active[j]).collect();
    let blocks = x.col(0).local_blocks() as u64;
    let mut reads: Vec<&DistVector<S>> = Vec::new();
    let mut writes: Vec<&DistVector<S>> = Vec::new();
    for &j in &actives {
        reads.push(x.col(j));
        reads.push(w.col(j));
        reads.push(y.col(j));
        writes.push(y.col(j));
    }
    charge_fused_panel(ctx, &reads, &writes, 4, actives.len(), 6, 3 * blocks * actives.len() as u64);
    let mut lanes = n2;
    lanes.extend(d);
    let col = ctx.mesh.col_comm();
    let reduced = col.allreduce_vec(tags::PBLOCK + 2, lanes, ReduceOp::Sum);
    (reduced[..k].to_vec(), reduced[k..].to_vec())
}

/// Fused `(⟨x_j,x_j⟩, ⟨x_j,y_j⟩)` per active column with one 2k-lane
/// allreduce — the batched twin of [`pfused_norm2_dot`].
pub fn pfused_norm2_dot_cols<S: Scalar>(
    ctx: &Ctx<'_, S>,
    x: &DistMultiVector<S>,
    y: &DistMultiVector<S>,
    active: &[bool],
) -> (Vec<S>, Vec<S>) {
    let k = x.ncols();
    assert_eq!(k, y.ncols(), "pfused_norm2_dot_cols panel width mismatch");
    let (mut n2, mut d) = (vec![S::zero(); k], vec![S::zero(); k]);
    for j in 0..k {
        if !active[j] {
            continue;
        }
        for l in 0..x.col(j).local_blocks() {
            let (bn2, bd) = linalg::norm2_dot(x.col(j).block(l), y.col(j).block(l));
            n2[j] += bn2;
            d[j] += bd;
        }
    }
    let actives: Vec<usize> = (0..k).filter(|&j| active[j]).collect();
    let blocks = x.col(0).local_blocks() as u64;
    let mut reads: Vec<&DistVector<S>> = Vec::new();
    for &j in &actives {
        reads.push(x.col(j));
        reads.push(y.col(j));
    }
    charge_fused_panel(ctx, &reads, &[], 2, actives.len(), 4, 2 * blocks * actives.len() as u64);
    let mut lanes = n2;
    lanes.extend(d);
    let col = ctx.mesh.col_comm();
    let reduced = col.allreduce_vec(tags::PBLOCK + 3, lanes, ReduceOp::Sum);
    (reduced[..k].to_vec(), reduced[k..].to_vec())
}

/// Fused `y_j = x_j + beta_j y_j` over an RHS panel — one launch for every
/// active column (the batched `p = r + beta p` recurrence).
pub fn pxpay_cols<S: Scalar>(
    ctx: &Ctx<'_, S>,
    beta: &[S],
    x: &DistMultiVector<S>,
    y: &mut DistMultiVector<S>,
    active: &[bool],
) {
    let k = x.ncols();
    assert_eq!(k, y.ncols(), "pxpay_cols panel width mismatch");
    assert_eq!(k, beta.len(), "pxpay_cols coefficient width mismatch");
    for j in 0..k {
        if !active[j] {
            continue;
        }
        let xj = x.col(j);
        let yj = y.col_mut(j);
        for l in 0..xj.local_blocks() {
            linalg::xpay(beta[j], xj.block(l), yj.block_mut(l));
        }
    }
    let actives: Vec<usize> = (0..k).filter(|&j| active[j]).collect();
    let blocks = x.col(0).local_blocks() as u64;
    let mut reads: Vec<&DistVector<S>> = Vec::new();
    let mut writes: Vec<&DistVector<S>> = Vec::new();
    for &j in &actives {
        reads.push(x.col(j));
        reads.push(y.col(j));
        writes.push(y.col(j));
    }
    charge_fused_panel(ctx, &reads, &writes, 3, actives.len(), 2, 2 * blocks * actives.len() as u64);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::CpuEngine;
    use crate::comm::{NetworkModel, World};
    use crate::dist::Descriptor;
    use crate::mesh::{Mesh, MeshShape};
    use std::sync::Arc;

    fn with_ctx<R: Send>(
        pr: usize,
        pc: usize,
        tile: usize,
        f: impl Fn(&Ctx<'_, f64>) -> R + Send + Sync,
    ) -> Vec<R> {
        World::run::<f64, _, _>(pr * pc, NetworkModel::ideal(), move |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
            let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(tile)));
            f(&ctx)
        })
    }

    #[test]
    fn pdot_matches_serial_all_mesh_shapes() {
        let n = 23usize;
        for (pr, pc) in [(1, 1), (2, 1), (1, 3), (2, 2), (2, 3)] {
            let out = with_ctx(pr, pc, 4, move |ctx| {
                let desc = Descriptor::new(n, n, 4, ctx.mesh.shape());
                let x = DistVector::from_fn(desc, ctx.mesh.row(), ctx.mesh.col(), |i| {
                    (i as f64 + 1.0).sin()
                });
                let y = DistVector::from_fn(desc, ctx.mesh.row(), ctx.mesh.col(), |i| {
                    (i as f64).cos()
                });
                pdot(ctx, &x, &y)
            });
            let want: f64 = (0..n).map(|i| ((i as f64) + 1.0).sin() * (i as f64).cos()).sum();
            for v in out {
                assert!((v - want).abs() < 1e-12, "pr={pr} pc={pc}: {v} vs {want}");
            }
        }
    }

    #[test]
    fn pnorm_and_axpy() {
        let n = 10usize;
        let out = with_ctx(2, 2, 4, move |ctx| {
            let desc = Descriptor::new(n, n, 4, ctx.mesh.shape());
            let x = DistVector::from_fn(desc, ctx.mesh.row(), ctx.mesh.col(), |_| 2.0);
            let mut y = DistVector::from_fn(desc, ctx.mesh.row(), ctx.mesh.col(), |_| 1.0);
            paxpy(ctx, 3.0, &x, &mut y); // y = 7 everywhere
            pscal(ctx, 0.5, &mut y); // 3.5
            (pnorm2(ctx, &x), pdot(ctx, &y, &y))
        });
        for (nx, dy) in out {
            assert!((nx - (4.0 * n as f64).sqrt()).abs() < 1e-12);
            assert!((dy - 3.5 * 3.5 * n as f64).abs() < 1e-12);
        }
    }

    #[test]
    fn fused_ops_match_unfused_bitwise_and_count_launches() {
        let n = 23usize;
        for (pr, pc) in [(1, 1), (2, 2), (2, 3)] {
            let out = with_ctx(pr, pc, 4, move |ctx| {
                let desc = Descriptor::new(n, n, 4, ctx.mesh.shape());
                let mk = |f: fn(usize) -> f64| {
                    DistVector::from_fn(desc, ctx.mesh.row(), ctx.mesh.col(), f)
                };
                let x = mk(|i| ((i + 1) as f64).sin());
                let w = mk(|i| (i as f64 * 0.9).cos());
                // Unfused reference sequence.
                let mut yu = mk(|i| (i as f64).cos());
                paxpy(ctx, -0.375, &x, &mut yu);
                let rru = pdot(ctx, &yu, &yu);
                pscal(ctx, 1.25, &mut yu);
                paxpy(ctx, 1.0, &x, &mut yu);
                let ddu = (pdot(ctx, &yu, &yu), pdot(ctx, &yu, &w));
                // Fused sequence.
                let mut yf = mk(|i| (i as f64).cos());
                let rrf = pfused_axpy_norm2(ctx, -0.375, &x, &mut yf);
                pxpay(ctx, 1.25, &x, &mut yf);
                let ddf = pfused_norm2_dot(ctx, &yf, &w);
                let bits_eq = (0..yu.local_blocks()).all(|l| {
                    yu.block(l)
                        .iter()
                        .zip(yf.block(l))
                        .all(|(a, b)| a.to_bits() == b.to_bits())
                });
                (
                    bits_eq,
                    rru.to_bits() == rrf.to_bits(),
                    ddu.0.to_bits() == ddf.0.to_bits() && ddu.1.to_bits() == ddf.1.to_bits(),
                    ctx.mesh.comm().stats().launches_fused(),
                )
            });
            for (bits_eq, rr_eq, dd_eq, fused) in out {
                assert!(bits_eq, "{pr}x{pc}: fused vector bits differ");
                assert!(rr_eq && dd_eq, "{pr}x{pc}: fused reductions differ");
                assert!(fused > 0, "{pr}x{pc}: fused launches must be counted");
            }
        }
    }

    #[test]
    fn cols_variants_match_looped_singles_bitwise() {
        let n = 23usize;
        let k = 3usize;
        for (pr, pc) in [(1, 1), (2, 2), (2, 3)] {
            let out = with_ctx(pr, pc, 4, move |ctx| {
                let desc = Descriptor::new(n, n, 4, ctx.mesh.shape());
                let (prow, pcol) = (ctx.mesh.row(), ctx.mesh.col());
                let x = DistMultiVector::from_fn(desc, prow, pcol, k, |i, j| {
                    ((i + 7 * j + 1) as f64).sin()
                });
                let y0 = DistMultiVector::from_fn(desc, prow, pcol, k, |i, j| {
                    (i as f64 * 0.3 + j as f64).cos()
                });
                let alpha = [-0.375, 0.5, 0.25];
                let active = [true, false, true];
                // Batched panel sequence (column 1 masked throughout).
                let mut yb = y0.clone_panel();
                let rrb = pfused_axpy_norm2_cols(ctx, &alpha, &x, &mut yb, &active);
                let ddb = pdot_cols(ctx, &x, &yb, &active);
                pxpay_cols(ctx, &alpha, &x, &mut yb, &active);
                let ndb = pfused_norm2_dot_cols(ctx, &yb, &x, &active);
                // Looped single-column reference.
                let mut eq = true;
                for j in 0..k {
                    if !active[j] {
                        // Masked column: untouched, bit for bit.
                        for l in 0..yb.col(j).local_blocks() {
                            eq &= yb.col(j).block(l) == y0.col(j).block(l);
                        }
                        eq &= rrb[j] == 0.0 && ddb[j] == 0.0;
                        continue;
                    }
                    let mut ys = y0.col(j).clone_vec();
                    let rrs = pfused_axpy_norm2(ctx, alpha[j], x.col(j), &mut ys);
                    let dds = pdot(ctx, x.col(j), &ys);
                    pxpay(ctx, alpha[j], x.col(j), &mut ys);
                    let nds = pfused_norm2_dot(ctx, &ys, x.col(j));
                    eq &= rrb[j].to_bits() == rrs.to_bits();
                    eq &= ddb[j].to_bits() == dds.to_bits();
                    eq &= ndb.0[j].to_bits() == nds.0.to_bits();
                    eq &= ndb.1[j].to_bits() == nds.1.to_bits();
                    for l in 0..ys.local_blocks() {
                        eq &= yb
                            .col(j)
                            .block(l)
                            .iter()
                            .zip(ys.block(l))
                            .all(|(a, b)| a.to_bits() == b.to_bits());
                    }
                }
                (eq, ctx.mesh.comm().stats().launches_fused())
            });
            for (eq, fused) in out {
                assert!(eq, "{pr}x{pc}: batched cols differ from looped singles");
                assert!(fused > 0, "{pr}x{pc}: batched launches must be fused-counted");
            }
        }
    }

    #[test]
    fn hi_kernels_reproduce_plain_kernels_bitwise_in_an_f64_world() {
        // For S = f64, Hi = Self and from_hi is the identity: every wide
        // kernel must BE its plain twin — same values, same wire, same
        // clock.  This is the `--no-mixed` honesty contract at the kernel
        // level.
        let n = 23usize;
        for (pr, pc) in [(1, 1), (2, 2), (2, 3)] {
            let out = with_ctx(pr, pc, 4, move |ctx| {
                let desc = Descriptor::new(n, n, 4, ctx.mesh.shape());
                let mk = |f: fn(usize) -> f64| {
                    DistVector::from_fn(desc, ctx.mesh.row(), ctx.mesh.col(), f)
                };
                let x = mk(|i| ((i + 1) as f64).sin());
                let w = mk(|i| (i as f64 * 0.9).cos());
                let mut ya = mk(|i| (i as f64).cos());
                let mut yb = mk(|i| (i as f64).cos());
                let d_eq = pdot_hi(ctx, &x, &w).to_bits() == pdot(ctx, &x, &w).to_bits();
                let ra = pfused_axpy_norm2_hi(ctx, -0.375, &x, &mut ya);
                let rb = pfused_axpy_norm2(ctx, -0.375, &x, &mut yb);
                let (na, da) = pfused_axpy_norm2_dot_hi(ctx, 0.25, &x, &mut ya, &w);
                let (nb, db) = pfused_axpy_norm2_dot(ctx, 0.25, &x, &mut yb, &w);
                let (pa, qa) = pfused_norm2_dot_hi(ctx, &ya, &w);
                let (pb, qb) = pfused_norm2_dot(ctx, &yb, &w);
                let vec_eq = (0..ya.local_blocks()).all(|l| {
                    ya.block(l)
                        .iter()
                        .zip(yb.block(l))
                        .all(|(a, b)| a.to_bits() == b.to_bits())
                });
                (
                    d_eq && vec_eq,
                    ra.to_bits() == rb.to_bits()
                        && na.to_bits() == nb.to_bits()
                        && da.to_bits() == db.to_bits()
                        && pa.to_bits() == pb.to_bits()
                        && qa.to_bits() == qb.to_bits(),
                )
            });
            for (data_eq, scalars_eq) in out {
                assert!(data_eq, "{pr}x{pc}: hi kernel data differs from plain");
                assert!(scalars_eq, "{pr}x{pc}: hi kernel scalars differ from plain");
            }
        }
    }

    #[test]
    fn hi_kernels_accumulate_wide_in_an_f32_world() {
        // f32 storage, f64 accumulation: the wide dot must land closer to
        // the exact sum than a pure-f32 chain on a cancellation-heavy
        // replica, while the reduction payload stays 4 bytes.
        let n = 4096usize;
        let out: Vec<(f64, f64)> =
            World::run::<f32, _, _>(2, NetworkModel::ideal(), move |comm| {
                let mesh = Mesh::new(&comm, MeshShape::new(2, 1));
                let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(4)));
                let desc = Descriptor::new(n, n, 4, mesh.shape());
                let x = DistVector::from_fn(desc, mesh.row(), mesh.col(), |i| {
                    if i % 2 == 0 { 1.0e3 } else { -1.0e3 }
                });
                let y = DistVector::from_fn(desc, mesh.row(), mesh.col(), |i| {
                    1.0 + (i as f32) * 1.0e-4
                });
                let wide = pdot_hi(&ctx, &x, &y);
                let narrow = pdot(&ctx, &x, &y) as f64;
                (wide, narrow)
            });
        let exact: f64 = (0..n)
            .map(|i| {
                let xi = if i % 2 == 0 { 1.0e3f32 } else { -1.0e3f32 };
                let yi = 1.0f32 + (i as f32) * 1.0e-4;
                xi as f64 * yi as f64
            })
            .sum();
        for (wide, narrow) in out {
            assert!((wide - exact).abs() <= (narrow - exact).abs());
            assert!((wide - exact).abs() < 1e-5 * exact.abs().max(1.0));
        }
    }

    #[test]
    fn virtual_cost_charged() {
        let out = with_ctx(2, 1, 4, |ctx| {
            let desc = Descriptor::new(8, 8, 4, ctx.mesh.shape());
            let x = DistVector::from_fn(desc, ctx.mesh.row(), ctx.mesh.col(), |_| 1.0);
            let _ = pdot(ctx, &x, &x);
            ctx.mesh.comm().clock().now()
        });
        for t in out {
            assert!(t > 0.0, "pdot must advance the virtual clock");
        }
    }
}
