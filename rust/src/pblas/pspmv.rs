//! Distributed sparse matrix-vector products over row-block CSR operands —
//! the kernel that puts the Krylov solvers in their natural (sparse) regime.
//!
//! Layouts: `A` is a [`DistCsrMatrix`] (rows in the vector layout's tile
//! blocks, replicated across process columns); `x`, `y` are row-distributed
//! / column-replicated ([`DistVector`]).  Conformability is descriptor
//! equality, exactly as for [`super::pgemv()`].
//!
//! `y = A x` ([`pspmv`], split-phase):
//!   1. **start the column allgather** — the full (padded) x is assembled
//!      from the column comm's members, one per process row.  This is the
//!      halo-free exchange the sparse cost model prices — no attempt to
//!      ship only the stencil halo — but it now rides the network timeline
//!      ([`crate::comm::AllgatherRequest`]) instead of blocking;
//!   2. **diagonal-block pass** — while the exchange is in flight, one
//!      engine pass over the pre-split part of the row block
//!      ([`crate::sparse::SplitBlocks`]) whose columns this rank's process
//!      row already owns;
//!   3. **off-block pass** — wait the exchange (charging only uncovered
//!      latency) and accumulate the remote-column part.  Every owned row is
//!      computed whole, so unlike `pgemv` there are no partial sums and
//!      **no row allreduce**.
//!
//! `y = A^T x` ([`pspmv_t`], BiCG's second sequence):
//!   1. **local** — `w = A_local^T x_local` over the full global column
//!      range (the owned x blocks are already home);
//!   2. **column allreduce** of the full-length partials, then each rank
//!      keeps its own blocks — y lands replicated exactly like x.
//!
//! Every process column performs the identical redundant computation, so
//! results stay column-replicated without extra traffic.
//!
//! Engine errors panic the calling rank (the same convention as
//! [`super::pgemv()`]'s tile ops): in particular the accelerated engine
//! has no sparse AOT artifact and always errors — run sparse operands
//! with the CPU engine ([`crate::accel::CpuEngine`]).  The gate is
//! testable directly on [`crate::accel::Engine::spmv`].

use super::{tags, Ctx, WireRoute};
use crate::comm::{NeighborExchange, ReduceOp};
use crate::dist::DistVector;
use crate::sparse::{owned_local_col, DistCsrMatrix};
use crate::Scalar;

/// This rank's vector blocks concatenated in local order — the per-rank
/// contribution to the column-comm allgather.
fn concat_blocks<S: Scalar>(x: &DistVector<S>) -> Vec<S> {
    let t = x.desc().tile;
    let mut mine = Vec::with_capacity(x.local_blocks() * t);
    for l in 0..x.local_blocks() {
        mine.extend_from_slice(x.block(l));
    }
    mine
}

/// Place the column comm's per-process-row contributions (`by_row`, indexed
/// by group rank == process row) into the full padded vector, following the
/// vector layout rule (tile `ti` lives at local offset `local_ti(ti)·t` on
/// process row `ti mod pr`).  `skip_prow` omits that row's tiles — the
/// split-phase path already placed its own blocks before the exchange.
fn fill_from_rows<S: Scalar>(
    desc: &crate::dist::Descriptor,
    by_row: &[Vec<S>],
    full: &mut [S],
    skip_prow: Option<usize>,
) {
    let t = desc.tile;
    for ti in 0..desc.mt() {
        let owner = ti % desc.shape.pr;
        if Some(owner) == skip_prow {
            continue;
        }
        let off = desc.local_ti(ti) * t;
        full[ti * t..(ti + 1) * t].copy_from_slice(&by_row[owner][off..off + t]);
    }
}

/// Assemble the full padded vector (`desc.padded_m()` elements) from this
/// rank's blocks via one column-comm allgather.  Shared with
/// [`super::linop`]'s sparse symmetric scaling, which needs the same
/// assembly for its column scales.
pub(super) fn allgather_full<S: Scalar>(
    ctx: &Ctx<'_, S>,
    x: &DistVector<S>,
    tag: u32,
) -> Vec<S> {
    let desc = *x.desc();
    let by_row = ctx.mesh.col_comm().allgather(tag, concat_blocks(x));
    let mut full = vec![S::zero(); desc.padded_m()];
    fill_from_rows(&desc, &by_row, &mut full, None);
    full
}

/// `y = A x`; returns y in the same layout as x.
///
/// **Split-phase**: the column-comm allgather of the x blocks is *started*,
/// the rows' diagonal-block entries (whose columns this rank's process row
/// already owns) are computed while the exchange is in flight, and the
/// off-block entries are finished once it completes — so on a slow network
/// the virtual clock sees `max(allgather, diag compute) + off compute`
/// instead of their full sum (DESIGN.md §11).  Per row, diagonal-block
/// contributions accumulate before off-block ones; both passes keep CSR
/// column order within themselves.
pub fn pspmv<S: Scalar>(
    ctx: &Ctx<'_, S>,
    a: &DistCsrMatrix<S>,
    x: &DistVector<S>,
) -> DistVector<S> {
    let desc = *a.desc();
    assert_eq!(&desc, x.desc(), "pspmv operand descriptors differ");
    let t = desc.tile;
    let mesh = ctx.mesh;

    // 1. Start the halo-free row-block exchange (split-phase allgather).
    let exchange = mesh.col_comm().iallgather(tags::PSPMV, concat_blocks(x));

    // 2. Overlapped: the diagonal-block part — its x blocks already home.
    let split = a.split_blocks();
    let mut xfull = vec![S::zero(); desc.padded_m()];
    for l in 0..x.local_blocks() {
        let ti = desc.global_ti(mesh.row(), l);
        xfull[ti * t..(ti + 1) * t].copy_from_slice(x.block(l));
    }
    let mut yloc = vec![S::zero(); a.local().nrows()];
    let cost = ctx.engine.spmv_part(&split.diag, a.local_nnz(), &xfull, &mut yloc).expect("spmv");
    ctx.charge(cost);

    // 3. Finish the exchange (charging only uncovered latency), assemble the
    //    remote blocks, and accumulate the off-block part.
    let by_row = exchange.wait();
    fill_from_rows(&desc, &by_row, &mut xfull, Some(mesh.row()));
    let cost = ctx.engine.spmv_part(&split.off, a.local_nnz(), &xfull, &mut yloc).expect("spmv");
    ctx.charge(cost);

    let mut y = DistVector::zeros(desc, mesh.row(), mesh.col());
    for l in 0..y.local_blocks() {
        y.block_mut(l).copy_from_slice(&yloc[l * t..(l + 1) * t]);
    }
    y
}

/// `y = A^T x`; returns y in the same layout as x.
pub fn pspmv_t<S: Scalar>(
    ctx: &Ctx<'_, S>,
    a: &DistCsrMatrix<S>,
    x: &DistVector<S>,
) -> DistVector<S> {
    let desc = *a.desc();
    assert_eq!(&desc, x.desc(), "pspmv_t operand descriptors differ");
    let t = desc.tile;
    let mesh = ctx.mesh;

    // 1. Local transpose product: owned rows of A are owned entries of x.
    let mut xloc = Vec::with_capacity(x.local_blocks() * t);
    for l in 0..x.local_blocks() {
        xloc.extend_from_slice(x.block(l));
    }
    let mut part = vec![S::zero(); desc.padded_n()];
    let cost = ctx.engine.spmv_t(a.local(), &xloc, &mut part).expect("spmv_t");
    ctx.charge(cost);

    // 2. Column allreduce of the full-length partials (one member per
    //    process row = the complete distributed sum).
    let summed = mesh.col_comm().allreduce_vec(tags::PSPMV_T, part, ReduceOp::Sum);

    // 3. Keep this rank's blocks.
    let mut y = DistVector::zeros(desc, mesh.row(), mesh.col());
    for l in 0..y.local_blocks() {
        let ti = desc.global_ti(mesh.row(), l);
        y.block_mut(l).copy_from_slice(&summed[ti * t..(ti + 1) * t]);
    }
    y
}

/// `y = A x` over the **halo-exchange** distribution (`DESIGN.md` §15):
/// instead of allgathering the whole padded vector, each rank ships only
/// the ghost elements its neighbors' patterns reference — O(surface) wire
/// volume — through point-to-point `isend`/`irecv`
/// ([`crate::comm::NeighborExchange`]), with the diagonal-block pass
/// overlapped under the exchange exactly like [`pspmv`]'s split-phase
/// path.
///
/// **Bit-identical to [`pspmv`]**: the plan's compact column renumbering
/// is monotone (see [`crate::sparse::HaloPlan`]), so every row's
/// accumulation order — diagonal-block entries first, off-block entries
/// second, CSR column order within each — matches the allgather path
/// operation for operation.  First call builds (and caches) the plan via
/// one collective index handshake; subsequent matvecs reuse it.
pub fn pspmv_halo<S: Scalar>(
    ctx: &Ctx<'_, S>,
    a: &DistCsrMatrix<S>,
    x: &DistVector<S>,
) -> DistVector<S> {
    let desc = *a.desc();
    assert_eq!(&desc, x.desc(), "pspmv_halo operand descriptors differ");
    let t = desc.tile;
    let mesh = ctx.mesh;
    let col = mesh.col_comm();
    let plan = a.halo_plan(&col, tags::HALO_PLAN);
    let xloc = concat_blocks(x);

    // 1. Start the ghost exchange: only the neighbor-referenced elements
    //    hit the wire.  The halo composes with GPUDirect (`DESIGN.md` §16):
    //    were the source vector device-dirty, each ghost segment would
    //    carry its own D2H leg jointly with its NIC occupancy — sparse
    //    interface bytes never touching the host.  On the host sparse
    //    engine the route is `Host`, every leg is zero, and this **is**
    //    `start_exchange`.
    let route = ctx.wire_read(&xloc);
    let pcie_bw = ctx.engine.profile().pcie_bw;
    let exchange = plan.start_exchange_wire(&col, tags::HALO, &desc, &xloc, |bytes| match route {
        WireRoute::Direct { .. } => bytes as f64 / pcie_bw,
        WireRoute::Host => 0.0,
    });

    // 2. Overlapped: the diagonal-block pass over the compact local block.
    let mut yloc = vec![S::zero(); a.local().nrows()];
    let cost =
        ctx.engine.spmv_part(&plan.diag_local, a.local_nnz(), &xloc, &mut yloc).expect("spmv");
    ctx.charge(cost);

    // 3. Finish the exchange (uncovered latency only), scatter the ghost
    //    segments, and accumulate the off-block pass.
    let received = exchange.wait();
    let mut xghost = vec![S::zero(); plan.ghost_elems()];
    plan.scatter_recv(&received, &mut xghost);
    let cost =
        ctx.engine.spmv_part(&plan.off_ghost, a.local_nnz(), &xghost, &mut yloc).expect("spmv");
    ctx.charge(cost);

    let mut y = DistVector::zeros(desc, mesh.row(), mesh.col());
    for l in 0..y.local_blocks() {
        y.block_mut(l).copy_from_slice(&yloc[l * t..(l + 1) * t]);
    }
    y
}

/// `y = A^T x` over the halo-exchange distribution: each rank's off-block
/// entries produce contributions to *remote-owned* columns, which travel
/// back along the reversed ghost routes (send and recv lists swap roles)
/// instead of through a full-length column allreduce.
///
/// **Bit-identical to [`pspmv_t`]**: the owned-column partial is folded
/// with the per-neighbor contributions in the column allreduce's exact
/// binomial-tree association — including explicit `+0.0` partials for
/// process rows whose patterns never touch the column, which is what the
/// allgather path's zero-filled full-length partials contribute — so every
/// element reproduces `allreduce_vec`'s floating-point sum bit for bit.
pub fn pspmv_t_halo<S: Scalar>(
    ctx: &Ctx<'_, S>,
    a: &DistCsrMatrix<S>,
    x: &DistVector<S>,
) -> DistVector<S> {
    let desc = *a.desc();
    assert_eq!(&desc, x.desc(), "pspmv_t_halo operand descriptors differ");
    let t = desc.tile;
    let mesh = ctx.mesh;
    let col = mesh.col_comm();
    let pr = desc.shape.pr;
    let me = mesh.row();
    let plan = a.halo_plan(&col, tags::HALO_PLAN);
    let xloc = concat_blocks(x);
    let width = a.local().nrows();

    // 1. Ghost-column partials first, so their exchange can start early.
    let mut wghost = vec![S::zero(); plan.ghost_elems()];
    let cost = ctx
        .engine
        .spmv_t_part(&plan.off_ghost, a.local_nnz(), desc.padded_n(), &xloc, &mut wghost)
        .expect("spmv_t");
    ctx.charge(cost);

    // 2. Reverse exchange: our ghost contributions go home to their
    //    columns' owners (forward recv lists become sends and vice versa).
    //    Same wire composition as the forward halo: device-dirty ghost
    //    partials would ride straight to the NIC; on the host engine the
    //    legs are zero and this is exactly the staged exchange.
    let route = ctx.wire_read(&wghost);
    let pcie_bw = ctx.engine.profile().pcie_bw;
    let outgoing: Vec<(usize, Vec<S>, f64)> = (0..pr)
        .filter(|&q| !plan.recv[q].is_empty())
        .map(|q| {
            let seg: Vec<S> = plan.recv_slots[q].iter().map(|&s| wghost[s]).collect();
            let leg = match route {
                WireRoute::Direct { .. } => (seg.len() * S::BYTES) as f64 / pcie_bw,
                WireRoute::Host => 0.0,
            };
            (q, seg, leg)
        })
        .collect();
    let incoming: Vec<usize> = (0..pr).filter(|&q| !plan.send[q].is_empty()).collect();
    let exchange = NeighborExchange::start_wire(&col, tags::HALO + 1, outgoing, &incoming);

    // 3. Overlapped: the owned-column partials.
    let mut wdiag = vec![S::zero(); width];
    let cost = ctx
        .engine
        .spmv_t_part(&plan.diag_local, a.local_nnz(), desc.padded_n(), &xloc, &mut wdiag)
        .expect("spmv_t");
    ctx.charge(cost);

    // 4. Fold the per-process-row contributions in `allreduce_vec`'s exact
    //    binomial association: level `mask` folds partner `r | mask` into
    //    survivor `r`, zeros standing in for non-contributing rows.
    let received = exchange.wait();
    let mut acc: Vec<Vec<S>> = (0..pr).map(|_| vec![S::zero(); width]).collect();
    acc[me] = wdiag;
    for (q, seg) in &received {
        for (&c, &v) in plan.send[*q].iter().zip(seg.iter()) {
            acc[*q][owned_local_col(&desc, c)] = v;
        }
    }
    let mut mask = 1;
    while mask < pr {
        let mut r = 0;
        while r + mask < pr {
            let (lo, hi) = acc.split_at_mut(r + mask);
            for (ai, bi) in lo[r].iter_mut().zip(hi[0].iter()) {
                *ai += *bi;
            }
            r += 2 * mask;
        }
        mask <<= 1;
    }

    let mut y = DistVector::zeros(desc, mesh.row(), mesh.col());
    for l in 0..y.local_blocks() {
        y.block_mut(l).copy_from_slice(&acc[0][l * t..(l + 1) * t]);
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::CpuEngine;
    use crate::comm::{NetworkModel, World};
    use crate::dist::{gather_vector, Descriptor};
    use crate::mesh::{Mesh, MeshShape};
    use std::sync::Arc;

    /// Deterministic sparse rows: diagonal + bands at ±2 and ±5.
    fn rows_of(n: usize) -> impl Fn(usize) -> Vec<(usize, f64)> + Clone + Send + Sync {
        move |i| {
            let mut r = vec![(i, 3.0 + ((i * 7) % 5) as f64)];
            for d in [2usize, 5] {
                if i + d < n {
                    r.push((i + d, -0.5 - (d as f64) * 0.1));
                }
                if i >= d {
                    r.push((i - d, 0.25 + (d as f64) * 0.05));
                }
            }
            r
        }
    }

    fn xval(i: usize) -> f64 {
        (i as f64 * 0.43).cos() + 0.1
    }

    fn serial_matvec(n: usize, transpose: bool) -> Vec<f64> {
        let rows = rows_of(n);
        let mut y = vec![0.0; n];
        for i in 0..n {
            for (j, v) in rows(i) {
                if transpose {
                    y[j] += v * xval(i);
                } else {
                    y[i] += v * xval(j);
                }
            }
        }
        y
    }

    fn run_case(n: usize, tile: usize, pr: usize, pc: usize, transpose: bool) {
        let out = World::run::<f64, _, _>(pr * pc, NetworkModel::ideal(), move |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
            let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(tile)));
            let desc = Descriptor::new(n, n, tile, mesh.shape());
            let a = DistCsrMatrix::from_row_fn(desc, mesh.row(), mesh.col(), rows_of(n));
            let x = DistVector::from_fn(desc, mesh.row(), mesh.col(), xval);
            let y = if transpose { pspmv_t(&ctx, &a, &x) } else { pspmv(&ctx, &a, &x) };
            gather_vector(&mesh, &y)
        });
        let got = out[0].as_ref().unwrap();
        let want = serial_matvec(n, transpose);
        for i in 0..n {
            assert!(
                (got[i] - want[i]).abs() < 1e-12,
                "n={n} tile={tile} {pr}x{pc} T={transpose} i={i}: {} vs {}",
                got[i],
                want[i]
            );
        }
    }

    #[test]
    fn pspmv_matches_serial() {
        for (pr, pc) in [(1, 1), (2, 1), (1, 2), (2, 2), (2, 3), (3, 2)] {
            run_case(12, 4, pr, pc, false); // aligned
            run_case(13, 4, pr, pc, false); // padded edge block
        }
    }

    #[test]
    fn pspmv_t_matches_serial() {
        for (pr, pc) in [(1, 1), (2, 1), (1, 2), (2, 2), (2, 3), (3, 2)] {
            run_case(12, 4, pr, pc, true);
            run_case(13, 4, pr, pc, true);
        }
    }

    #[test]
    fn pspmv_split_phase_hides_exchange_latency() {
        // On a 2-row mesh over gigabit, the diagonal-block pass must cover
        // part of the allgather: hidden latency is recorded on some rank,
        // and results stay exact (checked by pspmv_matches_serial).
        let out = World::run::<f64, _, _>(2, NetworkModel::gigabit_ethernet(), |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(2, 1));
            let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(4)));
            let desc = Descriptor::new(64, 64, 4, mesh.shape());
            let a = DistCsrMatrix::from_row_fn(desc, mesh.row(), mesh.col(), rows_of(64));
            let x = DistVector::from_fn(desc, mesh.row(), mesh.col(), xval);
            let _ = pspmv(&ctx, &a, &x);
            comm.stats().wait_saved_secs()
        });
        assert!(
            out.iter().any(|&s| s > 0.0),
            "split-phase pspmv must hide some exchange latency: {out:?}"
        );
    }

    #[test]
    fn pspmv_charges_comm_and_compute_on_multirank_meshes() {
        let out = World::run::<f64, _, _>(4, NetworkModel::gigabit_ethernet(), |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(2, 2));
            let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(4)));
            let desc = Descriptor::new(16, 16, 4, mesh.shape());
            let a = DistCsrMatrix::from_row_fn(desc, mesh.row(), mesh.col(), rows_of(16));
            let x = DistVector::from_fn(desc, mesh.row(), mesh.col(), xval);
            let _ = pspmv(&ctx, &a, &x);
            let c = comm.clock();
            (c.compute_secs(), c.comm_wait_secs())
        });
        assert!(out.iter().all(|&(comp, _)| comp > 0.0), "spmv must charge compute: {out:?}");
        assert!(
            out.iter().any(|&(_, comm)| comm > 0.0),
            "the x allgather must charge communication time: {out:?}"
        );
    }
}
