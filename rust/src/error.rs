//! Library-wide error type.

/// Errors surfaced by CUPLSS-RS.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Shape / distribution mismatch between operands.
    #[error("shape mismatch: {0}")]
    Shape(String),

    /// Invalid configuration (CLI, config file, mesh, tile size...).
    #[error("invalid configuration: {0}")]
    Config(String),

    /// A communication primitive was misused (unknown rank, tag clash...).
    #[error("communication error: {0}")]
    Comm(String),

    /// The PJRT runtime failed (artifact missing, compile error...).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// An iterative solver failed to converge within its iteration budget.
    #[error("solver did not converge: {method}: residual {residual:.3e} after {iterations} iterations (tol {tol:.3e})")]
    NoConvergence {
        method: &'static str,
        residual: f64,
        iterations: usize,
        tol: f64,
    },

    /// A factorization broke down (zero pivot, non-SPD matrix...).
    #[error("numerical breakdown in {method}: {detail}")]
    Breakdown {
        method: &'static str,
        detail: String,
    },

    /// Underlying XLA error.
    #[error("xla: {0}")]
    Xla(#[from] xla::Error),

    /// I/O error (artifact files, config files).
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Helper: shape error from anything displayable.
    pub fn shape(msg: impl std::fmt::Display) -> Self {
        Error::Shape(msg.to_string())
    }

    /// Helper: config error.
    pub fn config(msg: impl std::fmt::Display) -> Self {
        Error::Config(msg.to_string())
    }

    /// Helper: comm error.
    pub fn comm(msg: impl std::fmt::Display) -> Self {
        Error::Comm(msg.to_string())
    }

    /// Helper: runtime error.
    pub fn runtime(msg: impl std::fmt::Display) -> Self {
        Error::Runtime(msg.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = Error::shape("a 2x2 vs b 3x3");
        assert!(e.to_string().contains("2x2"));
        let e = Error::NoConvergence { method: "bicgstab", residual: 1.0, iterations: 7, tol: 1e-9 };
        let s = e.to_string();
        assert!(s.contains("bicgstab") && s.contains('7'));
    }
}
