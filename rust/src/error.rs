//! Library-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls (no `thiserror` in the offline crate
//! set); the message formats are part of the public behaviour and are
//! covered by tests.

use std::fmt;

/// Errors surfaced by CUPLSS-RS.
#[derive(Debug)]
pub enum Error {
    /// Shape / distribution mismatch between operands.
    Shape(String),

    /// Invalid configuration (CLI, config file, mesh, tile size...).
    Config(String),

    /// A communication primitive was misused (unknown rank, tag clash...).
    Comm(String),

    /// The PJRT runtime failed (artifact missing, compile error...).
    Runtime(String),

    /// An iterative solver failed to converge within its iteration budget.
    NoConvergence {
        /// Solver name.
        method: &'static str,
        /// Final relative residual.
        residual: f64,
        /// Iterations performed.
        iterations: usize,
        /// The tolerance that was not met.
        tol: f64,
    },

    /// A factorization broke down (zero pivot, non-SPD matrix...).
    Breakdown {
        /// Routine name.
        method: &'static str,
        /// What went wrong.
        detail: String,
    },

    /// A Krylov recurrence produced a NaN/Inf (poisoned operand, overflow):
    /// reported at the iteration it appears instead of silently iterating
    /// to `max_iter` on garbage.
    NonFinite {
        /// Solver name.
        method: &'static str,
        /// Iteration at which the non-finite value was detected.
        iteration: usize,
        /// Which recurrence quantity went non-finite.
        quantity: &'static str,
    },

    /// Underlying XLA error.
    Xla(xla::Error),

    /// I/O error (artifact files, config files).
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Shape(msg) => write!(f, "shape mismatch: {msg}"),
            Error::Config(msg) => write!(f, "invalid configuration: {msg}"),
            Error::Comm(msg) => write!(f, "communication error: {msg}"),
            Error::Runtime(msg) => write!(f, "runtime error: {msg}"),
            Error::NoConvergence { method, residual, iterations, tol } => write!(
                f,
                "solver did not converge: {method}: residual {residual:.3e} \
                 after {iterations} iterations (tol {tol:.3e})"
            ),
            Error::Breakdown { method, detail } => {
                write!(f, "numerical breakdown in {method}: {detail}")
            }
            Error::NonFinite { method, iteration, quantity } => write!(
                f,
                "non-finite value in {method}: {quantity} at iteration {iteration} \
                 is NaN or infinite"
            ),
            Error::Xla(e) => write!(f, "xla: {e}"),
            Error::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Xla(e) => Some(e),
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Helper: shape error from anything displayable.
    pub fn shape(msg: impl std::fmt::Display) -> Self {
        Error::Shape(msg.to_string())
    }

    /// Helper: config error.
    pub fn config(msg: impl std::fmt::Display) -> Self {
        Error::Config(msg.to_string())
    }

    /// Helper: comm error.
    pub fn comm(msg: impl std::fmt::Display) -> Self {
        Error::Comm(msg.to_string())
    }

    /// Helper: runtime error.
    pub fn runtime(msg: impl std::fmt::Display) -> Self {
        Error::Runtime(msg.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = Error::shape("a 2x2 vs b 3x3");
        assert!(e.to_string().contains("2x2"));
        let e = Error::NoConvergence { method: "bicgstab", residual: 1.0, iterations: 7, tol: 1e-9 };
        let s = e.to_string();
        assert!(s.contains("bicgstab") && s.contains('7'));
        let e = Error::NonFinite { method: "cg", iteration: 3, quantity: "p'Ap" };
        let s = e.to_string();
        assert!(s.contains("cg") && s.contains("p'Ap") && s.contains('3'), "{s}");
    }

    #[test]
    fn io_and_xla_wrap_with_source() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(e.to_string().starts_with("io:"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
