//! Sparse stencil workloads: the discretised Poisson operators that put
//! the Krylov solvers in their natural regime — very large systems with a
//! handful of nonzeros per row, where dense storage (and dense direct
//! methods) stop making sense.
//!
//! Two operators, both SPD in natural (lexicographic) ordering with
//! homogeneous Dirichlet boundaries:
//!
//! * **2-D, 5-point**: `g x g` interior grid, `n = g²`; row `i` couples
//!   `(4, -1, -1, -1, -1)` to itself and its N/S/E/W neighbours;
//! * **3-D, 7-point**: `g x g x g` grid, `n = g³`; diagonal `6`, six
//!   `-1` neighbours along the axes.
//!
//! Each generator emits the operator **directly as distributed CSR**
//! ([`DistCsrMatrix`]) — every rank materialises only its own row blocks
//! from the row function, no dense `n x n` detour and no data movement
//! (the paper's "each node initialises its shard locally" step 2).
//!
//! `nnz` closed forms ([`poisson2d_nnz`], [`poisson3d_nnz`]) feed the
//! model-mode sparse cost entry
//! [`crate::bench_harness::model::sparse_iter_makespan`].

use crate::dist::Descriptor;
use crate::sparse::DistCsrMatrix;
use crate::Scalar;

/// Grid side from a stencil problem size: asserts `n = g^dim` exactly.
fn grid_side(n: usize, dim: u32) -> usize {
    let g = (n as f64).powf(1.0 / f64::from(dim)).round() as usize;
    assert_eq!(g.pow(dim), n, "stencil workload needs n = g^{dim} (got n = {n})");
    g
}

/// Nonzero `(col, val)` entries of row `i` of the 2-D 5-point Poisson
/// operator on a `g x g` grid (columns ascending).
pub fn poisson2d_row<S: Scalar>(g: usize, i: usize) -> Vec<(usize, S)> {
    assert!(i < g * g, "row {i} outside the {g}x{g} grid");
    let (r, c) = (i / g, i % g);
    let mut out = Vec::with_capacity(5);
    if r > 0 {
        out.push((i - g, -S::one()));
    }
    if c > 0 {
        out.push((i - 1, -S::one()));
    }
    out.push((i, S::from_f64(4.0).unwrap()));
    if c + 1 < g {
        out.push((i + 1, -S::one()));
    }
    if r + 1 < g {
        out.push((i + g, -S::one()));
    }
    out
}

/// Nonzero `(col, val)` entries of row `i` of the 3-D 7-point Poisson
/// operator on a `g x g x g` grid (columns ascending).
pub fn poisson3d_row<S: Scalar>(g: usize, i: usize) -> Vec<(usize, S)> {
    assert!(i < g * g * g, "row {i} outside the {g}^3 grid");
    let (z, rem) = (i / (g * g), i % (g * g));
    let (y, x) = (rem / g, rem % g);
    let mut out = Vec::with_capacity(7);
    if z > 0 {
        out.push((i - g * g, -S::one()));
    }
    if y > 0 {
        out.push((i - g, -S::one()));
    }
    if x > 0 {
        out.push((i - 1, -S::one()));
    }
    out.push((i, S::from_f64(6.0).unwrap()));
    if x + 1 < g {
        out.push((i + 1, -S::one()));
    }
    if y + 1 < g {
        out.push((i + g, -S::one()));
    }
    if z + 1 < g {
        out.push((i + g * g, -S::one()));
    }
    out
}

/// This rank's shard of the distributed-CSR 2-D Poisson operator
/// (`desc.m` must be a perfect square `g²`).
pub fn poisson2d_csr<S: Scalar>(desc: Descriptor, prow: usize, pcol: usize) -> DistCsrMatrix<S> {
    let g = grid_side(desc.m, 2);
    DistCsrMatrix::from_row_fn(desc, prow, pcol, |i| poisson2d_row(g, i))
}

/// This rank's shard of the distributed-CSR 3-D Poisson operator
/// (`desc.m` must be a perfect cube `g³`).
pub fn poisson3d_csr<S: Scalar>(desc: Descriptor, prow: usize, pcol: usize) -> DistCsrMatrix<S> {
    let g = grid_side(desc.m, 3);
    DistCsrMatrix::from_row_fn(desc, prow, pcol, |i| poisson3d_row(g, i))
}

/// Stored entries of the 2-D operator: `5g² - 4g`.
pub fn poisson2d_nnz(g: usize) -> usize {
    5 * g * g - 4 * g
}

/// Stored entries of the 3-D operator: `7g³ - 6g²`.
pub fn poisson3d_nnz(g: usize) -> usize {
    7 * g * g * g - 6 * g * g
}

/// Exact right-hand-side entry `b_i = Σ_j A_ij · x_true(j)` for a stencil
/// row — only the stored nonzeros contribute, so each rank can evaluate
/// its rhs blocks in O(row nnz).
pub fn stencil_rhs<S: Scalar>(row: &[(usize, S)], x_true: impl Fn(usize) -> S) -> S {
    row.iter().fold(S::zero(), |acc, &(j, v)| acc + v * x_true(j))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::MeshShape;

    #[test]
    fn poisson2d_rows_match_the_dense_workload() {
        // The dense Poisson2d workload and the sparse generator must agree
        // entry for entry.
        let g = 5;
        let n = g * g;
        let dense = crate::workloads::Workload::Poisson2d.elem::<f64>(n);
        for i in 0..n {
            let row = poisson2d_row::<f64>(g, i);
            let mut from_dense: Vec<(usize, f64)> =
                (0..n).filter(|&j| dense(i, j) != 0.0).map(|j| (j, dense(i, j))).collect();
            from_dense.sort_by_key(|&(c, _)| c);
            assert_eq!(row, from_dense, "row {i}");
        }
    }

    #[test]
    fn poisson_rows_are_symmetric_and_dominant() {
        let cases: [(usize, fn(usize) -> Vec<(usize, f64)>); 2] =
            [(16, |i| poisson2d_row(4, i)), (27, |i| poisson3d_row(3, i))];
        for (n, row) in cases {
            for i in 0..n {
                let ri = row(i);
                let mut off = 0.0;
                let mut diag = 0.0;
                for &(j, v) in &ri {
                    if j == i {
                        diag = v;
                    } else {
                        off += v.abs();
                        // symmetry: (j, i) carries the same value
                        let back = row(j);
                        let &(_, w) = back.iter().find(|&&(c, _)| c == i).expect("sym");
                        assert_eq!(w, v, "({i},{j})");
                    }
                }
                assert!(diag >= off, "row {i}: {diag} vs {off}");
            }
        }
    }

    #[test]
    fn nnz_closed_forms_match_enumeration() {
        for g in [1usize, 2, 3, 5, 8] {
            let count2: usize = (0..g * g).map(|i| poisson2d_row::<f64>(g, i).len()).sum();
            assert_eq!(count2, poisson2d_nnz(g), "2d g={g}");
            let count3: usize = (0..g * g * g).map(|i| poisson3d_row::<f64>(g, i).len()).sum();
            assert_eq!(count3, poisson3d_nnz(g), "3d g={g}");
        }
    }

    #[test]
    fn distributed_generators_cover_all_rows() {
        let g = 4usize;
        for (n, dim) in [(g * g, 2u32), (g * g * g, 3)] {
            let shape = MeshShape::new(2, 2);
            let desc = Descriptor::new(n, n, 4, shape);
            let mut seen = vec![0u32; n];
            for prow in 0..2 {
                let a = if dim == 2 {
                    poisson2d_csr::<f64>(desc, prow, 0)
                } else {
                    poisson3d_csr::<f64>(desc, prow, 0)
                };
                for li in 0..a.local().nrows() {
                    let gi = a.global_row(li);
                    if gi < n {
                        seen[gi] += 1;
                        let want =
                            if dim == 2 { poisson2d_row(g, gi) } else { poisson3d_row(g, gi) };
                        let (cols, vals) = a.local().row(li);
                        assert_eq!(cols.len(), want.len(), "dim {dim} row {gi}");
                        for (k, &(c, v)) in want.iter().enumerate() {
                            assert_eq!((cols[k], vals[k]), (c, v));
                        }
                    }
                }
            }
            assert!(seen.iter().all(|&k| k == 1), "dim {dim}");
        }
    }

    #[test]
    #[should_panic(expected = "needs n = g^2")]
    fn non_square_size_rejected() {
        let desc = Descriptor::new(10, 10, 4, MeshShape::new(1, 1));
        let _ = poisson2d_csr::<f64>(desc, 0, 0);
    }

    #[test]
    fn stencil_rhs_matches_dense_sum() {
        let g = 4;
        let n = g * g;
        let dense = crate::workloads::Workload::Poisson2d.elem::<f64>(n);
        let xt = |j: usize| (j as f64 * 0.7).cos();
        for i in 0..n {
            let want: f64 = (0..n).map(|j| dense(i, j) * xt(j)).sum();
            let got = stencil_rhs(&poisson2d_row::<f64>(g, i), xt);
            assert!((got - want).abs() < 1e-14, "row {i}");
        }
    }
}
