//! Sparse stencil workloads: the discretised Poisson operators that put
//! the Krylov solvers in their natural regime — very large systems with a
//! handful of nonzeros per row, where dense storage (and dense direct
//! methods) stop making sense.
//!
//! Two operators, both SPD in natural (lexicographic) ordering with
//! homogeneous Dirichlet boundaries:
//!
//! * **2-D, 5-point**: `g x g` interior grid, `n = g²`; row `i` couples
//!   `(4, -1, -1, -1, -1)` to itself and its N/S/E/W neighbours;
//! * **3-D, 7-point**: `g x g x g` grid, `n = g³`; diagonal `6`, six
//!   `-1` neighbours along the axes.
//!
//! Each generator emits the operator **directly as distributed CSR**
//! ([`DistCsrMatrix`]) — every rank materialises only its own row blocks
//! from the row function, no dense `n x n` detour and no data movement
//! (the paper's "each node initialises its shard locally" step 2).
//!
//! `nnz` closed forms ([`poisson2d_nnz`], [`poisson3d_nnz`]) feed the
//! model-mode sparse cost entry
//! [`crate::bench_harness::model::sparse_iter_makespan`].

use crate::dist::Descriptor;
use crate::sparse::DistCsrMatrix;
use crate::Scalar;

/// Grid side from a stencil problem size: asserts `n = g^dim` exactly.
fn grid_side(n: usize, dim: u32) -> usize {
    let g = (n as f64).powf(1.0 / f64::from(dim)).round() as usize;
    assert_eq!(g.pow(dim), n, "stencil workload needs n = g^{dim} (got n = {n})");
    g
}

/// Nonzero `(col, val)` entries of row `i` of the 2-D 5-point Poisson
/// operator on a `g x g` grid (columns ascending).
pub fn poisson2d_row<S: Scalar>(g: usize, i: usize) -> Vec<(usize, S)> {
    assert!(i < g * g, "row {i} outside the {g}x{g} grid");
    let (r, c) = (i / g, i % g);
    let mut out = Vec::with_capacity(5);
    if r > 0 {
        out.push((i - g, -S::one()));
    }
    if c > 0 {
        out.push((i - 1, -S::one()));
    }
    out.push((i, S::from_f64(4.0).unwrap()));
    if c + 1 < g {
        out.push((i + 1, -S::one()));
    }
    if r + 1 < g {
        out.push((i + g, -S::one()));
    }
    out
}

/// Nonzero `(col, val)` entries of row `i` of the 3-D 7-point Poisson
/// operator on a `g x g x g` grid (columns ascending).
pub fn poisson3d_row<S: Scalar>(g: usize, i: usize) -> Vec<(usize, S)> {
    assert!(i < g * g * g, "row {i} outside the {g}^3 grid");
    let (z, rem) = (i / (g * g), i % (g * g));
    let (y, x) = (rem / g, rem % g);
    let mut out = Vec::with_capacity(7);
    if z > 0 {
        out.push((i - g * g, -S::one()));
    }
    if y > 0 {
        out.push((i - g, -S::one()));
    }
    if x > 0 {
        out.push((i - 1, -S::one()));
    }
    out.push((i, S::from_f64(6.0).unwrap()));
    if x + 1 < g {
        out.push((i + 1, -S::one()));
    }
    if y + 1 < g {
        out.push((i + g, -S::one()));
    }
    if z + 1 < g {
        out.push((i + g * g, -S::one()));
    }
    out
}

/// This rank's shard of the distributed-CSR 2-D Poisson operator
/// (`desc.m` must be a perfect square `g²`).
pub fn poisson2d_csr<S: Scalar>(desc: Descriptor, prow: usize, pcol: usize) -> DistCsrMatrix<S> {
    let g = grid_side(desc.m, 2);
    DistCsrMatrix::from_row_fn(desc, prow, pcol, |i| poisson2d_row(g, i))
}

/// This rank's shard of the distributed-CSR 3-D Poisson operator
/// (`desc.m` must be a perfect cube `g³`).
pub fn poisson3d_csr<S: Scalar>(desc: Descriptor, prow: usize, pcol: usize) -> DistCsrMatrix<S> {
    let g = grid_side(desc.m, 3);
    DistCsrMatrix::from_row_fn(desc, prow, pcol, |i| poisson3d_row(g, i))
}

/// Nonzero `(col, val)` entries of row `i` of the 1-D 3-point Poisson
/// operator (tridiagonal `(-1, 2, -1)`) on a `g`-point line.
pub fn poisson1d_row<S: Scalar>(g: usize, i: usize) -> Vec<(usize, S)> {
    assert!(i < g, "row {i} outside the {g}-point line");
    let mut out = Vec::with_capacity(3);
    if i > 0 {
        out.push((i - 1, -S::one()));
    }
    out.push((i, S::from_f64(2.0).unwrap()));
    if i + 1 < g {
        out.push((i + 1, -S::one()));
    }
    out
}

/// This rank's shard of the distributed-CSR 1-D Poisson operator
/// (`desc.m` is the line length `g` directly).
pub fn poisson1d_csr<S: Scalar>(desc: Descriptor, prow: usize, pcol: usize) -> DistCsrMatrix<S> {
    let g = desc.m;
    DistCsrMatrix::from_row_fn(desc, prow, pcol, |i| poisson1d_row(g, i))
}

/// Stored entries of the 1-D operator: `3g - 2`.
pub fn poisson1d_nnz(g: usize) -> usize {
    3 * g - 2
}

/// Stored entries of the 2-D operator: `5g² - 4g`.
pub fn poisson2d_nnz(g: usize) -> usize {
    5 * g * g - 4 * g
}

/// Stored entries of the 3-D operator: `7g³ - 6g²`.
pub fn poisson3d_nnz(g: usize) -> usize {
    7 * g * g * g - 6 * g * g
}

/// Exact right-hand-side entry `b_i = Σ_j A_ij · x_true(j)` for a stencil
/// row — only the stored nonzeros contribute, so each rank can evaluate
/// its rhs blocks in O(row nnz).
pub fn stencil_rhs<S: Scalar>(row: &[(usize, S)], x_true: impl Fn(usize) -> S) -> S {
    row.iter().fold(S::zero(), |acc, &(j, v)| acc + v * x_true(j))
}

/// Axis strides of a `dim`-dimensional `g`-point-per-side Poisson stencil:
/// the off-diagonal couplings of row `i` sit at `i ± stride`.
pub fn stencil_strides(g: usize, dim: u32) -> Vec<usize> {
    (0..dim).map(|k| g.pow(k)).collect()
}

/// Exact halo-surface counts of a Poisson stencil under the round-robin
/// tile-row distribution (tile row `ti` on process row `ti mod pr`) —
/// the inputs the halo cost model needs
/// ([`crate::bench_harness::model::sparse_iter_makespan_halo`]).
///
/// Round-robin tiling makes the coupling surface irregular (every tile
/// boundary is a rank boundary, and which neighbor owns the far side
/// cycles), so there is no trustworthy closed form; this is an exact
/// `O(n · dim)` enumeration, mirrored verbatim in
/// `python/tests/model_mirror.py`.  All `max` fields are worst-case over
/// process rows — the makespan rank.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StencilHalo {
    /// Max over process rows: distinct remote columns referenced (= ghost
    /// slots = elements received per matvec).
    pub ghost_elems: usize,
    /// Max over process rows: elements sent per matvec (one copy per
    /// neighbor that references the column).
    pub send_elems: usize,
    /// Max over process rows: peers exchanged with (send or receive).
    pub neighbors: usize,
    /// Stored entries whose column tile lives on the owning process row
    /// (the overlap-eligible diagonal-block share), summed over ranks.
    pub diag_nnz: usize,
    /// All stored entries (`poisson{1,2,3}d_nnz`).
    pub total_nnz: usize,
}

/// Enumerate [`StencilHalo`] for a `dim`-D Poisson operator on a
/// `g`-per-side grid, tile size `tile`, `pr` process rows.
pub fn stencil_halo_counts(g: usize, dim: u32, tile: usize, pr: usize) -> StencilHalo {
    let n = g.pow(dim);
    let strides = stencil_strides(g, dim);
    let owner = |x: usize| (x / tile) % pr;
    let mut ghost = vec![0usize; pr];
    let mut send = vec![0usize; pr];
    // pair[r][q]: does r exchange with q (either direction)?
    let mut pair = vec![vec![false; pr]; pr];
    let mut diag_nnz = n; // every diagonal entry is owned by its own row
    let mut total_nnz = n;
    for j in 0..n {
        let oj = owner(j);
        // Process rows referencing column j from a remote row i = j -+ s.
        let mut refs: Vec<usize> = Vec::with_capacity(2 * dim as usize);
        for &s in &strides {
            // i = j - s references j = i + s: valid when i's axis
            // coordinate is below the far face.
            if j >= s && (j - s) / s % g < g - 1 {
                let oi = owner(j - s);
                total_nnz += 1;
                if oi != oj {
                    if !refs.contains(&oi) {
                        refs.push(oi);
                    }
                } else {
                    diag_nnz += 1;
                }
            }
            // i = j + s references j = i - s: valid when i's axis
            // coordinate is above the near face.
            if j + s < n && (j + s) / s % g > 0 {
                let oi = owner(j + s);
                total_nnz += 1;
                if oi != oj {
                    if !refs.contains(&oi) {
                        refs.push(oi);
                    }
                } else {
                    diag_nnz += 1;
                }
            }
        }
        for &r in &refs {
            ghost[r] += 1;
            pair[r][oj] = true;
            pair[oj][r] = true;
        }
        send[oj] += refs.len();
    }
    let neighbors =
        (0..pr).map(|r| (0..pr).filter(|&q| pair[r][q]).count()).max().unwrap_or(0);
    StencilHalo {
        ghost_elems: ghost.iter().copied().max().unwrap_or(0),
        send_elems: send.iter().copied().max().unwrap_or(0),
        neighbors,
        diag_nnz,
        total_nnz,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::MeshShape;

    #[test]
    fn poisson2d_rows_match_the_dense_workload() {
        // The dense Poisson2d workload and the sparse generator must agree
        // entry for entry.
        let g = 5;
        let n = g * g;
        let dense = crate::workloads::Workload::Poisson2d.elem::<f64>(n);
        for i in 0..n {
            let row = poisson2d_row::<f64>(g, i);
            let mut from_dense: Vec<(usize, f64)> =
                (0..n).filter(|&j| dense(i, j) != 0.0).map(|j| (j, dense(i, j))).collect();
            from_dense.sort_by_key(|&(c, _)| c);
            assert_eq!(row, from_dense, "row {i}");
        }
    }

    #[test]
    fn poisson_rows_are_symmetric_and_dominant() {
        let cases: [(usize, fn(usize) -> Vec<(usize, f64)>); 2] =
            [(16, |i| poisson2d_row(4, i)), (27, |i| poisson3d_row(3, i))];
        for (n, row) in cases {
            for i in 0..n {
                let ri = row(i);
                let mut off = 0.0;
                let mut diag = 0.0;
                for &(j, v) in &ri {
                    if j == i {
                        diag = v;
                    } else {
                        off += v.abs();
                        // symmetry: (j, i) carries the same value
                        let back = row(j);
                        let &(_, w) = back.iter().find(|&&(c, _)| c == i).expect("sym");
                        assert_eq!(w, v, "({i},{j})");
                    }
                }
                assert!(diag >= off, "row {i}: {diag} vs {off}");
            }
        }
    }

    #[test]
    fn nnz_closed_forms_match_enumeration() {
        for g in [1usize, 2, 3, 5, 8] {
            let count2: usize = (0..g * g).map(|i| poisson2d_row::<f64>(g, i).len()).sum();
            assert_eq!(count2, poisson2d_nnz(g), "2d g={g}");
            let count3: usize = (0..g * g * g).map(|i| poisson3d_row::<f64>(g, i).len()).sum();
            assert_eq!(count3, poisson3d_nnz(g), "3d g={g}");
        }
    }

    #[test]
    fn distributed_generators_cover_all_rows() {
        let g = 4usize;
        for (n, dim) in [(g * g, 2u32), (g * g * g, 3)] {
            let shape = MeshShape::new(2, 2);
            let desc = Descriptor::new(n, n, 4, shape);
            let mut seen = vec![0u32; n];
            for prow in 0..2 {
                let a = if dim == 2 {
                    poisson2d_csr::<f64>(desc, prow, 0)
                } else {
                    poisson3d_csr::<f64>(desc, prow, 0)
                };
                for li in 0..a.local().nrows() {
                    let gi = a.global_row(li);
                    if gi < n {
                        seen[gi] += 1;
                        let want =
                            if dim == 2 { poisson2d_row(g, gi) } else { poisson3d_row(g, gi) };
                        let (cols, vals) = a.local().row(li);
                        assert_eq!(cols.len(), want.len(), "dim {dim} row {gi}");
                        for (k, &(c, v)) in want.iter().enumerate() {
                            assert_eq!((cols[k], vals[k]), (c, v));
                        }
                    }
                }
            }
            assert!(seen.iter().all(|&k| k == 1), "dim {dim}");
        }
    }

    #[test]
    #[should_panic(expected = "needs n = g^2")]
    fn non_square_size_rejected() {
        let desc = Descriptor::new(10, 10, 4, MeshShape::new(1, 1));
        let _ = poisson2d_csr::<f64>(desc, 0, 0);
    }

    #[test]
    fn poisson1d_is_tridiagonal_and_counted() {
        let g = 7;
        for i in 0..g {
            let row = poisson1d_row::<f64>(g, i);
            for &(j, v) in &row {
                assert_eq!(v, if i == j { 2.0 } else { -1.0 }, "({i},{j})");
                assert!(j.abs_diff(i) <= 1);
            }
            assert_eq!(row.len(), if i == 0 || i == g - 1 { 2 } else { 3 });
        }
        let count: usize = (0..g).map(|i| poisson1d_row::<f64>(g, i).len()).sum();
        assert_eq!(count, poisson1d_nnz(g));
    }

    /// The enumeration must agree with real `HaloPlan`s built from the
    /// same operators — worst-case-over-ranks, field for field.
    #[test]
    fn halo_counts_match_built_plans() {
        use crate::comm::{NetworkModel, World};
        use crate::mesh::Mesh;
        let cases: [(usize, u32, usize, usize); 5] = [
            (12, 1, 4, 2),
            (5, 2, 4, 2),  // ragged: n = 25, tile 4
            (4, 2, 4, 3),  // pr = 3, some rank pairs never touch
            (3, 3, 4, 2),  // n = 27
            (2, 3, 2, 4),  // tiny tiles, pr = 4 (empty-neighbor ranks)
        ];
        for (g, dim, tile, pr) in cases {
            let n = g.pow(dim);
            let want = stencil_halo_counts(g, dim, tile, pr);
            let got = World::run::<f64, _, _>(pr, NetworkModel::ideal(), move |comm| {
                let mesh = Mesh::new(&comm, MeshShape::new(pr, 1));
                let desc = Descriptor::new(n, n, tile, mesh.shape());
                let a = match dim {
                    1 => poisson1d_csr::<f64>(desc, mesh.row(), mesh.col()),
                    2 => poisson2d_csr::<f64>(desc, mesh.row(), mesh.col()),
                    _ => poisson3d_csr::<f64>(desc, mesh.row(), mesh.col()),
                };
                let col = mesh.col_comm();
                let plan = a.halo_plan(&col, 61);
                (
                    plan.ghost_elems(),
                    plan.send_elems(),
                    plan.neighbors(),
                    plan.diag_local.nnz(),
                    a.local_nnz(),
                )
            });
            let ghost = got.iter().map(|r| r.0).max().unwrap();
            let send = got.iter().map(|r| r.1).max().unwrap();
            let neigh = got.iter().map(|r| r.2).max().unwrap();
            let diag: usize = got.iter().map(|r| r.3).sum();
            let total: usize = got.iter().map(|r| r.4).sum();
            let label = format!("g={g} dim={dim} tile={tile} pr={pr}");
            assert_eq!(want.ghost_elems, ghost, "{label} ghost");
            assert_eq!(want.send_elems, send, "{label} send");
            assert_eq!(want.neighbors, neigh, "{label} neighbors");
            assert_eq!(want.diag_nnz, diag, "{label} diag nnz");
            assert_eq!(want.total_nnz, total, "{label} total nnz");
        }
    }

    /// Serial counts degenerate: no ghosts, no neighbors, all-diag nnz
    /// equal to the closed forms.
    #[test]
    fn halo_counts_serial_degenerate() {
        for (g, dim, nnz) in [
            (9usize, 1u32, poisson1d_nnz(9)),
            (6, 2, poisson2d_nnz(6)),
            (3, 3, poisson3d_nnz(3)),
        ] {
            let h = stencil_halo_counts(g, dim, 4, 1);
            assert_eq!(h.ghost_elems, 0, "dim {dim}");
            assert_eq!(h.send_elems, 0, "dim {dim}");
            assert_eq!(h.neighbors, 0, "dim {dim}");
            assert_eq!(h.diag_nnz, nnz, "dim {dim}");
            assert_eq!(h.total_nnz, nnz, "dim {dim}");
        }
    }

    #[test]
    fn stencil_rhs_matches_dense_sum() {
        let g = 4;
        let n = g * g;
        let dense = crate::workloads::Workload::Poisson2d.elem::<f64>(n);
        let xt = |j: usize| (j as f64 * 0.7).cos();
        for i in 0..n {
            let want: f64 = (0..n).map(|j| dense(i, j) * xt(j)).sum();
            let got = stencil_rhs(&poisson2d_row::<f64>(g, i), xt);
            assert!((got - want).abs() < 1e-14, "row {i}");
        }
    }
}
