//! Workload generators: the linear systems the paper's introduction
//! motivates ("from physics and engineering to macroeconometric modeling").
//!
//! Each workload is a deterministic element function — every rank
//! regenerates exactly its own shard with no data movement (the paper's
//! step 2, "initialize matrices and vectors in the host memory") — plus a
//! right-hand side with a *known* solution so residual checks are exact.
//!
//! Dense workloads live in this module ([`Workload`]); the sparse stencil
//! workloads (2-D/3-D Poisson emitted directly as distributed CSR) are in
//! [`stencil`].

pub mod stencil;

pub use stencil::{
    poisson1d_csr, poisson1d_row, poisson2d_csr, poisson2d_row, poisson3d_csr, poisson3d_row,
    stencil_halo_counts, StencilHalo,
};

use crate::Scalar;

/// A named linear-system workload with deterministic elements.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Workload {
    /// Dense symmetric positive definite (Cholesky / CG).
    Spd,
    /// Dense diagonally-dominant nonsymmetric (LU / BiCG / BiCGSTAB / GMRES).
    DiagDominant,
    /// 2-D Poisson 5-point stencil on a `g x g` grid, stored dense
    /// (n = g²) — the engineering PDE workload.
    Poisson2d,
    /// Macroeconometric simultaneous-equations structure: dense country
    /// blocks on the diagonal, sparse trade-linkage coupling off-diagonal
    /// (the paper authors' own application domain).
    Econometric,
}

impl Workload {
    /// Parse a CLI name.
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "spd" => Ok(Workload::Spd),
            "diagdom" | "dense" | "nonsym" => Ok(Workload::DiagDominant),
            "poisson" | "poisson2d" => Ok(Workload::Poisson2d),
            "econ" | "econometric" => Ok(Workload::Econometric),
            other => Err(crate::Error::config(format!(
                "unknown workload {other:?} (spd|diagdom|poisson2d|econometric)"
            ))),
        }
    }

    /// Is the generated matrix symmetric positive definite?
    pub fn is_spd(&self) -> bool {
        matches!(self, Workload::Spd | Workload::Poisson2d)
    }

    /// Element function for an `n x n` instance of this workload.
    pub fn elem<S: Scalar>(&self, n: usize) -> impl Fn(usize, usize) -> S + Clone + Send + Sync {
        let kind = *self;
        move |i, j| S::from_f64(elem_f64(kind, n, i, j)).unwrap()
    }

    /// The known solution the rhs is generated from.
    pub fn x_true<S: Scalar>(&self, _n: usize) -> impl Fn(usize) -> S + Clone + Send + Sync {
        move |i| S::from_f64(x_true_f64(i)).unwrap()
    }

    /// Right-hand side b = A x_true (O(n) per element; evaluated lazily by
    /// each rank for its own blocks).
    pub fn rhs<S: Scalar>(&self, n: usize) -> impl Fn(usize) -> S + Clone + Send + Sync {
        let kind = *self;
        move |i| {
            let mut s = 0.0;
            match kind {
                // Poisson rows have <= 5 nonzeros: sum only those.
                Workload::Poisson2d => {
                    let g = isqrt(n);
                    for j in poisson_neighbors(g, i) {
                        s += elem_f64(kind, n, i, j) * x_true_f64(j);
                    }
                }
                _ => {
                    for j in 0..n {
                        s += elem_f64(kind, n, i, j) * x_true_f64(j);
                    }
                }
            }
            S::from_f64(s).unwrap()
        }
    }
}

fn x_true_f64(i: usize) -> f64 {
    ((i as f64) * 0.21).sin() + 1.0
}

fn isqrt(n: usize) -> usize {
    let g = (n as f64).sqrt().round() as usize;
    assert_eq!(g * g, n, "poisson2d needs a square size (got n={n})");
    g
}

fn poisson_neighbors(g: usize, i: usize) -> Vec<usize> {
    let (r, c) = (i / g, i % g);
    let mut out = vec![i];
    if r > 0 {
        out.push(i - g);
    }
    if r + 1 < g {
        out.push(i + g);
    }
    if c > 0 {
        out.push(i - 1);
    }
    if c + 1 < g {
        out.push(i + 1);
    }
    out
}

fn elem_f64(kind: Workload, n: usize, i: usize, j: usize) -> f64 {
    match kind {
        Workload::Spd => {
            let base = (((i * 37 + j * 61) % 97) as f64) / 97.0 - 0.5;
            let sym = base + ((((j * 37 + i * 61) % 97) as f64) / 97.0 - 0.5);
            if i == j {
                2.0 * n as f64 + sym
            } else {
                0.5 * sym
            }
        }
        Workload::DiagDominant => {
            let v = (((i * 13 + j * 29 + 7) % 101) as f64) / 101.0 - 0.5;
            if i == j {
                n as f64 + 1.0 + v
            } else {
                v
            }
        }
        Workload::Poisson2d => {
            let g = isqrt(n);
            let (ri, ci) = (i / g, i % g);
            let (rj, cj) = (j / g, j % g);
            if i == j {
                4.0
            } else if (ri == rj && ci.abs_diff(cj) == 1) || (ci == cj && ri.abs_diff(rj) == 1) {
                -1.0
            } else {
                0.0
            }
        }
        Workload::Econometric => {
            // Country blocks of 32 equations; dense within a block,
            // weak trade coupling between blocks decaying with distance.
            const BS: usize = 32;
            let (bi, bj) = (i / BS, j / BS);
            if bi == bj {
                let v = (((i * 17 + j * 23 + 3) % 89) as f64) / 89.0 - 0.5;
                if i == j {
                    BS as f64 * 2.0 + v.abs() + 1.0
                } else {
                    v
                }
            } else {
                let d = bi.abs_diff(bj) as f64;
                let v = (((i * 7 + j * 11 + 1) % 83) as f64) / 83.0 - 0.5;
                v * 0.3 / (d * d)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_names() {
        assert_eq!(Workload::parse("spd").unwrap(), Workload::Spd);
        assert_eq!(Workload::parse("poisson2d").unwrap(), Workload::Poisson2d);
        assert_eq!(Workload::parse("econ").unwrap(), Workload::Econometric);
        assert!(Workload::parse("nope").is_err());
    }

    #[test]
    fn spd_is_symmetric_and_dominant() {
        let n = 40;
        let f = Workload::Spd.elem::<f64>(n);
        for i in 0..n {
            let mut off = 0.0;
            for j in 0..n {
                assert_eq!(f(i, j), f(j, i), "symmetry ({i},{j})");
                if i != j {
                    off += f(i, j).abs();
                }
            }
            assert!(f(i, i) > off, "row {i} not dominant: {} vs {off}", f(i, i));
        }
    }

    #[test]
    fn diagdom_rows_dominant() {
        let n = 50;
        let f = Workload::DiagDominant.elem::<f64>(n);
        for i in 0..n {
            let off: f64 =
                (0..n).filter(|&j| j != i).map(|j| f(i, j).abs()).sum();
            assert!(f(i, i).abs() > off);
        }
    }

    #[test]
    fn poisson_structure() {
        let g = 5;
        let n = g * g;
        let f = Workload::Poisson2d.elem::<f64>(n);
        assert_eq!(f(0, 0), 4.0);
        assert_eq!(f(0, 1), -1.0);
        assert_eq!(f(0, g), -1.0);
        assert_eq!(f(0, 2), 0.0);
        // row ends don't wrap
        assert_eq!(f(g - 1, g), 0.0);
        // symmetric
        for i in 0..n {
            for j in 0..n {
                assert_eq!(f(i, j), f(j, i));
            }
        }
    }

    #[test]
    fn rhs_matches_dense_sum() {
        let n = 25;
        for w in [Workload::Spd, Workload::DiagDominant, Workload::Poisson2d] {
            let f = w.elem::<f64>(n);
            let rhs = w.rhs::<f64>(n);
            let xt = w.x_true::<f64>(n);
            for i in 0..n {
                let want: f64 = (0..n).map(|j| f(i, j) * xt(j)).sum();
                assert!((rhs(i) - want).abs() < 1e-12, "{w:?} row {i}");
            }
        }
    }

    #[test]
    fn econometric_block_structure() {
        let n = 96;
        let f = Workload::Econometric.elem::<f64>(n);
        // within-block entries larger than cross-block
        assert!(f(0, 0) > 1.0);
        assert!(f(0, 80).abs() < 0.5, "far blocks weakly coupled: {}", f(0, 80));
    }
}
