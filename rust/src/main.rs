//! `cuplss` — the CUPLSS-RS launcher.
//!
//! ```text
//! cuplss solve  --workload diagdom --method lu --n 512 --ranks 4 \
//!               --engine atlas|cuda --tile 128|256 --dtype f32|f64 \
//!               [--streaming] [--no-prefetch] [--no-gpudirect] \
//!               [--no-mixed] [--device-mem BYTES] \
//!               [--fault-plan SPEC] [--ckpt-every K]
//! cuplss serve  [--requests 16] [--n 192] [--ranks 4] [--rhs-batch 8] \
//!               [--no-batching] [--no-factor-cache] [--factor-cache-cap K] \
//!               [--deadline SECS] [--retry-budget K] # solve-request scheduler
//! cuplss fig3   [--dp] [--n 60000] [--iters 100]      # model-mode Figure 3
//! cuplss fig4   [--dp] [--n 60000] [--cholesky]       # model-mode Figure 4
//! cuplss calibrate [--method lu]                      # live vs model (E8)
//! cuplss info                                         # artifacts + profiles
//! ```
//!
//! `--config FILE` loads `[cluster] / [network] / [solver]` sections
//! (see `rust/src/config.rs`); explicit CLI options override the file.

use cuplss::accel::{ComputeProfile, EngineKind};
use cuplss::bench_harness::{self, calibrate, figures};
use cuplss::cli::Args;
use cuplss::cluster::{Cluster, ClusterConfig, Method};
use cuplss::config::Config;
use cuplss::runtime::Runtime;
use cuplss::solvers::IterConfig;
use cuplss::util::fmt;
use cuplss::workloads::Workload;
use cuplss::Result;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn cluster_config(args: &Args) -> Result<ClusterConfig> {
    let mut cfg = match args.opt("config") {
        Some(path) => Config::load(path)?.cluster_config()?,
        None => ClusterConfig::default(),
    };
    cfg.ranks = args.opt_or("ranks", cfg.ranks)?;
    cfg.tile = args.opt_or("tile", cfg.tile)?;
    if let Some(e) = args.opt("engine") {
        cfg.engine = EngineKind::parse(e)?;
    }
    cfg.iter = IterConfig {
        tol: args.opt_or("tol", cfg.iter.tol)?,
        max_iter: args.opt_or("max-iter", cfg.iter.max_iter)?,
        restart: args.opt_or("restart", cfg.iter.restart)?,
    };
    // --streaming disables the tile cache: every operand pays the paper's
    // copy-per-call §3 *transfer* accounting again.  The fused BLAS-1
    // kernels are part of the solvers themselves (bit-identical math, so
    // there is nothing to A/B) and stay active either way; --device-mem
    // sizes the cache (bytes, GTX 280 = 1 GiB).  --no-prefetch keeps the
    // cache but turns the copy-engine timeline off, so every surviving
    // transfer charges the compute timeline synchronously — the A/B arm
    // for the async prefetch / write-back subsystem (DESIGN.md §13);
    // results are bit-identical either way.
    if args.has_flag("streaming") {
        cfg.residency = false;
    }
    if args.has_flag("no-prefetch") {
        cfg.prefetch = false;
    }
    // --no-gpudirect keeps prefetch but stages every send payload through
    // the blocking host_read barrier again — the A/B arm for the
    // device-to-NIC wire (DESIGN.md §16); results are bit-identical.
    if args.has_flag("no-gpudirect") {
        cfg.gpudirect = false;
    }
    // --no-mixed runs uniform wide precision — the A/B arm for the
    // f32-factor + f64-refine / f64-accumulate-Krylov path (DESIGN.md §17).
    // Unlike the transfer knobs this one *could* change results (different
    // rounding), which is exactly why it exists: the mixed path's claim is
    // that it does not change them beyond the refined backward-error bound.
    if args.has_flag("no-mixed") {
        cfg.mixed_precision = false;
    }
    cfg.device_mem = args.opt_or("device-mem", cfg.device_mem)?;
    // --fault-plan injects deterministic failures (see comm::faults for the
    // spec grammar: "crash:RANK@T; slow:RANKxRATE; drop:SRC-DST#N; ...");
    // --ckpt-every K checkpoints factorizations/Krylov state every K panels
    // or iterations so a crash rolls back instead of recomputing from zero.
    if let Some(spec) = args.opt("fault-plan") {
        cfg.fault_plan = cuplss::comm::FaultPlan::parse(spec)?;
    }
    if args.opt("ckpt-every").is_some() {
        cfg.ckpt_every = Some(args.opt_or("ckpt-every", 0usize)?);
    }
    Ok(cfg)
}

fn run(args: &Args) -> Result<()> {
    match args.command() {
        Some("solve") => cmd_solve(args),
        Some("serve") => cmd_serve(args),
        Some("fig3") => cmd_fig3(args),
        Some("fig4") => cmd_fig4(args),
        Some("calibrate") => cmd_calibrate(args),
        Some("info") => cmd_info(args),
        other => {
            if let Some(cmd) = other {
                eprintln!("unknown command {cmd:?}\n");
            }
            eprintln!(
                "usage: cuplss <solve|serve|fig3|fig4|calibrate|info> [options]\n\
                 see rust/src/main.rs header for the option list"
            );
            Ok(())
        }
    }
}

fn cmd_solve(args: &Args) -> Result<()> {
    let cfg = cluster_config(args)?;
    let workload = Workload::parse(args.opt("workload").unwrap_or("diagdom"))?;
    let method = Method::parse(args.opt("method").unwrap_or("lu"))?;
    let n: usize = args.opt_or("n", 512)?;
    let dtype = args.opt("dtype").unwrap_or("f64");
    let cluster = Cluster::new(cfg)?;
    let report = match dtype {
        "f32" => cluster.solve::<f32>(workload, n, method)?,
        "f64" => cluster.solve::<f64>(workload, n, method)?,
        other => return Err(cuplss::Error::config(format!("dtype {other:?} (f32|f64)"))),
    };
    println!("{}", report.summary());
    println!(
        "  virtual makespan {}   wall {}   msgs {}   volume {}   \
         pcie saved {}   pcie hidden {}   prefetch hits {}   wire direct {}   \
         stage saved {}   launches fused {}",
        fmt::secs(report.makespan()),
        fmt::secs(report.wall_max()),
        report.total_msgs(),
        fmt::bytes(report.total_bytes() as f64),
        fmt::bytes(report.total_pcie_saved() as f64),
        fmt::secs(report.total_pcie_hidden()),
        report.total_prefetch_hits(),
        fmt::bytes(report.total_wire_direct() as f64),
        fmt::secs(report.total_host_stage_saved()),
        report.total_launches_fused(),
    );
    for m in &report.per_rank {
        println!(
            "  rank {:>2}: vtime {} (compute {}, wait {}, pcie {})",
            m.rank,
            fmt::secs(m.vtime),
            fmt::secs(m.compute),
            fmt::secs(m.comm_wait),
            fmt::secs(m.transfer),
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use cuplss::serve::{demo_stream, serve_cluster, ServeConfig};
    let cfg = cluster_config(args)?;
    let n_requests: usize = args.opt_or("requests", 16)?;
    let base_n: usize = args.opt_or("n", 192)?;
    let dtype = args.opt("dtype").unwrap_or("f64");
    // --no-batching is the A/B arm: the identical stream, singleton
    // batches, no amortization — same answers, worse timeline.
    let scfg = ServeConfig {
        rhs_batch: args.opt_or("rhs-batch", 8)?,
        batching: !args.has_flag("no-batching"),
        factor_cache: !args.has_flag("no-factor-cache"),
        factor_cache_cap: args.opt_or("factor-cache-cap", usize::MAX)?,
        deadline: args.opt("deadline").map(|_| args.opt_or("deadline", 0.0)).transpose()?,
        retry_budget: args.opt_or("retry-budget", 0)?,
    };
    let cluster = Cluster::new(cfg)?;
    let stream = demo_stream(n_requests, base_n);
    let report = match dtype {
        "f32" => serve_cluster::<f32>(&cluster, &stream, &scfg)?,
        "f64" => serve_cluster::<f64>(&cluster, &stream, &scfg)?,
        other => return Err(cuplss::Error::config(format!("dtype {other:?} (f32|f64)"))),
    };
    println!(
        "serve: {} requests, rhs-batch {}, batching {}",
        n_requests,
        scfg.rhs_batch,
        if scfg.batching { "on" } else { "off" }
    );
    println!("{}", report.summary());
    for o in &report.outcomes {
        println!(
            "  req {:>3} {:<9} n={:<6} batch {:>2}  arrived {}  finished {}  \
             latency {}  attributed {}",
            o.id,
            o.method,
            o.n,
            o.batch,
            fmt::secs(o.arrival),
            fmt::secs(o.finish),
            fmt::secs(o.latency()),
            fmt::secs(o.attributed_secs),
        );
        if o.deadline_missed {
            println!("           ^ missed its deadline");
        }
    }
    Ok(())
}

fn cmd_fig3(args: &Args) -> Result<()> {
    let n: usize = args.opt_or("n", bench_harness::PAPER_N)?;
    let iters: usize = args.opt_or("iters", 100)?;
    let tile: usize = args.opt_or("tile", cuplss::DEFAULT_TILE)?;
    let series = if args.has_flag("dp") {
        figures::fig3_series::<f64>(n, iters, tile)
    } else {
        figures::fig3_series::<f32>(n, iters, tile)
    };
    let label = if args.has_flag("dp") { "double" } else { "single" };
    println!(
        "{}",
        figures::render_table(
            &format!("Figure 3: iterative-solver speedup, n={n}, {label} precision"),
            &series
        )
    );
    Ok(())
}

fn cmd_fig4(args: &Args) -> Result<()> {
    let n: usize = args.opt_or("n", bench_harness::PAPER_N)?;
    let tile: usize = args.opt_or("tile", cuplss::DEFAULT_TILE)?;
    let chol = args.has_flag("cholesky");
    let series = if args.has_flag("dp") {
        figures::fig4_series::<f64>(n, tile, chol)
    } else {
        figures::fig4_series::<f32>(n, tile, chol)
    };
    let label = if args.has_flag("dp") { "double" } else { "single" };
    println!(
        "{}",
        figures::render_table(
            &format!("Figure 4: direct-solver speedup, n={n}, {label} precision"),
            &series
        )
    );
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let method = Method::parse(args.opt("method").unwrap_or("lu"))?;
    let workload = if matches!(method, Method::Cholesky) {
        Workload::Spd
    } else {
        Workload::DiagDominant
    };
    let tile: usize = args.opt_or("tile", 64)?;
    let points = calibrate::calibrate(method, workload, &[256, 512], &[1, 4], tile)?;
    println!("{}", calibrate::render(&points));
    println!("max ratio error: {:.2}x", calibrate::max_ratio_error(&points));
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args
        .opt("out")
        .unwrap_or(cuplss::runtime::DEFAULT_ARTIFACT_DIR)
        .to_string();
    println!("CUPLSS-RS — hybrid distributed linear algebra (paper reproduction)");
    println!("profiles:");
    for p in [ComputeProfile::gtx280_cublas(), ComputeProfile::q6600_atlas()] {
        println!(
            "  {:<14} SGEMM {}  DGEMM {}  mem {}/s  pcie {}",
            p.name,
            fmt::flops(p.flops3_sp),
            fmt::flops(p.flops3_dp),
            fmt::bytes(p.mem_bw),
            if p.pcie_bw > 0.0 { fmt::bytes(p.pcie_bw) + "/s" } else { "-".into() },
        );
    }
    match Runtime::new(&dir) {
        Ok(rt) => {
            println!("artifacts ({}): {} executables in manifest", dir, rt.manifest().len());
            let mut names: Vec<_> = rt.manifest().iter().map(|m| m.artifact.clone()).collect();
            names.sort();
            for chunk in names.chunks(4) {
                println!("  {}", chunk.join("  "));
            }
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    Ok(())
}
