//! PJRT execution of the AOT artifacts — the library's "CUDA runtime".
//!
//! One process-wide [`Runtime`] owns a PJRT CPU client and a lazily-populated
//! cache of compiled executables, keyed by artifact name.  Node threads share
//! it: the underlying `TfrtCpuClient` is thread-safe for compile/execute
//! (this is how jax drives it from multiple host threads), but the `xla`
//! crate's raw-pointer wrappers don't declare `Send`/`Sync`, so we provide a
//! justified `unsafe impl` on a private wrapper.  Compilation is serialised
//! behind a mutex; execution is lock-free.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex, OnceLock, RwLock};

use super::artifact::{ArtifactMeta, Manifest};
use crate::{Error, Result, Scalar};

/// `xla` crate objects wrap thread-safe C++ (PJRT CPU client / loaded
/// executables / immutable literals) in raw pointers without Send/Sync.
/// SAFETY: TfrtCpuClient's compile+execute are thread-safe; executables are
/// immutable after compilation; we never share `Literal`s across threads.
struct ShareableExe(xla::PjRtLoadedExecutable);
unsafe impl Send for ShareableExe {}
unsafe impl Sync for ShareableExe {}

struct ShareableClient(xla::PjRtClient);
unsafe impl Send for ShareableClient {}
unsafe impl Sync for ShareableClient {}

/// A compiled tile op, shareable across rank threads.
#[derive(Clone)]
pub struct Executable {
    exe: Arc<ShareableExe>,
    meta: ArtifactMeta,
}

impl Executable {
    /// The artifact metadata (shapes, flops).
    pub fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    /// Execute with `inputs` matching the artifact's declared shapes; returns
    /// the flattened output buffer.
    pub fn run<S: Scalar>(&self, inputs: &[&[S]]) -> Result<Vec<S>> {
        let metas = &self.meta.in_shapes;
        if inputs.len() != metas.len() {
            return Err(Error::runtime(format!(
                "{}: got {} inputs, expected {}",
                self.meta.artifact,
                inputs.len(),
                metas.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, shape) in inputs.iter().zip(metas) {
            let elems = ArtifactMeta::elems(shape);
            if buf.len() != elems {
                return Err(Error::runtime(format!(
                    "{}: input len {} != shape {:?}",
                    self.meta.artifact,
                    buf.len(),
                    shape
                )));
            }
            let bytes = unsafe {
                std::slice::from_raw_parts(buf.as_ptr() as *const u8, buf.len() * S::BYTES)
            };
            let lit = xla::Literal::create_from_shape_and_untyped_data(S::TY, shape, bytes)?;
            literals.push(lit);
        }
        let result = self.exe.0.execute::<xla::Literal>(&literals)?;
        let out = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True -> 1-tuple.
        let out = out.to_tuple1()?;
        Ok(out.to_vec::<S>()?)
    }
}

/// Process-wide PJRT runtime: client + executable cache.
pub struct Runtime {
    client: ShareableClient,
    manifest: Manifest,
    cache: RwLock<HashMap<String, Executable>>,
    compile_lock: Mutex<()>,
    dir: PathBuf,
}

impl Runtime {
    /// Create a runtime over an artifact directory (reads the manifest; the
    /// PJRT client starts immediately, executables compile on first use).
    pub fn new(artifact_dir: impl Into<PathBuf>) -> Result<Arc<Runtime>> {
        let dir = artifact_dir.into();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Arc::new(Runtime {
            client: ShareableClient(client),
            manifest,
            cache: RwLock::new(HashMap::new()),
            compile_lock: Mutex::new(()),
            dir,
        }))
    }

    /// The process-wide shared runtime for the default `artifacts/` dir
    /// (first call wins; later calls with a different dir error).
    pub fn global(artifact_dir: &str) -> Result<Arc<Runtime>> {
        static GLOBAL: OnceLock<std::result::Result<Arc<Runtime>, String>> = OnceLock::new();
        let r = GLOBAL.get_or_init(|| Runtime::new(artifact_dir).map_err(|e| e.to_string()));
        match r {
            Ok(rt) => Ok(rt.clone()),
            Err(e) => Err(Error::runtime(e.clone())),
        }
    }

    /// The manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Artifact directory.
    pub fn dir(&self) -> &std::path::Path {
        &self.dir
    }

    /// Get (compiling if needed) the executable for `artifact`.
    pub fn executable(&self, artifact: &str) -> Result<Executable> {
        if let Some(e) = self.cache.read().unwrap().get(artifact) {
            return Ok(e.clone());
        }
        // Compile outside the read lock; serialise compilation.
        let _guard = self.compile_lock.lock().unwrap();
        if let Some(e) = self.cache.read().unwrap().get(artifact) {
            return Ok(e.clone()); // raced
        }
        let meta = self
            .manifest
            .get(artifact)
            .ok_or_else(|| Error::runtime(format!("unknown artifact {artifact:?}")))?
            .clone();
        let proto = xla::HloModuleProto::from_text_file(&meta.path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.0.compile(&comp)?;
        let executable = Executable { exe: Arc::new(ShareableExe(exe)), meta };
        self.cache.write().unwrap().insert(artifact.to_string(), executable.clone());
        Ok(executable)
    }

    /// Get (compiling if needed) the executable for (op, dtype, tile).
    pub fn op<S: Scalar>(&self, op: &str, tile: usize) -> Result<Executable> {
        let name = format!("{op}_{}_{tile}", S::DTYPE);
        self.executable(&name)
    }

    /// Number of executables compiled so far.
    pub fn compiled_count(&self) -> usize {
        self.cache.read().unwrap().len()
    }
}
