//! Artifact manifest parsing.
//!
//! `make artifacts` writes `artifacts/manifest.txt` with one line per AOT
//! executable (see `python/compile/aot.py`):
//!
//! ```text
//! <artifact> <op> <dtype> <tile> <flops> <arity> <in0,in1,...> <out>
//! ```
//!
//! Shapes are `x`-separated dims, `s` for a rank-0 scalar.  The format is
//! deliberately dependency-free (the offline crate set has no serde).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::{Error, Result};

/// Metadata of one AOT-compiled tile op.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactMeta {
    /// Artifact file stem, e.g. `gemm_f32_256`.
    pub artifact: String,
    /// Op name, e.g. `gemm`.
    pub op: String,
    /// `f32` or `f64`.
    pub dtype: String,
    /// Tile edge the shapes are built from.
    pub tile: usize,
    /// Exact flop count of one invocation (cost-model input).
    pub flops: u64,
    /// Input shapes (empty vec = rank-0 scalar).
    pub in_shapes: Vec<Vec<usize>>,
    /// Output shape.
    pub out_shape: Vec<usize>,
    /// Absolute path of the `.hlo.txt` file.
    pub path: PathBuf,
}

impl ArtifactMeta {
    /// Number of inputs.
    pub fn arity(&self) -> usize {
        self.in_shapes.len()
    }

    /// Elements in a shape.
    pub fn elems(shape: &[usize]) -> usize {
        shape.iter().product()
    }

    /// Total input elements (host->device traffic per call).
    pub fn in_elems(&self) -> usize {
        self.in_shapes.iter().map(|s| Self::elems(s)).sum()
    }

    /// Output elements (device->host traffic per call).
    pub fn out_elems(&self) -> usize {
        Self::elems(&self.out_shape)
    }
}

fn parse_shape(s: &str) -> Result<Vec<usize>> {
    if s == "s" {
        return Ok(Vec::new());
    }
    s.split('x')
        .map(|d| {
            d.parse::<usize>()
                .map_err(|_| Error::runtime(format!("bad shape component {d:?}")))
        })
        .collect()
}

/// Parse one manifest line.
fn parse_line(dir: &Path, line: &str) -> Result<ArtifactMeta> {
    let parts: Vec<&str> = line.split_whitespace().collect();
    if parts.len() != 8 {
        return Err(Error::runtime(format!("manifest line has {} fields: {line:?}", parts.len())));
    }
    let arity: usize =
        parts[5].parse().map_err(|_| Error::runtime(format!("bad arity in {line:?}")))?;
    let in_shapes: Vec<Vec<usize>> =
        parts[6].split(',').map(parse_shape).collect::<Result<_>>()?;
    if in_shapes.len() != arity {
        return Err(Error::runtime(format!("arity mismatch in {line:?}")));
    }
    Ok(ArtifactMeta {
        artifact: parts[0].to_string(),
        op: parts[1].to_string(),
        dtype: parts[2].to_string(),
        tile: parts[3].parse().map_err(|_| Error::runtime("bad tile"))?,
        flops: parts[4].parse().map_err(|_| Error::runtime("bad flops"))?,
        in_shapes,
        out_shape: parse_shape(parts[7])?,
        path: dir.join(format!("{}.hlo.txt", parts[0])),
    })
}

/// The parsed manifest: artifact name -> metadata.
#[derive(Debug, Default)]
pub struct Manifest {
    entries: HashMap<String, ArtifactMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref();
        let text = std::fs::read_to_string(dir.join("manifest.txt")).map_err(|e| {
            Error::runtime(format!(
                "cannot read {}/manifest.txt (run `make artifacts` first): {e}",
                dir.display()
            ))
        })?;
        Self::parse(dir, &text)
    }

    /// Parse manifest text (artifact paths resolved against `dir`).
    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let mut entries = HashMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let meta = parse_line(dir, line)?;
            entries.insert(meta.artifact.clone(), meta);
        }
        Ok(Manifest { entries })
    }

    /// Look up by artifact name (`gemm_f32_256`).
    pub fn get(&self, artifact: &str) -> Option<&ArtifactMeta> {
        self.entries.get(artifact)
    }

    /// Look up by (op, dtype, tile).
    pub fn find(&self, op: &str, dtype: &str, tile: usize) -> Option<&ArtifactMeta> {
        self.entries.get(&format!("{op}_{dtype}_{tile}"))
    }

    /// Number of artifacts.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no artifacts are listed.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// All entries (arbitrary order).
    pub fn iter(&self) -> impl Iterator<Item = &ArtifactMeta> {
        self.entries.values()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
gemm_f32_256 gemm f32 256 33554432 2 256x256,256x256 256x256
axpy_f64_128 axpy f64 128 256 3 s,128,128 128
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.len(), 2);
        let g = m.get("gemm_f32_256").unwrap();
        assert_eq!(g.op, "gemm");
        assert_eq!(g.tile, 256);
        assert_eq!(g.flops, 33_554_432);
        assert_eq!(g.in_shapes, vec![vec![256, 256], vec![256, 256]]);
        assert_eq!(g.out_shape, vec![256, 256]);
        assert_eq!(g.path, Path::new("/tmp/a/gemm_f32_256.hlo.txt"));
        let a = m.find("axpy", "f64", 128).unwrap();
        assert_eq!(a.arity(), 3);
        assert_eq!(a.in_shapes[0], Vec::<usize>::new()); // scalar
        assert_eq!(a.in_elems(), 1 + 128 + 128);
        assert_eq!(a.out_elems(), 128);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse(Path::new("/"), "too few fields\n").is_err());
        assert!(Manifest::parse(Path::new("/"), "a b f32 256 1 1 1x1\n").is_err());
    }

    #[test]
    fn skips_comments_and_blanks() {
        let m = Manifest::parse(Path::new("/"), "# c\n\n").unwrap();
        assert!(m.is_empty());
    }
}
