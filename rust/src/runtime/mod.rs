//! The PJRT runtime (CUPLSS level 1, "CUDA runtime + CUBLAS" slot): loads
//! the HLO-text artifacts emitted by `python/compile/aot.py`, compiles them
//! once on the PJRT CPU client, and executes them from the rust request path.
//! Python never runs at solve time.

pub mod artifact;
pub mod executor;

pub use artifact::{ArtifactMeta, Manifest};
pub use executor::{Executable, Runtime};

/// Default artifact directory relative to the repo root.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";
