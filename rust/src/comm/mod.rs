//! The message-passing substrate: CUPLSS's MPI stand-in.
//!
//! The paper runs on a 16-workstation MPICH cluster over Gigabit Ethernet.
//! This module reproduces that *programming model* in-process:
//!
//! * a [`World`] of `P` ranks, one OS thread per rank;
//! * lossless, FIFO, typed point-to-point channels ([`transport`]);
//! * MPI-style collectives with the same algorithmic structure MPICH uses
//!   (binomial trees, recursive doubling — [`collectives`]);
//! * a **virtual clock** per rank ([`clock`]): local compute advances it via
//!   the engine cost models, and every message advances the receiver to
//!   `max(recv_clock, send_clock + α + β·bytes)` under a configurable network
//!   profile ([`model`]).  The parallel makespan is `max` over rank clocks —
//!   this is how the paper's wall-clock speedup curves are regenerated
//!   without 16 physical machines (DESIGN.md §3).
//!
//! Payloads really move between ranks, so every distributed algorithm is
//! genuinely message-passing; the virtual clock is bookkeeping on the side.
//!
//! Communication comes in two flavours (DESIGN.md §11):
//!
//! * **blocking** — `send`/`recv` and the plain collectives charge the full
//!   transfer to the caller's compute timeline, exactly as before;
//! * **split-phase** — [`Comm::isend`]/[`Comm::irecv`] and the
//!   `i`-collectives ([`transport::Group::ibcast`] and friends) return
//!   request handles; the transfer progresses on the rank's *network*
//!   timeline while the caller computes, and `wait` charges only the
//!   latency compute did not cover.  The hot paths (pipelined SUMMA,
//!   lookahead LU/Cholesky, split-phase `pspmv`, pipelined CG) are built on
//!   these.

pub mod clock;
pub mod collectives;
pub mod faults;
pub mod message;
pub mod model;
pub mod neighbor;
pub mod transport;

pub use clock::VClock;
pub use faults::{CheckpointPolicy, FaultEvent, FaultPlan};
pub use message::{Payload, Tag};
pub use model::NetworkModel;
pub use collectives::{AllgatherRequest, AllreduceRequest, BcastRequest, ReduceOp};
pub use neighbor::NeighborExchange;
pub use transport::{Comm, CommStats, Group, RecvRequest, SendRequest, World};
