//! Network cost model (the "Gigabit Ethernet" of the simulated cluster).
//!
//! A postal / alpha-beta model: a point-to-point message of `b` bytes from
//! rank `s` to rank `d` arrives at
//!
//! ```text
//!     t_arrive = t_send + alpha + b * beta        (s != d)
//!     t_arrive = t_send + alpha_local             (s == d, loopback)
//! ```
//!
//! MPICH's collectives decompose into point-to-point rounds, so modelling the
//! p2p cost and letting the collectives emit real messages reproduces the
//! `log P` scaling terms without a separate collective model.

/// Alpha-beta network profile.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NetworkModel {
    /// Per-message latency, seconds (MPI stack + switch + NIC).
    pub alpha: f64,
    /// Per-byte cost, seconds (inverse effective bandwidth).
    pub beta: f64,
    /// Loopback (same-rank) per-message cost, seconds.
    pub alpha_local: f64,
}

impl NetworkModel {
    /// The paper's testbed: "standard Gigabit LAN", MPICH.
    /// ~50 µs MPI p2p latency; 1 Gb/s ≈ 117 MiB/s effective ≈ 8.5 ns/B.
    pub fn gigabit_ethernet() -> Self {
        NetworkModel { alpha: 50e-6, beta: 8.5e-9, alpha_local: 0.5e-6 }
    }

    /// A much faster interconnect (for ablation E4: how much of the lost
    /// speedup is network?).  ~2 µs latency, ~25 Gb/s.
    pub fn fast_interconnect() -> Self {
        NetworkModel { alpha: 2e-6, beta: 0.32e-9, alpha_local: 0.2e-6 }
    }

    /// Zero-cost network (upper bound / algorithmic-overhead-only runs).
    pub fn ideal() -> Self {
        NetworkModel { alpha: 0.0, beta: 0.0, alpha_local: 0.0 }
    }

    /// Transfer time for `bytes` between distinct ranks.
    pub fn p2p_secs(&self, bytes: usize) -> f64 {
        self.alpha + bytes as f64 * self.beta
    }

    /// Transfer time for a loopback message.
    pub fn local_secs(&self, _bytes: usize) -> f64 {
        self.alpha_local
    }

    /// Cost of a message from `src` to `dst`.
    pub fn msg_secs(&self, src: usize, dst: usize, bytes: usize) -> f64 {
        if src == dst { self.local_secs(bytes) } else { self.p2p_secs(bytes) }
    }
}

impl Default for NetworkModel {
    fn default() -> Self {
        Self::gigabit_ethernet()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gigabit_magnitudes() {
        let m = NetworkModel::gigabit_ethernet();
        // 1 MiB at ~117 MiB/s ≈ 8.9 ms; plus 50 µs latency.
        let t = m.p2p_secs(1 << 20);
        assert!(t > 8e-3 && t < 10e-3, "t={t}");
        // Tiny message dominated by latency.
        let t0 = m.p2p_secs(8);
        assert!((t0 - 50e-6).abs() < 1e-6);
    }

    #[test]
    fn loopback_cheaper() {
        let m = NetworkModel::gigabit_ethernet();
        assert!(m.msg_secs(3, 3, 1 << 20) < m.msg_secs(3, 4, 1 << 20));
    }

    #[test]
    fn ordering_of_profiles() {
        let slow = NetworkModel::gigabit_ethernet();
        let fast = NetworkModel::fast_interconnect();
        let ideal = NetworkModel::ideal();
        let b = 1 << 16;
        assert!(slow.p2p_secs(b) > fast.p2p_secs(b));
        assert!(fast.p2p_secs(b) > ideal.p2p_secs(b));
        assert_eq!(ideal.p2p_secs(b), 0.0);
    }
}
