//! Per-rank virtual clock (Lamport-style timestamp propagation).
//!
//! Each rank owns a `VClock`.  Local compute advances it by the engine cost
//! model's estimate; receiving a message advances it to the message's arrival
//! time if that is later.  Because every distributed algorithm in this crate
//! is deterministic message passing, the resulting `max` over rank clocks is
//! exactly the makespan a real cluster with those compute/network costs would
//! see — this is the quantity the paper's Figures 3/4 plot (via speedup).
//!
//! The clock also accumulates a breakdown (compute vs communication wait vs
//! accelerator transfer) used by the bench reports.

use std::cell::Cell;

/// Virtual time accounting for one rank.  Single-threaded by design: each
/// rank thread owns its clock (interior mutability avoids `&mut` plumbing
/// through the solver call trees).
#[derive(Debug, Default)]
pub struct VClock {
    now: Cell<f64>,
    compute: Cell<f64>,
    comm_wait: Cell<f64>,
    xfer: Cell<f64>,
}

impl VClock {
    /// A clock at t = 0.
    pub fn new() -> Self {
        Self::default()
    }

    /// Current virtual time (seconds).
    pub fn now(&self) -> f64 {
        self.now.get()
    }

    /// Advance by a local-compute interval.
    pub fn advance_compute(&self, dt: f64) {
        debug_assert!(dt >= 0.0, "negative compute interval {dt}");
        self.now.set(self.now.get() + dt);
        self.compute.set(self.compute.get() + dt);
    }

    /// Advance by a host<->accelerator transfer interval (the PCIe term of
    /// the GPU engine cost model; tracked separately because the paper calls
    /// this out as the reason the CUDA gain is modest).
    pub fn advance_transfer(&self, dt: f64) {
        debug_assert!(dt >= 0.0);
        self.now.set(self.now.get() + dt);
        self.xfer.set(self.xfer.get() + dt);
    }

    /// Advance by a send-side occupancy interval (LogGP's `G·bytes`: the
    /// NIC serialises outgoing bytes at line rate, so a burst of sends from
    /// one rank cannot overlap — accounted as communication time).
    pub fn advance_send(&self, dt: f64) {
        debug_assert!(dt >= 0.0);
        self.now.set(self.now.get() + dt);
        self.comm_wait.set(self.comm_wait.get() + dt);
    }

    /// Observe a message that arrives at absolute virtual time `arrival`:
    /// the rank blocks until then if it is early (that blocked interval is
    /// communication wait).
    pub fn observe_arrival(&self, arrival: f64) {
        let now = self.now.get();
        if arrival > now {
            self.comm_wait.set(self.comm_wait.get() + (arrival - now));
            self.now.set(arrival);
        }
    }

    /// Jump to at least `t` without attributing the interval (used by
    /// barrier-like synchronisation points).
    pub fn sync_to(&self, t: f64) {
        self.observe_arrival(t);
    }

    /// Total virtual seconds attributed to local compute.
    pub fn compute_secs(&self) -> f64 {
        self.compute.get()
    }

    /// Total virtual seconds spent blocked on messages.
    pub fn comm_wait_secs(&self) -> f64 {
        self.comm_wait.get()
    }

    /// Total virtual seconds of host<->accelerator transfer.
    pub fn transfer_secs(&self) -> f64 {
        self.xfer.get()
    }

    /// Reset to t = 0 (between bench repetitions).
    pub fn reset(&self) {
        self.now.set(0.0);
        self.compute.set(0.0);
        self.comm_wait.set(0.0);
        self.xfer.set(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advance_and_breakdown() {
        let c = VClock::new();
        c.advance_compute(1.0);
        c.advance_transfer(0.25);
        assert!((c.now() - 1.25).abs() < 1e-12);
        assert_eq!(c.compute_secs(), 1.0);
        assert_eq!(c.transfer_secs(), 0.25);
    }

    #[test]
    fn arrival_in_future_blocks() {
        let c = VClock::new();
        c.advance_compute(1.0);
        c.observe_arrival(3.0);
        assert_eq!(c.now(), 3.0);
        assert_eq!(c.comm_wait_secs(), 2.0);
    }

    #[test]
    fn arrival_in_past_is_free() {
        let c = VClock::new();
        c.advance_compute(5.0);
        c.observe_arrival(3.0);
        assert_eq!(c.now(), 5.0);
        assert_eq!(c.comm_wait_secs(), 0.0);
    }

    #[test]
    fn reset_clears() {
        let c = VClock::new();
        c.advance_compute(1.0);
        c.observe_arrival(9.0);
        c.reset();
        assert_eq!(c.now(), 0.0);
        assert_eq!(c.compute_secs(), 0.0);
        assert_eq!(c.comm_wait_secs(), 0.0);
    }
}
