//! Per-rank virtual clock (Lamport-style timestamp propagation) with **three
//! overlappable timelines**.
//!
//! Each rank owns a `VClock`.  Local compute advances the *compute* timeline
//! (`now`) by the engine cost model's estimate; receiving a message advances
//! it to the message's arrival time if that is later.  Because every
//! distributed algorithm in this crate is deterministic message passing, the
//! resulting `max` over rank clocks is exactly the makespan a real cluster
//! with those compute/network costs would see — this is the quantity the
//! paper's Figures 3/4 plot (via speedup).
//!
//! The second timeline is the **NIC** (`nic_free`): outgoing bytes serialise
//! at line rate on the rank's network interface, but — as on a real cluster
//! with non-blocking MPI — that serialisation proceeds *while the CPU
//! computes*.  A blocking send advances the compute timeline to the end of
//! the NIC occupancy (the old fully-synchronous behaviour); a split-phase
//! `isend` only occupies the NIC timeline and leaves `now` untouched, so the
//! occupancy is hidden unless the rank later has to wait for it.  `wait` on
//! an in-flight message charges only the *remaining* latency: the part of
//! the transfer that did not fit under the compute performed since the
//! request was posted (DESIGN.md §11).
//!
//! The third timeline is the **copy engine** (`pcie_free`): real CUDA
//! devices have dedicated DMA engines, so host<->device transfers can
//! stream while the SMs compute.  A blocking transfer still advances the
//! compute timeline ([`VClock::advance_transfer`], the paper's §3
//! semantics); an *asynchronous* transfer ([`VClock::pcie_occupy`]) only
//! occupies the copy-engine timeline, and [`VClock::pcie_wait`] at use time
//! charges only the latency compute did not cover — same occupy/block/wait
//! discipline as the NIC, applied to PCIe (DESIGN.md §13).
//!
//! The clock also accumulates a breakdown (compute vs communication wait vs
//! accelerator transfer) used by the bench reports.

use std::cell::Cell;

/// Virtual time accounting for one rank.  Single-threaded by design: each
/// rank thread owns its clock (interior mutability avoids `&mut` plumbing
/// through the solver call trees).
#[derive(Debug)]
pub struct VClock {
    now: Cell<f64>,
    /// When this rank's NIC finishes serialising everything queued so far.
    /// Always `>= 0`; may run ahead of `now` while isends are in flight.
    nic_free: Cell<f64>,
    /// When this rank's copy engine finishes every queued async transfer.
    /// Like `nic_free`, may run ahead of `now` while prefetches / flushes
    /// are in flight.
    pcie_free: Cell<f64>,
    compute: Cell<f64>,
    comm_wait: Cell<f64>,
    xfer: Cell<f64>,
    /// Compute-rate multiplier: a straggler rank ([`crate::comm::faults::
    /// FaultEvent::Straggler`]) advances `rate×` slower per unit of work.
    /// 1.0 (an IEEE-exact identity) everywhere else; survives `reset`
    /// because it is a property of the rank, not of the run.
    rate: Cell<f64>,
}

impl Default for VClock {
    fn default() -> Self {
        Self {
            now: Cell::new(0.0),
            nic_free: Cell::new(0.0),
            pcie_free: Cell::new(0.0),
            compute: Cell::new(0.0),
            comm_wait: Cell::new(0.0),
            xfer: Cell::new(0.0),
            rate: Cell::new(1.0),
        }
    }
}

impl VClock {
    /// A clock at t = 0.
    pub fn new() -> Self {
        Self::default()
    }

    /// Current virtual time on the compute timeline (seconds).
    pub fn now(&self) -> f64 {
        self.now.get()
    }

    /// When the NIC timeline drains (>= `now` only while sends are queued).
    pub fn nic_free(&self) -> f64 {
        self.nic_free.get()
    }

    /// When the copy-engine timeline drains (>= `now` only while async
    /// transfers are queued).
    pub fn pcie_free(&self) -> f64 {
        self.pcie_free.get()
    }

    /// The instant this rank is completely idle: compute done, NIC drained
    /// *and* copy engine drained.  This is what the makespan aggregation
    /// reads — a rank whose last act was an isend (or an async write-back)
    /// is still busy until the bytes leave the wire / the link.
    pub fn busy_until(&self) -> f64 {
        self.now.get().max(self.nic_free.get()).max(self.pcie_free.get())
    }

    /// Advance by a local-compute interval (scaled by the rank's
    /// compute-rate multiplier — `× 1.0` exactly on non-straggler ranks).
    pub fn advance_compute(&self, dt: f64) {
        debug_assert!(dt >= 0.0, "negative compute interval {dt}");
        let dt = dt * self.rate.get();
        self.now.set(self.now.get() + dt);
        self.compute.set(self.compute.get() + dt);
    }

    /// Set the straggler compute-rate multiplier (>= 1 slows the rank
    /// down).  `1.0` is the exact identity.
    pub fn set_compute_rate(&self, rate: f64) {
        debug_assert!(rate > 0.0, "non-positive compute rate {rate}");
        self.rate.set(rate);
    }

    /// The straggler compute-rate multiplier in force.
    pub fn compute_rate(&self) -> f64 {
        self.rate.get()
    }

    /// Advance by a host<->accelerator transfer interval (the PCIe term of
    /// the GPU engine cost model; tracked separately because the paper calls
    /// this out as the reason the CUDA gain is modest).
    pub fn advance_transfer(&self, dt: f64) {
        debug_assert!(dt >= 0.0);
        self.now.set(self.now.get() + dt);
        self.xfer.set(self.xfer.get() + dt);
    }

    /// Occupy the NIC timeline for `dt` seconds starting no earlier than
    /// `at` (and never before previously queued traffic).  Returns the
    /// occupancy's end time — the instant the last byte leaves the wire.
    /// Does **not** advance the compute timeline: this is the split-phase
    /// half of a send.
    pub fn nic_occupy_from(&self, at: f64, dt: f64) -> f64 {
        debug_assert!(dt >= 0.0);
        let start = self.nic_free.get().max(at);
        let end = start + dt;
        self.nic_free.set(end);
        end
    }

    /// Occupy the NIC starting from the current compute time.
    pub fn nic_occupy(&self, dt: f64) -> f64 {
        self.nic_occupy_from(self.now.get(), dt)
    }

    /// Occupy the copy-engine timeline for `dt` seconds starting no earlier
    /// than the current compute time (and never before previously queued
    /// async transfers).  Returns the occupancy's end time — the instant
    /// the transfer lands.  Does **not** advance the compute timeline: this
    /// is the split-phase half of an async H2D prefetch or D2H write-back.
    pub fn pcie_occupy(&self, dt: f64) -> f64 {
        self.pcie_occupy_from(self.now.get(), dt)
    }

    /// Occupy the copy-engine timeline starting no earlier than `at`.
    pub fn pcie_occupy_from(&self, at: f64, dt: f64) -> f64 {
        debug_assert!(dt >= 0.0);
        let start = self.pcie_free.get().max(at);
        let end = start + dt;
        self.pcie_free.set(end);
        end
    }

    /// Block the compute timeline until an async transfer queued on the
    /// copy engine has landed (its `pcie_occupy` end time): charges only the
    /// *remaining* latency — the part of the transfer that did not fit under
    /// the compute performed since it was issued — attributed to the
    /// host<->device transfer breakdown, like a blocking transfer would be.
    pub fn pcie_wait(&self, ready: f64) {
        let now = self.now.get();
        if ready > now {
            self.xfer.set(self.xfer.get() + (ready - now));
            self.now.set(ready);
        }
    }

    /// Advance by a send-side occupancy interval (LogGP's `G·bytes`) on the
    /// *blocking* path: the occupancy is queued on the NIC timeline and the
    /// compute timeline blocks until it drains — accounted as communication
    /// time, exactly the old fully-synchronous semantics when no isends are
    /// outstanding.
    pub fn advance_send(&self, dt: f64) {
        let end = self.nic_occupy(dt);
        self.observe_arrival(end);
    }

    /// GPUDirect send of a device-resident payload: the NIC reads device
    /// memory through the PCIe switch, so the two engines are occupied
    /// **jointly** — the transfer starts once *both* timelines are free
    /// (and no earlier than `at`), then each advances by its own leg
    /// (`nic_dt` on the wire, `pcie_dt` on the link).  Returns the instant
    /// the last byte leaves the wire.  Does **not** advance the compute
    /// timeline: there is no host staging copy to block on (DESIGN.md §16).
    pub fn wire_occupy_from(&self, at: f64, nic_dt: f64, pcie_dt: f64) -> f64 {
        debug_assert!(nic_dt >= 0.0 && pcie_dt >= 0.0);
        let start = self.nic_free.get().max(self.pcie_free.get()).max(at);
        self.nic_free.set(start + nic_dt);
        self.pcie_free.set(start + pcie_dt);
        start + nic_dt.max(pcie_dt)
    }

    /// Observe a message that arrives at absolute virtual time `arrival`:
    /// the rank blocks until then if it is early (that blocked interval is
    /// communication wait — the *remaining* latency of an overlapped
    /// transfer, or the whole latency of a blocking one).
    pub fn observe_arrival(&self, arrival: f64) {
        let now = self.now.get();
        if arrival > now {
            self.comm_wait.set(self.comm_wait.get() + (arrival - now));
            self.now.set(arrival);
        }
    }

    /// Jump to at least `t` without attributing the interval (used by
    /// barrier-like synchronisation points).
    pub fn sync_to(&self, t: f64) {
        self.observe_arrival(t);
    }

    /// Total virtual seconds attributed to local compute.
    pub fn compute_secs(&self) -> f64 {
        self.compute.get()
    }

    /// Total virtual seconds spent blocked on messages.
    pub fn comm_wait_secs(&self) -> f64 {
        self.comm_wait.get()
    }

    /// Total virtual seconds of host<->accelerator transfer.
    pub fn transfer_secs(&self) -> f64 {
        self.xfer.get()
    }

    /// Reset to t = 0 (between bench repetitions).  The compute-rate
    /// multiplier is a rank property, not run state, and survives.
    pub fn reset(&self) {
        self.now.set(0.0);
        self.nic_free.set(0.0);
        self.pcie_free.set(0.0);
        self.compute.set(0.0);
        self.comm_wait.set(0.0);
        self.xfer.set(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn advance_and_breakdown() {
        let c = VClock::new();
        c.advance_compute(1.0);
        c.advance_transfer(0.25);
        assert!((c.now() - 1.25).abs() < 1e-12);
        assert_eq!(c.compute_secs(), 1.0);
        assert_eq!(c.transfer_secs(), 0.25);
    }

    #[test]
    fn arrival_in_future_blocks() {
        let c = VClock::new();
        c.advance_compute(1.0);
        c.observe_arrival(3.0);
        assert_eq!(c.now(), 3.0);
        assert_eq!(c.comm_wait_secs(), 2.0);
    }

    #[test]
    fn arrival_in_past_is_free() {
        let c = VClock::new();
        c.advance_compute(5.0);
        c.observe_arrival(3.0);
        assert_eq!(c.now(), 5.0);
        assert_eq!(c.comm_wait_secs(), 0.0);
    }

    #[test]
    fn blocking_send_still_charges_full_occupancy() {
        // The legacy semantics: with nothing queued, advance_send moves the
        // compute timeline by exactly dt and attributes it to comm.
        let c = VClock::new();
        c.advance_compute(1.0);
        c.advance_send(0.5);
        assert!((c.now() - 1.5).abs() < 1e-12);
        assert!((c.comm_wait_secs() - 0.5).abs() < 1e-12);
        assert_eq!(c.nic_free(), c.now());
    }

    #[test]
    fn isend_occupancy_is_hidden_behind_compute() {
        let c = VClock::new();
        let end = c.nic_occupy(0.5); // split-phase: now untouched
        assert_eq!(end, 0.5);
        assert_eq!(c.now(), 0.0);
        assert_eq!(c.busy_until(), 0.5);
        c.advance_compute(2.0); // compute runs past the occupancy
        assert_eq!(c.busy_until(), 2.0);
        assert_eq!(c.comm_wait_secs(), 0.0);
    }

    #[test]
    fn queued_isends_serialise_on_the_nic() {
        let c = VClock::new();
        assert_eq!(c.nic_occupy(0.25), 0.25);
        assert_eq!(c.nic_occupy(0.25), 0.5); // back-to-back: queued
        c.advance_compute(1.0);
        assert_eq!(c.nic_occupy(0.25), 1.25); // NIC idle since 0.5: restarts at now
        // A blocking send behind a busy NIC waits for the queue to drain.
        c.advance_send(0.25);
        assert!((c.now() - 1.5).abs() < 1e-12);
        assert!((c.comm_wait_secs() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn reset_clears() {
        let c = VClock::new();
        c.advance_compute(1.0);
        c.nic_occupy(4.0);
        c.pcie_occupy(2.0);
        c.observe_arrival(9.0);
        c.reset();
        assert_eq!(c.now(), 0.0);
        assert_eq!(c.nic_free(), 0.0);
        assert_eq!(c.pcie_free(), 0.0);
        assert_eq!(c.compute_secs(), 0.0);
        assert_eq!(c.comm_wait_secs(), 0.0);
    }

    #[test]
    fn straggler_rate_scales_compute_and_survives_reset() {
        let c = VClock::new();
        assert_eq!(c.compute_rate(), 1.0);
        c.set_compute_rate(1.5);
        c.advance_compute(2.0);
        assert!((c.now() - 3.0).abs() < 1e-12);
        assert!((c.compute_secs() - 3.0).abs() < 1e-12);
        c.reset();
        assert_eq!(c.now(), 0.0);
        assert_eq!(c.compute_rate(), 1.5); // rank property: survives reset
        // The NIC and copy-engine timelines are unaffected by stragglers.
        assert_eq!(c.nic_occupy(0.5), 0.5);
    }

    #[test]
    fn async_transfer_hides_behind_compute() {
        let c = VClock::new();
        let ready = c.pcie_occupy(0.5); // issue: compute timeline untouched
        assert_eq!(ready, 0.5);
        assert_eq!(c.now(), 0.0);
        assert_eq!(c.busy_until(), 0.5);
        c.advance_compute(2.0); // compute runs past the transfer
        c.pcie_wait(ready); // fully hidden: zero remaining latency
        assert_eq!(c.now(), 2.0);
        assert_eq!(c.transfer_secs(), 0.0);
    }

    #[test]
    fn async_transfer_waited_early_charges_only_the_remainder() {
        let c = VClock::new();
        let ready = c.pcie_occupy(1.0);
        c.advance_compute(0.25);
        c.pcie_wait(ready); // 0.75 of the transfer did not hide
        assert!((c.now() - 1.0).abs() < 1e-12);
        assert!((c.transfer_secs() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn queued_async_transfers_serialise_on_the_copy_engine() {
        let c = VClock::new();
        assert_eq!(c.pcie_occupy(0.25), 0.25);
        assert_eq!(c.pcie_occupy(0.25), 0.5); // back-to-back: queued
        c.advance_compute(1.0);
        assert_eq!(c.pcie_occupy(0.25), 1.25); // engine idle since 0.5: restarts at now
        // The copy engine and the NIC are independent timelines.
        c.nic_occupy(10.0);
        assert_eq!(c.pcie_free(), 1.25);
        assert_eq!(c.busy_until(), 11.0);
    }

    #[test]
    fn wire_occupy_couples_nic_and_copy_engine_jointly() {
        let c = VClock::new();
        // Pre-queue unequal backlogs on the two engines.
        c.nic_occupy(0.5);
        c.pcie_occupy(1.0);
        // Joint start = max of both frees; each leg advances its own
        // timeline; the compute timeline is untouched.
        let end = c.wire_occupy_from(0.0, 0.25, 0.75);
        assert!((end - 1.75).abs() < 1e-12, "{end}");
        assert!((c.nic_free() - 1.25).abs() < 1e-12);
        assert!((c.pcie_free() - 1.75).abs() < 1e-12);
        assert_eq!(c.now(), 0.0);
        assert_eq!(c.compute_secs(), 0.0);
        assert_eq!(c.transfer_secs(), 0.0);
        assert_eq!(c.comm_wait_secs(), 0.0);
        // `at` later than both frees delays the joint start.
        let end2 = c.wire_occupy_from(3.0, 0.5, 0.25);
        assert!((end2 - 3.5).abs() < 1e-12, "{end2}");
        assert!((c.nic_free() - 3.5).abs() < 1e-12);
        assert!((c.pcie_free() - 3.25).abs() < 1e-12);
    }

    /// The overlap-clock property the bench reports rely on, extended to
    /// **three** timelines: replay one random trace of compute intervals,
    /// sends, message arrivals and host<->device transfers in (a) blocking
    /// mode (sends via `advance_send`, transfers via `advance_transfer`)
    /// and (b) overlapped mode (sends via `nic_occupy`, transfers via
    /// `pcie_occupy` + `pcie_wait` a few events later).  Then, per rank:
    ///
    /// * `max(compute, send occupancy, transfer occupancy) <= overlapped
    ///   makespan` (each timeline is a lower bound),
    /// * `overlapped makespan <= compute + comm + transfer` (full
    ///   serialisation is the worst case), and
    /// * the overlapped makespan never exceeds the blocking one.
    #[test]
    fn overlap_never_loses_and_is_bounded_on_three_timelines() {
        forall(200, 0xc10c, |rng| {
            let blocking = VClock::new();
            let overlapped = VClock::new();
            let mut total_compute = 0.0f64;
            let mut total_send = 0.0f64;
            let mut total_xfer = 0.0f64;
            let mut total_comm_blocking = 0.0f64;
            // Async transfers outstanding on the overlapped clock, waited
            // lazily (a later event or the end of the trace).
            let mut pending: Vec<f64> = Vec::new();
            let n_events = 1 + rng.below(40);
            for _ in 0..n_events {
                match rng.below(6) {
                    0 => {
                        let dt = rng.uniform() * 2.0;
                        blocking.advance_compute(dt);
                        overlapped.advance_compute(dt);
                        total_compute += dt;
                    }
                    1 => {
                        let dt = rng.uniform();
                        blocking.advance_send(dt);
                        overlapped.nic_occupy(dt);
                        total_send += dt;
                        total_comm_blocking += dt;
                    }
                    2 => {
                        let dt = rng.uniform() * 0.5;
                        blocking.advance_transfer(dt);
                        pending.push(overlapped.pcie_occupy(dt));
                        total_xfer += dt;
                    }
                    3 => {
                        if let Some(ready) = pending.pop() {
                            overlapped.pcie_wait(ready);
                        }
                    }
                    4 => {
                        // An externally-stamped arrival: same absolute time
                        // observed by both replays (identical trace).
                        let arr = rng.uniform() * 10.0;
                        let before = blocking.now();
                        blocking.observe_arrival(arr);
                        total_comm_blocking += (arr - before).max(0.0);
                        overlapped.observe_arrival(arr);
                    }
                    _ => {
                        // A device-payload send: the blocking replay stages
                        // through the host (D2H on the compute timeline,
                        // then a blocking send); the overlapped replay hands
                        // the buffer straight to the NIC — joint occupancy,
                        // no compute charge.
                        let nic_dt = rng.uniform();
                        let pcie_dt = rng.uniform() * 0.5;
                        blocking.advance_transfer(pcie_dt);
                        blocking.advance_send(nic_dt);
                        overlapped.wire_occupy_from(overlapped.now(), nic_dt, pcie_dt);
                        total_send += nic_dt;
                        total_xfer += pcie_dt;
                        total_comm_blocking += nic_dt;
                    }
                }
            }
            for ready in pending.drain(..) {
                overlapped.pcie_wait(ready);
            }
            let ms_over = overlapped.busy_until();
            let ms_block = blocking.busy_until();
            let eps = 1e-12;
            assert!(
                total_compute.max(total_send).max(total_xfer) <= ms_over + eps,
                "lower bound: max({total_compute}, {total_send}, {total_xfer}) vs {ms_over}"
            );
            assert!(
                ms_over <= total_compute + total_comm_blocking + total_xfer + eps,
                "upper bound: {ms_over} vs \
                 {total_compute} + {total_comm_blocking} + {total_xfer}"
            );
            assert!(
                ms_over <= ms_block + eps,
                "overlap must never lose: {ms_over} vs blocking {ms_block}"
            );
            // Breakdown is preserved: compute attribution identical in
            // both, and the overlapped transfer charge never exceeds the
            // blocking one (waits charge only the remainder).
            assert!((overlapped.compute_secs() - total_compute).abs() < 1e-9);
            assert!((blocking.compute_secs() - total_compute).abs() < 1e-9);
            assert!(overlapped.transfer_secs() <= blocking.transfer_secs() + eps);
        });
    }
}
