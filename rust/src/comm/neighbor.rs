//! Point-to-point neighbor exchange — the sparse halo's communication
//! primitive (`DESIGN.md` §15).
//!
//! A [`NeighborExchange`] posts one split-phase receive per expected
//! neighbor and one split-phase send per outgoing ghost segment, then
//! returns immediately so the caller can compute under the exchange (the
//! halo `pspmv`'s diagonal-block pass).  [`NeighborExchange::wait`] drains
//! the receives (charging only latency compute did not cover, exactly like
//! the `i`-collectives) and then retires the sends.
//!
//! Unlike the collectives there is no fixed algorithmic shape: the peer
//! sets come from data (a [`crate::sparse::HaloPlan`]'s send/recv lists),
//! may be empty (interior ranks of a 1-D stencil chain talk to at most two
//! neighbors; a rank whose columns are all local talks to nobody), and are
//! in general asymmetric per direction.  What *is* fixed is the wire
//! discipline: every posted message really moves through the transport and
//! charges the NIC timeline `alpha + beta * bytes`, so the cost model's
//! O(surface) halo terms are measuring the same machinery the allgather
//! path does — just with far fewer bytes on it.

use super::message::{Payload, Tag};
use super::transport::{Group, RecvRequest, SendRequest};
use crate::Scalar;

/// An in-flight neighbor exchange over a [`Group`]: ghost segments out to
/// each send-neighbor, one segment expected back from each recv-neighbor.
pub struct NeighborExchange<'a, S: Scalar> {
    recvs: Vec<(usize, RecvRequest<'a, S>)>,
    sends: Vec<SendRequest<'a, S>>,
}

impl<'a, S: Scalar> NeighborExchange<'a, S> {
    /// Start the exchange: post a receive from every group rank in
    /// `incoming`, then send each `(group rank, segment)` of `outgoing`.
    /// Receives are posted before any send so a symmetric exchange never
    /// deadlocks regardless of peer order; self-loops are a caller bug
    /// (a halo never ships locally-owned data).
    pub fn start(
        group: &Group<'a, S>,
        tag: u32,
        outgoing: Vec<(usize, Vec<S>)>,
        incoming: &[usize],
    ) -> Self {
        let me = group.rank();
        let recvs = incoming
            .iter()
            .map(|&src| {
                assert_ne!(src, me, "neighbor exchange: receive from self");
                (src, group.irecv(src, Tag::P2p(tag)))
            })
            .collect();
        let sends = outgoing
            .into_iter()
            .map(|(dst, data)| {
                assert_ne!(dst, me, "neighbor exchange: send to self");
                group.isend(dst, Tag::P2p(tag), Payload::Data(data))
            })
            .collect();
        NeighborExchange { recvs, sends }
    }

    /// Start the exchange with device-wire sends: identical to
    /// [`NeighborExchange::start`] (same receives, same message order, same
    /// payloads), but each outgoing segment goes through
    /// [`crate::comm::Comm::isend_wire`] with `pcie_secs` as its D2H leg —
    /// under GPUDirect, the sparse interface bytes never touch the host.
    /// With `pcie_secs <= 0` (host engine, GPUDirect off) this **is**
    /// [`NeighborExchange::start`].
    pub fn start_wire(
        group: &Group<'a, S>,
        tag: u32,
        outgoing: Vec<(usize, Vec<S>, f64)>,
        incoming: &[usize],
    ) -> Self {
        let me = group.rank();
        let recvs = incoming
            .iter()
            .map(|&src| {
                assert_ne!(src, me, "neighbor exchange: receive from self");
                (src, group.irecv(src, Tag::P2p(tag)))
            })
            .collect();
        let sends = outgoing
            .into_iter()
            .map(|(dst, data, pcie_secs)| {
                assert_ne!(dst, me, "neighbor exchange: send to self");
                group.isend_wire(dst, Tag::P2p(tag), Payload::Data(data), pcie_secs)
            })
            .collect();
        NeighborExchange { recvs, sends }
    }

    /// Complete the exchange: wait every receive (in posted order),
    /// then retire the sends.  Returns `(group rank, segment)` per
    /// incoming neighbor, in the order `incoming` was given.
    pub fn wait(self) -> Vec<(usize, Vec<S>)> {
        let received: Vec<(usize, Vec<S>)> = self
            .recvs
            .into_iter()
            .map(|(src, req)| (src, req.wait().into_data()))
            .collect();
        for s in self.sends {
            s.wait();
        }
        received
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{NetworkModel, World};

    #[test]
    fn ring_exchange_delivers_each_segment() {
        // 3 ranks, each sends its rank-stamped segment to the next and
        // expects one from the previous.
        let out = World::run::<f64, _, _>(3, NetworkModel::ideal(), |comm| {
            let g = comm.world();
            let me = g.rank();
            let p = g.size();
            let next = (me + 1) % p;
            let prev = (me + p - 1) % p;
            let seg = vec![me as f64; 4];
            let ex = NeighborExchange::start(&g, 7, vec![(next, seg)], &[prev]);
            let got = ex.wait();
            assert_eq!(got.len(), 1);
            assert_eq!(got[0].0, prev);
            got[0].1.clone()
        });
        for (me, seg) in out.iter().enumerate() {
            let prev = (me + 3 - 1) % 3;
            assert_eq!(seg, &vec![prev as f64; 4]);
        }
    }

    #[test]
    fn empty_exchange_is_a_no_op() {
        // A rank with no neighbors posts nothing and never blocks — and the
        // wire stays silent.
        let out = World::run::<f64, _, _>(2, NetworkModel::gigabit_ethernet(), |comm| {
            let g = comm.world();
            let ex = NeighborExchange::start(&g, 9, Vec::new(), &[]);
            assert!(ex.wait().is_empty());
            comm.stats().bytes_sent()
        });
        assert!(out.iter().all(|&b| b == 0), "no ghost traffic expected: {out:?}");
    }

    #[test]
    fn wire_exchange_delivers_identically_and_occupies_the_copy_engine() {
        // Same ring as above, over the device wire: payloads identical,
        // and each sender's copy engine carries exactly its ghost leg.
        let pcie = 1e-3;
        let out = World::run::<f64, _, _>(3, NetworkModel::gigabit_ethernet(), move |comm| {
            let g = comm.world();
            let me = g.rank();
            let p = g.size();
            let next = (me + 1) % p;
            let prev = (me + p - 1) % p;
            let seg = vec![me as f64; 4];
            let ex = NeighborExchange::start_wire(&g, 7, vec![(next, seg, pcie)], &[prev]);
            let got = ex.wait();
            (got[0].1.clone(), comm.clock().pcie_free())
        });
        for (me, (seg, pcie_free)) in out.iter().enumerate() {
            let prev = (me + 3 - 1) % 3;
            assert_eq!(seg, &vec![prev as f64; 4]);
            assert!((pcie_free - pcie).abs() < 1e-12, "rank {me}: {pcie_free}");
        }
    }

    #[test]
    fn asymmetric_peer_sets_complete() {
        // Rank 0 broadcasts a segment to 1 and 2; only rank 1 replies.
        let out = World::run::<f32, _, _>(3, NetworkModel::ideal(), |comm| {
            let g = comm.world();
            match g.rank() {
                0 => {
                    let ex = NeighborExchange::start(
                        &g,
                        3,
                        vec![(1, vec![1.5f32]), (2, vec![2.5f32])],
                        &[1],
                    );
                    let got = ex.wait();
                    (got[0].0, got[0].1[0])
                }
                1 => {
                    let ex =
                        NeighborExchange::start(&g, 3, vec![(0, vec![9.0f32])], &[0]);
                    let got = ex.wait();
                    (got[0].0, got[0].1[0])
                }
                _ => {
                    let ex = NeighborExchange::start(&g, 3, Vec::new(), &[0]);
                    let got = ex.wait();
                    (got[0].0, got[0].1[0])
                }
            }
        });
        assert_eq!(out, vec![(1, 9.0), (0, 1.5), (0, 2.5)]);
    }
}
