//! MPI-style collectives over a [`Group`], built from real point-to-point
//! messages with the same algorithmic structure MPICH uses:
//!
//! * broadcast / reduce — binomial trees (`log P` rounds);
//! * allreduce — reduce-to-0 + broadcast;
//! * allgather — bandwidth-optimal ring (`P-1` rounds);
//! * gather / scatter — linear (our payloads are long tiles, where MPICH
//!   also switches to linear);
//! * barrier — dissemination.
//!
//! Because each tree edge is an actual message through the transport, the
//! virtual clock picks up the right `alpha·log P + bytes·beta` cost shape
//! without a separate collective cost model.

use std::cell::Cell;
use std::rc::Rc;

use super::message::{Payload, Tag};
use super::transport::{Comm, Group};
use crate::Scalar;

/// Element-wise reduction operators.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceOp {
    /// Element-wise sum.
    Sum,
    /// Element-wise max.
    Max,
    /// Element-wise min.
    Min,
}

impl ReduceOp {
    fn combine<S: Scalar>(self, a: S, b: S) -> S {
        match self {
            ReduceOp::Sum => a + b,
            ReduceOp::Max => {
                if b > a { b } else { a }
            }
            ReduceOp::Min => {
                if b < a { b } else { a }
            }
        }
    }

    fn combine_vec<S: Scalar>(self, acc: &mut [S], other: &[S]) {
        assert_eq!(acc.len(), other.len(), "reduce length mismatch");
        for (a, &b) in acc.iter_mut().zip(other) {
            *a = self.combine(*a, b);
        }
    }
}

/// The bit on which tree-relative rank `rel` (> 0) receives its copy in
/// the binomial broadcast tree over `p` nodes: the lowest set bit of `rel`.
fn bcast_recv_mask(rel: usize, p: usize) -> usize {
    debug_assert!(rel > 0 && rel < p);
    let mut mask = 1usize;
    while rel & mask == 0 {
        mask <<= 1;
    }
    mask
}

/// Tree-relative ranks of the subtree children `rel` forwards to in the
/// binomial broadcast tree over `p` nodes, in send order.  `recv_mask` is
/// the bit on which `rel` received its copy ([`bcast_recv_mask`]); pass 0
/// for the root, which owns the whole tree.  Every broadcast path —
/// blocking, split-phase start, stamped forwarding, the allreduce down
/// phase — enumerates its edges through this one function, which is what
/// keeps their message order (and therefore solver reproducibility)
/// identical across the blocking and overlapped schedules.
fn bcast_children(rel: usize, p: usize, recv_mask: usize) -> Vec<usize> {
    let mut mask = if recv_mask == 0 {
        let mut m = 1usize;
        while m < p {
            m <<= 1;
        }
        m >> 1
    } else {
        recv_mask >> 1
    };
    let mut out = Vec::new();
    while mask > 0 {
        if rel + mask < p {
            out.push(rel + mask);
        }
        mask >>= 1;
    }
    out
}

impl<'a, S: Scalar> Group<'a, S> {
    /// Binomial-tree broadcast from group rank `root`.  `data` is the
    /// payload on the root and ignored elsewhere; every rank returns the
    /// broadcast payload.
    pub fn bcast(&self, root: usize, tag: u32, data: Option<Payload<S>>) -> Payload<S> {
        let p = self.size();
        let me = self.rank();
        if p == 1 {
            return data.expect("bcast root must supply data");
        }
        let rel = (me + p - root) % p;
        // Receive phase.
        let (pl, recv_mask) = if me == root {
            (data.expect("bcast root must supply data"), 0)
        } else {
            let recv_mask = bcast_recv_mask(rel, p);
            let src = (me + p - recv_mask) % p;
            (self.comm().recv(self.world_rank(src), Tag::Bcast(tag)), recv_mask)
        };
        // Send phase: forward down the subtree.
        for child in bcast_children(rel, p, recv_mask) {
            let dst = (me + (child - rel)) % p;
            self.comm().send(self.world_rank(dst), Tag::Bcast(tag), pl.clone());
        }
        pl
    }

    /// Binomial-tree element-wise reduction of equal-length vectors to group
    /// rank `root`.  Returns `Some(result)` on the root, `None` elsewhere.
    pub fn reduce_vec(
        &self,
        root: usize,
        tag: u32,
        mut mine: Vec<S>,
        op: ReduceOp,
    ) -> Option<Vec<S>> {
        let p = self.size();
        let me = self.rank();
        if p == 1 {
            return Some(mine);
        }
        let rel = (me + p - root) % p;
        let mut mask = 1usize;
        while mask < p {
            if rel & mask == 0 {
                let peer_rel = rel | mask;
                if peer_rel < p {
                    let src = (peer_rel + root) % p;
                    let other =
                        self.comm().recv(self.world_rank(src), Tag::Reduce(tag)).into_data();
                    op.combine_vec(&mut mine, &other);
                }
            } else {
                let dst = (rel - mask + root) % p;
                self.comm().send(self.world_rank(dst), Tag::Reduce(tag), Payload::Data(mine));
                return None;
            }
            mask <<= 1;
        }
        Some(mine)
    }

    /// Allreduce of equal-length vectors: reduce to rank 0, then broadcast.
    pub fn allreduce_vec(&self, tag: u32, mine: Vec<S>, op: ReduceOp) -> Vec<S> {
        let reduced = self.reduce_vec(0, tag, mine, op);
        self.bcast(0, tag, reduced.map(Payload::Data)).into_data()
    }

    /// Allreduce of a single scalar.
    pub fn allreduce_scalar(&self, tag: u32, mine: S, op: ReduceOp) -> S {
        self.allreduce_vec(tag, vec![mine], op)[0]
    }

    /// GPUDirect broadcast: identical tree, message order and payloads to
    /// [`Group::bcast`] — only the *root's own* tree edges go over the
    /// device wire ([`Comm::send_wire`]'s joint NIC/PCIe occupancy with
    /// `pcie_secs` as the D2H leg).  Forwarded copies are host-resident
    /// (they arrived through the transport), so interior ranks send
    /// plainly.  With `pcie_secs <= 0` this **is** [`Group::bcast`].
    pub fn bcast_wire(
        &self,
        root: usize,
        tag: u32,
        data: Option<Payload<S>>,
        pcie_secs: f64,
    ) -> Payload<S> {
        if pcie_secs <= 0.0 {
            return self.bcast(root, tag, data);
        }
        let p = self.size();
        let me = self.rank();
        if p == 1 {
            return data.expect("bcast root must supply data");
        }
        let rel = (me + p - root) % p;
        let (pl, recv_mask) = if me == root {
            (data.expect("bcast root must supply data"), 0)
        } else {
            let recv_mask = bcast_recv_mask(rel, p);
            let src = (me + p - recv_mask) % p;
            (self.comm().recv(self.world_rank(src), Tag::Bcast(tag)), recv_mask)
        };
        let mut leg = pcie_secs;
        for child in bcast_children(rel, p, recv_mask) {
            let dst = (me + (child - rel)) % p;
            if me == root {
                // The NIC reads the dirty device buffer directly.  The D2H
                // leg is paid once per payload, not once per edge: after
                // the first edge the bytes sit in the NIC's pinned window.
                self.comm().send_wire(self.world_rank(dst), Tag::Bcast(tag), pl.clone(), leg);
                leg = 0.0;
            } else {
                self.comm().send(self.world_rank(dst), Tag::Bcast(tag), pl.clone());
            }
        }
        pl
    }

    /// GPUDirect reduction: identical tree and combine order to
    /// [`Group::reduce_vec`].  Only a **virgin leaf** — a rank that ships
    /// its contribution before folding in any received partial — holds a
    /// device-dirty buffer; once `combine_vec` has run, the accumulator is
    /// host-resident and goes over the plain wire.  With `pcie_secs <= 0`
    /// this **is** [`Group::reduce_vec`].
    pub fn reduce_vec_wire(
        &self,
        root: usize,
        tag: u32,
        mut mine: Vec<S>,
        op: ReduceOp,
        pcie_secs: f64,
    ) -> Option<Vec<S>> {
        if pcie_secs <= 0.0 {
            return self.reduce_vec(root, tag, mine, op);
        }
        let p = self.size();
        let me = self.rank();
        if p == 1 {
            return Some(mine);
        }
        let rel = (me + p - root) % p;
        let mut mask = 1usize;
        let mut virgin = true;
        while mask < p {
            if rel & mask == 0 {
                let peer_rel = rel | mask;
                if peer_rel < p {
                    let src = (peer_rel + root) % p;
                    let other =
                        self.comm().recv(self.world_rank(src), Tag::Reduce(tag)).into_data();
                    op.combine_vec(&mut mine, &other);
                    virgin = false;
                }
            } else {
                let dst = (rel - mask + root) % p;
                let leg = if virgin { pcie_secs } else { 0.0 };
                self.comm().send_wire(
                    self.world_rank(dst),
                    Tag::Reduce(tag),
                    Payload::Data(mine),
                    leg,
                );
                return None;
            }
            mask <<= 1;
        }
        Some(mine)
    }

    /// GPUDirect allreduce: [`Group::reduce_vec_wire`] up (virgin leaves on
    /// the device wire), plain [`Group::bcast`] down (the reduced vector is
    /// host-resident on every rank that holds it).  Bit-identical results
    /// to [`Group::allreduce_vec`] always — the wire only reroutes clock
    /// occupancy, never data.
    pub fn allreduce_vec_wire(
        &self,
        tag: u32,
        mine: Vec<S>,
        op: ReduceOp,
        pcie_secs: f64,
    ) -> Vec<S> {
        let reduced = self.reduce_vec_wire(0, tag, mine, op, pcie_secs);
        self.bcast(0, tag, reduced.map(Payload::Data)).into_data()
    }

    /// Allreduce of an (|value|, index) pair under max-abs — the pivot search
    /// of distributed partial pivoting (MPI_MAXLOC).  Ties break toward the
    /// smaller index so every rank picks the identical pivot.
    pub fn allreduce_maxabsloc(&self, tag: u32, value: S, index: i64) -> (S, i64) {
        // Pack as two lanes; combine manually via gather-to-0 + bcast on a
        // binomial tree (reuse reduce machinery with a custom fold).
        let p = self.size();
        let me = self.rank();
        let mut best = (value, index);
        if p > 1 {
            let rel = me; // root 0
            let mut mask = 1usize;
            let mut sent = false;
            while mask < p && !sent {
                if rel & mask == 0 {
                    let peer = rel | mask;
                    if peer < p {
                        let data =
                            self.comm().recv(self.world_rank(peer), Tag::Reduce(tag)).into_data();
                        let (v, i) = (data[0], data[1].to_f64().unwrap() as i64);
                        if v.abs() > best.0.abs()
                            || (v.abs() == best.0.abs() && i < best.1)
                        {
                            best = (v, i);
                        }
                    }
                } else {
                    let dst = rel & !mask;
                    let enc = vec![best.0, S::from_f64(best.1 as f64).unwrap()];
                    self.comm().send(self.world_rank(dst), Tag::Reduce(tag), Payload::Data(enc));
                    sent = true;
                }
                mask <<= 1;
            }
            let enc = if me == 0 {
                Some(Payload::Data(vec![best.0, S::from_f64(best.1 as f64).unwrap()]))
            } else {
                None
            };
            let out = self.bcast(0, tag, enc).into_data();
            best = (out[0], out[1].to_f64().unwrap() as i64);
        }
        best
    }

    /// Ring allgather: every rank contributes `mine`; everyone returns all
    /// contributions indexed by group rank.  Block lengths may differ.
    pub fn allgather(&self, tag: u32, mine: Vec<S>) -> Vec<Vec<S>> {
        let p = self.size();
        let me = self.rank();
        let mut blocks: Vec<Option<Vec<S>>> = (0..p).map(|_| None).collect();
        blocks[me] = Some(mine);
        let next = (me + 1) % p;
        let prev = (me + p - 1) % p;
        for r in 0..p.saturating_sub(1) {
            // Send the block that originated at (me - r), receive the one
            // that originated at (prev - r) == (me - r - 1).
            let send_origin = (me + p - r % p) % p;
            let recv_origin = (me + p - r % p + p - 1) % p;
            let out = blocks[send_origin].clone().expect("ring allgather order");
            self.comm().send(self.world_rank(next), Tag::AllGather(tag), Payload::Data(out));
            let got = self.comm().recv(self.world_rank(prev), Tag::AllGather(tag)).into_data();
            blocks[recv_origin] = Some(got);
        }
        blocks.into_iter().map(|b| b.expect("ring allgather complete")).collect()
    }

    /// Linear gather to group rank `root`: root returns all blocks indexed by
    /// group rank, others return `None`.
    pub fn gather(&self, root: usize, tag: u32, mine: Vec<S>) -> Option<Vec<Vec<S>>> {
        let p = self.size();
        let me = self.rank();
        if me != root {
            self.comm().send(self.world_rank(root), Tag::Gather(tag), Payload::Data(mine));
            return None;
        }
        let mut out: Vec<Vec<S>> = (0..p).map(|_| Vec::new()).collect();
        out[me] = mine;
        for src in 0..p {
            if src != me {
                out[src] = self.comm().recv(self.world_rank(src), Tag::Gather(tag)).into_data();
            }
        }
        Some(out)
    }

    /// Linear scatter from `root`: root supplies one block per group rank;
    /// every rank returns its block.
    pub fn scatter(&self, root: usize, tag: u32, blocks: Option<Vec<Vec<S>>>) -> Vec<S> {
        let p = self.size();
        let me = self.rank();
        if me == root {
            let mut blocks = blocks.expect("scatter root must supply blocks");
            assert_eq!(blocks.len(), p, "scatter needs one block per rank");
            let mut own = Vec::new();
            for (dst, block) in blocks.drain(..).enumerate() {
                if dst == me {
                    own = block;
                } else {
                    self.comm().send(self.world_rank(dst), Tag::Scatter(tag), Payload::Data(block));
                }
            }
            own
        } else {
            self.comm().recv(self.world_rank(root), Tag::Scatter(tag)).into_data()
        }
    }

    /// Start a split-phase binomial broadcast (same tree, same message
    /// order as [`Group::bcast`]).  The root's tree edges are posted
    /// immediately with the payload's availability stamp, so the transfer
    /// progresses on the network timeline while the caller computes;
    /// [`BcastRequest::wait`] charges only the latency that compute did not
    /// cover.  Non-root interior ranks forward their edges at `wait`, but
    /// stamped from the *arrival* of the incoming message — modelling the
    /// asynchronous progression a real MPI progress engine provides.
    pub fn ibcast(&self, root: usize, tag: u32, data: Option<Payload<S>>) -> BcastRequest<'a, S> {
        let p = self.size();
        let me = self.rank();
        self.comm().req_open();
        if p == 1 {
            let pl = data.expect("bcast root must supply data");
            return BcastRequest {
                comm: self.comm(),
                ranks: self.ranks.clone(),
                me,
                root,
                tag,
                payload: Some(pl),
                recv_mask: 0,
                posted_at: self.comm().clock().now(),
                done: Cell::new(false),
            };
        }
        let rel = (me + p - root) % p;
        let posted_at = self.comm().clock().now();
        if me == root {
            // Post every tree edge now: the payload is already available.
            let pl = data.expect("bcast root must supply data");
            for child in bcast_children(0, p, 0) {
                let dst = (me + child) % p;
                self.comm().post_at(self.world_rank(dst), Tag::Bcast(tag), pl.clone(), posted_at);
            }
            return BcastRequest {
                comm: self.comm(),
                ranks: self.ranks.clone(),
                me,
                root,
                tag,
                payload: Some(pl),
                recv_mask: 0,
                posted_at,
                done: Cell::new(false),
            };
        }
        BcastRequest {
            comm: self.comm(),
            ranks: self.ranks.clone(),
            me,
            root,
            tag,
            payload: None,
            recv_mask: bcast_recv_mask(rel, p),
            posted_at,
            done: Cell::new(false),
        }
    }

    /// Start a split-phase GPUDirect broadcast: [`Group::ibcast`] with the
    /// root's tree edges posted over the device wire
    /// ([`Comm::post_wire_at`] — joint NIC/PCIe occupancy, no host staging
    /// copy).  Non-root ranks behave exactly as in [`Group::ibcast`]: their
    /// forwarded copies arrived through the transport and are
    /// host-resident.  With `pcie_secs <= 0` this **is** [`Group::ibcast`].
    pub fn ibcast_wire(
        &self,
        root: usize,
        tag: u32,
        data: Option<Payload<S>>,
        pcie_secs: f64,
    ) -> BcastRequest<'a, S> {
        let p = self.size();
        let me = self.rank();
        if pcie_secs <= 0.0 || p == 1 || me != root {
            return self.ibcast(root, tag, data);
        }
        self.comm().req_open();
        let posted_at = self.comm().clock().now();
        let pl = data.expect("bcast root must supply data");
        for child in bcast_children(0, p, 0) {
            let dst = (me + child) % p;
            self.comm().post_wire_at(
                self.world_rank(dst),
                Tag::Bcast(tag),
                pl.clone(),
                posted_at,
                pcie_secs,
            );
        }
        BcastRequest {
            comm: self.comm(),
            ranks: self.ranks.clone(),
            me,
            root,
            tag,
            payload: Some(pl),
            recv_mask: 0,
            posted_at,
            done: Cell::new(false),
        }
    }

    /// Start a split-phase ring allgather (same ring, same message order as
    /// [`Group::allgather`]).  This rank's own block is posted immediately;
    /// the remaining `P-2` forwarding hops are stamped from each incoming
    /// arrival at [`AllgatherRequest::wait`] — the ring progresses in the
    /// background while the caller computes on data it already owns (the
    /// split-phase `pspmv` pattern).
    pub fn iallgather(&self, tag: u32, mine: Vec<S>) -> AllgatherRequest<'a, S> {
        let p = self.size();
        let me = self.rank();
        self.comm().req_open();
        let posted_at = self.comm().clock().now();
        if p > 1 {
            let next = (me + 1) % p;
            self.comm().post_at(
                self.world_rank(next),
                Tag::AllGather(tag),
                Payload::Data(mine.clone()),
                posted_at,
            );
        }
        let mut blocks: Vec<Option<Vec<S>>> = (0..p).map(|_| None).collect();
        blocks[me] = Some(mine);
        let (comm, ranks) = (self.comm(), self.ranks.clone());
        AllgatherRequest { comm, ranks, me, tag, blocks, posted_at, done: Cell::new(false) }
    }

    /// Start a split-phase allreduce (binomial reduce-to-0 + broadcast, the
    /// same tree and combine order as [`Group::allreduce_vec`] so results
    /// are bit-identical).  All tree edges are stamped from data
    /// availability — a leaf's contribution from the post time, an interior
    /// combine from the latest arrival feeding it — so the whole reduction
    /// progresses as if driven by a progress thread while the caller
    /// computes (the Ghysels pipelined-CG overlap);
    /// [`AllreduceRequest::wait`] charges only the uncovered remainder.
    pub fn iallreduce_vec(&self, tag: u32, mine: Vec<S>, op: ReduceOp) -> AllreduceRequest<'a, S> {
        self.comm().req_open();
        AllreduceRequest {
            comm: self.comm(),
            ranks: self.ranks.clone(),
            me: self.rank(),
            tag,
            op,
            mine: Some(mine),
            posted_at: self.comm().clock().now(),
            done: Cell::new(false),
        }
    }

    /// Dissemination barrier (works for any group size).
    pub fn barrier(&self, tag: u32) {
        let p = self.size();
        let me = self.rank();
        let mut k = 0u32;
        let mut dist = 1usize;
        while dist < p {
            let dst = (me + dist) % p;
            let src = (me + p - dist) % p;
            self.comm().send(self.world_rank(dst), Tag::Barrier(tag + k), Payload::Empty);
            self.comm().recv(self.world_rank(src), Tag::Barrier(tag + k));
            dist <<= 1;
            k += 1;
        }
    }
}

/// In-flight split-phase broadcast (see [`Group::ibcast`]).
#[must_use = "a split-phase collective must be waited"]
pub struct BcastRequest<'a, S: Scalar> {
    comm: &'a Comm<S>,
    ranks: Rc<[usize]>,
    me: usize,
    root: usize,
    tag: u32,
    payload: Option<Payload<S>>,
    recv_mask: usize,
    posted_at: f64,
    done: Cell<bool>,
}

impl<S: Scalar> Drop for BcastRequest<'_, S> {
    fn drop(&mut self) {
        // Balance the request counter even on an unwaited drop (e.g. an
        // in-flight lookahead panel abandoned by an error return).
        if !self.done.get() {
            self.comm.req_close();
        }
    }
}

impl<S: Scalar> BcastRequest<'_, S> {
    /// Complete the broadcast: receive this rank's copy (charging only the
    /// remaining latency), forward the subtree edges stamped from the
    /// arrival, and return the payload.
    pub fn wait(mut self) -> Payload<S> {
        self.done.set(true);
        self.comm.req_close();
        if let Some(pl) = self.payload.take() {
            return pl; // root (or singleton group): data was local all along
        }
        let p = self.ranks.len();
        let rel = (self.me + p - self.root) % p;
        let src = (self.me + p - self.recv_mask) % p;
        let msg = self.comm.take_matching(self.ranks[src], Tag::Bcast(self.tag));
        // Forward down the subtree as a progress engine would: available the
        // instant the incoming copy landed, not when this wait ran.
        for child in bcast_children(rel, p, self.recv_mask) {
            let dst = (self.me + (child - rel)) % p;
            self.comm.post_at(
                self.ranks[dst],
                Tag::Bcast(self.tag),
                msg.payload.clone(),
                msg.arrival,
            );
        }
        self.comm.credit_overlap(self.posted_at, msg.arrival);
        self.comm.clock().observe_arrival(msg.arrival);
        msg.payload
    }
}

/// In-flight split-phase ring allgather (see [`Group::iallgather`]).
#[must_use = "a split-phase collective must be waited"]
pub struct AllgatherRequest<'a, S: Scalar> {
    comm: &'a Comm<S>,
    ranks: Rc<[usize]>,
    me: usize,
    tag: u32,
    blocks: Vec<Option<Vec<S>>>,
    posted_at: f64,
    done: Cell<bool>,
}

impl<S: Scalar> Drop for AllgatherRequest<'_, S> {
    fn drop(&mut self) {
        if !self.done.get() {
            self.comm.req_close();
        }
    }
}

impl<S: Scalar> AllgatherRequest<'_, S> {
    /// Complete the ring: drain the remaining rounds (forwards stamped from
    /// each arrival), charge only the uncovered latency of the last hop,
    /// and return all contributions indexed by group rank.
    pub fn wait(mut self) -> Vec<Vec<S>> {
        self.done.set(true);
        self.comm.req_close();
        let p = self.ranks.len();
        let me = self.me;
        let next = (me + 1) % p;
        let prev = (me + p - 1) % p;
        let mut last_arrival = self.posted_at;
        for r in 0..p.saturating_sub(1) {
            let recv_origin = (me + p - r % p + p - 1) % p;
            let msg = self.comm.take_matching(self.ranks[prev], Tag::AllGather(self.tag));
            last_arrival = last_arrival.max(msg.arrival);
            if r + 1 < p - 1 {
                // This block is what the ring sends next round — forward it
                // the moment it landed, not when this wait ran.
                self.comm.post_at(
                    self.ranks[next],
                    Tag::AllGather(self.tag),
                    msg.payload.clone(),
                    msg.arrival,
                );
            }
            self.blocks[recv_origin] = Some(msg.payload.into_data());
        }
        self.comm.credit_overlap(self.posted_at, last_arrival);
        self.comm.clock().observe_arrival(last_arrival);
        let blocks = std::mem::take(&mut self.blocks);
        blocks.into_iter().map(|b| b.expect("ring allgather complete")).collect()
    }
}

/// In-flight split-phase allreduce (see [`Group::iallreduce_vec`]).
#[must_use = "a split-phase collective must be waited"]
pub struct AllreduceRequest<'a, S: Scalar> {
    comm: &'a Comm<S>,
    ranks: Rc<[usize]>,
    me: usize,
    tag: u32,
    op: ReduceOp,
    mine: Option<Vec<S>>,
    posted_at: f64,
    done: Cell<bool>,
}

impl<S: Scalar> Drop for AllreduceRequest<'_, S> {
    fn drop(&mut self) {
        if !self.done.get() {
            self.comm.req_close();
        }
    }
}

impl<S: Scalar> AllreduceRequest<'_, S> {
    /// Complete the reduction: run the reduce-to-0 tree and the down
    /// broadcast with availability stamps (each edge leaves the instant its
    /// inputs exist), charge only the latency compute did not cover, and
    /// return the reduced vector.
    pub fn wait(mut self) -> Vec<S> {
        self.done.set(true);
        self.comm.req_close();
        let p = self.ranks.len();
        let me = self.me;
        let mut acc = self.mine.take().expect("allreduce contribution");
        if p == 1 {
            return acc;
        }
        // --- up phase: binomial reduce to group rank 0, stamped -----------
        // `avail` is when this rank's partial sum exists: its own post time,
        // pushed later by every child arrival it folds in.
        let mut avail = self.posted_at;
        let mut mask = 1usize;
        let mut sent = false;
        while mask < p && !sent {
            if me & mask == 0 {
                let peer = me | mask;
                if peer < p {
                    let msg = self.comm.take_matching(self.ranks[peer], Tag::Reduce(self.tag));
                    avail = avail.max(msg.arrival);
                    self.op.combine_vec(&mut acc, &msg.payload.into_data());
                }
            } else {
                let dst = me & !mask;
                self.comm.post_at(
                    self.ranks[dst],
                    Tag::Reduce(self.tag),
                    Payload::Data(acc.clone()),
                    avail,
                );
                sent = true;
            }
            mask <<= 1;
        }
        // --- down phase: binomial broadcast from 0, stamped ----------------
        // (root 0, so tree-relative rank == group rank and children are
        // absolute; same edge enumeration as every other broadcast path.)
        let final_arrival;
        if me == 0 {
            final_arrival = avail;
            for child in bcast_children(0, p, 0) {
                self.comm.post_at(
                    self.ranks[child],
                    Tag::Bcast(self.tag),
                    Payload::Data(acc.clone()),
                    avail,
                );
            }
        } else {
            let recv_mask = bcast_recv_mask(me, p);
            let src = me - recv_mask;
            let msg = self.comm.take_matching(self.ranks[src], Tag::Bcast(self.tag));
            final_arrival = msg.arrival;
            for child in bcast_children(me, p, recv_mask) {
                self.comm.post_at(
                    self.ranks[child],
                    Tag::Bcast(self.tag),
                    msg.payload.clone(),
                    msg.arrival,
                );
            }
            acc = msg.payload.into_data();
        }
        self.comm.credit_overlap(self.posted_at, final_arrival);
        self.comm.clock().observe_arrival(final_arrival);
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{NetworkModel, World};

    fn run<R: Send>(p: usize, f: impl Fn(crate::comm::Comm<f64>) -> R + Send + Sync) -> Vec<R> {
        World::run::<f64, _, _>(p, NetworkModel::ideal(), f)
    }

    #[test]
    fn bcast_all_sizes_all_roots() {
        for p in [1usize, 2, 3, 4, 5, 8] {
            for root in 0..p {
                let out = run(p, move |comm| {
                    let g = comm.world();
                    let data = if comm.rank() == root {
                        Some(Payload::Data(vec![42.0, root as f64]))
                    } else {
                        None
                    };
                    g.bcast(root, 1, data).into_data()
                });
                for v in out {
                    assert_eq!(v, vec![42.0, root as f64], "p={p} root={root}");
                }
            }
        }
    }

    #[test]
    fn reduce_sum_matches() {
        for p in [1usize, 2, 3, 4, 7, 8] {
            let out = run(p, move |comm| {
                let g = comm.world();
                let mine = vec![comm.rank() as f64, 1.0];
                g.reduce_vec(0, 2, mine, ReduceOp::Sum)
            });
            let expect_sum: f64 = (0..p).map(|r| r as f64).sum();
            assert_eq!(out[0].as_ref().unwrap(), &vec![expect_sum, p as f64]);
            for r in 1..p {
                assert!(out[r].is_none());
            }
        }
    }

    #[test]
    fn allreduce_ops() {
        for p in [2usize, 3, 4, 6] {
            let out = run(p, move |comm| {
                let g = comm.world();
                let r = comm.rank() as f64;
                (
                    g.allreduce_scalar(3, r, ReduceOp::Sum),
                    g.allreduce_scalar(4, r, ReduceOp::Max),
                    g.allreduce_scalar(5, r, ReduceOp::Min),
                )
            });
            let sum: f64 = (0..p).map(|r| r as f64).sum();
            for (s, mx, mn) in out {
                assert_eq!(s, sum);
                assert_eq!(mx, (p - 1) as f64);
                assert_eq!(mn, 0.0);
            }
        }
    }

    #[test]
    fn maxabsloc_picks_global_pivot() {
        for p in [1usize, 2, 3, 5, 8] {
            let out = run(p, move |comm| {
                let g = comm.world();
                // rank r contributes value (-1)^r * r with index 100 + r.
                let r = comm.rank();
                let v = if r % 2 == 0 { r as f64 } else { -(r as f64) };
                g.allreduce_maxabsloc(6, v, 100 + r as i64)
            });
            let best = (p - 1) as f64;
            for (v, i) in out {
                assert_eq!(v.abs(), best, "p={p}");
                assert_eq!(i, 100 + (p - 1) as i64);
            }
        }
    }

    #[test]
    fn allgather_ring() {
        for p in [1usize, 2, 3, 4, 5] {
            let out = run(p, move |comm| {
                let g = comm.world();
                // variable-length contribution: rank r sends r+1 copies of r.
                let mine = vec![comm.rank() as f64; comm.rank() + 1];
                g.allgather(7, mine)
            });
            for blocks in out {
                assert_eq!(blocks.len(), p);
                for (r, b) in blocks.iter().enumerate() {
                    assert_eq!(b, &vec![r as f64; r + 1]);
                }
            }
        }
    }

    #[test]
    fn gather_scatter_roundtrip() {
        for p in [1usize, 2, 4, 5] {
            for root in 0..p {
                let out = run(p, move |comm| {
                    let g = comm.world();
                    let mine = vec![comm.rank() as f64 * 10.0];
                    let gathered = g.gather(root, 8, mine);
                    // root redistributes doubled blocks
                    let blocks = gathered.map(|bs| {
                        bs.into_iter()
                            .map(|b| b.iter().map(|x| x * 2.0).collect())
                            .collect::<Vec<_>>()
                    });
                    g.scatter(root, 9, blocks)
                });
                for (r, b) in out.iter().enumerate() {
                    assert_eq!(b, &vec![r as f64 * 20.0], "p={p} root={root}");
                }
            }
        }
    }

    #[test]
    fn barrier_synchronises_clocks() {
        let net = NetworkModel::gigabit_ethernet();
        let out = World::run::<f64, _, _>(4, net, |comm| {
            // Rank 2 is slow.
            if comm.rank() == 2 {
                comm.clock().advance_compute(1.0);
            }
            comm.world().barrier(20);
            comm.clock().now()
        });
        for t in &out {
            assert!(*t >= 1.0, "barrier must not complete before slowest rank: {out:?}");
        }
    }

    #[test]
    fn ibcast_matches_bcast_on_all_sizes_and_roots() {
        for p in [1usize, 2, 3, 4, 5, 8] {
            for root in 0..p {
                let out = run(p, move |comm| {
                    let g = comm.world();
                    let data = if comm.rank() == root {
                        Some(Payload::Data(vec![root as f64, 42.0]))
                    } else {
                        None
                    };
                    g.ibcast(root, 11, data).wait().into_data()
                });
                for v in out {
                    assert_eq!(v, vec![root as f64, 42.0], "p={p} root={root}");
                }
            }
        }
    }

    #[test]
    fn iallgather_matches_allgather() {
        for p in [1usize, 2, 3, 5] {
            let out = run(p, move |comm| {
                let g = comm.world();
                let mine = vec![comm.rank() as f64; comm.rank() + 1];
                g.iallgather(12, mine).wait()
            });
            for blocks in out {
                for (r, b) in blocks.iter().enumerate() {
                    assert_eq!(b, &vec![r as f64; r + 1]);
                }
            }
        }
    }

    #[test]
    fn iallreduce_bit_identical_to_blocking() {
        // Same tree, same combine order: the split-phase sum must be
        // *bit-identical* to the blocking one (solver reproducibility).
        for p in [1usize, 2, 3, 4, 6, 7, 8] {
            let out = run(p, move |comm| {
                let g = comm.world();
                let mine = vec![
                    (comm.rank() as f64 * 0.1).sin(),
                    1.0 / (comm.rank() as f64 + 3.0),
                ];
                let blocking = g.allreduce_vec(13, mine.clone(), ReduceOp::Sum);
                let split = g.iallreduce_vec(14, mine, ReduceOp::Sum).wait();
                (blocking, split)
            });
            for (blocking, split) in out {
                assert_eq!(blocking, split, "p={p}");
            }
        }
    }

    #[test]
    fn wire_collectives_are_bit_identical_to_their_host_twins() {
        // Same trees, same message order, same payloads: the device wire
        // reroutes clock occupancy only.  Data must match bit for bit, on
        // every size and root, with the wire leg on and off.
        for p in [1usize, 2, 3, 4, 5, 8] {
            for root in 0..p {
                let out = run(p, move |comm| {
                    let g = comm.world();
                    let mk = || vec![(comm.rank() as f64 * 0.3).cos(), root as f64];
                    let data =
                        if comm.rank() == root { Some(Payload::Data(mk())) } else { None };
                    let b = g.bcast(root, 1, data.clone()).into_data();
                    let bw = g.bcast_wire(root, 2, data.clone(), 1e-4).into_data();
                    let ib = g.ibcast_wire(root, 3, data, 1e-4).wait().into_data();
                    let r = g.reduce_vec(root, 4, mk(), ReduceOp::Sum);
                    let rw = g.reduce_vec_wire(root, 5, mk(), ReduceOp::Sum, 1e-4);
                    let a = g.allreduce_vec(6, mk(), ReduceOp::Sum);
                    let aw = g.allreduce_vec_wire(7, mk(), ReduceOp::Sum, 1e-4);
                    (b == bw && b == ib, r == rw, a == aw)
                });
                for (b, r, a) in out {
                    assert!(b && r && a, "p={p} root={root}");
                }
            }
        }
    }

    #[test]
    fn wire_bcast_charges_the_pcie_leg_once_per_payload() {
        // Root of an 8-rank binomial tree sends 3 edges; the D2H-equivalent
        // leg must occupy the copy engine once, not three times.
        let net = NetworkModel::gigabit_ethernet();
        let pcie = 1e-3;
        let out = World::run::<f64, _, _>(8, net, move |comm| {
            let g = comm.world();
            let data = if comm.rank() == 0 {
                Some(Payload::Data(vec![1.0; 64]))
            } else {
                None
            };
            g.bcast_wire(0, 30, data, pcie);
            comm.clock().pcie_free()
        });
        assert!(out[0] > 0.0, "root's copy engine carried the leg");
        assert!(out[0] <= pcie * 1.5, "one leg, not one per edge: {}", out[0]);
        for (r, &pf) in out.iter().enumerate().skip(1) {
            assert_eq!(pf, 0.0, "rank {r} forwards host-resident copies");
        }
    }

    #[test]
    fn unwaited_collective_requests_still_close_the_counter() {
        // Dropping a request unwaited (e.g. an abandoned lookahead panel on
        // an error return) must balance the outstanding-request counter.
        let out = run(1, |comm| {
            for _ in 0..3 {
                let _r = comm.world().iallreduce_vec(99, vec![1.0], ReduceOp::Sum);
            }
            comm.stats().max_outstanding_reqs()
        });
        assert_eq!(out[0], 1, "sequential dropped requests must not stack");
    }

    #[test]
    fn split_phase_collectives_hide_latency_behind_compute() {
        // Every rank starts an allreduce, computes for longer than the
        // whole tree takes, then waits: the wait must be (nearly) free and
        // the saving recorded, while a blocking allreduce at the same spot
        // charges the full tree latency on at least the leaf ranks.
        let net = NetworkModel::gigabit_ethernet();
        let compute = 1.0; // far above any alpha*log(p)
        let out = World::run::<f64, _, _>(8, net, move |comm| {
            let g = comm.world();
            let req = g.iallreduce_vec(15, vec![comm.rank() as f64], ReduceOp::Sum);
            comm.clock().advance_compute(compute);
            let s = req.wait();
            (s[0], comm.clock().comm_wait_secs(), comm.stats().wait_saved_secs())
        });
        let want: f64 = (0..8).map(|r| r as f64).sum();
        for (s, wait, saved) in out {
            assert_eq!(s, want);
            assert!(wait < 1e-3, "overlapped wait must be tiny: {wait}");
            assert!(saved > 0.0, "hidden latency must be recorded");
        }
    }

    #[test]
    fn bcast_cost_scales_log_p() {
        // Under the alpha-beta model, a small-message bcast over p ranks
        // costs ~ceil(log2 p) * alpha on the critical path.
        let net = NetworkModel::gigabit_ethernet();
        let mut costs = Vec::new();
        for p in [2usize, 4, 8, 16] {
            let out = World::run::<f64, _, _>(p, net, |comm| {
                let g = comm.world();
                let data =
                    if comm.rank() == 0 { Some(Payload::Scalar(1.0)) } else { None };
                g.bcast(0, 1, data);
                comm.clock().now()
            });
            costs.push(out.iter().cloned().fold(0.0, f64::max));
        }
        // log2: 1, 2, 3, 4 rounds.
        for (i, c) in costs.iter().enumerate() {
            let rounds = (i + 1) as f64;
            assert!(
                (*c - rounds * net.alpha).abs() < net.alpha * 0.51,
                "p=2^{} cost {c} vs {} rounds",
                i + 1,
                rounds,
            );
        }
    }
}
