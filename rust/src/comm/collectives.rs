//! MPI-style collectives over a [`Group`], built from real point-to-point
//! messages with the same algorithmic structure MPICH uses:
//!
//! * broadcast / reduce — binomial trees (`log P` rounds);
//! * allreduce — reduce-to-0 + broadcast;
//! * allgather — bandwidth-optimal ring (`P-1` rounds);
//! * gather / scatter — linear (our payloads are long tiles, where MPICH
//!   also switches to linear);
//! * barrier — dissemination.
//!
//! Because each tree edge is an actual message through the transport, the
//! virtual clock picks up the right `alpha·log P + bytes·beta` cost shape
//! without a separate collective cost model.

use super::message::{Payload, Tag};
use super::transport::Group;
use crate::Scalar;

/// Element-wise reduction operators.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceOp {
    /// Element-wise sum.
    Sum,
    /// Element-wise max.
    Max,
    /// Element-wise min.
    Min,
}

impl ReduceOp {
    fn combine<S: Scalar>(self, a: S, b: S) -> S {
        match self {
            ReduceOp::Sum => a + b,
            ReduceOp::Max => {
                if b > a { b } else { a }
            }
            ReduceOp::Min => {
                if b < a { b } else { a }
            }
        }
    }

    fn combine_vec<S: Scalar>(self, acc: &mut [S], other: &[S]) {
        assert_eq!(acc.len(), other.len(), "reduce length mismatch");
        for (a, &b) in acc.iter_mut().zip(other) {
            *a = self.combine(*a, b);
        }
    }
}

impl<'a, S: Scalar> Group<'a, S> {
    /// Binomial-tree broadcast from group rank `root`.  `data` is the
    /// payload on the root and ignored elsewhere; every rank returns the
    /// broadcast payload.
    pub fn bcast(&self, root: usize, tag: u32, data: Option<Payload<S>>) -> Payload<S> {
        let p = self.size();
        let me = self.rank();
        if p == 1 {
            return data.expect("bcast root must supply data");
        }
        let rel = (me + p - root) % p;
        let mut payload = if me == root {
            Some(data.expect("bcast root must supply data"))
        } else {
            None
        };
        // Receive phase: find the bit on which this rank receives.
        let mut mask = 1usize;
        while mask < p {
            if rel & mask != 0 {
                let src = (me + p - mask) % p;
                payload = Some(self.comm().recv(self.world_rank(src), Tag::Bcast(tag)));
                break;
            }
            mask <<= 1;
        }
        // Send phase: forward down the tree.
        let pl = payload.expect("binomial bcast bookkeeping");
        let mut mask = mask >> 1;
        while mask > 0 {
            if rel + mask < p {
                let dst = (me + mask) % p;
                self.comm().send(self.world_rank(dst), Tag::Bcast(tag), pl.clone());
            }
            mask >>= 1;
        }
        pl
    }

    /// Binomial-tree element-wise reduction of equal-length vectors to group
    /// rank `root`.  Returns `Some(result)` on the root, `None` elsewhere.
    pub fn reduce_vec(
        &self,
        root: usize,
        tag: u32,
        mut mine: Vec<S>,
        op: ReduceOp,
    ) -> Option<Vec<S>> {
        let p = self.size();
        let me = self.rank();
        if p == 1 {
            return Some(mine);
        }
        let rel = (me + p - root) % p;
        let mut mask = 1usize;
        while mask < p {
            if rel & mask == 0 {
                let peer_rel = rel | mask;
                if peer_rel < p {
                    let src = (peer_rel + root) % p;
                    let other =
                        self.comm().recv(self.world_rank(src), Tag::Reduce(tag)).into_data();
                    op.combine_vec(&mut mine, &other);
                }
            } else {
                let dst = (rel - mask + root) % p;
                self.comm().send(self.world_rank(dst), Tag::Reduce(tag), Payload::Data(mine));
                return None;
            }
            mask <<= 1;
        }
        Some(mine)
    }

    /// Allreduce of equal-length vectors: reduce to rank 0, then broadcast.
    pub fn allreduce_vec(&self, tag: u32, mine: Vec<S>, op: ReduceOp) -> Vec<S> {
        let reduced = self.reduce_vec(0, tag, mine, op);
        self.bcast(0, tag, reduced.map(Payload::Data)).into_data()
    }

    /// Allreduce of a single scalar.
    pub fn allreduce_scalar(&self, tag: u32, mine: S, op: ReduceOp) -> S {
        self.allreduce_vec(tag, vec![mine], op)[0]
    }

    /// Allreduce of an (|value|, index) pair under max-abs — the pivot search
    /// of distributed partial pivoting (MPI_MAXLOC).  Ties break toward the
    /// smaller index so every rank picks the identical pivot.
    pub fn allreduce_maxabsloc(&self, tag: u32, value: S, index: i64) -> (S, i64) {
        // Pack as two lanes; combine manually via gather-to-0 + bcast on a
        // binomial tree (reuse reduce machinery with a custom fold).
        let p = self.size();
        let me = self.rank();
        let mut best = (value, index);
        if p > 1 {
            let rel = me; // root 0
            let mut mask = 1usize;
            let mut sent = false;
            while mask < p && !sent {
                if rel & mask == 0 {
                    let peer = rel | mask;
                    if peer < p {
                        let data =
                            self.comm().recv(self.world_rank(peer), Tag::Reduce(tag)).into_data();
                        let (v, i) = (data[0], data[1].to_f64().unwrap() as i64);
                        if v.abs() > best.0.abs()
                            || (v.abs() == best.0.abs() && i < best.1)
                        {
                            best = (v, i);
                        }
                    }
                } else {
                    let dst = rel & !mask;
                    let enc = vec![best.0, S::from_f64(best.1 as f64).unwrap()];
                    self.comm().send(self.world_rank(dst), Tag::Reduce(tag), Payload::Data(enc));
                    sent = true;
                }
                mask <<= 1;
            }
            let enc = if me == 0 {
                Some(Payload::Data(vec![best.0, S::from_f64(best.1 as f64).unwrap()]))
            } else {
                None
            };
            let out = self.bcast(0, tag, enc).into_data();
            best = (out[0], out[1].to_f64().unwrap() as i64);
        }
        best
    }

    /// Ring allgather: every rank contributes `mine`; everyone returns all
    /// contributions indexed by group rank.  Block lengths may differ.
    pub fn allgather(&self, tag: u32, mine: Vec<S>) -> Vec<Vec<S>> {
        let p = self.size();
        let me = self.rank();
        let mut blocks: Vec<Option<Vec<S>>> = (0..p).map(|_| None).collect();
        blocks[me] = Some(mine);
        let next = (me + 1) % p;
        let prev = (me + p - 1) % p;
        for r in 0..p.saturating_sub(1) {
            // Send the block that originated at (me - r), receive the one
            // that originated at (prev - r) == (me - r - 1).
            let send_origin = (me + p - r % p) % p;
            let recv_origin = (me + p - r % p + p - 1) % p;
            let out = blocks[send_origin].clone().expect("ring allgather order");
            self.comm().send(self.world_rank(next), Tag::AllGather(tag), Payload::Data(out));
            let got = self.comm().recv(self.world_rank(prev), Tag::AllGather(tag)).into_data();
            blocks[recv_origin] = Some(got);
        }
        blocks.into_iter().map(|b| b.expect("ring allgather complete")).collect()
    }

    /// Linear gather to group rank `root`: root returns all blocks indexed by
    /// group rank, others return `None`.
    pub fn gather(&self, root: usize, tag: u32, mine: Vec<S>) -> Option<Vec<Vec<S>>> {
        let p = self.size();
        let me = self.rank();
        if me != root {
            self.comm().send(self.world_rank(root), Tag::Gather(tag), Payload::Data(mine));
            return None;
        }
        let mut out: Vec<Vec<S>> = (0..p).map(|_| Vec::new()).collect();
        out[me] = mine;
        for src in 0..p {
            if src != me {
                out[src] = self.comm().recv(self.world_rank(src), Tag::Gather(tag)).into_data();
            }
        }
        Some(out)
    }

    /// Linear scatter from `root`: root supplies one block per group rank;
    /// every rank returns its block.
    pub fn scatter(&self, root: usize, tag: u32, blocks: Option<Vec<Vec<S>>>) -> Vec<S> {
        let p = self.size();
        let me = self.rank();
        if me == root {
            let mut blocks = blocks.expect("scatter root must supply blocks");
            assert_eq!(blocks.len(), p, "scatter needs one block per rank");
            let mut own = Vec::new();
            for (dst, block) in blocks.drain(..).enumerate() {
                if dst == me {
                    own = block;
                } else {
                    self.comm().send(self.world_rank(dst), Tag::Scatter(tag), Payload::Data(block));
                }
            }
            own
        } else {
            self.comm().recv(self.world_rank(root), Tag::Scatter(tag)).into_data()
        }
    }

    /// Dissemination barrier (works for any group size).
    pub fn barrier(&self, tag: u32) {
        let p = self.size();
        let me = self.rank();
        let mut k = 0u32;
        let mut dist = 1usize;
        while dist < p {
            let dst = (me + dist) % p;
            let src = (me + p - dist) % p;
            self.comm().send(self.world_rank(dst), Tag::Barrier(tag + k), Payload::Empty);
            self.comm().recv(self.world_rank(src), Tag::Barrier(tag + k));
            dist <<= 1;
            k += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{NetworkModel, World};

    fn run<R: Send>(p: usize, f: impl Fn(crate::comm::Comm<f64>) -> R + Send + Sync) -> Vec<R> {
        World::run::<f64, _, _>(p, NetworkModel::ideal(), f)
    }

    #[test]
    fn bcast_all_sizes_all_roots() {
        for p in [1usize, 2, 3, 4, 5, 8] {
            for root in 0..p {
                let out = run(p, move |comm| {
                    let g = comm.world();
                    let data = if comm.rank() == root {
                        Some(Payload::Data(vec![42.0, root as f64]))
                    } else {
                        None
                    };
                    g.bcast(root, 1, data).into_data()
                });
                for v in out {
                    assert_eq!(v, vec![42.0, root as f64], "p={p} root={root}");
                }
            }
        }
    }

    #[test]
    fn reduce_sum_matches() {
        for p in [1usize, 2, 3, 4, 7, 8] {
            let out = run(p, move |comm| {
                let g = comm.world();
                let mine = vec![comm.rank() as f64, 1.0];
                g.reduce_vec(0, 2, mine, ReduceOp::Sum)
            });
            let expect_sum: f64 = (0..p).map(|r| r as f64).sum();
            assert_eq!(out[0].as_ref().unwrap(), &vec![expect_sum, p as f64]);
            for r in 1..p {
                assert!(out[r].is_none());
            }
        }
    }

    #[test]
    fn allreduce_ops() {
        for p in [2usize, 3, 4, 6] {
            let out = run(p, move |comm| {
                let g = comm.world();
                let r = comm.rank() as f64;
                (
                    g.allreduce_scalar(3, r, ReduceOp::Sum),
                    g.allreduce_scalar(4, r, ReduceOp::Max),
                    g.allreduce_scalar(5, r, ReduceOp::Min),
                )
            });
            let sum: f64 = (0..p).map(|r| r as f64).sum();
            for (s, mx, mn) in out {
                assert_eq!(s, sum);
                assert_eq!(mx, (p - 1) as f64);
                assert_eq!(mn, 0.0);
            }
        }
    }

    #[test]
    fn maxabsloc_picks_global_pivot() {
        for p in [1usize, 2, 3, 5, 8] {
            let out = run(p, move |comm| {
                let g = comm.world();
                // rank r contributes value (-1)^r * r with index 100 + r.
                let r = comm.rank();
                let v = if r % 2 == 0 { r as f64 } else { -(r as f64) };
                g.allreduce_maxabsloc(6, v, 100 + r as i64)
            });
            let best = (p - 1) as f64;
            for (v, i) in out {
                assert_eq!(v.abs(), best, "p={p}");
                assert_eq!(i, 100 + (p - 1) as i64);
            }
        }
    }

    #[test]
    fn allgather_ring() {
        for p in [1usize, 2, 3, 4, 5] {
            let out = run(p, move |comm| {
                let g = comm.world();
                // variable-length contribution: rank r sends r+1 copies of r.
                let mine = vec![comm.rank() as f64; comm.rank() + 1];
                g.allgather(7, mine)
            });
            for blocks in out {
                assert_eq!(blocks.len(), p);
                for (r, b) in blocks.iter().enumerate() {
                    assert_eq!(b, &vec![r as f64; r + 1]);
                }
            }
        }
    }

    #[test]
    fn gather_scatter_roundtrip() {
        for p in [1usize, 2, 4, 5] {
            for root in 0..p {
                let out = run(p, move |comm| {
                    let g = comm.world();
                    let mine = vec![comm.rank() as f64 * 10.0];
                    let gathered = g.gather(root, 8, mine);
                    // root redistributes doubled blocks
                    let blocks = gathered.map(|bs| {
                        bs.into_iter()
                            .map(|b| b.iter().map(|x| x * 2.0).collect())
                            .collect::<Vec<_>>()
                    });
                    g.scatter(root, 9, blocks)
                });
                for (r, b) in out.iter().enumerate() {
                    assert_eq!(b, &vec![r as f64 * 20.0], "p={p} root={root}");
                }
            }
        }
    }

    #[test]
    fn barrier_synchronises_clocks() {
        let net = NetworkModel::gigabit_ethernet();
        let out = World::run::<f64, _, _>(4, net, |comm| {
            // Rank 2 is slow.
            if comm.rank() == 2 {
                comm.clock().advance_compute(1.0);
            }
            comm.world().barrier(20);
            comm.clock().now()
        });
        for t in &out {
            assert!(*t >= 1.0, "barrier must not complete before slowest rank: {out:?}");
        }
    }

    #[test]
    fn bcast_cost_scales_log_p() {
        // Under the alpha-beta model, a small-message bcast over p ranks
        // costs ~ceil(log2 p) * alpha on the critical path.
        let net = NetworkModel::gigabit_ethernet();
        let mut costs = Vec::new();
        for p in [2usize, 4, 8, 16] {
            let out = World::run::<f64, _, _>(p, net, |comm| {
                let g = comm.world();
                let data =
                    if comm.rank() == 0 { Some(Payload::Scalar(1.0)) } else { None };
                g.bcast(0, 1, data);
                comm.clock().now()
            });
            costs.push(out.iter().cloned().fold(0.0, f64::max));
        }
        // log2: 1, 2, 3, 4 rounds.
        for (i, c) in costs.iter().enumerate() {
            let rounds = (i + 1) as f64;
            assert!(
                (*c - rounds * net.alpha).abs() < net.alpha * 0.51,
                "p=2^{} cost {c} vs {} rounds",
                i + 1,
                rounds,
            );
        }
    }
}
