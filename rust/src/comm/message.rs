//! Typed messages exchanged between ranks.

use crate::Scalar;

/// Message tags: every distinct communication context gets its own tag so a
/// mismatched send/recv pair fails loudly instead of silently crossing.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Tag {
    /// Point-to-point data transfer (dist/redistribute, row swaps...).
    P2p(u32),
    /// Broadcast tree edges.
    Bcast(u32),
    /// Reduce tree edges.
    Reduce(u32),
    /// All-gather rounds.
    AllGather(u32),
    /// Scatter tree edges.
    Scatter(u32),
    /// Gather tree edges.
    Gather(u32),
    /// Barrier rounds.
    Barrier(u32),
    /// Pivot-row exchange during LU.
    PivotSwap(u32),
}

/// Message payloads.  `Vec<S>` covers matrix/vector tiles; the integer
/// variants carry pivot indices and control data.
#[derive(Clone, Debug)]
pub enum Payload<S: Scalar> {
    /// Dense scalar data (tiles, vector blocks, partial sums).
    Data(Vec<S>),
    /// A single scalar (dot products, norms, convergence flags).
    Scalar(S),
    /// Integer data (pivot vectors, dimensions).
    Ints(Vec<i64>),
    /// Wide-accumulation data: `S::Hi` values crossing a world whose
    /// working dtype is `S`.  The mixed-precision refinement loop runs in
    /// the *reduced* dtype's world but must ship its f64 solution vector
    /// between ranks for the residual — this variant prices those elements
    /// at the wide width instead of `S::BYTES`.
    Hi(Vec<<S as Scalar>::Hi>),
    /// Empty (barrier tokens).
    Empty,
}

impl<S: Scalar> Payload<S> {
    /// Payload size in bytes as it would cross the wire (element bytes only;
    /// the alpha term of the network model covers per-message framing).
    pub fn wire_bytes(&self) -> usize {
        match self {
            Payload::Data(v) => v.len() * S::BYTES,
            Payload::Scalar(_) => S::BYTES,
            Payload::Ints(v) => v.len() * 8,
            Payload::Hi(v) => v.len() * <S::Hi as Scalar>::BYTES,
            Payload::Empty => 0,
        }
    }

    /// Unwrap `Data`, panicking with context otherwise (a tag mismatch is a
    /// library bug, not a user error).
    pub fn into_data(self) -> Vec<S> {
        match self {
            Payload::Data(v) => v,
            other => panic!("expected Payload::Data, got {other:?}"),
        }
    }

    /// Unwrap `Scalar`.
    pub fn into_scalar(self) -> S {
        match self {
            Payload::Scalar(s) => s,
            other => panic!("expected Payload::Scalar, got {other:?}"),
        }
    }

    /// Unwrap `Ints`.
    pub fn into_ints(self) -> Vec<i64> {
        match self {
            Payload::Ints(v) => v,
            other => panic!("expected Payload::Ints, got {other:?}"),
        }
    }

    /// Unwrap `Hi`.
    pub fn into_hi(self) -> Vec<<S as Scalar>::Hi> {
        match self {
            Payload::Hi(v) => v,
            other => panic!("expected Payload::Hi, got {other:?}"),
        }
    }
}

/// A message in flight: payload + tag + virtual arrival time.
#[derive(Debug)]
pub struct Message<S: Scalar> {
    /// Sending rank (world numbering).
    pub src: usize,
    /// Communication context tag.
    pub tag: Tag,
    /// The data.
    pub payload: Payload<S>,
    /// Virtual time at which this message arrives at the receiver
    /// (sender clock at send + network model transfer cost).
    pub arrival: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_bytes() {
        let p: Payload<f32> = Payload::Data(vec![0.0; 10]);
        assert_eq!(p.wire_bytes(), 40);
        let p: Payload<f64> = Payload::Data(vec![0.0; 10]);
        assert_eq!(p.wire_bytes(), 80);
        let p: Payload<f64> = Payload::Scalar(1.0);
        assert_eq!(p.wire_bytes(), 8);
        let p: Payload<f32> = Payload::Ints(vec![1, 2, 3]);
        assert_eq!(p.wire_bytes(), 24);
        let p: Payload<f32> = Payload::Empty;
        assert_eq!(p.wire_bytes(), 0);
        // Hi elements always price at the wide width, even in an f32 world.
        let p: Payload<f32> = Payload::Hi(vec![0.0f64; 10]);
        assert_eq!(p.wire_bytes(), 80);
        let p: Payload<f64> = Payload::Hi(vec![0.0f64; 10]);
        assert_eq!(p.wire_bytes(), 80);
    }

    #[test]
    fn unwrap_helpers() {
        let p: Payload<f64> = Payload::Data(vec![1.0, 2.0]);
        assert_eq!(p.into_data(), vec![1.0, 2.0]);
        let p: Payload<f64> = Payload::Scalar(3.0);
        assert_eq!(p.into_scalar(), 3.0);
        let p: Payload<f64> = Payload::Ints(vec![7]);
        assert_eq!(p.into_ints(), vec![7]);
        let p: Payload<f32> = Payload::Hi(vec![1.5f64]);
        assert_eq!(p.into_hi(), vec![1.5f64]);
    }

    #[test]
    #[should_panic(expected = "expected Payload::Data")]
    fn unwrap_mismatch_panics() {
        let p: Payload<f64> = Payload::Empty;
        p.into_data();
    }

    #[test]
    fn tags_distinct() {
        assert_ne!(Tag::P2p(1), Tag::P2p(2));
        assert_ne!(Tag::Bcast(1), Tag::Reduce(1));
    }
}
