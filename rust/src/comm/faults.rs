//! Deterministic fault injection: an explicit, RNG-free schedule of
//! failures consumed by the transport and the virtual clock.
//!
//! The paper's cluster (and this simulator through PR 9) assumes every
//! rank, link and device is perfect forever.  A [`FaultPlan`] breaks that
//! assumption *reproducibly*: each event names a rank/route and a virtual
//! time or ordinal, so the same plan replays the same failure sequence on
//! every run — failures are part of the schedule, not noise.  The plan is
//! threaded through [`super::transport::World::run_with_faults`]; an empty
//! plan is pinned **bit-identical** to running with no fault layer at all
//! (every hook either short-circuits before touching a float or applies an
//! exact `× 1.0`), so the fault-free hot paths pay nothing (DESIGN.md §18).
//!
//! Event semantics:
//!
//! * **crash** — the rank's device/solver state is lost at virtual time
//!   `t`.  Detection is cooperative: solvers probe at checkpoint
//!   boundaries ([`crate::pblas::fault_probe`]); the crashed rank pays
//!   [`FaultPlan::reboot_secs`] and every rank rolls back to the last
//!   checkpoint ([`CheckpointPolicy`]).
//! * **drop** — the `nth` point-to-point send on a route is lost `times`
//!   consecutive times; the transport re-flies it after a timeout that
//!   doubles per attempt (bounded exponential backoff), priced on the NIC
//!   timeline and counted in `CommStats::{retries, timeout_secs}`.
//! * **degrade** — a rank's NIC serialises `factor×` slower over a virtual
//!   time window (a flapping or congested link).
//! * **slow** — a straggler: the rank's compute timeline advances `rate×`
//!   slower for the whole run ([`super::VClock::set_compute_rate`]).
//! * **ecc** — device "ECC page retirement": the rank's usable device
//!   memory budget shrinks to `keep_bytes`, forcing the residency layer
//!   to evict harder.  Never changes results, only PCIe traffic.

use crate::{Error, Result};

/// One scripted failure.  Times are virtual seconds on the affected
/// rank's clock; ordinals count that route's remote sends from 1.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultEvent {
    /// Rank `rank` loses its device/solver state at virtual time `at`.
    RankCrash { rank: usize, at: f64 },
    /// Rank `rank`'s NIC serialises `factor`× slower over `[from, until)`.
    LinkDegrade { rank: usize, factor: f64, from: f64, until: f64 },
    /// The `nth` remote send from `src` to `dst` is lost `times`
    /// consecutive times before going through.
    MessageDrop { src: usize, dst: usize, nth: u64, times: u32 },
    /// Rank `rank`'s device memory budget shrinks to `keep_bytes`.
    EccRetirement { rank: usize, keep_bytes: usize },
    /// Rank `rank` computes `rate`× slower for the whole run.
    Straggler { rank: usize, rate: f64 },
}

/// A deterministic failure schedule plus the recovery-pricing knobs.
///
/// Build programmatically ([`FaultPlan::push`]) or from the compact DSL
/// ([`FaultPlan::parse`]) used by `--fault-plan` / `cluster.fault_plan`:
/// `;`-separated events —
///
/// ```text
/// crash:RANK@T           rank crash at virtual time T
/// slow:RANKxRATE         straggler (compute RATE× slower)
/// degrade:RANKxF@T0-T1   link F× slower over [T0, T1)
/// drop:SRC-DST#NTH       drop the NTH send on the route once
/// drop:SRC-DST#NTHxK     ... K consecutive times
/// ecc:RANK@BYTES         shrink device memory to BYTES
/// timeout:SECS           base retry timeout (default 1e-3)
/// reboot:SECS            crash reboot cost (default 0.5)
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// The scripted events, in declaration order.
    pub events: Vec<FaultEvent>,
    /// Base send-timeout before the first retry; doubles per attempt.
    pub retry_timeout: f64,
    /// Virtual seconds a crashed rank spends rebooting before it rejoins
    /// the recovery protocol.
    pub reboot_secs: f64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self { events: Vec::new(), retry_timeout: 1e-3, reboot_secs: 0.5 }
    }
}

impl FaultPlan {
    /// The empty plan (no events; bit-identical to no fault layer).
    pub fn new() -> Self {
        Self::default()
    }

    /// Append an event (builder style).
    pub fn push(mut self, event: FaultEvent) -> Self {
        self.events.push(event);
        self
    }

    /// No events scripted: every transport hook short-circuits.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Whether any rank crash is scripted (gates the solvers' probe
    /// collectives, so crash-free plans add zero probe traffic).
    pub fn has_crashes(&self) -> bool {
        self.events.iter().any(|e| matches!(e, FaultEvent::RankCrash { .. }))
    }

    /// Scripted crash times for `rank`, sorted ascending.
    pub fn crash_times(&self, rank: usize) -> Vec<f64> {
        let mut times: Vec<f64> = self
            .events
            .iter()
            .filter_map(|e| match e {
                FaultEvent::RankCrash { rank: r, at } if *r == rank => Some(*at),
                _ => None,
            })
            .collect();
        times.sort_by(|a, b| a.partial_cmp(b).expect("crash time NaN"));
        times
    }

    /// The rank's compute-rate multiplier (product of its straggler
    /// events; 1.0 when none).
    pub fn compute_rate(&self, rank: usize) -> f64 {
        self.events
            .iter()
            .filter_map(|e| match e {
                FaultEvent::Straggler { rank: r, rate } if *r == rank => Some(*rate),
                _ => None,
            })
            .product()
    }

    /// The rank's NIC slowdown factor at virtual time `at` (product of
    /// the degrade windows covering `at`; 1.0 when none).
    pub fn degrade_factor(&self, rank: usize, at: f64) -> f64 {
        self.events
            .iter()
            .filter_map(|e| match e {
                FaultEvent::LinkDegrade { rank: r, factor, from, until }
                    if *r == rank && at >= *from && at < *until =>
                {
                    Some(*factor)
                }
                _ => None,
            })
            .product()
    }

    /// How many consecutive times the `nth` remote send from `src` to
    /// `dst` is scripted to drop (sum over matching events; 0 when none).
    pub fn drops(&self, src: usize, dst: usize, nth: u64) -> u32 {
        self.events
            .iter()
            .filter_map(|e| match e {
                FaultEvent::MessageDrop { src: s, dst: d, nth: n, times }
                    if *s == src && *d == dst && *n == nth =>
                {
                    Some(*times)
                }
                _ => None,
            })
            .sum()
    }

    /// The rank's usable device-memory budget after ECC retirements
    /// (minimum over matching events; `usize::MAX` when none).
    pub fn keep_bytes(&self, rank: usize) -> usize {
        self.events
            .iter()
            .filter_map(|e| match e {
                FaultEvent::EccRetirement { rank: r, keep_bytes } if *r == rank => {
                    Some(*keep_bytes)
                }
                _ => None,
            })
            .min()
            .unwrap_or(usize::MAX)
    }

    /// Parse the `--fault-plan` DSL (see the type docs for the grammar).
    pub fn parse(spec: &str) -> Result<Self> {
        let mut plan = FaultPlan::default();
        for item in spec.split(';') {
            let item = item.trim();
            if item.is_empty() {
                continue;
            }
            let (kind, body) = item
                .split_once(':')
                .ok_or_else(|| bad_plan(item, "expected KIND:ARGS"))?;
            match kind.trim() {
                "crash" => {
                    let (rank, at) = split2(body, '@', item)?;
                    plan.events.push(FaultEvent::RankCrash {
                        rank: parse_usize(rank, item)?,
                        at: parse_f64(at, item)?,
                    });
                }
                "slow" => {
                    let (rank, rate) = split2(body, 'x', item)?;
                    plan.events.push(FaultEvent::Straggler {
                        rank: parse_usize(rank, item)?,
                        rate: parse_f64(rate, item)?,
                    });
                }
                "degrade" => {
                    let (head, window) = split2(body, '@', item)?;
                    let (rank, factor) = split2(head, 'x', item)?;
                    let (from, until) = split2(window, '-', item)?;
                    plan.events.push(FaultEvent::LinkDegrade {
                        rank: parse_usize(rank, item)?,
                        factor: parse_f64(factor, item)?,
                        from: parse_f64(from, item)?,
                        until: parse_f64(until, item)?,
                    });
                }
                "drop" => {
                    let (route, ordinal) = split2(body, '#', item)?;
                    let (src, dst) = split2(route, '-', item)?;
                    let (nth, times) = match ordinal.split_once('x') {
                        Some((n, k)) => (n, parse_u32(k, item)?),
                        None => (ordinal, 1),
                    };
                    plan.events.push(FaultEvent::MessageDrop {
                        src: parse_usize(src, item)?,
                        dst: parse_usize(dst, item)?,
                        nth: parse_u64(nth, item)?,
                        times,
                    });
                }
                "ecc" => {
                    let (rank, bytes) = split2(body, '@', item)?;
                    plan.events.push(FaultEvent::EccRetirement {
                        rank: parse_usize(rank, item)?,
                        keep_bytes: parse_usize(bytes, item)?,
                    });
                }
                "timeout" => plan.retry_timeout = parse_f64(body, item)?,
                "reboot" => plan.reboot_secs = parse_f64(body, item)?,
                other => {
                    return Err(bad_plan(item, &format!("unknown event kind `{other}`")));
                }
            }
        }
        if plan.retry_timeout <= 0.0 {
            return Err(Error::Config("fault plan: retry timeout must be positive".into()));
        }
        if plan.reboot_secs < 0.0 {
            return Err(Error::Config("fault plan: reboot cost must be >= 0".into()));
        }
        Ok(plan)
    }
}

/// Checkpoint cadence for the fault-tolerant direct factorizations (and,
/// by the same parameter, the Krylov snapshot interval): solver state is
/// snapshotted every `every_k_panels` panels (iterations), so a crash
/// costs at most that much rework plus the reboot and restore traffic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CheckpointPolicy {
    /// Panels (direct) or iterations (Krylov) between checkpoints; >= 1.
    pub every_k_panels: usize,
}

impl CheckpointPolicy {
    /// Checkpoint every `k` panels/iterations (`k` is clamped to >= 1).
    pub fn every(k: usize) -> Self {
        Self { every_k_panels: k.max(1) }
    }
}

fn bad_plan(item: &str, detail: &str) -> Error {
    Error::Config(format!("fault plan item `{item}`: {detail}"))
}

fn split2<'a>(s: &'a str, sep: char, item: &str) -> Result<(&'a str, &'a str)> {
    s.split_once(sep)
        .ok_or_else(|| bad_plan(item, &format!("expected `{sep}` separator")))
}

fn parse_usize(s: &str, item: &str) -> Result<usize> {
    s.trim().parse().map_err(|_| bad_plan(item, &format!("bad integer `{s}`")))
}

fn parse_u64(s: &str, item: &str) -> Result<u64> {
    s.trim().parse().map_err(|_| bad_plan(item, &format!("bad integer `{s}`")))
}

fn parse_u32(s: &str, item: &str) -> Result<u32> {
    s.trim().parse().map_err(|_| bad_plan(item, &format!("bad integer `{s}`")))
}

fn parse_f64(s: &str, item: &str) -> Result<f64> {
    s.trim().parse().map_err(|_| bad_plan(item, &format!("bad number `{s}`")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_is_empty() {
        let plan = FaultPlan::default();
        assert!(plan.is_empty());
        assert!(!plan.has_crashes());
        assert_eq!(plan.compute_rate(3), 1.0);
        assert_eq!(plan.degrade_factor(0, 1.0), 1.0);
        assert_eq!(plan.drops(0, 1, 5), 0);
        assert_eq!(plan.keep_bytes(2), usize::MAX);
        assert!(plan.crash_times(0).is_empty());
    }

    #[test]
    fn parse_full_grammar() {
        let plan = FaultPlan::parse(
            "crash:2@0.5; slow:3x1.5; degrade:1x2.0@0.1-0.9; \
             drop:0-1#3x2; drop:0-1#7; ecc:0@1048576; timeout:2e-3; reboot:0.25",
        )
        .unwrap();
        assert_eq!(plan.events.len(), 6);
        assert!(plan.has_crashes());
        assert_eq!(plan.crash_times(2), vec![0.5]);
        assert!(plan.crash_times(0).is_empty());
        assert_eq!(plan.compute_rate(3), 1.5);
        assert_eq!(plan.compute_rate(2), 1.0);
        assert_eq!(plan.degrade_factor(1, 0.5), 2.0);
        assert_eq!(plan.degrade_factor(1, 0.95), 1.0); // outside the window
        assert_eq!(plan.drops(0, 1, 3), 2);
        assert_eq!(plan.drops(0, 1, 7), 1);
        assert_eq!(plan.drops(1, 0, 3), 0); // routes are directed
        assert_eq!(plan.keep_bytes(0), 1048576);
        assert_eq!(plan.retry_timeout, 2e-3);
        assert_eq!(plan.reboot_secs, 0.25);
    }

    #[test]
    fn parse_rejects_malformed_items() {
        assert!(FaultPlan::parse("crash:2").is_err()); // missing @T
        assert!(FaultPlan::parse("boom:1@2").is_err()); // unknown kind
        assert!(FaultPlan::parse("drop:0-1").is_err()); // missing #N
        assert!(FaultPlan::parse("timeout:0").is_err()); // non-positive
        assert!(FaultPlan::parse("crash:x@1").is_err()); // bad integer
    }

    #[test]
    fn parse_empty_spec_is_the_empty_plan() {
        assert_eq!(FaultPlan::parse("").unwrap(), FaultPlan::default());
        assert_eq!(FaultPlan::parse(" ; ;").unwrap(), FaultPlan::default());
    }

    #[test]
    fn overlapping_degrade_windows_compound() {
        let plan = FaultPlan::parse("degrade:0x2.0@0.0-1.0; degrade:0x3.0@0.5-2.0").unwrap();
        assert_eq!(plan.degrade_factor(0, 0.25), 2.0);
        assert_eq!(plan.degrade_factor(0, 0.75), 6.0);
        assert_eq!(plan.degrade_factor(0, 1.5), 3.0);
    }

    #[test]
    fn checkpoint_policy_clamps() {
        assert_eq!(CheckpointPolicy::every(0).every_k_panels, 1);
        assert_eq!(CheckpointPolicy::every(8).every_k_panels, 8);
    }

    #[test]
    fn crash_times_sorted() {
        let plan = FaultPlan::parse("crash:1@2.0; crash:1@0.5; crash:0@1.0").unwrap();
        assert_eq!(plan.crash_times(1), vec![0.5, 2.0]);
        assert_eq!(plan.crash_times(0), vec![1.0]);
    }
}
