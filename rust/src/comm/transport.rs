//! In-process transport: the `World` of ranks and each rank's `Comm` endpoint.
//!
//! A [`World`] owns a full mesh of lossless FIFO channels (one per ordered
//! rank pair, like MPI's reliable transport).  [`World::run`] spawns one OS
//! thread per rank and hands each a [`Comm`] endpoint — the analogue of
//! `MPI_COMM_WORLD` after `MPI_Init`.
//!
//! [`Comm::group`] carves out sub-communicators (the 2-D mesh's row/col
//! communicators) by rank translation, without extra channels — exactly how
//! `MPI_Comm_split` behaves from the user's point of view.

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::sync::mpsc;

use super::clock::VClock;
use super::message::{Message, Payload, Tag};
use super::model::NetworkModel;
use crate::Scalar;

/// Per-endpoint traffic statistics (virtual *and* wall time are tracked; the
/// wall numbers feed the calibration experiment E8).
#[derive(Debug, Default)]
pub struct CommStats {
    msgs_sent: Cell<u64>,
    bytes_sent: Cell<u64>,
    wall_wait: Cell<f64>,
}

impl CommStats {
    /// Messages sent from this endpoint.
    pub fn msgs_sent(&self) -> u64 {
        self.msgs_sent.get()
    }

    /// Payload bytes sent from this endpoint.
    pub fn bytes_sent(&self) -> u64 {
        self.bytes_sent.get()
    }

    /// Wall-clock seconds spent blocked in `recv`.
    pub fn wall_wait_secs(&self) -> f64 {
        self.wall_wait.get()
    }
}

struct PendingRx<S: Scalar> {
    rx: mpsc::Receiver<Message<S>>,
    /// Messages received but not yet claimed (tag mismatch buffering).
    pending: VecDeque<Message<S>>,
}

/// One rank's endpoint: owned by that rank's thread, never shared.
pub struct Comm<S: Scalar> {
    rank: usize,
    size: usize,
    /// senders[dst]: channel from this rank to `dst`.
    senders: Vec<mpsc::Sender<Message<S>>>,
    /// receivers[src]: channel from `src` to this rank.
    receivers: Vec<RefCell<PendingRx<S>>>,
    clock: VClock,
    net: NetworkModel,
    stats: CommStats,
}

impl<S: Scalar> Comm<S> {
    /// This endpoint's world rank.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// World size.
    pub fn size(&self) -> usize {
        self.size
    }

    /// The rank's virtual clock.
    pub fn clock(&self) -> &VClock {
        &self.clock
    }

    /// The network model in force.
    pub fn net(&self) -> &NetworkModel {
        self.net_ref()
    }

    fn net_ref(&self) -> &NetworkModel {
        &self.net
    }

    /// Traffic statistics.
    pub fn stats(&self) -> &CommStats {
        &self.stats
    }

    /// Send `payload` to world rank `dst` under `tag`.
    ///
    /// LogGP semantics: the sender's clock advances by the NIC occupancy
    /// `beta * bytes` (back-to-back sends from one rank serialise at line
    /// rate, as on a real Gigabit NIC), then the message arrives at the
    /// receiver after the additional wire latency `alpha`.
    pub fn send(&self, dst: usize, tag: Tag, payload: Payload<S>) {
        assert!(dst < self.size, "send to rank {dst} of {}", self.size);
        let bytes = payload.wire_bytes();
        let arrival = if dst == self.rank {
            self.clock.now() + self.net.local_secs(bytes)
        } else {
            self.clock.advance_send(bytes as f64 * self.net.beta);
            self.clock.now() + self.net.alpha
        };
        self.stats.msgs_sent.set(self.stats.msgs_sent.get() + 1);
        self.stats.bytes_sent.set(self.stats.bytes_sent.get() + bytes as u64);
        let msg = Message { src: self.rank, tag, payload, arrival };
        // A send can only fail if the receiving rank already exited — that is
        // a protocol bug (mismatched collective participation), so panic.
        self.senders[dst]
            .send(msg)
            .unwrap_or_else(|_| panic!("rank {} send to dead rank {dst}", self.rank));
    }

    /// Blocking receive of the next message from `src` with `tag`.
    /// Messages from `src` with other tags are buffered, preserving FIFO per
    /// tag — mirroring MPI's (source, tag) matching.
    pub fn recv(&self, src: usize, tag: Tag) -> Payload<S> {
        assert!(src < self.size, "recv from rank {src} of {}", self.size);
        let mut rx = self.receivers[src].borrow_mut();
        // Buffered first.
        if let Some(pos) = rx.pending.iter().position(|m| m.tag == tag) {
            let msg = rx.pending.remove(pos).unwrap();
            self.clock.observe_arrival(msg.arrival);
            return msg.payload;
        }
        let sw = std::time::Instant::now();
        loop {
            let msg = rx
                .rx
                .recv()
                .unwrap_or_else(|_| panic!("rank {} recv from dead rank {src}", self.rank));
            if msg.tag == tag {
                self.stats
                    .wall_wait
                    .set(self.stats.wall_wait.get() + sw.elapsed().as_secs_f64());
                self.clock.observe_arrival(msg.arrival);
                return msg.payload;
            }
            rx.pending.push_back(msg);
        }
    }

    /// A sub-communicator over `ranks` (world numbering).  This rank must be
    /// a member.  Collectives and rank-translated send/recv live on the
    /// returned [`Group`].
    pub fn group<'a>(&'a self, ranks: &[usize]) -> Group<'a, S> {
        let me = ranks
            .iter()
            .position(|&r| r == self.rank)
            .unwrap_or_else(|| panic!("rank {} not in group {ranks:?}", self.rank));
        Group { comm: self, ranks: ranks.to_vec(), me }
    }

    /// The full world as a [`Group`].
    pub fn world(&self) -> Group<'_, S> {
        Group { comm: self, ranks: (0..self.size).collect(), me: self.rank }
    }
}

/// A sub-communicator view: group-rank numbering over a subset of the world.
pub struct Group<'a, S: Scalar> {
    pub(crate) comm: &'a Comm<S>,
    pub(crate) ranks: Vec<usize>,
    pub(crate) me: usize,
}

impl<'a, S: Scalar> Group<'a, S> {
    /// This rank's position within the group.
    pub fn rank(&self) -> usize {
        self.me
    }

    /// Group size.
    pub fn size(&self) -> usize {
        self.ranks.len()
    }

    /// Translate group rank to world rank.
    pub fn world_rank(&self, group_rank: usize) -> usize {
        self.ranks[group_rank]
    }

    /// The underlying endpoint.
    pub fn comm(&self) -> &'a Comm<S> {
        self.comm
    }

    /// Send to a group rank.
    pub fn send(&self, dst: usize, tag: Tag, payload: Payload<S>) {
        self.comm.send(self.ranks[dst], tag, payload);
    }

    /// Receive from a group rank.
    pub fn recv(&self, src: usize, tag: Tag) -> Payload<S> {
        self.comm.recv(self.ranks[src], tag)
    }
}

/// The simulated cluster: builds the channel mesh and runs one closure per
/// rank on its own OS thread.
pub struct World;

impl World {
    /// Run `f(comm)` on `p` ranks; returns each rank's result, indexed by
    /// rank.  Panics in any rank propagate (fail-fast, like an MPI abort).
    pub fn run<S, R, F>(p: usize, net: NetworkModel, f: F) -> Vec<R>
    where
        S: Scalar,
        R: Send,
        F: Fn(Comm<S>) -> R + Send + Sync,
    {
        assert!(p > 0, "world size must be positive");
        // channels[src][dst]
        let mut senders: Vec<Vec<mpsc::Sender<Message<S>>>> = Vec::with_capacity(p);
        let mut receivers: Vec<Vec<Option<mpsc::Receiver<Message<S>>>>> =
            (0..p).map(|_| (0..p).map(|_| None).collect()).collect();
        for src in 0..p {
            let mut row = Vec::with_capacity(p);
            for dst in 0..p {
                let (tx, rx) = mpsc::channel();
                row.push(tx);
                receivers[dst][src] = Some(rx);
            }
            senders.push(row);
        }
        let mut comms: Vec<Comm<S>> = senders
            .into_iter()
            .zip(receivers)
            .enumerate()
            .map(|(rank, (senders, rxs))| Comm {
                rank,
                size: p,
                senders,
                receivers: rxs
                    .into_iter()
                    .map(|rx| {
                        RefCell::new(PendingRx { rx: rx.unwrap(), pending: VecDeque::new() })
                    })
                    .collect(),
                clock: VClock::new(),
                net,
                stats: CommStats::default(),
            })
            .collect();

        std::thread::scope(|scope| {
            let f = &f;
            let handles: Vec<_> = comms
                .drain(..)
                .map(|comm| scope.spawn(move || f(comm)))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("rank thread panicked"))
                .collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p2p_roundtrip() {
        let results = World::run::<f64, _, _>(2, NetworkModel::ideal(), |comm| {
            if comm.rank() == 0 {
                comm.send(1, Tag::P2p(0), Payload::Data(vec![1.0, 2.0, 3.0]));
                comm.recv(1, Tag::P2p(1)).into_scalar()
            } else {
                let v = comm.recv(0, Tag::P2p(0)).into_data();
                let sum: f64 = v.iter().sum();
                comm.send(0, Tag::P2p(1), Payload::Scalar(sum));
                sum
            }
        });
        assert_eq!(results, vec![6.0, 6.0]);
    }

    #[test]
    fn tag_mismatch_buffers() {
        // Rank 0 sends tag B then tag A; rank 1 receives A first, then B.
        let results = World::run::<f64, _, _>(2, NetworkModel::ideal(), |comm| {
            if comm.rank() == 0 {
                comm.send(1, Tag::P2p(7), Payload::Scalar(7.0));
                comm.send(1, Tag::P2p(8), Payload::Scalar(8.0));
                0.0
            } else {
                let a = comm.recv(0, Tag::P2p(8)).into_scalar();
                let b = comm.recv(0, Tag::P2p(7)).into_scalar();
                a * 10.0 + b
            }
        });
        assert_eq!(results[1], 87.0);
    }

    #[test]
    fn virtual_clock_advances_on_recv() {
        let net = NetworkModel::gigabit_ethernet();
        let results = World::run::<f32, _, _>(2, net, |comm| {
            if comm.rank() == 0 {
                comm.send(1, Tag::P2p(0), Payload::Data(vec![0.0f32; 1 << 18])); // 1 MiB
                comm.clock().now()
            } else {
                comm.recv(0, Tag::P2p(0));
                comm.clock().now()
            }
        });
        // Sender pays the NIC occupancy (beta*bytes)...
        let occupy = (1u64 << 20) as f64 * net.beta;
        assert!((results[0] - occupy).abs() < 1e-12, "{} vs {occupy}", results[0]);
        // ...receiver sees occupancy + wire latency = the full alpha-beta cost.
        let expect = net.p2p_secs(1 << 20);
        assert!((results[1] - expect).abs() < 1e-9, "{} vs {expect}", results[1]);
    }

    #[test]
    fn group_rank_translation() {
        let results = World::run::<f64, _, _>(4, NetworkModel::ideal(), |comm| {
            // Group of even ranks {0, 2}: group rank 1 is world rank 2.
            if comm.rank() % 2 == 0 {
                let g = comm.group(&[0, 2]);
                if g.rank() == 0 {
                    g.send(1, Tag::P2p(0), Payload::Scalar(5.0));
                    0.0
                } else {
                    g.recv(0, Tag::P2p(0)).into_scalar()
                }
            } else {
                -1.0
            }
        });
        assert_eq!(results, vec![0.0, -1.0, 5.0, -1.0]);
    }

    #[test]
    fn stats_count_traffic() {
        let results = World::run::<f64, _, _>(2, NetworkModel::ideal(), |comm| {
            if comm.rank() == 0 {
                comm.send(1, Tag::P2p(0), Payload::Data(vec![0.0; 100]));
                (comm.stats().msgs_sent(), comm.stats().bytes_sent())
            } else {
                comm.recv(0, Tag::P2p(0));
                (0, 0)
            }
        });
        assert_eq!(results[0], (1, 800));
    }

    #[test]
    #[should_panic]
    fn group_requires_membership() {
        World::run::<f64, _, _>(2, NetworkModel::ideal(), |comm| {
            comm.group(&[1]); // rank 0 is not a member -> panic on rank 0
        });
    }
}
