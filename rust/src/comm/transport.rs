//! In-process transport: the `World` of ranks and each rank's `Comm` endpoint.
//!
//! A [`World`] owns a full mesh of lossless FIFO channels (one per ordered
//! rank pair, like MPI's reliable transport).  [`World::run`] spawns one OS
//! thread per rank and hands each a [`Comm`] endpoint — the analogue of
//! `MPI_COMM_WORLD` after `MPI_Init`.
//!
//! [`Comm::group`] carves out sub-communicators (the 2-D mesh's row/col
//! communicators) by rank translation, without extra channels — exactly how
//! `MPI_Comm_split` behaves from the user's point of view.

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::rc::Rc;
use std::sync::{mpsc, Arc};

use super::clock::VClock;
use super::faults::FaultPlan;
use super::message::{Message, Payload, Tag};
use super::model::NetworkModel;
use crate::Scalar;

/// Per-endpoint traffic statistics (virtual *and* wall time are tracked; the
/// wall numbers feed the calibration experiment E8).
#[derive(Debug, Default)]
pub struct CommStats {
    msgs_sent: Cell<u64>,
    bytes_sent: Cell<u64>,
    wall_wait: Cell<f64>,
    cur_reqs: Cell<u64>,
    max_reqs: Cell<u64>,
    wait_saved: Cell<f64>,
    pcie_saved: Cell<u64>,
    launches_fused: Cell<u64>,
    pcie_hidden: Cell<f64>,
    prefetch_hits: Cell<u64>,
    wire_direct: Cell<u64>,
    host_stage_saved: Cell<f64>,
    retries: Cell<u64>,
    timeout_secs: Cell<f64>,
}

impl CommStats {
    /// Messages sent from this endpoint.
    pub fn msgs_sent(&self) -> u64 {
        self.msgs_sent.get()
    }

    /// Payload bytes sent from this endpoint.
    pub fn bytes_sent(&self) -> u64 {
        self.bytes_sent.get()
    }

    /// Wall-clock seconds spent blocked in `recv`.
    pub fn wall_wait_secs(&self) -> f64 {
        self.wall_wait.get()
    }

    /// Peak number of split-phase requests (isend/irecv/collective handles)
    /// simultaneously outstanding on this endpoint.
    pub fn max_outstanding_reqs(&self) -> u64 {
        self.max_reqs.get()
    }

    /// Virtual seconds of communication latency hidden by overlap: what the
    /// blocking equivalent would have charged at post time, minus what the
    /// split-phase `wait` actually charged.  Occupancy is credited
    /// optimistically when posted; a blocking send that later stalls on
    /// that queued occupancy revokes the credit, and the metrics capture
    /// nets out any backlog still queued at snapshot time (which extends
    /// `busy_until`, so it was not hidden either).
    pub fn wait_saved_secs(&self) -> f64 {
        self.wait_saved.get()
    }

    /// PCIe bytes the residency layer kept off the host<->device link (0
    /// on host profiles, where nothing streams in the first place).
    pub fn pcie_saved_bytes(&self) -> u64 {
        self.pcie_saved.get()
    }

    /// Kernel launches eliminated by fused BLAS-1 ops (per fused call: the
    /// launches the unfused op-per-block sequence would have made, minus
    /// the one launch actually charged).
    pub fn launches_fused(&self) -> u64 {
        self.launches_fused.get()
    }

    /// Virtual seconds of PCIe transfer hidden behind compute by the
    /// copy-engine timeline (async H2D prefetch / async D2H write-back,
    /// `DESIGN.md` §13).  Occupancy is credited optimistically at issue; a
    /// wait that still blocks revokes the remainder, and the metrics
    /// capture nets out any occupancy still queued at snapshot time (which
    /// extends `busy_until`, so it was not hidden either) — the same
    /// discipline as [`CommStats::wait_saved_secs`] on the NIC.
    pub fn pcie_hidden_secs(&self) -> f64 {
        self.pcie_hidden.get()
    }

    /// Operand accesses served by an in-flight async prefetch (the operand
    /// was already on the copy-engine timeline when the op needed it).
    pub fn prefetch_hits(&self) -> u64 {
        self.prefetch_hits.get()
    }

    /// Payload bytes the GPUDirect wire handed straight from device memory
    /// to the NIC — no host staging copy, no `host_read` barrier
    /// (`DESIGN.md` §16).  Always 0 on host profiles and with
    /// `cluster.gpudirect` off.
    pub fn wire_direct_bytes(&self) -> u64 {
        self.wire_direct.get()
    }

    /// Virtual seconds of blocking D2H staging the GPUDirect wire removed
    /// from the compute timeline: the flush wait a `host_read` barrier
    /// would have charged at each send site the wire routed around.
    pub fn host_stage_saved_secs(&self) -> f64 {
        self.host_stage_saved.get()
    }

    /// Send attempts re-flown after a scripted message drop
    /// ([`super::faults::FaultEvent::MessageDrop`]).  0 without a fault
    /// plan.
    pub fn retries(&self) -> u64 {
        self.retries.get()
    }

    /// Virtual seconds spent in retry-timeout windows (the sender's
    /// loss-detection delay: base timeout doubling per attempt).
    pub fn timeout_secs(&self) -> f64 {
        self.timeout_secs.get()
    }

    pub(crate) fn add_retries(&self, n: u64, secs: f64) {
        self.retries.set(self.retries.get() + n);
        self.timeout_secs.set(self.timeout_secs.get() + secs);
    }

    pub(crate) fn add_pcie_saved(&self, bytes: u64) {
        self.pcie_saved.set(self.pcie_saved.get() + bytes);
    }

    pub(crate) fn add_pcie_hidden(&self, secs: f64) {
        if secs > 0.0 {
            self.pcie_hidden.set(self.pcie_hidden.get() + secs);
        }
    }

    pub(crate) fn revoke_pcie_hidden(&self, secs: f64) {
        if secs > 0.0 {
            self.pcie_hidden.set((self.pcie_hidden.get() - secs).max(0.0));
        }
    }

    pub(crate) fn add_prefetch_hit(&self) {
        self.prefetch_hits.set(self.prefetch_hits.get() + 1);
    }

    pub(crate) fn add_launches_fused(&self, n: u64) {
        self.launches_fused.set(self.launches_fused.get() + n);
    }

    pub(crate) fn add_wire_direct(&self, bytes: u64) {
        self.wire_direct.set(self.wire_direct.get() + bytes);
    }

    pub(crate) fn add_host_stage_saved(&self, secs: f64) {
        if secs > 0.0 {
            self.host_stage_saved.set(self.host_stage_saved.get() + secs);
        }
    }

    fn req_open(&self) {
        let cur = self.cur_reqs.get() + 1;
        self.cur_reqs.set(cur);
        if cur > self.max_reqs.get() {
            self.max_reqs.set(cur);
        }
    }

    fn req_close(&self) {
        self.cur_reqs.set(self.cur_reqs.get().saturating_sub(1));
    }

    fn add_wait_saved(&self, secs: f64) {
        if secs > 0.0 {
            self.wait_saved.set(self.wait_saved.get() + secs);
        }
    }

    fn revoke_wait_saved(&self, secs: f64) {
        if secs > 0.0 {
            self.wait_saved.set((self.wait_saved.get() - secs).max(0.0));
        }
    }
}

struct PendingRx<S: Scalar> {
    rx: mpsc::Receiver<Message<S>>,
    /// Messages received but not yet claimed (tag mismatch buffering).
    pending: VecDeque<Message<S>>,
}

/// One rank's endpoint: owned by that rank's thread, never shared.
pub struct Comm<S: Scalar> {
    rank: usize,
    size: usize,
    /// senders[dst]: channel from this rank to `dst`.
    senders: Vec<mpsc::Sender<Message<S>>>,
    /// receivers[src]: channel from `src` to this rank.
    receivers: Vec<RefCell<PendingRx<S>>>,
    clock: VClock,
    net: NetworkModel,
    stats: CommStats,
    /// The fault schedule in force (empty by default: every hook below
    /// short-circuits, pinning the fault-free paths bit-identical).
    faults: Arc<FaultPlan>,
    /// This rank's scripted crash times, sorted; consumed monotonically
    /// by [`Comm::take_crash`].
    crash_times: Vec<f64>,
    crash_next: Cell<usize>,
    /// Per-destination count of remote sends, for matching scripted
    /// `MessageDrop { nth }` events.  Only bumped when the plan is
    /// non-empty.
    route_sends: Vec<Cell<u64>>,
}

impl<S: Scalar> Comm<S> {
    /// This endpoint's world rank.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// World size.
    pub fn size(&self) -> usize {
        self.size
    }

    /// The rank's virtual clock.
    pub fn clock(&self) -> &VClock {
        &self.clock
    }

    /// The network model in force.
    pub fn net(&self) -> &NetworkModel {
        self.net_ref()
    }

    fn net_ref(&self) -> &NetworkModel {
        &self.net
    }

    /// Traffic statistics.
    pub fn stats(&self) -> &CommStats {
        &self.stats
    }

    /// The fault schedule in force (the empty plan without one).
    pub fn fault_plan(&self) -> &FaultPlan {
        &self.faults
    }

    /// True exactly once per scripted crash of this rank whose virtual
    /// time has passed.  The caller (a solver's fault probe) prices the
    /// reboot and drives recovery; consumption is monotone, so a crash
    /// fires at the first probe at or after its scripted time and never
    /// again — in particular not during the recovery replay.
    pub fn take_crash(&self) -> bool {
        match self.crash_times.get(self.crash_next.get()) {
            Some(&t) if self.clock.now() >= t => {
                self.crash_next.set(self.crash_next.get() + 1);
                true
            }
            _ => false,
        }
    }

    /// NIC occupancy of `bytes` starting at `at`, under any link
    /// degradation active then.  With an empty plan this is exactly
    /// `beta · bytes` (no multiply touches it).
    fn occupancy(&self, bytes: usize, at: f64) -> f64 {
        let base = bytes as f64 * self.net.beta;
        if self.faults.is_empty() {
            base
        } else {
            base * self.faults.degrade_factor(self.rank, at)
        }
    }

    /// Deterministic drop/retry pricing for the next logical send to
    /// `dst`: count the route's remote sends, look up scripted drops of
    /// this one, and price each failed attempt as its NIC occupancy
    /// followed by a loss-detection timeout that doubles per attempt
    /// (bounded exponential backoff).  The failed occupancies queue on
    /// the NIC timeline; the returned instant is when the wire may carry
    /// the successful attempt (`available_at` exactly when nothing is
    /// scripted or the plan is empty).
    fn retry_gate(&self, dst: usize, available_at: f64, bytes: usize) -> f64 {
        if self.faults.is_empty() || dst == self.rank {
            return available_at;
        }
        let nth = self.route_sends[dst].get() + 1;
        self.route_sends[dst].set(nth);
        let drops = self.faults.drops(self.rank, dst, nth);
        if drops == 0 {
            return available_at;
        }
        let mut at = available_at;
        let mut timeout = self.faults.retry_timeout;
        let mut waited = 0.0;
        for _ in 0..drops {
            let end = self.clock.nic_occupy_from(at, self.occupancy(bytes, at));
            // The sender only learns of the loss when the timeout expires.
            at = end + timeout;
            waited += timeout;
            timeout *= 2.0;
        }
        self.stats.add_retries(drops as u64, waited);
        at
    }

    /// Send `payload` to world rank `dst` under `tag` (blocking semantics).
    ///
    /// LogGP semantics: the sender's clock advances by the NIC occupancy
    /// `beta * bytes` (back-to-back sends from one rank serialise at line
    /// rate, as on a real Gigabit NIC), then the message arrives at the
    /// receiver after the additional wire latency `alpha`.
    pub fn send(&self, dst: usize, tag: Tag, payload: Payload<S>) {
        assert!(dst < self.size, "send to rank {dst} of {}", self.size);
        let bytes = payload.wire_bytes();
        let arrival = if dst == self.rank {
            self.clock.now() + self.net.local_secs(bytes)
        } else {
            // Occupancy still queued from earlier isends is about to stall
            // this blocking send — that part was credited as hidden at post
            // time but is being paid after all, so revoke it.
            let backlog = (self.clock.nic_free() - self.clock.now()).max(0.0);
            self.stats.revoke_wait_saved(backlog);
            // Scripted drops re-fly first (failed occupancies + timeouts
            // queue ahead on the NIC); the blocking caller waits through
            // the successful attempt's occupancy end.
            let at = self.retry_gate(dst, self.clock.now(), bytes);
            let end = self.clock.nic_occupy_from(at, self.occupancy(bytes, at));
            self.clock.observe_arrival(end);
            self.clock.now() + self.net.alpha
        };
        self.push(dst, tag, payload, arrival, bytes);
    }

    /// Split-phase send: the payload leaves immediately (channels are
    /// buffered), the NIC occupancy is queued on the network timeline
    /// instead of blocking the compute timeline, and the returned request
    /// completes trivially (payloads move by value, so there is no buffer to
    /// protect — `wait` exists for symmetry and request accounting).
    pub fn isend(&self, dst: usize, tag: Tag, payload: Payload<S>) -> SendRequest<'_, S> {
        self.post_at(dst, tag, payload, self.clock.now());
        self.stats.req_open();
        SendRequest { comm: self, done: Cell::new(false) }
    }

    /// GPUDirect blocking send: the payload's bytes are device-resident and
    /// dirty, so the NIC reads them straight from device memory — the NIC
    /// and copy-engine timelines are occupied jointly ([`VClock::
    /// wire_occupy_from`]: `pcie_secs` on the link, `beta·bytes` on the
    /// wire), with no host staging copy on the compute timeline.  The
    /// compute timeline still blocks until the last byte leaves (blocking
    /// semantics, like [`Comm::send`]).  With `pcie_secs <= 0` (host-clean
    /// payload, host profile, GPUDirect off) this **is** [`Comm::send`] —
    /// the bit-identical fallback (`DESIGN.md` §16).
    pub fn send_wire(&self, dst: usize, tag: Tag, payload: Payload<S>, pcie_secs: f64) {
        if pcie_secs <= 0.0 || dst == self.rank {
            return self.send(dst, tag, payload);
        }
        let bytes = payload.wire_bytes();
        // As in `send`: queued occupancy about to stall this blocking send
        // was never hidden — revoke the post-time credit.
        let backlog = (self.clock.nic_free() - self.clock.now()).max(0.0);
        self.stats.revoke_wait_saved(backlog);
        // Scripted drops re-fly as NIC-only attempts (the retransmit comes
        // from the NIC's bounce buffer; the device is read once, on the
        // successful attempt's joint occupancy).
        let at = self.retry_gate(dst, self.clock.now(), bytes);
        let end = self.clock.wire_occupy_from(at, self.occupancy(bytes, at), pcie_secs);
        self.clock.observe_arrival(end);
        self.stats.add_wire_direct(bytes as u64);
        let arrival = self.clock.now() + self.net.alpha;
        self.push(dst, tag, payload, arrival, bytes);
    }

    /// Split-phase GPUDirect send: like [`Comm::isend`], but the NIC reads
    /// the device-dirty payload directly ([`Comm::send_wire`]'s joint
    /// occupancy, queued from the current compute time without blocking).
    pub fn isend_wire(
        &self,
        dst: usize,
        tag: Tag,
        payload: Payload<S>,
        pcie_secs: f64,
    ) -> SendRequest<'_, S> {
        self.post_wire_at(dst, tag, payload, self.clock.now(), pcie_secs);
        self.stats.req_open();
        SendRequest { comm: self, done: Cell::new(false) }
    }

    /// Internal stamped send: the payload becomes available for the wire at
    /// virtual time `available_at` (>= any earlier traffic on this NIC),
    /// *without* advancing the sender's compute timeline.  This is how the
    /// split-phase collectives model background progression: a forwarded
    /// tree edge is stamped from the incoming message's arrival, as if a
    /// progress thread had relayed it the moment it landed.
    pub(crate) fn post_at(&self, dst: usize, tag: Tag, payload: Payload<S>, available_at: f64) {
        assert!(dst < self.size, "send to rank {dst} of {}", self.size);
        let bytes = payload.wire_bytes();
        let arrival = if dst == self.rank {
            available_at + self.net.local_secs(bytes)
        } else {
            let at = self.retry_gate(dst, available_at, bytes);
            let occupancy = self.occupancy(bytes, at);
            // Occupancy that never blocks the compute timeline is latency
            // hidden by overlap (a blocking send would have charged it).
            self.stats.add_wait_saved(occupancy);
            self.clock.nic_occupy_from(at, occupancy) + self.net.alpha
        };
        self.push(dst, tag, payload, arrival, bytes);
    }

    /// Stamped GPUDirect send ([`Comm::post_at`] with the joint NIC/PCIe
    /// occupancy): the device-dirty payload becomes wire-eligible at
    /// `available_at`, and the NIC reads it straight from device memory.
    /// Delegates to [`Comm::post_at`] when `pcie_secs <= 0` or the
    /// destination is local — the bit-identical fallback.
    pub(crate) fn post_wire_at(
        &self,
        dst: usize,
        tag: Tag,
        payload: Payload<S>,
        available_at: f64,
        pcie_secs: f64,
    ) {
        if pcie_secs <= 0.0 || dst == self.rank {
            return self.post_at(dst, tag, payload, available_at);
        }
        assert!(dst < self.size, "send to rank {dst} of {}", self.size);
        let bytes = payload.wire_bytes();
        let at = self.retry_gate(dst, available_at, bytes);
        let occupancy = self.occupancy(bytes, at);
        // Occupancy that never blocks the compute timeline is latency
        // hidden by overlap, exactly as on the staged path.
        self.stats.add_wait_saved(occupancy);
        self.stats.add_wire_direct(bytes as u64);
        let arrival = self.clock.wire_occupy_from(at, occupancy, pcie_secs) + self.net.alpha;
        self.push(dst, tag, payload, arrival, bytes);
    }

    fn push(&self, dst: usize, tag: Tag, payload: Payload<S>, arrival: f64, bytes: usize) {
        self.stats.msgs_sent.set(self.stats.msgs_sent.get() + 1);
        self.stats.bytes_sent.set(self.stats.bytes_sent.get() + bytes as u64);
        let msg = Message { src: self.rank, tag, payload, arrival };
        // A send can only fail if the receiving rank already exited — that is
        // a protocol bug (mismatched collective participation), so panic.
        self.senders[dst]
            .send(msg)
            .unwrap_or_else(|_| panic!("rank {} send to dead rank {dst}", self.rank));
    }

    /// Blocking receive of the next message from `src` with `tag`.
    /// Messages from `src` with other tags are buffered, preserving FIFO per
    /// tag — mirroring MPI's (source, tag) matching.
    pub fn recv(&self, src: usize, tag: Tag) -> Payload<S> {
        let msg = self.take_matching(src, tag);
        self.clock.observe_arrival(msg.arrival);
        msg.payload
    }

    /// Post a split-phase receive.  The message is claimed (and the
    /// remaining latency charged) at [`RecvRequest::wait`]; latency that
    /// elapsed under compute performed between post and wait is recorded as
    /// [`CommStats::wait_saved_secs`].
    pub fn irecv(&self, src: usize, tag: Tag) -> RecvRequest<'_, S> {
        assert!(src < self.size, "recv from rank {src} of {}", self.size);
        self.stats.req_open();
        RecvRequest { comm: self, src, tag, posted_at: self.clock.now(), done: Cell::new(false) }
    }

    /// Thread-blocking matching of the next `(src, tag)` message, without
    /// touching the virtual clock — shared by the blocking and split-phase
    /// receive paths.
    pub(crate) fn take_matching(&self, src: usize, tag: Tag) -> Message<S> {
        assert!(src < self.size, "recv from rank {src} of {}", self.size);
        let mut rx = self.receivers[src].borrow_mut();
        // Buffered first.
        if let Some(pos) = rx.pending.iter().position(|m| m.tag == tag) {
            return rx.pending.remove(pos).unwrap();
        }
        let sw = std::time::Instant::now();
        loop {
            let msg = rx
                .rx
                .recv()
                .unwrap_or_else(|_| panic!("rank {} recv from dead rank {src}", self.rank));
            if msg.tag == tag {
                self.stats
                    .wall_wait
                    .set(self.stats.wall_wait.get() + sw.elapsed().as_secs_f64());
                return msg;
            }
            rx.pending.push_back(msg);
        }
    }

    /// Record how much latency a split-phase wait hid: the blocking
    /// equivalent posted at `posted_at` would have charged up to `arrival`;
    /// the overlapped wait at `now` only charged the remainder.
    pub(crate) fn credit_overlap(&self, posted_at: f64, arrival: f64) {
        let now = self.clock.now();
        self.stats.add_wait_saved(arrival.min(now) - posted_at);
    }

    pub(crate) fn req_open(&self) {
        self.stats.req_open();
    }

    pub(crate) fn req_close(&self) {
        self.stats.req_close();
    }

    /// A sub-communicator over `ranks` (world numbering).  This rank must be
    /// a member.  Collectives and rank-translated send/recv live on the
    /// returned [`Group`].
    pub fn group<'a>(&'a self, ranks: &[usize]) -> Group<'a, S> {
        let me = ranks
            .iter()
            .position(|&r| r == self.rank)
            .unwrap_or_else(|| panic!("rank {} not in group {ranks:?}", self.rank));
        Group { comm: self, ranks: Rc::from(ranks), me }
    }

    /// The full world as a [`Group`].
    pub fn world(&self) -> Group<'_, S> {
        Group { comm: self, ranks: (0..self.size).collect::<Vec<_>>().into(), me: self.rank }
    }
}

/// Handle of a split-phase send.  Completion is trivial (payloads move by
/// value), but waiting (or dropping) the handle closes the request for the
/// [`CommStats::max_outstanding_reqs`] accounting.
#[must_use = "split-phase requests should be waited"]
pub struct SendRequest<'a, S: Scalar> {
    comm: &'a Comm<S>,
    done: Cell<bool>,
}

impl<S: Scalar> SendRequest<'_, S> {
    /// Complete the send (free in virtual time: the buffer already moved).
    pub fn wait(self) {
        self.done.set(true);
        self.comm.stats.req_close();
    }
}

impl<S: Scalar> Drop for SendRequest<'_, S> {
    fn drop(&mut self) {
        if !self.done.get() {
            self.comm.stats.req_close();
        }
    }
}

/// Handle of a split-phase receive: claim the message with [`wait`].
///
/// Matching is lazy: the message is claimed at `wait`, FIFO per
/// `(src, tag)` among whoever claims — so a *blocking* `recv` on the same
/// pair issued between post and wait claims first (unlike MPI's
/// posted-receive queue; don't mix the two styles on one tag).  Dropping a
/// request without waiting leaves the message unclaimed in the channel —
/// legal, but any later receive on the pair will match it first, so in
/// practice every posted receive should be waited, exactly as in MPI.
///
/// [`wait`]: RecvRequest::wait
#[must_use = "a posted receive must be waited"]
pub struct RecvRequest<'a, S: Scalar> {
    comm: &'a Comm<S>,
    src: usize,
    tag: Tag,
    posted_at: f64,
    done: Cell<bool>,
}

impl<S: Scalar> RecvRequest<'_, S> {
    /// Block until the message lands; charge only the latency that was not
    /// hidden by compute performed since the request was posted.
    pub fn wait(self) -> Payload<S> {
        let msg = self.comm.take_matching(self.src, self.tag);
        self.comm.credit_overlap(self.posted_at, msg.arrival);
        self.comm.clock().observe_arrival(msg.arrival);
        self.done.set(true);
        self.comm.stats.req_close();
        msg.payload
    }
}

impl<S: Scalar> Drop for RecvRequest<'_, S> {
    fn drop(&mut self) {
        if !self.done.get() {
            self.comm.stats.req_close();
        }
    }
}

/// A sub-communicator view: group-rank numbering over a subset of the world.
pub struct Group<'a, S: Scalar> {
    pub(crate) comm: &'a Comm<S>,
    /// Group-to-world rank translation, shared with every split-phase
    /// request started on this group (an `Rc` clone per request, not a
    /// fresh `Vec` — requests are per-tile on the pipelined hot paths).
    pub(crate) ranks: Rc<[usize]>,
    pub(crate) me: usize,
}

impl<'a, S: Scalar> Group<'a, S> {
    /// This rank's position within the group.
    pub fn rank(&self) -> usize {
        self.me
    }

    /// Group size.
    pub fn size(&self) -> usize {
        self.ranks.len()
    }

    /// Translate group rank to world rank.
    pub fn world_rank(&self, group_rank: usize) -> usize {
        self.ranks[group_rank]
    }

    /// The underlying endpoint.
    pub fn comm(&self) -> &'a Comm<S> {
        self.comm
    }

    /// Send to a group rank.
    pub fn send(&self, dst: usize, tag: Tag, payload: Payload<S>) {
        self.comm.send(self.ranks[dst], tag, payload);
    }

    /// Receive from a group rank.
    pub fn recv(&self, src: usize, tag: Tag) -> Payload<S> {
        self.comm.recv(self.ranks[src], tag)
    }

    /// Split-phase send to a group rank.
    pub fn isend(&self, dst: usize, tag: Tag, payload: Payload<S>) -> SendRequest<'a, S> {
        self.comm.isend(self.ranks[dst], tag, payload)
    }

    /// GPUDirect blocking send to a group rank ([`Comm::send_wire`]).
    pub fn send_wire(&self, dst: usize, tag: Tag, payload: Payload<S>, pcie_secs: f64) {
        self.comm.send_wire(self.ranks[dst], tag, payload, pcie_secs);
    }

    /// Split-phase GPUDirect send to a group rank ([`Comm::isend_wire`]).
    pub fn isend_wire(
        &self,
        dst: usize,
        tag: Tag,
        payload: Payload<S>,
        pcie_secs: f64,
    ) -> SendRequest<'a, S> {
        self.comm.isend_wire(self.ranks[dst], tag, payload, pcie_secs)
    }

    /// Post a split-phase receive from a group rank.
    pub fn irecv(&self, src: usize, tag: Tag) -> RecvRequest<'a, S> {
        self.comm.irecv(self.ranks[src], tag)
    }
}

/// The simulated cluster: builds the channel mesh and runs one closure per
/// rank on its own OS thread.
pub struct World;

impl World {
    /// Run `f(comm)` on `p` ranks; returns each rank's result, indexed by
    /// rank.  Panics in any rank propagate (fail-fast, like an MPI abort).
    pub fn run<S, R, F>(p: usize, net: NetworkModel, f: F) -> Vec<R>
    where
        S: Scalar,
        R: Send,
        F: Fn(Comm<S>) -> R + Send + Sync,
    {
        Self::run_with_faults(p, net, FaultPlan::default(), f)
    }

    /// [`World::run`] under a deterministic fault schedule: stragglers set
    /// each rank's compute rate, link degradation and scripted message
    /// drops are priced inside the transport, and crashes are exposed to
    /// the solvers via [`Comm::take_crash`].  The empty plan is
    /// bit-identical to [`World::run`].
    pub fn run_with_faults<S, R, F>(p: usize, net: NetworkModel, plan: FaultPlan, f: F) -> Vec<R>
    where
        S: Scalar,
        R: Send,
        F: Fn(Comm<S>) -> R + Send + Sync,
    {
        assert!(p > 0, "world size must be positive");
        let plan = Arc::new(plan);
        // channels[src][dst]
        let mut senders: Vec<Vec<mpsc::Sender<Message<S>>>> = Vec::with_capacity(p);
        let mut receivers: Vec<Vec<Option<mpsc::Receiver<Message<S>>>>> =
            (0..p).map(|_| (0..p).map(|_| None).collect()).collect();
        for src in 0..p {
            let mut row = Vec::with_capacity(p);
            for dst in 0..p {
                let (tx, rx) = mpsc::channel();
                row.push(tx);
                receivers[dst][src] = Some(rx);
            }
            senders.push(row);
        }
        let mut comms: Vec<Comm<S>> = senders
            .into_iter()
            .zip(receivers)
            .enumerate()
            .map(|(rank, (senders, rxs))| {
                let clock = VClock::new();
                clock.set_compute_rate(plan.compute_rate(rank));
                Comm {
                    rank,
                    size: p,
                    senders,
                    receivers: rxs
                        .into_iter()
                        .map(|rx| {
                            RefCell::new(PendingRx { rx: rx.unwrap(), pending: VecDeque::new() })
                        })
                        .collect(),
                    clock,
                    net,
                    stats: CommStats::default(),
                    faults: Arc::clone(&plan),
                    crash_times: plan.crash_times(rank),
                    crash_next: Cell::new(0),
                    route_sends: (0..p).map(|_| Cell::new(0)).collect(),
                }
            })
            .collect();

        std::thread::scope(|scope| {
            let f = &f;
            let handles: Vec<_> = comms
                .drain(..)
                .map(|comm| scope.spawn(move || f(comm)))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("rank thread panicked"))
                .collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p2p_roundtrip() {
        let results = World::run::<f64, _, _>(2, NetworkModel::ideal(), |comm| {
            if comm.rank() == 0 {
                comm.send(1, Tag::P2p(0), Payload::Data(vec![1.0, 2.0, 3.0]));
                comm.recv(1, Tag::P2p(1)).into_scalar()
            } else {
                let v = comm.recv(0, Tag::P2p(0)).into_data();
                let sum: f64 = v.iter().sum();
                comm.send(0, Tag::P2p(1), Payload::Scalar(sum));
                sum
            }
        });
        assert_eq!(results, vec![6.0, 6.0]);
    }

    #[test]
    fn tag_mismatch_buffers() {
        // Rank 0 sends tag B then tag A; rank 1 receives A first, then B.
        let results = World::run::<f64, _, _>(2, NetworkModel::ideal(), |comm| {
            if comm.rank() == 0 {
                comm.send(1, Tag::P2p(7), Payload::Scalar(7.0));
                comm.send(1, Tag::P2p(8), Payload::Scalar(8.0));
                0.0
            } else {
                let a = comm.recv(0, Tag::P2p(8)).into_scalar();
                let b = comm.recv(0, Tag::P2p(7)).into_scalar();
                a * 10.0 + b
            }
        });
        assert_eq!(results[1], 87.0);
    }

    #[test]
    fn virtual_clock_advances_on_recv() {
        let net = NetworkModel::gigabit_ethernet();
        let results = World::run::<f32, _, _>(2, net, |comm| {
            if comm.rank() == 0 {
                comm.send(1, Tag::P2p(0), Payload::Data(vec![0.0f32; 1 << 18])); // 1 MiB
                comm.clock().now()
            } else {
                comm.recv(0, Tag::P2p(0));
                comm.clock().now()
            }
        });
        // Sender pays the NIC occupancy (beta*bytes)...
        let occupy = (1u64 << 20) as f64 * net.beta;
        assert!((results[0] - occupy).abs() < 1e-12, "{} vs {occupy}", results[0]);
        // ...receiver sees occupancy + wire latency = the full alpha-beta cost.
        let expect = net.p2p_secs(1 << 20);
        assert!((results[1] - expect).abs() < 1e-9, "{} vs {expect}", results[1]);
    }

    #[test]
    fn group_rank_translation() {
        let results = World::run::<f64, _, _>(4, NetworkModel::ideal(), |comm| {
            // Group of even ranks {0, 2}: group rank 1 is world rank 2.
            if comm.rank() % 2 == 0 {
                let g = comm.group(&[0, 2]);
                if g.rank() == 0 {
                    g.send(1, Tag::P2p(0), Payload::Scalar(5.0));
                    0.0
                } else {
                    g.recv(0, Tag::P2p(0)).into_scalar()
                }
            } else {
                -1.0
            }
        });
        assert_eq!(results, vec![0.0, -1.0, 5.0, -1.0]);
    }

    #[test]
    fn stats_count_traffic() {
        let results = World::run::<f64, _, _>(2, NetworkModel::ideal(), |comm| {
            if comm.rank() == 0 {
                comm.send(1, Tag::P2p(0), Payload::Data(vec![0.0; 100]));
                (comm.stats().msgs_sent(), comm.stats().bytes_sent())
            } else {
                comm.recv(0, Tag::P2p(0));
                (0, 0)
            }
        });
        assert_eq!(results[0], (1, 800));
    }

    #[test]
    #[should_panic]
    fn group_requires_membership() {
        World::run::<f64, _, _>(2, NetworkModel::ideal(), |comm| {
            comm.group(&[1]); // rank 0 is not a member -> panic on rank 0
        });
    }

    #[test]
    fn isend_hides_occupancy_behind_compute() {
        let net = NetworkModel::gigabit_ethernet();
        let occupy = (1u64 << 20) as f64 * net.beta;
        let results = World::run::<f32, _, _>(2, net, move |comm| {
            if comm.rank() == 0 {
                let req = comm.isend(1, Tag::P2p(0), Payload::Data(vec![0.0f32; 1 << 18]));
                comm.clock().advance_compute(2.0 * occupy);
                req.wait();
                (comm.clock().now(), comm.clock().comm_wait_secs(), comm.stats().wait_saved_secs())
            } else {
                comm.recv(0, Tag::P2p(0));
                (0.0, 0.0, 0.0)
            }
        });
        let (now, wait, saved) = results[0];
        // Compute only: the NIC serialised the megabyte in the background.
        assert!((now - 2.0 * occupy).abs() < 1e-12, "{now} vs {}", 2.0 * occupy);
        assert_eq!(wait, 0.0);
        assert!((saved - occupy).abs() < 1e-12, "hidden occupancy {saved} vs {occupy}");
    }

    #[test]
    fn blocking_send_revokes_wait_saved_for_backlog_it_pays() {
        // isend a megabyte then immediately issue a blocking send: the
        // queued occupancy stalls the blocking send, so it was never
        // hidden — wait_saved must not report it.
        let net = NetworkModel::gigabit_ethernet();
        let occupy = (1u64 << 20) as f64 * net.beta;
        let results = World::run::<f32, _, _>(2, net, move |comm| {
            if comm.rank() == 0 {
                let req = comm.isend(1, Tag::P2p(0), Payload::Data(vec![0.0f32; 1 << 18]));
                comm.send(1, Tag::P2p(1), Payload::Scalar(1.0)); // stalls on the backlog
                req.wait();
                (comm.stats().wait_saved_secs(), comm.clock().comm_wait_secs())
            } else {
                comm.recv(0, Tag::P2p(0));
                comm.recv(0, Tag::P2p(1));
                (0.0, 0.0)
            }
        });
        let (saved, wait) = results[0];
        assert!(saved < 1e-12, "credit must be revoked once the backlog is paid: {saved}");
        assert!(wait >= occupy, "the blocking send pays the queued occupancy: {wait}");
    }

    #[test]
    fn irecv_charges_only_remaining_latency() {
        let net = NetworkModel::gigabit_ethernet();
        let full = net.p2p_secs(1 << 20);
        let results = World::run::<f32, _, _>(2, net, move |comm| {
            if comm.rank() == 0 {
                comm.isend(1, Tag::P2p(0), Payload::Data(vec![0.0f32; 1 << 18])).wait();
                (0.0, 0.0)
            } else {
                let req = comm.irecv(0, Tag::P2p(0));
                // Compute covering half the transfer: only the rest waits.
                comm.clock().advance_compute(full / 2.0);
                req.wait();
                (comm.clock().comm_wait_secs(), comm.stats().wait_saved_secs())
            }
        });
        let (wait, saved) = results[1];
        assert!((wait - full / 2.0).abs() < 1e-9, "remaining wait {wait} vs {}", full / 2.0);
        assert!((saved - full / 2.0).abs() < 1e-9, "hidden latency {saved}");
    }

    #[test]
    fn split_phase_matches_blocking_payloads_and_order() {
        // Messages claim FIFO per (src, tag) at *wait* time: waits in post
        // order see the sends in send order, and other tags stay buffered.
        let results = World::run::<f64, _, _>(2, NetworkModel::ideal(), |comm| {
            if comm.rank() == 0 {
                comm.isend(1, Tag::P2p(1), Payload::Scalar(1.0)).wait();
                comm.isend(1, Tag::P2p(2), Payload::Scalar(2.0)).wait();
                comm.isend(1, Tag::P2p(1), Payload::Scalar(3.0)).wait();
                0.0
            } else {
                let r1a = comm.irecv(0, Tag::P2p(1));
                let r1b = comm.irecv(0, Tag::P2p(1));
                let r2 = comm.irecv(0, Tag::P2p(2));
                let a = r1a.wait().into_scalar();
                let b = r1b.wait().into_scalar();
                let c = r2.wait().into_scalar();
                a * 100.0 + b * 10.0 + c
            }
        });
        assert_eq!(results[1], 1.0 * 100.0 + 3.0 * 10.0 + 2.0);
    }

    #[test]
    fn wire_send_occupies_nic_and_copy_engine_jointly_with_no_xfer_charge() {
        let net = NetworkModel::gigabit_ethernet();
        let occupy = (1u64 << 20) as f64 * net.beta;
        let pcie = occupy / 4.0;
        let results = World::run::<f32, _, _>(2, net, move |comm| {
            if comm.rank() == 0 {
                comm.send_wire(1, Tag::P2p(0), Payload::Data(vec![0.0f32; 1 << 18]), pcie);
                (
                    comm.clock().now(),
                    comm.clock().transfer_secs(),
                    comm.clock().pcie_free(),
                    comm.stats().wire_direct_bytes(),
                )
            } else {
                comm.recv(0, Tag::P2p(0));
                (comm.clock().now(), 0.0, 0.0, 0)
            }
        });
        let (now, xfer, pcie_free, direct) = results[0];
        // The blocking wire send costs max(nic, pcie) = the NIC leg here —
        // the D2H staging copy is gone from the compute timeline entirely.
        assert!((now - occupy).abs() < 1e-12, "{now} vs {occupy}");
        assert_eq!(xfer, 0.0, "no host staging: zero transfer charge");
        assert!((pcie_free - pcie).abs() < 1e-12, "copy engine carried its leg");
        assert_eq!(direct, 1u64 << 20);
        // Receiver sees the same alpha-beta arrival as a staged send whose
        // D2H had already completed.
        let (rnow, ..) = results[1];
        assert!((rnow - net.p2p_secs(1 << 20)).abs() < 1e-9, "{rnow}");
    }

    #[test]
    fn wire_send_with_zero_pcie_leg_is_exactly_a_host_send() {
        let net = NetworkModel::gigabit_ethernet();
        let results = World::run::<f32, _, _>(2, net, move |comm| {
            if comm.rank() == 0 {
                comm.send_wire(1, Tag::P2p(0), Payload::Data(vec![0.0f32; 256]), 0.0);
                comm.isend_wire(1, Tag::P2p(1), Payload::Data(vec![0.0f32; 256]), 0.0).wait();
                (comm.clock().now(), comm.stats().wire_direct_bytes(), comm.clock().pcie_free())
            } else {
                comm.recv(0, Tag::P2p(0));
                comm.recv(0, Tag::P2p(1));
                (0.0, 0, 0.0)
            }
        });
        let (now, direct, pcie_free) = results[0];
        assert!((now - 1024.0 * net.beta).abs() < 1e-15, "blocking leg only: {now}");
        assert_eq!(direct, 0, "fallback path must not count wire bytes");
        assert_eq!(pcie_free, 0.0, "fallback path must not touch the copy engine");
    }

    #[test]
    fn isend_wire_hides_the_joint_occupancy_behind_compute() {
        let net = NetworkModel::gigabit_ethernet();
        let occupy = (1u64 << 20) as f64 * net.beta;
        let pcie = 2.0 * occupy; // PCIe leg longer than the wire leg
        let results = World::run::<f32, _, _>(2, net, move |comm| {
            if comm.rank() == 0 {
                let req =
                    comm.isend_wire(1, Tag::P2p(0), Payload::Data(vec![0.0f32; 1 << 18]), pcie);
                comm.clock().advance_compute(3.0 * occupy);
                req.wait();
                (comm.clock().now(), comm.clock().comm_wait_secs(), comm.clock().busy_until())
            } else {
                comm.recv(0, Tag::P2p(0));
                (comm.clock().now(), 0.0, 0.0)
            }
        });
        let (now, wait, busy) = results[0];
        assert!((now - 3.0 * occupy).abs() < 1e-12, "compute only: {now}");
        assert_eq!(wait, 0.0);
        assert!((busy - 3.0 * occupy).abs() < 1e-12, "both legs hid under compute");
        // Receiver: arrival = joint-occupancy end (the slower leg — here
        // the PCIe one) + alpha.
        let (rnow, ..) = results[1];
        assert!((rnow - (pcie + net.alpha)).abs() < 1e-9, "{rnow}");
    }

    #[test]
    fn scripted_drop_prices_retries_exactly() {
        use super::super::faults::FaultPlan;
        // Drop the 2nd send from rank 0 to rank 1 twice: the sender pays
        // two extra occupancies plus timeout + 2*timeout (exponential
        // backoff), then the message goes through unchanged.
        let net = NetworkModel::gigabit_ethernet();
        let plan = FaultPlan::parse("drop:0-1#2x2; timeout:1e-3").unwrap();
        let occupy = 800.0 * net.beta;
        let results = World::run_with_faults::<f64, _, _>(2, net, plan, |comm| {
            if comm.rank() == 0 {
                comm.send(1, Tag::P2p(0), Payload::Data(vec![0.0; 100]));
                comm.send(1, Tag::P2p(1), Payload::Data(vec![0.0; 100]));
                (
                    comm.clock().now(),
                    comm.stats().retries(),
                    comm.stats().timeout_secs(),
                )
            } else {
                let a = comm.recv(0, Tag::P2p(0)).into_data();
                let b = comm.recv(0, Tag::P2p(1)).into_data();
                ((a.len() + b.len()) as f64, 0, 0.0)
            }
        });
        let (now, retries, waited) = results[0];
        assert_eq!(retries, 2);
        assert!((waited - 3e-3).abs() < 1e-12, "1ms + 2ms backoff: {waited}");
        // Timeline: send 1 occupies [0, o); send 2's failed attempts end
        // at 2o and 3o+1ms, the successful one at 4o+3ms.
        assert!((now - (4.0 * occupy + 3e-3)).abs() < 1e-12, "{now}");
        // Payloads still arrive intact and in order.
        assert_eq!(results[1].0, 200.0);
    }

    #[test]
    fn degraded_link_slows_only_its_window() {
        use super::super::faults::FaultPlan;
        let net = NetworkModel::gigabit_ethernet();
        let occupy = 800.0 * net.beta;
        // The window covers the first send only (it starts at t=0).
        let plan = FaultPlan::parse(&format!("degrade:0x3.0@0.0-{}", occupy * 2.0)).unwrap();
        let results = World::run_with_faults::<f64, _, _>(2, net, plan, |comm| {
            if comm.rank() == 0 {
                comm.send(1, Tag::P2p(0), Payload::Data(vec![0.0; 100]));
                let mid = comm.clock().now();
                comm.clock().advance_compute(occupy * 2.0); // leave the window
                comm.send(1, Tag::P2p(1), Payload::Data(vec![0.0; 100]));
                (mid, comm.clock().now())
            } else {
                comm.recv(0, Tag::P2p(0));
                comm.recv(0, Tag::P2p(1));
                (0.0, 0.0)
            }
        });
        let (mid, end) = results[0];
        assert!((mid - 3.0 * occupy).abs() < 1e-12, "degraded leg 3x: {mid}");
        let expect = 3.0 * occupy + 2.0 * occupy + occupy;
        assert!((end - expect).abs() < 1e-12, "clean leg past the window: {end}");
    }

    #[test]
    fn take_crash_fires_once_at_its_time() {
        use super::super::faults::FaultPlan;
        let plan = FaultPlan::parse("crash:0@1.0").unwrap();
        let results = World::run_with_faults::<f64, _, _>(1, NetworkModel::ideal(), plan, |comm| {
            let before = comm.take_crash(); // t=0: not yet
            comm.clock().advance_compute(2.0);
            let fired = comm.take_crash();
            let again = comm.take_crash(); // consumed: never re-fires
            (before, fired, again)
        });
        assert_eq!(results[0], (false, true, false));
    }

    #[test]
    fn straggler_slows_compute_not_results() {
        use super::super::faults::FaultPlan;
        let plan = FaultPlan::parse("slow:1x2.0").unwrap();
        let results = World::run_with_faults::<f64, _, _>(2, NetworkModel::ideal(), plan, |comm| {
            comm.clock().advance_compute(1.0);
            comm.clock().now()
        });
        assert_eq!(results[0], 1.0);
        assert_eq!(results[1], 2.0);
    }

    #[test]
    fn outstanding_requests_are_counted() {
        let results = World::run::<f64, _, _>(2, NetworkModel::ideal(), |comm| {
            if comm.rank() == 0 {
                let a = comm.isend(1, Tag::P2p(0), Payload::Scalar(1.0));
                let b = comm.isend(1, Tag::P2p(1), Payload::Scalar(2.0));
                let c = comm.isend(1, Tag::P2p(2), Payload::Scalar(3.0));
                a.wait();
                b.wait();
                c.wait();
                comm.stats().max_outstanding_reqs()
            } else {
                let r0 = comm.irecv(0, Tag::P2p(0));
                let r1 = comm.irecv(0, Tag::P2p(1));
                r0.wait();
                r1.wait();
                comm.recv(0, Tag::P2p(2));
                comm.stats().max_outstanding_reqs()
            }
        });
        assert_eq!(results[0], 3);
        assert_eq!(results[1], 2);
    }
}
