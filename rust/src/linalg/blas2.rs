//! Level-2 BLAS: matrix-vector operations (row-major, packed).

use crate::Scalar;

/// `y = A x` with A `m x n` row-major.
pub fn gemv<S: Scalar>(m: usize, n: usize, a: &[S], x: &[S], y: &mut [S]) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(x.len(), n);
    debug_assert_eq!(y.len(), m);
    for i in 0..m {
        y[i] = super::blas1::dot(&a[i * n..(i + 1) * n], x);
    }
}

/// `y += A x` (the device-resident matvec accumulation: each element adds
/// one finished row dot, so the result is bit-identical to the former
/// gemv-into-scratch + axpy pair — same dot order, one final add).
pub fn gemv_add<S: Scalar>(m: usize, n: usize, a: &[S], x: &[S], y: &mut [S]) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(x.len(), n);
    debug_assert_eq!(y.len(), m);
    for i in 0..m {
        y[i] += super::blas1::dot(&a[i * n..(i + 1) * n], x);
    }
}

/// `y += A^T x`.  The column sums are finished in a scratch pass with the
/// same accumulation order as [`gemv_t`], then added element-wise — which
/// keeps the result bit-identical to the former gemv_t-into-scratch + axpy
/// pair (in-place accumulation would re-associate the sums).
pub fn gemv_t_add<S: Scalar>(m: usize, n: usize, a: &[S], x: &[S], y: &mut [S]) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(x.len(), m);
    debug_assert_eq!(y.len(), n);
    let mut tmp = vec![S::zero(); n];
    gemv_t(m, n, a, x, &mut tmp);
    for (yj, &tj) in y.iter_mut().zip(&tmp) {
        *yj += tj;
    }
}

/// `y -= A x` (accumulating matvec used by distributed substitution).
pub fn gemv_sub<S: Scalar>(m: usize, n: usize, a: &[S], x: &[S], y: &mut [S]) {
    debug_assert_eq!(a.len(), m * n);
    for i in 0..m {
        y[i] -= super::blas1::dot(&a[i * n..(i + 1) * n], x);
    }
}

/// `y = A^T x` with A `m x n` row-major (y has length n).
pub fn gemv_t<S: Scalar>(m: usize, n: usize, a: &[S], x: &[S], y: &mut [S]) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(x.len(), m);
    debug_assert_eq!(y.len(), n);
    for v in y.iter_mut() {
        *v = S::zero();
    }
    // Row-major A: accumulate row i of A scaled by x[i] — unit-stride inner loop.
    for i in 0..m {
        let xi = x[i];
        let row = &a[i * n..(i + 1) * n];
        for (yj, &aij) in y.iter_mut().zip(row) {
            *yj += xi * aij;
        }
    }
}

/// `y -= A^T x`.
pub fn gemv_t_sub<S: Scalar>(m: usize, n: usize, a: &[S], x: &[S], y: &mut [S]) {
    debug_assert_eq!(a.len(), m * n);
    for i in 0..m {
        let xi = x[i];
        let row = &a[i * n..(i + 1) * n];
        for (yj, &aij) in y.iter_mut().zip(row) {
            *yj -= xi * aij;
        }
    }
}

/// Rank-1 update `A -= x y^T` (the inner step of unblocked LU).
pub fn ger_sub<S: Scalar>(m: usize, n: usize, a: &mut [S], x: &[S], y: &[S]) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(x.len(), m);
    debug_assert_eq!(y.len(), n);
    for i in 0..m {
        let xi = x[i];
        let row = &mut a[i * n..(i + 1) * n];
        for (aij, &yj) in row.iter_mut().zip(y) {
            *aij -= xi * yj;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // A = [[1,2,3],[4,5,6]] (2x3)
    const A: [f64; 6] = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];

    #[test]
    fn gemv_basic() {
        let x = [1.0, 0.0, -1.0];
        let mut y = [0.0; 2];
        gemv(2, 3, &A, &x, &mut y);
        assert_eq!(y, [-2.0, -2.0]);
    }

    #[test]
    fn gemv_add_matches_gemv_then_axpy_bitwise() {
        let x = [0.371, -1.25, 0.8];
        let mut y = [10.0, -3.5];
        let mut tmp = [0.0; 2];
        gemv(2, 3, &A, &x, &mut tmp);
        let mut want = y;
        for i in 0..2 {
            want[i] += tmp[i];
        }
        gemv_add(2, 3, &A, &x, &mut y);
        assert_eq!(y.map(f64::to_bits), want.map(f64::to_bits));
    }

    #[test]
    fn gemv_t_add_matches_gemv_t_then_axpy_bitwise() {
        let x = [0.371, -1.25];
        let mut y = [10.0, -3.5, 0.125];
        let mut tmp = [0.0; 3];
        gemv_t(2, 3, &A, &x, &mut tmp);
        let mut want = y;
        for j in 0..3 {
            want[j] += tmp[j];
        }
        gemv_t_add(2, 3, &A, &x, &mut y);
        assert_eq!(y.map(f64::to_bits), want.map(f64::to_bits));
    }

    #[test]
    fn gemv_sub_accumulates() {
        let x = [1.0, 1.0, 1.0];
        let mut y = [10.0, 20.0];
        gemv_sub(2, 3, &A, &x, &mut y);
        assert_eq!(y, [10.0 - 6.0, 20.0 - 15.0]);
    }

    #[test]
    fn gemv_t_basic() {
        let x = [1.0, 2.0];
        let mut y = [0.0; 3];
        gemv_t(2, 3, &A, &x, &mut y);
        assert_eq!(y, [9.0, 12.0, 15.0]); // A^T x
    }

    #[test]
    fn gemv_t_sub_accumulates() {
        let x = [1.0, 1.0];
        let mut y = [10.0, 10.0, 10.0];
        gemv_t_sub(2, 3, &A, &x, &mut y);
        assert_eq!(y, [5.0, 3.0, 1.0]);
    }

    #[test]
    fn ger_sub_rank1() {
        let mut a = [0.0f64; 6];
        ger_sub(2, 3, &mut a, &[1.0, 2.0], &[3.0, 4.0, 5.0]);
        assert_eq!(a, [-3.0, -4.0, -5.0, -6.0, -8.0, -10.0]);
    }
}
