//! Local dense linear algebra in pure rust — the library's "serial ATLAS".
//!
//! The paper's ablation replaces CUBLAS-accelerated local computation with a
//! tuned serial CPU BLAS (ATLAS).  This module plays that role: row-major
//! dense kernels, register/cache-blocked where it matters (GEMM), used both
//! by the [`crate::accel::CpuEngine`] and by the serial reference solvers.
//!
//! Everything is generic over [`crate::Scalar`] (`f32` / `f64`) and operates
//! on caller-owned slices with explicit dimensions, row-major, tightly packed
//! (`lda == ncols`) — matching the tile storage of [`crate::dist`].

pub mod blas1;
pub mod blas2;
pub mod blas3;
pub mod chol;
pub mod givens;
pub mod lu;
pub mod trsm;

pub use blas1::{axpy, axpy_norm2, copy, dot, iamax, norm2_dot, nrm2, scal, swap, xpay};
pub use blas2::{gemv, gemv_add, gemv_sub, gemv_t, gemv_t_add, gemv_t_sub, ger_sub};
pub use blas3::{gemm, gemm_add, gemm_nt_sub, gemm_sub};
pub use chol::potrf;
pub use lu::{getrf, getrf_lda, laswp, lu_solve};
pub use trsm::{trsm_llu, trsm_rlt, trsm_ru, trsv_l, trsv_lt, trsv_lu, trsv_u};
