//! Local Cholesky factorisation (`potrf`), row-major, lower variant.

use crate::{Error, Result, Scalar};

/// In-place lower Cholesky of an `n x n` SPD matrix: A = L·L^T, L written to
/// the lower triangle (the strict upper triangle is zeroed so the buffer can
/// be used directly as L).
pub fn potrf<S: Scalar>(n: usize, a: &mut [S]) -> Result<()> {
    debug_assert_eq!(a.len(), n * n);
    for j in 0..n {
        // d = a[j,j] - sum_{k<j} L[j,k]^2
        let mut d = a[j * n + j];
        for k in 0..j {
            let l = a[j * n + k];
            d -= l * l;
        }
        if d <= S::zero() {
            return Err(Error::Breakdown {
                method: "potrf",
                detail: format!("matrix not positive definite at column {j}"),
            });
        }
        let ljj = d.sqrt();
        a[j * n + j] = ljj;
        let inv = S::one() / ljj;
        for i in j + 1..n {
            // L[i,j] = (a[i,j] - sum_{k<j} L[i,k] L[j,k]) / L[j,j]
            let mut s = a[i * n + j];
            let (jrow, irow) = {
                let (head, tail) = a.split_at(i * n);
                (&head[j * n..j * n + j], &tail[..j])
            };
            for (&ljk, &lik) in jrow.iter().zip(irow) {
                s -= ljk * lik;
            }
            a[i * n + j] = s * inv;
        }
    }
    // Zero the strict upper triangle.
    for i in 0..n {
        for j in i + 1..n {
            a[i * n + j] = S::zero();
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    fn spd(rng: &mut Prng, n: usize) -> Vec<f64> {
        let mut g = vec![0.0f64; n * n];
        rng.fill_normal(&mut g);
        let mut a = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += g[i * n + k] * g[j * n + k];
                }
                a[i * n + j] = s;
            }
            a[i * n + i] += n as f64;
        }
        a
    }

    #[test]
    fn potrf_reconstructs() {
        let mut rng = Prng::new(31);
        for n in [1usize, 2, 7, 20] {
            let a0 = spd(&mut rng, n);
            let mut l = a0.clone();
            potrf(n, &mut l).unwrap();
            // check L L^T == A and upper zero
            for i in 0..n {
                for j in 0..n {
                    let mut s = 0.0;
                    for k in 0..=i.min(j) {
                        s += l[i * n + k] * l[j * n + k];
                    }
                    assert!((s - a0[i * n + j]).abs() < 1e-8, "n={n} ({i},{j})");
                    if j > i {
                        assert_eq!(l[i * n + j], 0.0);
                    }
                }
            }
        }
    }

    #[test]
    fn potrf_rejects_indefinite() {
        let mut a = vec![1.0f64, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(matches!(potrf(2, &mut a), Err(Error::Breakdown { .. })));
    }

    #[test]
    fn potrf_identity() {
        let n = 5;
        let mut a = vec![0.0f64; n * n];
        for i in 0..n {
            a[i * n + i] = 1.0;
        }
        potrf(n, &mut a).unwrap();
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert_eq!(a[i * n + j], want);
            }
        }
    }
}
