//! Level-3 BLAS: cache-blocked GEMM (row-major, packed).
//!
//! The delayed-update kernel `gemm_sub` (C -= A·B) is the serial hot spot of
//! the ATLAS-path solvers, so it gets the tuning attention: (mc, kc) L2/L1
//! blocking and an i-k-j loop order whose inner loop is unit-stride over both
//! B and C rows (auto-vectorises cleanly).

use crate::Scalar;

/// L2 block over rows of A / C.
const MC: usize = 64;
/// L1 block over the contraction dimension.
const KC: usize = 128;

#[inline]
fn gemm_block<S: Scalar, const SUB: bool>(
    n: usize,
    k: usize,
    a: &[S],
    b: &[S],
    c: &mut [S],
    i0: usize,
    i1: usize,
    p0: usize,
    p1: usize,
) {
    // Branch-free 4-wide micro-kernel: on dense random tiles a
    // data-dependent zero-skip per multiply is pure misprediction overhead,
    // so every `a` element is applied unconditionally; the j-loop is
    // unrolled 4-wide over unit-stride B and C rows (independent
    // accumulators keep the FMA pipes full and auto-vectorise cleanly).
    for i in i0..i1 {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for p in p0..p1 {
            let aip = if SUB { S::zero() - arow[p] } else { arow[p] };
            let brow = &b[p * n..(p + 1) * n];
            let chunks = n / 4;
            for q in 0..chunks {
                let j = q * 4;
                crow[j] += aip * brow[j];
                crow[j + 1] += aip * brow[j + 1];
                crow[j + 2] += aip * brow[j + 2];
                crow[j + 3] += aip * brow[j + 3];
            }
            for j in chunks * 4..n {
                crow[j] += aip * brow[j];
            }
        }
    }
}

/// `C = A·B` (A `m x k`, B `k x n`, C `m x n`, all row-major).
pub fn gemm<S: Scalar>(m: usize, n: usize, k: usize, a: &[S], b: &[S], c: &mut [S]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for v in c.iter_mut() {
        *v = S::zero();
    }
    for i0 in (0..m).step_by(MC) {
        let i1 = (i0 + MC).min(m);
        for p0 in (0..k).step_by(KC) {
            let p1 = (p0 + KC).min(k);
            gemm_block::<S, false>(n, k, a, b, c, i0, i1, p0, p1);
        }
    }
}

/// `C += A·B` — the SUMMA local accumulation ([`crate::accel::Engine::gemm_acc`]):
/// one kernel instead of a fresh-GEMM-plus-host-axpy pair, so `C` can stay
/// device-resident across panel steps.
pub fn gemm_add<S: Scalar>(m: usize, n: usize, k: usize, a: &[S], b: &[S], c: &mut [S]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for i0 in (0..m).step_by(MC) {
        let i1 = (i0 + MC).min(m);
        for p0 in (0..k).step_by(KC) {
            let p1 = (p0 + KC).min(k);
            gemm_block::<S, false>(n, k, a, b, c, i0, i1, p0, p1);
        }
    }
}

/// `C -= A·B` — the BLAS-3 delayed rank-k update of block LU / Cholesky.
pub fn gemm_sub<S: Scalar>(m: usize, n: usize, k: usize, a: &[S], b: &[S], c: &mut [S]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for i0 in (0..m).step_by(MC) {
        let i1 = (i0 + MC).min(m);
        for p0 in (0..k).step_by(KC) {
            let p1 = (p0 + KC).min(k);
            gemm_block::<S, true>(n, k, a, b, c, i0, i1, p0, p1);
        }
    }
}

/// `C -= A·B^T` (A `m x k`, B `n x k`, C `m x n`) — the symmetric trailing
/// update of block Cholesky without materialising B^T.
pub fn gemm_nt_sub<S: Scalar>(m: usize, n: usize, k: usize, a: &[S], b: &[S], c: &mut [S]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    // C[i,j] -= dot(A[i,:], B[j,:]) — both rows unit-stride.
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (j, cij) in crow.iter_mut().enumerate() {
            *cij -= super::blas1::dot(arow, &b[j * k..(j + 1) * k]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    fn naive_gemm(m: usize, n: usize, k: usize, a: &[f64], b: &[f64]) -> Vec<f64> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for p in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + p] * b[p * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn gemm_matches_naive() {
        let mut rng = Prng::new(1);
        for (m, n, k) in [(3, 4, 5), (17, 9, 33), (64, 64, 64), (70, 130, 129)] {
            let mut a = vec![0.0f64; m * k];
            let mut b = vec![0.0f64; k * n];
            rng.fill_normal(&mut a);
            rng.fill_normal(&mut b);
            let mut c = vec![0.0f64; m * n];
            gemm(m, n, k, &a, &b, &mut c);
            let want = naive_gemm(m, n, k, &a, &b);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() < 1e-10, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn gemm_sub_matches() {
        let mut rng = Prng::new(2);
        let (m, n, k) = (33, 21, 40);
        let mut a = vec![0.0f64; m * k];
        let mut b = vec![0.0f64; k * n];
        let mut c0 = vec![0.0f64; m * n];
        rng.fill_normal(&mut a);
        rng.fill_normal(&mut b);
        rng.fill_normal(&mut c0);
        let mut c = c0.clone();
        gemm_sub(m, n, k, &a, &b, &mut c);
        let prod = naive_gemm(m, n, k, &a, &b);
        for i in 0..m * n {
            assert!((c[i] - (c0[i] - prod[i])).abs() < 1e-10);
        }
    }

    #[test]
    fn gemm_nt_sub_matches() {
        let mut rng = Prng::new(5);
        let (m, n, k) = (12, 9, 15);
        let mut a = vec![0.0f64; m * k];
        let mut b = vec![0.0f64; n * k];
        let mut c0 = vec![0.0f64; m * n];
        rng.fill_normal(&mut a);
        rng.fill_normal(&mut b);
        rng.fill_normal(&mut c0);
        let mut c = c0.clone();
        gemm_nt_sub(m, n, k, &a, &b, &mut c);
        for i in 0..m {
            for j in 0..n {
                let prod: f64 = (0..k).map(|p| a[i * k + p] * b[j * k + p]).sum();
                assert!((c[i * n + j] - (c0[i * n + j] - prod)).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn gemm_add_accumulates() {
        let mut rng = Prng::new(7);
        for (m, n, k) in [(3, 4, 5), (17, 9, 33), (64, 64, 64), (13, 7, 2)] {
            let mut a = vec![0.0f64; m * k];
            let mut b = vec![0.0f64; k * n];
            let mut c0 = vec![0.0f64; m * n];
            rng.fill_normal(&mut a);
            rng.fill_normal(&mut b);
            rng.fill_normal(&mut c0);
            let mut c = c0.clone();
            gemm_add(m, n, k, &a, &b, &mut c);
            let prod = naive_gemm(m, n, k, &a, &b);
            for i in 0..m * n {
                assert!((c[i] - (c0[i] + prod[i])).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn sparse_operands_survive_the_branch_free_kernel() {
        // The old inner loop skipped a == 0 terms; the branch-free kernel
        // must produce the same result on zero-heavy operands (incl. the
        // -0.0 corner: 0 - 0.0 multiplies through harmlessly).
        let mut rng = Prng::new(11);
        let (m, n, k) = (19, 23, 17);
        let mut a = vec![0.0f64; m * k];
        rng.fill_normal(&mut a);
        for (i, v) in a.iter_mut().enumerate() {
            if i % 3 != 0 {
                *v = 0.0;
            }
        }
        let mut b = vec![0.0f64; k * n];
        rng.fill_normal(&mut b);
        let mut c = vec![0.0f64; m * n];
        gemm(m, n, k, &a, &b, &mut c);
        let want = naive_gemm(m, n, k, &a, &b);
        for i in 0..m * n {
            assert!((c[i] - want[i]).abs() < 1e-12);
        }
        let mut cs = want.clone();
        gemm_sub(m, n, k, &a, &b, &mut cs);
        for v in &cs {
            assert!(v.abs() < 1e-10, "C - A·B with C = A·B must vanish");
        }
    }

    #[test]
    fn gemm_identity() {
        let n = 20;
        let mut rng = Prng::new(3);
        let mut a = vec![0.0f64; n * n];
        rng.fill_normal(&mut a);
        let mut eye = vec![0.0f64; n * n];
        for i in 0..n {
            eye[i * n + i] = 1.0;
        }
        let mut c = vec![0.0f64; n * n];
        gemm(n, n, n, &a, &eye, &mut c);
        assert_eq!(c, a);
    }

    #[test]
    fn gemm_f32_tolerance() {
        let mut rng = Prng::new(4);
        let (m, n, k) = (50, 50, 200);
        let mut a = vec![0.0f32; m * k];
        let mut b = vec![0.0f32; k * n];
        rng.fill_normal(&mut a);
        rng.fill_normal(&mut b);
        let mut c = vec![0.0f32; m * n];
        gemm(m, n, k, &a, &b, &mut c);
        // spot-check one element against f64 accumulation
        let i = 13;
        let j = 7;
        let want: f64 = (0..k).map(|p| a[i * k + p] as f64 * b[p * n + j] as f64).sum();
        assert!((c[i * n + j] as f64 - want).abs() < 1e-3);
    }
}
