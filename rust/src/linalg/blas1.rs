//! Level-1 BLAS: vector-vector operations.

use crate::Scalar;

/// Inner product `x . y`.
pub fn dot<S: Scalar>(x: &[S], y: &[S]) -> S {
    debug_assert_eq!(x.len(), y.len());
    // Four-way unrolled accumulation: better ILP and (for f32) less error
    // growth than a single serial chain.
    let n = x.len();
    let chunks = n / 4;
    let (mut a0, mut a1, mut a2, mut a3) = (S::zero(), S::zero(), S::zero(), S::zero());
    for c in 0..chunks {
        let i = c * 4;
        a0 += x[i] * y[i];
        a1 += x[i + 1] * y[i + 1];
        a2 += x[i + 2] * y[i + 2];
        a3 += x[i + 3] * y[i + 3];
    }
    let mut acc = (a0 + a1) + (a2 + a3);
    for i in chunks * 4..n {
        acc += x[i] * y[i];
    }
    acc
}

/// `y += alpha * x`.
pub fn axpy<S: Scalar>(alpha: S, x: &[S], y: &mut [S]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// `x *= alpha`.
pub fn scal<S: Scalar>(alpha: S, x: &mut [S]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// `y = x + beta * y` in one pass — the fused form of `scal(beta, y)`
/// followed by `axpy(1, x, y)`.  Bit-identical to that pair: IEEE addition
/// commutes, so `x + beta*y == beta*y + 1*x` exactly.
pub fn xpay<S: Scalar>(beta: S, x: &[S], y: &mut [S]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi = xi + beta * *yi;
    }
}

/// Fused `y += alpha * x; ⟨y, y⟩` — one pass over both vectors instead of
/// an axpy kernel plus a dot kernel.  The arithmetic is the unfused
/// sequence's exactly (same axpy loop, then the same 4-way-unrolled dot).
pub fn axpy_norm2<S: Scalar>(alpha: S, x: &[S], y: &mut [S]) -> S {
    axpy(alpha, x, y);
    dot(y, y)
}

/// Fused `(⟨x, x⟩, ⟨x, y⟩)` — the pipelined-CG reduction pair computed in
/// one pass; each lane is the plain [`dot`] bit-for-bit.
pub fn norm2_dot<S: Scalar>(x: &[S], y: &[S]) -> (S, S) {
    (dot(x, x), dot(x, y))
}

/// Euclidean norm.
pub fn nrm2<S: Scalar>(x: &[S]) -> S {
    dot(x, x).sqrt()
}

/// Inner product `x . y` with every product and the running sums carried in
/// the wide dtype `S::Hi` — the f64-accumulate arm of the mixed-precision
/// Krylov kernels.  Same 4-way unrolled association as [`dot`], so for
/// `S = f64` (where `Hi = S`) it reproduces [`dot`] bit for bit.
pub fn dot_hi<S: Scalar>(x: &[S], y: &[S]) -> S::Hi {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 4;
    let zero = <S::Hi as num_traits::Zero>::zero();
    let (mut a0, mut a1, mut a2, mut a3) = (zero, zero, zero, zero);
    for c in 0..chunks {
        let i = c * 4;
        a0 += x[i].to_hi() * y[i].to_hi();
        a1 += x[i + 1].to_hi() * y[i + 1].to_hi();
        a2 += x[i + 2].to_hi() * y[i + 2].to_hi();
        a3 += x[i + 3].to_hi() * y[i + 3].to_hi();
    }
    let mut acc = (a0 + a1) + (a2 + a3);
    for i in chunks * 4..n {
        acc += x[i].to_hi() * y[i].to_hi();
    }
    acc
}

/// Fused `y += alpha * x; ⟨y, y⟩` with the norm accumulated in `S::Hi`:
/// the update stays in the storage dtype (that is what ships over the
/// wire), only the reduction rides the wide accumulator.
pub fn axpy_norm2_hi<S: Scalar>(alpha: S, x: &[S], y: &mut [S]) -> S::Hi {
    axpy(alpha, x, y);
    dot_hi(y, y)
}

/// Fused `(⟨x, x⟩, ⟨x, y⟩)` with both lanes accumulated in `S::Hi`; each
/// lane is the plain [`dot_hi`] bit for bit.
pub fn norm2_dot_hi<S: Scalar>(x: &[S], y: &[S]) -> (S::Hi, S::Hi) {
    (dot_hi(x, x), dot_hi(x, y))
}

/// Index of the element with the largest absolute value (first on ties).
pub fn iamax<S: Scalar>(x: &[S]) -> usize {
    let mut best = 0usize;
    let mut bv = S::zero();
    for (i, &v) in x.iter().enumerate() {
        let a = v.abs();
        if a > bv {
            bv = a;
            best = i;
        }
    }
    best
}

/// `y = x`.
pub fn copy<S: Scalar>(x: &[S], y: &mut [S]) {
    y.copy_from_slice(x);
}

/// Exchange `x` and `y`.
pub fn swap<S: Scalar>(x: &mut [S], y: &mut [S]) {
    debug_assert_eq!(x.len(), y.len());
    for (a, b) in x.iter_mut().zip(y.iter_mut()) {
        std::mem::swap(a, b);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let x: Vec<f64> = (0..101).map(|i| i as f64 * 0.5).collect();
        let y: Vec<f64> = (0..101).map(|i| (i as f64).sin()).collect();
        let naive: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - naive).abs() < 1e-9);
    }

    #[test]
    fn axpy_scal() {
        let x = vec![1.0f32, 2.0, 3.0];
        let mut y = vec![10.0f32, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
        scal(0.5, &mut y);
        assert_eq!(y, vec![6.0, 12.0, 18.0]);
    }

    #[test]
    fn fused_ops_match_unfused_sequences_bitwise() {
        let x: Vec<f64> = (0..37).map(|i| (i as f64 * 0.7).sin()).collect();
        let y0: Vec<f64> = (0..37).map(|i| (i as f64 * 1.3).cos()).collect();
        // xpay == scal-then-axpy, bit for bit.
        let beta = 0.8311;
        let mut a = y0.clone();
        scal(beta, &mut a);
        axpy(1.0, &x, &mut a);
        let mut b = y0.clone();
        xpay(beta, &x, &mut b);
        assert_eq!(a, b);
        // axpy_norm2 == axpy-then-dot, bit for bit.
        let mut c = y0.clone();
        axpy(-0.25, &x, &mut c);
        let want = dot(&c, &c);
        let mut d = y0.clone();
        assert_eq!(axpy_norm2(-0.25, &x, &mut d), want);
        assert_eq!(c, d);
        // norm2_dot lanes are the plain dots.
        assert_eq!(norm2_dot(&x, &y0), (dot(&x, &x), dot(&x, &y0)));
    }

    #[test]
    fn hi_accumulate_is_dot_bitwise_for_f64_and_tighter_for_f32() {
        // f64: Hi = Self, so the wide kernel IS the plain kernel.
        let x: Vec<f64> = (0..41).map(|i| (i as f64 * 0.7).sin()).collect();
        let y: Vec<f64> = (0..41).map(|i| (i as f64 * 1.3).cos()).collect();
        assert_eq!(dot_hi(&x, &y), dot(&x, &y));
        assert_eq!(norm2_dot_hi(&x, &y), norm2_dot(&x, &y));
        let mut a = y.clone();
        let mut b = y.clone();
        assert_eq!(axpy_norm2_hi(0.25, &x, &mut a), axpy_norm2(0.25, &x, &mut b));
        assert_eq!(a, b);
        // f32 storage: the wide accumulator must land closer to the exact
        // (f64) answer than the f32 chain on a cancellation-heavy input.
        let xs: Vec<f32> = (0..10_001).map(|i| if i % 2 == 0 { 1.0e3 } else { -1.0e3 }).collect();
        let ys: Vec<f32> = (0..10_001).map(|i| 1.0 + (i as f32) * 1.0e-4).collect();
        let exact: f64 = xs.iter().zip(&ys).map(|(&a, &b)| a as f64 * b as f64).sum();
        let wide = dot_hi(&xs, &ys);
        let narrow = dot(&xs, &ys) as f64;
        assert!((wide - exact).abs() <= (narrow - exact).abs());
        assert!((wide - exact).abs() < 1e-6 * exact.abs().max(1.0));
    }

    #[test]
    fn nrm2_pythagoras() {
        assert!((nrm2(&[3.0f64, 4.0]) - 5.0).abs() < 1e-15);
    }

    #[test]
    fn iamax_finds_peak() {
        assert_eq!(iamax(&[1.0f64, -7.0, 3.0]), 1);
        assert_eq!(iamax(&[0.0f64, 0.0]), 0);
        // first on ties
        assert_eq!(iamax(&[2.0f64, -2.0]), 0);
    }

    #[test]
    fn copy_swap() {
        let mut a = vec![1.0f64, 2.0];
        let mut b = vec![3.0f64, 4.0];
        swap(&mut a, &mut b);
        assert_eq!(a, vec![3.0, 4.0]);
        let mut c = vec![0.0f64; 2];
        copy(&a, &mut c);
        assert_eq!(c, a);
    }
}
