//! Triangular solves (tile-local), matching the AOT op set of
//! `python/compile/model.py` so the CPU and XLA engines are interchangeable.
//!
//! All matrices are `n x n` row-major, packed; B/X are `n x m` row-major
//! (or length-n vectors for the `trsv_*` forms).  Solves are in place.

use crate::Scalar;

/// Solve `L X = B` with L **unit** lower triangular; B (`n x m`) is
/// overwritten with X.  (Block LU: computes the U12 block row.)
pub fn trsm_llu<S: Scalar>(n: usize, m: usize, l: &[S], b: &mut [S]) {
    debug_assert_eq!(l.len(), n * n);
    debug_assert_eq!(b.len(), n * m);
    for i in 0..n {
        // b[i] -= sum_{j<i} L[i,j] * b[j]
        let (head, tail) = b.split_at_mut(i * m);
        let bi = &mut tail[..m];
        for j in 0..i {
            let lij = l[i * n + j];
            if lij != S::zero() {
                let bj = &head[j * m..(j + 1) * m];
                for (x, &y) in bi.iter_mut().zip(bj) {
                    *x -= lij * y;
                }
            }
        }
    }
}

/// Solve `X U = B` with U upper triangular; B (`m x n`) overwritten with X.
/// (Block LU: computes the L21 block column.)
pub fn trsm_ru<S: Scalar>(m: usize, n: usize, u: &[S], b: &mut [S]) {
    debug_assert_eq!(u.len(), n * n);
    debug_assert_eq!(b.len(), m * n);
    // Row-oriented: each row of B solves x U = b independently.
    for r in 0..m {
        let row = &mut b[r * n..(r + 1) * n];
        for j in 0..n {
            let mut s = row[j];
            for k in 0..j {
                s -= row[k] * u[k * n + j];
            }
            row[j] = s / u[j * n + j];
        }
    }
}

/// Solve `X L^T = B` with L lower triangular; B (`m x n`) overwritten with X.
/// (Block Cholesky: computes the L21 block column.)
pub fn trsm_rlt<S: Scalar>(m: usize, n: usize, l: &[S], b: &mut [S]) {
    debug_assert_eq!(l.len(), n * n);
    debug_assert_eq!(b.len(), m * n);
    // X L^T = B row-wise: x_j = (b_j - sum_{k<j} x_k L[j,k]) / L[j,j]
    for r in 0..m {
        let row = &mut b[r * n..(r + 1) * n];
        for j in 0..n {
            let mut s = row[j];
            let lrow = &l[j * n..j * n + j];
            for (k, &ljk) in lrow.iter().enumerate() {
                s -= row[k] * ljk;
            }
            row[j] = s / l[j * n + j];
        }
    }
}

/// Solve `L y = b` with L **unit** lower triangular (vector form, in place).
pub fn trsv_lu<S: Scalar>(n: usize, l: &[S], b: &mut [S]) {
    debug_assert_eq!(l.len(), n * n);
    debug_assert_eq!(b.len(), n);
    for i in 0..n {
        let mut s = b[i];
        for j in 0..i {
            s -= l[i * n + j] * b[j];
        }
        b[i] = s;
    }
}

/// Solve `L y = b` with L general lower triangular (vector form, in place).
pub fn trsv_l<S: Scalar>(n: usize, l: &[S], b: &mut [S]) {
    debug_assert_eq!(l.len(), n * n);
    for i in 0..n {
        let mut s = b[i];
        for j in 0..i {
            s -= l[i * n + j] * b[j];
        }
        b[i] = s / l[i * n + i];
    }
}

/// Solve `U x = y` with U upper triangular (vector form, in place).
pub fn trsv_u<S: Scalar>(n: usize, u: &[S], b: &mut [S]) {
    debug_assert_eq!(u.len(), n * n);
    for i in (0..n).rev() {
        let mut s = b[i];
        for j in i + 1..n {
            s -= u[i * n + j] * b[j];
        }
        b[i] = s / u[i * n + i];
    }
}

/// Solve `L^T x = y` with L lower triangular (vector form, in place).
pub fn trsv_lt<S: Scalar>(n: usize, l: &[S], b: &mut [S]) {
    debug_assert_eq!(l.len(), n * n);
    for i in (0..n).rev() {
        let mut s = b[i];
        for j in i + 1..n {
            s -= l[j * n + i] * b[j]; // (L^T)[i,j] = L[j,i]
        }
        b[i] = s / l[i * n + i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    fn rand_lower(rng: &mut Prng, n: usize, unit: bool) -> Vec<f64> {
        let mut l = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..=i {
                l[i * n + j] = rng.normal() * 0.3;
            }
            l[i * n + i] = if unit { 1.0 } else { rng.normal().abs() + 1.0 };
        }
        l
    }

    fn rand_upper(rng: &mut Prng, n: usize) -> Vec<f64> {
        let mut u = vec![0.0f64; n * n];
        for i in 0..n {
            for j in i..n {
                u[i * n + j] = rng.normal() * 0.3;
            }
            u[i * n + i] = rng.normal().abs() + 1.0;
        }
        u
    }

    fn matmul(m: usize, n: usize, k: usize, a: &[f64], b: &[f64]) -> Vec<f64> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for p in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + p] * b[p * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn trsm_llu_solves() {
        let mut rng = Prng::new(10);
        let (n, m) = (13, 7);
        let l = rand_lower(&mut rng, n, true);
        let mut b = vec![0.0f64; n * m];
        rng.fill_normal(&mut b);
        let b0 = b.clone();
        trsm_llu(n, m, &l, &mut b);
        let lb = matmul(n, m, n, &l, &b);
        for i in 0..n * m {
            assert!((lb[i] - b0[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn trsm_ru_solves() {
        let mut rng = Prng::new(11);
        let (m, n) = (9, 12);
        let u = rand_upper(&mut rng, n);
        let mut b = vec![0.0f64; m * n];
        rng.fill_normal(&mut b);
        let b0 = b.clone();
        trsm_ru(m, n, &u, &mut b);
        let xu = matmul(m, n, n, &b, &u);
        for i in 0..m * n {
            assert!((xu[i] - b0[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn trsm_rlt_solves() {
        let mut rng = Prng::new(12);
        let (m, n) = (8, 11);
        let l = rand_lower(&mut rng, n, false);
        let mut b = vec![0.0f64; m * n];
        rng.fill_normal(&mut b);
        let b0 = b.clone();
        trsm_rlt(m, n, &l, &mut b);
        // X L^T == B ?
        let mut lt = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                lt[i * n + j] = l[j * n + i];
            }
        }
        let xlt = matmul(m, n, n, &b, &lt);
        for i in 0..m * n {
            assert!((xlt[i] - b0[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn trsv_variants_solve() {
        let mut rng = Prng::new(13);
        let n = 17;
        let l = rand_lower(&mut rng, n, false);
        let lu = rand_lower(&mut rng, n, true);
        let u = rand_upper(&mut rng, n);
        let mut b = vec![0.0f64; n];
        rng.fill_normal(&mut b);

        // trsv_l
        let mut x = b.clone();
        trsv_l(n, &l, &mut x);
        let mut r = vec![0.0; n];
        for i in 0..n {
            for j in 0..n {
                r[i] += l[i * n + j] * x[j];
            }
        }
        for i in 0..n {
            assert!((r[i] - b[i]).abs() < 1e-9, "trsv_l");
        }

        // trsv_lu
        let mut x = b.clone();
        trsv_lu(n, &lu, &mut x);
        let mut r = vec![0.0; n];
        for i in 0..n {
            for j in 0..n {
                r[i] += lu[i * n + j] * x[j];
            }
        }
        for i in 0..n {
            assert!((r[i] - b[i]).abs() < 1e-9, "trsv_lu");
        }

        // trsv_u
        let mut x = b.clone();
        trsv_u(n, &u, &mut x);
        let mut r = vec![0.0; n];
        for i in 0..n {
            for j in 0..n {
                r[i] += u[i * n + j] * x[j];
            }
        }
        for i in 0..n {
            assert!((r[i] - b[i]).abs() < 1e-9, "trsv_u");
        }

        // trsv_lt
        let mut x = b.clone();
        trsv_lt(n, &l, &mut x);
        let mut r = vec![0.0; n];
        for i in 0..n {
            for j in 0..n {
                r[i] += l[j * n + i] * x[j];
            }
        }
        for i in 0..n {
            assert!((r[i] - b[i]).abs() < 1e-9, "trsv_lt");
        }
    }

    #[test]
    fn unit_diagonal_ignored_values() {
        // trsm_llu must never read the diagonal: poison it.
        let n = 5;
        let mut rng = Prng::new(14);
        let mut l = rand_lower(&mut rng, n, true);
        for i in 0..n {
            l[i * n + i] = f64::NAN;
        }
        let mut b = vec![1.0f64; n];
        trsv_lu(n, &l, &mut b);
        assert!(b.iter().all(|x| x.is_finite()), "diagonal must be implicit");
    }
}
