//! Local LU factorisation with partial pivoting (`getrf`) and row
//! interchange application (`laswp`) — LAPACK-style, row-major.
//!
//! `getrf` factorises a (possibly rectangular) `m x n` panel in place:
//! `P A = L U` with L unit lower (its strict lower part stored in A) and U
//! upper.  The distributed block LU gathers each panel to its owner, calls
//! this, and scatters the factors back (DESIGN.md S9).

use super::blas1;
use crate::{Error, Result, Scalar};

/// In-place partial-pivoted LU of an `m x n` row-major panel.
/// Returns the pivot vector: `piv[j] = i` means rows j and i were swapped at
/// step j (LAPACK ipiv convention, 0-based).
pub fn getrf<S: Scalar>(m: usize, n: usize, a: &mut [S]) -> Result<Vec<usize>> {
    getrf_lda(m, n, n, a)
}

/// [`getrf`] over a sub-panel embedded in a wider buffer (row stride `lda`):
/// the distributed factorisation uses this to factor the *real* rows/columns
/// of a tile-padded panel without disturbing the identity padding.
pub fn getrf_lda<S: Scalar>(m: usize, n: usize, lda: usize, a: &mut [S]) -> Result<Vec<usize>> {
    debug_assert!(lda >= n);
    debug_assert!(a.len() >= m * lda || m == 0);
    let steps = m.min(n);
    let mut piv = Vec::with_capacity(steps);
    for j in 0..steps {
        // Pivot search in column j, rows j..m.
        let mut p = j;
        let mut best = a[j * lda + j].abs();
        for i in j + 1..m {
            let v = a[i * lda + j].abs();
            if v > best {
                best = v;
                p = i;
            }
        }
        if best == S::zero() {
            return Err(Error::Breakdown {
                method: "getrf",
                detail: format!("exactly singular at column {j}"),
            });
        }
        piv.push(p);
        if p != j {
            let (lo, hi) = a.split_at_mut(p * lda);
            blas1::swap(&mut lo[j * lda..j * lda + n], &mut hi[..n]);
        }
        // Scale multipliers, rank-1 update of the trailing block.
        let pivot = a[j * lda + j];
        let inv = S::one() / pivot;
        for i in j + 1..m {
            a[i * lda + j] *= inv;
        }
        for i in j + 1..m {
            let lij = a[i * lda + j];
            if lij == S::zero() {
                continue;
            }
            // a[i, j+1..n] -= lij * a[j, j+1..n]; split_at_mut for aliasing.
            let (urow, irow) = {
                let (head, tail) = a.split_at_mut(i * lda);
                (&head[j * lda + j + 1..j * lda + n], &mut tail[j + 1..n])
            };
            for (x, &u) in irow.iter_mut().zip(urow) {
                *x -= lij * u;
            }
        }
    }
    Ok(piv)
}

/// Apply the row interchanges recorded by [`getrf`] to an `m x n` matrix
/// (forward order).  `piv[j] = i` swaps rows j and i.
pub fn laswp<S: Scalar>(n: usize, a: &mut [S], piv: &[usize]) {
    for (j, &p) in piv.iter().enumerate() {
        if p != j {
            let (lo_idx, hi_idx) = (j.min(p), j.max(p));
            let (lo, hi) = a.split_at_mut(hi_idx * n);
            blas1::swap(&mut lo[lo_idx * n..(lo_idx + 1) * n], &mut hi[..n]);
        }
    }
}

/// Convenience: solve `A x = b` densely via LU (serial path / oracles).
pub fn lu_solve<S: Scalar>(n: usize, a: &mut [S], b: &mut [S]) -> Result<()> {
    debug_assert_eq!(a.len(), n * n);
    debug_assert_eq!(b.len(), n);
    let piv = getrf(n, n, a)?;
    // Apply pivots to b.
    for (j, &p) in piv.iter().enumerate() {
        if p != j {
            b.swap(j, p);
        }
    }
    super::trsm::trsv_lu(n, a, b);
    super::trsm::trsv_u(n, a, b);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    fn reconstruct(m: usize, n: usize, lu: &[f64], piv: &[usize]) -> Vec<f64> {
        // build L (m x s) and U (s x n), s = min(m, n); return P^T L U
        let s = m.min(n);
        let mut l = vec![0.0; m * s];
        let mut u = vec![0.0; s * n];
        for i in 0..m {
            for j in 0..s.min(i) {
                l[i * s + j] = lu[i * n + j];
            }
            if i < s {
                l[i * s + i] = 1.0;
            }
        }
        for i in 0..s {
            for j in i..n {
                u[i * n + j] = lu[i * n + j];
            }
        }
        let mut pa = vec![0.0; m * n];
        for i in 0..m {
            for p in 0..s {
                for j in 0..n {
                    pa[i * n + j] += l[i * s + p] * u[p * n + j];
                }
            }
        }
        // undo pivots (apply inverse permutation: reverse order swaps)
        for (j, &p) in piv.iter().enumerate().rev() {
            if p != j {
                for col in 0..n {
                    pa.swap(j * n + col, p * n + col);
                }
            }
        }
        pa
    }

    #[test]
    fn getrf_reconstructs_square() {
        let mut rng = Prng::new(21);
        for n in [1usize, 2, 5, 16, 33] {
            let mut a0 = vec![0.0f64; n * n];
            rng.fill_normal(&mut a0);
            let mut a = a0.clone();
            let piv = getrf(n, n, &mut a).unwrap();
            let got = reconstruct(n, n, &a, &piv);
            for i in 0..n * n {
                assert!((got[i] - a0[i]).abs() < 1e-9, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn getrf_reconstructs_tall_panel() {
        let mut rng = Prng::new(22);
        let (m, n) = (40, 8);
        let mut a0 = vec![0.0f64; m * n];
        rng.fill_normal(&mut a0);
        let mut a = a0.clone();
        let piv = getrf(m, n, &mut a).unwrap();
        assert_eq!(piv.len(), 8);
        let got = reconstruct(m, n, &a, &piv);
        for i in 0..m * n {
            assert!((got[i] - a0[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn getrf_pivots_actually_pivot() {
        // Matrix needing a swap: first pivot is 0.
        let mut a = vec![0.0f64, 1.0, 1.0, 0.0];
        let piv = getrf(2, 2, &mut a).unwrap();
        assert_eq!(piv[0], 1);
    }

    #[test]
    fn getrf_singular_errors() {
        let mut a = vec![1.0f64, 2.0, 2.0, 4.0]; // rank 1
        let err = getrf(2, 2, &mut a).unwrap_err();
        assert!(matches!(err, Error::Breakdown { .. }));
    }

    #[test]
    fn laswp_applies_in_forward_order() {
        // 3 rows; piv = [2, 2]: step0 swaps r0<->r2, step1 swaps r1<->r2.
        let mut a: Vec<f64> = vec![0.0, 1.0, 2.0]; // one column
        laswp(1, &mut a, &[2, 2]);
        assert_eq!(a, vec![2.0, 0.0, 1.0]);
    }

    #[test]
    fn lu_solve_random_system() {
        let mut rng = Prng::new(23);
        let n = 24;
        let mut a = vec![0.0f64; n * n];
        rng.fill_normal(&mut a);
        for i in 0..n {
            a[i * n + i] += n as f64; // well-conditioned
        }
        let x_true: Vec<f64> = (0..n).map(|i| (i as f64).cos()).collect();
        let mut b = vec![0.0; n];
        for i in 0..n {
            for j in 0..n {
                b[i] += a[i * n + j] * x_true[j];
            }
        }
        let mut a_f = a.clone();
        lu_solve(n, &mut a_f, &mut b).unwrap();
        for i in 0..n {
            assert!((b[i] - x_true[i]).abs() < 1e-9);
        }
    }
}
