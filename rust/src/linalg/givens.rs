//! Givens rotations — the GMRES Hessenberg least-squares machinery.
//!
//! GMRES(m) reduces the (k+1) x k Hessenberg matrix to triangular form with
//! one rotation per column, applied incrementally as columns arrive.  The
//! rotations and the small triangular system are replicated on every rank
//! (they are O(m²) data), so this is plain serial code.

use crate::Scalar;

/// A single Givens rotation (c, s) chosen so that
/// `[c s; -s c]^T [a; b] = [r; 0]`.
#[derive(Clone, Copy, Debug)]
pub struct Givens<S> {
    /// cosine
    pub c: S,
    /// sine
    pub s: S,
}

impl<S: Scalar> Givens<S> {
    /// Construct the rotation annihilating `b` against `a`; returns
    /// (rotation, r).
    pub fn make(a: S, b: S) -> (Self, S) {
        if b == S::zero() {
            (Givens { c: S::one(), s: S::zero() }, a)
        } else {
            // Numerically-stable form (avoids overflow in a*a + b*b).
            let (aa, ab) = (a.abs(), b.abs());
            let scale = aa.max(ab);
            let an = a / scale;
            let bn = b / scale;
            let r = scale * (an * an + bn * bn).sqrt();
            (Givens { c: a / r, s: b / r }, r)
        }
    }

    /// Apply to a pair: returns (c*a + s*b, -s*a + c*b).
    pub fn apply(&self, a: S, b: S) -> (S, S) {
        (self.c * a + self.s * b, self.c * b - self.s * a)
    }
}

/// Incremental Hessenberg QR for GMRES: maintains the rotations, the
/// triangularised columns and the rotated RHS `g`; exposes the current
/// residual norm `|g[k]|` for the convergence test.
pub struct HessenbergQr<S: Scalar> {
    m: usize,
    /// Upper-triangular R, column-major by insertion order (r[j] has j+1 entries).
    r: Vec<Vec<S>>,
    rot: Vec<Givens<S>>,
    g: Vec<S>,
}

impl<S: Scalar> HessenbergQr<S> {
    /// Start a new least-squares problem of max size `m` with initial
    /// residual norm `beta` (g = beta * e1).
    pub fn new(m: usize, beta: S) -> Self {
        let mut g = vec![S::zero(); m + 1];
        g[0] = beta;
        HessenbergQr { m, r: Vec::new(), rot: Vec::new(), g }
    }

    /// Insert Hessenberg column `h` (length k+2 for column k: entries
    /// h[0..=k+1]); returns the updated residual norm.
    pub fn push_column(&mut self, mut h: Vec<S>) -> S {
        let k = self.r.len();
        assert!(k < self.m, "HessenbergQr over capacity");
        assert_eq!(h.len(), k + 2, "column {k} must have {} entries", k + 2);
        // Apply previous rotations.
        for (j, rot) in self.rot.iter().enumerate() {
            let (a, b) = rot.apply(h[j], h[j + 1]);
            h[j] = a;
            h[j + 1] = b;
        }
        // New rotation annihilates h[k+1].
        let (rot, r) = Givens::make(h[k], h[k + 1]);
        h[k] = r;
        h.truncate(k + 1);
        self.r.push(h);
        // Rotate g.
        let (ga, gb) = rot.apply(self.g[k], self.g[k + 1]);
        self.g[k] = ga;
        self.g[k + 1] = gb;
        self.rot.push(rot);
        self.g[k + 1].abs()
    }

    /// Number of columns inserted so far.
    pub fn len(&self) -> usize {
        self.r.len()
    }

    /// True if no columns have been inserted.
    pub fn is_empty(&self) -> bool {
        self.r.is_empty()
    }

    /// Current residual norm |g[k]|.
    pub fn residual(&self) -> S {
        self.g[self.r.len()].abs()
    }

    /// Solve R y = g for the current k columns (back substitution).
    pub fn solve(&self) -> Vec<S> {
        let k = self.r.len();
        let mut y = vec![S::zero(); k];
        for j in (0..k).rev() {
            let mut s = self.g[j];
            for i in j + 1..k {
                s -= self.r[i][j] * y[i];
            }
            y[j] = s / self.r[j][j];
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn givens_annihilates() {
        let (g, r) = Givens::make(3.0f64, 4.0);
        let (x, y) = g.apply(3.0, 4.0);
        assert!((x - 5.0).abs() < 1e-12 && y.abs() < 1e-12);
        assert!((r - 5.0).abs() < 1e-12);
    }

    #[test]
    fn givens_zero_b() {
        let (g, r) = Givens::make(2.0f64, 0.0);
        assert_eq!((g.c, g.s), (1.0, 0.0));
        assert_eq!(r, 2.0);
    }

    #[test]
    fn hessenberg_qr_small_least_squares() {
        // Solve min || H y - beta e1 || for a known 3x2 Hessenberg.
        // H = [[2, 1], [1, 3], [0, 1]], beta = 1.
        let mut qr = HessenbergQr::<f64>::new(2, 1.0);
        qr.push_column(vec![2.0, 1.0]);
        let res = qr.push_column(vec![1.0, 3.0, 1.0]);
        let y = qr.solve();
        // Check against normal equations: H^T H y = H^T (beta e1).
        let h = [[2.0, 1.0], [1.0, 3.0], [0.0, 1.0]];
        let hth = [
            [h[0][0] * h[0][0] + h[1][0] * h[1][0], h[0][0] * h[0][1] + h[1][0] * h[1][1]],
            [
                h[0][0] * h[0][1] + h[1][0] * h[1][1],
                h[0][1] * h[0][1] + h[1][1] * h[1][1] + h[2][1] * h[2][1],
            ],
        ];
        let htb = [h[0][0], h[0][1]];
        // solve 2x2
        let det = hth[0][0] * hth[1][1] - hth[0][1] * hth[1][0];
        let y0 = (htb[0] * hth[1][1] - hth[0][1] * htb[1]) / det;
        let y1 = (hth[0][0] * htb[1] - htb[0] * hth[1][0]) / det;
        assert!((y[0] - y0).abs() < 1e-12, "{y:?} vs ({y0},{y1})");
        assert!((y[1] - y1).abs() < 1e-12);
        // Residual from QR should match direct computation.
        let r0 = 1.0 - (h[0][0] * y[0] + h[0][1] * y[1]);
        let r1 = -(h[1][0] * y[0] + h[1][1] * y[1]);
        let r2 = -(h[2][1] * y[1]);
        let want = (r0 * r0 + r1 * r1 + r2 * r2).sqrt();
        assert!((res - want).abs() < 1e-12, "res {res} want {want}");
    }

    #[test]
    fn residual_decreases_monotonically() {
        let mut qr = HessenbergQr::<f64>::new(3, 2.0);
        let r0 = qr.push_column(vec![1.0, 0.5]);
        let r1 = qr.push_column(vec![0.3, 1.0, 0.4]);
        let r2 = qr.push_column(vec![0.1, 0.2, 1.0, 0.3]);
        assert!(r0 <= 2.0 + 1e-15);
        assert!(r1 <= r0 + 1e-15);
        assert!(r2 <= r1 + 1e-15);
        assert_eq!(qr.len(), 3);
    }
}
