//! Config-file support: a dependency-free `key = value` format with
//! `[section]` headers (a TOML subset — the offline crate set has no serde).
//!
//! ```text
//! # cluster.conf
//! [cluster]
//! ranks = 16
//! tile = 256
//! engine = cuda          # cuda | atlas
//! residency = true       # device tile cache (false = paper copy-per-call)
//! device_mem = 1073741824  # residency budget, bytes (GTX 280 = 1 GiB)
//! prefetch = true        # copy-engine timeline (false = synchronous PCIe)
//! gpudirect = true       # device-to-NIC wire (false = host-staged sends)
//! mixed_precision = true # f32 factor + f64 refine (false = uniform wide)
//! fault_plan = crash:1@0.5; slow:2x0.5   # injected faults (see comm::faults)
//! ckpt_every = 16        # checkpoint period, panels/iterations (absent = off)
//!
//! [network]
//! alpha_us = 50
//! beta_ns_per_byte = 8.5
//!
//! [solver]
//! tol = 1e-8
//! max_iter = 500
//! restart = 30
//! ```

use std::collections::HashMap;

use crate::accel::EngineKind;
use crate::cluster::ClusterConfig;
use crate::comm::{FaultPlan, NetworkModel};
use crate::solvers::IterConfig;
use crate::{Error, Result};

/// Parsed config: `section.key -> value` (top-level keys use section "").
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: HashMap<String, String>,
}

impl Config {
    /// Parse config text.
    pub fn parse(text: &str) -> Result<Config> {
        let mut values = HashMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| Error::config(format!("line {}: unclosed section", lineno + 1)))?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                Error::config(format!("line {}: expected key = value, got {line:?}", lineno + 1))
            })?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            values.insert(key, v.trim().to_string());
        }
        Ok(Config { values })
    }

    /// Load from a file.
    pub fn load(path: &str) -> Result<Config> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// Raw lookup.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// Typed lookup with default.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.values.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::config(format!("bad value for {key}: {v:?}"))),
        }
    }

    /// Number of entries.
    pub fn len(&self) -> usize {
        self.values.len()
    }

    /// Is the config empty?
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Assemble a [`ClusterConfig`] from `[cluster]`, `[network]`, `[solver]`.
    pub fn cluster_config(&self) -> Result<ClusterConfig> {
        let mut net = NetworkModel::gigabit_ethernet();
        net.alpha = self.get_or("network.alpha_us", net.alpha * 1e6)? * 1e-6;
        net.beta = self.get_or("network.beta_ns_per_byte", net.beta * 1e9)? * 1e-9;
        let engine = match self.get("cluster.engine") {
            Some(s) => EngineKind::parse(s)?,
            None => EngineKind::CpuSerial,
        };
        Ok(ClusterConfig {
            ranks: self.get_or("cluster.ranks", 4)?,
            tile: self.get_or("cluster.tile", crate::DEFAULT_TILE)?,
            engine,
            net,
            artifact_dir: self
                .get("cluster.artifacts")
                .unwrap_or(crate::runtime::DEFAULT_ARTIFACT_DIR)
                .to_string(),
            residency: self.get_or("cluster.residency", true)?,
            device_mem: self.get_or("cluster.device_mem", crate::accel::DEFAULT_DEVICE_MEM)?,
            prefetch: self.get_or("cluster.prefetch", true)?,
            gpudirect: self.get_or("cluster.gpudirect", true)?,
            mixed_precision: self.get_or("cluster.mixed_precision", true)?,
            fault_plan: match self.get("cluster.fault_plan") {
                Some(spec) => FaultPlan::parse(spec)?,
                None => FaultPlan::default(),
            },
            ckpt_every: match self.get("cluster.ckpt_every") {
                Some(_) => Some(self.get_or("cluster.ckpt_every", 0usize)?),
                None => None,
            },
            iter: IterConfig {
                tol: self.get_or("solver.tol", 1e-8)?,
                max_iter: self.get_or("solver.max_iter", 500)?,
                restart: self.get_or("solver.restart", 30)?,
            },
        })
    }
}

fn strip_comment(line: &str) -> &str {
    match line.find('#') {
        Some(pos) => &line[..pos],
        None => line,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "
# comment
top = 1
[cluster]
ranks = 16
tile = 128
engine = cuda
[solver]
tol = 1e-6
";

    #[test]
    fn parses_sections_and_comments() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get("top"), Some("1"));
        assert_eq!(c.get("cluster.ranks"), Some("16"));
        assert_eq!(c.get_or("cluster.tile", 0usize).unwrap(), 128);
        assert_eq!(c.get_or("missing", 7usize).unwrap(), 7);
    }

    #[test]
    fn builds_cluster_config() {
        let c = Config::parse(SAMPLE).unwrap();
        let cc = c.cluster_config().unwrap();
        assert_eq!(cc.ranks, 16);
        assert_eq!(cc.tile, 128);
        assert_eq!(cc.engine, crate::accel::EngineKind::Accelerated);
        assert!((cc.iter.tol - 1e-6).abs() < 1e-18);
        // defaults preserved
        assert_eq!(cc.iter.max_iter, 500);
        assert!(cc.residency);
        assert_eq!(cc.device_mem, crate::accel::DEFAULT_DEVICE_MEM);
        assert!(cc.prefetch, "the copy-engine timeline defaults on");
        assert!(cc.gpudirect, "the GPUDirect wire defaults on");
        assert!(cc.mixed_precision, "mixed precision defaults on");
    }

    #[test]
    fn mixed_precision_override() {
        let c = Config::parse("[cluster]\nmixed_precision = false\n").unwrap();
        assert!(!c.cluster_config().unwrap().mixed_precision);
        assert!(Config::parse("[cluster]\nmixed_precision = sometimes\n")
            .unwrap()
            .cluster_config()
            .is_err());
    }

    #[test]
    fn residency_overrides() {
        let c = Config::parse(
            "[cluster]\nresidency = false\ndevice_mem = 4096\nprefetch = false\ngpudirect = false\n",
        )
        .unwrap();
        let cc = c.cluster_config().unwrap();
        assert!(!cc.residency);
        assert_eq!(cc.device_mem, 4096);
        assert!(!cc.prefetch);
        assert!(!cc.gpudirect);
        assert!(Config::parse("[cluster]\nresidency = maybe\n")
            .unwrap()
            .cluster_config()
            .is_err());
    }

    #[test]
    fn network_overrides() {
        let c = Config::parse("[network]\nalpha_us = 2\nbeta_ns_per_byte = 0.5\n").unwrap();
        let cc = c.cluster_config().unwrap();
        assert!((cc.net.alpha - 2e-6).abs() < 1e-12);
        assert!((cc.net.beta - 0.5e-9).abs() < 1e-15);
    }

    #[test]
    fn fault_plan_and_checkpoint_overrides() {
        let c = Config::parse("[cluster]\nfault_plan = crash:1@0.5\nckpt_every = 16\n").unwrap();
        let cc = c.cluster_config().unwrap();
        assert!(cc.fault_plan.has_crashes());
        assert_eq!(cc.ckpt_every, Some(16));
        // Defaults: no faults, no checkpoints.
        let cc = Config::parse("").unwrap().cluster_config().unwrap();
        assert!(cc.fault_plan.is_empty());
        assert_eq!(cc.ckpt_every, None);
        assert!(Config::parse("[cluster]\nfault_plan = crash:oops\n")
            .unwrap()
            .cluster_config()
            .is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Config::parse("[open\n").is_err());
        assert!(Config::parse("novalue\n").is_err());
        let c = Config::parse("x = notanumber").unwrap();
        assert!(c.get_or("x", 1usize).is_err());
    }

    #[test]
    fn empty_is_default() {
        let c = Config::parse("").unwrap();
        assert!(c.is_empty());
        let cc = c.cluster_config().unwrap();
        assert_eq!(cc.ranks, 4);
    }
}
