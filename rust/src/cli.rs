//! Minimal CLI argument parser (no clap in the offline crate set):
//! `--key value`, `--key=value`, `--flag`, and positionals.

use std::collections::HashMap;

use crate::{Error, Result};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    opts: HashMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

/// Option names that take a value (anything else after `--` is a flag).
const VALUE_OPTS: &[&str] = &[
    "ranks", "tile", "engine", "method", "workload", "n", "dtype", "tol", "max-iter",
    "restart", "config", "net", "iters", "out", "device-mem", "rhs-batch", "requests",
    "fault-plan", "ckpt-every", "factor-cache-cap", "deadline", "retry-budget",
];

impl Args {
    /// Parse an argv-style iterator (without the program name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if VALUE_OPTS.contains(&body) {
                    let v = it.next().ok_or_else(|| {
                        Error::config(format!("--{body} expects a value"))
                    })?;
                    args.opts.insert(body.to_string(), v);
                } else {
                    args.flags.push(body.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    /// Parse the process's own command line.
    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    /// The subcommand (first positional), if any.
    pub fn command(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    /// Positional arguments after the subcommand.
    pub fn rest(&self) -> &[String] {
        if self.positional.is_empty() { &[] } else { &self.positional[1..] }
    }

    /// Is a bare flag present?
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Raw option value.
    pub fn opt(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// Typed option with default.
    pub fn opt_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.opts.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::config(format!("bad value for --{name}: {v:?}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn parses_mixed_forms() {
        let a = parse("solve --ranks 16 --tile=128 --verbose --method lu extra");
        assert_eq!(a.command(), Some("solve"));
        assert_eq!(a.opt_or("ranks", 0usize).unwrap(), 16);
        assert_eq!(a.opt_or("tile", 0usize).unwrap(), 128);
        assert_eq!(a.opt("method"), Some("lu"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.rest(), &["extra".to_string()]);
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse("bench");
        assert_eq!(a.opt_or("ranks", 9usize).unwrap(), 9);
        assert!(parse("x --n abc").opt_or("n", 0usize).is_err());
        assert!(Args::parse(["--ranks".to_string()]).is_err());
    }
}
