//! The cluster runtime: CUPLSS's user-facing entry point ("the parallelism
//! is hidden from the user", paper §3).
//!
//! [`Cluster::solve`] spins up the simulated MPI world, distributes the
//! workload, runs the requested solver with the requested local-compute
//! engine, verifies the solution against the workload's known answer, and
//! returns a [`SolveReport`] with the virtual-time breakdown per rank —
//! everything the bench harness needs to plot the paper's figures.

pub mod metrics;

pub use metrics::{RankMetrics, SolveReport};

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::accel::{make_engine, ComputeProfile, Engine, EngineKind};
use crate::comm::{CheckpointPolicy, FaultPlan, NetworkModel, World};
use crate::dist::{
    gather_vector, ptranspose, Descriptor, DistMatrix, DistMultiVector, DistVector,
};
use crate::mesh::{Mesh, MeshShape};
use crate::pblas::Ctx;
use crate::runtime::Runtime;
use crate::solvers::{
    apply_pivots, bicg, bicgstab_ft, bicgstab_mixed, block_bicgstab, block_cg, cg_ft,
    cg_mixed, gmres_ft, pchol_factor_ckpt, pchol_solve_panel_ckpt, pchol_solve_refined,
    pipecg, plu_factor_ckpt, plu_solve_panel_ckpt, plu_solve_refined, ptrsm, IterConfig,
    IterMethod, IterStats, PivotMap, TriKind,
};
use crate::workloads::Workload;
use crate::{mixed_capable, Error, Result, Scalar};

/// The wide accumulation dtype of a narrow world: `f64` for every supported
/// scalar (`f32::Hi = f64`).  Spelled as an alias because the mixed solve
/// runs the *world* at `S::Lo` and carries its high-precision shadows at
/// this type.
type LoHi<S> = <<S as Scalar>::Lo as Scalar>::Hi;

/// Which solver to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Blocked LU with partial pivoting + triangular solves.
    Lu,
    /// Blocked Cholesky + triangular solves (SPD only).
    Cholesky,
    /// A non-stationary iterative method.
    Iterative(IterMethod),
}

impl Method {
    /// Parse a CLI name.
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "lu" => Ok(Method::Lu),
            "chol" | "cholesky" => Ok(Method::Cholesky),
            other => Ok(Method::Iterative(IterMethod::parse(other)?)),
        }
    }

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            Method::Lu => "LU",
            Method::Cholesky => "Cholesky",
            Method::Iterative(m) => m.name(),
        }
    }
}

/// Everything needed to run one distributed solve.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Number of ranks (the paper sweeps 1, 2, 4, 8, 16).
    pub ranks: usize,
    /// Tile size (must have matching artifacts for the accelerated engine).
    pub tile: usize,
    /// Local-compute arm (the paper's CUDA-vs-ATLAS axis).
    pub engine: EngineKind,
    /// Network profile for the virtual clock.
    pub net: NetworkModel,
    /// Artifact directory (PJRT runtime), used by the accelerated arm.
    pub artifact_dir: String,
    /// Device residency: keep tiles/vectors device-side across calls
    /// (`DESIGN.md` §12).  `false` reproduces the paper's §3
    /// copy-per-call flow.  Never changes results, only PCIe charges.
    pub residency: bool,
    /// Device-memory budget for the residency cache, bytes.
    pub device_mem: usize,
    /// Copy-engine timeline: route surviving transfers through async H2D
    /// prefetch / D2H write-back overlapped with compute (`DESIGN.md`
    /// §13).  `false` keeps residency's synchronous accounting — the
    /// `--no-prefetch` A/B arm.  Never changes results, only *when* PCIe
    /// time is charged.  Inert without residency.
    pub prefetch: bool,
    /// GPUDirect wire: device-dirty send payloads go straight to the NIC,
    /// occupying NIC + copy engine jointly with no host staging barrier
    /// (`DESIGN.md` §16).  `false` keeps the blocking host_read-then-send
    /// flow — the `--no-gpudirect` A/B arm.  Never changes results.  Inert
    /// without residency + prefetch.
    pub gpudirect: bool,
    /// Mixed precision: factor/iterate at `S::Lo` (f32) with f64 correction
    /// — iterative refinement around the direct solvers, f64-accumulate
    /// Krylov, and narrow wire payloads (`DESIGN.md` §17).  Engages only
    /// when the engine profile actually rewards it
    /// ([`ComputeProfile::mixed_advantage`]) and the requested dtype has a
    /// narrower storage type; `false` is the `--no-mixed` A/B arm and is
    /// bit-identical to a pure-wide run.  Falls back to uniform precision
    /// (both runs billed) when refinement misses its backward-error bound.
    pub mixed_precision: bool,
    /// Iterative controls.
    pub iter: IterConfig,
    /// Scripted fault schedule (`DESIGN.md` §18): rank crashes, link
    /// degradation windows, message drops, ECC retirements, stragglers —
    /// all priced on the virtual clock.  The empty plan (the default) is
    /// bit-identical to a run with no fault layer at all.
    pub fault_plan: FaultPlan,
    /// Checkpoint period for fault-tolerant solving: every `k` panels
    /// (direct) or iterations (Krylov) the solver snapshots its state so a
    /// scripted crash rolls back at most `k` steps instead of restarting.
    /// `None` disables checkpointing — a crash then fails the solve with a
    /// diagnostic instead of silently recomputing.
    pub ckpt_every: Option<usize>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            ranks: 4,
            tile: crate::DEFAULT_TILE,
            engine: EngineKind::CpuSerial,
            net: NetworkModel::gigabit_ethernet(),
            artifact_dir: crate::runtime::DEFAULT_ARTIFACT_DIR.to_string(),
            residency: true,
            device_mem: crate::accel::DEFAULT_DEVICE_MEM,
            prefetch: true,
            gpudirect: true,
            mixed_precision: true,
            iter: IterConfig::default(),
            fault_plan: FaultPlan::default(),
            ckpt_every: None,
        }
    }
}

impl ClusterConfig {
    /// Small-instance config for tests and demos: `ranks` ranks on
    /// `tile`-sized tiles, everything else default.  Prefer this (or at
    /// least `..Default::default()`) over spelling out full literals in
    /// tests — a new config field then inherits its default instead of
    /// breaking every literal in the tree (`DESIGN.md` §14).
    pub fn small(ranks: usize, tile: usize) -> Self {
        ClusterConfig { ranks, tile, ..Default::default() }
    }
}

/// Cache key: a factorization is reusable exactly when a later request
/// names the same operator — same workload generator, size, method and
/// dtype.  Mesh shape and tile are fixed per [`Cluster`], so they are not
/// part of the key.
type FactorKey = (Workload, usize, &'static str, &'static str);

/// One cached factorization: every rank's factored tiles (promoted to f64,
/// which is exact for all supported dtypes), plus whatever the
/// substitutions need that the factorization produced — LU's pivot swaps,
/// Cholesky's transposed factor.
struct CachedFactor {
    /// `tiles[rank]` = that rank's factored tiles in [`DistMatrix::owned_tiles`]
    /// order.
    tiles: Vec<Vec<Vec<f64>>>,
    /// Cholesky only: the transposed factor `L^T`, same layout — caching it
    /// skips the transpose-redistribution as well as the factorization.
    lt_tiles: Option<Vec<Vec<Vec<f64>>>>,
    /// LU only: the pivot swap list (identical on every rank).
    swaps: Vec<(usize, usize)>,
}

/// Cross-request factorization cache (`DESIGN.md` §17): the serve layer
/// keeps one per cluster so a repeat request for an already-factored
/// operator pays only the triangular substitutions.  **Bounded**: holds at
/// most `capacity` factorizations and evicts in LRU order (a hit or a
/// re-insert refreshes recency) — the default capacity is unbounded, so
/// existing callers see the old seen-forever behaviour unchanged.
pub struct FactorCache {
    inner: Mutex<FactorCacheInner>,
}

struct FactorCacheInner {
    map: HashMap<FactorKey, Arc<CachedFactor>>,
    /// Recency order: front = least recently used, back = most recent.
    order: Vec<FactorKey>,
    capacity: usize,
    evictions: u64,
}

impl FactorCacheInner {
    /// Move `key` to the most-recent slot (appending if absent).
    fn touch(&mut self, key: &FactorKey) {
        if let Some(pos) = self.order.iter().position(|k| k == key) {
            self.order.remove(pos);
        }
        self.order.push(*key);
    }

    /// Evict least-recently-used entries until within capacity.
    fn shrink(&mut self) {
        while self.map.len() > self.capacity {
            let lru = self.order.remove(0);
            self.map.remove(&lru);
            self.evictions += 1;
        }
    }
}

impl FactorCache {
    /// Empty, unbounded cache.
    pub fn new() -> Self {
        Self::with_capacity(usize::MAX)
    }

    /// Empty cache holding at most `capacity` factorizations (0 caches
    /// nothing: every insert is immediately evicted).
    pub fn with_capacity(capacity: usize) -> Self {
        FactorCache {
            inner: Mutex::new(FactorCacheInner {
                map: HashMap::new(),
                order: Vec::new(),
                capacity,
                evictions: 0,
            }),
        }
    }

    /// Number of cached factorizations.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    /// No factorizations cached yet?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Factorizations evicted to stay within capacity so far.
    pub fn evictions(&self) -> u64 {
        self.inner.lock().unwrap().evictions
    }

    /// Change the capacity, evicting LRU entries if the cache is over it.
    pub fn set_capacity(&self, capacity: usize) {
        let mut inner = self.inner.lock().unwrap();
        inner.capacity = capacity;
        inner.shrink();
    }

    fn get(&self, key: &FactorKey) -> Option<Arc<CachedFactor>> {
        let mut inner = self.inner.lock().unwrap();
        let hit = inner.map.get(key).cloned();
        if hit.is_some() {
            inner.touch(key);
        }
        hit
    }

    fn put(&self, key: FactorKey, factor: CachedFactor) {
        let mut inner = self.inner.lock().unwrap();
        inner.map.insert(key, Arc::new(factor));
        inner.touch(&key);
        inner.shrink();
    }
}

impl Default for FactorCache {
    fn default() -> Self {
        Self::new()
    }
}

/// Should this (config, dtype, method) combination run the mixed-precision
/// path?  Requires all three legs: the knob is on, the dtype has a narrower
/// storage type to drop to, and the engine's cost profile actually rewards
/// narrow arithmetic ([`ComputeProfile::mixed_advantage`] — true for the
/// CUDA arm, false for the host arm, where SSE2 double throughput equals
/// single and there is nothing to win).  Only methods with a wide-recovery
/// story are admitted: refined LU/Cholesky and f64-accumulate CG/BiCGSTAB.
fn mixed_engaged<S: Scalar>(cfg: &ClusterConfig, method: Method) -> bool {
    let profile = match cfg.engine {
        EngineKind::Accelerated => ComputeProfile::gtx280_cublas(),
        EngineKind::CpuSerial => ComputeProfile::q6600_atlas(),
    };
    cfg.mixed_precision
        && mixed_capable::<S>()
        && profile.mixed_advantage()
        && matches!(
            method,
            Method::Lu
                | Method::Cholesky
                | Method::Iterative(IterMethod::Cg | IterMethod::Bicgstab)
        )
}

/// The cluster facade.
pub struct Cluster {
    cfg: ClusterConfig,
    runtime: Option<Arc<Runtime>>,
    factor_cache: FactorCache,
}

impl Cluster {
    /// Construct; loads the PJRT runtime when the accelerated engine is
    /// requested.
    pub fn new(cfg: ClusterConfig) -> Result<Self> {
        let runtime = match cfg.engine {
            EngineKind::Accelerated => Some(Runtime::new(&cfg.artifact_dir)?),
            EngineKind::CpuSerial => None,
        };
        Ok(Cluster { cfg, runtime, factor_cache: FactorCache::new() })
    }

    /// The cross-request factorization cache (populated by
    /// [`Cluster::solve_batch_cached`] when caching is requested).
    pub fn factor_cache(&self) -> &FactorCache {
        &self.factor_cache
    }

    /// The active config.
    pub fn config(&self) -> &ClusterConfig {
        &self.cfg
    }

    /// Solve an `n x n` instance of `workload` with `method`; returns the
    /// report (makespan, per-rank breakdown, solution error vs the known
    /// answer).  Routes through the mixed-precision path (narrow storage,
    /// wide recovery, `DESIGN.md` §17) when [`ClusterConfig::mixed_precision`]
    /// is on and the engine/dtype/method combination qualifies; otherwise —
    /// including under `--no-mixed` — runs bit-identically to the uniform
    /// wide solve.
    pub fn solve<S: Scalar>(&self, workload: Workload, n: usize, method: Method) -> Result<SolveReport> {
        validate_method(workload, method)?;
        // Crash recovery (checkpoint/rollback) lives in the uniform-precision
        // solvers; with crashes scheduled the mixed gamble stands down so the
        // fault story stays single-path.  Stragglers/degradation/drops/ECC
        // ride along on either path.
        if mixed_engaged::<S>(&self.cfg, method) && !self.cfg.fault_plan.has_crashes() {
            self.solve_mixed::<S>(workload, n, method)
        } else {
            self.solve_uniform::<S>(workload, n, method)
        }
    }

    /// The uniform-precision solve: everything — storage, arithmetic, wire
    /// — at `S`.
    fn solve_uniform<S: Scalar>(
        &self,
        workload: Workload,
        n: usize,
        method: Method,
    ) -> Result<SolveReport> {
        let cfg = &self.cfg;
        let shape = MeshShape::near_square(cfg.ranks);
        // Shared engine: constructed once, used by all rank threads (each
        // node in the paper has its own GPU; the cost model is per-op, so
        // sharing the compiled executables is timing-neutral).
        let engine: Arc<dyn Engine<S>> =
            make_engine(cfg.engine, cfg.tile, self.runtime.as_ref())?;
        let iter_cfg = cfg.iter;
        let tile = cfg.tile;
        let (residency, device_mem, prefetch, gpudirect) =
            (cfg.residency, cfg.device_mem, cfg.prefetch, cfg.gpudirect);
        let ckpt = cfg.ckpt_every.map(CheckpointPolicy::every);
        let plan = cfg.fault_plan.clone();

        let results = World::run_with_faults::<S, Result<(RankMetrics, Option<Vec<S>>, Option<(usize, f64, bool)>)>, _>(
            cfg.ranks,
            cfg.net,
            plan,
            move |comm| {
                let mesh = Mesh::new(&comm, shape);
                // An ECC retirement shrinks this rank's residency budget
                // (min with usize::MAX — the no-event value — is exact).
                let device_mem = device_mem.min(comm.fault_plan().keep_bytes(comm.rank()));
                let ctx = if residency {
                    Ctx::with_device_mem(&mesh, engine.clone(), device_mem)
                        .with_prefetch(prefetch)
                        .with_gpudirect(gpudirect)
                } else {
                    Ctx::streaming(&mesh, engine.clone())
                };
                let desc = Descriptor::new(n, n, tile, shape);
                let elem = workload.elem::<S>(n);
                let rhs = workload.rhs::<S>(n);
                let a0 = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), elem);
                let b = DistVector::from_fn(desc, mesh.row(), mesh.col(), rhs);
                // Synchronise before timing (all ranks at t=0 after setup).
                comm.clock().reset();
                let wall = crate::util::Stopwatch::start();

                let (x, iter_stats) = match method {
                    Method::Lu => {
                        let mut a = a0;
                        let x = plu_solve_panel_ckpt(
                            &ctx,
                            &mut a,
                            &DistMultiVector::from_cols(vec![b.clone_vec()]),
                            ckpt,
                        )?;
                        (x.into_cols().remove(0), None)
                    }
                    Method::Cholesky => {
                        let mut a = a0;
                        let x = pchol_solve_panel_ckpt(
                            &ctx,
                            &mut a,
                            &DistMultiVector::from_cols(vec![b.clone_vec()]),
                            ckpt,
                        )?;
                        (x.into_cols().remove(0), None)
                    }
                    Method::Iterative(m) => {
                        let (x, st) = match m {
                            IterMethod::Cg => cg_ft(&ctx, &a0, &b, &iter_cfg, ckpt)?,
                            IterMethod::PipeCg => pipecg(&ctx, &a0, &b, &iter_cfg)?,
                            IterMethod::Bicg => bicg(&ctx, &a0, &b, &iter_cfg)?,
                            IterMethod::Bicgstab => bicgstab_ft(&ctx, &a0, &b, &iter_cfg, ckpt)?,
                            IterMethod::Gmres => gmres_ft(&ctx, &a0, &b, &iter_cfg, ckpt)?,
                        };
                        (
                            x,
                            Some((
                                st.iterations,
                                st.rel_residual.to_f64().unwrap_or(f64::NAN),
                                st.converged,
                            )),
                        )
                    }
                };
                let metrics = RankMetrics::capture(&comm, wall.secs());
                let gathered = gather_vector(&mesh, &x);
                Ok((metrics, gathered, iter_stats))
            },
        );

        let mut per_rank = Vec::with_capacity(cfg.ranks);
        let mut solution: Option<Vec<S>> = None;
        let mut iter_stats = None;
        for r in results {
            let (m, sol, st) = r?;
            per_rank.push(m);
            if sol.is_some() {
                solution = sol;
            }
            if st.is_some() {
                iter_stats = st;
            }
        }
        let solution = solution.expect("rank 0 gathers the solution");
        let xt = workload.x_true::<S>(n);
        let mut max_err = 0.0f64;
        for (i, &xi) in solution.iter().enumerate() {
            let want = xt(i).to_f64().unwrap();
            let err = (xi.to_f64().unwrap() - want).abs();
            max_err = max_err.max(err);
        }
        Ok(SolveReport::new(
            method.name(),
            workload,
            n,
            cfg.ranks,
            cfg.engine,
            per_rank,
            max_err,
            iter_stats,
        ))
    }

    /// The mixed-precision solve: ONE narrow world (`World::run::<S::Lo>`)
    /// whose storage, kernels and wire traffic run at `S::Lo`, with the
    /// wide recovery carried by locally-constructed f64 shadows — built
    /// from the *same* f64 workload generators the narrow operands were
    /// demoted from, so no wide redistribution is ever needed.  Direct
    /// methods run factored-narrow + refined-wide
    /// ([`plu_solve_refined`]/[`pchol_solve_refined`]); CG/BiCGSTAB run
    /// the f64-accumulate variants.  A refinement that misses its
    /// backward-error bound (or a narrow breakdown / non-convergence —
    /// both SPMD-deterministic, so every rank takes the exit together)
    /// falls back to the uniform wide solve, and the report then carries
    /// **both** runs' per-rank bills summed ([`RankMetrics::absorb`]).
    fn solve_mixed<S: Scalar>(
        &self,
        workload: Workload,
        n: usize,
        method: Method,
    ) -> Result<SolveReport> {
        let cfg = &self.cfg;
        let shape = MeshShape::near_square(cfg.ranks);
        let engine: Arc<dyn Engine<S::Lo>> =
            make_engine(cfg.engine, cfg.tile, self.runtime.as_ref())?;
        let iter_cfg = cfg.iter;
        let tile = cfg.tile;
        let (residency, device_mem, prefetch, gpudirect) =
            (cfg.residency, cfg.device_mem, cfg.prefetch, cfg.gpudirect);

        let plan = cfg.fault_plan.clone();

        // (metrics, local worst error, iter stats, refine sweeps, converged)
        type MixedOut = (RankMetrics, f64, Option<(usize, f64, bool)>, usize, bool);
        let results =
            World::run_with_faults::<S::Lo, Result<MixedOut>, _>(cfg.ranks, cfg.net, plan, move |comm| {
                let mesh = Mesh::new(&comm, shape);
                let device_mem = device_mem.min(comm.fault_plan().keep_bytes(comm.rank()));
                let ctx = if residency {
                    Ctx::with_device_mem(&mesh, engine.clone(), device_mem)
                        .with_prefetch(prefetch)
                        .with_gpudirect(gpudirect)
                } else {
                    Ctx::streaming(&mesh, engine.clone())
                };
                let desc = Descriptor::new(n, n, tile, shape);
                let a_lo = DistMatrix::from_fn(
                    desc,
                    mesh.row(),
                    mesh.col(),
                    workload.elem::<S::Lo>(n),
                );
                comm.clock().reset();
                let wall = crate::util::Stopwatch::start();

                let (err, iter_stats, sweeps, ok) = match method {
                    Method::Lu | Method::Cholesky => {
                        let a_hi = DistMatrix::from_fn(
                            desc,
                            mesh.row(),
                            mesh.col(),
                            workload.elem::<LoHi<S>>(n),
                        );
                        let b_hi = DistVector::from_fn(
                            desc,
                            mesh.row(),
                            mesh.col(),
                            workload.rhs::<LoHi<S>>(n),
                        );
                        let mut a = a_lo;
                        let solved = if method == Method::Lu {
                            plu_solve_refined(&ctx, &mut a, &a_hi, &b_hi)
                        } else {
                            pchol_solve_refined(&ctx, &mut a, &a_hi, &b_hi)
                        };
                        match solved {
                            Ok((x_hi, st)) => {
                                let err = local_worst_err(&x_hi, workload, n);
                                (err, None, st.sweeps, st.converged)
                            }
                            // A narrow zero pivot / lost definiteness: the
                            // wide fallback will handle it.
                            Err(Error::Breakdown { .. }) => (f64::INFINITY, None, 0, false),
                            Err(e) => return Err(e),
                        }
                    }
                    Method::Iterative(m) => {
                        let b_lo = DistVector::from_fn(
                            desc,
                            mesh.row(),
                            mesh.col(),
                            workload.rhs::<S::Lo>(n),
                        );
                        let solved = match m {
                            IterMethod::Cg => cg_mixed(&ctx, &a_lo, &b_lo, &iter_cfg),
                            IterMethod::Bicgstab => {
                                bicgstab_mixed(&ctx, &a_lo, &b_lo, &iter_cfg)
                            }
                            _ => unreachable!("gate admits CG/BiCGSTAB only"),
                        };
                        match solved {
                            Ok((x, st)) => {
                                let err = local_worst_err(&x, workload, n);
                                let stats = Some((
                                    st.iterations,
                                    st.rel_residual.to_f64().unwrap_or(f64::NAN),
                                    st.converged,
                                ));
                                (err, stats, 0, st.converged)
                            }
                            // Narrow storage can cap the attainable
                            // residual short of a tight tolerance.
                            Err(
                                Error::Breakdown { .. } | Error::NoConvergence { .. },
                            ) => (f64::INFINITY, None, 0, false),
                            Err(e) => return Err(e),
                        }
                    }
                };
                let metrics = RankMetrics::capture(&comm, wall.secs());
                Ok((metrics, err, iter_stats, sweeps, ok))
            });

        let mut per_rank = Vec::with_capacity(cfg.ranks);
        let mut max_err = 0.0f64;
        let mut iter_stats = None;
        let mut sweeps = 0usize;
        let mut ok = true;
        for r in results {
            let (m, e, st, s, o) = r?;
            per_rank.push(m);
            max_err = max_err.max(e);
            if st.is_some() {
                iter_stats = st;
            }
            sweeps = sweeps.max(s);
            ok &= o;
        }

        if !ok {
            // The narrow gamble lost: re-run wide and bill both attempts.
            let mut report = self.solve_uniform::<S>(workload, n, method)?;
            for (wide, narrow) in report.per_rank.iter_mut().zip(&per_rank) {
                wide.absorb(narrow);
            }
            return Ok(report.with_mixed(sweeps, 0, true));
        }

        // Every payload of the narrow world would have shipped at S::BYTES
        // under the uniform solve; what it saved is the width ratio minus
        // the bytes actually sent.  (Slight overcount: the refinement's few
        // Payload::Hi legs are already wide.)
        let ratio = (S::BYTES / <S::Lo as Scalar>::BYTES) as u64;
        let bytes_saved: u64 = per_rank.iter().map(|m| m.bytes * (ratio - 1)).sum();
        Ok(SolveReport::new(
            method.name(),
            workload,
            n,
            cfg.ranks,
            cfg.engine,
            per_rank,
            max_err,
            iter_stats,
        )
        .with_mixed(sweeps, bytes_saved, false))
    }

    /// Solve `A X = B` for a whole batch of `k = coeffs.len()` right-hand
    /// sides sharing one operator: `b_j = coeffs[j] · b` (so the known
    /// answer is `x_j = coeffs[j] · x_true`) with per-request tolerance
    /// `tols[j]`.  Direct methods factor **once** and run the RHS-panel
    /// substitutions ([`plu_solve_panel`]/[`pchol_solve_panel`]); CG and
    /// BiCGSTAB run blocked (shared matvec sweeps, k-lane reductions);
    /// the remaining iterative methods loop single-RHS solves under the
    /// same attribution accounting.  The report carries per-request
    /// attribution buckets ([`SolveReport::attribution`]) and worst-column
    /// `iter_stats`.
    pub fn solve_batch<S: Scalar>(
        &self,
        workload: Workload,
        n: usize,
        method: Method,
        coeffs: &[f64],
        tols: &[f64],
    ) -> Result<SolveReport> {
        self.solve_batch_cached::<S>(workload, n, method, coeffs, tols, false)
    }

    /// [`Cluster::solve_batch`] with the cross-request factor cache: when
    /// `use_cache` is on and a prior cached batch on this cluster factored
    /// the same `(workload, n, method, dtype)` operator, the factor tiles
    /// (and pivots / transposed factor) are restored for free — the factors
    /// are already resident from the earlier request — and only the
    /// substitutions are charged.  The substitution sequence is identical
    /// either way, so a hit returns bit-identical solutions.  A miss runs
    /// the full solve and populates the cache for the next request.
    pub fn solve_batch_cached<S: Scalar>(
        &self,
        workload: Workload,
        n: usize,
        method: Method,
        coeffs: &[f64],
        tols: &[f64],
        use_cache: bool,
    ) -> Result<SolveReport> {
        let k = coeffs.len();
        if k == 0 || tols.len() != k {
            return Err(Error::config(format!(
                "solve_batch needs matching non-empty coeffs/tols, got {}/{}",
                k,
                tols.len()
            )));
        }
        validate_method(workload, method)?;
        let cacheable = matches!(method, Method::Lu | Method::Cholesky);
        let key: FactorKey = (workload, n, method.name(), S::DTYPE);
        let cached: Option<Arc<CachedFactor>> =
            if use_cache && cacheable { self.factor_cache.get(&key) } else { None };
        let hit = cached.is_some();
        let exporting = use_cache && cacheable && !hit;
        let cfg = &self.cfg;
        let shape = MeshShape::near_square(cfg.ranks);
        let engine: Arc<dyn Engine<S>> =
            make_engine(cfg.engine, cfg.tile, self.runtime.as_ref())?;
        let iter_cfg = cfg.iter;
        let tile = cfg.tile;
        let (residency, device_mem, prefetch, gpudirect) =
            (cfg.residency, cfg.device_mem, cfg.prefetch, cfg.gpudirect);
        let coeffs_owned: Vec<f64> = coeffs.to_vec();
        let tols_owned: Vec<f64> = tols.to_vec();
        let ckpt = cfg.ckpt_every.map(CheckpointPolicy::every);
        let plan = cfg.fault_plan.clone();

        type Exported = (Vec<Vec<f64>>, Option<Vec<Vec<f64>>>, Vec<(usize, usize)>);
        type BatchOut<S> = (
            RankMetrics,
            Option<Vec<Vec<S>>>,
            Option<Vec<(usize, f64, bool)>>,
            Vec<f64>,
            Option<Exported>,
        );
        let results = World::run_with_faults::<S, Result<BatchOut<S>>, _>(cfg.ranks, cfg.net, plan, move |comm| {
            let mesh = Mesh::new(&comm, shape);
            let device_mem = device_mem.min(comm.fault_plan().keep_bytes(comm.rank()));
            let ctx = if residency {
                Ctx::with_device_mem(&mesh, engine.clone(), device_mem)
                    .with_prefetch(prefetch)
                    .with_gpudirect(gpudirect)
            } else {
                Ctx::streaming(&mesh, engine.clone())
            };
            let desc = Descriptor::new(n, n, tile, shape);
            let elem = workload.elem::<S>(n);
            let rhs = workload.rhs::<S>(n);
            let a0 = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), elem);
            let scales: Vec<S> =
                coeffs_owned.iter().map(|&c| S::from_f64(c).unwrap()).collect();
            let b = DistMultiVector::from_fn(desc, mesh.row(), mesh.col(), k, |i, j| {
                scales[j] * rhs(i)
            });
            ctx.enable_attribution(k);
            comm.clock().reset();
            let wall = crate::util::Stopwatch::start();

            type Solved<S> =
                (DistMultiVector<S>, Option<Vec<IterStats<S>>>, Option<Exported>);
            let (x, col_stats, export): Solved<S> = match method {
                Method::Lu => {
                    let mut a = a0;
                    let (x, swaps) = match cached.as_deref() {
                        Some(cf) => {
                            // Restore is free: the factors are resident
                            // from the request that populated the cache.
                            restore_tiles(&mut a, &cf.tiles[comm.rank()]);
                            let piv = PivotMap::from_swaps(cf.swaps.clone());
                            let mut x = b.clone_panel();
                            for j in 0..x.ncols() {
                                ctx.set_tenant(Some(j));
                                apply_pivots(&ctx, &piv, x.col_mut(j));
                                ctx.set_tenant(None);
                            }
                            ptrsm(&ctx, &a, &mut x, TriKind::LowerUnit)?;
                            ptrsm(&ctx, &a, &mut x, TriKind::Upper)?;
                            (x, Vec::new())
                        }
                        // [`plu_solve_panel`] inlined so the pivot map and
                        // factored tiles survive for export.
                        None => {
                            let piv = plu_factor_ckpt(&ctx, &mut a, ckpt)?;
                            let mut x = b.clone_panel();
                            for j in 0..x.ncols() {
                                ctx.set_tenant(Some(j));
                                apply_pivots(&ctx, &piv, x.col_mut(j));
                                ctx.set_tenant(None);
                            }
                            ptrsm(&ctx, &a, &mut x, TriKind::LowerUnit)?;
                            ptrsm(&ctx, &a, &mut x, TriKind::Upper)?;
                            (x, piv.swaps().to_vec())
                        }
                    };
                    let export = exporting.then(|| (export_tiles(&a), None, swaps));
                    (x, None, export)
                }
                Method::Cholesky => {
                    let mut a = a0;
                    let (x, lt) = match cached.as_deref() {
                        Some(cf) => {
                            restore_tiles(&mut a, &cf.tiles[comm.rank()]);
                            let mut lt = DistMatrix::zeros(desc, mesh.row(), mesh.col());
                            let saved_lt =
                                cf.lt_tiles.as_ref().expect("Cholesky cache carries L^T");
                            restore_tiles(&mut lt, &saved_lt[comm.rank()]);
                            let mut x = b.clone_panel();
                            ptrsm(&ctx, &a, &mut x, TriKind::Lower)?;
                            // Cached L^T also skips the
                            // transpose-redistribution.
                            ptrsm(&ctx, &lt, &mut x, TriKind::Upper)?;
                            (x, lt)
                        }
                        // [`pchol_solve_panel`] inlined to keep L and L^T.
                        None => {
                            pchol_factor_ckpt(&ctx, &mut a, ckpt)?;
                            let mut x = b.clone_panel();
                            ptrsm(&ctx, &a, &mut x, TriKind::Lower)?;
                            let lt = ptranspose(ctx.mesh, &a);
                            ptrsm(&ctx, &lt, &mut x, TriKind::Upper)?;
                            (x, lt)
                        }
                    };
                    let export = exporting
                        .then(|| (export_tiles(&a), Some(export_tiles(&lt)), Vec::new()));
                    (x, None, export)
                }
                Method::Iterative(IterMethod::Cg) => {
                    let (x, st) = block_cg(&ctx, &a0, &b, &iter_cfg, &tols_owned)?;
                    (x, Some(st), None)
                }
                Method::Iterative(IterMethod::Bicgstab) => {
                    let (x, st) = block_bicgstab(&ctx, &a0, &b, &iter_cfg, &tols_owned)?;
                    (x, Some(st), None)
                }
                Method::Iterative(m) => {
                    // No blocked variant: loop single-RHS solves, tagging
                    // each for attribution (factor-free methods amortize
                    // nothing here, but the serving path stays uniform).
                    let mut cols = Vec::with_capacity(k);
                    let mut st = Vec::with_capacity(k);
                    for j in 0..k {
                        let cfg_j = IterConfig { tol: tols_owned[j], ..iter_cfg };
                        ctx.set_tenant(Some(j));
                        let out = match m {
                            IterMethod::PipeCg => pipecg(&ctx, &a0, b.col(j), &cfg_j),
                            IterMethod::Bicg => bicg(&ctx, &a0, b.col(j), &cfg_j),
                            IterMethod::Gmres => gmres_ft(&ctx, &a0, b.col(j), &cfg_j, ckpt),
                            IterMethod::Cg | IterMethod::Bicgstab => unreachable!(),
                        };
                        ctx.set_tenant(None);
                        let (x, s) = out?;
                        cols.push(x);
                        st.push(s);
                    }
                    (DistMultiVector::from_cols(cols), Some(st), None)
                }
            };
            let metrics = RankMetrics::capture(&comm, wall.secs());
            let mut gathered: Option<Vec<Vec<S>>> = None;
            for j in 0..k {
                if let Some(col) = gather_vector(&mesh, x.col(j)) {
                    gathered.get_or_insert_with(Vec::new).push(col);
                }
            }
            let col_stats = col_stats.map(|st| {
                st.iter()
                    .map(|s| {
                        (s.iterations, s.rel_residual.to_f64().unwrap_or(f64::NAN), s.converged)
                    })
                    .collect()
            });
            Ok((metrics, gathered, col_stats, ctx.attribution(), export))
        });

        let mut per_rank = Vec::with_capacity(cfg.ranks);
        let mut solution: Option<Vec<Vec<S>>> = None;
        let mut col_stats: Option<Vec<(usize, f64, bool)>> = None;
        let mut attribution = vec![0.0f64; k + 1];
        let mut exports: Vec<(Vec<Vec<f64>>, Option<Vec<Vec<f64>>>, Vec<(usize, usize)>)> =
            Vec::new();
        for r in results {
            let (m, sol, st, attr, exp) = r?;
            per_rank.push(m);
            if sol.is_some() {
                solution = sol;
            }
            if st.is_some() {
                col_stats = st;
            }
            for (acc, v) in attribution.iter_mut().zip(attr) {
                *acc += v;
            }
            if let Some(e) = exp {
                exports.push(e);
            }
        }
        if exporting && exports.len() == cfg.ranks {
            // Results arrive in rank order; swaps are rank-replicated.
            let swaps = exports[0].2.clone();
            let lt_tiles: Option<Vec<Vec<Vec<f64>>>> = if exports[0].1.is_some() {
                Some(exports.iter_mut().map(|e| e.1.take().unwrap()).collect())
            } else {
                None
            };
            let tiles = exports.into_iter().map(|e| e.0).collect();
            self.factor_cache.put(key, CachedFactor { tiles, lt_tiles, swaps });
        }
        let solution = solution.expect("rank 0 gathers the solution");
        let xt = workload.x_true::<S>(n);
        let mut max_err = 0.0f64;
        for (j, col) in solution.iter().enumerate() {
            for (i, &xi) in col.iter().enumerate() {
                let want = coeffs[j] * xt(i).to_f64().unwrap();
                max_err = max_err.max((xi.to_f64().unwrap() - want).abs());
            }
        }
        // Worst column: the batch is done when its slowest member is.
        let iter_stats = col_stats.map(|st| {
            st.iter().fold((0usize, 0.0f64, true), |(it, res, conv), &(i, r, c)| {
                (it.max(i), if r.is_nan() || r > res { r } else { res }, conv && c)
            })
        });
        Ok(SolveReport::new(
            method.name(),
            workload,
            n,
            cfg.ranks,
            cfg.engine,
            per_rank,
            max_err,
            iter_stats,
        )
        .with_batch(k, attribution)
        .with_factor_cached(hit))
    }
}

/// Reject method/workload combinations with no mathematical meaning.
fn validate_method(workload: Workload, method: Method) -> Result<()> {
    if matches!(
        method,
        Method::Cholesky | Method::Iterative(IterMethod::Cg | IterMethod::PipeCg)
    ) && !workload.is_spd()
    {
        return Err(Error::config(format!(
            "{} requires an SPD workload, got {workload:?}",
            method.name()
        )));
    }
    Ok(())
}

/// This rank's worst solution error against the workload's known answer,
/// over the vector blocks it holds.  The mixed path checks errors per rank
/// (and maxes host-side) because its wide solution vector cannot ride the
/// narrow-typed world's gather.
fn local_worst_err<T: Scalar>(x: &DistVector<T>, workload: Workload, n: usize) -> f64 {
    let desc = *x.desc();
    let t = desc.tile;
    let xt = workload.x_true::<f64>(n);
    let mut worst = 0.0f64;
    for l in 0..x.local_blocks() {
        let base = desc.global_ti(x.prow(), l) * t;
        for (i, &v) in x.block(l).iter().enumerate() {
            let g = base + i;
            if g < n {
                worst = worst.max((v.to_f64().unwrap() - xt(g)).abs());
            }
        }
    }
    worst
}

/// Snapshot a rank's owned tiles as f64 (exact for all supported dtypes),
/// in [`DistMatrix::owned_tiles`] order.
fn export_tiles<S: Scalar>(a: &DistMatrix<S>) -> Vec<Vec<f64>> {
    a.owned_tiles()
        .map(|(lti, ltj, _, _)| a.tile(lti, ltj).iter().map(|v| v.to_f64().unwrap()).collect())
        .collect()
}

/// Overwrite a rank's owned tiles from a [`FactorCache`] snapshot.  The
/// f64 round-trip is exact, so a restored factor is bit-identical to the
/// one that was exported.
fn restore_tiles<S: Scalar>(a: &mut DistMatrix<S>, saved: &[Vec<f64>]) {
    let idx: Vec<(usize, usize)> = a.owned_tiles().map(|(lti, ltj, _, _)| (lti, ltj)).collect();
    for ((lti, ltj), src) in idx.into_iter().zip(saved) {
        for (dst, &v) in a.tile_mut(lti, ltj).iter_mut().zip(src) {
            *dst = S::from_f64(v).unwrap();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse() {
        assert_eq!(Method::parse("lu").unwrap(), Method::Lu);
        assert_eq!(Method::parse("cholesky").unwrap(), Method::Cholesky);
        assert_eq!(Method::parse("gmres").unwrap(), Method::Iterative(IterMethod::Gmres));
        assert!(Method::parse("qr").is_err());
    }

    #[test]
    fn cholesky_rejects_nonsym_workload() {
        let cluster = Cluster::new(ClusterConfig::small(1, 8)).unwrap();
        let err = cluster.solve::<f64>(Workload::DiagDominant, 16, Method::Cholesky);
        assert!(err.is_err());
    }

    #[test]
    fn small_lu_solve_end_to_end() {
        let cluster = Cluster::new(ClusterConfig::small(4, 8)).unwrap();
        let report = cluster.solve::<f64>(Workload::DiagDominant, 32, Method::Lu).unwrap();
        assert!(report.max_err < 1e-8, "max_err {}", report.max_err);
        assert_eq!(report.per_rank.len(), 4);
        assert!(report.makespan() > 0.0);
    }

    #[test]
    fn solve_batch_end_to_end_with_attribution() {
        let cluster = Cluster::new(ClusterConfig::small(2, 8)).unwrap();
        let report = cluster
            .solve_batch::<f64>(Workload::DiagDominant, 24, Method::Lu, &[1.0, 1.5], &[1e-8; 2])
            .unwrap();
        assert!(report.max_err < 1e-8, "max_err {}", report.max_err);
        assert_eq!(report.nrhs, 2);
        // k per-request buckets + the shared bucket, all finite, some work
        // actually attributed somewhere.
        assert_eq!(report.attribution.len(), 3);
        assert!(report.attribution.iter().all(|v| v.is_finite() && *v >= 0.0));
        assert!(report.attribution.iter().sum::<f64>() > 0.0);
        assert_eq!(report.per_request_secs().len(), 2);
        // A batch of two must beat two separate solves on the clock.
        let single = cluster.solve::<f64>(Workload::DiagDominant, 24, Method::Lu).unwrap();
        assert!(
            report.makespan() < 2.0 * single.makespan(),
            "batched {} vs 2x single {}",
            report.makespan(),
            2.0 * single.makespan()
        );
    }

    #[test]
    fn solve_batch_rejects_mismatched_widths() {
        let cluster = Cluster::new(ClusterConfig::small(1, 8)).unwrap();
        assert!(cluster
            .solve_batch::<f64>(Workload::DiagDominant, 16, Method::Lu, &[], &[])
            .is_err());
        assert!(cluster
            .solve_batch::<f64>(Workload::DiagDominant, 16, Method::Lu, &[1.0, 2.0], &[1e-8])
            .is_err());
    }

    #[test]
    fn small_iterative_solve_end_to_end() {
        let cluster = Cluster::new(ClusterConfig {
            iter: IterConfig { tol: 1e-10, max_iter: 400, restart: 20 },
            ..ClusterConfig::small(2, 8)
        })
        .unwrap();
        let report = cluster
            .solve::<f64>(Workload::Spd, 32, Method::Iterative(IterMethod::Cg))
            .unwrap();
        assert!(report.max_err < 1e-6, "max_err {}", report.max_err);
        let (iters, _res, conv) = report.iter_stats.unwrap();
        assert!(conv && iters > 0);
    }

    #[test]
    fn mixed_gate_needs_profile_dtype_and_method() {
        // Host arm: SSE2 double throughput equals single and nothing
        // streams over PCIe — no advantage, gate closed.
        let host = ClusterConfig::small(2, 8);
        assert!(!mixed_engaged::<f64>(&host, Method::Lu));
        // CUDA arm: every qualifying method opens it...
        let cuda =
            ClusterConfig { engine: EngineKind::Accelerated, ..ClusterConfig::small(2, 8) };
        assert!(mixed_engaged::<f64>(&cuda, Method::Lu));
        assert!(mixed_engaged::<f64>(&cuda, Method::Cholesky));
        assert!(mixed_engaged::<f64>(&cuda, Method::Iterative(IterMethod::Cg)));
        assert!(mixed_engaged::<f64>(&cuda, Method::Iterative(IterMethod::Bicgstab)));
        // ...but f32 has no narrower storage to drop to, GMRES has no
        // wide-recovery story, and --no-mixed closes it outright.
        assert!(!mixed_engaged::<f32>(&cuda, Method::Lu));
        assert!(!mixed_engaged::<f64>(&cuda, Method::Iterative(IterMethod::Gmres)));
        let off = ClusterConfig { mixed_precision: false, ..cuda };
        assert!(!mixed_engaged::<f64>(&off, Method::Lu));
    }

    #[test]
    fn no_mixed_is_bit_identical_when_the_gate_is_closed() {
        let on = Cluster::new(ClusterConfig::small(2, 8)).unwrap();
        let off = Cluster::new(ClusterConfig {
            mixed_precision: false,
            ..ClusterConfig::small(2, 8)
        })
        .unwrap();
        let a = on.solve::<f64>(Workload::DiagDominant, 24, Method::Lu).unwrap();
        let b = off.solve::<f64>(Workload::DiagDominant, 24, Method::Lu).unwrap();
        assert_eq!(a.max_err.to_bits(), b.max_err.to_bits());
        assert_eq!(a.makespan(), b.makespan());
        assert_eq!(a.refine_iters, 0);
        assert_eq!(a.bytes_saved_mixed, 0);
        assert!(!a.mixed_fallback);
    }

    #[test]
    fn factor_cache_hit_prices_only_the_substitutions() {
        for (workload, method) in
            [(Workload::DiagDominant, Method::Lu), (Workload::Spd, Method::Cholesky)]
        {
            let cluster = Cluster::new(ClusterConfig::small(2, 8)).unwrap();
            let args = (&[1.0, 1.5][..], &[1e-8; 2][..]);
            let miss = cluster
                .solve_batch_cached::<f64>(workload, 24, method, args.0, args.1, true)
                .unwrap();
            assert!(!miss.factor_cached);
            assert_eq!(cluster.factor_cache().len(), 1);
            let hit = cluster
                .solve_batch_cached::<f64>(workload, 24, method, args.0, args.1, true)
                .unwrap();
            assert!(hit.factor_cached);
            assert_eq!(cluster.factor_cache().len(), 1);
            // The restored factor is bit-identical, so the substitutions
            // produce the same solution — for strictly less virtual time.
            assert_eq!(hit.max_err.to_bits(), miss.max_err.to_bits());
            assert!(
                hit.makespan() < miss.makespan(),
                "{}: hit {} vs miss {}",
                method.name(),
                hit.makespan(),
                miss.makespan()
            );
        }
        // Without opting in, nothing is cached and nothing is restored.
        let plain = Cluster::new(ClusterConfig::small(2, 8)).unwrap();
        let rep = plain
            .solve_batch::<f64>(Workload::DiagDominant, 24, Method::Lu, &[1.0], &[1e-8])
            .unwrap();
        assert!(plain.factor_cache().is_empty() && !rep.factor_cached);
    }

    #[test]
    fn factor_cache_capacity_evicts_lru() {
        let cache = FactorCache::with_capacity(2);
        let factor = || CachedFactor { tiles: Vec::new(), lt_tiles: None, swaps: Vec::new() };
        let k1: FactorKey = (Workload::DiagDominant, 16, "LU", "f64");
        let k2: FactorKey = (Workload::DiagDominant, 32, "LU", "f64");
        let k3: FactorKey = (Workload::DiagDominant, 64, "LU", "f64");
        cache.put(k1, factor());
        cache.put(k2, factor());
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.evictions(), 0);
        // A hit refreshes recency: k1 survives the next eviction, k2 does
        // not.
        assert!(cache.get(&k1).is_some());
        cache.put(k3, factor());
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.evictions(), 1);
        assert!(cache.get(&k2).is_none());
        assert!(cache.get(&k1).is_some() && cache.get(&k3).is_some());
        // Shrinking the capacity evicts down to it, LRU first.
        cache.set_capacity(1);
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.evictions(), 2);
        assert!(cache.get(&k3).is_some());
    }

    #[test]
    fn unbounded_default_cache_never_evicts() {
        let cache = FactorCache::new();
        for n in [16usize, 32, 64, 128] {
            cache.put(
                (Workload::DiagDominant, n, "LU", "f64"),
                CachedFactor { tiles: Vec::new(), lt_tiles: None, swaps: Vec::new() },
            );
        }
        assert_eq!(cache.len(), 4);
        assert_eq!(cache.evictions(), 0);
    }
}
