//! The cluster runtime: CUPLSS's user-facing entry point ("the parallelism
//! is hidden from the user", paper §3).
//!
//! [`Cluster::solve`] spins up the simulated MPI world, distributes the
//! workload, runs the requested solver with the requested local-compute
//! engine, verifies the solution against the workload's known answer, and
//! returns a [`SolveReport`] with the virtual-time breakdown per rank —
//! everything the bench harness needs to plot the paper's figures.

pub mod metrics;

pub use metrics::{RankMetrics, SolveReport};

use std::sync::Arc;

use crate::accel::{make_engine, Engine, EngineKind};
use crate::comm::{NetworkModel, World};
use crate::dist::{gather_vector, Descriptor, DistMatrix, DistMultiVector, DistVector};
use crate::mesh::{Mesh, MeshShape};
use crate::pblas::Ctx;
use crate::runtime::Runtime;
use crate::solvers::{
    bicg, bicgstab, block_bicgstab, block_cg, cg, gmres, pchol_solve, pchol_solve_panel,
    pipecg, plu_solve, plu_solve_panel, IterConfig, IterMethod, IterStats,
};
use crate::workloads::Workload;
use crate::{Error, Result, Scalar};

/// Which solver to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Blocked LU with partial pivoting + triangular solves.
    Lu,
    /// Blocked Cholesky + triangular solves (SPD only).
    Cholesky,
    /// A non-stationary iterative method.
    Iterative(IterMethod),
}

impl Method {
    /// Parse a CLI name.
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "lu" => Ok(Method::Lu),
            "chol" | "cholesky" => Ok(Method::Cholesky),
            other => Ok(Method::Iterative(IterMethod::parse(other)?)),
        }
    }

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            Method::Lu => "LU",
            Method::Cholesky => "Cholesky",
            Method::Iterative(m) => m.name(),
        }
    }
}

/// Everything needed to run one distributed solve.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Number of ranks (the paper sweeps 1, 2, 4, 8, 16).
    pub ranks: usize,
    /// Tile size (must have matching artifacts for the accelerated engine).
    pub tile: usize,
    /// Local-compute arm (the paper's CUDA-vs-ATLAS axis).
    pub engine: EngineKind,
    /// Network profile for the virtual clock.
    pub net: NetworkModel,
    /// Artifact directory (PJRT runtime), used by the accelerated arm.
    pub artifact_dir: String,
    /// Device residency: keep tiles/vectors device-side across calls
    /// (`DESIGN.md` §12).  `false` reproduces the paper's §3
    /// copy-per-call flow.  Never changes results, only PCIe charges.
    pub residency: bool,
    /// Device-memory budget for the residency cache, bytes.
    pub device_mem: usize,
    /// Copy-engine timeline: route surviving transfers through async H2D
    /// prefetch / D2H write-back overlapped with compute (`DESIGN.md`
    /// §13).  `false` keeps residency's synchronous accounting — the
    /// `--no-prefetch` A/B arm.  Never changes results, only *when* PCIe
    /// time is charged.  Inert without residency.
    pub prefetch: bool,
    /// GPUDirect wire: device-dirty send payloads go straight to the NIC,
    /// occupying NIC + copy engine jointly with no host staging barrier
    /// (`DESIGN.md` §16).  `false` keeps the blocking host_read-then-send
    /// flow — the `--no-gpudirect` A/B arm.  Never changes results.  Inert
    /// without residency + prefetch.
    pub gpudirect: bool,
    /// Iterative controls.
    pub iter: IterConfig,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            ranks: 4,
            tile: crate::DEFAULT_TILE,
            engine: EngineKind::CpuSerial,
            net: NetworkModel::gigabit_ethernet(),
            artifact_dir: crate::runtime::DEFAULT_ARTIFACT_DIR.to_string(),
            residency: true,
            device_mem: crate::accel::DEFAULT_DEVICE_MEM,
            prefetch: true,
            gpudirect: true,
            iter: IterConfig::default(),
        }
    }
}

impl ClusterConfig {
    /// Small-instance config for tests and demos: `ranks` ranks on
    /// `tile`-sized tiles, everything else default.  Prefer this (or at
    /// least `..Default::default()`) over spelling out full literals in
    /// tests — a new config field then inherits its default instead of
    /// breaking every literal in the tree (`DESIGN.md` §14).
    pub fn small(ranks: usize, tile: usize) -> Self {
        ClusterConfig { ranks, tile, ..Default::default() }
    }
}

/// The cluster facade.
pub struct Cluster {
    cfg: ClusterConfig,
    runtime: Option<Arc<Runtime>>,
}

impl Cluster {
    /// Construct; loads the PJRT runtime when the accelerated engine is
    /// requested.
    pub fn new(cfg: ClusterConfig) -> Result<Self> {
        let runtime = match cfg.engine {
            EngineKind::Accelerated => Some(Runtime::new(&cfg.artifact_dir)?),
            EngineKind::CpuSerial => None,
        };
        Ok(Cluster { cfg, runtime })
    }

    /// The active config.
    pub fn config(&self) -> &ClusterConfig {
        &self.cfg
    }

    /// Solve an `n x n` instance of `workload` with `method`; returns the
    /// report (makespan, per-rank breakdown, solution error vs the known
    /// answer).
    pub fn solve<S: Scalar>(&self, workload: Workload, n: usize, method: Method) -> Result<SolveReport> {
        if matches!(
            method,
            Method::Cholesky | Method::Iterative(IterMethod::Cg | IterMethod::PipeCg)
        ) && !workload.is_spd()
        {
            return Err(Error::config(format!(
                "{} requires an SPD workload, got {workload:?}",
                method.name()
            )));
        }
        let cfg = &self.cfg;
        let shape = MeshShape::near_square(cfg.ranks);
        // Shared engine: constructed once, used by all rank threads (each
        // node in the paper has its own GPU; the cost model is per-op, so
        // sharing the compiled executables is timing-neutral).
        let engine: Arc<dyn Engine<S>> =
            make_engine(cfg.engine, cfg.tile, self.runtime.as_ref())?;
        let iter_cfg = cfg.iter;
        let tile = cfg.tile;
        let (residency, device_mem, prefetch, gpudirect) =
            (cfg.residency, cfg.device_mem, cfg.prefetch, cfg.gpudirect);

        let results = World::run::<S, Result<(RankMetrics, Option<Vec<S>>, Option<(usize, f64, bool)>)>, _>(
            cfg.ranks,
            cfg.net,
            move |comm| {
                let mesh = Mesh::new(&comm, shape);
                let ctx = if residency {
                    Ctx::with_device_mem(&mesh, engine.clone(), device_mem)
                        .with_prefetch(prefetch)
                        .with_gpudirect(gpudirect)
                } else {
                    Ctx::streaming(&mesh, engine.clone())
                };
                let desc = Descriptor::new(n, n, tile, shape);
                let elem = workload.elem::<S>(n);
                let rhs = workload.rhs::<S>(n);
                let a0 = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), elem);
                let b = DistVector::from_fn(desc, mesh.row(), mesh.col(), rhs);
                // Synchronise before timing (all ranks at t=0 after setup).
                comm.clock().reset();
                let wall = crate::util::Stopwatch::start();

                let (x, iter_stats) = match method {
                    Method::Lu => {
                        let mut a = a0;
                        (plu_solve(&ctx, &mut a, &b)?, None)
                    }
                    Method::Cholesky => {
                        let mut a = a0;
                        (pchol_solve(&ctx, &mut a, &b)?, None)
                    }
                    Method::Iterative(m) => {
                        let (x, st) = match m {
                            IterMethod::Cg => cg(&ctx, &a0, &b, &iter_cfg)?,
                            IterMethod::PipeCg => pipecg(&ctx, &a0, &b, &iter_cfg)?,
                            IterMethod::Bicg => bicg(&ctx, &a0, &b, &iter_cfg)?,
                            IterMethod::Bicgstab => bicgstab(&ctx, &a0, &b, &iter_cfg)?,
                            IterMethod::Gmres => gmres(&ctx, &a0, &b, &iter_cfg)?,
                        };
                        (
                            x,
                            Some((
                                st.iterations,
                                st.rel_residual.to_f64().unwrap_or(f64::NAN),
                                st.converged,
                            )),
                        )
                    }
                };
                let metrics = RankMetrics::capture(&comm, wall.secs());
                let gathered = gather_vector(&mesh, &x);
                Ok((metrics, gathered, iter_stats))
            },
        );

        let mut per_rank = Vec::with_capacity(cfg.ranks);
        let mut solution: Option<Vec<S>> = None;
        let mut iter_stats = None;
        for r in results {
            let (m, sol, st) = r?;
            per_rank.push(m);
            if sol.is_some() {
                solution = sol;
            }
            if st.is_some() {
                iter_stats = st;
            }
        }
        let solution = solution.expect("rank 0 gathers the solution");
        let xt = workload.x_true::<S>(n);
        let mut max_err = 0.0f64;
        for (i, &xi) in solution.iter().enumerate() {
            let want = xt(i).to_f64().unwrap();
            let err = (xi.to_f64().unwrap() - want).abs();
            max_err = max_err.max(err);
        }
        Ok(SolveReport::new(
            method.name(),
            workload,
            n,
            cfg.ranks,
            cfg.engine,
            per_rank,
            max_err,
            iter_stats,
        ))
    }

    /// Solve `A X = B` for a whole batch of `k = coeffs.len()` right-hand
    /// sides sharing one operator: `b_j = coeffs[j] · b` (so the known
    /// answer is `x_j = coeffs[j] · x_true`) with per-request tolerance
    /// `tols[j]`.  Direct methods factor **once** and run the RHS-panel
    /// substitutions ([`plu_solve_panel`]/[`pchol_solve_panel`]); CG and
    /// BiCGSTAB run blocked (shared matvec sweeps, k-lane reductions);
    /// the remaining iterative methods loop single-RHS solves under the
    /// same attribution accounting.  The report carries per-request
    /// attribution buckets ([`SolveReport::attribution`]) and worst-column
    /// `iter_stats`.
    pub fn solve_batch<S: Scalar>(
        &self,
        workload: Workload,
        n: usize,
        method: Method,
        coeffs: &[f64],
        tols: &[f64],
    ) -> Result<SolveReport> {
        let k = coeffs.len();
        if k == 0 || tols.len() != k {
            return Err(Error::config(format!(
                "solve_batch needs matching non-empty coeffs/tols, got {}/{}",
                k,
                tols.len()
            )));
        }
        if matches!(
            method,
            Method::Cholesky | Method::Iterative(IterMethod::Cg | IterMethod::PipeCg)
        ) && !workload.is_spd()
        {
            return Err(Error::config(format!(
                "{} requires an SPD workload, got {workload:?}",
                method.name()
            )));
        }
        let cfg = &self.cfg;
        let shape = MeshShape::near_square(cfg.ranks);
        let engine: Arc<dyn Engine<S>> =
            make_engine(cfg.engine, cfg.tile, self.runtime.as_ref())?;
        let iter_cfg = cfg.iter;
        let tile = cfg.tile;
        let (residency, device_mem, prefetch, gpudirect) =
            (cfg.residency, cfg.device_mem, cfg.prefetch, cfg.gpudirect);
        let coeffs_owned: Vec<f64> = coeffs.to_vec();
        let tols_owned: Vec<f64> = tols.to_vec();

        type BatchOut<S> =
            (RankMetrics, Option<Vec<Vec<S>>>, Option<Vec<(usize, f64, bool)>>, Vec<f64>);
        let results = World::run::<S, Result<BatchOut<S>>, _>(cfg.ranks, cfg.net, move |comm| {
            let mesh = Mesh::new(&comm, shape);
            let ctx = if residency {
                Ctx::with_device_mem(&mesh, engine.clone(), device_mem)
                    .with_prefetch(prefetch)
                    .with_gpudirect(gpudirect)
            } else {
                Ctx::streaming(&mesh, engine.clone())
            };
            let desc = Descriptor::new(n, n, tile, shape);
            let elem = workload.elem::<S>(n);
            let rhs = workload.rhs::<S>(n);
            let a0 = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), elem);
            let scales: Vec<S> =
                coeffs_owned.iter().map(|&c| S::from_f64(c).unwrap()).collect();
            let b = DistMultiVector::from_fn(desc, mesh.row(), mesh.col(), k, |i, j| {
                scales[j] * rhs(i)
            });
            ctx.enable_attribution(k);
            comm.clock().reset();
            let wall = crate::util::Stopwatch::start();

            let (x, col_stats): (DistMultiVector<S>, Option<Vec<IterStats<S>>>) = match method {
                Method::Lu => {
                    let mut a = a0;
                    (plu_solve_panel(&ctx, &mut a, &b)?, None)
                }
                Method::Cholesky => {
                    let mut a = a0;
                    (pchol_solve_panel(&ctx, &mut a, &b)?, None)
                }
                Method::Iterative(IterMethod::Cg) => {
                    let (x, st) = block_cg(&ctx, &a0, &b, &iter_cfg, &tols_owned)?;
                    (x, Some(st))
                }
                Method::Iterative(IterMethod::Bicgstab) => {
                    let (x, st) = block_bicgstab(&ctx, &a0, &b, &iter_cfg, &tols_owned)?;
                    (x, Some(st))
                }
                Method::Iterative(m) => {
                    // No blocked variant: loop single-RHS solves, tagging
                    // each for attribution (factor-free methods amortize
                    // nothing here, but the serving path stays uniform).
                    let mut cols = Vec::with_capacity(k);
                    let mut st = Vec::with_capacity(k);
                    for j in 0..k {
                        let cfg_j = IterConfig { tol: tols_owned[j], ..iter_cfg };
                        ctx.set_tenant(Some(j));
                        let out = match m {
                            IterMethod::PipeCg => pipecg(&ctx, &a0, b.col(j), &cfg_j),
                            IterMethod::Bicg => bicg(&ctx, &a0, b.col(j), &cfg_j),
                            IterMethod::Gmres => gmres(&ctx, &a0, b.col(j), &cfg_j),
                            IterMethod::Cg | IterMethod::Bicgstab => unreachable!(),
                        };
                        ctx.set_tenant(None);
                        let (x, s) = out?;
                        cols.push(x);
                        st.push(s);
                    }
                    (DistMultiVector::from_cols(cols), Some(st))
                }
            };
            let metrics = RankMetrics::capture(&comm, wall.secs());
            let mut gathered: Option<Vec<Vec<S>>> = None;
            for j in 0..k {
                if let Some(col) = gather_vector(&mesh, x.col(j)) {
                    gathered.get_or_insert_with(Vec::new).push(col);
                }
            }
            let col_stats = col_stats.map(|st| {
                st.iter()
                    .map(|s| {
                        (s.iterations, s.rel_residual.to_f64().unwrap_or(f64::NAN), s.converged)
                    })
                    .collect()
            });
            Ok((metrics, gathered, col_stats, ctx.attribution()))
        });

        let mut per_rank = Vec::with_capacity(cfg.ranks);
        let mut solution: Option<Vec<Vec<S>>> = None;
        let mut col_stats: Option<Vec<(usize, f64, bool)>> = None;
        let mut attribution = vec![0.0f64; k + 1];
        for r in results {
            let (m, sol, st, attr) = r?;
            per_rank.push(m);
            if sol.is_some() {
                solution = sol;
            }
            if st.is_some() {
                col_stats = st;
            }
            for (acc, v) in attribution.iter_mut().zip(attr) {
                *acc += v;
            }
        }
        let solution = solution.expect("rank 0 gathers the solution");
        let xt = workload.x_true::<S>(n);
        let mut max_err = 0.0f64;
        for (j, col) in solution.iter().enumerate() {
            for (i, &xi) in col.iter().enumerate() {
                let want = coeffs[j] * xt(i).to_f64().unwrap();
                max_err = max_err.max((xi.to_f64().unwrap() - want).abs());
            }
        }
        // Worst column: the batch is done when its slowest member is.
        let iter_stats = col_stats.map(|st| {
            st.iter().fold((0usize, 0.0f64, true), |(it, res, conv), &(i, r, c)| {
                (it.max(i), if r.is_nan() || r > res { r } else { res }, conv && c)
            })
        });
        Ok(SolveReport::new(
            method.name(),
            workload,
            n,
            cfg.ranks,
            cfg.engine,
            per_rank,
            max_err,
            iter_stats,
        )
        .with_batch(k, attribution))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse() {
        assert_eq!(Method::parse("lu").unwrap(), Method::Lu);
        assert_eq!(Method::parse("cholesky").unwrap(), Method::Cholesky);
        assert_eq!(Method::parse("gmres").unwrap(), Method::Iterative(IterMethod::Gmres));
        assert!(Method::parse("qr").is_err());
    }

    #[test]
    fn cholesky_rejects_nonsym_workload() {
        let cluster = Cluster::new(ClusterConfig::small(1, 8)).unwrap();
        let err = cluster.solve::<f64>(Workload::DiagDominant, 16, Method::Cholesky);
        assert!(err.is_err());
    }

    #[test]
    fn small_lu_solve_end_to_end() {
        let cluster = Cluster::new(ClusterConfig::small(4, 8)).unwrap();
        let report = cluster.solve::<f64>(Workload::DiagDominant, 32, Method::Lu).unwrap();
        assert!(report.max_err < 1e-8, "max_err {}", report.max_err);
        assert_eq!(report.per_rank.len(), 4);
        assert!(report.makespan() > 0.0);
    }

    #[test]
    fn solve_batch_end_to_end_with_attribution() {
        let cluster = Cluster::new(ClusterConfig::small(2, 8)).unwrap();
        let report = cluster
            .solve_batch::<f64>(Workload::DiagDominant, 24, Method::Lu, &[1.0, 1.5], &[1e-8; 2])
            .unwrap();
        assert!(report.max_err < 1e-8, "max_err {}", report.max_err);
        assert_eq!(report.nrhs, 2);
        // k per-request buckets + the shared bucket, all finite, some work
        // actually attributed somewhere.
        assert_eq!(report.attribution.len(), 3);
        assert!(report.attribution.iter().all(|v| v.is_finite() && *v >= 0.0));
        assert!(report.attribution.iter().sum::<f64>() > 0.0);
        assert_eq!(report.per_request_secs().len(), 2);
        // A batch of two must beat two separate solves on the clock.
        let single = cluster.solve::<f64>(Workload::DiagDominant, 24, Method::Lu).unwrap();
        assert!(
            report.makespan() < 2.0 * single.makespan(),
            "batched {} vs 2x single {}",
            report.makespan(),
            2.0 * single.makespan()
        );
    }

    #[test]
    fn solve_batch_rejects_mismatched_widths() {
        let cluster = Cluster::new(ClusterConfig::small(1, 8)).unwrap();
        assert!(cluster
            .solve_batch::<f64>(Workload::DiagDominant, 16, Method::Lu, &[], &[])
            .is_err());
        assert!(cluster
            .solve_batch::<f64>(Workload::DiagDominant, 16, Method::Lu, &[1.0, 2.0], &[1e-8])
            .is_err());
    }

    #[test]
    fn small_iterative_solve_end_to_end() {
        let cluster = Cluster::new(ClusterConfig {
            iter: IterConfig { tol: 1e-10, max_iter: 400, restart: 20 },
            ..ClusterConfig::small(2, 8)
        })
        .unwrap();
        let report = cluster
            .solve::<f64>(Workload::Spd, 32, Method::Iterative(IterMethod::Cg))
            .unwrap();
        assert!(report.max_err < 1e-6, "max_err {}", report.max_err);
        let (iters, _res, conv) = report.iter_stats.unwrap();
        assert!(conv && iters > 0);
    }
}
