//! The cluster runtime: CUPLSS's user-facing entry point ("the parallelism
//! is hidden from the user", paper §3).
//!
//! [`Cluster::solve`] spins up the simulated MPI world, distributes the
//! workload, runs the requested solver with the requested local-compute
//! engine, verifies the solution against the workload's known answer, and
//! returns a [`SolveReport`] with the virtual-time breakdown per rank —
//! everything the bench harness needs to plot the paper's figures.

pub mod metrics;

pub use metrics::{RankMetrics, SolveReport};

use std::sync::Arc;

use crate::accel::{make_engine, Engine, EngineKind};
use crate::comm::{NetworkModel, World};
use crate::dist::{gather_vector, Descriptor, DistMatrix, DistVector};
use crate::mesh::{Mesh, MeshShape};
use crate::pblas::Ctx;
use crate::runtime::Runtime;
use crate::solvers::{
    bicg, bicgstab, cg, gmres, pchol_solve, pipecg, plu_solve, IterConfig, IterMethod,
};
use crate::workloads::Workload;
use crate::{Error, Result, Scalar};

/// Which solver to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Blocked LU with partial pivoting + triangular solves.
    Lu,
    /// Blocked Cholesky + triangular solves (SPD only).
    Cholesky,
    /// A non-stationary iterative method.
    Iterative(IterMethod),
}

impl Method {
    /// Parse a CLI name.
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "lu" => Ok(Method::Lu),
            "chol" | "cholesky" => Ok(Method::Cholesky),
            other => Ok(Method::Iterative(IterMethod::parse(other)?)),
        }
    }

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            Method::Lu => "LU",
            Method::Cholesky => "Cholesky",
            Method::Iterative(m) => m.name(),
        }
    }
}

/// Everything needed to run one distributed solve.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Number of ranks (the paper sweeps 1, 2, 4, 8, 16).
    pub ranks: usize,
    /// Tile size (must have matching artifacts for the accelerated engine).
    pub tile: usize,
    /// Local-compute arm (the paper's CUDA-vs-ATLAS axis).
    pub engine: EngineKind,
    /// Network profile for the virtual clock.
    pub net: NetworkModel,
    /// Artifact directory (PJRT runtime), used by the accelerated arm.
    pub artifact_dir: String,
    /// Device residency: keep tiles/vectors device-side across calls
    /// (`DESIGN.md` §12).  `false` reproduces the paper's §3
    /// copy-per-call flow.  Never changes results, only PCIe charges.
    pub residency: bool,
    /// Device-memory budget for the residency cache, bytes.
    pub device_mem: usize,
    /// Copy-engine timeline: route surviving transfers through async H2D
    /// prefetch / D2H write-back overlapped with compute (`DESIGN.md`
    /// §13).  `false` keeps residency's synchronous accounting — the
    /// `--no-prefetch` A/B arm.  Never changes results, only *when* PCIe
    /// time is charged.  Inert without residency.
    pub prefetch: bool,
    /// Iterative controls.
    pub iter: IterConfig,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            ranks: 4,
            tile: crate::DEFAULT_TILE,
            engine: EngineKind::CpuSerial,
            net: NetworkModel::gigabit_ethernet(),
            artifact_dir: crate::runtime::DEFAULT_ARTIFACT_DIR.to_string(),
            residency: true,
            device_mem: crate::accel::DEFAULT_DEVICE_MEM,
            prefetch: true,
            iter: IterConfig::default(),
        }
    }
}

/// The cluster facade.
pub struct Cluster {
    cfg: ClusterConfig,
    runtime: Option<Arc<Runtime>>,
}

impl Cluster {
    /// Construct; loads the PJRT runtime when the accelerated engine is
    /// requested.
    pub fn new(cfg: ClusterConfig) -> Result<Self> {
        let runtime = match cfg.engine {
            EngineKind::Accelerated => Some(Runtime::new(&cfg.artifact_dir)?),
            EngineKind::CpuSerial => None,
        };
        Ok(Cluster { cfg, runtime })
    }

    /// The active config.
    pub fn config(&self) -> &ClusterConfig {
        &self.cfg
    }

    /// Solve an `n x n` instance of `workload` with `method`; returns the
    /// report (makespan, per-rank breakdown, solution error vs the known
    /// answer).
    pub fn solve<S: Scalar>(&self, workload: Workload, n: usize, method: Method) -> Result<SolveReport> {
        if matches!(
            method,
            Method::Cholesky | Method::Iterative(IterMethod::Cg | IterMethod::PipeCg)
        ) && !workload.is_spd()
        {
            return Err(Error::config(format!(
                "{} requires an SPD workload, got {workload:?}",
                method.name()
            )));
        }
        let cfg = &self.cfg;
        let shape = MeshShape::near_square(cfg.ranks);
        // Shared engine: constructed once, used by all rank threads (each
        // node in the paper has its own GPU; the cost model is per-op, so
        // sharing the compiled executables is timing-neutral).
        let engine: Arc<dyn Engine<S>> =
            make_engine(cfg.engine, cfg.tile, self.runtime.as_ref())?;
        let iter_cfg = cfg.iter;
        let tile = cfg.tile;
        let (residency, device_mem, prefetch) = (cfg.residency, cfg.device_mem, cfg.prefetch);

        let results = World::run::<S, Result<(RankMetrics, Option<Vec<S>>, Option<(usize, f64, bool)>)>, _>(
            cfg.ranks,
            cfg.net,
            move |comm| {
                let mesh = Mesh::new(&comm, shape);
                let ctx = if residency {
                    Ctx::with_device_mem(&mesh, engine.clone(), device_mem)
                        .with_prefetch(prefetch)
                } else {
                    Ctx::streaming(&mesh, engine.clone())
                };
                let desc = Descriptor::new(n, n, tile, shape);
                let elem = workload.elem::<S>(n);
                let rhs = workload.rhs::<S>(n);
                let a0 = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), elem);
                let b = DistVector::from_fn(desc, mesh.row(), mesh.col(), rhs);
                // Synchronise before timing (all ranks at t=0 after setup).
                comm.clock().reset();
                let wall = crate::util::Stopwatch::start();

                let (x, iter_stats) = match method {
                    Method::Lu => {
                        let mut a = a0;
                        (plu_solve(&ctx, &mut a, &b)?, None)
                    }
                    Method::Cholesky => {
                        let mut a = a0;
                        (pchol_solve(&ctx, &mut a, &b)?, None)
                    }
                    Method::Iterative(m) => {
                        let (x, st) = match m {
                            IterMethod::Cg => cg(&ctx, &a0, &b, &iter_cfg)?,
                            IterMethod::PipeCg => pipecg(&ctx, &a0, &b, &iter_cfg)?,
                            IterMethod::Bicg => bicg(&ctx, &a0, &b, &iter_cfg)?,
                            IterMethod::Bicgstab => bicgstab(&ctx, &a0, &b, &iter_cfg)?,
                            IterMethod::Gmres => gmres(&ctx, &a0, &b, &iter_cfg)?,
                        };
                        (
                            x,
                            Some((
                                st.iterations,
                                st.rel_residual.to_f64().unwrap_or(f64::NAN),
                                st.converged,
                            )),
                        )
                    }
                };
                let metrics = RankMetrics::capture(&comm, wall.secs());
                let gathered = gather_vector(&mesh, &x);
                Ok((metrics, gathered, iter_stats))
            },
        );

        let mut per_rank = Vec::with_capacity(cfg.ranks);
        let mut solution: Option<Vec<S>> = None;
        let mut iter_stats = None;
        for r in results {
            let (m, sol, st) = r?;
            per_rank.push(m);
            if sol.is_some() {
                solution = sol;
            }
            if st.is_some() {
                iter_stats = st;
            }
        }
        let solution = solution.expect("rank 0 gathers the solution");
        let xt = workload.x_true::<S>(n);
        let mut max_err = 0.0f64;
        for (i, &xi) in solution.iter().enumerate() {
            let want = xt(i).to_f64().unwrap();
            let err = (xi.to_f64().unwrap() - want).abs();
            max_err = max_err.max(err);
        }
        Ok(SolveReport::new(
            method.name(),
            workload,
            n,
            cfg.ranks,
            cfg.engine,
            per_rank,
            max_err,
            iter_stats,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse() {
        assert_eq!(Method::parse("lu").unwrap(), Method::Lu);
        assert_eq!(Method::parse("cholesky").unwrap(), Method::Cholesky);
        assert_eq!(Method::parse("gmres").unwrap(), Method::Iterative(IterMethod::Gmres));
        assert!(Method::parse("qr").is_err());
    }

    #[test]
    fn cholesky_rejects_nonsym_workload() {
        let cluster = Cluster::new(ClusterConfig {
            ranks: 1,
            tile: 8,
            ..Default::default()
        })
        .unwrap();
        let err = cluster.solve::<f64>(Workload::DiagDominant, 16, Method::Cholesky);
        assert!(err.is_err());
    }

    #[test]
    fn small_lu_solve_end_to_end() {
        let cluster = Cluster::new(ClusterConfig {
            ranks: 4,
            tile: 8,
            ..Default::default()
        })
        .unwrap();
        let report = cluster.solve::<f64>(Workload::DiagDominant, 32, Method::Lu).unwrap();
        assert!(report.max_err < 1e-8, "max_err {}", report.max_err);
        assert_eq!(report.per_rank.len(), 4);
        assert!(report.makespan() > 0.0);
    }

    #[test]
    fn small_iterative_solve_end_to_end() {
        let cluster = Cluster::new(ClusterConfig {
            ranks: 2,
            tile: 8,
            iter: IterConfig { tol: 1e-10, max_iter: 400, restart: 20 },
            ..Default::default()
        })
        .unwrap();
        let report = cluster
            .solve::<f64>(Workload::Spd, 32, Method::Iterative(IterMethod::Cg))
            .unwrap();
        assert!(report.max_err < 1e-6, "max_err {}", report.max_err);
        let (iters, _res, conv) = report.iter_stats.unwrap();
        assert!(conv && iters > 0);
    }
}
