//! Per-rank and per-solve metrics: the raw material of every figure.

use crate::accel::EngineKind;
use crate::comm::Comm;
use crate::workloads::Workload;
use crate::Scalar;

/// One rank's accounting after a solve.
#[derive(Clone, Debug)]
pub struct RankMetrics {
    /// World rank.
    pub rank: usize,
    /// Final virtual time (seconds).
    pub vtime: f64,
    /// Virtual seconds of local compute.
    pub compute: f64,
    /// Virtual seconds blocked on messages.
    pub comm_wait: f64,
    /// Virtual seconds of host<->accelerator transfer.
    pub transfer: f64,
    /// Messages sent.
    pub msgs: u64,
    /// Payload bytes sent.
    pub bytes: u64,
    /// Peak number of split-phase requests simultaneously outstanding.
    pub max_outstanding_reqs: u64,
    /// Virtual seconds of communication latency hidden by overlap
    /// (what blocking would have charged minus what `wait` charged).
    pub wait_saved: f64,
    /// PCIe bytes the device-residency layer kept off the host<->device
    /// link (0 on host profiles — nothing streams there to begin with).
    pub pcie_saved_bytes: u64,
    /// Virtual seconds of PCIe transfer hidden behind compute by the
    /// copy-engine timeline (async prefetch / write-back; 0 on host
    /// profiles and with `--no-prefetch`).
    pub pcie_hidden_secs: f64,
    /// Operand accesses served by an in-flight async prefetch.
    pub prefetch_hits: u64,
    /// Payload bytes sent straight off the device (GPUDirect wire, no host
    /// staging barrier; 0 on host profiles and with `--no-gpudirect`).
    pub wire_direct_bytes: u64,
    /// Virtual seconds of host staging (flush-barrier waits at send sites)
    /// the GPUDirect wire avoided.
    pub host_stage_saved_secs: f64,
    /// Kernel launches eliminated by fused BLAS-1 ops.
    pub launches_fused: u64,
    /// Sends retransmitted after a scripted drop (fault plan; 0 without
    /// one).
    pub retries: u64,
    /// Virtual seconds spent in retry timeouts (bounded exponential
    /// backoff) waiting out those drops.
    pub timeout_secs: f64,
    /// Wall-clock seconds this rank actually took (calibration data).
    pub wall: f64,
}

impl RankMetrics {
    /// Snapshot a rank's clock + traffic counters.  `vtime` reads
    /// [`crate::comm::VClock::busy_until`]: a rank whose last act was an
    /// isend is busy until its NIC drains.  For the same reason that tail
    /// backlog is netted out of `wait_saved` — occupancy still queued at
    /// capture time extends the makespan, so it was credited at post but
    /// not actually hidden.
    pub fn capture<S: Scalar>(comm: &Comm<S>, wall: f64) -> Self {
        let tail_backlog = (comm.clock().nic_free() - comm.clock().now()).max(0.0);
        let pcie_backlog = (comm.clock().pcie_free() - comm.clock().now()).max(0.0);
        RankMetrics {
            rank: comm.rank(),
            vtime: comm.clock().busy_until(),
            compute: comm.clock().compute_secs(),
            comm_wait: comm.clock().comm_wait_secs(),
            transfer: comm.clock().transfer_secs(),
            msgs: comm.stats().msgs_sent(),
            bytes: comm.stats().bytes_sent(),
            max_outstanding_reqs: comm.stats().max_outstanding_reqs(),
            wait_saved: (comm.stats().wait_saved_secs() - tail_backlog).max(0.0),
            pcie_saved_bytes: comm.stats().pcie_saved_bytes(),
            pcie_hidden_secs: (comm.stats().pcie_hidden_secs() - pcie_backlog).max(0.0),
            prefetch_hits: comm.stats().prefetch_hits(),
            wire_direct_bytes: comm.stats().wire_direct_bytes(),
            host_stage_saved_secs: comm.stats().host_stage_saved_secs(),
            launches_fused: comm.stats().launches_fused(),
            retries: comm.stats().retries(),
            timeout_secs: comm.stats().timeout_secs(),
            wall,
        }
    }

    /// Accumulate another capture of the *same rank* into this one
    /// (mixed-precision fallback: the failed narrow attempt ran first, so
    /// its bill is added to the wide re-run's — sequential composition).
    /// Every field is additive except `max_outstanding_reqs`, which is a
    /// peak.
    pub(crate) fn absorb(&mut self, other: &RankMetrics) {
        self.vtime += other.vtime;
        self.compute += other.compute;
        self.comm_wait += other.comm_wait;
        self.transfer += other.transfer;
        self.msgs += other.msgs;
        self.bytes += other.bytes;
        self.max_outstanding_reqs = self.max_outstanding_reqs.max(other.max_outstanding_reqs);
        self.wait_saved += other.wait_saved;
        self.pcie_saved_bytes += other.pcie_saved_bytes;
        self.pcie_hidden_secs += other.pcie_hidden_secs;
        self.prefetch_hits += other.prefetch_hits;
        self.wire_direct_bytes += other.wire_direct_bytes;
        self.host_stage_saved_secs += other.host_stage_saved_secs;
        self.launches_fused += other.launches_fused;
        self.retries += other.retries;
        self.timeout_secs += other.timeout_secs;
        self.wall += other.wall;
    }
}

/// Result of one distributed solve.
#[derive(Clone, Debug)]
pub struct SolveReport {
    /// Solver name ("LU", "BiCGSTAB", ...).
    pub method: &'static str,
    /// Workload solved.
    pub workload: Workload,
    /// Problem size.
    pub n: usize,
    /// Ranks used.
    pub ranks: usize,
    /// Local-compute arm.
    pub engine: EngineKind,
    /// Per-rank accounting.
    pub per_rank: Vec<RankMetrics>,
    /// Max abs error vs the workload's known solution.
    pub max_err: f64,
    /// (iterations, final relative residual, converged) for iterative runs.
    /// For a batch this is the worst column: max iterations, max residual,
    /// converged only if every column converged.
    pub iter_stats: Option<(usize, f64, bool)>,
    /// Right-hand sides solved together (1 for the single-RHS entry point).
    pub nrhs: usize,
    /// Per-request attribution: engine-priced virtual seconds summed over
    /// ranks, `nrhs + 1` buckets — one per right-hand side plus a final
    /// *shared* bucket (factorization, panel kernels, batched collectives).
    /// Empty when attribution was not enabled (single-RHS solves).
    pub attribution: Vec<f64>,
    /// Iterative-refinement correction sweeps the mixed-precision path
    /// applied (`DESIGN.md` §17); 0 for uniform-precision runs and for
    /// mixed Krylov (whose extra work is counted in `iter_stats`).
    pub refine_iters: usize,
    /// Payload bytes the reduced-precision storage kept off the wire:
    /// every message of the mixed run priced at the wide dtype minus what
    /// actually shipped.  Slight overcount — the refinement's few
    /// [`crate::comm::Payload::Hi`] legs are rated like storage traffic —
    /// and 0 for uniform runs and after a fallback (nothing was saved;
    /// the narrow attempt was re-done wide).
    pub bytes_saved_mixed: u64,
    /// The mixed-precision attempt missed its backward-error bound (or the
    /// narrow factorization broke down) and the solve re-ran at uniform
    /// precision; the per-rank metrics then include **both** runs — the
    /// honest price of the gamble.
    pub mixed_fallback: bool,
    /// The factorization was restored from the cross-request factor cache
    /// (serve layer): only the substitutions ran.
    pub factor_cached: bool,
}

impl SolveReport {
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn new(
        method: &'static str,
        workload: Workload,
        n: usize,
        ranks: usize,
        engine: EngineKind,
        per_rank: Vec<RankMetrics>,
        max_err: f64,
        iter_stats: Option<(usize, f64, bool)>,
    ) -> Self {
        SolveReport {
            method,
            workload,
            n,
            ranks,
            engine,
            per_rank,
            max_err,
            iter_stats,
            nrhs: 1,
            attribution: Vec::new(),
            refine_iters: 0,
            bytes_saved_mixed: 0,
            mixed_fallback: false,
            factor_cached: false,
        }
    }

    /// Attach batch metadata (builder-style, so single-RHS call sites stay
    /// untouched): the RHS count and the per-request attribution buckets.
    pub(crate) fn with_batch(mut self, nrhs: usize, attribution: Vec<f64>) -> Self {
        self.nrhs = nrhs;
        self.attribution = attribution;
        self
    }

    /// Attach mixed-precision metadata (builder-style): refinement sweeps,
    /// wire bytes saved, and whether the uniform fallback ran.
    pub(crate) fn with_mixed(
        mut self,
        refine_iters: usize,
        bytes_saved_mixed: u64,
        mixed_fallback: bool,
    ) -> Self {
        self.refine_iters = refine_iters;
        self.bytes_saved_mixed = bytes_saved_mixed;
        self.mixed_fallback = mixed_fallback;
        self
    }

    /// Mark the factorization as restored from the factor cache.
    pub(crate) fn with_factor_cached(mut self, cached: bool) -> Self {
        self.factor_cached = cached;
        self
    }

    /// Per-request virtual seconds: each request's own bucket plus an even
    /// share of the batch's shared bucket (the honest way to price an
    /// amortized factorization back to its beneficiaries).  Empty when
    /// attribution was off.
    pub fn per_request_secs(&self) -> Vec<f64> {
        if self.attribution.len() != self.nrhs + 1 {
            return Vec::new();
        }
        let share = self.attribution[self.nrhs] / self.nrhs as f64;
        (0..self.nrhs).map(|j| self.attribution[j] + share).collect()
    }

    /// Virtual-time makespan: max over rank clocks — what a real cluster's
    /// wall clock would have read.
    pub fn makespan(&self) -> f64 {
        self.per_rank.iter().map(|m| m.vtime).fold(0.0, f64::max)
    }

    /// Total virtual compute seconds across ranks.
    pub fn total_compute(&self) -> f64 {
        self.per_rank.iter().map(|m| m.compute).sum()
    }

    /// Total virtual transfer (PCIe) seconds across ranks.
    pub fn total_transfer(&self) -> f64 {
        self.per_rank.iter().map(|m| m.transfer).sum()
    }

    /// Mean fraction of makespan the ranks spent blocked on communication.
    pub fn comm_fraction(&self) -> f64 {
        let ms = self.makespan();
        if ms == 0.0 {
            return 0.0;
        }
        let mean_wait: f64 =
            self.per_rank.iter().map(|m| m.comm_wait).sum::<f64>() / self.per_rank.len() as f64;
        mean_wait / ms
    }

    /// Total messages sent.
    pub fn total_msgs(&self) -> u64 {
        self.per_rank.iter().map(|m| m.msgs).sum()
    }

    /// Total virtual seconds of latency hidden by split-phase overlap.
    pub fn total_wait_saved(&self) -> f64 {
        self.per_rank.iter().map(|m| m.wait_saved).sum()
    }

    /// Peak outstanding split-phase requests on any rank.
    pub fn max_outstanding_reqs(&self) -> u64 {
        self.per_rank.iter().map(|m| m.max_outstanding_reqs).max().unwrap_or(0)
    }

    /// Total payload bytes sent.
    pub fn total_bytes(&self) -> u64 {
        self.per_rank.iter().map(|m| m.bytes).sum()
    }

    /// Total PCIe bytes kept off the host<->device link by residency.
    pub fn total_pcie_saved(&self) -> u64 {
        self.per_rank.iter().map(|m| m.pcie_saved_bytes).sum()
    }

    /// Total virtual seconds of PCIe transfer hidden behind compute by the
    /// copy-engine timeline.
    pub fn total_pcie_hidden(&self) -> f64 {
        self.per_rank.iter().map(|m| m.pcie_hidden_secs).sum()
    }

    /// Total operand accesses served by an in-flight async prefetch.
    pub fn total_prefetch_hits(&self) -> u64 {
        self.per_rank.iter().map(|m| m.prefetch_hits).sum()
    }

    /// Total payload bytes sent straight off the device (GPUDirect wire).
    pub fn total_wire_direct(&self) -> u64 {
        self.per_rank.iter().map(|m| m.wire_direct_bytes).sum()
    }

    /// Total virtual seconds of send-site host staging the GPUDirect wire
    /// avoided.
    pub fn total_host_stage_saved(&self) -> f64 {
        self.per_rank.iter().map(|m| m.host_stage_saved_secs).sum()
    }

    /// Total kernel launches eliminated by fused BLAS-1 ops.
    pub fn total_launches_fused(&self) -> u64 {
        self.per_rank.iter().map(|m| m.launches_fused).sum()
    }

    /// Total sends retransmitted after scripted drops (fault plan).
    pub fn total_retries(&self) -> u64 {
        self.per_rank.iter().map(|m| m.retries).sum()
    }

    /// Total virtual seconds spent in retry timeouts across ranks.
    pub fn total_timeout_secs(&self) -> f64 {
        self.per_rank.iter().map(|m| m.timeout_secs).sum()
    }

    /// Max wall-clock across ranks (the real elapsed time of the run).
    pub fn wall_max(&self) -> f64 {
        self.per_rank.iter().map(|m| m.wall).fold(0.0, f64::max)
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        let iter = match self.iter_stats {
            Some((it, res, conv)) => {
                format!(", {it} iters, res {res:.2e}{}", if conv { "" } else { " (no conv)" })
            }
            None => String::new(),
        };
        let faults = if self.total_retries() > 0 {
            format!(
                ", retries {} ({} timeout)",
                self.total_retries(),
                crate::util::fmt::secs(self.total_timeout_secs())
            )
        } else {
            String::new()
        };
        let mixed = if self.mixed_fallback {
            format!(", mixed fallback after {} sweeps", self.refine_iters)
        } else {
            format!(
                ", mixed saved {} ({} refine)",
                crate::util::fmt::bytes(self.bytes_saved_mixed as f64),
                self.refine_iters
            )
        };
        format!(
            "{} on {:?} n={} P={} [{}]: makespan {}, err {:.2e}, comm {:.0}%, \
             hidden {}, reqs<={}, pcie saved {}, pcie hidden {}, prefetch hits {}, \
             wire direct {}, stage saved {}, fused {}{}{}{}{}",
            self.method,
            self.workload,
            self.n,
            self.ranks,
            self.engine.label(),
            crate::util::fmt::secs(self.makespan()),
            self.max_err,
            self.comm_fraction() * 100.0,
            crate::util::fmt::secs(self.total_wait_saved()),
            self.max_outstanding_reqs(),
            crate::util::fmt::bytes(self.total_pcie_saved() as f64),
            crate::util::fmt::secs(self.total_pcie_hidden()),
            self.total_prefetch_hits(),
            crate::util::fmt::bytes(self.total_wire_direct() as f64),
            crate::util::fmt::secs(self.total_host_stage_saved()),
            self.total_launches_fused(),
            faults,
            mixed,
            if self.factor_cached { ", factor cached" } else { "" },
            iter
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(vtime: f64, compute: f64, wait: f64) -> RankMetrics {
        RankMetrics {
            rank: 0,
            vtime,
            compute,
            comm_wait: wait,
            transfer: 0.0,
            msgs: 10,
            bytes: 100,
            max_outstanding_reqs: 3,
            wait_saved: 0.25,
            pcie_saved_bytes: 1024,
            pcie_hidden_secs: 0.125,
            prefetch_hits: 5,
            wire_direct_bytes: 512,
            host_stage_saved_secs: 0.0625,
            launches_fused: 7,
            retries: 2,
            timeout_secs: 0.003,
            wall: 0.01,
        }
    }

    #[test]
    fn makespan_is_max() {
        let r = SolveReport::new(
            "LU",
            Workload::Spd,
            64,
            2,
            EngineKind::CpuSerial,
            vec![mk(1.0, 0.8, 0.1), mk(2.0, 1.5, 0.5)],
            1e-12,
            None,
        );
        assert_eq!(r.makespan(), 2.0);
        assert!((r.total_compute() - 2.3).abs() < 1e-12);
        assert!((r.comm_fraction() - 0.15).abs() < 1e-12);
        assert_eq!(r.total_msgs(), 20);
        assert!((r.total_wait_saved() - 0.5).abs() < 1e-12);
        assert_eq!(r.max_outstanding_reqs(), 3);
        assert_eq!(r.total_pcie_saved(), 2048);
        assert!((r.total_pcie_hidden() - 0.25).abs() < 1e-12);
        assert_eq!(r.total_prefetch_hits(), 10);
        assert_eq!(r.total_wire_direct(), 1024);
        assert!((r.total_host_stage_saved() - 0.125).abs() < 1e-12);
        assert_eq!(r.total_launches_fused(), 14);
        assert_eq!(r.total_retries(), 4);
        assert!((r.total_timeout_secs() - 0.006).abs() < 1e-12);
        assert!(r.summary().contains("LU"));
        assert!(r.summary().contains("hidden"));
        assert!(r.summary().contains("pcie saved"));
        assert!(r.summary().contains("pcie hidden"));
        assert!(r.summary().contains("prefetch hits"));
        assert!(r.summary().contains("wire direct"));
        assert!(r.summary().contains("stage saved"));
        assert!(r.summary().contains("mixed saved"));
    }

    #[test]
    fn mixed_builder_and_summary_variants() {
        let base = SolveReport::new(
            "LU",
            Workload::Spd,
            64,
            1,
            EngineKind::CpuSerial,
            vec![mk(1.0, 0.8, 0.1)],
            1e-12,
            None,
        );
        assert_eq!(base.refine_iters, 0);
        assert_eq!(base.bytes_saved_mixed, 0);
        assert!(!base.mixed_fallback && !base.factor_cached);
        let mixed = base.clone().with_mixed(3, 4096, false);
        assert_eq!(mixed.refine_iters, 3);
        assert_eq!(mixed.bytes_saved_mixed, 4096);
        assert!(mixed.summary().contains("3 refine"));
        let fell = base.clone().with_mixed(10, 0, true);
        assert!(fell.mixed_fallback);
        assert!(fell.summary().contains("mixed fallback after 10 sweeps"));
        let cached = base.with_factor_cached(true);
        assert!(cached.factor_cached);
        assert!(cached.summary().contains("factor cached"));
    }

    #[test]
    fn per_request_secs_shares_the_common_bucket_evenly() {
        let r = SolveReport::new(
            "LU",
            Workload::Spd,
            64,
            2,
            EngineKind::CpuSerial,
            vec![mk(1.0, 0.8, 0.1)],
            1e-12,
            None,
        );
        assert_eq!(r.nrhs, 1);
        assert!(r.attribution.is_empty() && r.per_request_secs().is_empty());
        let r = r.with_batch(2, vec![0.5, 0.3, 4.0]);
        let per = r.per_request_secs();
        assert_eq!(per.len(), 2);
        assert!((per[0] - 2.5).abs() < 1e-12 && (per[1] - 2.3).abs() < 1e-12);
        // The split is conservative: buckets sum to the attributed total.
        assert!((per.iter().sum::<f64>() - 4.8).abs() < 1e-12);
    }
}
