//! BiConjugate Gradients — the paper's §2: "BiCG generates two mutually
//! orthogonal sequences of residual vectors... performed using the system's
//! matrix and its transpose."  The transpose sequence uses the operator's
//! `apply_t` — [`crate::pblas::pgemv_t`] (dense: the 2-D layout's
//! column-reduce/row-allgather path) or [`crate::pblas::pspmv_t`] (sparse:
//! local transpose matvec + column allreduce).
//!
//! The BLAS-1 chain runs on the **fused** kernels (`DESIGN.md` §12), like
//! CG/PipeCG/BiCGSTAB: the residual update fuses with its norm *and* the
//! next `rho = <r~, r>` into one [`pfused_axpy_norm2_dot`] (one kernel, one
//! two-lane allreduce where the unfused chain paid two scalar reductions),
//! and both direction recurrences collapse to one [`pxpay`] pass each.
//! Every scalar is bit-identical to the unfused sequence's: the shadow
//! residual is updated first (the two updates are independent, so the
//! values cannot differ), the fused lanes are the same dots in the same
//! order, and `xpay` re-associates nothing (`x + beta*y` multiplies then
//! adds exactly like scal-then-axpy).

use super::{norm_negligible, IterConfig, IterStats};
use crate::dist::DistVector;
use crate::pblas::{paxpy, pdot, pfused_axpy_norm2_dot, pnorm2, pxpay, Ctx, LinOp};
use crate::{Error, Result, Scalar};

/// Solve `A x = b` (general nonsymmetric) from the zero initial guess.
/// `A` is any [`LinOp`]; the transpose sequence uses its `apply_t`.
pub fn bicg<S: Scalar, A: LinOp<S> + ?Sized>(
    ctx: &Ctx<'_, S>,
    a: &A,
    b: &DistVector<S>,
    cfg: &IterConfig,
) -> Result<(DistVector<S>, IterStats<S>)> {
    let desc = *a.desc();
    let mesh = ctx.mesh;
    let bnorm = pnorm2(ctx, b);
    let mut x = DistVector::zeros(desc, mesh.row(), mesh.col());
    if norm_negligible(bnorm, desc.m) {
        return Ok((x, IterStats::new(0, S::zero(), true)));
    }
    let tol = S::from_f64(cfg.tol).unwrap() * bnorm;

    let mut r = b.clone_vec();
    let mut rt = b.clone_vec(); // shadow residual (r~ = r0 is the usual choice)
    let mut p = r.clone_vec();
    let mut pt = rt.clone_vec();
    let mut rho = pdot(ctx, &rt, &r);

    for it in 0..cfg.max_iter {
        if rho == S::zero() {
            return Err(Error::Breakdown {
                method: "bicg",
                detail: format!("rho = 0 at iteration {it}"),
            });
        }
        let ap = a.apply(ctx, &p);
        let atpt = a.apply_t(ctx, &pt);
        let ptap = pdot(ctx, &pt, &ap);
        if ptap == S::zero() {
            return Err(Error::Breakdown {
                method: "bicg",
                detail: format!("pt^T A p = 0 at iteration {it}"),
            });
        }
        let alpha = rho / ptap;
        paxpy(ctx, alpha, &p, &mut x);
        // The shadow residual first (independent of r, so reordering ahead
        // of the r update cannot change any value), then the r update
        // fused with ||r||^2 and the next rho = <r~, r> — one kernel and
        // one two-lane allreduce instead of an axpy plus two scalar dots.
        paxpy(ctx, -alpha, &atpt, &mut rt);
        let (rr, rho_new) = pfused_axpy_norm2_dot(ctx, -alpha, &ap, &mut r, &rt);
        let rnorm = rr.sqrt();
        if rnorm <= tol {
            return Ok((x, IterStats::new(it + 1, rnorm / bnorm, true)));
        }
        let beta = rho_new / rho;
        rho = rho_new;
        // p = r + beta p ; pt = rt + beta pt — one fused pass each.
        pxpay(ctx, beta, &r, &mut p);
        pxpay(ctx, beta, &rt, &mut pt);
    }
    let rnorm = pnorm2(ctx, &r);
    Ok((x, IterStats::new(cfg.max_iter, rnorm / bnorm, false)))
}
