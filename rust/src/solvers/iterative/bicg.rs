//! BiConjugate Gradients — the paper's §2: "BiCG generates two mutually
//! orthogonal sequences of residual vectors... performed using the system's
//! matrix and its transpose."  The transpose sequence uses the operator's
//! `apply_t` — [`crate::pblas::pgemv_t`] (dense: the 2-D layout's
//! column-reduce/row-allgather path) or [`crate::pblas::pspmv_t`] (sparse:
//! local transpose matvec + column allreduce).

use super::{norm_negligible, IterConfig, IterStats};
use crate::dist::DistVector;
use crate::pblas::{paxpy, pdot, pnorm2, pscal, Ctx, LinOp};
use crate::{Error, Result, Scalar};

/// Solve `A x = b` (general nonsymmetric) from the zero initial guess.
/// `A` is any [`LinOp`]; the transpose sequence uses its `apply_t`.
pub fn bicg<S: Scalar, A: LinOp<S> + ?Sized>(
    ctx: &Ctx<'_, S>,
    a: &A,
    b: &DistVector<S>,
    cfg: &IterConfig,
) -> Result<(DistVector<S>, IterStats<S>)> {
    let desc = *a.desc();
    let mesh = ctx.mesh;
    let bnorm = pnorm2(ctx, b);
    let mut x = DistVector::zeros(desc, mesh.row(), mesh.col());
    if norm_negligible(bnorm, desc.m) {
        return Ok((x, IterStats::new(0, S::zero(), true)));
    }
    let tol = S::from_f64(cfg.tol).unwrap() * bnorm;

    let mut r = b.clone_vec();
    let mut rt = b.clone_vec(); // shadow residual (r~ = r0 is the usual choice)
    let mut p = r.clone_vec();
    let mut pt = rt.clone_vec();
    let mut rho = pdot(ctx, &rt, &r);

    for it in 0..cfg.max_iter {
        if rho == S::zero() {
            return Err(Error::Breakdown {
                method: "bicg",
                detail: format!("rho = 0 at iteration {it}"),
            });
        }
        let ap = a.apply(ctx, &p);
        let atpt = a.apply_t(ctx, &pt);
        let ptap = pdot(ctx, &pt, &ap);
        if ptap == S::zero() {
            return Err(Error::Breakdown {
                method: "bicg",
                detail: format!("pt^T A p = 0 at iteration {it}"),
            });
        }
        let alpha = rho / ptap;
        paxpy(ctx, alpha, &p, &mut x);
        paxpy(ctx, -alpha, &ap, &mut r);
        paxpy(ctx, -alpha, &atpt, &mut rt);
        let rnorm = pnorm2(ctx, &r);
        if rnorm <= tol {
            return Ok((x, IterStats::new(it + 1, rnorm / bnorm, true)));
        }
        let rho_new = pdot(ctx, &rt, &r);
        let beta = rho_new / rho;
        rho = rho_new;
        // p = r + beta p ; pt = rt + beta pt
        pscal(ctx, beta, &mut p);
        paxpy(ctx, S::one(), &r, &mut p);
        pscal(ctx, beta, &mut pt);
        paxpy(ctx, S::one(), &rt, &mut pt);
    }
    let rnorm = pnorm2(ctx, &r);
    Ok((x, IterStats::new(cfg.max_iter, rnorm / bnorm, false)))
}
