//! Jacobi (diagonal) preconditioning — an extension beyond the paper's
//! solver set (its future-work direction is richer preconditioning; the
//! diagonal scaler is the natural first step and exercises the same
//! distributed plumbing).
//!
//! Rather than threading M^{-1} through every solver, the preconditioner
//! *transforms the system*: solve `(D^{-1/2} A D^{-1/2}) (D^{1/2} x) =
//! D^{-1/2} b` — symmetric scaling that preserves SPD-ness for CG.

use crate::dist::{DistMatrix, DistVector};
use crate::pblas::Ctx;
use crate::Scalar;

/// Symmetric Jacobi scaling of a distributed system.
pub struct JacobiPrecond<S: Scalar> {
    /// d[i] = 1/sqrt(|A[i,i]|), replicated like a distributed vector.
    dinv_sqrt: DistVector<S>,
}

impl<S: Scalar> JacobiPrecond<S> {
    /// Extract the diagonal of `a` and build the scaler.  The diagonal tiles
    /// live on the mesh diagonal; each owner broadcasts its block along its
    /// process row, then the standard vector layout is assembled locally.
    pub fn build(ctx: &Ctx<'_, S>, a: &DistMatrix<S>) -> Self {
        let desc = *a.desc();
        let t = desc.tile;
        let mesh = ctx.mesh;
        let row = mesh.row_comm();
        let mut dinv = DistVector::zeros(desc, mesh.row(), mesh.col());
        for l in 0..dinv.local_blocks() {
            let ti = desc.global_ti(mesh.row(), l);
            let owner_col = ti % desc.shape.pc;
            let data = if mesh.col() == owner_col {
                let tile = a.global_tile(ti, ti);
                let mut d = vec![S::zero(); t];
                for i in 0..t {
                    d[i] = tile[i * t + i];
                }
                Some(crate::comm::Payload::Data(d))
            } else {
                None
            };
            let d = row.bcast(owner_col, 5_000 + ti as u32, data).into_data();
            let blk = dinv.block_mut(l);
            for i in 0..t {
                let v = d[i].abs();
                blk[i] = if v > S::zero() { S::one() / v.sqrt() } else { S::one() };
            }
        }
        JacobiPrecond { dinv_sqrt: dinv }
    }

    /// Scale the matrix in place: `A := D^{-1/2} A D^{-1/2}`.
    pub fn scale_matrix(&self, ctx: &Ctx<'_, S>, a: &mut DistMatrix<S>) {
        let desc = *a.desc();
        let t = desc.tile;
        let mesh = ctx.mesh;
        // Row scaling needs d for owned tile rows (local); column scaling
        // needs d for owned tile cols (allgather over the column comm, same
        // pattern as pgemv's x distribution).
        let mut mine = Vec::new();
        for l in 0..self.dinv_sqrt.local_blocks() {
            mine.extend_from_slice(self.dinv_sqrt.block(l));
        }
        let col = mesh.col_comm();
        let by_row = col.allgather(5_100, mine);
        for (lti, ltj, ti, tj) in a.owned_tiles().collect::<Vec<_>>() {
            let drow = self.dinv_sqrt.global_block(ti).to_vec();
            let owner = tj % desc.shape.pr;
            let off = desc.local_ti(tj) * t;
            let dcol = by_row[owner][off..off + t].to_vec();
            let tile = a.tile_mut(lti, ltj);
            for i in 0..t {
                for j in 0..t {
                    tile[i * t + j] *= drow[i] * dcol[j];
                }
            }
            ctx.charge(ctx.engine.blas1_cost(t * t));
        }
    }

    /// Scale a rhs: `b := D^{-1/2} b`.
    pub fn scale_rhs(&self, ctx: &Ctx<'_, S>, b: &mut DistVector<S>) {
        for l in 0..b.local_blocks() {
            let d = self.dinv_sqrt.block(l).to_vec();
            let blk = b.block_mut(l);
            for i in 0..blk.len() {
                blk[i] *= d[i];
            }
            ctx.charge(ctx.engine.blas1_cost(blk.len()));
        }
    }

    /// Recover the original unknowns: `x := D^{-1/2} x_scaled`.
    pub fn unscale_solution(&self, ctx: &Ctx<'_, S>, x: &mut DistVector<S>) {
        // (D^{1/2} x) was solved for, so x = D^{-1/2} x_scaled.
        self.scale_rhs(ctx, x);
    }
}
