//! Preconditioning — an extension beyond the paper's solver set (its
//! future-work direction is richer preconditioning; the diagonal scaler
//! is the natural first step and exercises the same distributed
//! plumbing).  Two flavors live here:
//!
//! * [`JacobiPrecond`] — symmetric diagonal scaling (transforms the
//!   system once, solvers run unmodified);
//! * [`BlockJacobiPrecond`] — zero-overlap additive Schwarz over the
//!   sparse row-block distribution: `M^{-1}` applies each rank's owned
//!   diagonal block inverse (by a communication-free local CG), consumed
//!   through the [`Preconditioner`] trait by [`crate::solvers::pcg`]
//!   (`DESIGN.md` §15).
//!
//! Rather than threading M^{-1} through every solver, the preconditioner
//! *transforms the system*: solve `(D^{-1/2} A D^{-1/2}) (D^{1/2} x) =
//! D^{-1/2} b` — symmetric scaling that preserves SPD-ness for CG.
//!
//! Operator-generic: [`JacobiPrecond::build`] works on any
//! [`LinOp`] — the dense path broadcasts diagonal tiles along process
//! rows, the sparse path reads its locally owned rows (see
//! [`LinOp::extract_diag`] and `DESIGN.md` §10).
//!
//! Guards: a diagonal entry that is zero, non-finite, or below the
//! underflow threshold — and every *padded* position (global index ≥ `m`,
//! identity `1` for dense operands, structural zero for sparse ones) —
//! keeps scale `1` instead of emitting an `inf`/overflowed `1/sqrt(d)`
//! that would poison every row it touches.  Keeping padded scales at `1`
//! also preserves the dense identity-padding invariant through
//! [`LinOp::scale_sym`].

use super::schur::local_cg;
use super::IterConfig;
use crate::dist::DistVector;
use crate::pblas::{tags, Ctx, LinOp};
use crate::sparse::{CsrMatrix, DistCsrMatrix};
use crate::{Result, Scalar};

/// An application-form preconditioner: `z = M^{-1} r`.
///
/// Unlike [`JacobiPrecond`] (which rescales the system once up front),
/// these are consumed *inside* the iteration — see
/// [`crate::solvers::pcg`].  `apply` must be a fixed linear SPD operator
/// for PCG's recurrences to hold; inexact inner solves should therefore
/// run to a tolerance well below the outer solver's.
pub trait Preconditioner<S: Scalar> {
    /// Apply `M^{-1}` to a residual (column-replicated, like every vector
    /// in the crate: replicas compute identically).
    fn apply(&self, ctx: &Ctx<'_, S>, r: &DistVector<S>) -> Result<DistVector<S>>;
}

/// Zero-overlap additive Schwarz (block Jacobi) over the sparse row-block
/// distribution: `M = diag(A_1, ..., A_pr)` where `A_k` is process row
/// `k`'s owned diagonal block — exactly the halo plan's `diag_local`
/// compact half, so the subdomains are the distribution's own partition
/// and applying `M^{-1}` needs **zero communication**: each rank runs a
/// local CG on its own block.
///
/// Padded positions are empty rows with zero right-hand sides; the local
/// CG keeps them exactly zero (zero columns never receive mass), so the
/// zero-padding invariant survives without special-casing.
pub struct BlockJacobiPrecond<S: Scalar> {
    /// This rank's owned diagonal block (square: the row-block layout
    /// owns matching row and column tiles).
    block: CsrMatrix<S>,
    /// Local-solve controls (tolerance should undercut the outer tol).
    inner: IterConfig,
}

impl<S: Scalar> BlockJacobiPrecond<S> {
    /// Snapshot `a`'s owned diagonal block (building the halo plan if not
    /// already cached — first use is collective over the column comm).
    pub fn build(ctx: &Ctx<'_, S>, a: &DistCsrMatrix<S>, inner: IterConfig) -> Self {
        let col = ctx.mesh.col_comm();
        let plan = a.halo_plan(&col, tags::HALO_PLAN);
        let block = plan.diag_local.clone();
        assert_eq!(
            block.nrows(),
            block.ncols(),
            "row-block diagonal block must be square (owned rows == owned cols)"
        );
        BlockJacobiPrecond { block, inner }
    }

    /// The local block (inspection / tests).
    pub fn block(&self) -> &CsrMatrix<S> {
        &self.block
    }
}

impl<S: Scalar> Preconditioner<S> for BlockJacobiPrecond<S> {
    fn apply(&self, ctx: &Ctx<'_, S>, r: &DistVector<S>) -> Result<DistVector<S>> {
        let desc = *r.desc();
        let t = desc.tile;
        let mut rloc = Vec::with_capacity(r.local_blocks() * t);
        for l in 0..r.local_blocks() {
            rloc.extend_from_slice(r.block(l));
        }
        let (zloc, _iters) = local_cg(ctx, &self.block, &rloc, &self.inner)?;
        let mesh = ctx.mesh;
        let mut z = DistVector::zeros(desc, mesh.row(), mesh.col());
        for l in 0..z.local_blocks() {
            z.block_mut(l).copy_from_slice(&zloc[l * t..(l + 1) * t]);
        }
        Ok(z)
    }
}

/// Symmetric Jacobi scaling of a distributed system.
pub struct JacobiPrecond<S: Scalar> {
    /// d[i] = 1/sqrt(|A[i,i]|) (or 1 where unscalable), in the standard
    /// row-distributed / column-replicated vector layout.
    dinv_sqrt: DistVector<S>,
}

impl<S: Scalar> JacobiPrecond<S> {
    /// Extract the diagonal of `a` and build the scaler.
    pub fn build<A: LinOp<S> + ?Sized>(ctx: &Ctx<'_, S>, a: &A) -> Self {
        let desc = *a.desc();
        let t = desc.tile;
        let mesh = ctx.mesh;
        let diag = a.extract_diag(ctx);
        let mut dinv = DistVector::zeros(desc, mesh.row(), mesh.col());
        for l in 0..dinv.local_blocks() {
            let ti = desc.global_ti(mesh.row(), l);
            let src = diag.block(l).to_vec();
            let blk = dinv.block_mut(l);
            for k in 0..t {
                let gi = ti * t + k;
                let v = src[k].abs();
                // Padded rows and zero / subnormal / non-finite diagonal
                // entries are unscalable: keep scale 1.
                blk[k] = if gi < desc.m && v.is_finite() && v >= S::min_positive_value() {
                    S::one() / v.sqrt()
                } else {
                    S::one()
                };
            }
        }
        JacobiPrecond { dinv_sqrt: dinv }
    }

    /// Scale the operator in place: `A := D^{-1/2} A D^{-1/2}`.
    pub fn scale_matrix<A: LinOp<S> + ?Sized>(&self, ctx: &Ctx<'_, S>, a: &mut A) {
        a.scale_sym(ctx, &self.dinv_sqrt);
    }

    /// Scale a rhs: `b := D^{-1/2} b`.
    pub fn scale_rhs(&self, ctx: &Ctx<'_, S>, b: &mut DistVector<S>) {
        for l in 0..b.local_blocks() {
            let d = self.dinv_sqrt.block(l).to_vec();
            let blk = b.block_mut(l);
            for i in 0..blk.len() {
                blk[i] *= d[i];
            }
            ctx.charge(ctx.engine.blas1_cost(blk.len()));
        }
    }

    /// Recover the original unknowns: `x := D^{-1/2} x_scaled`.
    pub fn unscale_solution(&self, ctx: &Ctx<'_, S>, x: &mut DistVector<S>) {
        // (D^{1/2} x) was solved for, so x = D^{-1/2} x_scaled.
        self.scale_rhs(ctx, x);
    }

    /// The scale vector (inspection / tests).
    pub fn dinv_sqrt(&self) -> &DistVector<S> {
        &self.dinv_sqrt
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::CpuEngine;
    use crate::comm::{NetworkModel, World};
    use crate::dist::{gather_vector, Descriptor, DistMatrix};
    use crate::mesh::{Mesh, MeshShape};
    use crate::pblas::pdot;
    use crate::solvers::{cg, IterConfig};
    use crate::sparse::DistCsrMatrix;
    use std::sync::Arc;

    /// Badly scaled SPD elements: diagonal spans 8 orders of magnitude.
    fn skewed_elem(n: usize) -> impl Fn(usize, usize) -> f64 + Clone + Send + Sync {
        move |i, j| {
            let di = 10f64.powi((i % 9) as i32 - 4);
            let dj = 10f64.powi((j % 9) as i32 - 4);
            if i == j {
                di * dj * 2.0 * n as f64
            } else {
                let sym = ((((i * 37 + j * 61) + (j * 37 + i * 61)) % 97) as f64) / 97.0 - 1.0;
                di * dj * 0.5 * sym
            }
        }
    }

    /// Non-divisible n (edge-tile padding) on non-square meshes: the
    /// extract-diagonal path must read the right diagonal tiles, padded
    /// scales must stay exactly 1, and the scaled system must still solve.
    #[test]
    fn build_and_solve_with_edge_tile_padding() {
        let n = 11usize; // tile 4 -> mt = 3, last tile padded
        for (pr, pc) in [(1usize, 1usize), (2, 2), (2, 3), (3, 2)] {
            let out = World::run::<f64, _, _>(pr * pc, NetworkModel::ideal(), move |comm| {
                let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
                let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(4)));
                let desc = Descriptor::new(n, n, 4, mesh.shape());
                let mut a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), skewed_elem(n));
                let xt = |i: usize| (i as f64 * 0.21).sin() + 1.0;
                let elem = skewed_elem(n);
                let mut b = DistVector::from_fn(desc, mesh.row(), mesh.col(), |i| {
                    (0..n).map(|j| elem(i, j) * xt(j)).sum()
                });
                let pre = JacobiPrecond::build(&ctx, &a);
                // Every scale must be finite; padded positions exactly 1.
                let scales = gather_vector(&mesh, pre.dinv_sqrt());
                let pad_ok = {
                    let mut ok = true;
                    for l in 0..pre.dinv_sqrt().local_blocks() {
                        let ti = desc.global_ti(mesh.row(), l);
                        for (k, &s) in pre.dinv_sqrt().block(l).iter().enumerate() {
                            if ti * 4 + k >= n {
                                ok &= s == 1.0;
                            }
                            ok &= s.is_finite() && s > 0.0;
                        }
                    }
                    ok
                };
                pre.scale_matrix(&ctx, &mut a);
                pre.scale_rhs(&ctx, &mut b);
                let cfg = IterConfig { tol: 1e-12, max_iter: 500, restart: 30 };
                let (mut x, st) = cg(&ctx, &a, &b, &cfg).expect("cg on scaled system");
                pre.unscale_solution(&ctx, &mut x);
                (gather_vector(&mesh, &x), scales, pad_ok, st.converged)
            });
            let (x, _scales, pad_ok, converged) = out[0].clone();
            assert!(pad_ok, "{pr}x{pc}: padded/zero scales must be finite 1s");
            assert!(converged, "{pr}x{pc}: scaled CG must converge");
            let x = x.unwrap();
            for (i, &xi) in x.iter().enumerate() {
                let want = (i as f64 * 0.21).sin() + 1.0;
                assert!((xi - want).abs() < 1e-6, "{pr}x{pc} x[{i}] = {xi} vs {want}");
            }
        }
    }

    /// A zero (stored or structural) diagonal entry must not emit an inf
    /// scale, on either operand format.
    #[test]
    fn zero_diagonal_entries_keep_scale_one() {
        let n = 6usize;
        let out = World::run::<f64, _, _>(2, NetworkModel::ideal(), move |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(2, 1));
            let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(4)));
            let desc = Descriptor::new(n, n, 4, mesh.shape());
            // Dense: row 2 has an exactly-zero diagonal entry.
            let a = DistMatrix::from_fn(desc, mesh.row(), mesh.col(), |i, j| {
                if i == j && i == 2 {
                    0.0
                } else if i == j {
                    4.0
                } else {
                    0.0
                }
            });
            // Sparse: row 3's diagonal is structurally absent.
            let s = DistCsrMatrix::from_row_fn(desc, mesh.row(), mesh.col(), |i| {
                if i == 3 {
                    vec![]
                } else {
                    vec![(i, 4.0)]
                }
            });
            let pa = JacobiPrecond::build(&ctx, &a);
            let ps = JacobiPrecond::build(&ctx, &s);
            // All-finite check via a dot with itself (inf would propagate).
            let fa = pdot(&ctx, pa.dinv_sqrt(), pa.dinv_sqrt());
            let fs = pdot(&ctx, ps.dinv_sqrt(), ps.dinv_sqrt());
            (gather_vector(&mesh, pa.dinv_sqrt()), gather_vector(&mesh, ps.dinv_sqrt()), fa, fs)
        });
        let (da, ds, fa, fs) = out[0].clone();
        assert!(fa.is_finite() && fs.is_finite());
        let (da, ds) = (da.unwrap(), ds.unwrap());
        assert_eq!(da[2], 1.0, "zero dense diagonal keeps scale 1: {da:?}");
        assert_eq!(ds[3], 1.0, "missing sparse diagonal keeps scale 1: {ds:?}");
        assert!((da[0] - 0.5).abs() < 1e-15, "normal entries scale: {da:?}");
    }

    /// Block-Jacobi PCG: communication-free preconditioner applications,
    /// the same answer as plain CG at the same tolerance, and no more
    /// iterations (M captures every intra-block coupling).
    #[test]
    fn block_jacobi_pcg_matches_cg() {
        use crate::solvers::iterative::pcg;
        let n = 37usize; // ragged edge tile on pr = 2, tile 4
        let rows = move |i: usize| {
            let mut r = vec![(i, 6.0 + ((i * 3) % 4) as f64)];
            if i + 1 < n {
                r.push((i + 1, -1.0));
            }
            if i >= 1 {
                r.push((i - 1, -1.0));
            }
            r
        };
        for (pr, pc) in [(1usize, 1usize), (2, 1), (2, 2)] {
            let out = World::run::<f64, _, _>(pr * pc, NetworkModel::ideal(), move |comm| {
                let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
                let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(4)));
                let desc = Descriptor::new(n, n, 4, mesh.shape());
                let a = DistCsrMatrix::from_row_fn(desc, mesh.row(), mesh.col(), rows);
                let b = DistVector::from_fn(desc, mesh.row(), mesh.col(), |i| {
                    (i as f64 * 0.61).cos() + 2.0
                });
                let cfg = IterConfig { tol: 1e-10, max_iter: 400, restart: 30 };
                let inner = IterConfig { tol: 1e-13, max_iter: 400, restart: 30 };
                let m = BlockJacobiPrecond::build(&ctx, &a, inner);
                // Preconditioner applications are communication-free.
                let r0 = b.clone_vec();
                let before = comm.stats().bytes_sent();
                let _ = m.apply(&ctx, &r0).expect("block-jacobi apply");
                let precond_bytes = comm.stats().bytes_sent() - before;
                let (xp, sp) = pcg(&ctx, &a, &m, &b, &cfg).expect("pcg");
                let (xc, sc) = cg(&ctx, &a, &b, &cfg).expect("cg");
                (gather_vector(&mesh, &xp), gather_vector(&mesh, &xc), sp, sc, precond_bytes)
            });
            for (xp, xc, sp, sc, precond_bytes) in out {
                assert_eq!(precond_bytes, 0, "{pr}x{pc}: M^-1 must not communicate");
                assert!(sp.converged && sc.converged, "{pr}x{pc}: both must converge");
                assert!(
                    sp.iterations <= sc.iterations,
                    "{pr}x{pc}: PCG ({}) must not exceed CG ({})",
                    sp.iterations,
                    sc.iterations
                );
                let (xp, xc) = (xp.unwrap(), xc.unwrap());
                for i in 0..n {
                    assert!((xp[i] - xc[i]).abs() < 1e-7, "{pr}x{pc} x[{i}]: {} vs {}", xp[i], xc[i]);
                }
            }
        }
    }
}
