//! Conjugate gradients — the classical Krylov method the paper's §2 builds
//! from ("one of the most used Krylov methods... solves SPD systems").
//!
//! Operator-generic: `A` is any [`LinOp`] — dense block-cyclic or sparse
//! row-block CSR (`DESIGN.md` §10).
//!
//! The per-iteration BLAS-1 chain runs on the **fused** kernels
//! (`DESIGN.md` §12): the residual update + norm collapse into one
//! [`pfused_axpy_norm2`] and the `p = r + beta p` recurrence into one
//! [`pxpay`] — same arithmetic bit for bit, 3 memory passes and a
//! launch-per-block fewer each iteration.

use super::precond::Preconditioner;
use super::{norm_negligible, restore_vec, snapshot_vecs, IterConfig, IterStats};
use crate::comm::CheckpointPolicy;
use crate::dist::DistVector;
use crate::pblas::{fault_probe, paxpy, pdot, pfused_axpy_norm2, pnorm2, pxpay, Ctx, LinOp};
use crate::{Error, Result, Scalar};

/// Solve `A x = b` (A SPD) from the zero initial guess.
pub fn cg<S: Scalar, A: LinOp<S> + ?Sized>(
    ctx: &Ctx<'_, S>,
    a: &A,
    b: &DistVector<S>,
    cfg: &IterConfig,
) -> Result<(DistVector<S>, IterStats<S>)> {
    cg_ft(ctx, a, b, cfg, None)
}

/// [`cg`] with snapshot-restart fault tolerance.  Every
/// `snap.every_k_panels` iterations the recurrence state `(x, r, p, rr)` is
/// snapshotted — pricing the D2H leg of every device-dirty block, nothing
/// else — and when the cluster fault plan schedules a rank crash, the
/// collective probe at the next boundary detects it and **all** ranks roll
/// back to the last snapshot: a fault costs at most `k` replayed iterations
/// plus the snapshot traffic.  With `snap = None` and no crash scheduled
/// this is bit-identical to the un-instrumented loop; a crash with no
/// policy is an honest [`Error::Runtime`] on every rank.
pub fn cg_ft<S: Scalar, A: LinOp<S> + ?Sized>(
    ctx: &Ctx<'_, S>,
    a: &A,
    b: &DistVector<S>,
    cfg: &IterConfig,
    snap: Option<CheckpointPolicy>,
) -> Result<(DistVector<S>, IterStats<S>)> {
    let desc = *a.desc();
    let mesh = ctx.mesh;
    let bnorm = pnorm2(ctx, b);
    let mut x = DistVector::zeros(desc, mesh.row(), mesh.col());
    if norm_negligible(bnorm, desc.m) {
        return Ok((x, IterStats::new(0, S::zero(), true)));
    }
    let tol = S::from_f64(cfg.tol).unwrap() * bnorm;

    let mut r = b.clone_vec();
    let mut p = r.clone_vec();
    let mut rr = pdot(ctx, &r, &r);

    let probing = mesh.comm().fault_plan().has_crashes();
    let every = snap.map(|c| c.every_k_panels.max(1));
    let mut saved: Option<(usize, DistVector<S>, DistVector<S>, DistVector<S>, S)> = None;
    let mut just_restored = false;
    let mut it = 0usize;
    while it < cfg.max_iter {
        // Snapshot/probe boundary (same protocol as the factorizations):
        // probe collectively for a crash first — rolling back, if one hit —
        // then snapshot.  Without a policy every iteration is a probe
        // boundary, so a crash is reported rather than silently absorbed.
        let boundary = every.map_or(probing, |e| it % e == 0);
        if probing && boundary && it > 0 && !just_restored && fault_probe(ctx) {
            let Some((sit, sx, sr, sp, srr)) = saved.as_ref() else {
                return Err(Error::Runtime(format!(
                    "cg: rank crash detected at iteration {it} with no snapshot \
                     (CheckpointPolicy not set)"
                )));
            };
            restore_vec(ctx, &mut x, sx);
            restore_vec(ctx, &mut r, sr);
            restore_vec(ctx, &mut p, sp);
            rr = *srr;
            it = *sit;
            just_restored = true;
            continue;
        }
        if let Some(e) = every {
            if it % e == 0 && !just_restored {
                let mut vs = snapshot_vecs(ctx, &[&x, &r, &p]);
                let sp = vs.pop().unwrap();
                let sr = vs.pop().unwrap();
                let sx = vs.pop().unwrap();
                saved = Some((it, sx, sr, sp, rr));
            }
        }
        just_restored = false;

        let ap = a.apply(ctx, &p);
        let pap = pdot(ctx, &p, &ap);
        if !pap.is_finite() {
            return Err(Error::NonFinite { method: "cg", iteration: it, quantity: "p'Ap" });
        }
        if pap <= S::zero() {
            return Err(Error::Breakdown {
                method: "cg",
                detail: format!("p^T A p = {pap} at iteration {it} (matrix not SPD?)"),
            });
        }
        let alpha = rr / pap;
        paxpy(ctx, alpha, &p, &mut x);
        // r -= alpha A p and ||r||^2 in one fused kernel.
        let rr_new = pfused_axpy_norm2(ctx, -alpha, &ap, &mut r);
        if !rr_new.is_finite() {
            return Err(Error::NonFinite { method: "cg", iteration: it, quantity: "||r||^2" });
        }
        let rnorm = rr_new.sqrt();
        if rnorm <= tol {
            return Ok((x, IterStats::new(it + 1, rnorm / bnorm, true)));
        }
        let beta = rr_new / rr;
        rr = rr_new;
        pxpay(ctx, beta, &r, &mut p); // p = r + beta p
        it += 1;
    }
    let rnorm = pnorm2(ctx, &r);
    Ok((x, IterStats::new(cfg.max_iter, rnorm / bnorm, false)))
}

/// Preconditioned CG: solve `A x = b` (A SPD) with a [`Preconditioner`]
/// `m` approximating `A^{-1}` — the standard PCG recurrence on the
/// `M^{-1}`-inner product.  Convergence is still judged on the *true*
/// residual norm `||r||`, so results are comparable with [`cg`] at the
/// same tolerance; the preconditioner only changes how fast it gets there.
pub fn pcg<S: Scalar, A: LinOp<S> + ?Sized, M: Preconditioner<S> + ?Sized>(
    ctx: &Ctx<'_, S>,
    a: &A,
    m: &M,
    b: &DistVector<S>,
    cfg: &IterConfig,
) -> Result<(DistVector<S>, IterStats<S>)> {
    let desc = *a.desc();
    let mesh = ctx.mesh;
    let bnorm = pnorm2(ctx, b);
    let mut x = DistVector::zeros(desc, mesh.row(), mesh.col());
    if norm_negligible(bnorm, desc.m) {
        return Ok((x, IterStats::new(0, S::zero(), true)));
    }
    let tol = S::from_f64(cfg.tol).unwrap() * bnorm;

    let mut r = b.clone_vec();
    let z = m.apply(ctx, &r)?;
    let mut p = z.clone_vec();
    let mut rz = pdot(ctx, &r, &z);
    if rz <= S::zero() {
        return Err(Error::Breakdown {
            method: "pcg",
            detail: format!("r^T M^-1 r = {rz} at startup (preconditioner not SPD?)"),
        });
    }

    for it in 0..cfg.max_iter {
        let ap = a.apply(ctx, &p);
        let pap = pdot(ctx, &p, &ap);
        if !pap.is_finite() {
            return Err(Error::NonFinite { method: "pcg", iteration: it, quantity: "p'Ap" });
        }
        if pap <= S::zero() {
            return Err(Error::Breakdown {
                method: "pcg",
                detail: format!("p^T A p = {pap} at iteration {it} (matrix not SPD?)"),
            });
        }
        let alpha = rz / pap;
        paxpy(ctx, alpha, &p, &mut x);
        // r -= alpha A p and ||r||^2 in one fused kernel.
        let rr_new = pfused_axpy_norm2(ctx, -alpha, &ap, &mut r);
        let rnorm = rr_new.sqrt();
        if rnorm <= tol {
            return Ok((x, IterStats::new(it + 1, rnorm / bnorm, true)));
        }
        let z = m.apply(ctx, &r)?;
        let rz_new = pdot(ctx, &r, &z);
        if !rz_new.is_finite() {
            return Err(Error::NonFinite { method: "pcg", iteration: it, quantity: "r'z" });
        }
        if rz_new <= S::zero() {
            return Err(Error::Breakdown {
                method: "pcg",
                detail: format!("r^T M^-1 r = {rz_new} at iteration {it}"),
            });
        }
        let beta = rz_new / rz;
        rz = rz_new;
        pxpay(ctx, beta, &z, &mut p); // p = z + beta p
    }
    let rnorm = pnorm2(ctx, &r);
    Ok((x, IterStats::new(cfg.max_iter, rnorm / bnorm, false)))
}
