//! Conjugate gradients — the classical Krylov method the paper's §2 builds
//! from ("one of the most used Krylov methods... solves SPD systems").
//!
//! Operator-generic: `A` is any [`LinOp`] — dense block-cyclic or sparse
//! row-block CSR (`DESIGN.md` §10).
//!
//! The per-iteration BLAS-1 chain runs on the **fused** kernels
//! (`DESIGN.md` §12): the residual update + norm collapse into one
//! [`pfused_axpy_norm2`] and the `p = r + beta p` recurrence into one
//! [`pxpay`] — same arithmetic bit for bit, 3 memory passes and a
//! launch-per-block fewer each iteration.

use super::precond::Preconditioner;
use super::{norm_negligible, IterConfig, IterStats};
use crate::dist::DistVector;
use crate::pblas::{paxpy, pdot, pfused_axpy_norm2, pnorm2, pxpay, Ctx, LinOp};
use crate::{Error, Result, Scalar};

/// Solve `A x = b` (A SPD) from the zero initial guess.
pub fn cg<S: Scalar, A: LinOp<S> + ?Sized>(
    ctx: &Ctx<'_, S>,
    a: &A,
    b: &DistVector<S>,
    cfg: &IterConfig,
) -> Result<(DistVector<S>, IterStats<S>)> {
    let desc = *a.desc();
    let mesh = ctx.mesh;
    let bnorm = pnorm2(ctx, b);
    let mut x = DistVector::zeros(desc, mesh.row(), mesh.col());
    if norm_negligible(bnorm, desc.m) {
        return Ok((x, IterStats::new(0, S::zero(), true)));
    }
    let tol = S::from_f64(cfg.tol).unwrap() * bnorm;

    let mut r = b.clone_vec();
    let mut p = r.clone_vec();
    let mut rr = pdot(ctx, &r, &r);

    for it in 0..cfg.max_iter {
        let ap = a.apply(ctx, &p);
        let pap = pdot(ctx, &p, &ap);
        if pap <= S::zero() {
            return Err(Error::Breakdown {
                method: "cg",
                detail: format!("p^T A p = {pap} at iteration {it} (matrix not SPD?)"),
            });
        }
        let alpha = rr / pap;
        paxpy(ctx, alpha, &p, &mut x);
        // r -= alpha A p and ||r||^2 in one fused kernel.
        let rr_new = pfused_axpy_norm2(ctx, -alpha, &ap, &mut r);
        let rnorm = rr_new.sqrt();
        if rnorm <= tol {
            return Ok((x, IterStats::new(it + 1, rnorm / bnorm, true)));
        }
        let beta = rr_new / rr;
        rr = rr_new;
        pxpay(ctx, beta, &r, &mut p); // p = r + beta p
    }
    let rnorm = pnorm2(ctx, &r);
    Ok((x, IterStats::new(cfg.max_iter, rnorm / bnorm, false)))
}

/// Preconditioned CG: solve `A x = b` (A SPD) with a [`Preconditioner`]
/// `m` approximating `A^{-1}` — the standard PCG recurrence on the
/// `M^{-1}`-inner product.  Convergence is still judged on the *true*
/// residual norm `||r||`, so results are comparable with [`cg`] at the
/// same tolerance; the preconditioner only changes how fast it gets there.
pub fn pcg<S: Scalar, A: LinOp<S> + ?Sized, M: Preconditioner<S> + ?Sized>(
    ctx: &Ctx<'_, S>,
    a: &A,
    m: &M,
    b: &DistVector<S>,
    cfg: &IterConfig,
) -> Result<(DistVector<S>, IterStats<S>)> {
    let desc = *a.desc();
    let mesh = ctx.mesh;
    let bnorm = pnorm2(ctx, b);
    let mut x = DistVector::zeros(desc, mesh.row(), mesh.col());
    if norm_negligible(bnorm, desc.m) {
        return Ok((x, IterStats::new(0, S::zero(), true)));
    }
    let tol = S::from_f64(cfg.tol).unwrap() * bnorm;

    let mut r = b.clone_vec();
    let z = m.apply(ctx, &r)?;
    let mut p = z.clone_vec();
    let mut rz = pdot(ctx, &r, &z);
    if rz <= S::zero() {
        return Err(Error::Breakdown {
            method: "pcg",
            detail: format!("r^T M^-1 r = {rz} at startup (preconditioner not SPD?)"),
        });
    }

    for it in 0..cfg.max_iter {
        let ap = a.apply(ctx, &p);
        let pap = pdot(ctx, &p, &ap);
        if pap <= S::zero() {
            return Err(Error::Breakdown {
                method: "pcg",
                detail: format!("p^T A p = {pap} at iteration {it} (matrix not SPD?)"),
            });
        }
        let alpha = rz / pap;
        paxpy(ctx, alpha, &p, &mut x);
        // r -= alpha A p and ||r||^2 in one fused kernel.
        let rr_new = pfused_axpy_norm2(ctx, -alpha, &ap, &mut r);
        let rnorm = rr_new.sqrt();
        if rnorm <= tol {
            return Ok((x, IterStats::new(it + 1, rnorm / bnorm, true)));
        }
        let z = m.apply(ctx, &r)?;
        let rz_new = pdot(ctx, &r, &z);
        if rz_new <= S::zero() {
            return Err(Error::Breakdown {
                method: "pcg",
                detail: format!("r^T M^-1 r = {rz_new} at iteration {it}"),
            });
        }
        let beta = rz_new / rz;
        rz = rz_new;
        pxpay(ctx, beta, &z, &mut p); // p = z + beta p
    }
    let rnorm = pnorm2(ctx, &r);
    Ok((x, IterStats::new(cfg.max_iter, rnorm / bnorm, false)))
}
