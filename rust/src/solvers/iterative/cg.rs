//! Conjugate gradients — the classical Krylov method the paper's §2 builds
//! from ("one of the most used Krylov methods... solves SPD systems").
//!
//! Operator-generic: `A` is any [`LinOp`] — dense block-cyclic or sparse
//! row-block CSR (`DESIGN.md` §10).

use super::{norm_negligible, IterConfig, IterStats};
use crate::dist::DistVector;
use crate::pblas::{paxpy, pdot, pnorm2, pscal, Ctx, LinOp};
use crate::{Error, Result, Scalar};

/// Solve `A x = b` (A SPD) from the zero initial guess.
pub fn cg<S: Scalar, A: LinOp<S> + ?Sized>(
    ctx: &Ctx<'_, S>,
    a: &A,
    b: &DistVector<S>,
    cfg: &IterConfig,
) -> Result<(DistVector<S>, IterStats<S>)> {
    let desc = *a.desc();
    let mesh = ctx.mesh;
    let bnorm = pnorm2(ctx, b);
    let mut x = DistVector::zeros(desc, mesh.row(), mesh.col());
    if norm_negligible(bnorm, desc.m) {
        return Ok((x, IterStats::new(0, S::zero(), true)));
    }
    let tol = S::from_f64(cfg.tol).unwrap() * bnorm;

    let mut r = b.clone_vec();
    let mut p = r.clone_vec();
    let mut rr = pdot(ctx, &r, &r);

    for it in 0..cfg.max_iter {
        let ap = a.apply(ctx, &p);
        let pap = pdot(ctx, &p, &ap);
        if pap <= S::zero() {
            return Err(Error::Breakdown {
                method: "cg",
                detail: format!("p^T A p = {pap} at iteration {it} (matrix not SPD?)"),
            });
        }
        let alpha = rr / pap;
        paxpy(ctx, alpha, &p, &mut x);
        paxpy(ctx, -alpha, &ap, &mut r);
        let rr_new = pdot(ctx, &r, &r);
        let rnorm = rr_new.sqrt();
        if rnorm <= tol {
            return Ok((x, IterStats::new(it + 1, rnorm / bnorm, true)));
        }
        let beta = rr_new / rr;
        rr = rr_new;
        // p = r + beta p
        pscal(ctx, beta, &mut p);
        paxpy(ctx, S::one(), &r, &mut p);
    }
    let rnorm = pnorm2(ctx, &r);
    Ok((x, IterStats::new(cfg.max_iter, rnorm / bnorm, false)))
}
