//! Non-stationary iterative solvers (the paper's §2 set): CG, BiCG,
//! BiCGSTAB and restarted GMRES, over distributed operands.
//!
//! All solvers are **operator-generic**: the system matrix is any
//! [`LinOp`] — a dense block-cyclic [`crate::dist::DistMatrix`] (matvecs
//! via [`crate::pblas::pgemv()`]/[`crate::pblas::pgemv_t`]) or a sparse
//! row-block [`crate::sparse::DistCsrMatrix`] (via
//! [`crate::pblas::pspmv()`]/[`crate::pblas::pspmv_t`]) — with no per-solver
//! forks; see `DESIGN.md` §10 for the trait contract.  All share the same
//! SPMD structure: matvecs through `LinOp::apply`/`apply_t`, inner
//! products via [`crate::pblas::pdot`] — every scalar recurrence
//! coefficient is computed from allreduced dots, so all ranks advance
//! identically.  All five solvers run their BLAS-1 chains on the **fused**
//! `pvec` kernels wherever the data flow allows (`DESIGN.md` §12),
//! bit-identically to the unfused sequences.

pub mod bicg;
pub mod bicgstab;
pub mod block;
pub mod cg;
pub mod gmres;
pub mod mixed;
pub mod pipecg;
pub mod precond;
pub mod schur;

pub use bicg::bicg;
pub use bicgstab::{bicgstab, bicgstab_ft};
pub use block::{block_bicgstab, block_cg};
pub use cg::{cg, cg_ft, pcg};
pub use gmres::{gmres, gmres_ft};
pub use mixed::{bicgstab_mixed, cg_mixed};
pub use pipecg::pipecg;
pub use precond::{BlockJacobiPrecond, JacobiPrecond, Preconditioner};
pub use schur::{schur_cg, SchurStats};

pub use crate::pblas::LinOp;

use crate::dist::DistVector;
use crate::pblas::Ctx;
use crate::Scalar;

/// Snapshot a set of recurrence vectors for fault-tolerant restart: price
/// the D2H leg of every device-dirty block ([`Ctx::snapshot_read`] — the
/// dirty period stays open, exactly like the factorization checkpoints),
/// then clone the host copies.  Under an empty fault layer the pricing is a
/// no-op and the clones are plain host copies.
pub(crate) fn snapshot_vecs<S: Scalar>(
    ctx: &Ctx<'_, S>,
    vecs: &[&DistVector<S>],
) -> Vec<DistVector<S>> {
    vecs.iter()
        .map(|v| {
            for l in 0..v.local_blocks() {
                ctx.snapshot_read(v.block(l));
            }
            v.clone_vec()
        })
        .collect()
}

/// Roll a recurrence vector back to its snapshot: retire the live vector's
/// device entries (its buffers are about to be replaced and a later clone
/// could alias the freed allocation), install a fresh clone of the snapshot,
/// and mark the clone's blocks host-authoritative.
pub(crate) fn restore_vec<S: Scalar>(ctx: &Ctx<'_, S>, dst: &mut DistVector<S>, src: &DistVector<S>) {
    for l in 0..dst.local_blocks() {
        ctx.host_mut(dst.block(l));
    }
    *dst = src.clone_vec();
    for l in 0..dst.local_blocks() {
        ctx.host_mut(dst.block(l));
    }
}

/// Underflow guard for vector norms, replacing the exact `norm == 0` float
/// comparisons the Krylov solvers used to make.  Below
/// `sqrt(MIN_POSITIVE) * n` the recurrences stop being meaningful — squared
/// norms (`rr = <r, r>`) and products like `tol * ||b||` underflow to
/// denormals or zero — so such a right-hand side takes the degenerate-case
/// path.  The threshold is far beneath any legitimately scaled data
/// (~1e-19·n for f32, ~1e-154·n for f64), so small-but-valid systems are
/// *not* swallowed; this is deliberately an underflow test, not a
/// magnitude test.
pub fn norm_negligible<S: Scalar>(norm: S, n: usize) -> bool {
    norm <= S::min_positive_value().sqrt() * S::from_f64(n.max(1) as f64).unwrap()
}

/// Relative round-off test: is `value` negligible next to `scale` (the
/// magnitude of the quantities it was computed from)?  Used for the GMRES
/// lucky-breakdown check, where the Arnoldi residual's natural scale is the
/// Hessenberg column it came out of (~||A||), not 1.
pub fn negligible_at_scale<S: Scalar>(value: S, scale: S, n: usize) -> bool {
    value <= S::epsilon() * S::from_f64(n.max(1) as f64).unwrap() * scale
}

/// Convergence controls shared by all iterative solvers.
#[derive(Clone, Copy, Debug)]
pub struct IterConfig {
    /// Relative residual target: stop when `||r|| <= tol * ||b||`.
    pub tol: f64,
    /// Iteration budget (matvec count for CG/BiCG-family; total inner
    /// iterations for GMRES).
    pub max_iter: usize,
    /// GMRES restart length `m` (ignored by the other methods).
    pub restart: usize,
}

impl Default for IterConfig {
    fn default() -> Self {
        IterConfig { tol: 1e-8, max_iter: 500, restart: 30 }
    }
}

/// Outcome of an iterative solve.
#[derive(Clone, Copy, Debug)]
pub struct IterStats<S> {
    /// Iterations actually performed.
    pub iterations: usize,
    /// Final relative residual `||r|| / ||b||`.
    pub rel_residual: S,
    /// Whether the tolerance was met within the budget.
    pub converged: bool,
}

impl<S: Scalar> IterStats<S> {
    pub(crate) fn new(iterations: usize, rel_residual: S, converged: bool) -> Self {
        IterStats { iterations, rel_residual, converged }
    }
}

/// Named solver selector (CLI / bench harness).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IterMethod {
    /// Conjugate gradients (SPD).
    Cg,
    /// Pipelined CG (SPD): one fused, matvec-overlapped reduction per
    /// iteration (Ghysels-style; see [`pipecg()`]).
    PipeCg,
    /// BiConjugate gradients.
    Bicg,
    /// BiCGSTAB.
    Bicgstab,
    /// Restarted GMRES(m).
    Gmres,
}

impl IterMethod {
    /// Parse a CLI name.
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "cg" => Ok(IterMethod::Cg),
            "pipecg" => Ok(IterMethod::PipeCg),
            "bicg" => Ok(IterMethod::Bicg),
            "bicgstab" => Ok(IterMethod::Bicgstab),
            "gmres" => Ok(IterMethod::Gmres),
            other => Err(crate::Error::config(format!(
                "unknown iterative method {other:?} (cg|pipecg|bicg|bicgstab|gmres)"
            ))),
        }
    }

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            IterMethod::Cg => "CG",
            IterMethod::PipeCg => "PipeCG",
            IterMethod::Bicg => "BiCG",
            IterMethod::Bicgstab => "BiCGSTAB",
            IterMethod::Gmres => "GMRES",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for (s, m) in [
            ("cg", IterMethod::Cg),
            ("PipeCG", IterMethod::PipeCg),
            ("BiCG", IterMethod::Bicg),
            ("bicgstab", IterMethod::Bicgstab),
            ("GMRES", IterMethod::Gmres),
        ] {
            assert_eq!(IterMethod::parse(s).unwrap(), m);
        }
        assert!(IterMethod::parse("sor").is_err());
    }

    #[test]
    fn default_config_sane() {
        let c = IterConfig::default();
        assert!(c.tol > 0.0 && c.max_iter > 0 && c.restart > 1);
    }

    #[test]
    fn norm_negligible_is_an_underflow_guard_not_a_magnitude_test() {
        // Exact zero and denormal-scale norms are negligible...
        assert!(norm_negligible(0.0f64, 1000));
        assert!(norm_negligible(f64::MIN_POSITIVE, 1000));
        assert!(norm_negligible(0.0f32, 20_000));
        // ...but small, legitimately scaled right-hand sides are not.
        assert!(!norm_negligible(5e-9f32, 20_000));
        assert!(!norm_negligible(1e-30f64, 20_000));
    }

    #[test]
    fn negligible_at_scale_tracks_the_operand_magnitude() {
        // wnorm ~ 1e-4 next to a column of scale 1e-4 is NOT a breakdown...
        assert!(!negligible_at_scale(1e-4f32, 1e-4f32, 10_000));
        // ...but the same wnorm next to an O(1) column is round-off (f32:
        // eps * n = 1.2e-3), and exact zero always is.
        assert!(negligible_at_scale(1e-4f32, 1.0f32, 10_000));
        assert!(negligible_at_scale(0.0f64, 0.0f64, 10));
    }
}
