//! Non-stationary iterative solvers (the paper's §2 set): CG, BiCG,
//! BiCGSTAB and restarted GMRES, over distributed operands.
//!
//! All solvers share the same SPMD structure: matvecs via
//! [`crate::pblas::pgemv`] (and [`crate::pblas::pgemv_t`] for BiCG's second
//! sequence), inner products via [`crate::pblas::pdot`] — every scalar
//! recurrence coefficient is computed from allreduced dots, so all ranks
//! advance identically.

pub mod bicg;
pub mod bicgstab;
pub mod cg;
pub mod gmres;
pub mod precond;

pub use bicg::bicg;
pub use bicgstab::bicgstab;
pub use cg::cg;
pub use gmres::gmres;
pub use precond::JacobiPrecond;

use crate::Scalar;

/// Convergence controls shared by all iterative solvers.
#[derive(Clone, Copy, Debug)]
pub struct IterConfig {
    /// Relative residual target: stop when `||r|| <= tol * ||b||`.
    pub tol: f64,
    /// Iteration budget (matvec count for CG/BiCG-family; total inner
    /// iterations for GMRES).
    pub max_iter: usize,
    /// GMRES restart length `m` (ignored by the other methods).
    pub restart: usize,
}

impl Default for IterConfig {
    fn default() -> Self {
        IterConfig { tol: 1e-8, max_iter: 500, restart: 30 }
    }
}

/// Outcome of an iterative solve.
#[derive(Clone, Copy, Debug)]
pub struct IterStats<S> {
    /// Iterations actually performed.
    pub iterations: usize,
    /// Final relative residual `||r|| / ||b||`.
    pub rel_residual: S,
    /// Whether the tolerance was met within the budget.
    pub converged: bool,
}

impl<S: Scalar> IterStats<S> {
    pub(crate) fn new(iterations: usize, rel_residual: S, converged: bool) -> Self {
        IterStats { iterations, rel_residual, converged }
    }
}

/// Named solver selector (CLI / bench harness).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IterMethod {
    /// Conjugate gradients (SPD).
    Cg,
    /// BiConjugate gradients.
    Bicg,
    /// BiCGSTAB.
    Bicgstab,
    /// Restarted GMRES(m).
    Gmres,
}

impl IterMethod {
    /// Parse a CLI name.
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "cg" => Ok(IterMethod::Cg),
            "bicg" => Ok(IterMethod::Bicg),
            "bicgstab" => Ok(IterMethod::Bicgstab),
            "gmres" => Ok(IterMethod::Gmres),
            other => Err(crate::Error::config(format!(
                "unknown iterative method {other:?} (cg|bicg|bicgstab|gmres)"
            ))),
        }
    }

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            IterMethod::Cg => "CG",
            IterMethod::Bicg => "BiCG",
            IterMethod::Bicgstab => "BiCGSTAB",
            IterMethod::Gmres => "GMRES",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for (s, m) in [
            ("cg", IterMethod::Cg),
            ("BiCG", IterMethod::Bicg),
            ("bicgstab", IterMethod::Bicgstab),
            ("GMRES", IterMethod::Gmres),
        ] {
            assert_eq!(IterMethod::parse(s).unwrap(), m);
        }
        assert!(IterMethod::parse("sor").is_err());
    }

    #[test]
    fn default_config_sane() {
        let c = IterConfig::default();
        assert!(c.tol > 0.0 && c.max_iter > 0 && c.restart > 1);
    }
}
