//! Schur-complement sub-structuring over the row-block sparse operator
//! (`DESIGN.md` §15): split each rank's owned unknowns into **interior**
//! (coupled only to locally-owned unknowns, and referenced by no other
//! rank) and **interface** (everything on the inter-rank coupling
//! surface), eliminate the interior block with purely local solves, and
//! run the distributed Krylov iteration only on the interface system
//!
//! ```text
//!   S x_B = b_B - A_BI A_II^{-1} b_I,     S = A_BB - A_BI A_II^{-1} A_IB
//! ```
//!
//! `A_II` is block-diagonal across ranks (interior unknowns never couple
//! across rank boundaries — that is the definition of interior), so every
//! `A_II^{-1}` application is an embarrassingly parallel *local* CG with
//! zero communication.  The outer CG's operator application costs one
//! halo matvec ([`crate::pblas::pspmv_halo`], O(surface) wire), one local
//! inner solve, and one local `A_BI` matvec — the communication volume
//! per outer iteration is exactly the ghost surface, while the outer
//! iteration count reflects the (smaller, better-conditioned) interface
//! system rather than the full operator.
//!
//! Interface vectors ride in full-length [`DistVector`]s supported on the
//! interface positions (zeros elsewhere): the standard `pdot`/`paxpy`
//! plumbing then applies unchanged, and the embedding is exactly what the
//! halo matvec wants.  With `pr = 1` there are no remote couplings, every
//! unknown is interior, and the method degenerates to one local solve.

use super::{norm_negligible, IterConfig, IterStats};
use crate::comm::ReduceOp;
use crate::dist::DistVector;
use crate::pblas::{paxpy, pdot, pfused_axpy_norm2, pnorm2, pspmv_halo, pxpay, tags, Ctx};
use crate::sparse::{owned_local_col, CsrMatrix, DistCsrMatrix};
use crate::{Error, Result, Scalar};

/// Outcome of a [`schur_cg`] solve.
#[derive(Clone, Copy, Debug)]
pub struct SchurStats<S> {
    /// The outer (interface-system) CG outcome.
    pub outer: IterStats<S>,
    /// Total inner (local `A_II`) CG iterations on this rank, across the
    /// rhs reduction, every outer operator application, and the back
    /// substitution.
    pub inner_iterations: usize,
    /// Global interface unknown count (the outer system's dimension).
    pub interface_unknowns: usize,
    /// Global interior unknown count (eliminated locally).
    pub interior_unknowns: usize,
}

/// Serial (single-rank-local) CG on a compact SPD CSR block, engine-charged.
///
/// Shared by the Schur interior elimination and the block-Jacobi
/// preconditioner.  Returns the solution and the iteration count; like the
/// distributed [`super::cg`] it errors on an indefinite pivot but treats
/// exhausting `max_iter` as a plain (unconverged) return — preconditioner
/// callers cap the budget deliberately.
pub(crate) fn local_cg<S: Scalar>(
    ctx: &Ctx<'_, S>,
    a: &CsrMatrix<S>,
    b: &[S],
    cfg: &IterConfig,
) -> Result<(Vec<S>, usize)> {
    let n = a.nrows();
    assert_eq!(a.ncols(), n, "local_cg needs a square block");
    assert_eq!(b.len(), n, "local_cg rhs length mismatch");
    let mut x = vec![S::zero(); n];
    let dot = |u: &[S], v: &[S]| {
        let mut acc = S::zero();
        for (ui, vi) in u.iter().zip(v) {
            acc += *ui * *vi;
        }
        acc
    };
    ctx.charge(ctx.engine.blas1_cost(n));
    let bnorm = dot(b, b).sqrt();
    if norm_negligible(bnorm, n) {
        return Ok((x, 0));
    }
    let tol = S::from_f64(cfg.tol).unwrap() * bnorm;
    let mut r = b.to_vec();
    let mut p = r.clone();
    let mut ap = vec![S::zero(); n];
    let mut rr = dot(&r, &r);
    ctx.charge(ctx.engine.blas1_cost(n));
    for it in 0..cfg.max_iter {
        let cost = ctx.engine.spmv(a, &p, &mut ap)?;
        ctx.charge(cost);
        let pap = dot(&p, &ap);
        ctx.charge(ctx.engine.blas1_cost(n));
        if pap <= S::zero() {
            return Err(Error::Breakdown {
                method: "schur-local-cg",
                detail: format!("p^T A p = {pap} at local iteration {it} (block not SPD?)"),
            });
        }
        let alpha = rr / pap;
        let mut rr_new = S::zero();
        for i in 0..n {
            x[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
            rr_new += r[i] * r[i];
        }
        ctx.charge(ctx.engine.blas1_fused_cost(n, 3, 6));
        if rr_new.sqrt() <= tol {
            return Ok((x, it + 1));
        }
        let beta = rr_new / rr;
        rr = rr_new;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        ctx.charge(ctx.engine.blas1_fused_cost(n, 2, 2));
    }
    Ok((x, cfg.max_iter))
}

/// One rank's sub-structuring of the owned row block: the interface mask,
/// the compact interior operator, and the interface-from-interior coupling.
struct Substructure<S: Scalar> {
    /// Per local element (padded local row index): on the coupling surface?
    is_ifc: Vec<bool>,
    /// Local row indices of the interior unknowns, ascending.
    int_rows: Vec<usize>,
    /// Local row indices of the interface unknowns, ascending.
    ifc_rows: Vec<usize>,
    /// `A_II` — interior rows x interior columns, compact.
    aii: CsrMatrix<S>,
    /// `A_BI` — interface rows x interior columns, compact.
    abi: CsrMatrix<S>,
}

impl<S: Scalar> Substructure<S> {
    fn build(ctx: &Ctx<'_, S>, a: &DistCsrMatrix<S>) -> Self {
        let desc = *a.desc();
        let width = a.local().nrows();
        // Interface = rows coupled to a remote column, plus rows some
        // neighbor's off-block references (both sides of the surface).
        // Everything is read off the halo plan, so classification costs
        // nothing beyond the (cached) plan build.
        let is_ifc = {
            let col = ctx.mesh.col_comm();
            let plan = a.halo_plan(&col, tags::HALO_PLAN);
            let mut m = vec![false; width];
            for li in 0..width {
                if !plan.off_ghost.row(li).0.is_empty() {
                    m[li] = true;
                }
            }
            for peer in &plan.send {
                for &c in peer {
                    m[owned_local_col(&desc, c)] = true;
                }
            }
            m
        };
        let (mut int_rows, mut ifc_rows) = (Vec::new(), Vec::new());
        let mut int_of = vec![usize::MAX; width];
        for li in 0..width {
            if a.global_row(li) >= desc.m {
                continue; // padding: neither class, stays exactly zero
            }
            if is_ifc[li] {
                ifc_rows.push(li);
            } else {
                int_of[li] = int_rows.len();
                int_rows.push(li);
            }
        }
        let mut aii_rows = Vec::with_capacity(int_rows.len());
        for &li in &int_rows {
            let (cols, vals) = a.local().row(li);
            let mut row = Vec::new();
            for (&c, &v) in cols.iter().zip(vals) {
                // Interior rows couple only to locally-owned columns.
                let e = owned_local_col(&desc, c);
                if int_of[e] != usize::MAX {
                    row.push((int_of[e], v));
                }
                // else: an A_IB entry — recovered through the full halo
                // matvec on interface-supported vectors, never stored.
            }
            aii_rows.push(row);
        }
        let mut abi_rows = Vec::with_capacity(ifc_rows.len());
        for &li in &ifc_rows {
            let (cols, vals) = a.local().row(li);
            let mut row = Vec::new();
            for (&c, &v) in cols.iter().zip(vals) {
                if (c / desc.tile) % desc.shape.pr == a.prow() {
                    let e = owned_local_col(&desc, c);
                    if int_of[e] != usize::MAX {
                        row.push((int_of[e], v));
                    }
                }
            }
            abi_rows.push(row);
        }
        Substructure {
            is_ifc,
            aii: CsrMatrix::from_rows(int_rows.len(), aii_rows),
            abi: CsrMatrix::from_rows(int_rows.len(), abi_rows),
            int_rows,
            ifc_rows,
        }
    }

    /// Compact interior slice of a full local vector.
    fn take_interior(&self, loc: &[S]) -> Vec<S> {
        self.int_rows.iter().map(|&li| loc[li]).collect()
    }
}

fn local_view<S: Scalar>(x: &DistVector<S>) -> Vec<S> {
    let t = x.desc().tile;
    let mut loc = Vec::with_capacity(x.local_blocks() * t);
    for l in 0..x.local_blocks() {
        loc.extend_from_slice(x.block(l));
    }
    loc
}

fn vec_from_local<S: Scalar>(ctx: &Ctx<'_, S>, desc: &crate::dist::Descriptor, loc: &[S]) -> DistVector<S> {
    let mesh = ctx.mesh;
    let mut v = DistVector::zeros(*desc, mesh.row(), mesh.col());
    let t = desc.tile;
    for l in 0..v.local_blocks() {
        v.block_mut(l).copy_from_slice(&loc[l * t..(l + 1) * t]);
    }
    v
}

/// Solve `A x = b` (A SPD, sparse row-block distributed) by
/// Schur-complement sub-structuring: local interior elimination, outer CG
/// on the interface system (see the module docs).  `outer` controls the
/// interface CG; `inner` the local `A_II` solves (its tolerance should be
/// a couple of orders tighter than `outer.tol` — the outer operator is
/// only as symmetric as the inner solves are exact).
pub fn schur_cg<S: Scalar>(
    ctx: &Ctx<'_, S>,
    a: &DistCsrMatrix<S>,
    b: &DistVector<S>,
    outer: &IterConfig,
    inner: &IterConfig,
) -> Result<(DistVector<S>, SchurStats<S>)> {
    let desc = *a.desc();
    assert_eq!(&desc, b.desc(), "schur_cg operand descriptors differ");
    let mesh = ctx.mesh;
    let col = mesh.col_comm();
    let sub = Substructure::build(ctx, a);
    let mut inner_iters = 0usize;

    // Global class sizes (counts are exactly representable well past any
    // test problem, even in f32).
    let count = |n: usize| -> usize {
        let total =
            col.allreduce_scalar(tags::SCHUR, S::from_f64(n as f64).unwrap(), ReduceOp::Sum);
        total.to_f64().unwrap().round() as usize
    };
    let n_ifc_global = count(sub.ifc_rows.len());
    let n_int_global = count(sub.int_rows.len());

    let bloc = local_view(b);
    let b_int = sub.take_interior(&bloc);

    // Interface rhs: g_B = b_B - A_BI A_II^{-1} b_I, embedded full-length.
    let (t0, it0) = local_cg(ctx, &sub.aii, &b_int, inner)?;
    inner_iters += it0;
    let mut ub = vec![S::zero(); sub.ifc_rows.len()];
    let cost = ctx.engine.spmv(&sub.abi, &t0, &mut ub)?;
    ctx.charge(cost);
    let mut gloc = vec![S::zero(); bloc.len()];
    for (k, &li) in sub.ifc_rows.iter().enumerate() {
        gloc[li] = bloc[li] - ub[k];
    }
    ctx.charge(ctx.engine.blas1_cost(sub.ifc_rows.len()));
    let g = vec_from_local(ctx, &desc, &gloc);

    // S v for an interface-supported v: one halo matvec gives A_BB v on
    // the interface rows (interior positions of v are zero, ghosts of an
    // interface vector are the neighbors' interface values) and A_IB v on
    // the interior rows for free; one local solve and one compact A_BI
    // matvec finish the correction term.
    let mut apply_s = |v: &DistVector<S>, inner_iters: &mut usize| -> Result<DistVector<S>> {
        let w = pspmv_halo(ctx, a, v);
        let wloc = local_view(&w);
        let w_int = sub.take_interior(&wloc);
        let (t, it) = local_cg(ctx, &sub.aii, &w_int, inner)?;
        *inner_iters += it;
        let mut ub = vec![S::zero(); sub.ifc_rows.len()];
        let cost = ctx.engine.spmv(&sub.abi, &t, &mut ub)?;
        ctx.charge(cost);
        let mut sloc = vec![S::zero(); wloc.len()];
        for (k, &li) in sub.ifc_rows.iter().enumerate() {
            sloc[li] = wloc[li] - ub[k];
        }
        ctx.charge(ctx.engine.blas1_cost(sub.ifc_rows.len()));
        Ok(vec_from_local(ctx, &desc, &sloc))
    };

    // Outer CG on the interface system (the [`super::cg`] recurrence, with
    // the operator application inlined so inner iterations are counted).
    let bnorm = pnorm2(ctx, &g);
    let mut xb = DistVector::zeros(desc, mesh.row(), mesh.col());
    let outer_stats = if norm_negligible(bnorm, n_ifc_global.max(1)) {
        IterStats::new(0, S::zero(), true)
    } else {
        let tol = S::from_f64(outer.tol).unwrap() * bnorm;
        let mut r = g.clone_vec();
        let mut p = r.clone_vec();
        let mut rr = pdot(ctx, &r, &r);
        let mut stats = None;
        for it in 0..outer.max_iter {
            let ap = apply_s(&p, &mut inner_iters)?;
            let pap = pdot(ctx, &p, &ap);
            if pap <= S::zero() {
                return Err(Error::Breakdown {
                    method: "schur-cg",
                    detail: format!("p^T S p = {pap} at outer iteration {it}"),
                });
            }
            let alpha = rr / pap;
            paxpy(ctx, alpha, &p, &mut xb);
            let rr_new = pfused_axpy_norm2(ctx, -alpha, &ap, &mut r);
            let rnorm = rr_new.sqrt();
            if rnorm <= tol {
                stats = Some(IterStats::new(it + 1, rnorm / bnorm, true));
                break;
            }
            let beta = rr_new / rr;
            rr = rr_new;
            pxpay(ctx, beta, &r, &mut p);
        }
        stats.unwrap_or_else(|| {
            let rnorm = pnorm2(ctx, &r);
            IterStats::new(outer.max_iter, rnorm / bnorm, false)
        })
    };

    // Back substitution: x_I = A_II^{-1} (b_I - A_IB x_B), then assemble.
    let w2 = pspmv_halo(ctx, a, &xb);
    let w2loc = local_view(&w2);
    let rhs_int: Vec<S> =
        sub.int_rows.iter().enumerate().map(|(k, &li)| b_int[k] - w2loc[li]).collect();
    ctx.charge(ctx.engine.blas1_cost(sub.int_rows.len()));
    let (xi, it_back) = local_cg(ctx, &sub.aii, &rhs_int, inner)?;
    inner_iters += it_back;
    let mut xloc = local_view(&xb);
    for (k, &li) in sub.int_rows.iter().enumerate() {
        debug_assert!(!sub.is_ifc[li]);
        xloc[li] = xi[k];
    }
    let x = vec_from_local(ctx, &desc, &xloc);

    Ok((
        x,
        SchurStats {
            outer: outer_stats,
            inner_iterations: inner_iters,
            interface_unknowns: n_ifc_global,
            interior_unknowns: n_int_global,
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::CpuEngine;
    use crate::comm::{NetworkModel, World};
    use crate::dist::{gather_vector, Descriptor};
    use crate::mesh::{Mesh, MeshShape};
    use crate::solvers::cg;
    use std::sync::Arc;

    /// SPD banded test operator: strong diagonal, bands at +-1 and +-4.
    fn rows_of(n: usize) -> impl Fn(usize) -> Vec<(usize, f64)> + Clone + Send + Sync {
        move |i| {
            let mut r = vec![(i, 8.0 + ((i * 3) % 5) as f64)];
            for d in [1usize, 4] {
                if i + d < n {
                    r.push((i + d, -1.0 - 0.1 * d as f64));
                }
                if i >= d {
                    r.push((i - d, -1.0 - 0.1 * d as f64));
                }
            }
            r
        }
    }

    fn solve_case(n: usize, tile: usize, pr: usize, pc: usize) {
        let out = World::run::<f64, _, _>(pr * pc, NetworkModel::gigabit_ethernet(), move |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
            let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(4)));
            let desc = Descriptor::new(n, n, tile, mesh.shape());
            let a = DistCsrMatrix::from_row_fn(desc, mesh.row(), mesh.col(), rows_of(n));
            let b = DistVector::from_fn(desc, mesh.row(), mesh.col(), |i| {
                (i as f64 * 0.37).sin() + 1.5
            });
            let outer = IterConfig { tol: 1e-10, max_iter: 400, restart: 30 };
            let inner = IterConfig { tol: 1e-13, max_iter: 800, restart: 30 };
            let (x, st) = schur_cg(&ctx, &a, &b, &outer, &inner).expect("schur_cg");
            let (xref, stref) = cg(&ctx, &a, &b, &outer).expect("reference cg");
            (gather_vector(&mesh, &x), gather_vector(&mesh, &xref), st, stref.converged)
        });
        for (x, xref, st, ref_conv) in out {
            assert!(ref_conv, "{pr}x{pc} reference CG must converge");
            assert!(st.outer.converged, "{pr}x{pc} outer CG must converge: {st:?}");
            assert_eq!(
                st.interface_unknowns + st.interior_unknowns,
                n,
                "{pr}x{pc}: classes partition the unknowns"
            );
            if pr == 1 {
                assert_eq!(st.interface_unknowns, 0, "single process row: all interior");
                assert_eq!(st.outer.iterations, 0, "empty interface system");
            } else {
                assert!(st.interface_unknowns > 0, "{pr}x{pc}: surface must be nonempty");
                assert!(
                    st.interface_unknowns < n,
                    "{pr}x{pc}: interior elimination must eliminate something"
                );
            }
            let (x, xref) = (x.unwrap(), xref.unwrap());
            for i in 0..n {
                assert!(
                    (x[i] - xref[i]).abs() < 1e-7,
                    "{pr}x{pc} x[{i}] = {} vs reference {}",
                    x[i],
                    xref[i]
                );
            }
        }
    }

    /// pr = 1 degenerates to a single local solve (zero interface).
    #[test]
    fn serial_case_is_one_local_solve() {
        solve_case(12, 4, 1, 1);
        solve_case(13, 4, 1, 2); // replicated across process columns
    }

    /// Multi-rank meshes, divisible and ragged n: same answer as plain CG.
    #[test]
    fn matches_plain_cg_across_meshes() {
        solve_case(24, 4, 2, 1);
        solve_case(23, 4, 2, 2); // ragged edge tile
        solve_case(26, 3, 3, 1); // pr = 3, tile 3
    }

    /// The interface must be exactly the coupling surface: with bandwidth 4
    /// and tile 4 on pr = 2, each tile-boundary strip is interface, interior
    /// strictly less than n.
    #[test]
    fn interface_is_the_coupling_surface() {
        let out = World::run::<f64, _, _>(2, NetworkModel::ideal(), move |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(2, 1));
            let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(4)));
            let n = 32;
            let desc = Descriptor::new(n, n, 4, mesh.shape());
            let a = DistCsrMatrix::from_row_fn(desc, mesh.row(), mesh.col(), rows_of(n));
            let sub = Substructure::build(&ctx, &a);
            // Brute-force oracle for this rank's interface set.
            let mut want = vec![false; a.local().nrows()];
            for li in 0..a.local().nrows() {
                let gi = a.global_row(li);
                if gi >= n {
                    continue;
                }
                for (j, _) in rows_of(n)(gi) {
                    if (j / 4) % 2 != mesh.row() {
                        want[li] = true; // couples out
                    }
                }
                for other in 0..n {
                    if (other / 4) % 2 != mesh.row()
                        && rows_of(n)(other).iter().any(|&(j, _)| j == gi)
                    {
                        want[li] = true; // referenced from outside
                    }
                }
            }
            let mut got = vec![false; a.local().nrows()];
            for &li in &sub.ifc_rows {
                got[li] = true;
            }
            (got, want)
        });
        for (got, want) in out {
            assert_eq!(got, want, "interface mask must equal the coupling surface");
        }
    }
}
