//! Mixed-precision Krylov: **storage and communication in `S`, every
//! reduction and recurrence scalar accumulated in `S::Hi`** (DESIGN.md
//! §17).
//!
//! The cluster layer runs these solvers in the *reduced* dtype's world
//! (f32 tiles through the cache/prefetch/wire machinery at half the
//! bytes), and the wide accumulators recover most of the dot-product
//! accuracy an all-f32 recurrence would lose: the `pvec` `_hi` kernels
//! compute local partials in f64 and ship only `S`-width reduction
//! payloads, so the wire sees exactly the plain kernels' traffic.
//!
//! For `S = f64` (`Hi = Self`, `from_hi` the identity) both solvers
//! reproduce their uniform-precision twins bit for bit — the `--no-mixed`
//! honesty contract.

use super::{norm_negligible, IterConfig, IterStats};
use crate::dist::DistVector;
use crate::pblas::{
    paxpy, pdot_hi, pfused_axpy_norm2_dot_hi, pfused_axpy_norm2_hi, pfused_norm2_dot_hi,
    pnorm2_hi, pxpay, Ctx, LinOp,
};
use crate::{Error, Result, Scalar};

/// Solve `A x = b` (A SPD) with f64-accumulate reductions over `S`-storage
/// operands.  Same recurrence shape as [`super::cg`], scalar for scalar.
pub fn cg_mixed<S: Scalar, A: LinOp<S> + ?Sized>(
    ctx: &Ctx<'_, S>,
    a: &A,
    b: &DistVector<S>,
    cfg: &IterConfig,
) -> Result<(DistVector<S>, IterStats<S>)> {
    let desc = *a.desc();
    let mesh = ctx.mesh;
    let zero = <S::Hi as num_traits::Zero>::zero();
    let bnorm = pnorm2_hi(ctx, b);
    let mut x = DistVector::zeros(desc, mesh.row(), mesh.col());
    if norm_negligible(S::from_hi(bnorm), desc.m) {
        return Ok((x, IterStats::new(0, S::zero(), true)));
    }
    let tol = <S::Hi as Scalar>::from_f64(cfg.tol).unwrap() * bnorm;

    let mut r = b.clone_vec();
    let mut p = r.clone_vec();
    let mut rr = pdot_hi(ctx, &r, &r);

    for it in 0..cfg.max_iter {
        let ap = a.apply(ctx, &p);
        let pap = pdot_hi(ctx, &p, &ap);
        if pap <= zero {
            return Err(Error::Breakdown {
                method: "cg_mixed",
                detail: format!("p^T A p = {pap} at iteration {it} (matrix not SPD?)"),
            });
        }
        let alpha = rr / pap;
        paxpy(ctx, S::from_hi(alpha), &p, &mut x);
        // r -= alpha A p and ||r||^2 in one fused wide-accumulate kernel.
        let rr_new = pfused_axpy_norm2_hi(ctx, S::from_hi(-alpha), &ap, &mut r);
        let rnorm = rr_new.sqrt();
        if rnorm <= tol {
            return Ok((x, IterStats::new(it + 1, S::from_hi(rnorm / bnorm), true)));
        }
        let beta = rr_new / rr;
        rr = rr_new;
        pxpay(ctx, S::from_hi(beta), &r, &mut p); // p = r + beta p
    }
    let rnorm = pnorm2_hi(ctx, &r);
    Ok((x, IterStats::new(cfg.max_iter, S::from_hi(rnorm / bnorm), false)))
}

/// Solve `A x = b` (general nonsymmetric) with f64-accumulate reductions
/// over `S`-storage operands.  Same recurrence shape as
/// [`super::bicgstab`], scalar for scalar.
pub fn bicgstab_mixed<S: Scalar, A: LinOp<S> + ?Sized>(
    ctx: &Ctx<'_, S>,
    a: &A,
    b: &DistVector<S>,
    cfg: &IterConfig,
) -> Result<(DistVector<S>, IterStats<S>)> {
    let desc = *a.desc();
    let mesh = ctx.mesh;
    let zero = <S::Hi as num_traits::Zero>::zero();
    let bnorm = pnorm2_hi(ctx, b);
    let mut x = DistVector::zeros(desc, mesh.row(), mesh.col());
    if norm_negligible(S::from_hi(bnorm), desc.m) {
        return Ok((x, IterStats::new(0, S::zero(), true)));
    }
    let tol = <S::Hi as Scalar>::from_f64(cfg.tol).unwrap() * bnorm;

    let mut r = b.clone_vec();
    let r0 = b.clone_vec(); // shadow residual
    let mut p = r.clone_vec();
    let mut rho = pdot_hi(ctx, &r0, &r);

    for it in 0..cfg.max_iter {
        if rho == zero {
            return Err(Error::Breakdown {
                method: "bicgstab_mixed",
                detail: format!("rho = 0 at iteration {it}"),
            });
        }
        let v = a.apply(ctx, &p);
        let r0v = pdot_hi(ctx, &r0, &v);
        if r0v == zero {
            return Err(Error::Breakdown {
                method: "bicgstab_mixed",
                detail: format!("r0.v = 0 at iteration {it}"),
            });
        }
        let alpha = rho / r0v;
        // s = r - alpha v, fused with ||s||^2.  The fresh clone's blocks are
        // host-authoritative: drop any aliased device entries first.
        let mut s = r.clone_vec();
        for l in 0..s.local_blocks() {
            ctx.host_mut(s.block(l));
        }
        let snorm = pfused_axpy_norm2_hi(ctx, S::from_hi(-alpha), &v, &mut s).sqrt();
        if snorm <= tol {
            paxpy(ctx, S::from_hi(alpha), &p, &mut x);
            return Ok((x, IterStats::new(it + 1, S::from_hi(snorm / bnorm), true)));
        }
        let t = a.apply(ctx, &s);
        // (t.t, t.s) in one pass and one two-lane allreduce.
        let (tt, ts) = pfused_norm2_dot_hi(ctx, &t, &s);
        if tt == zero {
            return Err(Error::Breakdown {
                method: "bicgstab_mixed",
                detail: format!("t.t = 0 at iteration {it}"),
            });
        }
        let omega = ts / tt;
        // x += alpha p + omega s
        paxpy(ctx, S::from_hi(alpha), &p, &mut x);
        paxpy(ctx, S::from_hi(omega), &s, &mut x);
        // r = s - omega t, fused with ||r||^2 and the next rho = r0.r.
        // Retire the old residual's device entries before its buffers drop
        // (a later clone could alias the freed allocation).
        for l in 0..r.local_blocks() {
            ctx.host_mut(r.block(l));
        }
        r = s;
        let (rr, rho_new) =
            pfused_axpy_norm2_dot_hi(ctx, S::from_hi(-omega), &t, &mut r, &r0);
        let rnorm = rr.sqrt();
        if rnorm <= tol {
            return Ok((x, IterStats::new(it + 1, S::from_hi(rnorm / bnorm), true)));
        }
        let beta = (rho_new / rho) * (alpha / omega);
        rho = rho_new;
        // p = r + beta (p - omega v)
        paxpy(ctx, S::from_hi(-omega), &v, &mut p);
        pxpay(ctx, S::from_hi(beta), &r, &mut p);
    }
    let rnorm = pnorm2_hi(ctx, &r);
    Ok((x, IterStats::new(cfg.max_iter, S::from_hi(rnorm / bnorm), false)))
}
