//! Restarted GMRES(m) — the paper's §2: Arnoldi with Gram-Schmidt
//! orthogonalisation, "restarting the computations after a fixed number of
//! iterations" to bound the growing basis storage.
//!
//! Distributed structure: the Krylov basis is a list of [`DistVector`]s; the
//! (m+1) x m Hessenberg least-squares problem is O(m²) data, solved
//! redundantly on every rank with the incremental Givens QR
//! ([`crate::linalg::givens::HessenbergQr`]) so no extra communication is
//! needed beyond the matvecs and dots.
//!
//! The BLAS-1 chain runs on the **fused** kernels where the data flow
//! allows (`DESIGN.md` §12): every residual formation `r = b - A x` fuses
//! its axpy with `||r||²` ([`pfused_axpy_norm2`]), and the *last* modified
//! Gram-Schmidt step of each Arnoldi iteration fuses its axpy with the
//! `||w||` that immediately follows — one kernel and one reduction fewer
//! per inner iteration.  (The earlier MGS steps cannot fuse: each `h_ij`
//! dot depends on the previous axpy's result.)  Arithmetic is the unfused
//! sequence's bit for bit: the fused kernel is the same per-block axpy
//! loop followed by the same 4-wide dot, in the same order.

use super::{
    negligible_at_scale, norm_negligible, restore_vec, snapshot_vecs, IterConfig, IterStats,
};
use crate::comm::CheckpointPolicy;
use crate::dist::DistVector;
use crate::linalg::givens::HessenbergQr;
use crate::pblas::{fault_probe, paxpy, pdot, pfused_axpy_norm2, pnorm2, pscal, Ctx, LinOp};
use crate::{Error, Result, Scalar};

/// `||b - A x||²` with the subtraction fused into the norm pass: clone `b`,
/// retire the clone's blocks (a reused allocation must never alias a stale
/// device entry), one fused axpy+norm² kernel.  Returns `(r, ||r||)`.
fn residual_fused<S: Scalar, A: LinOp<S> + ?Sized>(
    ctx: &Ctx<'_, S>,
    a: &A,
    b: &DistVector<S>,
    x: &DistVector<S>,
) -> (DistVector<S>, S) {
    let ax = a.apply(ctx, x);
    let mut r = b.clone_vec();
    for l in 0..r.local_blocks() {
        ctx.host_mut(r.block(l));
    }
    let rr = pfused_axpy_norm2(ctx, -S::one(), &ax, &mut r);
    (r, rr.sqrt())
}

/// Solve `A x = b` (general nonsymmetric) from the zero initial guess with
/// restart length `cfg.restart`.  `A` is any [`LinOp`] (dense or sparse).
pub fn gmres<S: Scalar, A: LinOp<S> + ?Sized>(
    ctx: &Ctx<'_, S>,
    a: &A,
    b: &DistVector<S>,
    cfg: &IterConfig,
) -> Result<(DistVector<S>, IterStats<S>)> {
    gmres_ft(ctx, a, b, cfg, None)
}

/// [`gmres`] with snapshot-restart fault tolerance.  GMRES already rebuilds
/// its whole Krylov basis from `x` at every restart, so the natural
/// snapshot is just `x` at each cycle boundary — the policy's period is
/// ignored (the restart length `m` **is** the rework bound: a fault costs
/// at most one replayed cycle plus the snapshot D2H traffic).  `snap`
/// enables snapshotting; with crashes scheduled and `snap = None` a
/// detected crash is an honest [`Error::Runtime`] on every rank.
pub fn gmres_ft<S: Scalar, A: LinOp<S> + ?Sized>(
    ctx: &Ctx<'_, S>,
    a: &A,
    b: &DistVector<S>,
    cfg: &IterConfig,
    snap: Option<CheckpointPolicy>,
) -> Result<(DistVector<S>, IterStats<S>)> {
    let desc = *a.desc();
    let mesh = ctx.mesh;
    let bnorm = pnorm2(ctx, b);
    let mut x = DistVector::zeros(desc, mesh.row(), mesh.col());
    if norm_negligible(bnorm, desc.m) {
        return Ok((x, IterStats::new(0, S::zero(), true)));
    }
    let tol = S::from_f64(cfg.tol).unwrap() * bnorm;
    let m = cfg.restart.max(1);
    let mut total_iters = 0usize;

    let probing = mesh.comm().fault_plan().has_crashes();
    let snapping = snap.is_some();
    let mut saved: Option<(usize, DistVector<S>)> = None;
    let mut just_restored = false;
    loop {
        // Cycle boundary: probe collectively for a crash, rolling x back to
        // the last cycle's snapshot if one hit; otherwise snapshot x.
        if probing && total_iters > 0 && !just_restored && fault_probe(ctx) {
            let Some((sit, sx)) = saved.as_ref() else {
                return Err(Error::Runtime(format!(
                    "gmres: rank crash detected at iteration {total_iters} with no snapshot \
                     (CheckpointPolicy not set)"
                )));
            };
            restore_vec(ctx, &mut x, sx);
            total_iters = *sit;
            just_restored = true;
            continue;
        }
        if snapping && !just_restored {
            let sx = snapshot_vecs(ctx, &[&x]).pop().expect("one snapshot vector");
            saved = Some((total_iters, sx));
        }
        just_restored = false;

        // r = b - A x (fresh residual at each restart), fused with ||r||².
        let (mut r, beta) = residual_fused(ctx, a, b, &x);
        if !beta.is_finite() {
            return Err(Error::NonFinite {
                method: "gmres",
                iteration: total_iters,
                quantity: "||r||",
            });
        }
        if beta <= tol {
            return Ok((x, IterStats::new(total_iters, beta / bnorm, true)));
        }
        if total_iters >= cfg.max_iter {
            return Ok((x, IterStats::new(total_iters, beta / bnorm, false)));
        }

        // Arnoldi with modified Gram-Schmidt.
        let mut basis: Vec<DistVector<S>> = Vec::with_capacity(m + 1);
        pscal(ctx, S::one() / beta, &mut r);
        basis.push(r);
        let mut qr = HessenbergQr::<S>::new(m, beta);
        let mut k = 0usize;
        while k < m && total_iters < cfg.max_iter {
            let mut w = a.apply(ctx, &basis[k]);
            let mut h = Vec::with_capacity(k + 2);
            // MGS against all but the newest basis vector: each h_ij dot
            // reads the previous axpy's w, so these stay unfused.
            for v in basis.iter().take(k) {
                let hij = pdot(ctx, v, &w);
                paxpy(ctx, -hij, v, &mut w);
                h.push(hij);
            }
            // The newest vector's step fuses its axpy with the ||w|| that
            // follows — same axpy, same dot, one kernel and one reduction.
            let hkk = pdot(ctx, &basis[k], &w);
            let wnorm2 = pfused_axpy_norm2(ctx, -hkk, &basis[k], &mut w);
            h.push(hkk);
            let wnorm = wnorm2.sqrt();
            if !wnorm.is_finite() {
                return Err(Error::NonFinite {
                    method: "gmres",
                    iteration: total_iters,
                    quantity: "||w||",
                });
            }
            h.push(wnorm);
            let hscale = h.iter().fold(S::zero(), |acc, &v| acc.max(v.abs()));
            let res = qr.push_column(h);
            total_iters += 1;
            k += 1;
            if negligible_at_scale(wnorm, hscale, desc.m) {
                break; // lucky breakdown: exact solution in the basis
            }
            pscal(ctx, S::one() / wnorm, &mut w);
            basis.push(w);
            if res <= tol {
                break;
            }
        }

        // x += V_k y, y from the triangularised least-squares problem.
        let y = qr.solve();
        for (j, yj) in y.iter().enumerate() {
            paxpy(ctx, *yj, &basis[j], &mut x);
        }
        let res = qr.residual();
        if res <= tol {
            // Confirm with a true residual (restart loop re-checks too).
            let (_r, rnorm) = residual_fused(ctx, a, b, &x);
            if rnorm <= tol {
                return Ok((x, IterStats::new(total_iters, rnorm / bnorm, true)));
            }
        }
        if total_iters >= cfg.max_iter {
            let (_r, rnorm) = residual_fused(ctx, a, b, &x);
            return Ok((x, IterStats::new(total_iters, rnorm / bnorm, rnorm <= tol)));
        }
    }
}
