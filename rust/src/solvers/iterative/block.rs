//! Blocked (multi-RHS) Krylov solvers: k residuals carried through
//! **shared** matvec sweeps — the `A` tiles stream once per iteration for
//! every right-hand side ([`crate::pblas::pgemv_cols`]), the per-iteration
//! reductions ride k-lane allreduces (one tree latency for the batch), and
//! the BLAS-1 chain runs on the column-batched fused kernels (one launch
//! per block per panel instead of one per block per column).
//!
//! **Bit-identity contract**: every column's arithmetic is exactly the
//! looped single-RHS solver's — same recurrence coefficients from the same
//! lane-wise-identical reductions, same fused update kernels, same
//! convergence tests in the same order — so `block_cg` with k columns
//! returns bit-for-bit what k independent [`super::cg()`] calls return
//! (pinned by `tests/multi_rhs.rs`), and batching changes only the *cost*
//! of getting there.  Columns converge independently: a finished column is
//! masked out of every subsequent kernel (convergence masking) without
//! perturbing its neighbours.
//!
//! Per-column tolerances let a serving batch mix accuracy targets (the
//! [`crate::serve`] scheduler groups requests by operator, not tolerance).

use super::{norm_negligible, IterConfig, IterStats};
use crate::dist::DistMultiVector;
use crate::pblas::{
    paxpy_cols, pdot_cols, pfused_axpy_norm2_cols, pfused_axpy_norm2_dot_cols,
    pfused_norm2_dot_cols, pnorm2, pnorm2_cols, pxpay_cols, Ctx, LinOp,
};
use crate::{Error, Result, Scalar};

/// Per-column relative tolerances: `tols[j]` plays the role of
/// [`IterConfig::tol`] for column `j`.
fn check_widths(k: usize, tols: &[f64], what: &str) {
    assert_eq!(k, tols.len(), "{what} per-column tolerance width mismatch");
}

/// Solve `A X = B` (A SPD) for a whole RHS panel from the zero initial
/// guess, one CG recurrence per column through shared matvec sweeps.
/// Returns the solution panel and one [`IterStats`] per column.
pub fn block_cg<S: Scalar, A: LinOp<S> + ?Sized>(
    ctx: &Ctx<'_, S>,
    a: &A,
    b: &DistMultiVector<S>,
    cfg: &IterConfig,
    tols: &[f64],
) -> Result<(DistMultiVector<S>, Vec<IterStats<S>>)> {
    let desc = *a.desc();
    let k = b.ncols();
    check_widths(k, tols, "block_cg");
    let mesh = ctx.mesh;
    let bnorm = pnorm2_cols(ctx, b);
    let mut x = DistMultiVector::zeros(desc, mesh.row(), mesh.col(), k);
    let mut active = vec![true; k];
    let mut stats: Vec<Option<IterStats<S>>> = vec![None; k];
    for j in 0..k {
        if norm_negligible(bnorm[j], desc.m) {
            active[j] = false;
            stats[j] = Some(IterStats::new(0, S::zero(), true));
        }
    }
    let tol: Vec<S> =
        (0..k).map(|j| S::from_f64(tols[j]).unwrap() * bnorm[j]).collect();

    let mut r = b.clone_panel();
    let mut p = r.clone_panel();
    let mut rr = pdot_cols(ctx, &r, &r, &active);

    for it in 0..cfg.max_iter {
        if active.iter().all(|a| !a) {
            break;
        }
        let ap = a.apply_cols(ctx, &p, &active);
        let pap = pdot_cols(ctx, &p, &ap, &active);
        for j in 0..k {
            if active[j] && pap[j] <= S::zero() {
                return Err(Error::Breakdown {
                    method: "block_cg",
                    detail: format!(
                        "p^T A p = {} for column {j} at iteration {it} (matrix not SPD?)",
                        pap[j]
                    ),
                });
            }
        }
        let alpha: Vec<S> =
            (0..k).map(|j| if active[j] { rr[j] / pap[j] } else { S::zero() }).collect();
        paxpy_cols(ctx, &alpha, &p, &mut x, &active);
        // r_j -= alpha_j A p_j and ||r_j||^2, one panel launch per block.
        let neg: Vec<S> = alpha.iter().map(|&a| -a).collect();
        let rr_new = pfused_axpy_norm2_cols(ctx, &neg, &ap, &mut r, &active);
        for j in 0..k {
            if !active[j] {
                continue;
            }
            let rnorm = rr_new[j].sqrt();
            if rnorm <= tol[j] {
                active[j] = false;
                stats[j] = Some(IterStats::new(it + 1, rnorm / bnorm[j], true));
            }
        }
        let beta: Vec<S> =
            (0..k).map(|j| if active[j] { rr_new[j] / rr[j] } else { S::zero() }).collect();
        rr = rr_new;
        pxpay_cols(ctx, &beta, &r, &mut p, &active); // p_j = r_j + beta_j p_j
    }
    for j in 0..k {
        if active[j] {
            ctx.set_tenant(Some(j));
            let rnorm = pnorm2(ctx, r.col(j));
            ctx.set_tenant(None);
            stats[j] = Some(IterStats::new(cfg.max_iter, rnorm / bnorm[j], false));
        }
    }
    Ok((x, stats.into_iter().map(|s| s.expect("every column reported")).collect()))
}

/// Solve `A X = B` (general nonsymmetric) for a whole RHS panel, one
/// BiCGSTAB recurrence per column through shared matvec sweeps.
///
/// "Lite": where the single-RHS [`super::bicgstab()`] aborts the whole
/// solve on a scalar breakdown (`rho = 0`, `r0·v = 0`, `t·t = 0`), the
/// blocked variant *deactivates the affected column* with
/// `converged = false` and lets its batch-mates finish — one pathological
/// right-hand side must not sink a serving batch.  On breakdown-free runs
/// every column is bit-identical to the looped solver (the k = 1 case is
/// pinned by `tests/multi_rhs.rs`).
pub fn block_bicgstab<S: Scalar, A: LinOp<S> + ?Sized>(
    ctx: &Ctx<'_, S>,
    a: &A,
    b: &DistMultiVector<S>,
    cfg: &IterConfig,
    tols: &[f64],
) -> Result<(DistMultiVector<S>, Vec<IterStats<S>>)> {
    let desc = *a.desc();
    let k = b.ncols();
    check_widths(k, tols, "block_bicgstab");
    let mesh = ctx.mesh;
    let bnorm = pnorm2_cols(ctx, b);
    let mut x = DistMultiVector::zeros(desc, mesh.row(), mesh.col(), k);
    let mut active = vec![true; k];
    let mut stats: Vec<Option<IterStats<S>>> = vec![None; k];
    for j in 0..k {
        if norm_negligible(bnorm[j], desc.m) {
            active[j] = false;
            stats[j] = Some(IterStats::new(0, S::zero(), true));
        }
    }
    let tol: Vec<S> =
        (0..k).map(|j| S::from_f64(tols[j]).unwrap() * bnorm[j]).collect();

    let mut r = b.clone_panel();
    let r0 = b.clone_panel(); // shadow residuals
    let mut p = r.clone_panel();
    let mut rho = pdot_cols(ctx, &r0, &r, &active);

    for it in 0..cfg.max_iter {
        if active.iter().all(|a| !a) {
            break;
        }
        for j in 0..k {
            if active[j] && rho[j] == S::zero() {
                // rho breakdown: retire the lane, current residual is r_j.
                active[j] = false;
                ctx.set_tenant(Some(j));
                let rnorm = pnorm2(ctx, r.col(j));
                ctx.set_tenant(None);
                stats[j] = Some(IterStats::new(it, rnorm / bnorm[j], false));
            }
        }
        let v = a.apply_cols(ctx, &p, &active);
        let r0v = pdot_cols(ctx, &r0, &v, &active);
        for j in 0..k {
            if active[j] && r0v[j] == S::zero() {
                active[j] = false;
                ctx.set_tenant(Some(j));
                let rnorm = pnorm2(ctx, r.col(j));
                ctx.set_tenant(None);
                stats[j] = Some(IterStats::new(it, rnorm / bnorm[j], false));
            }
        }
        let alpha: Vec<S> =
            (0..k).map(|j| if active[j] { rho[j] / r0v[j] } else { S::zero() }).collect();
        // s_j = r_j - alpha_j v_j fused with ||s_j||^2.  Fresh clones are
        // host-authoritative: drop aliased device entries first.
        let mut s = r.clone_panel();
        for col in s.cols() {
            for l in 0..col.local_blocks() {
                ctx.host_mut(col.block(l));
            }
        }
        let neg_alpha: Vec<S> = alpha.iter().map(|&a| -a).collect();
        let ss = pfused_axpy_norm2_cols(ctx, &neg_alpha, &v, &mut s, &active);
        // Early convergence at the half step: x_j += alpha_j p_j, done.
        let mut early = vec![false; k];
        for j in 0..k {
            if !active[j] {
                continue;
            }
            let snorm = ss[j].sqrt();
            if snorm <= tol[j] {
                early[j] = true;
                active[j] = false;
                stats[j] = Some(IterStats::new(it + 1, snorm / bnorm[j], true));
            }
        }
        if early.iter().any(|&e| e) {
            paxpy_cols(ctx, &alpha, &p, &mut x, &early);
        }
        let t = a.apply_cols(ctx, &s, &active);
        // (t_j·t_j, t_j·s_j) in one pass and one 2k-lane allreduce.
        let (tt, ts) = pfused_norm2_dot_cols(ctx, &t, &s, &active);
        let mut tt_break = vec![false; k];
        for j in 0..k {
            if active[j] && tt[j] == S::zero() {
                // t·t breakdown: take the half step (residual becomes s_j)
                // and retire the lane unconverged.
                tt_break[j] = true;
                active[j] = false;
                stats[j] = Some(IterStats::new(it + 1, ss[j].sqrt() / bnorm[j], false));
            }
        }
        if tt_break.iter().any(|&e| e) {
            paxpy_cols(ctx, &alpha, &p, &mut x, &tt_break);
        }
        let omega: Vec<S> =
            (0..k).map(|j| if active[j] { ts[j] / tt[j] } else { S::zero() }).collect();
        // x_j += alpha_j p_j + omega_j s_j
        paxpy_cols(ctx, &alpha, &p, &mut x, &active);
        paxpy_cols(ctx, &omega, &s, &mut x, &active);
        // r_j = s_j - omega_j t_j fused with ||r_j||^2 and rho_j = r0_j·r_j.
        // Retire the old residuals' device entries before the buffers drop.
        for col in r.cols() {
            for l in 0..col.local_blocks() {
                ctx.host_mut(col.block(l));
            }
        }
        r = s;
        let neg_omega: Vec<S> = omega.iter().map(|&w| -w).collect();
        let (rr, rho_new) =
            pfused_axpy_norm2_dot_cols(ctx, &neg_omega, &t, &mut r, &r0, &active);
        for j in 0..k {
            if !active[j] {
                continue;
            }
            let rnorm = rr[j].sqrt();
            if rnorm <= tol[j] {
                active[j] = false;
                stats[j] = Some(IterStats::new(it + 1, rnorm / bnorm[j], true));
            }
        }
        let beta: Vec<S> = (0..k)
            .map(|j| {
                if active[j] {
                    (rho_new[j] / rho[j]) * (alpha[j] / omega[j])
                } else {
                    S::zero()
                }
            })
            .collect();
        rho = rho_new;
        // p_j = r_j + beta_j (p_j - omega_j v_j)
        paxpy_cols(ctx, &neg_omega, &v, &mut p, &active);
        pxpay_cols(ctx, &beta, &r, &mut p, &active);
    }
    for j in 0..k {
        if active[j] {
            ctx.set_tenant(Some(j));
            let rnorm = pnorm2(ctx, r.col(j));
            ctx.set_tenant(None);
            stats[j] = Some(IterStats::new(cfg.max_iter, rnorm / bnorm[j], false));
        }
    }
    Ok((x, stats.into_iter().map(|s| s.expect("every column reported")).collect()))
}
