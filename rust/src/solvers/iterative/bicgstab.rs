//! BiCGSTAB — "in our library we've implemented a version of BiCG called
//! BiCGSTAB" (paper §2): the smoothed variant that avoids A^T and BiCG's
//! irregular convergence.

use super::{norm_negligible, IterConfig, IterStats};
use crate::dist::DistVector;
use crate::pblas::{paxpy, pdot, pnorm2, pscal, Ctx, LinOp};
use crate::{Error, Result, Scalar};

/// Solve `A x = b` (general nonsymmetric) from the zero initial guess.
/// `A` is any [`LinOp`] (dense or sparse).
pub fn bicgstab<S: Scalar, A: LinOp<S> + ?Sized>(
    ctx: &Ctx<'_, S>,
    a: &A,
    b: &DistVector<S>,
    cfg: &IterConfig,
) -> Result<(DistVector<S>, IterStats<S>)> {
    let desc = *a.desc();
    let mesh = ctx.mesh;
    let bnorm = pnorm2(ctx, b);
    let mut x = DistVector::zeros(desc, mesh.row(), mesh.col());
    if norm_negligible(bnorm, desc.m) {
        return Ok((x, IterStats::new(0, S::zero(), true)));
    }
    let tol = S::from_f64(cfg.tol).unwrap() * bnorm;

    let mut r = b.clone_vec();
    let r0 = b.clone_vec(); // shadow residual
    let mut p = r.clone_vec();
    let mut rho = pdot(ctx, &r0, &r);

    for it in 0..cfg.max_iter {
        if rho == S::zero() {
            return Err(Error::Breakdown {
                method: "bicgstab",
                detail: format!("rho = 0 at iteration {it}"),
            });
        }
        let v = a.apply(ctx, &p);
        let r0v = pdot(ctx, &r0, &v);
        if r0v == S::zero() {
            return Err(Error::Breakdown {
                method: "bicgstab",
                detail: format!("r0.v = 0 at iteration {it}"),
            });
        }
        let alpha = rho / r0v;
        // s = r - alpha v
        let mut s = r.clone_vec();
        paxpy(ctx, -alpha, &v, &mut s);
        let snorm = pnorm2(ctx, &s);
        if snorm <= tol {
            paxpy(ctx, alpha, &p, &mut x);
            return Ok((x, IterStats::new(it + 1, snorm / bnorm, true)));
        }
        let t = a.apply(ctx, &s);
        let tt = pdot(ctx, &t, &t);
        if tt == S::zero() {
            return Err(Error::Breakdown {
                method: "bicgstab",
                detail: format!("t.t = 0 at iteration {it}"),
            });
        }
        let omega = pdot(ctx, &t, &s) / tt;
        // x += alpha p + omega s
        paxpy(ctx, alpha, &p, &mut x);
        paxpy(ctx, omega, &s, &mut x);
        // r = s - omega t
        r = s;
        paxpy(ctx, -omega, &t, &mut r);
        let rnorm = pnorm2(ctx, &r);
        if rnorm <= tol {
            return Ok((x, IterStats::new(it + 1, rnorm / bnorm, true)));
        }
        let rho_new = pdot(ctx, &r0, &r);
        let beta = (rho_new / rho) * (alpha / omega);
        rho = rho_new;
        // p = r + beta (p - omega v)
        paxpy(ctx, -omega, &v, &mut p);
        pscal(ctx, beta, &mut p);
        paxpy(ctx, S::one(), &r, &mut p);
    }
    let rnorm = pnorm2(ctx, &r);
    Ok((x, IterStats::new(cfg.max_iter, rnorm / bnorm, false)))
}
