//! BiCGSTAB — "in our library we've implemented a version of BiCG called
//! BiCGSTAB" (paper §2): the smoothed variant that avoids A^T and BiCG's
//! irregular convergence.
//!
//! The BLAS-1 chain runs on the fused kernels (`DESIGN.md` §12): the two
//! residual updates fuse with their norm/ρ reductions
//! ([`pfused_axpy_norm2`], [`pfused_axpy_norm2_dot`]), `(⟨t,t⟩, ⟨t,s⟩)`
//! shares one two-lane allreduce, and the `p` recurrence ends in one
//! [`pxpay`] — four reduction latencies per iteration instead of six, with
//! every scalar bit-identical to the unfused sequence's.

use super::{norm_negligible, restore_vec, snapshot_vecs, IterConfig, IterStats};
use crate::comm::CheckpointPolicy;
use crate::dist::DistVector;
use crate::pblas::{
    fault_probe, paxpy, pdot, pfused_axpy_norm2, pfused_axpy_norm2_dot, pfused_norm2_dot,
    pnorm2, pxpay, Ctx, LinOp,
};
use crate::{Error, Result, Scalar};

/// Solve `A x = b` (general nonsymmetric) from the zero initial guess.
/// `A` is any [`LinOp`] (dense or sparse).
pub fn bicgstab<S: Scalar, A: LinOp<S> + ?Sized>(
    ctx: &Ctx<'_, S>,
    a: &A,
    b: &DistVector<S>,
    cfg: &IterConfig,
) -> Result<(DistVector<S>, IterStats<S>)> {
    bicgstab_ft(ctx, a, b, cfg, None)
}

/// [`bicgstab`] with snapshot-restart fault tolerance (see
/// [`super::cg::cg_ft`] for the protocol): the snapshotted recurrence state
/// is `(x, r, p, rho)` — the shadow residual `r0` is constant and needs no
/// snapshot.  A fault costs at most `snap.every_k_panels` replayed
/// iterations plus the snapshot D2H traffic.
pub fn bicgstab_ft<S: Scalar, A: LinOp<S> + ?Sized>(
    ctx: &Ctx<'_, S>,
    a: &A,
    b: &DistVector<S>,
    cfg: &IterConfig,
    snap: Option<CheckpointPolicy>,
) -> Result<(DistVector<S>, IterStats<S>)> {
    let desc = *a.desc();
    let mesh = ctx.mesh;
    let bnorm = pnorm2(ctx, b);
    let mut x = DistVector::zeros(desc, mesh.row(), mesh.col());
    if norm_negligible(bnorm, desc.m) {
        return Ok((x, IterStats::new(0, S::zero(), true)));
    }
    let tol = S::from_f64(cfg.tol).unwrap() * bnorm;

    let mut r = b.clone_vec();
    let r0 = b.clone_vec(); // shadow residual
    let mut p = r.clone_vec();
    let mut rho = pdot(ctx, &r0, &r);

    let probing = mesh.comm().fault_plan().has_crashes();
    let every = snap.map(|c| c.every_k_panels.max(1));
    let mut saved: Option<(usize, DistVector<S>, DistVector<S>, DistVector<S>, S)> = None;
    let mut just_restored = false;
    let mut it = 0usize;
    while it < cfg.max_iter {
        let boundary = every.map_or(probing, |e| it % e == 0);
        if probing && boundary && it > 0 && !just_restored && fault_probe(ctx) {
            let Some((sit, sx, sr, sp, srho)) = saved.as_ref() else {
                return Err(Error::Runtime(format!(
                    "bicgstab: rank crash detected at iteration {it} with no snapshot \
                     (CheckpointPolicy not set)"
                )));
            };
            restore_vec(ctx, &mut x, sx);
            restore_vec(ctx, &mut r, sr);
            restore_vec(ctx, &mut p, sp);
            rho = *srho;
            it = *sit;
            just_restored = true;
            continue;
        }
        if let Some(e) = every {
            if it % e == 0 && !just_restored {
                let mut vs = snapshot_vecs(ctx, &[&x, &r, &p]);
                let sp = vs.pop().unwrap();
                let sr = vs.pop().unwrap();
                let sx = vs.pop().unwrap();
                saved = Some((it, sx, sr, sp, rho));
            }
        }
        just_restored = false;

        if !rho.is_finite() {
            return Err(Error::NonFinite { method: "bicgstab", iteration: it, quantity: "rho" });
        }
        if rho == S::zero() {
            return Err(Error::Breakdown {
                method: "bicgstab",
                detail: format!("rho = 0 at iteration {it}"),
            });
        }
        let v = a.apply(ctx, &p);
        let r0v = pdot(ctx, &r0, &v);
        if !r0v.is_finite() {
            return Err(Error::NonFinite { method: "bicgstab", iteration: it, quantity: "r0'v" });
        }
        if r0v == S::zero() {
            return Err(Error::Breakdown {
                method: "bicgstab",
                detail: format!("r0.v = 0 at iteration {it}"),
            });
        }
        let alpha = rho / r0v;
        // s = r - alpha v, fused with ||s||^2.  The fresh clone's blocks are
        // host-authoritative: drop any aliased device entries first.
        let mut s = r.clone_vec();
        for l in 0..s.local_blocks() {
            ctx.host_mut(s.block(l));
        }
        let snorm = pfused_axpy_norm2(ctx, -alpha, &v, &mut s).sqrt();
        if snorm <= tol {
            paxpy(ctx, alpha, &p, &mut x);
            return Ok((x, IterStats::new(it + 1, snorm / bnorm, true)));
        }
        let t = a.apply(ctx, &s);
        // (t.t, t.s) in one pass and one two-lane allreduce.
        let (tt, ts) = pfused_norm2_dot(ctx, &t, &s);
        if !tt.is_finite() {
            return Err(Error::NonFinite { method: "bicgstab", iteration: it, quantity: "t't" });
        }
        if tt == S::zero() {
            return Err(Error::Breakdown {
                method: "bicgstab",
                detail: format!("t.t = 0 at iteration {it}"),
            });
        }
        let omega = ts / tt;
        // x += alpha p + omega s
        paxpy(ctx, alpha, &p, &mut x);
        paxpy(ctx, omega, &s, &mut x);
        // r = s - omega t, fused with ||r||^2 and the next rho = r0.r.
        // Retire the old residual's device entries before its buffers drop
        // (a later clone could alias the freed allocation).
        for l in 0..r.local_blocks() {
            ctx.host_mut(r.block(l));
        }
        r = s;
        let (rr, rho_new) = pfused_axpy_norm2_dot(ctx, -omega, &t, &mut r, &r0);
        if !rr.is_finite() {
            return Err(Error::NonFinite { method: "bicgstab", iteration: it, quantity: "||r||^2" });
        }
        let rnorm = rr.sqrt();
        if rnorm <= tol {
            return Ok((x, IterStats::new(it + 1, rnorm / bnorm, true)));
        }
        let beta = (rho_new / rho) * (alpha / omega);
        rho = rho_new;
        // p = r + beta (p - omega v)
        paxpy(ctx, -omega, &v, &mut p);
        pxpay(ctx, beta, &r, &mut p);
        it += 1;
    }
    let rnorm = pnorm2(ctx, &r);
    Ok((x, IterStats::new(cfg.max_iter, rnorm / bnorm, false)))
}
