//! Pipelined conjugate gradients (Ghysels & Vanroose, *Hiding global
//! synchronization latency in the preconditioned Conjugate Gradient
//! algorithm*) — CG restructured so each iteration has **one** fused
//! reduction, and that reduction is *overlapped with the matvec* via the
//! split-phase [`crate::comm::AllreduceRequest`].
//!
//! Classic CG pays two blocking allreduces per iteration (`p·Ap` and
//! `r·r`), each a `2·log P` latency wall on a gigabit cluster.  The
//! pipelined recurrence trades them for one fused `(γ, δ) = (⟨r,r⟩, ⟨w,r⟩)`
//! reduction that rides the network while `q = A w` computes, plus three
//! extra vector recurrences (`z`, `s`, `p`) — pure local BLAS-1.  In exact
//! arithmetic the iterates are identical to CG's; in floating point they
//! differ by round-off (the recurrences re-associate the same quantities),
//! which is why this is a separate solver rather than a CG flag.
//!
//! Unpreconditioned, from the zero initial guess, like [`super::cg()`].
//! The (γ, δ) partials and the three recurrences run on the fused BLAS-1
//! kernels (`DESIGN.md` §12): one pass computes both dot partials, one
//! `xpay` pass each replaces the scal + axpy pairs — 5 kernels per
//! iteration where the unfused chain launched 8 per block.

use super::{norm_negligible, IterConfig, IterStats};
use crate::comm::ReduceOp;
use crate::dist::DistVector;
use crate::pblas::{
    paxpy, pcopy, pfused_norm2_dot_partial, pnorm2, pxpay, tags, Ctx, LinOp,
};
use crate::{Error, Result, Scalar};

/// Solve `A x = b` (A SPD) from the zero initial guess with pipelined CG.
pub fn pipecg<S: Scalar, A: LinOp<S> + ?Sized>(
    ctx: &Ctx<'_, S>,
    a: &A,
    b: &DistVector<S>,
    cfg: &IterConfig,
) -> Result<(DistVector<S>, IterStats<S>)> {
    let desc = *a.desc();
    let mesh = ctx.mesh;
    let bnorm = pnorm2(ctx, b);
    let mut x = DistVector::zeros(desc, mesh.row(), mesh.col());
    if norm_negligible(bnorm, desc.m) {
        return Ok((x, IterStats::new(0, S::zero(), true)));
    }
    let tol = S::from_f64(cfg.tol).unwrap() * bnorm;

    let mut r = b.clone_vec(); // x0 = 0
    let mut w = a.apply(ctx, &r);
    let mut z = DistVector::zeros(desc, mesh.row(), mesh.col());
    let mut s = DistVector::zeros(desc, mesh.row(), mesh.col());
    let mut p = DistVector::zeros(desc, mesh.row(), mesh.col());
    let mut gamma_prev = S::zero();
    let mut alpha_prev = S::zero();

    for it in 0..cfg.max_iter {
        // One fused reduction per iteration, overlapped with the matvec;
        // the (γ, δ) partials come from a single fused memory pass too.
        let (gamma_part, delta_part) = pfused_norm2_dot_partial(ctx, &r, &w);
        let partials = vec![gamma_part, delta_part];
        let reduction = mesh.col_comm().iallreduce_vec(tags::PIPECG, partials, ReduceOp::Sum);
        let q = a.apply(ctx, &w); // q = A w rides over the reduction
        let reduced = reduction.wait();
        let (gamma, delta) = (reduced[0], reduced[1]);

        let rnorm = gamma.sqrt();
        if rnorm <= tol {
            return Ok((x, IterStats::new(it, rnorm / bnorm, true)));
        }

        let (alpha, beta) = if it == 0 {
            if delta <= S::zero() {
                return Err(Error::Breakdown {
                    method: "pipecg",
                    detail: format!("w^T r = {delta} at iteration 0 (matrix not SPD?)"),
                });
            }
            (gamma / delta, S::zero())
        } else {
            let beta = gamma / gamma_prev;
            let denom = delta - beta * gamma / alpha_prev;
            if denom <= S::zero() {
                return Err(Error::Breakdown {
                    method: "pipecg",
                    detail: format!(
                        "pipelined p^T A p = {denom} at iteration {it} (matrix not SPD?)"
                    ),
                });
            }
            (gamma / denom, beta)
        };

        if it == 0 {
            pcopy(ctx, &q, &mut z); // z = q
            pcopy(ctx, &w, &mut s); // s = w
            pcopy(ctx, &r, &mut p); // p = r
        } else {
            // z = q + beta z;  s = w + beta s;  p = r + beta p — each a
            // single fused xpay pass instead of a scal + axpy pair.
            pxpay(ctx, beta, &q, &mut z);
            pxpay(ctx, beta, &w, &mut s);
            pxpay(ctx, beta, &r, &mut p);
        }
        paxpy(ctx, alpha, &p, &mut x);
        paxpy(ctx, -alpha, &s, &mut r);
        paxpy(ctx, -alpha, &z, &mut w);
        gamma_prev = gamma;
        alpha_prev = alpha;
    }
    let rnorm = pnorm2(ctx, &r);
    Ok((x, IterStats::new(cfg.max_iter, rnorm / bnorm, false)))
}
