//! The CUPLSS solver API (level 4 of the paper's Figure 2): direct methods
//! (blocked LU with partial pivoting, Cholesky) over 2-D block-cyclic
//! operands, and non-stationary iterative methods (CG, BiCG, BiCGSTAB,
//! GMRES(m)) over any [`LinOp`] operand — dense block-cyclic or sparse
//! row-block CSR (`DESIGN.md` §10) — plus the serial reference
//! implementations.

pub mod direct;
pub mod iterative;
pub mod serial;

pub use direct::{
    apply_pivots, pchol_factor, pchol_factor_ckpt, pchol_refine, pchol_solve,
    pchol_solve_panel, pchol_solve_panel_ckpt, pchol_solve_refined, plu_factor,
    plu_factor_ckpt, plu_refine, plu_solve, plu_solve_panel, plu_solve_panel_ckpt,
    plu_solve_refined, ptrsm, ptrsv, refine_bound, PivotMap, RefineStats, TriKind,
    REFINE_MAX_SWEEPS, REFINE_STAGNATION,
};
pub use iterative::{
    bicg, bicgstab, bicgstab_ft, bicgstab_mixed, block_bicgstab, block_cg, cg, cg_ft, cg_mixed,
    gmres, gmres_ft, pcg, pipecg, schur_cg, BlockJacobiPrecond, IterConfig, IterMethod,
    IterStats, JacobiPrecond, LinOp, Preconditioner, SchurStats,
};
