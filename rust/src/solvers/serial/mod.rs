//! Serial reference solvers — host-side, single-buffer implementations of
//! every method the distributed layer provides.  They serve two roles:
//!
//! 1. **numerical oracles** for the distributed solvers' tests;
//! 2. the **"classic programs written to be run on a single CPU"** the paper
//!    compares against — though for *timing* the baseline is the distributed
//!    code on a 1x1 mesh with the CPU engine (identical arithmetic, zero
//!    communication), which is how the bench harness computes `T_1`.

use crate::linalg::{self, givens::HessenbergQr};
use crate::solvers::iterative::{negligible_at_scale, norm_negligible};
use crate::{Error, Result, Scalar};

/// Iteration outcome (mirrors the distributed `IterStats`).
#[derive(Clone, Copy, Debug)]
pub struct SerialStats<S> {
    /// Iterations performed.
    pub iterations: usize,
    /// Final relative residual.
    pub rel_residual: S,
    /// Tolerance met?
    pub converged: bool,
}

/// Dense LU solve (destroys `a`, overwrites `b` with x).
pub fn lu_solve<S: Scalar>(n: usize, a: &mut [S], b: &mut [S]) -> Result<()> {
    linalg::lu::lu_solve(n, a, b)
}

/// Dense Cholesky solve (destroys `a`, overwrites `b` with x).
pub fn chol_solve<S: Scalar>(n: usize, a: &mut [S], b: &mut [S]) -> Result<()> {
    linalg::potrf(n, a)?;
    linalg::trsv_l(n, a, b);
    linalg::trsv_lt(n, a, b);
    Ok(())
}

fn matvec<S: Scalar>(n: usize, a: &[S], x: &[S], y: &mut [S]) {
    linalg::gemv(n, n, a, x, y);
}

/// Serial CG from the zero guess.
pub fn cg<S: Scalar>(
    n: usize,
    a: &[S],
    b: &[S],
    tol: f64,
    max_iter: usize,
) -> Result<(Vec<S>, SerialStats<S>)> {
    let bnorm = linalg::nrm2(b);
    let mut x = vec![S::zero(); n];
    if norm_negligible(bnorm, n) {
        return Ok((x, SerialStats { iterations: 0, rel_residual: S::zero(), converged: true }));
    }
    let tol = S::from_f64(tol).unwrap() * bnorm;
    let mut r = b.to_vec();
    let mut p = r.clone();
    let mut ap = vec![S::zero(); n];
    let mut rr = linalg::dot(&r, &r);
    for it in 0..max_iter {
        matvec(n, a, &p, &mut ap);
        let pap = linalg::dot(&p, &ap);
        if pap <= S::zero() {
            return Err(Error::Breakdown {
                method: "serial cg",
                detail: format!("pAp = {pap} at iteration {it}"),
            });
        }
        let alpha = rr / pap;
        linalg::axpy(alpha, &p, &mut x);
        linalg::axpy(-alpha, &ap, &mut r);
        let rr_new = linalg::dot(&r, &r);
        if rr_new.sqrt() <= tol {
            return Ok((
                x,
                SerialStats {
                    iterations: it + 1,
                    rel_residual: rr_new.sqrt() / bnorm,
                    converged: true,
                },
            ));
        }
        let beta = rr_new / rr;
        rr = rr_new;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
    }
    let res = linalg::nrm2(&r) / bnorm;
    Ok((x, SerialStats { iterations: max_iter, rel_residual: res, converged: false }))
}

/// Serial BiCG from the zero guess.
pub fn bicg<S: Scalar>(
    n: usize,
    a: &[S],
    b: &[S],
    tol: f64,
    max_iter: usize,
) -> Result<(Vec<S>, SerialStats<S>)> {
    let bnorm = linalg::nrm2(b);
    let mut x = vec![S::zero(); n];
    if norm_negligible(bnorm, n) {
        return Ok((x, SerialStats { iterations: 0, rel_residual: S::zero(), converged: true }));
    }
    let tol = S::from_f64(tol).unwrap() * bnorm;
    let mut r = b.to_vec();
    let mut rt = b.to_vec();
    let mut p = r.clone();
    let mut pt = rt.clone();
    let mut ap = vec![S::zero(); n];
    let mut atpt = vec![S::zero(); n];
    let mut rho = linalg::dot(&rt, &r);
    for it in 0..max_iter {
        if rho == S::zero() {
            return Err(Error::Breakdown {
                method: "serial bicg",
                detail: format!("rho = 0 at iteration {it}"),
            });
        }
        matvec(n, a, &p, &mut ap);
        linalg::gemv_t(n, n, a, &pt, &mut atpt);
        let ptap = linalg::dot(&pt, &ap);
        if ptap == S::zero() {
            return Err(Error::Breakdown {
                method: "serial bicg",
                detail: format!("ptAp = 0 at iteration {it}"),
            });
        }
        let alpha = rho / ptap;
        linalg::axpy(alpha, &p, &mut x);
        linalg::axpy(-alpha, &ap, &mut r);
        linalg::axpy(-alpha, &atpt, &mut rt);
        let rnorm = linalg::nrm2(&r);
        if rnorm <= tol {
            return Ok((
                x,
                SerialStats { iterations: it + 1, rel_residual: rnorm / bnorm, converged: true },
            ));
        }
        let rho_new = linalg::dot(&rt, &r);
        let beta = rho_new / rho;
        rho = rho_new;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
            pt[i] = rt[i] + beta * pt[i];
        }
    }
    let res = linalg::nrm2(&r) / bnorm;
    Ok((x, SerialStats { iterations: max_iter, rel_residual: res, converged: false }))
}

/// Serial BiCGSTAB from the zero guess.
pub fn bicgstab<S: Scalar>(
    n: usize,
    a: &[S],
    b: &[S],
    tol: f64,
    max_iter: usize,
) -> Result<(Vec<S>, SerialStats<S>)> {
    let bnorm = linalg::nrm2(b);
    let mut x = vec![S::zero(); n];
    if norm_negligible(bnorm, n) {
        return Ok((x, SerialStats { iterations: 0, rel_residual: S::zero(), converged: true }));
    }
    let tol = S::from_f64(tol).unwrap() * bnorm;
    let mut r = b.to_vec();
    let r0 = b.to_vec();
    let mut p = r.clone();
    let mut v = vec![S::zero(); n];
    let mut t = vec![S::zero(); n];
    let mut rho = linalg::dot(&r0, &r);
    for it in 0..max_iter {
        if rho == S::zero() {
            return Err(Error::Breakdown {
                method: "serial bicgstab",
                detail: format!("rho = 0 at iteration {it}"),
            });
        }
        matvec(n, a, &p, &mut v);
        let r0v = linalg::dot(&r0, &v);
        if r0v == S::zero() {
            return Err(Error::Breakdown {
                method: "serial bicgstab",
                detail: format!("r0.v = 0 at iteration {it}"),
            });
        }
        let alpha = rho / r0v;
        let mut s = r.clone();
        linalg::axpy(-alpha, &v, &mut s);
        let snorm = linalg::nrm2(&s);
        if snorm <= tol {
            linalg::axpy(alpha, &p, &mut x);
            return Ok((
                x,
                SerialStats { iterations: it + 1, rel_residual: snorm / bnorm, converged: true },
            ));
        }
        matvec(n, a, &s, &mut t);
        let tt = linalg::dot(&t, &t);
        if tt == S::zero() {
            return Err(Error::Breakdown {
                method: "serial bicgstab",
                detail: format!("t.t = 0 at iteration {it}"),
            });
        }
        let omega = linalg::dot(&t, &s) / tt;
        linalg::axpy(alpha, &p, &mut x);
        linalg::axpy(omega, &s, &mut x);
        r = s;
        linalg::axpy(-omega, &t, &mut r);
        let rnorm = linalg::nrm2(&r);
        if rnorm <= tol {
            return Ok((
                x,
                SerialStats { iterations: it + 1, rel_residual: rnorm / bnorm, converged: true },
            ));
        }
        let rho_new = linalg::dot(&r0, &r);
        let beta = (rho_new / rho) * (alpha / omega);
        rho = rho_new;
        for i in 0..n {
            p[i] = r[i] + beta * (p[i] - omega * v[i]);
        }
    }
    let res = linalg::nrm2(&r) / bnorm;
    Ok((x, SerialStats { iterations: max_iter, rel_residual: res, converged: false }))
}

/// Serial restarted GMRES(m) from the zero guess.
pub fn gmres<S: Scalar>(
    n: usize,
    a: &[S],
    b: &[S],
    tol: f64,
    max_iter: usize,
    restart: usize,
) -> Result<(Vec<S>, SerialStats<S>)> {
    let bnorm = linalg::nrm2(b);
    let mut x = vec![S::zero(); n];
    if norm_negligible(bnorm, n) {
        return Ok((x, SerialStats { iterations: 0, rel_residual: S::zero(), converged: true }));
    }
    let tol_abs = S::from_f64(tol).unwrap() * bnorm;
    let m = restart.max(1);
    let mut total = 0usize;
    let mut ax = vec![S::zero(); n];
    loop {
        matvec(n, a, &x, &mut ax);
        let mut r: Vec<S> = b.iter().zip(&ax).map(|(&bi, &ai)| bi - ai).collect();
        let beta = linalg::nrm2(&r);
        if beta <= tol_abs || total >= max_iter {
            return Ok((
                x,
                SerialStats {
                    iterations: total,
                    rel_residual: beta / bnorm,
                    converged: beta <= tol_abs,
                },
            ));
        }
        linalg::scal(S::one() / beta, &mut r);
        let mut basis = vec![r];
        let mut qr = HessenbergQr::<S>::new(m, beta);
        let mut k = 0;
        while k < m && total < max_iter {
            let mut w = vec![S::zero(); n];
            matvec(n, a, &basis[k], &mut w);
            let mut h = Vec::with_capacity(k + 2);
            for v in &basis {
                let hij = linalg::dot(v, &w);
                linalg::axpy(-hij, v, &mut w);
                h.push(hij);
            }
            let wnorm = linalg::nrm2(&w);
            h.push(wnorm);
            let hscale = h.iter().fold(S::zero(), |acc, &v| acc.max(v.abs()));
            let res = qr.push_column(h);
            total += 1;
            k += 1;
            if negligible_at_scale(wnorm, hscale, n) {
                break;
            }
            linalg::scal(S::one() / wnorm, &mut w);
            basis.push(w);
            if res <= tol_abs {
                break;
            }
        }
        let y = qr.solve();
        for (j, yj) in y.iter().enumerate() {
            linalg::axpy(*yj, &basis[j], &mut x);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    fn spd_system(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
        let mut rng = Prng::new(seed);
        let mut g = vec![0.0f64; n * n];
        rng.fill_normal(&mut g);
        let mut a = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += g[i * n + k] * g[j * n + k];
                }
                a[i * n + j] = s;
            }
            a[i * n + i] += n as f64;
        }
        let x_true: Vec<f64> = (0..n).map(|i| (i as f64 * 0.7).sin()).collect();
        let mut b = vec![0.0; n];
        linalg::gemv(n, n, &a, &x_true, &mut b);
        (a, b, x_true)
    }

    fn nonsym_system(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
        let mut rng = Prng::new(seed);
        let mut a = vec![0.0f64; n * n];
        rng.fill_normal(&mut a);
        for i in 0..n {
            a[i * n + i] += n as f64; // diagonally dominant
        }
        let x_true: Vec<f64> = (0..n).map(|i| (i as f64 * 0.3).cos()).collect();
        let mut b = vec![0.0; n];
        linalg::gemv(n, n, &a, &x_true, &mut b);
        (a, b, x_true)
    }

    #[test]
    fn serial_direct_solvers() {
        let n = 40;
        let (a, b, x_true) = spd_system(n, 1);
        let mut af = a.clone();
        let mut xb = b.clone();
        lu_solve(n, &mut af, &mut xb).unwrap();
        for i in 0..n {
            assert!((xb[i] - x_true[i]).abs() < 1e-8, "lu");
        }
        let mut af = a.clone();
        let mut xb = b.clone();
        chol_solve(n, &mut af, &mut xb).unwrap();
        for i in 0..n {
            assert!((xb[i] - x_true[i]).abs() < 1e-8, "chol");
        }
    }

    #[test]
    fn serial_cg_converges() {
        let n = 60;
        let (a, b, x_true) = spd_system(n, 2);
        let (x, st) = cg(n, &a, &b, 1e-12, 400).unwrap();
        assert!(st.converged, "res {}", st.rel_residual);
        for i in 0..n {
            assert!((x[i] - x_true[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn serial_bicg_bicgstab_gmres_converge() {
        let n = 50;
        let (a, b, x_true) = nonsym_system(n, 3);
        let (x, st) = bicg(n, &a, &b, 1e-12, 400).unwrap();
        assert!(st.converged, "bicg res {}", st.rel_residual);
        for i in 0..n {
            assert!((x[i] - x_true[i]).abs() < 1e-6, "bicg");
        }
        let (x, st) = bicgstab(n, &a, &b, 1e-12, 400).unwrap();
        assert!(st.converged, "bicgstab res {}", st.rel_residual);
        for i in 0..n {
            assert!((x[i] - x_true[i]).abs() < 1e-6, "bicgstab");
        }
        let (x, st) = gmres(n, &a, &b, 1e-12, 400, 25).unwrap();
        assert!(st.converged, "gmres res {}", st.rel_residual);
        for i in 0..n {
            assert!((x[i] - x_true[i]).abs() < 1e-6, "gmres");
        }
    }

    #[test]
    fn gmres_restart_shorter_than_needed_still_converges() {
        let n = 50;
        let (a, b, _x) = nonsym_system(n, 4);
        let (_x, st) = gmres(n, &a, &b, 1e-10, 500, 5).unwrap();
        assert!(st.converged, "restarted gmres res {}", st.rel_residual);
    }
}
