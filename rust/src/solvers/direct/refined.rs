//! Mixed-precision direct solves: **factor in the storage dtype `S`,
//! iterate the solution to working (`S::Hi`) accuracy** (DESIGN.md §17).
//!
//! The classic Wilkinson/Moler iterative refinement loop, distributed:
//!
//! 1. factor `A` once in `S` (f32 in a mixed f64 solve — the O(n³) step
//!    runs at the accelerator's single-precision rate and its tiles cross
//!    the wire at half the bytes);
//! 2. solve `A x₀ = b` with the `S` factors;
//! 3. sweep: compute the residual `r = b − A·x` **in `S::Hi`** against the
//!    wide shadow of `A`, solve the correction `A d = r` with the *same*
//!    `S` factors (two triangular substitutions, no refactorisation), and
//!    update `x += d` in `S::Hi`;
//! 4. stop when the componentwise-normwise backward error
//!    `‖r‖∞ / (‖A‖∞‖x‖∞ + ‖b‖∞)` reaches the wide dtype's O(n·u) bound, or
//!    when the residual stops contracting (stagnation — the matrix is too
//!    ill-conditioned for the `S` factors to act as a contraction map; the
//!    cluster layer then falls back to a uniform-`S::Hi` solve).
//!
//! Everything the *factorisation and substitutions* touch stays in `S` —
//! that is the whole point; only the refinement's own small legs are wide:
//! the residual gemv against the `S::Hi` shadow runs host-side at CPU
//! rates (latency-bound BLAS-2, kept off the accelerator exactly like the
//! LU panel `getrf`), and the solution allgather rides the wire as
//! [`Payload::Hi`] — the one full-width message class in an otherwise
//! reduced-precision exchange.  Convergence *scalars* are demoted to `S`
//! for the existing deterministic collectives: `max`/`sum` decisions only
//! need a few digits, and every rank must take the same branch.
//!
//! For `S = f64` (`Hi = Self`) the first residual already meets the bound
//! and the loop exits after zero sweeps with the uniform-precision answer.

use num_traits::{ToPrimitive, Zero};

use super::{apply_pivots, pchol_factor, plu_factor, ptrsv, PivotMap, TriKind};
use crate::accel::{ComputeProfile, OpClass};
use crate::comm::{Payload, ReduceOp, Tag};
use crate::dist::{ptranspose, DistMatrix, DistVector};
use crate::pblas::{tags, Ctx};
use crate::{Result, Scalar};

/// Sweep budget: refinement contracts the error by ~cond(A)·u_S per sweep,
/// so a system the `S` factors can refine at all converges in a handful;
/// ten sweeps without convergence means stagnation was missed only by
/// luck.
pub const REFINE_MAX_SWEEPS: usize = 10;

/// Contraction test: a sweep must at least halve `‖r‖∞`, or the `S`
/// factors are not a contraction for this system and further sweeps are
/// wasted work (Higham, *Accuracy and Stability*, ch. 12).
pub const REFINE_STAGNATION: f64 = 0.5;

/// Backward-error target: `8·n·u` in the wide dtype — the same O(n·u)
/// normwise bound a uniform-`S::Hi` factorisation satisfies, so a
/// converged refined solve is *as backward-stable as the solve it
/// replaced*.
pub fn refine_bound<S: Scalar>(n: usize) -> f64 {
    8.0 * n as f64 * <S::Hi as Scalar>::UNIT_ROUNDOFF
}

/// Outcome of one refined solve.
#[derive(Clone, Copy, Debug)]
pub struct RefineStats {
    /// Correction sweeps applied (0 = the initial solve already met the
    /// bound — always the case for `S = f64`).
    pub sweeps: usize,
    /// Whether the backward-error bound was met.  `false` routes the
    /// cluster layer to the uniform-precision fallback.
    pub converged: bool,
    /// Final normwise backward error `‖r‖∞ / (‖A‖∞‖x‖∞ + ‖b‖∞)`.
    pub backward_err: f64,
}

/// Solve `A x = b` by `S`-precision LU + `S::Hi` iterative refinement.
/// `a_lo` is factored in place (and stays factored — callers can reuse it
/// through [`plu_refine`] for further right-hand sides); `a_hi`/`b_hi` are
/// the wide shadows the residual is computed against.
pub fn plu_solve_refined<S: Scalar>(
    ctx: &Ctx<'_, S>,
    a_lo: &mut DistMatrix<S>,
    a_hi: &DistMatrix<<S as Scalar>::Hi>,
    b_hi: &DistVector<<S as Scalar>::Hi>,
) -> Result<(DistVector<<S as Scalar>::Hi>, RefineStats)> {
    let piv = plu_factor(ctx, a_lo)?;
    plu_refine(ctx, a_lo, &piv, a_hi, b_hi)
}

/// The refinement loop over an **already factored** LU matrix — the
/// factorisation-reuse entry point (serve-layer factor cache, multi-RHS).
pub fn plu_refine<S: Scalar>(
    ctx: &Ctx<'_, S>,
    a_fac: &DistMatrix<S>,
    piv: &PivotMap,
    a_hi: &DistMatrix<<S as Scalar>::Hi>,
    b_hi: &DistVector<<S as Scalar>::Hi>,
) -> Result<(DistVector<<S as Scalar>::Hi>, RefineStats)> {
    refine_with(ctx, a_hi, b_hi, |ctx, rhs| {
        apply_pivots(ctx, piv, rhs);
        ptrsv(ctx, a_fac, rhs, TriKind::LowerUnit)?;
        ptrsv(ctx, a_fac, rhs, TriKind::Upper)
    })
}

/// Solve `A x = b` (SPD) by `S`-precision Cholesky + `S::Hi` refinement.
/// The transpose factor is redistributed **once** and reused by every
/// sweep's backward substitution.
pub fn pchol_solve_refined<S: Scalar>(
    ctx: &Ctx<'_, S>,
    a_lo: &mut DistMatrix<S>,
    a_hi: &DistMatrix<<S as Scalar>::Hi>,
    b_hi: &DistVector<<S as Scalar>::Hi>,
) -> Result<(DistVector<<S as Scalar>::Hi>, RefineStats)> {
    pchol_factor(ctx, a_lo)?;
    let lt = ptranspose(ctx.mesh, a_lo);
    pchol_refine(ctx, a_lo, &lt, a_hi, b_hi)
}

/// The refinement loop over already factored Cholesky factors `L`, `Lᵀ`.
pub fn pchol_refine<S: Scalar>(
    ctx: &Ctx<'_, S>,
    l: &DistMatrix<S>,
    lt: &DistMatrix<S>,
    a_hi: &DistMatrix<<S as Scalar>::Hi>,
    b_hi: &DistVector<<S as Scalar>::Hi>,
) -> Result<(DistVector<<S as Scalar>::Hi>, RefineStats)> {
    refine_with(ctx, a_hi, b_hi, |ctx, rhs| {
        ptrsv(ctx, l, rhs, TriKind::Lower)?;
        ptrsv(ctx, lt, rhs, TriKind::Upper)
    })
}

/// Shared loop: `correct` solves `A d = rhs` in place with the `S`
/// factors (the two substitutions + pivoting of the concrete method).
fn refine_with<S: Scalar>(
    ctx: &Ctx<'_, S>,
    a_hi: &DistMatrix<<S as Scalar>::Hi>,
    b_hi: &DistVector<<S as Scalar>::Hi>,
    mut correct: impl FnMut(&Ctx<'_, S>, &mut DistVector<S>) -> Result<()>,
) -> Result<(DistVector<<S as Scalar>::Hi>, RefineStats)> {
    let desc = *b_hi.desc();
    let mesh = ctx.mesh;

    // Initial solve in the storage dtype: x0 = A_lo^-1 demote(b).
    let mut x_lo = demote_vec(ctx, b_hi);
    correct(ctx, &mut x_lo)?;
    let mut x_hi = DistVector::<<S as Scalar>::Hi>::zeros(desc, mesh.row(), mesh.col());
    add_promoted(ctx, &x_lo, &mut x_hi);

    // Norms of the fixed data, computed once per solve.
    let anorm = inf_norm_a(ctx, a_hi);
    let bnorm = inf_norm_b(ctx, b_hi);
    let bound = refine_bound::<S>(desc.m);
    let berr = |rnorm: f64, xnorm: f64| rnorm / (anorm * xnorm + bnorm).max(f64::MIN_POSITIVE);

    let (mut r, mut rnorm, mut xnorm) = residual(ctx, a_hi, b_hi, &x_hi);
    let mut err = berr(rnorm, xnorm);
    let mut sweeps = 0usize;
    let mut converged = err <= bound;
    while !converged && sweeps < REFINE_MAX_SWEEPS {
        // Correction: A d = r with the existing factors, then x += d wide.
        let mut d = demote_flat(ctx, &r, desc);
        correct(ctx, &mut d)?;
        add_promoted(ctx, &d, &mut x_hi);
        sweeps += 1;
        let (r2, rnorm2, xnorm2) = residual(ctx, a_hi, b_hi, &x_hi);
        let stagnated = rnorm2 > REFINE_STAGNATION * rnorm;
        r = r2;
        rnorm = rnorm2;
        xnorm = xnorm2;
        err = berr(rnorm, xnorm);
        converged = err <= bound;
        if !converged && stagnated {
            break; // not contracting: hand the fallback decision upward
        }
    }
    Ok((x_hi, RefineStats { sweeps, converged, backward_err: err }))
}

// ---------------------------------------------------------------------------
// Wide residual machinery
// ---------------------------------------------------------------------------

/// Residual `r = b − A·x` in `S::Hi` over this rank's tile rows, plus the
/// (globally agreed) `‖r‖∞` and `‖x‖∞`.  Returned residual blocks are
/// replicated across each process row, exactly like a [`DistVector`].
fn residual<S: Scalar>(
    ctx: &Ctx<'_, S>,
    a_hi: &DistMatrix<<S as Scalar>::Hi>,
    b_hi: &DistVector<<S as Scalar>::Hi>,
    x_hi: &DistVector<<S as Scalar>::Hi>,
) -> (Vec<<S as Scalar>::Hi>, f64, f64) {
    let desc = *a_hi.desc();
    let t = desc.tile;
    let mesh = ctx.mesh;
    let zero = <S::Hi as Zero>::zero();

    // 1. Every rank assembles the full wide solution (ring allgather over
    //    the process column — the Payload::Hi leg).
    let x_full = allgather_hi(ctx, x_hi);
    let xnorm = x_full
        .iter()
        .fold(zero, |m, &v| if v.abs() > m { v.abs() } else { m })
        .to_f64()
        .unwrap_or(0.0);

    // 2. Local partials of A·x over the owned tiles (host gemv at CPU
    //    rates: the refinement's O(n²/P) wide leg).
    let my_rows = desc.local_mt(mesh.row()) * t;
    let mut partial = vec![zero; my_rows];
    let mut ntiles = 0u64;
    for (lti, ltj, _ti, tj) in a_hi.owned_tiles() {
        let tile = a_hi.tile(lti, ltj);
        let xs = &x_full[tj * t..(tj + 1) * t];
        for r in 0..t {
            let mut acc = zero;
            let row = &tile[r * t..(r + 1) * t];
            for j in 0..t {
                acc += row[j] * xs[j];
            }
            partial[lti * t + r] += acc;
        }
        ntiles += 1;
    }
    let tb = t * t * <S::Hi as Scalar>::BYTES;
    charge_host::<S>(
        ctx,
        OpClass::Blas2,
        ntiles * 2 * (t as u64) * (t as u64),
        ntiles as usize * (tb + t * <S::Hi as Scalar>::BYTES),
        my_rows * <S::Hi as Scalar>::BYTES,
    );

    // 3. Sum the partials across the process row (ordered gather at the
    //    row root, broadcast back: bitwise-identical blocks row-wide).
    let ax = row_sum_hi(ctx, partial);

    // 4. r = b − A·x; its ∞-norm crosses ranks demoted to `S` (a
    //    convergence decision needs digits, not ulps) through the
    //    deterministic Max tree.
    let mut r = vec![zero; my_rows];
    let mut local_max = zero;
    for l in 0..b_hi.local_blocks() {
        let b_blk = b_hi.block(l);
        for i in 0..t {
            let v = b_blk[i] - ax[l * t + i];
            r[l * t + i] = v;
            if v.abs() > local_max {
                local_max = v.abs();
            }
        }
    }
    charge_host::<S>(
        ctx,
        OpClass::Blas1,
        2 * my_rows as u64,
        2 * my_rows * <S::Hi as Scalar>::BYTES,
        my_rows * <S::Hi as Scalar>::BYTES,
    );
    let col = mesh.col_comm();
    let rnorm = col
        .allreduce_scalar(tags::MIXED + 10, S::from_hi(local_max), ReduceOp::Max)
        .to_f64()
        .unwrap_or(f64::INFINITY);
    (r, rnorm, xnorm)
}

/// Ring allgather of the wide solution over the process column: `pr − 1`
/// steps, each forwarding the chunk received the step before, every
/// message a [`Payload::Hi`] (full-width elements — the refinement's only
/// wide wire traffic).
fn allgather_hi<S: Scalar>(
    ctx: &Ctx<'_, S>,
    x: &DistVector<<S as Scalar>::Hi>,
) -> Vec<<S as Scalar>::Hi> {
    let desc = *x.desc();
    let t = desc.tile;
    let mesh = ctx.mesh;
    let pr = desc.shape.pr;
    let zero = <S::Hi as Zero>::zero();
    let mut full = vec![zero; desc.mt() * t];
    for l in 0..x.local_blocks() {
        let ti = desc.global_ti(mesh.row(), l);
        full[ti * t..(ti + 1) * t].copy_from_slice(x.block(l));
    }
    if pr == 1 {
        return full;
    }
    let col = mesh.col_comm();
    let comm = mesh.comm();
    let me = col.rank();
    let succ = col.world_rank((me + 1) % pr);
    let pred = col.world_rank((me + pr - 1) % pr);
    // Pack my chunk (my process row's blocks, in local order).
    let mut chunk: Vec<<S as Scalar>::Hi> = Vec::with_capacity(desc.local_mt(me) * t);
    for l in 0..desc.local_mt(me) {
        let ti = desc.global_ti(me, l);
        chunk.extend_from_slice(&full[ti * t..(ti + 1) * t]);
    }
    for s in 0..pr - 1 {
        let tag = Tag::P2p(tags::MIXED + s as u32);
        comm.send(succ, tag, Payload::Hi(chunk));
        let incoming = comm.recv(pred, tag).into_hi();
        // The chunk arriving at step s originated at column rank me−1−s
        // (group rank == process row for the column communicator).
        let src_prow = (me + pr - 1 - s) % pr;
        for l in 0..desc.local_mt(src_prow) {
            let ti = desc.global_ti(src_prow, l);
            full[ti * t..(ti + 1) * t].copy_from_slice(&incoming[l * t..(l + 1) * t]);
        }
        chunk = incoming;
    }
    full
}

/// Ordered row-wide sum of wide partials: gather at the row root, sum in
/// ascending column order (one association, so every rank's copy of the
/// result is bitwise identical), broadcast back.
fn row_sum_hi<S: Scalar>(
    ctx: &Ctx<'_, S>,
    partial: Vec<<S as Scalar>::Hi>,
) -> Vec<<S as Scalar>::Hi> {
    let mesh = ctx.mesh;
    let row = mesh.row_comm();
    let pc = row.size();
    if pc == 1 {
        return partial;
    }
    let comm = mesh.comm();
    let me = row.rank();
    let len = partial.len();
    if me == 0 {
        let mut acc = partial;
        for c in 1..pc {
            let inc = comm
                .recv(row.world_rank(c), Tag::P2p(tags::MIXED + 100 + c as u32))
                .into_hi();
            for (a, b) in acc.iter_mut().zip(&inc) {
                *a += *b;
            }
        }
        charge_host::<S>(
            ctx,
            OpClass::Blas1,
            ((pc - 1) * len) as u64,
            pc * len * <S::Hi as Scalar>::BYTES,
            len * <S::Hi as Scalar>::BYTES,
        );
        for c in 1..pc {
            comm.send(
                row.world_rank(c),
                Tag::P2p(tags::MIXED + 200 + c as u32),
                Payload::Hi(acc.clone()),
            );
        }
        acc
    } else {
        comm.send(
            row.world_rank(0),
            Tag::P2p(tags::MIXED + 100 + me as u32),
            Payload::Hi(partial),
        );
        comm.recv(row.world_rank(0), Tag::P2p(tags::MIXED + 200 + me as u32)).into_hi()
    }
}

/// `‖A‖∞` of the wide shadow: local row sums, summed across the process
/// row and maxed across rows — demoted to `S` for the deterministic
/// collectives (a bound denominator needs digits, not ulps).
fn inf_norm_a<S: Scalar>(ctx: &Ctx<'_, S>, a_hi: &DistMatrix<<S as Scalar>::Hi>) -> f64 {
    let desc = *a_hi.desc();
    let t = desc.tile;
    let mesh = ctx.mesh;
    let zero = <S::Hi as Zero>::zero();
    let my_rows = desc.local_mt(mesh.row()) * t;
    let mut sums = vec![zero; my_rows];
    let mut ntiles = 0u64;
    for (lti, ltj, _ti, _tj) in a_hi.owned_tiles() {
        let tile = a_hi.tile(lti, ltj);
        for r in 0..t {
            let mut acc = zero;
            for j in 0..t {
                acc += tile[r * t + j].abs();
            }
            sums[lti * t + r] += acc;
        }
        ntiles += 1;
    }
    charge_host::<S>(
        ctx,
        OpClass::Blas1,
        ntiles * (t as u64) * (t as u64),
        ntiles as usize * t * t * <S::Hi as Scalar>::BYTES,
        my_rows * <S::Hi as Scalar>::BYTES,
    );
    let row = mesh.row_comm();
    let narrow: Vec<S> = sums.iter().map(|&h| S::from_hi(h)).collect();
    let summed = row.allreduce_vec(tags::MIXED + 11, narrow, ReduceOp::Sum);
    let local_max = summed.iter().fold(S::zero(), |m, &v| if v > m { v } else { m });
    let col = mesh.col_comm();
    col.allreduce_scalar(tags::MIXED + 12, local_max, ReduceOp::Max)
        .to_f64()
        .unwrap_or(f64::INFINITY)
}

/// `‖b‖∞` (blocks replicated across the process row: only the column
/// reduction crosses distinct data).
fn inf_norm_b<S: Scalar>(ctx: &Ctx<'_, S>, b_hi: &DistVector<<S as Scalar>::Hi>) -> f64 {
    let zero = <S::Hi as Zero>::zero();
    let mut local_max = zero;
    for l in 0..b_hi.local_blocks() {
        for &v in b_hi.block(l).iter() {
            if v.abs() > local_max {
                local_max = v.abs();
            }
        }
    }
    let col = ctx.mesh.col_comm();
    col.allreduce_scalar(tags::MIXED + 13, S::from_hi(local_max), ReduceOp::Max)
        .to_f64()
        .unwrap_or(f64::INFINITY)
}

// ---------------------------------------------------------------------------
// Demote / promote passes (the mixed path's byte savings are born here)
// ---------------------------------------------------------------------------

/// `demote(b)`: a fresh `S`-storage right-hand side.  Fresh allocations
/// are retired through `host_mut` so a recycled address can never alias a
/// stale device-residency entry.
fn demote_vec<S: Scalar>(
    ctx: &Ctx<'_, S>,
    src: &DistVector<<S as Scalar>::Hi>,
) -> DistVector<S> {
    let desc = *src.desc();
    let mesh = ctx.mesh;
    let mut out = DistVector::<S>::zeros(desc, mesh.row(), mesh.col());
    let mut elems = 0usize;
    for l in 0..out.local_blocks() {
        let s = src.block(l);
        let d = out.block_mut(l);
        for (di, &si) in d.iter_mut().zip(s.iter()) {
            *di = S::from_hi(si);
        }
        elems += d.len();
        ctx.host_mut(out.block(l));
    }
    charge_demote::<S>(ctx, elems);
    out
}

/// Demote the flat wide residual into a distributed `S` right-hand side.
fn demote_flat<S: Scalar>(
    ctx: &Ctx<'_, S>,
    r: &[<S as Scalar>::Hi],
    desc: crate::dist::Descriptor,
) -> DistVector<S> {
    let t = desc.tile;
    let mesh = ctx.mesh;
    let mut out = DistVector::<S>::zeros(desc, mesh.row(), mesh.col());
    let mut elems = 0usize;
    for l in 0..out.local_blocks() {
        let d = out.block_mut(l);
        for (i, di) in d.iter_mut().enumerate() {
            *di = S::from_hi(r[l * t + i]);
        }
        elems += d.len();
        ctx.host_mut(out.block(l));
    }
    charge_demote::<S>(ctx, elems);
    out
}

/// `x_hi += promote(d)` over the owned blocks (exact widening).
fn add_promoted<S: Scalar>(
    ctx: &Ctx<'_, S>,
    d: &DistVector<S>,
    x_hi: &mut DistVector<<S as Scalar>::Hi>,
) {
    let mut elems = 0usize;
    for l in 0..x_hi.local_blocks() {
        let src = d.block(l);
        let dst = x_hi.block_mut(l);
        for (xi, &si) in dst.iter_mut().zip(src) {
            *xi += si.to_hi();
        }
        elems += src.len();
    }
    charge_host::<S>(
        ctx,
        OpClass::Blas1,
        elems as u64,
        elems * (S::BYTES + <S::Hi as Scalar>::BYTES),
        elems * <S::Hi as Scalar>::BYTES,
    );
}

fn charge_demote<S: Scalar>(ctx: &Ctx<'_, S>, elems: usize) {
    charge_host::<S>(
        ctx,
        OpClass::Blas1,
        elems as u64,
        elems * <S::Hi as Scalar>::BYTES,
        elems * S::BYTES,
    );
}

/// The refinement's wide legs run host-side at CPU rates — the same
/// convention as the LU panel `getrf` (latency-bound work stays off the
/// accelerator; see `lu.rs`).
fn charge_host<S: Scalar>(ctx: &Ctx<'_, S>, class: OpClass, flops: u64, read: usize, write: usize) {
    let profile = ComputeProfile::q6600_atlas();
    ctx.charge(profile.op_cost::<<S as Scalar>::Hi>(class, flops, read, write));
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::accel::CpuEngine;
    use crate::comm::{NetworkModel, World};
    use crate::dist::Descriptor;
    use crate::mesh::{Mesh, MeshShape};

    fn nonsym(n: usize) -> impl Fn(usize, usize) -> f64 + Clone + Send + Sync {
        move |i, j| {
            let v = (((i * 13 + j * 29 + 7) % 101) as f64) / 101.0 - 0.5;
            if i == j {
                n as f64 + 1.0 + v
            } else {
                v
            }
        }
    }

    fn spd(n: usize) -> impl Fn(usize, usize) -> f64 + Clone + Send + Sync {
        move |i, j| {
            let base = (((i * 37 + j * 61) % 97) as f64) / 97.0 - 0.5;
            let sym = base + ((((j * 37 + i * 61) % 97) as f64) / 97.0 - 0.5);
            if i == j {
                2.0 * n as f64 + sym
            } else {
                sym * 0.5
            }
        }
    }

    fn xt(j: usize) -> f64 {
        ((j as f64) * 0.21).sin() + 1.0
    }

    fn rhs(n: usize, elem: &impl Fn(usize, usize) -> f64, i: usize) -> f64 {
        (0..n).map(|j| elem(i, j) * xt(j)).sum()
    }

    /// Refined f32-factor solves reach the *f64* backward-error bound —
    /// the result the mixed path promises — on square and ragged meshes.
    #[test]
    fn refined_lu_and_chol_reach_f64_accuracy_from_f32_factors() {
        for &(pr, pc, n) in &[(1usize, 1usize, 32usize), (2, 2, 45), (2, 3, 45)] {
            for &which in &["lu", "chol"] {
                let out = World::run::<f32, _, _>(
                    pr * pc,
                    NetworkModel::gigabit_ethernet(),
                    move |comm| {
                        let mesh = Mesh::new(&comm, MeshShape::new(pr, pc));
                        let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(8)));
                        let desc = Descriptor::new(n, n, 8, mesh.shape());
                        let spd_mat = which == "chol";
                        let elem = move |i: usize, j: usize| {
                            if spd_mat {
                                spd(n)(i, j)
                            } else {
                                nonsym(n)(i, j)
                            }
                        };
                        let a_hi =
                            DistMatrix::<f64>::from_fn(desc, mesh.row(), mesh.col(), elem);
                        let b_hi = DistVector::<f64>::from_fn(desc, mesh.row(), mesh.col(), |i| {
                            rhs(n, &elem, i)
                        });
                        let mut a_lo = DistMatrix::<f32>::from_fn(
                            desc,
                            mesh.row(),
                            mesh.col(),
                            move |i, j| elem(i, j) as f32,
                        );
                        let (x, st) = if which == "lu" {
                            plu_solve_refined(&ctx, &mut a_lo, &a_hi, &b_hi).unwrap()
                        } else {
                            pchol_solve_refined(&ctx, &mut a_lo, &a_hi, &b_hi).unwrap()
                        };
                        // Per-rank worst error of the owned wide blocks.
                        let mut worst = 0.0f64;
                        for l in 0..x.local_blocks() {
                            let ti = desc.global_ti(mesh.row(), l);
                            for (i, &v) in x.block(l).iter().enumerate() {
                                let g = ti * desc.tile + i;
                                if g < n {
                                    worst = worst.max((v - xt(g)).abs());
                                }
                            }
                        }
                        (st.sweeps, st.converged, st.backward_err, worst)
                    },
                );
                for (sweeps, converged, berr, worst) in out {
                    assert!(converged, "{which} {pr}x{pc}: berr {berr}");
                    assert!(sweeps >= 1, "{which}: f32 factors need at least one sweep");
                    assert!(berr <= refine_bound::<f32>(n), "{which}: berr {berr}");
                    // Forward error far beyond f32 (eps32 ~ 6e-8, err*cond).
                    assert!(worst < 1e-10, "{which} {pr}x{pc}: worst {worst}");
                }
            }
        }
    }

    /// For `S = f64` (`Hi = Self`) the initial solve already meets the
    /// bound: zero sweeps, answer is the uniform-precision solve's.
    #[test]
    fn refined_in_an_f64_world_is_the_plain_solve_with_zero_sweeps() {
        let n = 32;
        let out = World::run::<f64, _, _>(4, NetworkModel::gigabit_ethernet(), move |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(2, 2));
            let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(8)));
            let desc = Descriptor::new(n, n, 8, mesh.shape());
            let elem = nonsym(n);
            let a_hi = DistMatrix::<f64>::from_fn(desc, mesh.row(), mesh.col(), elem.clone());
            let b_hi = DistVector::<f64>::from_fn(desc, mesh.row(), mesh.col(), {
                let elem = elem.clone();
                move |i| rhs(n, &elem, i)
            });
            let mut a_lo = DistMatrix::<f64>::from_fn(desc, mesh.row(), mesh.col(), elem);
            let (_, st) = plu_solve_refined(&ctx, &mut a_lo, &a_hi, &b_hi).unwrap();
            (st.sweeps, st.converged, st.backward_err)
        });
        for (sweeps, converged, berr) in out {
            assert!(converged);
            assert_eq!(sweeps, 0, "f64 factors meet the f64 bound immediately");
            assert!(berr <= refine_bound::<f64>(n));
        }
    }

    /// A matrix too ill-conditioned for f32 factors must NOT be reported
    /// converged — the stagnation guard is the cluster fallback's trigger.
    #[test]
    fn ill_conditioned_system_trips_the_stagnation_guard() {
        let n = 24;
        let out = World::run::<f32, _, _>(1, NetworkModel::gigabit_ethernet(), move |comm| {
            let mesh = Mesh::new(&comm, MeshShape::new(1, 1));
            let ctx = Ctx::new(&mesh, Arc::new(CpuEngine::new(8)));
            let desc = Descriptor::new(n, n, 8, mesh.shape());
            // Hilbert matrix: cond ~ e^{3.5 n} — hopeless for f32 factors.
            let elem = |i: usize, j: usize| 1.0 / ((i + j + 1) as f64);
            let a_hi = DistMatrix::<f64>::from_fn(desc, mesh.row(), mesh.col(), elem);
            let b_hi =
                DistVector::<f64>::from_fn(desc, mesh.row(), mesh.col(), |i| {
                    (0..n).map(|j| elem(i, j) * xt(j)).sum()
                });
            let mut a_lo = DistMatrix::<f32>::from_fn(desc, mesh.row(), mesh.col(), move |i, j| {
                elem(i, j) as f32
            });
            match plu_solve_refined(&ctx, &mut a_lo, &a_hi, &b_hi) {
                Ok((_, st)) => !st.converged,
                Err(_) => true, // factorisation breakdown is also a fallback
            }
        });
        assert!(out[0], "refinement claimed convergence on a Hilbert system");
    }

    #[test]
    fn bound_scales_with_n_and_the_wide_roundoff() {
        assert!(refine_bound::<f32>(1000) == refine_bound::<f64>(1000));
        assert!(refine_bound::<f64>(2000) > refine_bound::<f64>(1000));
        assert!(refine_bound::<f64>(60_000) < 1e-10);
    }
}
