//! Distributed triangular substitutions over a factored block-cyclic matrix.
//!
//! Column-fan-out algorithm, same shape for forward and backward: at tile
//! step `k` the diagonal owner solves its `tile x tile` system on its local
//! replica of the rhs block, the solution broadcasts world-wide, the tiles of
//! column `k` broadcast along their process rows, and every rank downdates
//! its own (column-replicated) rhs blocks with the engine's fused
//! `gemv_update`.  O(n²) work next to the O(n³) factorisation — the paper's
//! "second step" — with O(n² log pc) broadcast volume.
//!
//! All tile-op charges route through [`Ctx::charge_op`] (the ROADMAP's
//! "remaining copy-per-call paths" item): with residency the rhs blocks
//! stay device-resident across the `kt` downdate steps and the factor
//! tiles across repeated solves, instead of paying the paper's per-call
//! stream; broadcast payload reads / host writes follow the standard
//! invalidation rules, and transient broadcast buffers are retired before
//! they drop (DESIGN.md §12–§13).

use crate::comm::Payload;
use crate::dist::{DistMatrix, DistVector};
use crate::pblas::{tags, Ctx};
use crate::{Result, Scalar};

/// Which triangle / diagonal convention to substitute with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TriKind {
    /// L with implicit unit diagonal (LU's L factor), forward order.
    LowerUnit,
    /// L with stored diagonal (Cholesky's L), forward order.
    Lower,
    /// U with stored diagonal (LU's U / transposed Cholesky), backward order.
    Upper,
}

/// Solve `T y = b` in place (`b` becomes `y`), `T` taken from the
/// corresponding triangle of the factored matrix `a`.
pub fn ptrsv<S: Scalar>(
    ctx: &Ctx<'_, S>,
    a: &DistMatrix<S>,
    b: &mut DistVector<S>,
    kind: TriKind,
) -> Result<()> {
    let desc = *a.desc();
    let kt = desc.mt();
    let mesh = ctx.mesh;
    let comm = mesh.comm();
    let (pr, pc) = (desc.shape.pr, desc.shape.pc);

    let steps: Vec<usize> = match kind {
        TriKind::LowerUnit | TriKind::Lower => (0..kt).collect(),
        TriKind::Upper => (0..kt).rev().collect(),
    };

    for &k in &steps {
        let ck = k % pc;
        let rk = k % pr;
        let diag_rank = desc.shape.rank_at(rk, ck);

        // 1. Diagonal solve on the owner, world broadcast of y(k).
        let yk_payload = if comm.rank() == diag_rank {
            let diag = a.global_tile(k, k);
            let blk = b.global_block_mut(k);
            let cost = match kind {
                TriKind::LowerUnit => ctx.engine.trsv_lu(diag, blk)?,
                TriKind::Lower => ctx.engine.trsv_l(diag, blk)?,
                TriKind::Upper => ctx.engine.trsv_u(diag, blk)?,
            };
            let blk = b.global_block(k);
            ctx.charge_op(cost, &[a.global_tile(k, k), blk], Some(blk));
            // The broadcast payload is a host read of the solved block.
            ctx.host_read(blk);
            Some(Payload::Data(blk.to_vec()))
        } else {
            None
        };
        let world = comm.world();
        let yk = world.bcast(diag_rank, tags::TRSV, yk_payload).into_data();
        if b.owns(k) && comm.rank() != diag_rank {
            b.global_block_mut(k).copy_from_slice(&yk);
            ctx.host_mut(b.global_block(k)); // fresh host data
        }

        // 2. Column-k tiles broadcast along process rows; every rank
        //    downdates its replica blocks.  With residency the rhs blocks
        //    stay device-resident (and dirty) across the kt steps; the
        //    broadcast tile is a transient buffer, retired before it drops.
        let row = mesh.row_comm();
        for lti in 0..a.local_mt() {
            let ti = desc.global_ti(mesh.row(), lti);
            let active = match kind {
                TriKind::LowerUnit | TriKind::Lower => ti > k,
                TriKind::Upper => ti < k,
            };
            if !active {
                continue;
            }
            let data = if mesh.col() == ck {
                ctx.host_read(a.tile(lti, desc.local_tj(k)));
                Some(Payload::Data(a.tile(lti, desc.local_tj(k)).to_vec()))
            } else {
                None
            };
            let tile = row.bcast(ck, tags::TRSV + 1, data).into_data();
            let cost = ctx.engine.gemv_update(b.global_block_mut(ti), &tile, &yk)?;
            let blk = b.global_block(ti);
            ctx.charge_op(cost, &[blk, &tile, &yk], Some(blk));
            ctx.host_mut(&tile);
        }
        ctx.host_mut(&yk);
    }
    // The solver hands the finished vector back to the host (payload
    // gathers, residual checks): flush every block's pending write-back.
    for l in 0..b.local_blocks() {
        ctx.host_read(b.block(l));
    }
    Ok(())
}
