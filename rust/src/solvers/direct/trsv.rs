//! Distributed triangular substitutions over a factored block-cyclic matrix.
//!
//! Column-fan-out algorithm, same shape for forward and backward: at tile
//! step `k` the diagonal owner solves its `tile x tile` system on its local
//! replica of the rhs block, the solution broadcasts world-wide, the tiles of
//! column `k` broadcast along their process rows, and every rank downdates
//! its own (column-replicated) rhs blocks with the engine's fused
//! `gemv_update`.  O(n²) work next to the O(n³) factorisation — the paper's
//! "second step" — with O(n² log pc) broadcast volume.

use crate::comm::Payload;
use crate::dist::{DistMatrix, DistVector};
use crate::pblas::{tags, Ctx};
use crate::{Result, Scalar};

/// Which triangle / diagonal convention to substitute with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TriKind {
    /// L with implicit unit diagonal (LU's L factor), forward order.
    LowerUnit,
    /// L with stored diagonal (Cholesky's L), forward order.
    Lower,
    /// U with stored diagonal (LU's U / transposed Cholesky), backward order.
    Upper,
}

/// Solve `T y = b` in place (`b` becomes `y`), `T` taken from the
/// corresponding triangle of the factored matrix `a`.
pub fn ptrsv<S: Scalar>(
    ctx: &Ctx<'_, S>,
    a: &DistMatrix<S>,
    b: &mut DistVector<S>,
    kind: TriKind,
) -> Result<()> {
    let desc = *a.desc();
    let kt = desc.mt();
    let mesh = ctx.mesh;
    let comm = mesh.comm();
    let (pr, pc) = (desc.shape.pr, desc.shape.pc);

    let steps: Vec<usize> = match kind {
        TriKind::LowerUnit | TriKind::Lower => (0..kt).collect(),
        TriKind::Upper => (0..kt).rev().collect(),
    };

    for &k in &steps {
        let ck = k % pc;
        let rk = k % pr;
        let diag_rank = desc.shape.rank_at(rk, ck);

        // 1. Diagonal solve on the owner, world broadcast of y(k).
        let yk_payload = if comm.rank() == diag_rank {
            let diag = a.global_tile(k, k);
            let blk = b.global_block_mut(k);
            let cost = match kind {
                TriKind::LowerUnit => ctx.engine.trsv_lu(diag, blk)?,
                TriKind::Lower => ctx.engine.trsv_l(diag, blk)?,
                TriKind::Upper => ctx.engine.trsv_u(diag, blk)?,
            };
            ctx.charge(cost);
            Some(Payload::Data(blk.clone()))
        } else {
            None
        };
        let world = comm.world();
        let yk = world.bcast(diag_rank, tags::TRSV, yk_payload).into_data();
        if b.owns(k) {
            b.global_block_mut(k).copy_from_slice(&yk);
        }

        // 2. Column-k tiles broadcast along process rows; every rank
        //    downdates its replica blocks.
        let row = mesh.row_comm();
        for lti in 0..a.local_mt() {
            let ti = desc.global_ti(mesh.row(), lti);
            let active = match kind {
                TriKind::LowerUnit | TriKind::Lower => ti > k,
                TriKind::Upper => ti < k,
            };
            if !active {
                continue;
            }
            let data = if mesh.col() == ck {
                Some(Payload::Data(a.tile(lti, desc.local_tj(k)).to_vec()))
            } else {
                None
            };
            let tile = row.bcast(ck, tags::TRSV + 1, data).into_data();
            let cost = ctx.engine.gemv_update(b.global_block_mut(ti), &tile, &yk)?;
            ctx.charge(cost);
        }
    }
    Ok(())
}
