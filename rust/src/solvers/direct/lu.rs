//! Distributed right-looking block LU with partial pivoting — the paper's
//! primary direct method ("the most important computational step being the
//! matrix factorization", §2) — with **depth-1 lookahead**.
//!
//! Per tile step `k` (panel = tile column k, tile rows k..KT), the panel
//! work of step `k+1` is performed *inside* step `k`, between the panel-k
//! column update and the trailing update, so the panel critical path
//! (gather → host `getrf` → scatter → pivot broadcast → L21 broadcasts)
//! rides the network and the diagonal owner's CPU while every other rank is
//! busy with step `k`'s rank-T update (the HPL-style lookahead; DESIGN.md
//! §11).  Concretely, one iteration runs:
//!
//! 1. **pivot wait** — complete the split-phase pivot broadcast started
//!    when panel `k` was factored (during step `k-1`'s trailing update);
//! 2. **row swaps** — every column outside the panel applies the
//!    interchanges (the distributed `laswp`);
//! 3. **U12 row** — the diagonal tile broadcasts along its process row; the
//!    owners of tile row k solve `L11 · U12 = A(k, j)` with `trsm_llu`,
//!    then U12 tiles broadcast along process columns;
//! 4. **L21 wait** — complete the split-phase L21 row broadcasts (also in
//!    flight since panel `k` was factored);
//! 5. **lookahead** — update *only* tile column `k+1` with panel `k`, then
//!    factor panel `k+1` (gather → `getrf` → scatter) and put its pivot
//!    broadcast and L21 row broadcasts on the wire, split-phase;
//! 6. **trailing update** — the delayed rank-T update
//!    `A(i,j) -= L(i,k) · U(k,j)` on the remaining trailing tiles
//!    (`j > k+1`) via the engine's fused `gemm_update` — the BLAS-3 hot
//!    spot that now hides step `k+1`'s panel path.
//!
//! On the accelerated arm the trailing sweep additionally prefetches the
//! next tile's operands onto the copy-engine timeline ([`Ctx::prefetch`]),
//! so the surviving PCIe streams (panel first touch, swap-invalidated
//! trailing tiles) hide under the gemm stream — compounding with the comm
//! lookahead (DESIGN.md §13).
//!
//! The operation *set* (and therefore every floating-point result) is
//! identical to the non-lookahead schedule: each tile still receives its
//! updates in ascending `k` order, swaps are applied after the update of
//! the step that produced them and before the next one, and the panel
//! factorisation sees exactly the same bytes.
//!
//! Padding: the panel's *real* sub-block (`getrf_lda`) is factored so the
//! identity padding of the last tile row/column is preserved — the padded
//! factorisation embeds the original exactly (see `dist::descriptor`).

use crate::comm::{BcastRequest, Payload, Tag};
use crate::dist::DistMatrix;
use crate::pblas::{fault_probe, tags, Ctx};
use crate::{linalg, Error, Result, Scalar};

/// Pivot record of one factorisation: `swaps[g] = p` means global rows
/// `g` and `p` were exchanged at elimination step `g` (applied in order).
#[derive(Clone, Debug, Default)]
pub struct PivotMap {
    swaps: Vec<(usize, usize)>,
}

impl PivotMap {
    /// Rebuild a pivot map from a recorded swap list (factor-cache restore:
    /// the serve layer replays a cached factorization's pivots against a
    /// fresh right-hand side without re-running `getrf`).
    pub fn from_swaps(swaps: Vec<(usize, usize)>) -> Self {
        PivotMap { swaps }
    }

    /// The ordered swap list.
    pub fn swaps(&self) -> &[(usize, usize)] {
        &self.swaps
    }

    /// Apply to a plain host vector (serial verification path).
    pub fn apply_host<S: Scalar>(&self, b: &mut [S]) {
        for &(g1, g2) in &self.swaps {
            b.swap(g1, g2);
        }
    }
}

/// Split-phase state of one factored panel: its pivot broadcast and its L21
/// row broadcasts, all started the moment the panel left the host `getrf`.
struct PanelInFlight<'a, S: Scalar> {
    /// World broadcast of the panel's global pivot rows.
    piv: BcastRequest<'a, S>,
    /// Per local tile row: the row-communicator broadcast of L(ti, k)
    /// (`None` for tile rows at or above the panel).
    l21: Vec<Option<BcastRequest<'a, S>>>,
}

/// Gather panel `k` to the diagonal owner, factor it on the host, scatter
/// the factored tiles back, and start the split-phase pivot + L21
/// broadcasts.  Mirrors steps 1–3 of the classic schedule; the broadcasts
/// ride the network while the caller returns to trailing-update work.
/// Also returns the diagonal owner's copy of the panel's global pivot rows
/// (empty elsewhere), so a checkpoint taken while the panel is in flight
/// can re-post the pivot broadcast without re-factoring ([`repost_panel`]).
fn factor_panel<'a, S: Scalar>(
    ctx: &Ctx<'a, S>,
    a: &mut DistMatrix<S>,
    k: usize,
) -> Result<(PanelInFlight<'a, S>, Vec<i64>)> {
    let desc = *a.desc();
    let t = desc.tile;
    let kt = desc.mt();
    let mesh = ctx.mesh;
    let comm = mesh.comm();
    let (pr, pc) = (desc.shape.pr, desc.shape.pc);
    let ck = k % pc;
    let rk = k % pr;
    let diag_rank = desc.shape.rank_at(rk, ck);
    let in_panel_col = mesh.col() == ck;
    let panel_tiles = kt - k;

    // Real (unpadded) extent of the panel.
    let m_real = desc.m - k * t; // rows below the panel top
    let n_real = m_real.min(t); // panel width

    // --- gather panel to the diagonal owner --------------------------------
    // The panel tiles carry trailing updates computed on the device; the
    // host observing them (copy / message payload) ends their dirty
    // periods (residency rules, DESIGN.md §12).
    let panel_tag = |ti: usize| Tag::P2p(tags::LU + 10 + ti as u32);
    let mut panel: Vec<S> = Vec::new();
    if comm.rank() == diag_rank {
        panel = vec![S::zero(); panel_tiles * t * t];
        for ti in k..kt {
            let src = desc.shape.rank_at(ti % pr, ck);
            let dst_off = (ti - k) * t * t;
            if src == comm.rank() {
                ctx.host_read(a.global_tile(ti, k));
                panel[dst_off..dst_off + t * t].copy_from_slice(a.global_tile(ti, k));
            } else {
                let data = comm.recv(src, panel_tag(ti)).into_data();
                panel[dst_off..dst_off + t * t].copy_from_slice(&data);
            }
        }
    } else if in_panel_col {
        for ti in k..kt {
            if a.owns_tile_row(ti) {
                // Pinned-buffer staging (`DESIGN.md` §16): under GPUDirect a
                // device-dirty panel tile leaves straight off the device —
                // the D2H leg rides the copy engine jointly with the NIC
                // occupancy instead of the blocking host_read barrier.
                let leg = ctx.wire_read(a.global_tile(ti, k)).pcie_secs();
                comm.isend_wire(
                    diag_rank,
                    panel_tag(ti),
                    Payload::Data(a.global_tile(ti, k).to_vec()),
                    leg,
                )
                .wait();
            }
        }
    }

    // --- factor the real sub-panel on the diagonal owner -------------------
    // (host-side: pivot search is latency-bound, kept on CPU as in
    // MAGMA-style hybrid factorisations; cost charged at CPU rates.)
    let mut piv_global: Vec<i64> = Vec::new();
    if comm.rank() == diag_rank {
        let piv = linalg::getrf_lda(m_real.min(panel_tiles * t), n_real, t, &mut panel)
            .map_err(|e| match e {
                Error::Breakdown { detail, .. } => Error::Breakdown {
                    method: "plu_factor",
                    detail: format!("panel {k}: {detail}"),
                },
                other => other,
            })?;
        // Panel-relative pivot row -> global row.
        piv_global = piv.iter().map(|&p| (k * t + p) as i64).collect();
        // Charge the panel factorisation at serial-CPU rates:
        // ~ m_real * n_real^2 flops.
        let flops = (m_real as u64) * (n_real as u64) * (n_real as u64);
        let profile = crate::accel::ComputeProfile::q6600_atlas();
        ctx.charge(profile.op_cost::<S>(
            crate::accel::OpClass::Blas3,
            flops,
            m_real * n_real * S::BYTES,
            m_real * n_real * S::BYTES,
        ));
    }

    // --- scatter factored panel back ---------------------------------------
    // Host writes: any device copy of a written tile is now stale.
    if comm.rank() == diag_rank {
        for ti in k..kt {
            let dst = desc.shape.rank_at(ti % pr, ck);
            let off = (ti - k) * t * t;
            if dst == comm.rank() {
                a.global_tile_mut(ti, k).copy_from_slice(&panel[off..off + t * t]);
                ctx.host_mut(a.global_tile(ti, k));
            } else {
                comm.isend(dst, panel_tag(ti), Payload::Data(panel[off..off + t * t].to_vec()))
                    .wait();
            }
        }
    } else if in_panel_col {
        for ti in k..kt {
            if a.owns_tile_row(ti) {
                let data = comm.recv(diag_rank, panel_tag(ti)).into_data();
                a.global_tile_mut(ti, k).copy_from_slice(&data);
                ctx.host_mut(a.global_tile(ti, k));
            }
        }
    }

    // --- start the split-phase pivot + L21 broadcasts ----------------------
    let world = comm.world();
    let piv_payload = if comm.rank() == diag_rank {
        Some(Payload::Ints(piv_global.clone()))
    } else {
        None
    };
    let piv = world.ibcast(diag_rank, tags::LU + 1, piv_payload);

    let row = mesh.row_comm();
    let mut l21: Vec<Option<BcastRequest<'a, S>>> = Vec::with_capacity(a.local_mt());
    for lti in 0..a.local_mt() {
        let ti = desc.global_ti(mesh.row(), lti);
        if ti > k {
            let data = if in_panel_col {
                Some(Payload::Data(a.tile(lti, desc.local_tj(k)).to_vec()))
            } else {
                None
            };
            l21.push(Some(row.ibcast(ck, tags::LU + 3, data)));
        } else {
            l21.push(None);
        }
    }
    Ok((PanelInFlight { piv, l21 }, piv_global))
}

/// Re-post panel `k`'s split-phase broadcasts from *restored* state: the
/// recovery twin of [`factor_panel`]'s final section.  The panel column in
/// `a` already holds the checkpointed factors and `piv_global` the
/// checkpointed pivot rows, so no gather, `getrf` or scatter re-runs —
/// recovery re-flies only the broadcasts the drained step lost.
fn repost_panel<'a, S: Scalar>(
    ctx: &Ctx<'a, S>,
    a: &DistMatrix<S>,
    k: usize,
    piv_global: &[i64],
) -> (PanelInFlight<'a, S>, Vec<i64>) {
    let desc = *a.desc();
    let mesh = ctx.mesh;
    let comm = mesh.comm();
    let ck = k % desc.shape.pc;
    let diag_rank = desc.shape.rank_at(k % desc.shape.pr, ck);
    let in_panel_col = mesh.col() == ck;

    let piv_payload = if comm.rank() == diag_rank {
        Some(Payload::Ints(piv_global.to_vec()))
    } else {
        None
    };
    let piv = comm.world().ibcast(diag_rank, tags::LU + 1, piv_payload);

    let row = mesh.row_comm();
    let mut l21: Vec<Option<BcastRequest<'a, S>>> = Vec::with_capacity(a.local_mt());
    for lti in 0..a.local_mt() {
        let ti = desc.global_ti(mesh.row(), lti);
        if ti > k {
            let data = if in_panel_col {
                Some(Payload::Data(a.tile(lti, desc.local_tj(k)).to_vec()))
            } else {
                None
            };
            l21.push(Some(row.ibcast(ck, tags::LU + 3, data)));
        } else {
            l21.push(None);
        }
    }
    (PanelInFlight { piv, l21 }, piv_global.to_vec())
}

/// Host-side snapshot of one rank's factorization state at a panel
/// boundary: every local tile, the pivot count, and the in-flight panel's
/// pivot rows (diagonal owner only) — enough to re-enter the main loop at
/// panel `k` as if the steps since never ran.
pub(crate) struct PanelCheckpoint<S: Scalar> {
    pub(crate) k: usize,
    /// All local tiles, `[lti * local_nt + ltj]`.
    pub(crate) tiles: Vec<Vec<S>>,
    /// Pivot swaps recorded so far (the restore truncates to this).
    pub(crate) n_swaps: usize,
    /// The in-flight panel `k`'s global pivot rows (empty off the
    /// diagonal owner, and for Cholesky).
    pub(crate) piv_pending: Vec<i64>,
}

/// Snapshot the rank's local tiles at panel boundary `k`.  Device-dirty
/// tiles must come down to the host first: each prices a blocking D2H on
/// the copy-engine timeline ([`Ctx::snapshot_read`]) *without* closing its
/// dirty period — the checkpoint is a side read, and the fault-free run's
/// later PCIe accounting stays exactly what it was (DESIGN.md §18).
pub(crate) fn take_checkpoint<S: Scalar>(
    ctx: &Ctx<'_, S>,
    a: &DistMatrix<S>,
    k: usize,
    n_swaps: usize,
    piv_pending: &[i64],
) -> PanelCheckpoint<S> {
    let nt = a.local_nt();
    let mut tiles = Vec::with_capacity(a.local_mt() * nt);
    for lti in 0..a.local_mt() {
        for ltj in 0..nt {
            ctx.snapshot_read(a.tile(lti, ltj));
            tiles.push(a.tile(lti, ltj).to_vec());
        }
    }
    PanelCheckpoint { k, tiles, n_swaps, piv_pending: piv_pending.to_vec() }
}

/// Roll the rank's local tiles back to a checkpoint.  Every tile is a host
/// write ([`Ctx::host_mut`]): stale device copies drop out of the
/// `TileCache` and the surviving factors re-admit (re-stream) on first
/// touch during the replay — recovery re-prices exactly the traffic it
/// re-causes.
pub(crate) fn restore_checkpoint<S: Scalar>(ctx: &Ctx<'_, S>, a: &mut DistMatrix<S>, c: &PanelCheckpoint<S>) {
    let nt = a.local_nt();
    for lti in 0..a.local_mt() {
        for ltj in 0..nt {
            a.tile_mut(lti, ltj).copy_from_slice(&c.tiles[lti * nt + ltj]);
            ctx.host_mut(a.tile(lti, ltj));
        }
    }
}

/// Drain a panel's in-flight broadcasts (crash detected: the step that
/// would have consumed them is abandoned, but every rank must still
/// complete the collectives so the channels stay aligned).
fn drain_panel<S: Scalar>(inflight: PanelInFlight<'_, S>) {
    inflight.piv.wait();
    for req in inflight.l21.into_iter().flatten() {
        req.wait();
    }
}

/// In-place distributed LU: on return `a` holds L (unit lower, implicit
/// diagonal) and U; the returned [`PivotMap`] records the interchanges.
pub fn plu_factor<S: Scalar>(ctx: &Ctx<'_, S>, a: &mut DistMatrix<S>) -> Result<PivotMap> {
    plu_factor_ckpt(ctx, a, None)
}

/// [`plu_factor`] with panel-granularity fault tolerance (DESIGN.md §18).
///
/// With a [`CheckpointPolicy`], every `every_k_panels`-th panel boundary
/// snapshots the local tiles (+ pivots and the in-flight panel's pivot
/// rows) to the host, pricing one blocking D2H per device-dirty tile on
/// the copy-engine timeline and nothing else — the fault-free overhead is
/// exactly those legs.  When the run's [`crate::comm::FaultPlan`] scripts
/// crashes, every boundary after the first also *probes* (a scalar
/// allreduce): a crashed rank pays the plan's reboot cost, and on a
/// positive probe all ranks drain the in-flight panel, roll their tiles
/// back to the last checkpoint, re-post its broadcasts and replay — at
/// most `every_k_panels` panels of rework, with bit-identical factors
/// (the replay recomputes exactly the drained steps from identical
/// inputs).  A crash with no checkpoint to roll back to (no policy, or a
/// crash firing before the first probe) is an honest error on all ranks.
///
/// `ckpt = None` together with a crash-free plan runs byte-for-byte the
/// plain schedule: no probe, no snapshot, no extra traffic.
pub fn plu_factor_ckpt<S: Scalar>(
    ctx: &Ctx<'_, S>,
    a: &mut DistMatrix<S>,
    ckpt: Option<crate::comm::CheckpointPolicy>,
) -> Result<PivotMap> {
    let desc = *a.desc();
    assert!(desc.is_square(), "plu_factor requires a square matrix");
    let t = desc.tile;
    let kt = desc.mt();
    let mesh = ctx.mesh;
    let (pr, pc) = (desc.shape.pr, desc.shape.pc);
    let mut pivots = PivotMap::default();

    let probing = mesh.comm().fault_plan().has_crashes();
    let every = ckpt.map(|c| c.every_k_panels.max(1));
    let mut saved: Option<PanelCheckpoint<S>> = None;
    // Suppress the boundary work once right after a rollback: the state
    // *is* the checkpoint, so re-probing / re-snapshotting it is pure
    // waste (and the consumed crash cannot re-fire anyway).
    let mut just_restored = false;

    // Prologue: factor panel 0; its pivots and L21 go on the wire now.
    let mut pending = Some(factor_panel(ctx, a, 0)?);

    let mut k = 0;
    while k < kt {
        // --- 0. fault boundary: probe for crashes, then checkpoint ---------
        let boundary = every.map_or(probing, |e| k % e == 0);
        if probing && boundary && k > 0 && !just_restored && fault_probe(ctx) {
            let (inflight, _) = pending.take().expect("panel in flight");
            drain_panel(inflight);
            let Some(c) = saved.as_ref() else {
                return Err(Error::Runtime(format!(
                    "plu_factor: rank crash detected at panel {k} with no checkpoint \
                     (CheckpointPolicy not set)"
                )));
            };
            restore_checkpoint(ctx, a, c);
            pivots.swaps.truncate(c.n_swaps);
            k = c.k;
            pending = Some(repost_panel(ctx, &*a, k, &c.piv_pending));
            just_restored = true;
            continue;
        }
        if let Some(e) = every {
            if k % e == 0 && !just_restored {
                let piv_pending = &pending.as_ref().expect("panel in flight").1;
                saved = Some(take_checkpoint(ctx, a, k, pivots.swaps.len(), piv_pending));
            }
        }
        just_restored = false;

        let ck = k % pc; // panel's process column
        let rk = k % pr; // diagonal tile's process row
        let (inflight, _) = pending.take().expect("panel in flight");

        let m_real = desc.m - k * t;
        let n_real = m_real.min(t);

        // --- 1. complete the pivot broadcast -------------------------------
        let piv_global = inflight.piv.wait().into_ints();

        // --- 2. apply row swaps outside the panel column -------------------
        for (j, &pg) in piv_global.iter().enumerate() {
            let g1 = k * t + j;
            let g2 = pg as usize;
            if g1 != g2 {
                pivots.swaps.push((g1, g2));
                swap_rows_outside_panel(ctx, a, g1, g2, k);
            }
        }

        if k + 1 == kt && n_real >= m_real {
            // No trailing work after the last panel; its L21 broadcasts were
            // empty (no tile rows below the panel), so nothing is in flight.
            break;
        }

        // --- 3. U12 row: broadcast diag tile along row rk, trsm ------------
        let row = mesh.row_comm();
        if mesh.row() == rk {
            let mut leg = 0.0;
            let diag_payload = if mesh.col() == ck {
                // Freshly scattered, so host-clean: the wire route falls
                // back to the staged flow bit-identically.
                leg = ctx.wire_read(a.global_tile(k, k)).pcie_secs();
                Some(Payload::Data(a.global_tile(k, k).to_vec()))
            } else {
                None
            };
            let l11 = row.bcast_wire(ck, tags::LU + 2, diag_payload, leg).into_data();
            for ltj in 0..a.local_nt() {
                let tj = desc.global_tj(mesh.col(), ltj);
                if tj > k {
                    let lti = desc.local_ti(k);
                    let cost = ctx.engine.trsm_llu(&l11, a.tile_mut(lti, ltj))?;
                    ctx.charge_op(cost, &[&l11, a.tile(lti, ltj)], Some(a.tile(lti, ltj)));
                }
            }
            ctx.host_mut(&l11); // transient broadcast buffer: retire
        }

        // --- 4. complete the L21 row broadcasts; U12 column broadcasts -----
        let mut l_panel: Vec<Option<Vec<S>>> = vec![None; a.local_mt()];
        for (lti, req) in inflight.l21.into_iter().enumerate() {
            if let Some(req) = req {
                l_panel[lti] = Some(req.wait().into_data());
            }
        }
        let col = mesh.col_comm();
        let mut u_panel: Vec<Option<Vec<S>>> = vec![None; a.local_nt()];
        for ltj in 0..a.local_nt() {
            let tj = desc.global_tj(mesh.col(), ltj);
            if tj > k {
                let mut leg = 0.0;
                let data = if mesh.row() == rk {
                    // The trsm result is device-dirty on the CUDA arm:
                    // under GPUDirect it broadcasts straight off the
                    // device; otherwise this is the staged host_read
                    // (ending its dirty period) exactly as before.
                    leg = ctx.wire_read(a.tile(desc.local_ti(k), ltj)).pcie_secs();
                    Some(Payload::Data(a.tile(desc.local_ti(k), ltj).to_vec()))
                } else {
                    None
                };
                u_panel[ltj] = Some(col.bcast_wire(rk, tags::LU + 4, data, leg).into_data());
            }
        }

        // --- 5. lookahead: update tile column k+1 first, factor it, and put
        //        its pivot + L21 broadcasts on the wire ----------------------
        if k + 1 < kt {
            let next_ck = (k + 1) % pc;
            if mesh.col() == next_ck {
                let ltj = desc.local_tj(k + 1);
                let u_tile = u_panel[ltj].as_ref().expect("U tile for lookahead column");
                let rows: Vec<usize> = (0..a.local_mt())
                    .filter(|&lti| desc.global_ti(mesh.row(), lti) > k)
                    .collect();
                for (idx, &lti) in rows.iter().enumerate() {
                    // Prefetch the next row's operands onto the copy engine
                    // while this row's gemm_update runs (DESIGN.md §13).
                    if let Some(&nlti) = rows.get(idx + 1) {
                        ctx.prefetch(a.tile(nlti, ltj));
                        ctx.prefetch(l_panel[nlti].as_ref().expect("L tile broadcast"));
                    }
                    let l_tile = l_panel[lti].as_ref().expect("L tile broadcast");
                    let cost = ctx.engine.gemm_update(a.tile_mut(lti, ltj), l_tile, u_tile)?;
                    ctx.charge_op(
                        cost,
                        &[a.tile(lti, ltj), l_tile, u_tile],
                        Some(a.tile(lti, ltj)),
                    );
                }
            }
            pending = Some(factor_panel(ctx, a, k + 1)?);
        }

        // --- 6. trailing rank-T update (hides step k+1's panel path) -------
        // The residency layer is what makes this leg cheap on the CUDA arm:
        // each broadcast L21/U12 buffer streams H2D once and is then reused
        // across the whole trailing sweep, and the C tiles stay device-
        // resident (and dirty) across the k steps (DESIGN.md §12).  The
        // surviving streams (panel first touch, swap-invalidated tiles)
        // ride the copy-engine timeline: each step prefetches the next
        // tile's operands under the current gemm_update (DESIGN.md §13).
        let trailing: Vec<(usize, usize)> = (0..a.local_mt())
            .filter(|&lti| desc.global_ti(mesh.row(), lti) > k)
            .flat_map(|lti| {
                (0..a.local_nt())
                    .filter(|&ltj| {
                        let tj = desc.global_tj(mesh.col(), ltj);
                        tj > k && tj != k + 1 // k+1 was updated ahead of the panel
                    })
                    .map(move |ltj| (lti, ltj))
            })
            .collect();
        for (idx, &(lti, ltj)) in trailing.iter().enumerate() {
            if let Some(&(nlti, nltj)) = trailing.get(idx + 1) {
                ctx.prefetch(a.tile(nlti, nltj));
                ctx.prefetch(l_panel[nlti].as_ref().expect("L tile broadcast"));
                ctx.prefetch(u_panel[nltj].as_ref().expect("U tile broadcast"));
            }
            let l_tile = l_panel[lti].as_ref().expect("L tile broadcast");
            let u_tile = u_panel[ltj].as_ref().expect("U tile broadcast");
            let cost = ctx.engine.gemm_update(a.tile_mut(lti, ltj), l_tile, u_tile)?;
            ctx.charge_op(
                cost,
                &[a.tile(lti, ltj), l_tile, u_tile],
                Some(a.tile(lti, ltj)),
            );
        }

        // Retire the step's broadcast panels before their buffers drop.
        for buf in l_panel.iter().chain(&u_panel).flatten() {
            ctx.host_mut(buf);
        }
        k += 1;
    }
    Ok(pivots)
}

/// Exchange global rows `g1` and `g2` in every tile column except `panel_k`
/// (whose tiles were already pivoted inside `getrf`).
fn swap_rows_outside_panel<S: Scalar>(
    ctx: &Ctx<'_, S>,
    a: &mut DistMatrix<S>,
    g1: usize,
    g2: usize,
    panel_k: usize,
) {
    let desc = *a.desc();
    let t = desc.tile;
    let mesh = ctx.mesh;
    let comm = mesh.comm();
    let (t1, r1) = (g1 / t, g1 % t);
    let (t2, r2) = (g2 / t, g2 % t);
    let pr1 = t1 % desc.shape.pr;
    let pr2 = t2 % desc.shape.pr;

    // Tile columns this rank participates in.
    let my_cols: Vec<usize> = (0..a.local_nt())
        .filter(|&ltj| desc.global_tj(mesh.col(), ltj) != panel_k)
        .collect();
    if my_cols.is_empty() {
        return;
    }

    if pr1 == pr2 {
        if mesh.row() == pr1 {
            // Both rows local to this process row: in-place swap.  Host
            // mutation: any device copy of a touched tile goes stale.
            for &ltj in &my_cols {
                let lt1 = desc.local_ti(t1);
                let lt2 = desc.local_ti(t2);
                if t1 == t2 {
                    let tile = a.tile_mut(lt1, ltj);
                    for c in 0..t {
                        tile.swap(r1 * t + c, r2 * t + c);
                    }
                    ctx.host_mut(a.tile(lt1, ltj));
                } else {
                    // Two different local tiles: swap row slices via split.
                    let (i1, i2) = (lt1, lt2);
                    // take rows out, swap, put back (avoids double-borrow)
                    let row1: Vec<S> = a.tile(i1, ltj)[r1 * t..(r1 + 1) * t].to_vec();
                    let row2: Vec<S> = a.tile(i2, ltj)[r2 * t..(r2 + 1) * t].to_vec();
                    a.tile_mut(i1, ltj)[r1 * t..(r1 + 1) * t].copy_from_slice(&row2);
                    a.tile_mut(i2, ltj)[r2 * t..(r2 + 1) * t].copy_from_slice(&row1);
                    ctx.host_mut(a.tile(i1, ltj));
                    ctx.host_mut(a.tile(i2, ltj));
                }
            }
        }
        return;
    }

    // Rows live on different process rows: pairwise exchange within my
    // process column.  Both sides send first (channels are buffered).
    let (my_row_tile, my_r, peer_prow, tag_off) = if mesh.row() == pr1 {
        (t1, r1, pr2, 0)
    } else if mesh.row() == pr2 {
        (t2, r2, pr1, 1)
    } else {
        return;
    };
    let peer = desc.shape.rank_at(peer_prow, mesh.col());
    let lti = desc.local_ti(my_row_tile);
    let mut out = Vec::with_capacity(my_cols.len() * t);
    for &ltj in &my_cols {
        out.extend_from_slice(&a.tile(lti, ltj)[my_r * t..(my_r + 1) * t]);
    }
    comm.send(peer, Tag::PivotSwap(tags::LU + tag_off), Payload::Data(out));
    let incoming = comm.recv(peer, Tag::PivotSwap(tags::LU + (1 - tag_off))).into_data();
    for (idx, &ltj) in my_cols.iter().enumerate() {
        a.tile_mut(lti, ltj)[my_r * t..(my_r + 1) * t]
            .copy_from_slice(&incoming[idx * t..(idx + 1) * t]);
        ctx.host_mut(a.tile(lti, ltj));
    }
}
