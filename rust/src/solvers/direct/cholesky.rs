//! Distributed right-looking block Cholesky (the paper's SPD direct method).
//!
//! Per tile step `k`:
//! 1. the diagonal owner factors its tile with the engine's `potrf` and
//!    broadcasts L11 down its process column;
//! 2. that column's owners of tile rows i > k solve
//!    `L(i,k) · L11^T = A(i,k)` with the engine's `trsm_rlt`;
//! 3. the L(·,k) tiles broadcast along process rows; each owned *column*
//!    block L(j,k) then broadcasts down its process column;
//! 4. trailing update on the lower half: `A(i,j) -= L(i,k) · L(j,k)^T`
//!    (i ≥ j > k) via the engine's fused `gemm_nt_update`.
//!
//! Only the lower triangle is referenced or updated; the strict upper
//! triangle of the shard is left stale.

use crate::comm::Payload;
use crate::dist::DistMatrix;
use crate::pblas::{tags, Ctx};
use crate::{Result, Scalar};

/// In-place distributed Cholesky: on return the lower triangle of `a` holds
/// L (with its diagonal); the strict upper triangle is unspecified.
pub fn pchol_factor<S: Scalar>(ctx: &Ctx<'_, S>, a: &mut DistMatrix<S>) -> Result<()> {
    let desc = *a.desc();
    assert!(desc.is_square(), "pchol_factor requires a square matrix");
    let kt = desc.mt();
    let mesh = ctx.mesh;
    let (pr, pc) = (desc.shape.pr, desc.shape.pc);

    for k in 0..kt {
        let ck = k % pc;
        let rk = k % pr;

        // --- 1. factor diagonal tile, broadcast L11 down the column -------
        let col = mesh.col_comm();
        let mut l11: Option<Vec<S>> = None;
        if mesh.col() == ck {
            let payload = if mesh.row() == rk {
                let tile = a.global_tile_mut(k, k);
                let cost = ctx.engine.potrf(tile)?;
                ctx.charge(cost);
                Some(Payload::Data(tile.clone()))
            } else {
                None
            };
            l11 = Some(col.bcast(rk, tags::CHOL, payload).into_data());
        }

        // --- 2. panel solve L(i,k) = A(i,k) L11^{-T} -----------------------
        if mesh.col() == ck {
            let l11 = l11.as_ref().expect("column ck has L11");
            for lti in 0..a.local_mt() {
                let ti = desc.global_ti(mesh.row(), lti);
                if ti > k {
                    let cost = ctx.engine.trsm_rlt(a.tile_mut(lti, desc.local_tj(k)), l11)?;
                    ctx.charge(cost);
                }
            }
        }

        if k + 1 == kt {
            break;
        }

        // --- 3a. broadcast L(i,k) along process rows ------------------------
        let row = mesh.row_comm();
        let mut l_rows: Vec<Option<Vec<S>>> = vec![None; a.local_mt()];
        for lti in 0..a.local_mt() {
            let ti = desc.global_ti(mesh.row(), lti);
            if ti > k {
                let data = if mesh.col() == ck {
                    Some(Payload::Data(a.tile(lti, desc.local_tj(k)).to_vec()))
                } else {
                    None
                };
                l_rows[lti] = Some(row.bcast(ck, tags::CHOL + 1, data).into_data());
            }
        }

        // --- 3b. broadcast L(j,k) down each owned process column -----------
        // After 3a, rank (j % pr, c) holds L(j,k) for every owned row j; the
        // tile (i,j) owners in column c sit in the same process column.
        let mut l_cols: Vec<Option<Vec<S>>> = vec![None; a.local_nt()];
        for ltj in 0..a.local_nt() {
            let tj = desc.global_tj(mesh.col(), ltj);
            if tj > k {
                let root = tj % pr;
                let data = if mesh.row() == root {
                    // From 3a: this rank's row-broadcast copy of L(tj, k).
                    let lti = desc.local_ti(tj);
                    Some(Payload::Data(
                        l_rows[lti].as_ref().expect("row tj broadcast").clone(),
                    ))
                } else {
                    None
                };
                l_cols[ltj] = Some(col.bcast(root, tags::CHOL + 2, data).into_data());
            }
        }

        // --- 4. trailing update, lower half only ----------------------------
        for lti in 0..a.local_mt() {
            let ti = desc.global_ti(mesh.row(), lti);
            if ti <= k {
                continue;
            }
            let l_ik = l_rows[lti].as_ref().expect("L row tile");
            for ltj in 0..a.local_nt() {
                let tj = desc.global_tj(mesh.col(), ltj);
                if tj <= k || tj > ti {
                    continue; // lower half only (i >= j)
                }
                let l_jk = l_cols[ltj].as_ref().expect("L col tile");
                let cost = ctx.engine.gemm_nt_update(a.tile_mut(lti, ltj), l_ik, l_jk)?;
                ctx.charge(cost);
            }
        }
    }
    Ok(())
}
