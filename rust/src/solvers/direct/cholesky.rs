//! Distributed right-looking block Cholesky (the paper's SPD direct method)
//! with **depth-1 lookahead**.
//!
//! The classic step `k`: factor the diagonal tile (`potrf`), solve the
//! panel (`trsm_rlt`), broadcast the panel tiles along process rows (3a)
//! and down process columns (3b), then apply the symmetric trailing update
//! `A(i,j) -= L(i,k) · L(j,k)^T` (i ≥ j > k).
//!
//! The lookahead schedule performs panel `k+1`'s work *inside* step `k`:
//! after panel `k`'s broadcasts land, tile column `k+1` is updated first,
//! panel `k+1` is factored immediately (potrf + trsm on its process
//! column), and its row broadcasts are started split-phase
//! ([`crate::comm::BcastRequest`]) — they then ride the network while every
//! rank runs step `k`'s remaining trailing update (`j > k+1`), so the panel
//! critical path is hidden behind the BLAS-3 stream (DESIGN.md §11).  On
//! the accelerated arm the update sweeps additionally prefetch the next
//! tile's operands onto the copy-engine timeline ([`Ctx::prefetch`]), so
//! the surviving PCIe streams hide under the BLAS-3 stream as well
//! (DESIGN.md §13).  The operation set and operands are identical to the
//! classic schedule, so the factor is bit-for-bit the same.
//!
//! Only the lower triangle is referenced or updated; the strict upper
//! triangle of the shard is left stale.

use super::lu::{restore_checkpoint, take_checkpoint, PanelCheckpoint};
use crate::comm::{BcastRequest, Payload};
use crate::dist::DistMatrix;
use crate::pblas::{fault_probe, tags, Ctx};
use crate::{Error, Result, Scalar};

/// Factor panel `k` (its column must already hold all updates through step
/// `k-1`): potrf the diagonal tile, broadcast L11 down the panel's process
/// column, solve the sub-diagonal tiles, and start the split-phase row
/// broadcasts of the finished L(·,k) tiles.
fn factor_panel<'a, S: Scalar>(
    ctx: &Ctx<'a, S>,
    a: &mut DistMatrix<S>,
    k: usize,
) -> Result<Vec<Option<BcastRequest<'a, S>>>> {
    let desc = *a.desc();
    let mesh = ctx.mesh;
    let (pr, pc) = (desc.shape.pr, desc.shape.pc);
    let ck = k % pc;
    let rk = k % pr;

    // --- factor diagonal tile, broadcast L11 down the column, panel solve --
    if mesh.col() == ck {
        let col = mesh.col_comm();
        let mut leg = 0.0;
        let payload = if mesh.row() == rk {
            let cost = ctx.engine.potrf(a.global_tile_mut(k, k))?;
            ctx.charge_op(cost, &[a.global_tile(k, k)], Some(a.global_tile(k, k)));
            // The potrf result is device-dirty on the CUDA arm: under
            // GPUDirect it broadcasts straight off the device; otherwise
            // this is the staged host_read exactly as before.
            leg = ctx.wire_read(a.global_tile(k, k)).pcie_secs();
            Some(Payload::Data(a.global_tile(k, k).to_vec()))
        } else {
            None
        };
        let l11 = col.bcast_wire(rk, tags::CHOL, payload, leg).into_data();
        for lti in 0..a.local_mt() {
            let ti = desc.global_ti(mesh.row(), lti);
            if ti > k {
                let cost = ctx.engine.trsm_rlt(a.tile_mut(lti, desc.local_tj(k)), &l11)?;
                let tile = a.tile(lti, desc.local_tj(k));
                ctx.charge_op(cost, &[tile, &l11], Some(tile));
            }
        }
        ctx.host_mut(&l11); // transient broadcast buffer: retire
    }

    // --- start the split-phase row broadcasts of L(i,k), i > k -------------
    let row = mesh.row_comm();
    let mut l_rows: Vec<Option<BcastRequest<'a, S>>> = Vec::with_capacity(a.local_mt());
    for lti in 0..a.local_mt() {
        let ti = desc.global_ti(mesh.row(), lti);
        if ti > k {
            let mut leg = 0.0;
            let data = if mesh.col() == ck {
                // Device-dirty trsm result: wire route under GPUDirect,
                // staged host_read (ending its dirty period) otherwise.
                leg = ctx.wire_read(a.tile(lti, desc.local_tj(k))).pcie_secs();
                Some(Payload::Data(a.tile(lti, desc.local_tj(k)).to_vec()))
            } else {
                None
            };
            l_rows.push(Some(row.ibcast_wire(ck, tags::CHOL + 1, data, leg)));
        } else {
            l_rows.push(None);
        }
    }
    Ok(l_rows)
}

/// Re-post panel `k`'s split-phase row broadcasts from *restored* state:
/// the recovery twin of [`factor_panel`]'s final section.  The panel
/// column in `a` already holds the checkpointed factors (host-clean after
/// the rollback, so the plain broadcast is the right wire route); no
/// `potrf`/`trsm` re-runs.
fn repost_panel<'a, S: Scalar>(
    ctx: &Ctx<'a, S>,
    a: &DistMatrix<S>,
    k: usize,
) -> Vec<Option<BcastRequest<'a, S>>> {
    let desc = *a.desc();
    let mesh = ctx.mesh;
    let ck = k % desc.shape.pc;
    let row = mesh.row_comm();
    let mut l_rows: Vec<Option<BcastRequest<'a, S>>> = Vec::with_capacity(a.local_mt());
    for lti in 0..a.local_mt() {
        let ti = desc.global_ti(mesh.row(), lti);
        if ti > k {
            let data = if mesh.col() == ck {
                Some(Payload::Data(a.tile(lti, desc.local_tj(k)).to_vec()))
            } else {
                None
            };
            l_rows.push(Some(row.ibcast(ck, tags::CHOL + 1, data)));
        } else {
            l_rows.push(None);
        }
    }
    l_rows
}

/// In-place distributed Cholesky: on return the lower triangle of `a` holds
/// L (with its diagonal); the strict upper triangle is unspecified.
pub fn pchol_factor<S: Scalar>(ctx: &Ctx<'_, S>, a: &mut DistMatrix<S>) -> Result<()> {
    pchol_factor_ckpt(ctx, a, None)
}

/// [`pchol_factor`] with panel-granularity fault tolerance: the Cholesky
/// twin of [`super::lu::plu_factor_ckpt`] (same boundary schedule — probe
/// when the fault plan scripts crashes, snapshot every `every_k_panels`
/// panels pricing only the device-dirty D2H legs, roll back + re-post +
/// replay on a positive probe — minus the pivot state Cholesky does not
/// have).  `ckpt = None` with a crash-free plan is byte-for-byte the
/// plain schedule.
pub fn pchol_factor_ckpt<S: Scalar>(
    ctx: &Ctx<'_, S>,
    a: &mut DistMatrix<S>,
    ckpt: Option<crate::comm::CheckpointPolicy>,
) -> Result<()> {
    let desc = *a.desc();
    assert!(desc.is_square(), "pchol_factor requires a square matrix");
    let kt = desc.mt();
    let mesh = ctx.mesh;
    let pr = desc.shape.pr;

    let probing = mesh.comm().fault_plan().has_crashes();
    let every = ckpt.map(|c| c.every_k_panels.max(1));
    let mut saved: Option<PanelCheckpoint<S>> = None;
    let mut just_restored = false;

    // Prologue: factor panel 0; its row broadcasts go on the wire now.
    let mut pending = Some(factor_panel(ctx, a, 0)?);

    let mut k = 0;
    while k < kt {
        // --- 0. fault boundary: probe for crashes, then checkpoint ---------
        let boundary = every.map_or(probing, |e| k % e == 0);
        if probing && boundary && k > 0 && !just_restored && fault_probe(ctx) {
            for req in pending.take().expect("panel in flight").into_iter().flatten() {
                req.wait(); // drain: keep the collectives aligned
            }
            let Some(c) = saved.as_ref() else {
                return Err(Error::Runtime(format!(
                    "pchol_factor: rank crash detected at panel {k} with no checkpoint \
                     (CheckpointPolicy not set)"
                )));
            };
            restore_checkpoint(ctx, a, c);
            k = c.k;
            pending = Some(repost_panel(ctx, &*a, k));
            just_restored = true;
            continue;
        }
        if let Some(e) = every {
            if k % e == 0 && !just_restored {
                saved = Some(take_checkpoint(ctx, a, k, 0, &[]));
            }
        }
        just_restored = false;

        let inflight = pending.take().expect("panel in flight");

        // --- 1. complete the L(i,k) row broadcasts -------------------------
        let mut l_rows: Vec<Option<Vec<S>>> = vec![None; a.local_mt()];
        for (lti, req) in inflight.into_iter().enumerate() {
            if let Some(req) = req {
                l_rows[lti] = Some(req.wait().into_data());
            }
        }

        if k + 1 == kt {
            for buf in l_rows.iter().flatten() {
                ctx.host_mut(buf); // retire before the buffers drop
            }
            break; // last panel: no trailing tiles, nothing left in flight
        }

        // --- 2. broadcast L(j,k) down each owned process column ------------
        // After step 1, rank (j % pr, c) holds L(j,k) for every owned row j;
        // the tile (i,j) owners in column c sit in the same process column.
        let col = mesh.col_comm();
        let mut l_cols: Vec<Option<Vec<S>>> = vec![None; a.local_nt()];
        for ltj in 0..a.local_nt() {
            let tj = desc.global_tj(mesh.col(), ltj);
            if tj > k {
                let root = tj % pr;
                let data = if mesh.row() == root {
                    // From step 1: this rank's row-broadcast copy of L(tj, k).
                    let lti = desc.local_ti(tj);
                    Some(Payload::Data(
                        l_rows[lti].as_ref().expect("row tj broadcast").clone(),
                    ))
                } else {
                    None
                };
                l_cols[ltj] = Some(col.bcast(root, tags::CHOL + 2, data).into_data());
            }
        }

        // --- 3. lookahead: update tile column k+1 first, then factor it ----
        let next_ck = (k + 1) % desc.shape.pc;
        if mesh.col() == next_ck {
            let ltj = desc.local_tj(k + 1);
            let l_jk = l_cols[ltj].as_ref().expect("L col tile for lookahead column");
            let rows: Vec<usize> = (0..a.local_mt())
                .filter(|&lti| desc.global_ti(mesh.row(), lti) > k)
                .collect();
            for (idx, &lti) in rows.iter().enumerate() {
                // Prefetch the next row's operands onto the copy engine
                // while this row's update runs (DESIGN.md §13).
                if let Some(&nlti) = rows.get(idx + 1) {
                    ctx.prefetch(a.tile(nlti, ltj));
                    ctx.prefetch(l_rows[nlti].as_ref().expect("L row tile"));
                }
                let l_ik = l_rows[lti].as_ref().expect("L row tile");
                let cost = ctx.engine.gemm_nt_update(a.tile_mut(lti, ltj), l_ik, l_jk)?;
                ctx.charge_op(
                    cost,
                    &[a.tile(lti, ltj), l_ik, l_jk],
                    Some(a.tile(lti, ltj)),
                );
            }
        }
        pending = Some(factor_panel(ctx, a, k + 1)?);

        // --- 4. trailing update, lower half, remaining columns (j > k+1) ---
        // Hides panel k+1's potrf/trsm critical path and its broadcasts.
        // With residency each broadcast L(i,k)/L(j,k) buffer streams H2D
        // once per step and the trailing tiles stay device-resident across
        // the k steps (DESIGN.md §12); the surviving streams ride the
        // copy-engine timeline via depth-1 prefetch (DESIGN.md §13).
        let trailing: Vec<(usize, usize)> = (0..a.local_mt())
            .flat_map(|lti| (0..a.local_nt()).map(move |ltj| (lti, ltj)))
            .filter(|&(lti, ltj)| {
                let ti = desc.global_ti(mesh.row(), lti);
                let tj = desc.global_tj(mesh.col(), ltj);
                ti > k && tj > k + 1 && tj <= ti // lower half only; k+1 done
            })
            .collect();
        for (idx, &(lti, ltj)) in trailing.iter().enumerate() {
            if let Some(&(nlti, nltj)) = trailing.get(idx + 1) {
                ctx.prefetch(a.tile(nlti, nltj));
                ctx.prefetch(l_rows[nlti].as_ref().expect("L row tile"));
                ctx.prefetch(l_cols[nltj].as_ref().expect("L col tile"));
            }
            let l_ik = l_rows[lti].as_ref().expect("L row tile");
            let l_jk = l_cols[ltj].as_ref().expect("L col tile");
            let cost = ctx.engine.gemm_nt_update(a.tile_mut(lti, ltj), l_ik, l_jk)?;
            ctx.charge_op(
                cost,
                &[a.tile(lti, ltj), l_ik, l_jk],
                Some(a.tile(lti, ltj)),
            );
        }

        // Retire the step's broadcast buffers before they drop.
        for buf in l_rows.iter().chain(&l_cols).flatten() {
            ctx.host_mut(buf);
        }
        k += 1;
    }
    Ok(())
}
