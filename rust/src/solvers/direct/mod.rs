//! Direct distributed solvers: factor + substitute (the paper's two-step
//! method: `A = LU` / `A = L·L^T`, then two triangular solves).

pub mod cholesky;
pub mod lu;
pub mod refined;
pub mod trsm;
pub mod trsv;

pub use cholesky::{pchol_factor, pchol_factor_ckpt};
pub use lu::{plu_factor, plu_factor_ckpt, PivotMap};
pub use refined::{
    pchol_refine, pchol_solve_refined, plu_refine, plu_solve_refined, refine_bound, RefineStats,
    REFINE_MAX_SWEEPS, REFINE_STAGNATION,
};
pub use trsm::ptrsm;
pub use trsv::{ptrsv, TriKind};

use crate::comm::{CheckpointPolicy, Payload, Tag};
use crate::dist::{ptranspose, DistMatrix, DistMultiVector, DistVector};
use crate::pblas::Ctx;
use crate::{Result, Scalar};

/// Apply a pivot map to a distributed (column-replicated) vector, in order.
pub fn apply_pivots<S: Scalar>(ctx: &Ctx<'_, S>, piv: &PivotMap, b: &mut DistVector<S>) {
    let desc = *b.desc();
    let t = desc.tile;
    let mesh = ctx.mesh;
    let comm = mesh.comm();
    for (s, &(g1, g2)) in piv.swaps().iter().enumerate() {
        let (t1, r1) = (g1 / t, g1 % t);
        let (t2, r2) = (g2 / t, g2 % t);
        let pr1 = t1 % desc.shape.pr;
        let pr2 = t2 % desc.shape.pr;
        if pr1 == pr2 {
            if mesh.row() == pr1 {
                if t1 == t2 {
                    b.global_block_mut(t1).swap(r1, r2);
                } else {
                    let v1 = b.global_block(t1)[r1];
                    let v2 = b.global_block(t2)[r2];
                    b.global_block_mut(t1)[r1] = v2;
                    b.global_block_mut(t2)[r2] = v1;
                }
            }
            continue;
        }
        // Cross-row exchange within this process column.
        let tag = |dir: u32| Tag::PivotSwap(4_000 + 2 * (s as u32 % 500) + dir);
        if mesh.row() == pr1 {
            let peer = desc.shape.rank_at(pr2, mesh.col());
            let mine = b.global_block(t1)[r1];
            comm.send(peer, tag(0), Payload::Scalar(mine));
            b.global_block_mut(t1)[r1] = comm.recv(peer, tag(1)).into_scalar();
        } else if mesh.row() == pr2 {
            let peer = desc.shape.rank_at(pr1, mesh.col());
            let mine = b.global_block(t2)[r2];
            comm.send(peer, tag(1), Payload::Scalar(mine));
            b.global_block_mut(t2)[r2] = comm.recv(peer, tag(0)).into_scalar();
        }
    }
}

/// Solve `A x = b` by distributed LU: factors `a` in place, then runs the
/// pivoted forward/backward substitutions.  Returns x (same layout as b).
/// Routed through the RHS-panel path ([`plu_solve_panel`]) with `k = 1` —
/// the panel kernels price a one-column panel exactly like the
/// single-column ops, and the arithmetic is identical.
pub fn plu_solve<S: Scalar>(
    ctx: &Ctx<'_, S>,
    a: &mut DistMatrix<S>,
    b: &DistVector<S>,
) -> Result<DistVector<S>> {
    let x = plu_solve_panel(ctx, a, &DistMultiVector::from_cols(vec![b.clone_vec()]))?;
    Ok(x.into_cols().remove(0))
}

/// Solve `A X = B` by distributed LU for a whole RHS panel: factor **once**
/// (amortized over every column), apply the pivot map per column, then run
/// the two panel substitutions ([`ptrsm`]) — one broadcast/downdate sweep
/// per panel step instead of one full [`ptrsv`] pass per vector.
pub fn plu_solve_panel<S: Scalar>(
    ctx: &Ctx<'_, S>,
    a: &mut DistMatrix<S>,
    b: &DistMultiVector<S>,
) -> Result<DistMultiVector<S>> {
    plu_solve_panel_ckpt(ctx, a, b, None)
}

/// [`plu_solve_panel`] with an optional panel-checkpoint policy threaded into
/// the factorization: under a fault plan with crashes, the factor phase rolls
/// back to the last checkpoint instead of restarting from scratch.  The
/// substitution sweeps run after the (recovered) factorization and need no
/// checkpointing of their own.
pub fn plu_solve_panel_ckpt<S: Scalar>(
    ctx: &Ctx<'_, S>,
    a: &mut DistMatrix<S>,
    b: &DistMultiVector<S>,
    ckpt: Option<CheckpointPolicy>,
) -> Result<DistMultiVector<S>> {
    let piv = plu_factor_ckpt(ctx, a, ckpt)?;
    let mut x = b.clone_panel();
    for j in 0..x.ncols() {
        ctx.set_tenant(Some(j));
        apply_pivots(ctx, &piv, x.col_mut(j));
        ctx.set_tenant(None);
    }
    ptrsm(ctx, a, &mut x, TriKind::LowerUnit)?;
    ptrsm(ctx, a, &mut x, TriKind::Upper)?;
    Ok(x)
}

/// Solve `A x = b` (SPD) by distributed Cholesky: factor, forward solve with
/// L, transpose-redistribute, backward solve with `L^T`.  Routed through
/// the RHS-panel path ([`pchol_solve_panel`]) with `k = 1`.
pub fn pchol_solve<S: Scalar>(
    ctx: &Ctx<'_, S>,
    a: &mut DistMatrix<S>,
    b: &DistVector<S>,
) -> Result<DistVector<S>> {
    let x = pchol_solve_panel(ctx, a, &DistMultiVector::from_cols(vec![b.clone_vec()]))?;
    Ok(x.into_cols().remove(0))
}

/// Solve `A X = B` (SPD) by distributed Cholesky for a whole RHS panel:
/// one factorization and **one** transpose-redistribution amortized over
/// every column, with both substitutions batched through [`ptrsm`].
pub fn pchol_solve_panel<S: Scalar>(
    ctx: &Ctx<'_, S>,
    a: &mut DistMatrix<S>,
    b: &DistMultiVector<S>,
) -> Result<DistMultiVector<S>> {
    pchol_solve_panel_ckpt(ctx, a, b, None)
}

/// [`pchol_solve_panel`] with an optional panel-checkpoint policy threaded
/// into the factorization (see [`plu_solve_panel_ckpt`]).
pub fn pchol_solve_panel_ckpt<S: Scalar>(
    ctx: &Ctx<'_, S>,
    a: &mut DistMatrix<S>,
    b: &DistMultiVector<S>,
    ckpt: Option<CheckpointPolicy>,
) -> Result<DistMultiVector<S>> {
    pchol_factor_ckpt(ctx, a, ckpt)?;
    let mut x = b.clone_panel();
    ptrsm(ctx, a, &mut x, TriKind::Lower)?;
    // U = L^T: the Upper substitution only reads the (valid) upper triangle
    // of the transposed factor; the stale strict-lower half is never touched.
    let lt = ptranspose(ctx.mesh, a);
    ptrsm(ctx, &lt, &mut x, TriKind::Upper)?;
    Ok(x)
}
