//! RHS-panel triangular substitution (`ptrsm`): [`ptrsv`]'s column-fan-out
//! algorithm generalized to a `k`-column right-hand-side panel, paying the
//! per-step communication and tile traffic **once for the whole panel**.
//!
//! At tile step `k` the diagonal owner panel-solves its `tile x tile`
//! system against all `k` rhs blocks in one batched kernel
//! ([`crate::accel::Engine::trsm_panel`]), the `k` solution blocks
//! broadcast world-wide as **one** `k·tile` payload (one tree latency
//! instead of `k`), the tiles of column `k` broadcast along their process
//! rows once — shared by every rhs column — and each rank downdates its
//! replica blocks with one `gemm`-shaped panel kernel per tile
//! ([`crate::accel::Engine::gemm_panel`]).  The factored tiles stay in the
//! [`crate::accel::TileCache`] across panel columns and repeated solves,
//! and the downdate sweep prefetches the next step's rhs blocks depth-1.
//!
//! Per column the arithmetic is exactly [`ptrsv`]'s — same diag solve,
//! same downdate order, no cross-column operations — so a `k`-column
//! `ptrsm` is bit-identical to `k` looped `ptrsv` calls
//! (`tests/multi_rhs.rs`); with `k = 1` the panel kernels price exactly
//! like the single-column ops (only the depth-1 rhs prefetch, which never
//! changes results, is new).
//!
//! [`ptrsv`]: super::ptrsv

use super::trsv::TriKind;
use crate::comm::Payload;
use crate::dist::{DistMatrix, DistMultiVector};
use crate::pblas::{tags, Ctx};
use crate::{Result, Scalar};

/// Solve `T Y = B` in place (`b` becomes `Y`), `T` taken from the
/// corresponding triangle of the factored matrix `a`, for every column of
/// the rhs panel `b`.
pub fn ptrsm<S: Scalar>(
    ctx: &Ctx<'_, S>,
    a: &DistMatrix<S>,
    b: &mut DistMultiVector<S>,
    kind: TriKind,
) -> Result<()> {
    let desc = *a.desc();
    assert_eq!(&desc, b.desc(), "ptrsm operand descriptors differ");
    let kt = desc.mt();
    let t = desc.tile;
    let nrhs = b.ncols();
    let mesh = ctx.mesh;
    let comm = mesh.comm();
    let (pr, pc) = (desc.shape.pr, desc.shape.pc);

    let steps: Vec<usize> = match kind {
        TriKind::LowerUnit | TriKind::Lower => (0..kt).collect(),
        TriKind::Upper => (0..kt).rev().collect(),
    };

    let op = match kind {
        TriKind::LowerUnit => "trsv_lu",
        TriKind::Lower => "trsv_l",
        TriKind::Upper => "trsv_u",
    };

    for &k in &steps {
        let ck = k % pc;
        let rk = k % pr;
        let diag_rank = desc.shape.rank_at(rk, ck);

        // 1. Panel diagonal solve on the owner: one batched kernel over
        //    all k rhs blocks, one world broadcast of the k·t payload.
        let yk_payload = if comm.rank() == diag_rank {
            let diag = a.global_tile(k, k);
            let cost = {
                let mut cols: Vec<&mut [S]> = b
                    .cols_mut()
                    .iter_mut()
                    .map(|v| &mut v.global_block_mut(k)[..])
                    .collect();
                ctx.engine.trsm_panel(op, diag, &mut cols)?
            };
            let mut operands: Vec<&[S]> = vec![a.global_tile(k, k)];
            let outs: Vec<&[S]> = b.cols().iter().map(|v| v.global_block(k)).collect();
            operands.extend(outs.iter().copied());
            ctx.charge_panel_op(cost, &operands, &outs);
            // The broadcast payload is a host read of every solved block.
            let mut payload = Vec::with_capacity(nrhs * t);
            for v in b.cols() {
                ctx.host_read(v.global_block(k));
                payload.extend_from_slice(v.global_block(k));
            }
            Some(Payload::Data(payload))
        } else {
            None
        };
        let world = comm.world();
        let yk = world.bcast(diag_rank, tags::TRSM, yk_payload).into_data();
        if b.col(0).owns(k) && comm.rank() != diag_rank {
            for (j, v) in b.cols_mut().iter_mut().enumerate() {
                v.global_block_mut(k).copy_from_slice(&yk[j * t..(j + 1) * t]);
                ctx.host_mut(v.global_block(k)); // fresh host data
            }
        }

        // 2. Column-k tiles broadcast along process rows — once per tile,
        //    shared by every rhs column — and each rank panel-downdates its
        //    replica blocks.  The next active step's rhs blocks prefetch
        //    depth-1 under the current downdate.
        let row = mesh.row_comm();
        let active: Vec<(usize, usize)> = (0..a.local_mt())
            .map(|lti| (lti, desc.global_ti(mesh.row(), lti)))
            .filter(|&(_, ti)| match kind {
                TriKind::LowerUnit | TriKind::Lower => ti > k,
                TriKind::Upper => ti < k,
            })
            .collect();
        let xs: Vec<&[S]> = (0..nrhs).map(|j| &yk[j * t..(j + 1) * t]).collect();
        for (idx, &(lti, ti)) in active.iter().enumerate() {
            if let Some(&(nlti, nti)) = active.get(idx + 1) {
                if mesh.col() == ck {
                    ctx.prefetch(a.tile(nlti, desc.local_tj(k)));
                }
                for v in b.cols() {
                    ctx.prefetch(v.global_block(nti));
                }
            }
            let data = if mesh.col() == ck {
                ctx.host_read(a.tile(lti, desc.local_tj(k)));
                Some(Payload::Data(a.tile(lti, desc.local_tj(k)).to_vec()))
            } else {
                None
            };
            let tile = row.bcast(ck, tags::TRSM + 1, data).into_data();
            let cost = {
                let mut cols: Vec<&mut [S]> = b
                    .cols_mut()
                    .iter_mut()
                    .map(|v| &mut v.global_block_mut(ti)[..])
                    .collect();
                ctx.engine.gemm_panel("gemv_update", &mut cols, &tile, &xs)?
            };
            let outs: Vec<&[S]> = b.cols().iter().map(|v| v.global_block(ti)).collect();
            let mut operands: Vec<&[S]> = outs.clone();
            operands.push(&tile);
            operands.extend(xs.iter().copied());
            ctx.charge_panel_op(cost, &operands, &outs);
            ctx.host_mut(&tile);
        }
        for chunk in xs {
            ctx.host_mut(chunk);
        }
    }
    // Hand the finished panel back to the host: flush every column block's
    // pending write-back.
    for v in b.cols() {
        for l in 0..v.local_blocks() {
            ctx.host_read(v.block(l));
        }
    }
    Ok(())
}
