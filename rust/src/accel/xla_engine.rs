//! The accelerated engine — the paper's "CUDA/CUBLAS" arm.
//!
//! Tile ops dispatch to the AOT-compiled XLA executables (Pallas GEMM/GEMV +
//! jax factor-tile ops) through the PJRT runtime; the paper's host->device ->
//! kernel -> device->host flow (its §3 steps 4–7) is charged per call from
//! the GTX-280 profile, including the PCIe transfer term that motivates the
//! paper's "the increase is not very high" conclusion.

use std::collections::HashMap;
use std::sync::Arc;

use super::costmodel::{ComputeProfile, OpCost};
use super::engine::{tile_op_cost, Engine, TILE_OPS};
use crate::runtime::{Executable, Runtime};
use crate::sparse::CsrMatrix;
use crate::{Error, Result, Scalar};

/// PJRT-backed engine with an accelerator cost profile.
pub struct XlaEngine<S: Scalar> {
    tile: usize,
    profile: ComputeProfile,
    /// op name -> compiled executable (all compiled at construction).
    exes: HashMap<&'static str, Executable>,
    _marker: std::marker::PhantomData<fn() -> S>,
}

impl<S: Scalar> XlaEngine<S> {
    /// Build over `runtime` for `tile`-sized tiles with the GTX-280 profile.
    /// Compiles (or fetches from cache) every tile op eagerly.
    pub fn new(runtime: &Arc<Runtime>, tile: usize) -> Result<Self> {
        Self::with_profile(runtime, tile, ComputeProfile::gtx280_cublas())
    }

    /// Build with an explicit accelerator profile (ablations).
    pub fn with_profile(
        runtime: &Arc<Runtime>,
        tile: usize,
        profile: ComputeProfile,
    ) -> Result<Self> {
        let mut exes = HashMap::new();
        for &op in TILE_OPS {
            let exe = runtime.op::<S>(op, tile).map_err(|e| {
                Error::runtime(format!("compiling {op} for tile {tile}: {e}"))
            })?;
            exes.insert(op, exe);
        }
        Ok(XlaEngine { tile, profile, exes, _marker: std::marker::PhantomData })
    }

    fn exe(&self, op: &'static str) -> &Executable {
        &self.exes[op]
    }

    fn cost(&self, op: &str) -> OpCost {
        tile_op_cost::<S>(&self.profile, op, self.tile)
    }

    /// Run `op` with `inputs`, write the result into `out`, return the cost.
    fn run_into(&self, op: &'static str, inputs: &[&[S]], out: &mut [S]) -> Result<OpCost> {
        let result = self.exe(op).run::<S>(inputs)?;
        out.copy_from_slice(&result);
        Ok(self.cost(op))
    }
}

impl<S: Scalar> Engine<S> for XlaEngine<S> {
    fn name(&self) -> &'static str {
        "xla-accel"
    }

    fn tile(&self) -> usize {
        self.tile
    }

    fn profile(&self) -> &ComputeProfile {
        &self.profile
    }

    fn gemm(&self, a: &[S], b: &[S], c: &mut [S]) -> Result<OpCost> {
        self.run_into("gemm", &[a, b], c)
    }

    fn gemm_acc(&self, c: &mut [S], a: &[S], b: &[S]) -> Result<OpCost> {
        let result = self.exe("gemm_acc").run::<S>(&[c, a, b])?;
        c.copy_from_slice(&result);
        Ok(self.cost("gemm_acc"))
    }

    fn gemm_update(&self, c: &mut [S], a: &[S], b: &[S]) -> Result<OpCost> {
        let result = self.exe("gemm_update").run::<S>(&[c, a, b])?;
        c.copy_from_slice(&result);
        Ok(self.cost("gemm_update"))
    }

    fn gemm_nt_update(&self, c: &mut [S], a: &[S], b: &[S]) -> Result<OpCost> {
        let result = self.exe("gemm_nt_update").run::<S>(&[c, a, b])?;
        c.copy_from_slice(&result);
        Ok(self.cost("gemm_nt_update"))
    }

    fn gemv(&self, a: &[S], x: &[S], y: &mut [S]) -> Result<OpCost> {
        self.run_into("gemv", &[a, x], y)
    }

    fn gemv_t(&self, a: &[S], x: &[S], y: &mut [S]) -> Result<OpCost> {
        self.run_into("gemv_t", &[a, x], y)
    }

    fn gemv_update(&self, y: &mut [S], a: &[S], x: &[S]) -> Result<OpCost> {
        let result = self.exe("gemv_update").run::<S>(&[y, a, x])?;
        y.copy_from_slice(&result);
        Ok(self.cost("gemv_update"))
    }

    fn gemv_acc(&self, y: &mut [S], a: &[S], x: &[S]) -> Result<OpCost> {
        let result = self.exe("gemv_acc").run::<S>(&[y, a, x])?;
        y.copy_from_slice(&result);
        Ok(self.cost("gemv_acc"))
    }

    fn gemv_t_acc(&self, y: &mut [S], a: &[S], x: &[S]) -> Result<OpCost> {
        let result = self.exe("gemv_t_acc").run::<S>(&[y, a, x])?;
        y.copy_from_slice(&result);
        Ok(self.cost("gemv_t_acc"))
    }

    fn trsm_llu(&self, l: &[S], b: &mut [S]) -> Result<OpCost> {
        let result = self.exe("trsm_llu").run::<S>(&[l, b])?;
        b.copy_from_slice(&result);
        Ok(self.cost("trsm_llu"))
    }

    fn trsm_ru(&self, b: &mut [S], u: &[S]) -> Result<OpCost> {
        let result = self.exe("trsm_ru").run::<S>(&[b, u])?;
        b.copy_from_slice(&result);
        Ok(self.cost("trsm_ru"))
    }

    fn trsm_rlt(&self, b: &mut [S], l: &[S]) -> Result<OpCost> {
        let result = self.exe("trsm_rlt").run::<S>(&[b, l])?;
        b.copy_from_slice(&result);
        Ok(self.cost("trsm_rlt"))
    }

    fn trsv_lu(&self, l: &[S], b: &mut [S]) -> Result<OpCost> {
        let result = self.exe("trsv_lu").run::<S>(&[l, b])?;
        b.copy_from_slice(&result);
        Ok(self.cost("trsv_lu"))
    }

    fn trsv_l(&self, l: &[S], b: &mut [S]) -> Result<OpCost> {
        let result = self.exe("trsv_l").run::<S>(&[l, b])?;
        b.copy_from_slice(&result);
        Ok(self.cost("trsv_l"))
    }

    fn trsv_u(&self, u: &[S], b: &mut [S]) -> Result<OpCost> {
        let result = self.exe("trsv_u").run::<S>(&[u, b])?;
        b.copy_from_slice(&result);
        Ok(self.cost("trsv_u"))
    }

    fn trsv_lt(&self, l: &[S], b: &mut [S]) -> Result<OpCost> {
        let result = self.exe("trsv_lt").run::<S>(&[l, b])?;
        b.copy_from_slice(&result);
        Ok(self.cost("trsv_lt"))
    }

    fn potrf(&self, a: &mut [S]) -> Result<OpCost> {
        let result = self.exe("potrf").run::<S>(&[a])?;
        a.copy_from_slice(&result);
        Ok(self.cost("potrf"))
    }

    fn spmv(&self, _a: &CsrMatrix<S>, _x: &[S], _y: &mut [S]) -> Result<OpCost> {
        // Sparse matvecs are variable-shape: there is no AOT artifact to
        // dispatch to, so the accelerated arm gates off exactly like a
        // missing artifact would (sparse operands run on the CPU engine).
        Err(Error::runtime(
            "spmv is not available on the accelerated engine: no AOT sparse kernel \
             artifact (run sparse operands with the CPU engine)",
        ))
    }

    fn spmv_t(&self, _a: &CsrMatrix<S>, _x: &[S], _y: &mut [S]) -> Result<OpCost> {
        Err(Error::runtime(
            "spmv_t is not available on the accelerated engine: no AOT sparse kernel \
             artifact (run sparse operands with the CPU engine)",
        ))
    }

    fn spmv_part(
        &self,
        _part: &CsrMatrix<S>,
        _total_nnz: usize,
        _x: &[S],
        _y: &mut [S],
    ) -> Result<OpCost> {
        Err(Error::runtime(
            "spmv_part is not available on the accelerated engine: no AOT sparse \
             kernel artifact (run sparse operands with the CPU engine)",
        ))
    }

    fn spmv_t_part(
        &self,
        _part: &CsrMatrix<S>,
        _total_nnz: usize,
        _total_ncols: usize,
        _x: &[S],
        _y: &mut [S],
    ) -> Result<OpCost> {
        Err(Error::runtime(
            "spmv_t_part is not available on the accelerated engine: no AOT sparse \
             kernel artifact (run sparse operands with the CPU engine)",
        ))
    }

    fn blas1_cost(&self, len: usize) -> OpCost {
        // *Unfused* vector-vector ops stay on the host even in the
        // accelerated arm: shipping a 1 KiB axpy over PCIe costs more than
        // computing it, so (like every sane CUBLAS-era code) only matrix
        // kernels offload.  *Fused* BLAS-1 chains are different — the
        // trait-default `blas1_fused_cost` prices them at this engine's own
        // (device) profile, because one launch over the whole resident
        // vector is exactly when offloading starts to pay (DESIGN.md §12).
        ComputeProfile::q6600_atlas().op_cost::<S>(
            super::costmodel::OpClass::Blas1,
            2 * len as u64,
            3 * len * S::BYTES,
            3 * len * S::BYTES,
        )
    }

    fn warmup(&self) -> Result<()> {
        // Everything compiled in `new`; run one gemm to fault-in PJRT paths.
        let t = self.tile;
        let a = vec![S::zero(); t * t];
        let b = vec![S::zero(); t * t];
        let mut c = vec![S::zero(); t * t];
        self.gemm(&a, &b, &mut c)?;
        Ok(())
    }
}
