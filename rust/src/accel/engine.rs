//! The compute-engine abstraction (CUPLSS level 2, "architecture
//! independence"): every local tile operation a distributed solver needs,
//! behind one trait, so the same solver code runs with CUDA-accelerated
//! local compute ([`super::XlaEngine`]) or serial-ATLAS local compute
//! ([`super::CpuEngine`]) — the exact substitution the paper's ablation
//! performs.
//!
//! Every method returns the [`OpCost`] the op would have cost on the
//! profiled hardware; callers charge it to their rank's virtual clock.
//! Matrix tiles are `tile x tile` row-major, vector blocks are `tile` long.
//!
//! BLAS-1 note: `dot`/`axpy`/`scal` execute host-side in both engines (their
//! data is tiny next to the tiles), but each engine *charges* them at its own
//! profile — the accelerated engine pays launch + PCIe per call, reproducing
//! the paper's finding that fine-grained ops cap the GPU's contribution.

use super::costmodel::{OpClass, OpCost};
use crate::sparse::CsrMatrix;
use crate::{Result, Scalar};

/// Exact flop counts per tile op (must match `python/compile/model.py`).
pub fn op_flops(op: &str, t: u64) -> u64 {
    match op {
        "gemm" => 2 * t * t * t,
        "gemm_update" | "gemm_nt_update" | "gemm_acc" => 2 * t * t * t + t * t,
        "gemv" | "gemv_t" => 2 * t * t,
        "gemv_update" | "gemv_acc" | "gemv_t_acc" => 2 * t * t + t,
        "potrf" => t * t * t / 3,
        "trsm_llu" | "trsm_ru" | "trsm_rlt" => t * t * t,
        "trsv_lu" | "trsv_l" | "trsv_u" | "trsv_lt" => t * t,
        "dot" | "axpy" => 2 * t,
        _ => panic!("unknown op {op:?}"),
    }
}

/// Local tile-compute engine.  All `&mut` arguments are updated in place.
pub trait Engine<S: Scalar>: Send + Sync {
    /// Engine label ("cuda"-path vs "atlas"-path in reports).
    fn name(&self) -> &'static str;

    /// Tile edge this engine is built for.
    fn tile(&self) -> usize;

    /// The cost profile tile ops are charged at.  Residency-aware callers
    /// ([`crate::pblas::Ctx::charge_op`]) read `pcie_bw` from here to
    /// re-price the transfer share of an [`OpCost`] after consulting the
    /// per-rank [`super::TileCache`].
    fn profile(&self) -> &super::costmodel::ComputeProfile;

    /// `C = A·B`.
    fn gemm(&self, a: &[S], b: &[S], c: &mut [S]) -> Result<OpCost>;
    /// `C += A·B` (SUMMA local accumulation: folds the former
    /// gemm-then-host-axpy pair into one kernel, so `C` can stay
    /// device-resident across the `kk` panel steps).
    fn gemm_acc(&self, c: &mut [S], a: &[S], b: &[S]) -> Result<OpCost>;
    /// `C -= A·B` (delayed rank-k update).
    fn gemm_update(&self, c: &mut [S], a: &[S], b: &[S]) -> Result<OpCost>;
    /// `C -= A·B^T` (symmetric trailing update).
    fn gemm_nt_update(&self, c: &mut [S], a: &[S], b: &[S]) -> Result<OpCost>;
    /// `y = A·x`.
    fn gemv(&self, a: &[S], x: &[S], y: &mut [S]) -> Result<OpCost>;
    /// `y = A^T·x`.
    fn gemv_t(&self, a: &[S], x: &[S], y: &mut [S]) -> Result<OpCost>;
    /// `y -= A·x`.
    fn gemv_update(&self, y: &mut [S], a: &[S], x: &[S]) -> Result<OpCost>;
    /// `y += A·x` — the matvec partial-sum accumulation fused into one
    /// kernel, so the distributed matvec's output block can stay
    /// device-resident across a rank's tile-row sweep instead of paying a
    /// host-side axpy (and its D2H) per tile (`DESIGN.md` §13).  Element
    /// values are bit-identical to the former gemv-into-scratch + host-axpy
    /// pair: same row-dot order, one final add per element.
    fn gemv_acc(&self, y: &mut [S], a: &[S], x: &[S]) -> Result<OpCost>;
    /// `y += A^T·x` — transpose twin of [`Engine::gemv_acc`] (BiCG's second
    /// sequence / `pgemv_t`'s partial accumulation).
    fn gemv_t_acc(&self, y: &mut [S], a: &[S], x: &[S]) -> Result<OpCost>;
    /// Solve `L X = B` (unit-lower L), B := X.
    fn trsm_llu(&self, l: &[S], b: &mut [S]) -> Result<OpCost>;
    /// Solve `X U = B` (upper U), B := X.
    fn trsm_ru(&self, b: &mut [S], u: &[S]) -> Result<OpCost>;
    /// Solve `X L^T = B` (lower L), B := X.
    fn trsm_rlt(&self, b: &mut [S], l: &[S]) -> Result<OpCost>;
    /// Solve `L y = b` (unit-lower), b := y.
    fn trsv_lu(&self, l: &[S], b: &mut [S]) -> Result<OpCost>;
    /// Solve `L y = b` (general lower), b := y.
    fn trsv_l(&self, l: &[S], b: &mut [S]) -> Result<OpCost>;
    /// Solve `U x = y` (upper), b := x.
    fn trsv_u(&self, u: &[S], b: &mut [S]) -> Result<OpCost>;
    /// Solve `L^T x = y`, b := x.
    fn trsv_lt(&self, l: &[S], b: &mut [S]) -> Result<OpCost>;
    /// In-place lower Cholesky of a diagonal tile.
    fn potrf(&self, a: &mut [S]) -> Result<OpCost>;

    /// Sparse `y = A x` over one CSR row block (`x.len() == a.ncols()`,
    /// `y.len() == a.nrows()`, `y` overwritten).  Unlike the tile ops this
    /// is variable-shape, so the accelerated engine — whose contract is a
    /// closed set of fixed-shape AOT executables — gates it off with a
    /// runtime error; sparse operands run on the CPU arm (see `DESIGN.md`
    /// §10).  Note [`crate::pblas::pspmv()`] panics its rank on an engine
    /// error, like every PBLAS routine.
    fn spmv(&self, a: &CsrMatrix<S>, x: &[S], y: &mut [S]) -> Result<OpCost>;

    /// Sparse `y = A^T x` (`x.len() == a.nrows()`, `y.len() == a.ncols()`,
    /// `y` overwritten) — the BiCG second sequence on sparse operands.
    fn spmv_t(&self, a: &CsrMatrix<S>, x: &[S], y: &mut [S]) -> Result<OpCost>;

    /// Sparse accumulation `y += A_part x` over one column-split *part* of
    /// a row block (see [`crate::sparse::SplitBlocks`]): the split-phase
    /// `pspmv` runs the diagonal-block part while the x allgather is in
    /// flight and the off-block part on completion (`DESIGN.md` §11).  The
    /// part references only its own columns, so the rest of `x` may be
    /// garbage.  Cost contract: `total_nnz` is the whole row block's
    /// stored-entry count and each call charges its part's *share* of one
    /// full [`spmv_cost`], so complementary parts sum to exactly one
    /// matvec — splitting never charges more than the blocking schedule.
    /// Gated off on the accelerated engine like the other sparse ops.
    fn spmv_part(
        &self,
        part: &CsrMatrix<S>,
        total_nnz: usize,
        x: &[S],
        y: &mut [S],
    ) -> Result<OpCost>;

    /// Transpose twin of [`Engine::spmv_part`]: accumulate `y += A_part^T x`
    /// over one column-split part of a row block.  `y.len() == part.ncols()`
    /// (the part's own compact column space — the halo `pspmv_t` runs one
    /// call per part and scatters each compact output itself).  Cost
    /// contract mirrors `spmv_part` against the *transpose* matvec price:
    /// each call charges `part.nnz / total_nnz` of one
    /// `spmv_cost(total_nnz, part.nrows(), total_ncols)`, so complementary
    /// parts sum to exactly the blocking transpose matvec — the compact
    /// halo layout never charges more virtual compute than the full-width
    /// `spmv_t` it replaces.  Gated off on the accelerated engine like the
    /// other sparse ops.
    fn spmv_t_part(
        &self,
        part: &CsrMatrix<S>,
        total_nnz: usize,
        total_ncols: usize,
        x: &[S],
        y: &mut [S],
    ) -> Result<OpCost>;

    /// Modelled cost of a BLAS-1 op of `len` elements on this engine.
    fn blas1_cost(&self, len: usize) -> OpCost;

    /// Modelled cost of one **fused** BLAS-1 kernel over a rank's whole
    /// local vector: `len` elements, `streams` vector-length operand
    /// streams through memory, `flops` total — one launch, one memory
    /// pass (Rupp et al., *Pipelined Iterative Solvers with Kernel Fusion
    /// for GPUs*).  Unlike [`Engine::blas1_cost`] (which both engines keep
    /// host-side), a fused kernel may run on **this engine's own
    /// profile**: fusion is what makes device-side BLAS-1 profitable once
    /// the vectors are cache-resident.  The dispatch picks whichever arm
    /// is cheaper per call — below a crossover length the device launch
    /// overhead still loses to the host pass, and a sane runtime keeps
    /// tiny fused ops on the host exactly like the unfused ones.  The
    /// host arm is pinned to the Q6600 profile, same as
    /// [`super::XlaEngine`]'s `blas1_cost`; the analytic twin
    /// (`ModelParams::blas1_fused`) prices that arm from its `panel_cpu`
    /// field, so ablations that swap `panel_cpu` away from the Q6600 must
    /// expect live-vs-model drift on the dispatch crossover.
    fn blas1_fused_cost(&self, len: usize, streams: usize, flops: u64) -> OpCost {
        let bytes = streams * len * S::BYTES;
        let own = self.profile().op_cost::<S>(
            super::costmodel::OpClass::Blas1,
            flops,
            bytes,
            bytes,
        );
        if self.profile().pcie_bw <= 0.0 {
            return own;
        }
        let host = super::costmodel::ComputeProfile::q6600_atlas().op_cost::<S>(
            super::costmodel::OpClass::Blas1,
            flops,
            bytes,
            bytes,
        );
        if host.total() < own.total() { host } else { own }
    }

    /// Host-side dot with this engine's modelled cost.
    fn dot(&self, x: &[S], y: &[S]) -> (S, OpCost) {
        (crate::linalg::dot(x, y), self.blas1_cost(x.len()))
    }

    /// Host-side axpy with this engine's modelled cost.
    fn axpy(&self, alpha: S, x: &[S], y: &mut [S]) -> OpCost {
        crate::linalg::axpy(alpha, x, y);
        self.blas1_cost(x.len())
    }

    /// Host-side scale with this engine's modelled cost.
    fn scal(&self, alpha: S, x: &mut [S]) -> OpCost {
        crate::linalg::scal(alpha, x);
        self.blas1_cost(x.len())
    }

    /// Pre-compile / warm every op this engine dispatches (no-op for host
    /// engines).  Call before timed sections.
    fn warmup(&self) -> Result<()> {
        Ok(())
    }

    /// RHS-panel triangular solve (a `trsm`-shaped op): solve the same
    /// `tile x tile` triangle against every column block of `cols` in one
    /// batched kernel — `op` names the single-column `trsv_*` variant.
    /// Arithmetic is the looped single-column calls', bit for bit (each
    /// column routes through the very same [`Engine::trsv_lu`]-family
    /// method); only the cost batches: one launch, the triangle streamed
    /// once, priced by [`panel_op_cost`] (`<= k x` the single-column cost,
    /// strictly so for `k > 1`).
    fn trsm_panel(&self, op: &str, tri: &[S], cols: &mut [&mut [S]]) -> Result<OpCost> {
        for blk in cols.iter_mut() {
            match op {
                "trsv_lu" => self.trsv_lu(tri, blk)?,
                "trsv_l" => self.trsv_l(tri, blk)?,
                "trsv_u" => self.trsv_u(tri, blk)?,
                "trsv_lt" => self.trsv_lt(tri, blk)?,
                other => {
                    return Err(crate::Error::config(format!(
                        "trsm_panel: unknown column op {other:?}"
                    )))
                }
            };
        }
        Ok(panel_op_cost::<S>(self.profile(), op, self.tile(), cols.len()))
    }

    /// RHS-panel matvec update (a `gemm`-shaped op): apply the same tile to
    /// `k` paired (y, x) column blocks in one batched kernel — `op` names
    /// the single-column `gemv_update`/`gemv_acc`/`gemv_t_acc` variant.
    /// Same bit-identity + batched-cost contract as [`Engine::trsm_panel`].
    fn gemm_panel(
        &self,
        op: &str,
        cols: &mut [&mut [S]],
        a: &[S],
        xs: &[&[S]],
    ) -> Result<OpCost> {
        assert_eq!(cols.len(), xs.len(), "gemm_panel column pairing mismatch");
        for (yb, xb) in cols.iter_mut().zip(xs) {
            match op {
                "gemv_update" => self.gemv_update(yb, a, xb)?,
                "gemv_acc" => self.gemv_acc(yb, a, xb)?,
                "gemv_t_acc" => self.gemv_t_acc(yb, a, xb)?,
                other => {
                    return Err(crate::Error::config(format!(
                        "gemm_panel: unknown column op {other:?}"
                    )))
                }
            };
        }
        Ok(panel_op_cost::<S>(self.profile(), op, self.tile(), cols.len()))
    }
}

/// Flop count of an RHS-panel op: `k` columns through one batched kernel
/// do exactly the arithmetic of `k` single-column calls (bit-identity is
/// the contract — batching changes cost, never values).
pub fn panel_op_flops(op: &str, t: u64, k: u64) -> u64 {
    k * op_flops(op, t)
}

/// Per-operand traffic of an RHS-panel op: the `tile x tile` operand is
/// touched **once** for all `k` columns (this is the amortization batching
/// buys), while every vector-length operand scales by `k`.  Derived from
/// [`op_operand_elems`], the single-column source of truth.
pub fn panel_operand_elems(op: &str, t: usize, k: usize) -> (Vec<usize>, usize) {
    let t2 = t * t;
    let (ins, out) = op_operand_elems(op, t);
    let ins = ins.into_iter().map(|e| if e == t2 { e } else { e * k }).collect();
    (ins, if out == t2 { out } else { out * k })
}

/// Cost of one RHS-panel op under a profile: `k` columns' flops, the tile
/// streamed once, the vectors streamed `k` times, **one** launch.  By
/// construction `panel_op_cost(op, t, k) <= k * tile_op_cost(op, t)` —
/// strictly for `k > 1` whenever the profile charges launches or the op
/// has a tile operand to amortize (both engines do).
pub fn panel_op_cost<S: Scalar>(
    profile: &super::costmodel::ComputeProfile,
    op: &str,
    tile: usize,
    k: usize,
) -> OpCost {
    let (ins, out) = panel_operand_elems(op, tile, k);
    let touched = (ins.iter().sum::<usize>() + out) * S::BYTES;
    profile.op_cost::<S>(OpClass::of(op), panel_op_flops(op, tile as u64, k as u64), touched, touched)
}

/// Every tile op the engines implement — used by warmup and tests.
pub const TILE_OPS: &[&str] = &[
    "gemm",
    "gemm_acc",
    "gemm_update",
    "gemm_nt_update",
    "gemv",
    "gemv_t",
    "gemv_update",
    "gemv_acc",
    "gemv_t_acc",
    "trsm_llu",
    "trsm_ru",
    "trsm_rlt",
    "trsv_lu",
    "trsv_l",
    "trsv_u",
    "trsv_lt",
    "potrf",
];

/// Per-operand traffic decomposition of one tile-op call: element counts of
/// the *read* operands (in call-argument order) and of the single written
/// operand.  This is the **one source of truth** for per-call traffic: the
/// paper §3 streaming totals ([`op_touched_elems`]) are its sums, and the
/// residency layer ([`super::TileCache`]) prices each operand individually
/// so a cache-resident operand stops streaming.  A read-write operand (the
/// `C` of the update ops) appears in both lists.
pub fn op_operand_elems(op: &str, t: usize) -> (Vec<usize>, usize) {
    let t2 = t * t;
    match op {
        "gemm" => (vec![t2, t2], t2),
        "gemm_acc" | "gemm_update" | "gemm_nt_update" => (vec![t2, t2, t2], t2),
        "gemv" | "gemv_t" => (vec![t2, t], t),
        "gemv_update" | "gemv_acc" | "gemv_t_acc" => (vec![t, t2, t], t),
        "potrf" => (vec![t2], t2),
        "trsm_llu" | "trsm_ru" | "trsm_rlt" => (vec![t2, t2], t2),
        "trsv_lu" | "trsv_l" | "trsv_u" | "trsv_lt" => (vec![t2, t], t),
        _ => panic!("unknown op {op:?}"),
    }
}

/// Total elements an op touches (`(in, out)`) — the sums of
/// [`op_operand_elems`].  Under the paper's §3 flow ("Step 4: Copy matrices
/// from host memory to device memory ... Step 7: Copy back the results")
/// this is also exactly what *streams* host<->device per call, which is why
/// the paper finds the CUDA arm's gain "not very high" for memory-bound
/// kernels; the residency subsystem exists to beat precisely this tax.
pub fn op_touched_elems(op: &str, t: usize) -> (usize, usize) {
    let (ins, out) = op_operand_elems(op, t);
    (ins.iter().sum(), out)
}

/// Flop count of a CSR matvec with `nnz` stored entries (one multiply-add
/// per entry) — the `2·nnz` the sparse cost model charges.
pub fn spmv_flops(nnz: u64) -> u64 {
    2 * nnz
}

/// Modelled cost of a CSR matvec under a profile — shared by the engines
/// and `bench_harness::model::sparse_iter_makespan`.
///
/// Memory-bound ([`OpClass::Blas2`]): per stored entry one value (`S`), one
/// 4-byte column index and one gathered `x` read stream through memory,
/// plus `nrows + 1` row pointers and `nout` output writes (`nout = nrows`
/// for `y = A x`, `ncols` for the transpose matvec).  Indices are priced
/// at the standard 4-byte CSR int even though the host [`CsrMatrix`]
/// stores `usize` — the model prices what a production kernel would
/// stream.
pub fn spmv_cost<S: Scalar>(
    profile: &super::costmodel::ComputeProfile,
    nnz: usize,
    nrows: usize,
    nout: usize,
) -> OpCost {
    let bytes = nnz * (2 * S::BYTES + 4) + (nrows + 1) * 4 + nout * S::BYTES;
    profile.op_cost::<S>(OpClass::Blas2, spmv_flops(nnz as u64), bytes, bytes)
}

/// Helper shared by engine impls and the analytic model: cost of a tile op
/// under a profile, with the op's standard touched footprint streaming in
/// full per call (the paper §3 flow; residency-aware callers re-price the
/// transfer share afterwards via [`crate::pblas::Ctx::charge_op`]).
pub fn tile_op_cost<S: Scalar>(
    profile: &super::costmodel::ComputeProfile,
    op: &str,
    tile: usize,
) -> OpCost {
    let (tin, tout) = op_touched_elems(op, tile);
    profile.op_cost::<S>(
        OpClass::of(op),
        op_flops(op, tile as u64),
        (tin + tout) * S::BYTES,
        (tin + tout) * S::BYTES,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flops_match_python_manifest_values() {
        // spot values from artifacts/manifest.txt
        assert_eq!(op_flops("gemm", 256), 33_554_432);
        assert_eq!(op_flops("gemm_update", 256), 33_619_968);
        assert_eq!(op_flops("gemm_acc", 256), 33_619_968);
        assert_eq!(op_flops("gemv", 128), 32_768);
        assert_eq!(op_flops("gemv_acc", 128), 32_896);
        assert_eq!(op_flops("gemv_t_acc", 128), 32_896);
        assert_eq!(op_flops("potrf", 128), 699_050);
        assert_eq!(op_flops("trsv_u", 128), 16_384);
        assert_eq!(op_flops("dot", 128), 256);
    }

    #[test]
    fn operand_decomposition_sums_to_touched_footprint() {
        // `op_operand_elems` is the single source of truth; the aggregate
        // views must be its sums for every op the engines dispatch.
        for &op in TILE_OPS {
            let (ins, out) = op_operand_elems(op, 32);
            let (tin, tout) = op_touched_elems(op, 32);
            assert_eq!(ins.iter().sum::<usize>(), tin, "{op}");
            assert_eq!(out, tout, "{op}");
            assert!(!ins.is_empty() && out > 0, "{op}");
        }
        // The update family reads its output tile too (3 ins), gemm doesn't.
        assert_eq!(op_operand_elems("gemm", 8).0.len(), 2);
        assert_eq!(op_operand_elems("gemm_acc", 8).0.len(), 3);
        assert_eq!(op_operand_elems("gemm_update", 8).0.len(), 3);
        assert_eq!(op_operand_elems("gemv_acc", 8).0.len(), 3);
        assert_eq!(op_operand_elems("gemv_t_acc", 8).0.len(), 3);
    }

    #[test]
    #[should_panic(expected = "unknown op")]
    fn unknown_op_panics() {
        op_flops("nope", 1);
    }

    #[test]
    fn panel_decomposition_amortizes_the_tile_only() {
        let (t, k) = (32usize, 5usize);
        for op in ["trsv_lu", "trsv_l", "trsv_u", "trsv_lt", "gemv_update", "gemv_acc"] {
            assert_eq!(panel_op_flops(op, t as u64, k as u64), k as u64 * op_flops(op, t as u64));
            let (ins, out) = panel_operand_elems(op, t, k);
            let (sins, sout) = op_operand_elems(op, t);
            // Tile operands appear once, vector operands k times.
            for (p, s) in ins.iter().zip(&sins) {
                assert_eq!(*p, if *s == t * t { *s } else { s * k }, "{op}");
            }
            assert_eq!(out, if sout == t * t { sout } else { sout * k }, "{op}");
            assert!(ins.iter().sum::<usize>() + out < k * (sins.iter().sum::<usize>() + sout));
        }
        // k = 1 degenerates to the single-column decomposition exactly.
        for op in ["trsv_lu", "gemv_update"] {
            assert_eq!(panel_operand_elems(op, t, 1), op_operand_elems(op, t));
        }
    }

    #[test]
    fn panel_cost_at_most_k_times_single_and_strict_for_k_gt_1() {
        for profile in [
            crate::accel::ComputeProfile::q6600_atlas(),
            crate::accel::ComputeProfile::gtx280_cublas(),
        ] {
            for op in ["trsv_lu", "trsv_u", "gemv_update", "gemv_acc"] {
                let single = tile_op_cost::<f32>(&profile, op, 256).total();
                for k in [1usize, 2, 3, 8] {
                    let panel = panel_op_cost::<f32>(&profile, op, 256, k).total();
                    assert!(
                        panel <= k as f64 * single * (1.0 + 1e-12),
                        "{op} k={k}: {panel} vs {}",
                        k as f64 * single
                    );
                    if k > 1 {
                        assert!(panel < k as f64 * single, "{op} k={k} must amortize");
                    }
                }
                // k = 1 is priced exactly like the single-column op.
                assert_eq!(panel_op_cost::<f32>(&profile, op, 256, 1).total(), single);
            }
        }
    }

    #[test]
    fn spmv_cost_is_memory_bound_and_scales_with_nnz() {
        assert_eq!(spmv_flops(5), 10);
        let cpu = crate::accel::ComputeProfile::q6600_atlas();
        let small = spmv_cost::<f64>(&cpu, 1_000, 100, 100);
        let big = spmv_cost::<f64>(&cpu, 100_000, 100, 100);
        assert!(big.total() > small.total());
        assert_eq!(small.transfer_secs, 0.0, "host profile streams nothing");
        // Transpose pricing: same row pointers, wider output.
        assert!(spmv_cost::<f64>(&cpu, 1_000, 100, 400).total() > small.total());
        // The accelerated profile pays PCIe per call, as for every tile op.
        let gpu = crate::accel::ComputeProfile::gtx280_cublas();
        assert!(spmv_cost::<f64>(&gpu, 1_000, 100, 100).transfer_secs > 0.0);
    }
}
