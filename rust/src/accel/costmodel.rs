//! Calibrated compute-cost profiles: how long a tile op takes on the
//! hardware the paper used.
//!
//! The virtual clock charges each local op with the time the *paper's*
//! testbed would need, so the regenerated Figures 3/4 reflect the paper's
//! compute/communication balance rather than this machine's.  Two profiles:
//!
//! * [`ComputeProfile::gtx280_cublas`] — the CUDA path.  NVIDIA GeForce
//!   GTX 280: 240 cores @ 1296 MHz, 141.7 GB/s device memory, PCIe 2.0 x16.
//!   CUBLAS-era sustained rates: SGEMM ~360 GFLOP/s, DGEMM ~60 GFLOP/s
//!   (the GT200's DP units run at 1/8 SP issue).  Every call pays
//!   host->device->host transfers (the paper's step 4/7 flow copies operands
//!   per call) — this is exactly why the paper finds the CUDA gain modest.
//! * [`ComputeProfile::q6600_atlas`] — the ATLAS path.  Intel Core2 Quad
//!   Q6600 @ 2.4 GHz, one core (the paper's baseline is serial): SSE2 gives
//!   ~19.2 GFLOP/s SP peak per core; ATLAS sustains ~70% on SGEMM.

use crate::Scalar;

/// Operation class — determines which throughput term dominates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpClass {
    /// Compute-bound: GEMM-family, tile factorisations, TRSM.
    Blas3,
    /// Memory-bound matrix-vector ops.
    Blas2,
    /// Memory-bound vector ops.
    Blas1,
}

impl OpClass {
    /// Classify an op by its artifact name.
    pub fn of(op: &str) -> OpClass {
        match op {
            "gemm" | "gemm_acc" | "gemm_update" | "gemm_nt_update" | "potrf" | "trsm_llu"
            | "trsm_ru" | "trsm_rlt" => OpClass::Blas3,
            "gemv" | "gemv_t" | "gemv_update" | "gemv_acc" | "gemv_t_acc" | "trsv_lu"
            | "trsv_l" | "trsv_u" | "trsv_lt" => OpClass::Blas2,
            _ => OpClass::Blas1,
        }
    }
}

/// Virtual-time charge for one op: compute vs host<->device transfer split
/// (the transfer share is the paper's "GPU memory contention" term).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct OpCost {
    /// Seconds of device/CPU compute.
    pub compute_secs: f64,
    /// Seconds of host<->device transfer (0 for host engines).
    pub transfer_secs: f64,
}

impl OpCost {
    /// Total seconds.
    pub fn total(&self) -> f64 {
        self.compute_secs + self.transfer_secs
    }

    /// Charge this cost to a rank's virtual clock.
    pub fn charge(&self, clock: &crate::comm::VClock) {
        clock.advance_compute(self.compute_secs);
        clock.advance_transfer(self.transfer_secs);
    }
}

/// Sustained-rate profile of one local compute substrate.
#[derive(Clone, Copy, Debug)]
pub struct ComputeProfile {
    /// Display name.
    pub name: &'static str,
    /// Sustained BLAS-3 FLOP/s, single precision.
    pub flops3_sp: f64,
    /// Sustained BLAS-3 FLOP/s, double precision.
    pub flops3_dp: f64,
    /// Memory bandwidth (bytes/s) bounding BLAS-1/2.
    pub mem_bw: f64,
    /// Per-call overhead (kernel launch / library dispatch), seconds.
    pub launch: f64,
    /// Host<->device bandwidth (bytes/s); 0 means host-resident (no copies).
    pub pcie_bw: f64,
}

impl ComputeProfile {
    /// The paper's GPU: GTX 280 + CUBLAS, PCIe 2.0 x16.
    pub fn gtx280_cublas() -> Self {
        ComputeProfile {
            name: "gtx280-cublas",
            flops3_sp: 360e9,
            flops3_dp: 60e9,
            mem_bw: 120e9,  // ~85% of the 141.7 GB/s peak
            launch: 12e-6,  // CUDA-era launch + CUBLAS dispatch
            pcie_bw: 5.5e9, // effective PCIe 2.0 x16
        }
    }

    /// The paper's CPU baseline: one Q6600 core running ATLAS
    /// (DDR2-800 dual channel sustains ~4 GB/s from one core).
    pub fn q6600_atlas() -> Self {
        ComputeProfile {
            name: "q6600-atlas",
            flops3_sp: 13.5e9, // ~70% of 19.2 GFLOP/s SSE2 SP peak
            flops3_dp: 6.7e9,  // ~70% of 9.6 GFLOP/s DP peak
            mem_bw: 4.0e9,
            launch: 0.2e-6,
            pcie_bw: 0.0, // host-resident
        }
    }

    /// BLAS-3 rate for a dtype.
    pub fn flops3<S: Scalar>(&self) -> f64 {
        if S::BYTES == 4 { self.flops3_sp } else { self.flops3_dp }
    }

    /// Does this profile price f32 above f64 — i.e. is there anything for
    /// a mixed-precision solve to win?  True on the CUDA arm (the GT200
    /// runs SP at 6x DP issue *and* every byte staged over PCIe halves);
    /// false on the host profile, whose whole advantage would be the
    /// memory-bound f32 passes — the model keeps the host arm an exact
    /// wash so the mixed twins degrade conservatively.
    pub fn mixed_advantage(&self) -> bool {
        self.pcie_bw > 0.0 && self.flops3_sp > self.flops3_dp
    }

    /// Model the cost of one op invocation.
    ///
    /// * `flops` — exact op flop count (manifest / closed form);
    /// * `touched_bytes` — total operand/result footprint on the compute
    ///   device (drives the memory-bandwidth bound for BLAS-1/2);
    /// * `stream_bytes` — bytes that cross the host<->device link *per
    ///   call* (device-resident operands excluded; see
    ///   [`super::engine::op_operand_elems`] and [`super::TileCache`]).
    pub fn op_cost<S: Scalar>(
        &self,
        class: OpClass,
        flops: u64,
        touched_bytes: usize,
        stream_bytes: usize,
    ) -> OpCost {
        let rate3 = self.flops3::<S>();
        let compute = match class {
            OpClass::Blas3 => flops as f64 / rate3,
            // Memory-bound classes: whichever of flops-at-1/8-rate3 or
            // memory traffic is slower (BLAS-2/1 sustain far below peak).
            OpClass::Blas2 | OpClass::Blas1 => {
                let flop_time = flops as f64 / (rate3 / 8.0);
                let mem_time = touched_bytes as f64 / self.mem_bw;
                flop_time.max(mem_time)
            }
        };
        let transfer =
            if self.pcie_bw > 0.0 { stream_bytes as f64 / self.pcie_bw } else { 0.0 };
        OpCost { compute_secs: compute + self.launch, transfer_secs: transfer }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_ops() {
        assert_eq!(OpClass::of("gemm"), OpClass::Blas3);
        assert_eq!(OpClass::of("gemm_nt_update"), OpClass::Blas3);
        assert_eq!(OpClass::of("gemv_t"), OpClass::Blas2);
        assert_eq!(OpClass::of("dot"), OpClass::Blas1);
    }

    #[test]
    fn gpu_beats_cpu_on_big_gemm_but_not_small() {
        let gpu = ComputeProfile::gtx280_cublas();
        let cpu = ComputeProfile::q6600_atlas();
        // 256-tile SGEMM: 2*256^3 = 33.5 MFLOP; 3 tiles in, 1 out.
        let flops = 2 * 256u64.pow(3);
        let bytes = 256 * 256 * 4;
        let g = gpu.op_cost::<f32>(OpClass::Blas3, flops, 2 * bytes, bytes);
        let c = cpu.op_cost::<f32>(OpClass::Blas3, flops, 2 * bytes, bytes);
        assert!(g.total() < c.total(), "gpu {g:?} vs cpu {c:?}");
        // 32-tile GEMV: transfer+launch dominates -> GPU slower.
        let flops = 2 * 32u64.pow(2);
        let bytes = 32 * 32 * 4;
        let g = gpu.op_cost::<f32>(OpClass::Blas2, flops, bytes + 128, 128);
        let c = cpu.op_cost::<f32>(OpClass::Blas2, flops, bytes + 128, 128);
        assert!(g.total() > c.total(), "small op must be cheaper on host");
    }

    #[test]
    fn dp_slower_than_sp_especially_on_gpu() {
        let gpu = ComputeProfile::gtx280_cublas();
        let flops = 2 * 256u64.pow(3);
        let sp = gpu.op_cost::<f32>(OpClass::Blas3, flops, 0, 0);
        let dp = gpu.op_cost::<f64>(OpClass::Blas3, flops, 0, 0);
        // GT200 DP is ~6x slower than SP at these sustained rates.
        let ratio = dp.compute_secs / sp.compute_secs;
        assert!(ratio > 4.0 && ratio < 8.0, "ratio={ratio}");
    }

    #[test]
    fn transfer_share_is_visible() {
        // The paper's observation: per-call PCIe copies eat a large share.
        let gpu = ComputeProfile::gtx280_cublas();
        let t = 256usize;
        let flops = 2 * (t as u64).pow(3);
        let bytes = t * t * 4;
        let cost = gpu.op_cost::<f32>(OpClass::Blas3, flops, 3 * bytes, bytes);
        let share = cost.transfer_secs / cost.total();
        assert!(share > 0.3, "transfer share {share} should be substantial");
    }

    #[test]
    fn mixed_advantage_only_on_the_accelerated_arm() {
        assert!(ComputeProfile::gtx280_cublas().mixed_advantage());
        assert!(!ComputeProfile::q6600_atlas().mixed_advantage());
    }

    #[test]
    fn charge_updates_clock() {
        let clock = crate::comm::VClock::new();
        OpCost { compute_secs: 1.0, transfer_secs: 0.5 }.charge(&clock);
        assert_eq!(clock.compute_secs(), 1.0);
        assert_eq!(clock.transfer_secs(), 0.5);
        assert_eq!(clock.now(), 1.5);
    }
}
