//! Device residency: the per-rank [`TileCache`] that stops the accelerated
//! arm from paying the paper's §3 copy-per-call PCIe tax.
//!
//! The paper's flow re-copies every operand host→device and every result
//! device→host on *every* call (its steps 4/7) — its own profiling blames
//! exactly this for the CUDA arm's modest gain.  The standard remedy
//! (Ioannidis et al., *On the performance of various parallel GMRES
//! implementations on CPU and GPU clusters*) is to keep operands
//! device-resident across calls.  `TileCache` models that: it tracks which
//! host buffers currently have a device copy, so an operand streams over
//! PCIe only on **first touch** or after a **host mutation**, under an LRU
//! eviction policy bounded by the device-memory budget (GTX 280 = 1 GB).
//!
//! Accounting rules (all charging happens inside [`TileCache::access`]):
//!
//! * **read operand** — streams H2D iff no device copy exists; afterwards a
//!   clean device copy is resident.
//! * **written operand** — the D2H write-back is paid **up front, once per
//!   dirty period**: the first device write after the buffer was clean (or
//!   absent) charges the eventual write-back; further writes are free until
//!   a host read ends the period ([`TileCache::host_read`]).  Paying at
//!   period start means the cache never carries an unflushed-debt liability
//!   — totals are exact whenever the host observes the data, which in this
//!   simulated cluster it always eventually does (payloads, gathers).
//! * **host mutation** ([`TileCache::host_mut`]) — drops the device copy;
//!   the next device use re-streams.  Also used to *retire* transient
//!   buffers (broadcast panels) before they are freed, so a reused heap
//!   allocation can never alias a stale entry.
//! * **eviction** — least-recently-used entries are dropped until the
//!   working set fits the budget; dirty victims were already paid for, so
//!   eviction itself is free (thrash shows up as re-streaming, as it
//!   should).
//!
//! Every per-call charge is `<=` the paper-flow streaming charge for the
//! same call, so cached virtual time can never exceed streaming virtual
//! time — the invariant `cargo bench --bench residency` asserts.  The cache
//! only ever re-prices the *transfer* share of an [`super::OpCost`]; the
//! math itself always executes identically, which is why results are
//! bit-identical with the cache on or off (pinned by `tests/residency.rs`).

use std::collections::{BTreeMap, HashMap, HashSet};

/// The GTX 280's device memory: the default residency budget.
pub const DEFAULT_DEVICE_MEM: usize = 1 << 30; // 1 GiB

/// Stable identity of one host buffer: its address and byte length.  Tile
/// and vector-block buffers never reallocate while in use, so the address
/// is stable; transient buffers must be retired before being freed (see the
/// module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct BufKey {
    ptr: usize,
    bytes: usize,
}

impl BufKey {
    /// Key of a slice's backing buffer.
    pub fn of<T>(buf: &[T]) -> BufKey {
        BufKey { ptr: buf.as_ptr() as usize, bytes: std::mem::size_of_val(buf) }
    }

    /// Device bytes this buffer occupies.
    pub fn bytes(&self) -> usize {
        self.bytes
    }
}

#[derive(Debug)]
struct Entry {
    bytes: usize,
    dirty: bool,
    tick: u64,
}

/// PCIe traffic of one op call under residency, next to what the paper's
/// streaming flow would have moved.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Traffic {
    /// Bytes streamed host→device (non-resident read operands).
    pub h2d_bytes: usize,
    /// Bytes charged device→host (write-back slots opened by this call).
    pub d2h_bytes: usize,
    /// Bytes the streaming flow would have moved for the same call.
    pub full_bytes: usize,
}

impl Traffic {
    /// Bytes actually crossing PCIe for this call.
    pub fn streamed(&self) -> usize {
        self.h2d_bytes + self.d2h_bytes
    }

    /// Bytes the residency layer kept off the link (never negative: each
    /// operand charges at most its streaming price).
    pub fn saved(&self) -> usize {
        self.full_bytes - self.streamed()
    }
}

/// Per-rank device-residency tracker (see the module docs for the rules).
#[derive(Debug)]
pub struct TileCache {
    budget: usize,
    map: HashMap<BufKey, Entry>,
    /// Recency index: tick -> key (ticks are unique), so the LRU victim is
    /// the first entry — O(log n) eviction even under thrash, where the
    /// hot paths miss on nearly every access.
    lru: BTreeMap<u64, BufKey>,
    /// Entries with an async transfer in flight (`DESIGN.md` §13): never
    /// evicted — a DMA's source/target cannot be dropped mid-transfer.
    /// Admission *declines* instead when pinned entries block the room, so
    /// a pathologically tight budget degrades to per-call streaming rather
    /// than evicting the very operands the imminent op prefetched.
    pinned: HashSet<BufKey>,
    used: usize,
    tick: u64,
}

impl TileCache {
    /// A cache bounded by `budget` device bytes.
    pub fn new(budget: usize) -> Self {
        TileCache {
            budget,
            map: HashMap::new(),
            lru: BTreeMap::new(),
            pinned: HashSet::new(),
            used: 0,
            tick: 0,
        }
    }

    /// A cache with the GTX 280 budget.
    pub fn default_budget() -> Self {
        Self::new(DEFAULT_DEVICE_MEM)
    }

    /// The configured device-memory budget, bytes.
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Device bytes currently resident.
    pub fn resident_bytes(&self) -> usize {
        self.used
    }

    /// Number of resident buffers.
    pub fn entries(&self) -> usize {
        self.map.len()
    }

    fn next_tick(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    /// Evict least-recently-used **unpinned** entries until `extra` more
    /// bytes fit (or only pinned entries remain).  Dirty victims were paid
    /// for at write time, so eviction is free.
    fn make_room(&mut self, extra: usize) {
        while self.used + extra > self.budget {
            let Some(victim) =
                self.lru.values().copied().find(|k| !self.pinned.contains(k))
            else {
                break; // everything left is mid-transfer: admission declines
            };
            let e = self.map.remove(&victim).expect("victim resident");
            self.lru.remove(&e.tick);
            self.used -= e.bytes;
        }
    }

    /// Move `key`'s recency stamp to `tick` in both indices.
    fn retouch(&mut self, key: BufKey, old_tick: u64, tick: u64) {
        self.lru.remove(&old_tick);
        self.lru.insert(tick, key);
    }

    /// Admit `key` if room can be made without touching pinned entries;
    /// returns whether it is now resident (a decline means the buffer
    /// streams per call until the pins drain — the caller already charged
    /// the stream either way).
    fn insert(&mut self, key: BufKey, dirty: bool, tick: u64) -> bool {
        self.make_room(key.bytes);
        if self.used + key.bytes > self.budget {
            return false;
        }
        self.map.insert(key, Entry { bytes: key.bytes, dirty, tick });
        self.lru.insert(tick, key);
        self.used += key.bytes;
        true
    }

    /// Is `key` currently resident?
    pub fn is_resident(&self, key: BufKey) -> bool {
        self.map.contains_key(&key)
    }

    /// Is `key` resident with device-side writes the host has not observed
    /// (an open dirty period)?  The GPUDirect wire reads exactly these
    /// buffers straight from device memory (`DESIGN.md` §16).
    pub fn is_dirty(&self, key: BufKey) -> bool {
        self.map.get(&key).is_some_and(|e| e.dirty)
    }

    /// Pin a resident entry against eviction while its async transfer is
    /// in flight (`DESIGN.md` §13); no-op if not resident.
    pub fn pin(&mut self, key: BufKey) {
        if self.map.contains_key(&key) {
            self.pinned.insert(key);
        }
    }

    /// Drop a pin (the in-flight transfer was consumed or abandoned).
    pub fn unpin(&mut self, key: BufKey) {
        self.pinned.remove(&key);
    }

    /// Ensure `key` is resident as a *clean* read copy; returns the H2D
    /// bytes this streams (0 on a hit).  Buffers larger than the whole
    /// budget stream per call and are never inserted.
    ///
    /// Public as the **async prefetch** entry point (`DESIGN.md` §13): the
    /// returned byte count is what [`crate::pblas::Ctx::prefetch`] queues
    /// on the copy-engine timeline ahead of use.  Prefetching is plain
    /// first-touch admission — same LRU, same budget — so a prefetched
    /// entry is indistinguishable from a demand-streamed one; only *when*
    /// the bytes cross the link changes, never whether.
    pub fn touch_read(&mut self, key: BufKey) -> usize {
        let tick = self.next_tick();
        if let Some(e) = self.map.get_mut(&key) {
            let old = e.tick;
            e.tick = tick;
            self.retouch(key, old, tick);
            return 0;
        }
        if key.bytes > self.budget {
            return key.bytes;
        }
        self.insert(key, false, tick); // may decline under pin pressure
        key.bytes
    }

    /// Record a device write to `key`; returns the D2H write-back bytes to
    /// charge now (one per dirty period; 0 while already dirty).  Public so
    /// the async accounting path can queue the write-back on the
    /// copy-engine timeline instead of the compute timeline.
    pub fn touch_write(&mut self, key: BufKey) -> usize {
        let tick = self.next_tick();
        if let Some(e) = self.map.get_mut(&key) {
            let old = e.tick;
            e.tick = tick;
            let was_dirty = e.dirty;
            e.dirty = true;
            self.retouch(key, old, tick);
            return if was_dirty { 0 } else { key.bytes };
        }
        // Not resident: open a write-back slot; oversized buffers stream.
        if key.bytes <= self.budget {
            self.insert(key, true, tick);
        }
        key.bytes
    }

    /// Account one op call: read operands `ins`, written operand `out`
    /// (pass the same key in both for read-write operands, as
    /// [`crate::accel::engine::op_operand_elems`] does).
    pub fn access(&mut self, ins: &[BufKey], out: Option<BufKey>) -> Traffic {
        let mut t = Traffic::default();
        for &k in ins {
            t.full_bytes += k.bytes;
            t.h2d_bytes += self.touch_read(k);
        }
        if let Some(k) = out {
            t.full_bytes += k.bytes;
            t.d2h_bytes += self.touch_write(k);
        }
        t
    }

    /// The host observes `buf`'s current value (message payload, gather):
    /// this ends the buffer's dirty period.  Free — the write-back was paid
    /// when the period opened.
    pub fn host_read(&mut self, key: BufKey) {
        if let Some(e) = self.map.get_mut(&key) {
            e.dirty = false;
        }
    }

    /// The host mutates (or is about to free) `buf`: the device copy is
    /// stale and is dropped (pins too — the transfer's consumer is gone);
    /// the next device use re-streams.
    pub fn host_mut(&mut self, key: BufKey) {
        self.pinned.remove(&key);
        if let Some(e) = self.map.remove(&key) {
            self.lru.remove(&e.tick);
            self.used -= e.bytes;
        }
    }

    /// Drop everything (between bench repetitions).
    pub fn clear(&mut self) {
        self.map.clear();
        self.lru.clear();
        self.pinned.clear();
        self.used = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(ptr: usize, bytes: usize) -> BufKey {
        BufKey { ptr, bytes }
    }

    #[test]
    fn first_touch_streams_then_hits() {
        let mut c = TileCache::new(1 << 20);
        let a = key(0x1000, 4096);
        let b = key(0x2000, 4096);
        let t = c.access(&[a, b], None);
        assert_eq!(t.h2d_bytes, 8192);
        assert_eq!(t.full_bytes, 8192);
        assert_eq!(t.saved(), 0);
        let t = c.access(&[a, b], None);
        assert_eq!(t.h2d_bytes, 0, "resident operands stop streaming");
        assert_eq!(t.saved(), 8192);
        assert_eq!(c.resident_bytes(), 8192);
    }

    #[test]
    fn writeback_paid_once_per_dirty_period() {
        let mut c = TileCache::new(1 << 20);
        let out = key(0x3000, 4096);
        // First write opens the period: D2H charged up front.
        assert_eq!(c.access(&[out], Some(out)).d2h_bytes, 4096);
        // Repeated device writes in the same period are free.
        assert_eq!(c.access(&[out], Some(out)).streamed(), 0);
        // A host read closes the period...
        c.host_read(out);
        assert_eq!(c.access(&[out], Some(out)).d2h_bytes, 4096, "new period");
        // ...and saved() never goes negative on any single call.
        c.host_read(out);
        let t = c.access(&[out], Some(out));
        assert!(t.streamed() <= t.full_bytes);
    }

    #[test]
    fn host_mut_invalidates() {
        let mut c = TileCache::new(1 << 20);
        let a = key(0x1000, 1024);
        c.access(&[a], None);
        c.host_mut(a);
        assert_eq!(c.entries(), 0);
        assert_eq!(c.access(&[a], None).h2d_bytes, 1024, "re-streams after mutation");
    }

    #[test]
    fn lru_eviction_respects_budget() {
        let mut c = TileCache::new(3000);
        let (a, b, d) = (key(0x1, 1024), key(0x2, 1024), key(0x3, 1024));
        c.access(&[a, b], None);
        c.access(&[a], None); // a more recent than b
        c.access(&[d], None); // evicts b (LRU)
        assert!(c.resident_bytes() <= 3000);
        assert_eq!(c.access(&[a], None).h2d_bytes, 0, "a survived");
        assert_eq!(c.access(&[b], None).h2d_bytes, 1024, "b was evicted");
    }

    #[test]
    fn pinned_entries_survive_pressure_and_admission_declines() {
        let mut c = TileCache::new(2048);
        let (a, b, d) = (key(0x1, 1024), key(0x2, 1024), key(0x3, 1024));
        c.access(&[a, b], None);
        c.pin(a);
        c.pin(b);
        // With everything pinned, admitting d must decline, not evict.
        assert_eq!(c.access(&[d], None).h2d_bytes, 1024, "d streams");
        assert!(!c.is_resident(d), "admission declined under pin pressure");
        assert!(c.is_resident(a) && c.is_resident(b), "pins survive");
        // Unpinning one frees a victim: the next admission evicts it.
        c.unpin(a);
        assert_eq!(c.access(&[d], None).h2d_bytes, 1024);
        assert!(c.is_resident(d) && !c.is_resident(a));
        assert!(c.is_resident(b), "the still-pinned entry survives");
        assert!(c.resident_bytes() <= c.budget());
        // host_mut drops entry and pin together.
        c.host_mut(b);
        assert!(!c.is_resident(b));
        assert_eq!(c.access(&[a], None).h2d_bytes, 1024, "a re-admits freely");
    }

    #[test]
    fn oversized_buffers_stream_without_residency() {
        let mut c = TileCache::new(1000);
        let big = key(0x9, 4096);
        assert_eq!(c.access(&[big], Some(big)).streamed(), 8192);
        assert_eq!(c.entries(), 0);
        // And charges never exceed the streaming flow.
        let t = c.access(&[big], Some(big));
        assert_eq!(t.streamed(), t.full_bytes);
    }

    #[test]
    fn every_call_charges_at_most_the_streaming_flow() {
        // Deterministic mixed trace over a small budget: per-call charged
        // <= full, cumulatively strictly less once anything is re-touched.
        let mut c = TileCache::new(8 * 512);
        let keys: Vec<BufKey> = (0..16).map(|i| key(0x1000 + i * 0x100, 512)).collect();
        let (mut charged, mut full) = (0usize, 0usize);
        for step in 0..200usize {
            let a = keys[step % 16];
            let b = keys[(step * 7 + 3) % 16];
            let out = keys[(step * 5 + 1) % 16];
            let t = c.access(&[a, b, out], Some(out));
            assert!(t.streamed() <= t.full_bytes, "step {step}");
            charged += t.streamed();
            full += t.full_bytes;
            if step % 9 == 0 {
                c.host_read(out);
            }
            if step % 13 == 0 {
                c.host_mut(b);
            }
            assert!(c.resident_bytes() <= c.budget());
        }
        assert!(charged < full, "residency must save something: {charged} vs {full}");
    }
}
