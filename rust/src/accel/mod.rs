//! Local acceleration layer (CUPLSS level 2): the [`Engine`] trait plus its
//! two implementations — the PJRT-backed [`XlaEngine`] (the paper's
//! CUDA/CUBLAS path) and the pure-rust [`CpuEngine`] (the serial-ATLAS
//! ablation path) — the calibrated hardware cost models that drive the
//! virtual clock, and the device-[`residency`] subsystem ([`TileCache`])
//! that lets hot paths stop paying the paper's copy-per-call PCIe tax
//! (`DESIGN.md` §12).

pub mod costmodel;
pub mod cpu_engine;
pub mod engine;
pub mod residency;
pub mod xla_engine;

pub use costmodel::{ComputeProfile, OpClass, OpCost};
pub use cpu_engine::CpuEngine;
pub use engine::{op_flops, panel_op_cost, panel_op_flops, panel_operand_elems, Engine, TILE_OPS};
pub use residency::{BufKey, TileCache, Traffic, DEFAULT_DEVICE_MEM};
pub use xla_engine::XlaEngine;

use crate::{Result, Scalar};
use std::sync::Arc;

/// Which local-compute arm to use — the paper's ablation axis.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Accelerated local compute (the paper's MPI+CUDA configuration).
    Accelerated,
    /// Serial CPU local compute (the paper's MPI+ATLAS configuration).
    CpuSerial,
}

impl EngineKind {
    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "cuda" | "accel" | "xla" | "gpu" => Ok(EngineKind::Accelerated),
            "atlas" | "cpu" | "serial" => Ok(EngineKind::CpuSerial),
            other => Err(crate::Error::config(format!(
                "unknown engine {other:?} (expected cuda|atlas)"
            ))),
        }
    }

    /// Display label matching the paper's figure legends.
    pub fn label(&self) -> &'static str {
        match self {
            EngineKind::Accelerated => "MPI+CUDA",
            EngineKind::CpuSerial => "MPI+ATLAS",
        }
    }
}

/// Construct an engine of `kind` over `tile`-sized tiles.
/// `runtime` is required for the accelerated arm.
pub fn make_engine<S: Scalar>(
    kind: EngineKind,
    tile: usize,
    runtime: Option<&Arc<crate::runtime::Runtime>>,
) -> Result<Arc<dyn Engine<S>>> {
    match kind {
        EngineKind::CpuSerial => Ok(Arc::new(CpuEngine::new(tile))),
        EngineKind::Accelerated => {
            let rt = runtime.ok_or_else(|| {
                crate::Error::config("accelerated engine needs a PJRT runtime")
            })?;
            Ok(Arc::new(XlaEngine::<S>::new(rt, tile)?))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parses() {
        assert_eq!(EngineKind::parse("cuda").unwrap(), EngineKind::Accelerated);
        assert_eq!(EngineKind::parse("atlas").unwrap(), EngineKind::CpuSerial);
        assert!(EngineKind::parse("quantum").is_err());
    }

    #[test]
    fn labels_match_paper() {
        assert_eq!(EngineKind::Accelerated.label(), "MPI+CUDA");
        assert_eq!(EngineKind::CpuSerial.label(), "MPI+ATLAS");
    }
}
