//! The serial-CPU engine — the paper's "ATLAS" ablation arm.
//!
//! Local tile ops execute through the pure-rust BLAS ([`crate::linalg`]);
//! virtual-time charges come from the Q6600/ATLAS profile (or any profile
//! the caller supplies, e.g. for ablation sweeps).

use super::costmodel::{ComputeProfile, OpCost};
use super::engine::{spmv_cost, tile_op_cost, Engine};
use crate::sparse::CsrMatrix;
use crate::{linalg, Result, Scalar};

/// Pure-rust serial engine with a modelled CPU profile.
pub struct CpuEngine {
    tile: usize,
    profile: ComputeProfile,
}

impl CpuEngine {
    /// Engine over `tile`-sized tiles with the classic ATLAS profile.
    pub fn new(tile: usize) -> Self {
        CpuEngine { tile, profile: ComputeProfile::q6600_atlas() }
    }

    /// Engine with an explicit cost profile (ablations).
    pub fn with_profile(tile: usize, profile: ComputeProfile) -> Self {
        CpuEngine { tile, profile }
    }

    fn cost<S: Scalar>(&self, op: &str) -> OpCost {
        tile_op_cost::<S>(&self.profile, op, self.tile)
    }
}

impl<S: Scalar> Engine<S> for CpuEngine {
    fn name(&self) -> &'static str {
        "cpu-serial"
    }

    fn tile(&self) -> usize {
        self.tile
    }

    fn profile(&self) -> &ComputeProfile {
        &self.profile
    }

    fn gemm(&self, a: &[S], b: &[S], c: &mut [S]) -> Result<OpCost> {
        let t = self.tile;
        linalg::gemm(t, t, t, a, b, c);
        Ok(self.cost::<S>("gemm"))
    }

    fn gemm_acc(&self, c: &mut [S], a: &[S], b: &[S]) -> Result<OpCost> {
        let t = self.tile;
        linalg::gemm_add(t, t, t, a, b, c);
        Ok(self.cost::<S>("gemm_acc"))
    }

    fn gemm_update(&self, c: &mut [S], a: &[S], b: &[S]) -> Result<OpCost> {
        let t = self.tile;
        linalg::gemm_sub(t, t, t, a, b, c);
        Ok(self.cost::<S>("gemm_update"))
    }

    fn gemm_nt_update(&self, c: &mut [S], a: &[S], b: &[S]) -> Result<OpCost> {
        let t = self.tile;
        linalg::gemm_nt_sub(t, t, t, a, b, c);
        Ok(self.cost::<S>("gemm_nt_update"))
    }

    fn gemv(&self, a: &[S], x: &[S], y: &mut [S]) -> Result<OpCost> {
        let t = self.tile;
        linalg::gemv(t, t, a, x, y);
        Ok(self.cost::<S>("gemv"))
    }

    fn gemv_t(&self, a: &[S], x: &[S], y: &mut [S]) -> Result<OpCost> {
        let t = self.tile;
        linalg::gemv_t(t, t, a, x, y);
        Ok(self.cost::<S>("gemv_t"))
    }

    fn gemv_update(&self, y: &mut [S], a: &[S], x: &[S]) -> Result<OpCost> {
        let t = self.tile;
        linalg::gemv_sub(t, t, a, x, y);
        Ok(self.cost::<S>("gemv_update"))
    }

    fn gemv_acc(&self, y: &mut [S], a: &[S], x: &[S]) -> Result<OpCost> {
        let t = self.tile;
        linalg::gemv_add(t, t, a, x, y);
        Ok(self.cost::<S>("gemv_acc"))
    }

    fn gemv_t_acc(&self, y: &mut [S], a: &[S], x: &[S]) -> Result<OpCost> {
        let t = self.tile;
        linalg::gemv_t_add(t, t, a, x, y);
        Ok(self.cost::<S>("gemv_t_acc"))
    }

    fn trsm_llu(&self, l: &[S], b: &mut [S]) -> Result<OpCost> {
        let t = self.tile;
        linalg::trsm_llu(t, t, l, b);
        Ok(self.cost::<S>("trsm_llu"))
    }

    fn trsm_ru(&self, b: &mut [S], u: &[S]) -> Result<OpCost> {
        let t = self.tile;
        linalg::trsm_ru(t, t, u, b);
        Ok(self.cost::<S>("trsm_ru"))
    }

    fn trsm_rlt(&self, b: &mut [S], l: &[S]) -> Result<OpCost> {
        let t = self.tile;
        linalg::trsm_rlt(t, t, l, b);
        Ok(self.cost::<S>("trsm_rlt"))
    }

    fn trsv_lu(&self, l: &[S], b: &mut [S]) -> Result<OpCost> {
        let t = self.tile;
        linalg::trsv_lu(t, l, b);
        Ok(self.cost::<S>("trsv_lu"))
    }

    fn trsv_l(&self, l: &[S], b: &mut [S]) -> Result<OpCost> {
        let t = self.tile;
        linalg::trsv_l(t, l, b);
        Ok(self.cost::<S>("trsv_l"))
    }

    fn trsv_u(&self, u: &[S], b: &mut [S]) -> Result<OpCost> {
        let t = self.tile;
        linalg::trsv_u(t, u, b);
        Ok(self.cost::<S>("trsv_u"))
    }

    fn trsv_lt(&self, l: &[S], b: &mut [S]) -> Result<OpCost> {
        let t = self.tile;
        linalg::trsv_lt(t, l, b);
        Ok(self.cost::<S>("trsv_lt"))
    }

    fn potrf(&self, a: &mut [S]) -> Result<OpCost> {
        let t = self.tile;
        linalg::potrf(t, a)?;
        Ok(self.cost::<S>("potrf"))
    }

    fn spmv(&self, a: &CsrMatrix<S>, x: &[S], y: &mut [S]) -> Result<OpCost> {
        a.spmv(x, y);
        Ok(spmv_cost::<S>(&self.profile, a.nnz(), a.nrows(), a.nrows()))
    }

    fn spmv_t(&self, a: &CsrMatrix<S>, x: &[S], y: &mut [S]) -> Result<OpCost> {
        a.spmv_t(x, y);
        Ok(spmv_cost::<S>(&self.profile, a.nnz(), a.nrows(), a.ncols()))
    }

    fn spmv_part(
        &self,
        part: &CsrMatrix<S>,
        total_nnz: usize,
        x: &[S],
        y: &mut [S],
    ) -> Result<OpCost> {
        assert_eq!(x.len(), part.ncols(), "spmv_part: x length != ncols");
        assert_eq!(y.len(), part.nrows(), "spmv_part: y length != nrows");
        assert!(part.nnz() <= total_nnz, "spmv_part: part larger than its whole");
        for (i, yi) in y.iter_mut().enumerate() {
            let (cols, vals) = part.row(i);
            let mut acc = S::zero();
            for (&c, &v) in cols.iter().zip(vals) {
                acc += v * x[c];
            }
            *yi += acc;
        }
        // Charged as this part's *share* of the one fused matvec the
        // blocking schedule prices: complementary parts sum exactly to
        // `spmv_cost`, so splitting never costs more virtual compute than
        // one `spmv` (the overlap-never-loses invariant).
        let total = spmv_cost::<S>(&self.profile, total_nnz, part.nrows(), part.nrows());
        let frac = if total_nnz == 0 { 0.0 } else { part.nnz() as f64 / total_nnz as f64 };
        Ok(OpCost {
            compute_secs: total.compute_secs * frac,
            transfer_secs: total.transfer_secs * frac,
        })
    }

    fn spmv_t_part(
        &self,
        part: &CsrMatrix<S>,
        total_nnz: usize,
        total_ncols: usize,
        x: &[S],
        y: &mut [S],
    ) -> Result<OpCost> {
        assert_eq!(x.len(), part.nrows(), "spmv_t_part: x length != nrows");
        assert_eq!(y.len(), part.ncols(), "spmv_t_part: y length != ncols");
        assert!(part.nnz() <= total_nnz, "spmv_t_part: part larger than its whole");
        // Same accumulation order as CsrMatrix::spmv_t, but *without* the
        // zero-fill: rows ascending, CSR column order within each row, one
        // `y[c] += v * x[i]` per stored entry — so running the column-split
        // parts back to back reproduces the unsplit transpose matvec bit
        // for bit on each part's own columns.
        for i in 0..part.nrows() {
            let (cols, vals) = part.row(i);
            let xi = x[i];
            for (&c, &v) in cols.iter().zip(vals) {
                y[c] += v * xi;
            }
        }
        // Fractional share of the one *transpose* matvec the blocking
        // schedule prices (output width `total_ncols`), mirroring
        // `spmv_part`'s share contract: complementary parts sum to exactly
        // `spmv_cost(total_nnz, nrows, total_ncols)`.
        let total = spmv_cost::<S>(&self.profile, total_nnz, part.nrows(), total_ncols);
        let frac = if total_nnz == 0 { 0.0 } else { part.nnz() as f64 / total_nnz as f64 };
        Ok(OpCost {
            compute_secs: total.compute_secs * frac,
            transfer_secs: total.transfer_secs * frac,
        })
    }

    fn blas1_cost(&self, len: usize) -> OpCost {
        // touched: 2 reads + 1 write; host engine streams nothing.
        self.profile.op_cost::<S>(
            super::costmodel::OpClass::Blas1,
            2 * len as u64,
            3 * len * S::BYTES,
            3 * len * S::BYTES,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::engine::Engine as _;
    use crate::util::Prng;

    #[test]
    fn gemm_runs_and_costs() {
        let e = CpuEngine::new(8);
        let mut rng = Prng::new(1);
        let mut a = vec![0.0f64; 64];
        let mut b = vec![0.0f64; 64];
        rng.fill_normal(&mut a);
        rng.fill_normal(&mut b);
        let mut c = vec![0.0f64; 64];
        let cost = Engine::<f64>::gemm(&e, &a, &b, &mut c).unwrap();
        assert!(cost.compute_secs > 0.0);
        assert_eq!(cost.transfer_secs, 0.0, "host engine has no PCIe term");
        // numerically correct?
        let mut want = vec![0.0f64; 64];
        crate::linalg::gemm(8, 8, 8, &a, &b, &mut want);
        assert_eq!(c, want);
    }

    #[test]
    fn trsm_inverse_of_gemm() {
        let e = CpuEngine::new(4);
        // L unit lower, X random; B = L X; solve must recover X.
        let l = vec![
            1.0f64, 0.0, 0.0, 0.0, //
            0.5, 1.0, 0.0, 0.0, //
            -0.25, 0.75, 1.0, 0.0, //
            0.1, -0.2, 0.3, 1.0,
        ];
        let mut rng = Prng::new(2);
        let mut x = vec![0.0f64; 16];
        rng.fill_normal(&mut x);
        let mut b = vec![0.0f64; 16];
        crate::linalg::gemm(4, 4, 4, &l, &x, &mut b);
        Engine::<f64>::trsm_llu(&e, &l, &mut b).unwrap();
        for i in 0..16 {
            assert!((b[i] - x[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn blas1_helpers() {
        let e = CpuEngine::new(16);
        let x = vec![1.0f32; 16];
        let y = vec![2.0f32; 16];
        let (d, cost) = Engine::<f32>::dot(&e, &x, &y);
        assert_eq!(d, 32.0);
        assert!(cost.total() > 0.0);
    }
}
