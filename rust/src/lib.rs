//! # CUPLSS-RS
//!
//! A reproduction of *"Developing a High Performance Software Library with
//! MPI and CUDA for Matrix Computations"* (Oancea & Andrei, 2015) as a
//! three-layer rust + JAX/Pallas + PJRT stack.
//!
//! The paper's CUPLSS library distributes dense matrices over an MPI cluster
//! (coarse-grained parallelism) and accelerates each node's local BLAS with
//! CUDA/CUBLAS (fine-grained parallelism).  Here:
//!
//! * the **cluster** is an in-process simulated MPI world — one OS thread per
//!   rank, lossless ordered channels, binomial-tree collectives, and a
//!   virtual-time model of a Gigabit-Ethernet network ([`comm`]);
//! * the **GPU** is an XLA/PJRT executable AOT-compiled from Pallas kernels
//!   ([`runtime`], [`accel::XlaEngine`]), with a calibrated GTX-280 cost
//!   model; the **ATLAS** serial-BLAS baseline is a pure-rust blocked BLAS
//!   ([`linalg`], [`accel::CpuEngine`]);
//! * the **solvers** are the paper's: blocked LU with partial pivoting and
//!   Cholesky (direct, both with depth-1 lookahead), CG / pipelined CG /
//!   BiCG / BiCGSTAB / GMRES(m) (non-stationary iterative), over 2-D
//!   block-cyclic distributed matrices ([`dist`], [`pblas`], [`solvers`]);
//! * **communication overlaps compute**: split-phase `isend`/`irecv` and
//!   `i`-collectives with request handles, a two-timeline virtual clock
//!   (NIC progresses during compute), pipelined SUMMA, split-phase sparse
//!   matvec and a Ghysels-style pipelined CG — see `DESIGN.md` §11 and
//!   `cargo bench --bench overlap`;
//! * **operands stay device-resident**: a per-rank [`accel::TileCache`]
//!   stops the paper's copy-per-call PCIe tax (operands stream only on
//!   first touch or after host mutation), and the Krylov BLAS-1 chains run
//!   as fused one-launch kernels — see `DESIGN.md` §12 and
//!   `cargo bench --bench residency`;
//! * **the surviving transfers hide behind compute**: a third virtual-clock
//!   timeline models the device's copy engine — hot paths prefetch their
//!   next operands async H2D and flush write-backs async D2H, so a
//!   transfer covered by compute costs zero makespan, and the matvec
//!   output stays device-resident via a fused `gemv_acc` — see `DESIGN.md`
//!   §13 and `cargo bench --bench prefetch`;
//! * the iterative solvers additionally accept **sparse** operands: a
//!   row-block-distributed CSR format ([`sparse`], [`pblas::pspmv()`]) behind
//!   the operator-generic [`pblas::LinOp`] trait, with 2-D/3-D Poisson
//!   stencil generators in [`workloads::stencil`] — the regime ("very
//!   large" systems) the paper motivates iterative methods with;
//! * **many right-hand sides amortize**: RHS-panel triangular solves
//!   ([`solvers::ptrsm`]), blocked CG/BiCGSTAB with per-column convergence
//!   masking ([`solvers::block_cg`]) — bit-identical per column to the
//!   looped single-RHS solvers — and a solve-request [`serve`] scheduler
//!   that batches compatible requests over one factorization or shared
//!   matvec sweeps and reports throughput + latency percentiles — see
//!   `DESIGN.md` §14 and `cargo bench --bench serving`.
//!
//! Mirroring the paper's Figure 2, the crate is layered:
//!
//! | CUPLSS level | this crate |
//! |---|---|
//! | 4. user API | [`cluster`], [`solvers`] entry points |
//! | 3. data distribution | [`dist`], [`sparse`], [`mesh`], [`pblas`] |
//! | 2. architecture independence | [`accel::Engine`] trait |
//! | 1. CUDA/CUBLAS/MPI/C runtimes | [`runtime`] (PJRT), [`linalg`], [`comm`] |
//!
//! See `README.md` for a quickstart, `DESIGN.md` for the substitution
//! table (what the paper ran on real hardware vs. what this repo
//! simulates; §10 covers the sparse subsystem, §11 the split-phase comm
//! layer, §12 the device-residency and kernel-fusion model) and
//! `EXPERIMENTS.md` for the regenerated Figures 3 and 4.

pub mod accel;
pub mod bench_harness;
pub mod cli;
pub mod cluster;
pub mod comm;
pub mod config;
pub mod dist;
pub mod error;
pub mod linalg;
pub mod mesh;
pub mod pblas;
pub mod runtime;
pub mod serve;
pub mod solvers;
pub mod sparse;
pub mod util;
pub mod workloads;

pub use error::{Error, Result};

/// Default library tile size (elements per tile edge).  Every distributed
/// matrix is stored as `TILE x TILE` local tiles so that each accelerator
/// call is one of a closed set of fixed-shape AOT executables.
pub const DEFAULT_TILE: usize = 256;

/// Scalar element trait: the library is generic over `f32` / `f64`
/// (the paper evaluates both single and double precision).
pub trait Scalar:
    Copy
    + Send
    + Sync
    + 'static
    + std::fmt::Debug
    + std::fmt::Display
    + num_traits::Float
    + num_traits::NumAssign
    + num_traits::FromPrimitive
    + num_traits::ToPrimitive
    + xla::NativeType
    + xla::ArrayElement
{
    /// Short dtype tag used in artifact names ("f32" / "f64").
    const DTYPE: &'static str;
    /// Bytes per element (for the network / PCIe cost models).
    const BYTES: usize;
    /// The reduced-precision companion dtype: what a mixed-precision solve
    /// stores, computes and communicates in.  `f32` for `f64`; `f32` is its
    /// own floor (`Lo = Self`), which is how the mixed path detects "no
    /// narrower dtype exists" (`Lo::BYTES == BYTES`) and degenerates to the
    /// uniform-precision flow.
    type Lo: Scalar;
    /// The wide accumulation dtype: residuals, dot products and recurrence
    /// scalars accumulate here.  `f64` for both `f32` and `f64`.
    type Hi: Scalar;
    /// Unit roundoff u = 2^-(mantissa bits + 1): the backward-error yard-
    /// stick the iterative-refinement loop converges against.
    const UNIT_ROUNDOFF: f64;
    /// Narrow to the storage/wire dtype (rounds to nearest).
    fn demote(self) -> Self::Lo;
    /// Widen a reduced-precision value back (exact).
    fn promote(lo: Self::Lo) -> Self;
    /// Widen to the accumulation dtype (exact).
    fn to_hi(self) -> Self::Hi;
    /// Narrow an accumulated value to the working dtype.
    fn from_hi(h: Self::Hi) -> Self;
}

impl Scalar for f32 {
    const DTYPE: &'static str = "f32";
    const BYTES: usize = 4;
    type Lo = f32;
    type Hi = f64;
    const UNIT_ROUNDOFF: f64 = f32::EPSILON as f64 / 2.0;
    fn demote(self) -> f32 {
        self
    }
    fn promote(lo: f32) -> f32 {
        lo
    }
    fn to_hi(self) -> f64 {
        self as f64
    }
    fn from_hi(h: f64) -> f32 {
        h as f32
    }
}

impl Scalar for f64 {
    const DTYPE: &'static str = "f64";
    const BYTES: usize = 8;
    type Lo = f32;
    type Hi = f64;
    const UNIT_ROUNDOFF: f64 = f64::EPSILON / 2.0;
    fn demote(self) -> f32 {
        self as f32
    }
    fn promote(lo: f32) -> f64 {
        lo as f64
    }
    fn to_hi(self) -> f64 {
        self
    }
    fn from_hi(h: f64) -> f64 {
        h
    }
}

/// Whether `S` has a strictly narrower storage dtype to mix down to.
/// `f64` does (`f32`); `f32` is already the floor.
pub fn mixed_capable<S: Scalar>() -> bool {
    <S::Lo as Scalar>::BYTES < S::BYTES
}
